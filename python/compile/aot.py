"""AOT lowering: integer inference graph -> HLO text artifacts.

Emits HLO **text**, NOT a serialized HloModuleProto: jax >= 0.5 writes
protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

The lowered module bakes the quantized weights (from weights.bin) in as
constants, so the rust runtime feeds a single int32 input tensor
[B, 512, 1] (int8-range sample values) and receives int32 logits [B, 2].
One artifact per batch size: the coordinator picks the executable that
matches its batch (1 = streaming, 6 = one vote group, 32 = offline
eval sweeps).

Usage: python -m compile.aot [--weights ../artifacts/weights.bin]
                             [--outdir ../artifacts] [--batches 1 6 32]
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import artifact, model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    CRITICAL: the text must be printed with ``print_large_constants``
    (the positional flag of ``as_hlo_text``). The default printer
    ELIDES big constants as ``constant({...})`` and the HLO parser
    re-materializes the elided payload as an iota-like filler — the
    module still parses, compiles, and runs, silently computing with
    garbage weights. (Found the hard way; guarded here and in
    python/tests/test_aot.py + rust integration tests.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text(True)  # True = print_large_constants
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_batch(layers, batch: int, use_pallas: bool = True) -> str:
    spec = jax.ShapeDtypeStruct((batch, model.REC_LEN, 1), jnp.int32)
    fn = lambda x: (model.forward_int(layers, x, use_pallas=use_pallas),)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--weights", type=str, default="../artifacts/weights.bin")
    ap.add_argument("--outdir", type=str, default="../artifacts")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 6, 32])
    args = ap.parse_args()

    layers = artifact.read_weights(args.weights)
    os.makedirs(args.outdir, exist_ok=True)
    # Runtime artifacts: the jnp-reference lowering. Interpret-mode
    # Pallas lowers its grid to XLA while-loops, which the CPU PJRT
    # client executes serially (~20× slower); the ref graph is the SAME
    # integer function (bit-exactness enforced by python tests and by
    # rust/tests/integration_bitexact.rs), so the request path ships
    # the fast lowering. (EXPERIMENTS.md §Perf L2.1.)
    for b in args.batches:
        text = lower_batch(layers, b, use_pallas=False)
        path = f"{args.outdir}/model_b{b}.hlo.txt"
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    # Semantics artifact: the Pallas/CMUL lowering, kept for the
    # cross-lowering equivalence test (and as what a TPU Mosaic build
    # would compile from).
    text = lower_batch(layers, 1, use_pallas=True)
    path = f"{args.outdir}/model_pallas_b1.hlo.txt"
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
