"""L2: the paper's 8-layer 1-D fully-convolutional network.

Two parallel definitions over the same architecture description:

* ``forward_float`` — float training graph (pure jnp, differentiable,
  with optional fake-quant + pruning masks for QAT); used only at build
  time by train.py.
* ``forward_int`` — the integer *inference* graph that calls the L1
  Pallas kernels and the shared requantization contract; this is what
  aot.py lowers to HLO text for the rust runtime.

Architecture (paper §2: "8-layer, one-dimensional, fully convolutional
network", 512-sample IEGM in, VA/non-VA out; channel counts chosen as
multiples of the chip's M=16 PE lanes, ~102 K parameters ≈ 3.9 MOPs per
inference, matching the 35 µs × 150 GOPS envelope of the paper within
the honesty of a simulator):

  idx  k  s  Cin  Cout  act
  1    7  2    1    16  relu
  2    5  2   16    32  relu
  3    5  2   32    48  relu
  4    5  2   48    64  relu
  5    5  2   64    64  relu
  6    3  2   64    96  relu
  7    3  2   96   128  relu
  8    1  1  128     2  none   → global average pool → int32 logits
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile import quantize as Q
from compile.kernels import sparse_conv1d as KN

REC_LEN = 512
NUM_CLASSES = 2  # non-VA, VA


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    k: int
    stride: int
    cin: int
    cout: int
    relu: bool
    nbits: int = 8  # CMUL precision for this layer (8/4/2/1)


def arch(nbits: int | list[int] = 8) -> list[LayerSpec]:
    """The 8-layer network. `nbits` may be a scalar or per-layer list
    (mixed-precision configuration)."""
    geo = [
        (7, 2, 1, 16, True),
        (5, 2, 16, 32, True),
        (5, 2, 32, 48, True),
        (5, 2, 48, 64, True),
        (5, 2, 64, 64, True),
        (3, 2, 64, 96, True),
        (3, 2, 96, 128, True),
        (1, 1, 128, NUM_CLASSES, False),
    ]
    bits = [nbits] * len(geo) if isinstance(nbits, int) else list(nbits)
    assert len(bits) == len(geo)
    return [LayerSpec(k, s, ci, co, r, nb)
            for (k, s, ci, co, r), nb in zip(geo, bits)]


def pad_amount(k: int, stride: int) -> tuple[int, int]:
    """'same'-style zero padding so Lout = L / stride (L divisible)."""
    p = k - stride
    return p // 2, p - p // 2


def out_len(l: int, spec: LayerSpec) -> int:
    pl_, pr = pad_amount(spec.k, spec.stride)
    return (l + pl_ + pr - spec.k) // spec.stride + 1


def init_params(key, specs: list[LayerSpec]) -> list[dict]:
    """He-normal float init."""
    params = []
    for spec in specs:
        key, k1 = jax.random.split(key)
        fan_in = spec.k * spec.cin
        w = jax.random.normal(k1, (spec.k, spec.cin, spec.cout),
                              dtype=jnp.float32) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros((spec.cout,), jnp.float32)})
    return params


def _pad(x, spec: LayerSpec):
    pl_, pr = pad_amount(spec.k, spec.stride)
    if pl_ == 0 and pr == 0:
        return x
    return jnp.pad(x, ((0, 0), (pl_, pr), (0, 0)))


def _conv_float(x, w, stride):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"))


def forward_float(params, x, specs, masks=None, fake_quant=False,
                  act_amax=None):
    """Float forward. x: float32 [B, 512, 1] -> logits float32 [B, 2].

    masks: optional pruning masks (list of bool arrays or None).
    fake_quant: apply STE weight fake-quant at each layer's nbits; with
    act_amax (list of floats, len = n_layers+1) also fake-quant the
    activations — full QAT matching the integer contract.
    """
    a = x
    if fake_quant and act_amax is not None:
        a = Q.fake_quant_act(a, act_amax[0])
    for i, (p, spec) in enumerate(zip(params, specs)):
        w = p["w"]
        if masks is not None and masks[i] is not None:
            w = w * masks[i]
        if fake_quant:
            w = Q.fake_quant_weight(w, spec.nbits)
        a = _conv_float(_pad(a, spec), w, spec.stride) + p["b"]
        if spec.relu:
            a = jax.nn.relu(a)
            if fake_quant and act_amax is not None:
                a = Q.fake_quant_act(a, act_amax[i + 1])
    return jnp.mean(a, axis=1)  # global average pool -> [B, 2]


def calibrate_amax(params, x, specs, masks=None) -> list[float]:
    """Per-layer activation absolute maxima on a calibration batch:
    [input, post-L1, ..., post-L7]. The head layer needs no output
    scale (the int32 accumulator is pooled directly)."""
    amax = [float(jnp.max(jnp.abs(x)))]
    a = x
    for i, (p, spec) in enumerate(zip(params, specs[:-1])):
        w = p["w"]
        if masks is not None and masks[i] is not None:
            w = w * masks[i]
        a = _conv_float(_pad(a, spec), w, spec.stride) + p["b"]
        if spec.relu:
            a = jax.nn.relu(a)
        amax.append(float(jnp.max(jnp.abs(a))))
    return amax


# ----------------------------------------------------------------------
# Integer model: quantize trained params, build the inference graph.
# ----------------------------------------------------------------------
@dataclasses.dataclass
class IntLayer:
    spec: LayerSpec
    w_q: np.ndarray      # int32 [K, Cin, Cout], zeros where pruned
    bias_q: np.ndarray   # int32 [Cout]
    m0: np.ndarray       # int32 [Cout]   (zeros for the head layer)
    shift: int
    s_in: float
    s_out: float


def quantize_model(params, specs, amax, input_scale) -> list[IntLayer]:
    """Float params + calibration -> integer layer descriptors.

    Scales: s_act[0] = input_scale (chip ADC), s_act[i] from calibrated
    amax; head layer keeps its raw int32 accumulator (no requant).
    """
    s_act = [input_scale] + [Q.act_scale(a) for a in amax[1:]]
    layers = []
    for i, (p, spec) in enumerate(zip(params, specs)):
        w = np.asarray(p["w"], dtype=np.float64)
        b = np.asarray(p["b"], dtype=np.float64)
        w_q, s_w = Q.quantize_weights(w, spec.nbits, axis=-1)
        s_in = s_act[i]
        bias_q = Q.round_half_up(b / (s_in * s_w.reshape(-1))).astype(np.int64)
        assert np.all(np.abs(bias_q) < 2**31), "bias overflow"
        if i < len(specs) - 1:
            s_out = s_act[i + 1]
            m0, shift = Q.requant_params(s_in, s_w, s_out)
        else:
            s_out = s_in  # head: raw accumulator, scale unused
            m0, shift = np.zeros(spec.cout, np.int32), 0
        layers.append(IntLayer(spec, w_q.astype(np.int32),
                               bias_q.astype(np.int32), m0, shift,
                               float(s_in), float(s_out)))
    return layers


def _requant_jnp(acc, m0, shift, relu):
    """Integer requant in the AOT graph — must mirror Q.requant and
    rust nn/requant.rs bit-exactly. int64 intermediate (x64 enabled by
    aot.py / train.py)."""
    t = acc.astype(jnp.int64) * m0.astype(jnp.int64)[None, None, :]
    t = jnp.right_shift(t + (1 << (shift - 1)), shift)
    if relu:
        t = jnp.maximum(t, 0)
    return jnp.clip(t, Q.QMIN, Q.QMAX).astype(jnp.int32)


def forward_int(layers: list[IntLayer], x_q, use_pallas: bool = True):
    """Integer inference. x_q: int32 [B, 512, 1] (int8-range values).

    Returns int32 logits [B, 2] = global-avg-pooled head accumulator.
    use_pallas=False swaps in the jnp reference ops (oracle path for
    tests; identical numerics by construction).
    """
    from compile.kernels import ref as REF
    a = x_q
    n = len(layers)
    for i, ly in enumerate(layers):
        spec = ly.spec
        a = _pad(a, spec)
        w = jnp.asarray(ly.w_q)
        b = jnp.asarray(ly.bias_q)
        if use_pallas:
            acc = KN.sparse_conv1d(a, w, b, stride=spec.stride,
                                   nbits=spec.nbits)
        else:
            acc = REF.conv1d_int_ref(a, w, b, stride=spec.stride)
        if i < n - 1:
            a = _requant_jnp(acc, jnp.asarray(ly.m0), ly.shift, spec.relu)
        else:
            a = acc  # head: int32 accumulator [B, 4, 2]
    # MPE global average pool (round-half-up integer division)
    if use_pallas:
        pooled = KN.pool1d(a, pool=a.shape[1], mode="avg")[:, 0, :]
    else:
        pooled = REF.global_avgpool_ref(a)
    return pooled


def mac_counts(specs: list[LayerSpec], l_in: int = REC_LEN) -> list[int]:
    """Dense MAC count per layer (the chip's OPs accounting: 1 MAC =
    2 OPs)."""
    out, l = [], l_in
    for spec in specs:
        lo = out_len(l, spec)
        out.append(lo * spec.k * spec.cin * spec.cout)
        l = lo
    return out
