"""Shared fixed-point quantization contract.

This module is the single source of truth for the integer semantics used
by ALL five execution paths (python fake-quant training, L1 Pallas
kernel, L2 AOT inference graph, rust golden model `rust/src/nn/`, and the
chip simulator PE datapath `rust/src/sim/`). Any change here must be
mirrored in rust/src/nn/requant.rs.

Contract
--------
* activations: signed, symmetric, per-layer scale ``s_a``; stored values
  in [-127, 127] (never -128, so 8-bit negate is safe in the CMUL).
* weights: signed, symmetric, per-output-channel scale ``s_w[co]``;
  ``nbits`` in {8, 4, 2, 1}; range [-(2^{nbits-1}-1), 2^{nbits-1}-1]
  (again excluding the asymmetric minimum).
* bias: int32, scale ``s_a * s_w[co]``.
* accumulator: int32 (worst case |acc| <= 512*127*127 < 2^23, safe).
* requantization to the next layer's scale: fixed-point multiply
  ``y = clamp(rshift_round(acc * M0, shift), -127, 127)`` with M0 int32,
  shift int, and **round-half-up** (add 2^(shift-1) then arithmetic
  right shift). acc*M0 is evaluated in int64.
"""

from __future__ import annotations

import numpy as np

QMIN, QMAX = -127, 127


def bits_range(nbits: int) -> int:
    """Symmetric max magnitude for an nbits signed weight: 2^(n-1)-1,
    except 1-bit weights which are ternary {-1, 0, +1} (qmax=1)."""
    if nbits == 1:
        return 1
    return (1 << (nbits - 1)) - 1


def quantize_weights(w: np.ndarray, nbits: int, axis: int = -1):
    """Per-output-channel symmetric quantization.

    w: float array [K, Cin, Cout]; axis selects the per-channel dim.
    Returns (w_q int32 array, s_w float array broadcastable over w).
    """
    qmax = bits_range(nbits)
    red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
    amax = np.maximum(np.abs(w).max(axis=red, keepdims=True), 1e-12)
    s_w = amax / qmax
    w_q = np.clip(round_half_up(w / s_w), -qmax, qmax).astype(np.int32)
    return w_q, s_w


def round_half_up(x: np.ndarray) -> np.ndarray:
    """round-half-up toward +inf: floor(x + 0.5). Matches the integer
    requant rounding (add 2^(s-1), arithmetic shift)."""
    return np.floor(x + 0.5)


def act_scale(amax: float) -> float:
    """Activation scale from a calibrated absolute maximum."""
    return max(amax, 1e-12) / QMAX


def requant_params(s_in: float, s_w: np.ndarray, s_out: float,
                   shift: int = 24):
    """Fixed-point multiplier per output channel.

    real multiplier  M = s_in * s_w / s_out  (must be < 2^7 at shift=24
    to keep M0 in int32; our layers satisfy M < 1 typically).
    Returns (M0 int32 [Cout], shift).
    """
    m = (s_in * np.asarray(s_w).reshape(-1)) / s_out
    m0 = round_half_up(m * (1 << shift)).astype(np.int64)
    assert np.all(np.abs(m0) < 2**31), "requant multiplier overflow"
    return m0.astype(np.int32), shift


def requant(acc: np.ndarray, m0: np.ndarray, shift: int,
            relu: bool = True) -> np.ndarray:
    """int32 accumulator -> int8-range activation (numpy reference).

    acc: int32 [..., Cout]; m0: int32 [Cout].
    """
    t = acc.astype(np.int64) * m0.astype(np.int64)
    t = (t + (1 << (shift - 1))) >> shift  # round-half-up, arithmetic
    if relu:
        t = np.maximum(t, 0)
    return np.clip(t, QMIN, QMAX).astype(np.int32)


def fake_quant_act(x, amax: float):
    """Straight-through fake quantization of activations (training)."""
    import jax.numpy as jnp
    s = act_scale(amax)
    q = jnp.clip(jnp.floor(x / s + 0.5), QMIN, QMAX)
    deq = q * s
    # straight-through estimator
    return x + (deq - x) if not hasattr(x, "aval") else _ste(x, deq)


def _ste(x, deq):
    import jax
    return x + jax.lax.stop_gradient(deq - x)


def fake_quant_weight(w, nbits: int):
    """STE fake quantization of weights, per-output-channel (axis -1)."""
    import jax
    import jax.numpy as jnp
    qmax = bits_range(nbits)
    red = tuple(range(w.ndim - 1))
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=red, keepdims=True), 1e-12)
    s = amax / qmax
    q = jnp.clip(jnp.floor(w / s + 0.5), -qmax, qmax)
    deq = q * s
    return w + jax.lax.stop_gradient(deq - w)
