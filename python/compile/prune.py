"""Co-design pruning (§2 of the paper).

The paper's compiler implements "a co-design pruning mechanism ... to
balance workloads and execution times across and within PEs". On the
chip, output channels map onto the 16 PE/MPE lanes of an SPE and all
lanes run synchronously, so a layer finishes when its *slowest* lane
finishes: unbalanced sparsity buys energy but not latency.

Two modes (the `sparsity` bench ablates them):

* ``balanced`` (the paper's scheme): per output channel, keep exactly
  ``round((1-sparsity)·K·Cin)`` largest-magnitude weights → every PE
  lane has the identical non-zero count, so zero-skipping converts 1:1
  into cycles.
* ``global``: one magnitude threshold per layer (classic magnitude
  pruning) → same total sparsity, unbalanced lanes.
"""

from __future__ import annotations

import numpy as np


def balanced_mask(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Per-output-channel top-k mask. w: [K, Cin, Cout] -> bool mask."""
    k, cin, cout = w.shape
    keep = max(1, int(round((1.0 - sparsity) * k * cin)))
    flat = np.abs(w).reshape(k * cin, cout)
    mask = np.zeros_like(flat, dtype=bool)
    # top-`keep` per column (output channel)
    idx = np.argsort(-flat, axis=0, kind="stable")[:keep, :]
    for co in range(cout):
        mask[idx[:, co], co] = True
    return mask.reshape(k, cin, cout)


def global_mask(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Layer-wide magnitude threshold mask (unbalanced baseline)."""
    flat = np.abs(w).reshape(-1)
    keep = max(1, int(round((1.0 - sparsity) * flat.size)))
    thresh = np.sort(flat)[::-1][keep - 1]
    return np.abs(w) >= thresh


def make_masks(params: list[dict], sparsity: float, mode: str = "balanced",
               skip_first_last: bool = True) -> list[np.ndarray | None]:
    """Masks for a list of conv layers ({'w': [K,Cin,Cout], ...}).

    First and last layers are conventionally kept dense (tiny, and
    accuracy-critical); the paper's 50 % figure is network-wide — we
    raise the middle-layer sparsity slightly so the *network* hits the
    target even with dense first/last layers.
    """
    n = len(params)
    sizes = np.array([p["w"].size for p in params], dtype=np.float64)
    prunable = [not (skip_first_last and (i == 0 or i == n - 1))
                for i in range(n)]
    target_zeros = sparsity * sizes.sum()
    prunable_size = sizes[np.array(prunable)].sum()
    s_eff = min(0.9375, target_zeros / max(prunable_size, 1.0))
    masks: list[np.ndarray | None] = []
    for i, p in enumerate(params):
        if not prunable[i]:
            masks.append(None)
            continue
        fn = balanced_mask if mode == "balanced" else global_mask
        masks.append(fn(p["w"], s_eff))
    return masks


def apply_masks(params: list[dict], masks) -> list[dict]:
    out = []
    for p, m in zip(params, masks):
        q = dict(p)
        if m is not None:
            q["w"] = p["w"] * m
        out.append(q)
    return out


def network_sparsity(params: list[dict]) -> float:
    total = sum(p["w"].size for p in params)
    zeros = sum(int((np.asarray(p["w"]) == 0).sum()) for p in params)
    return zeros / total


def lane_imbalance(w: np.ndarray) -> float:
    """Max/mean ratio of per-output-channel non-zero counts — the
    straggler factor a synchronous PE array pays. 1.0 == perfectly
    balanced."""
    nnz = (np.abs(w.reshape(-1, w.shape[-1])) > 0).sum(axis=0)
    mean = nnz.mean()
    return float(nnz.max() / mean) if mean > 0 else 1.0
