"""Binary artifact formats shared with the rust side.

weights.bin (little-endian), parsed by rust/src/compiler/loader.rs:

  magic   4  b"VACM"
  version u32 = 2
  n_layer u32
  per layer:
    k, stride, cin, cout      4 × u32
    relu, nbits, shift        3 × u32
    s_in, s_out               2 × f64
    w_q   : i8  × (k·cin·cout)   (order [K, Cin, Cout], C-contiguous)
    bias  : i32 × cout
    m0    : i32 × cout

eval.bin — fixed evaluation corpus (quantized inputs + labels), parsed
by rust/src/data/dataset.rs; this is the SAME byte stream python trained
against, so rust-vs-python accuracy comparisons are bit-exact:

  magic   4  b"VAEV"
  version u32 = 1
  n_rec   u32   rec_len u32
  labels  : i32 × n_rec          (4-class ids; VA = {2, 3})
  x_q     : i8  × n_rec·rec_len  (chip ADC int8 samples)
"""

from __future__ import annotations

import json
import struct

import numpy as np

WEIGHTS_MAGIC = b"VACM"
WEIGHTS_VERSION = 2
EVAL_MAGIC = b"VAEV"
EVAL_VERSION = 1


def write_weights(path: str, layers) -> None:
    """layers: list[model.IntLayer]."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<II", WEIGHTS_VERSION, len(layers)))
        for ly in layers:
            s = ly.spec
            f.write(struct.pack("<7I", s.k, s.stride, s.cin, s.cout,
                                int(s.relu), s.nbits, ly.shift))
            f.write(struct.pack("<2d", ly.s_in, ly.s_out))
            w = np.asarray(ly.w_q, dtype=np.int64)
            assert np.all((w >= -127) & (w <= 127))
            f.write(w.astype(np.int8).tobytes(order="C"))
            f.write(np.asarray(ly.bias_q, dtype=np.int32).tobytes())
            f.write(np.asarray(ly.m0, dtype=np.int32).tobytes())


def read_weights(path: str):
    """Round-trip reader (tests + debugging)."""
    from compile.model import IntLayer, LayerSpec
    with open(path, "rb") as f:
        assert f.read(4) == WEIGHTS_MAGIC
        version, n = struct.unpack("<II", f.read(8))
        assert version == WEIGHTS_VERSION
        layers = []
        for _ in range(n):
            k, stride, cin, cout, relu, nbits, shift = struct.unpack(
                "<7I", f.read(28))
            s_in, s_out = struct.unpack("<2d", f.read(16))
            w = np.frombuffer(f.read(k * cin * cout), dtype=np.int8)
            w = w.reshape(k, cin, cout).astype(np.int32)
            bias = np.frombuffer(f.read(4 * cout), dtype=np.int32).copy()
            m0 = np.frombuffer(f.read(4 * cout), dtype=np.int32).copy()
            spec = LayerSpec(k, stride, cin, cout, bool(relu), nbits)
            layers.append(IntLayer(spec, w, bias, m0, shift, s_in, s_out))
        return layers


def write_eval(path: str, x_q: np.ndarray, labels: np.ndarray) -> None:
    """x_q: int8 [N, L]; labels: int32 [N] (4-class)."""
    n, l = x_q.shape
    with open(path, "wb") as f:
        f.write(EVAL_MAGIC)
        f.write(struct.pack("<III", EVAL_VERSION, n, l))
        f.write(labels.astype(np.int32).tobytes())
        f.write(x_q.astype(np.int8).tobytes(order="C"))


def read_eval(path: str):
    with open(path, "rb") as f:
        assert f.read(4) == EVAL_MAGIC
        version, n, l = struct.unpack("<III", f.read(12))
        assert version == EVAL_VERSION
        labels = np.frombuffer(f.read(4 * n), dtype=np.int32).copy()
        x_q = np.frombuffer(f.read(n * l), dtype=np.int8)
        return x_q.reshape(n, l).copy(), labels


def write_qparams(path: str, meta: dict) -> None:
    with open(path, "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
