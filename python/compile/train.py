"""Build-time training pipeline (runs ONCE; never on the request path).

Stages (paper §2: "co-design pruning with 50 % sparsity and
hardware-aware quantization with 8-bit precision"):

  1. float training of the 8-layer 1-D FCN on the synthetic IEGM corpus
  2. co-design (PE-balanced) magnitude pruning to 50 % network sparsity
  3. masked fine-tuning with fake-quant QAT (STE), matching the chip's
     integer contract
  4. PTQ calibration of activation scales on the training set
  5. integer conversion + accuracy audit (float vs int vs voted
     diagnostic metrics)
  6. artifact emission: weights.bin, eval.bin, qparams.json

Usage: python -m compile.train [--epochs 40] [--noise 0.35] [--out DIR]
"""

from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile import artifact, data, model, prune  # noqa: E402

SEED_TRAIN, SEED_VAL, SEED_TEST = 42, 43, 44


# ----------------------------------------------------------------------
# Minimal Adam (no external deps)
# ----------------------------------------------------------------------
def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state["v"], grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps),
                          params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(y.shape[0]), y])


def make_train_step(specs, masks=None, fake_quant=False, act_amax=None):
    def loss_fn(params, x, y):
        logits = model.forward_float(params, x, specs, masks=masks,
                                     fake_quant=fake_quant,
                                     act_amax=act_amax)
        return cross_entropy(logits, y)

    @jax.jit
    def step(params, opt, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss

    return step


def train_loop(params, specs, x, y, epochs, lr, batch, rng,
               masks=None, fake_quant=False, act_amax=None, tag=""):
    step = make_train_step(specs, masks, fake_quant, act_amax)
    opt = adam_init(params)
    n = x.shape[0]
    xd, yd = jnp.asarray(x[..., None], jnp.float32), jnp.asarray(y)
    for ep in range(epochs):
        perm = rng.permutation(n)
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = perm[i: i + batch]
            params, opt, loss = step(params, opt, xd[idx], yd[idx],
                                     lr * (0.5 ** (ep // max(epochs // 3, 1))))
            losses.append(float(loss))
        if ep % 5 == 0 or ep == epochs - 1:
            print(f"  [{tag}] epoch {ep:3d}  loss {np.mean(losses):.4f}")
    return params


def accuracy_float(params, specs, x, y, masks=None):
    logits = model.forward_float(params, jnp.asarray(x[..., None]),
                                 specs, masks=masks)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))


def eval_int(layers, x_q, batch=64, use_pallas=False):
    """Integer-model predictions for an int8 corpus [N, L]."""
    preds = []
    fwd = jax.jit(lambda v: model.forward_int(layers, v,
                                              use_pallas=use_pallas))
    for i in range(0, x_q.shape[0], batch):
        xb = jnp.asarray(x_q[i: i + batch, :, None], jnp.int32)
        logits = fwd(xb)
        preds.append(np.argmax(np.asarray(logits), axis=-1))
    return np.concatenate(preds)


def vote_metrics(pred_bin: np.ndarray, y_bin: np.ndarray, group: int = 6,
                 seed: int = 7):
    """Paper's diagnosis protocol: majority vote over `group` recordings
    of the same episode. Groups are drawn per-class so every group is
    label-homogeneous (recordings from one episode share ground truth).
    Returns (diag_acc, precision, recall, n_groups)."""
    rng = np.random.default_rng(seed)
    tp = fp = tn = fn = 0
    for cls in (0, 1):
        idx = np.where(y_bin == cls)[0]
        rng.shuffle(idx)
        for i in range(0, len(idx) - group + 1, group):
            g = idx[i: i + group]
            vote = int(pred_bin[g].sum() * 2 > group)  # majority
            if cls == 1 and vote == 1:
                tp += 1
            elif cls == 1:
                fn += 1
            elif vote == 1:
                fp += 1
            else:
                tn += 1
    total = tp + fp + tn + fn
    acc = (tp + tn) / max(total, 1)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return acc, prec, rec, total


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--finetune-epochs", type=int, default=15)
    ap.add_argument("--n-per-class", type=int, default=384)
    ap.add_argument("--n-test-per-class", type=int, default=250)
    ap.add_argument("--noise", type=float, default=0.6,
                    help="sensor noise RMS (tuned so per-recording "
                         "accuracy lands near the paper's 92.35%)")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--nbits", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--out", type=str, default="../artifacts")
    args = ap.parse_args()

    t0 = time.time()
    specs = model.arch(args.nbits)
    print(f"== corpus (noise_rms={args.noise}) ==")
    xtr, ytr4 = data.make_corpus(SEED_TRAIN, args.n_per_class,
                                 noise_rms=args.noise)
    xte, yte4 = data.make_corpus(SEED_TEST, args.n_test_per_class,
                                 noise_rms=args.noise)
    ytr = data.make_binary_labels(ytr4)
    yte = data.make_binary_labels(yte4)
    print(f"  train {xtr.shape}  test {xte.shape}")

    rng = np.random.default_rng(0)
    params = model.init_params(jax.random.PRNGKey(0), specs)

    print("== stage 1: float training ==")
    params = train_loop(params, specs, xtr, ytr, args.epochs, args.lr,
                        args.batch, rng, tag="float")
    acc_float = accuracy_float(params, specs, xte, yte)
    print(f"  float test acc {acc_float:.4f}")

    print(f"== stage 2: co-design pruning to {args.sparsity:.0%} ==")
    params_np = [{"w": np.asarray(p["w"]), "b": np.asarray(p["b"])}
                 for p in params]
    masks = prune.make_masks(params_np, args.sparsity, mode="balanced")
    masks_j = [None if m is None else jnp.asarray(m) for m in masks]
    net_sp = prune.network_sparsity(prune.apply_masks(
        params_np, [None if m is None else m for m in masks]))
    print(f"  network sparsity {net_sp:.3f}")

    print("== stage 3: masked fine-tune + QAT ==")
    amax0 = model.calibrate_amax(params, jnp.asarray(xtr[:256, :, None]),
                                 specs, masks=masks_j)
    params = train_loop(params, specs, xtr, ytr, args.finetune_epochs,
                        args.lr * 0.3, args.batch, rng, masks=masks_j,
                        fake_quant=True, act_amax=amax0, tag="qat")
    acc_pruned = accuracy_float(params, specs, xte, yte, masks=masks_j)
    print(f"  pruned+QAT float test acc {acc_pruned:.4f}")

    print("== stage 4: PTQ calibration ==")
    amax = model.calibrate_amax(params, jnp.asarray(xtr[:512, :, None]),
                                specs, masks=masks_j)
    print("  amax:", [f"{a:.3f}" for a in amax])

    print("== stage 5: integer conversion + audit ==")
    params_masked = [
        {"w": np.asarray(p["w"]) * (1 if m is None else np.asarray(m)),
         "b": np.asarray(p["b"])}
        for p, m in zip(params, masks_j)]
    layers = model.quantize_model(params_masked, specs, amax,
                                  data.INPUT_SCALE)
    xte_q = np.stack([data.quantize_input(r) for r in xte])
    pred = eval_int(layers, xte_q)
    acc_int = float(np.mean(pred == yte))
    diag, prec, rec, n_groups = vote_metrics(pred, yte)
    print(f"  int test acc {acc_int:.4f}")
    print(f"  diagnostic (vote of 6, {n_groups} groups): "
          f"acc {diag:.4f} precision {prec:.4f} recall {rec:.4f}")
    # pallas path spot check (slow in interpret mode -> subset)
    pred_pl = eval_int(layers, xte_q[:32], use_pallas=True)
    assert (pred_pl == pred[:32]).all(), "pallas vs ref disagree"
    print("  pallas-vs-ref spot check OK")

    print("== stage 6: artifacts ==")
    import os
    os.makedirs(args.out, exist_ok=True)
    artifact.write_weights(f"{args.out}/weights.bin", layers)
    artifact.write_eval(f"{args.out}/eval.bin", xte_q, yte4)
    per_layer_sparsity = [
        float((np.asarray(ly.w_q) == 0).mean()) for ly in layers]
    artifact.write_qparams(f"{args.out}/qparams.json", {
        "arch": [[s.k, s.stride, s.cin, s.cout, int(s.relu), s.nbits]
                 for s in specs],
        "input_scale": data.INPUT_SCALE,
        "noise_rms": args.noise,
        "sparsity_target": args.sparsity,
        "sparsity_network": net_sp,
        "sparsity_per_layer": per_layer_sparsity,
        "mac_per_layer": model.mac_counts(specs),
        "acc_float": acc_float,
        "acc_pruned_qat": acc_pruned,
        "acc_int": acc_int,
        "diag_acc": diag,
        "diag_precision": prec,
        "diag_recall": rec,
        "vote_group": 6,
        "seeds": {"train": SEED_TRAIN, "val": SEED_VAL, "test": SEED_TEST},
        "paper": {"acc_int": 0.9235, "diag_acc": 0.9995,
                  "diag_precision": 0.9988, "diag_recall": 0.9984},
    })
    print(f"  wrote weights.bin / eval.bin / qparams.json to {args.out}")
    print(f"done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
