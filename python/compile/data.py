"""Synthetic IEGM corpus generator (build-time mirror of rust/src/data/).

The paper's data (SingularMedical single-lead RVA-Bi IEGM, 512 samples @
250 Hz, band-passed 15-55 Hz) is proprietary; we substitute a parametric
morphology model that preserves the discriminative structure of the VA
detection task:

  non-VA classes : NSR  (normal sinus rhythm, 60-100 bpm, regular RR)
                   SVT  (supraventricular tachycardia, 150-220 bpm,
                         regular RR, narrow deflection)
  VA classes     : VT   (ventricular tachycardia, 160-250 bpm, regular,
                         wide monomorphic deflection)
                   VF   (ventricular fibrillation, chaotic narrow-band
                         oscillation 4-7 Hz dominant, no discrete QRS)

Each recording is 512 samples at 250 Hz (2.048 s), band-pass filtered
15-55 Hz (2nd-order Butterworth biquad cascade, same coefficients as the
rust DSP front end), normalized, then quantized to int8 at the chip's
input scale.

Determinism: a splitmix64-seeded generator — the same seed reproduces the
same corpus within each language. The rust generator
(rust/src/data/) implements the identical equations and PRNG (the PRNG
stream is bit-identical — golden vectors in both test suites); the
float morphology may differ by libm ULPs across languages, so
*bit-exact* cross-language evaluation uses the serialized eval.bin
corpus, and the rust generator is used for streaming/scale workloads.
"""

from __future__ import annotations

import dataclasses

import numpy as np

FS_HZ = 250.0
REC_LEN = 512
BAND_LO_HZ = 15.0
BAND_HI_HZ = 55.0

# Class ids (shared with rust/src/data/iegm.rs)
CLS_NSR = 0
CLS_SVT = 1
CLS_VT = 2
CLS_VF = 3
VA_CLASSES = (CLS_VT, CLS_VF)
CLASS_NAMES = {CLS_NSR: "NSR", CLS_SVT: "SVT", CLS_VT: "VT", CLS_VF: "VF"}


def is_va(cls: int) -> bool:
    return cls in VA_CLASSES


# ----------------------------------------------------------------------
# splitmix64 — tiny deterministic PRNG implemented identically in rust.
# ----------------------------------------------------------------------
class SplitMix64:
    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def uniform(self) -> float:
        """U[0, 1) with 53-bit resolution (same as rust)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.uniform()

    def gauss(self) -> float:
        """Box-Muller, consuming exactly two uniforms (no caching, so the
        stream position is identical in rust)."""
        u1 = self.uniform()
        u2 = self.uniform()
        u1 = max(u1, 1e-12)
        return float(np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2))


# ----------------------------------------------------------------------
# Band-pass front end (Butterworth 2nd-order HP @ 15 Hz + LP @ 55 Hz),
# fixed coefficients shared with rust/src/signal/filter_design.rs.
# ----------------------------------------------------------------------
def _butter2(fc_hz: float, fs_hz: float, highpass: bool):
    """RBJ-cookbook biquad with Q = 1/sqrt(2) (Butterworth)."""
    w0 = 2.0 * np.pi * fc_hz / fs_hz
    cw, sw = np.cos(w0), np.sin(w0)
    q = 1.0 / np.sqrt(2.0)
    alpha = sw / (2.0 * q)
    if highpass:
        b0, b1, b2 = (1 + cw) / 2, -(1 + cw), (1 + cw) / 2
    else:
        b0, b1, b2 = (1 - cw) / 2, 1 - cw, (1 - cw) / 2
    a0, a1, a2 = 1 + alpha, -2 * cw, 1 - alpha
    return np.array([b0, b1, b2]) / a0, np.array([1.0, a1 / a0, a2 / a0])


def _biquad(x: np.ndarray, b: np.ndarray, a: np.ndarray) -> np.ndarray:
    y = np.zeros_like(x)
    x1 = x2 = y1 = y2 = 0.0
    for i, xi in enumerate(x):
        yi = b[0] * xi + b[1] * x1 + b[2] * x2 - a[1] * y1 - a[2] * y2
        x2, x1 = x1, xi
        y2, y1 = y1, yi
        y[i] = yi
    return y


def bandpass(x: np.ndarray, fs_hz: float = FS_HZ) -> np.ndarray:
    """15-55 Hz Butterworth band-pass (HP2 then LP2), direct-form I."""
    bh, ah = _butter2(BAND_LO_HZ, fs_hz, highpass=True)
    bl, al = _butter2(BAND_HI_HZ, fs_hz, highpass=False)
    return _biquad(_biquad(x.astype(np.float64), bh, ah), bl, al)


# ----------------------------------------------------------------------
# Morphology models
# ----------------------------------------------------------------------
def _spike_train(rng: SplitMix64, n: int, rate_bpm: float, jitter: float,
                 width_s: float, amp: float, biphasic: float) -> np.ndarray:
    """Sequence of Gaussian-derivative deflections (QRS-like) at the given
    rate. `biphasic` in [0,1] mixes mono- vs biphasic shape; `width_s` is
    the deflection half-width."""
    sig = np.zeros(n)
    t = np.arange(n) / FS_HZ
    period = 60.0 / rate_bpm
    # random initial phase so recordings are not beat-aligned
    tc = rng.range(0.0, period)
    while tc < n / FS_HZ + 2 * width_s:
        w = width_s * (1.0 + 0.1 * rng.gauss())
        a = amp * (1.0 + 0.1 * rng.gauss())
        d = (t - tc) / max(w, 1e-4)
        mono = np.exp(-0.5 * d * d)
        bi = -d * np.exp(-0.5 * d * d) * 1.6487212707001282  # exp(0.5)
        sig += a * ((1.0 - biphasic) * mono + biphasic * bi)
        tc += period * (1.0 + jitter * rng.gauss())
    return sig


def _vf_chaos(rng: SplitMix64, n: int) -> np.ndarray:
    """VF: sum of 3 drifting sinusoids in the 4-7 Hz band with random walk
    amplitude — coarse fibrillatory baseline, no discrete activations."""
    t = np.arange(n) / FS_HZ
    sig = np.zeros(n)
    for _ in range(3):
        f0 = rng.range(4.0, 7.0)
        fm = rng.range(0.1, 0.5)     # frequency wobble rate
        fd = rng.range(0.3, 1.2)     # wobble depth
        ph = rng.range(0.0, 2.0 * np.pi)
        am = 0.5 + 0.5 * rng.uniform()
        inst = f0 + fd * np.sin(2 * np.pi * fm * t + ph)
        phase = 2 * np.pi * np.cumsum(inst) / FS_HZ
        sig += am * np.sin(phase + ph)
    # VF also shows high-frequency fractionation
    for _ in range(2):
        f0 = rng.range(12.0, 25.0)
        ph = rng.range(0.0, 2.0 * np.pi)
        am = 0.15 + 0.2 * rng.uniform()
        sig += am * np.sin(2 * np.pi * f0 * t + ph)
    return sig


@dataclasses.dataclass
class RecordingParams:
    cls: int
    noise_rms: float = 0.05
    wander_amp: float = 0.3


def synth_recording(rng: SplitMix64, cls: int, noise_rms: float = 0.05,
                    wander_amp: float = 0.3) -> np.ndarray:
    """One raw (pre-filter) recording of REC_LEN samples, float64."""
    n = REC_LEN
    if cls == CLS_NSR:
        rate = rng.range(55.0, 100.0)
        sig = _spike_train(rng, n, rate, 0.04, 0.012, 1.0, 0.8)
        # far-field T-wave-ish slow component (mostly filtered out)
        sig += _spike_train(rng, n, rate, 0.04, 0.06, 0.25, 0.0)
    elif cls == CLS_SVT:
        rate = rng.range(150.0, 220.0)
        sig = _spike_train(rng, n, rate, 0.02, 0.011, 0.9, 0.8)
    elif cls == CLS_VT:
        rate = rng.range(160.0, 250.0)
        # wide, monomorphic, large-amplitude ventricular deflections
        sig = _spike_train(rng, n, rate, 0.015, 0.030, 1.3, 0.45)
    elif cls == CLS_VF:
        sig = _vf_chaos(rng, n)
    else:
        raise ValueError(f"unknown class {cls}")
    # baseline wander (respiration ~0.3 Hz) + white noise
    t = np.arange(n) / FS_HZ
    ph = rng.range(0.0, 2.0 * np.pi)
    sig = sig + wander_amp * np.sin(2 * np.pi * 0.3 * t + ph)
    noise = np.array([rng.gauss() for _ in range(n)]) * noise_rms
    return sig + noise


def preprocess(raw: np.ndarray) -> np.ndarray:
    """Band-pass then per-recording RMS normalization (target RMS 0.25 of
    full scale) and clamp to [-1, 1]. Shared with the rust front end."""
    y = bandpass(raw)
    rms = float(np.sqrt(np.mean(y * y)))
    if rms > 1e-9:
        y = y * (0.25 / rms)
    return np.clip(y, -1.0, 1.0)


INPUT_SCALE = 1.0 / 127.0  # int8 input quantization scale


def quantize_input(x: np.ndarray) -> np.ndarray:
    """float [-1,1] -> int8, round-half-away-from-zero (chip ADC front)."""
    q = np.where(x >= 0, np.floor(x / INPUT_SCALE + 0.5),
                 np.ceil(x / INPUT_SCALE - 0.5))
    return np.clip(q, -127, 127).astype(np.int8)


def make_corpus(seed: int, n_per_class: int,
                noise_rms: float = 0.05) -> tuple[np.ndarray, np.ndarray]:
    """Returns (x, y): x float32 [n, REC_LEN] preprocessed, y int labels.

    Recordings are generated class-round-robin from one RNG stream so the
    corpus for a given (seed, n_per_class) is unique and reproducible.
    """
    rng = SplitMix64(seed)
    xs, ys = [], []
    for i in range(n_per_class):
        for cls in (CLS_NSR, CLS_SVT, CLS_VT, CLS_VF):
            raw = synth_recording(rng, cls, noise_rms=noise_rms)
            xs.append(preprocess(raw).astype(np.float32))
            ys.append(cls)
    return np.stack(xs), np.array(ys, dtype=np.int32)


def make_binary_labels(y: np.ndarray) -> np.ndarray:
    """4-class label -> VA (1) / non-VA (0)."""
    return np.isin(y, VA_CLASSES).astype(np.int32)
