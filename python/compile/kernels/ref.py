"""Pure-jnp correctness oracle for the L1 kernel.

Direct integer 1-D convolution (no bit-plane decomposition, no tiling):
the mathematical definition the Pallas kernel must match **bit-exactly**
(integer arithmetic, so the test is equality, not allclose).
"""

from __future__ import annotations

import jax.numpy as jnp


def conv1d_int_ref(x, w, bias=None, stride: int = 1):
    """Integer valid 1-D convolution.

    x:    int32 [B, L, Cin]
    w:    int32 [K, Cin, Cout]
    bias: int32 [Cout] or None
    returns int32 accumulator [B, Lout, Cout], Lout = (L - K)//stride + 1
    """
    k, cin, cout = w.shape
    lout = (x.shape[1] - k) // stride + 1
    # windows[b, l, kk, c] = x[b, l*stride + kk, c]
    cols = [x[:, kk: kk + lout * stride: stride, :] for kk in range(k)]
    windows = jnp.stack(cols, axis=2)  # [B, Lout, K, Cin]
    acc = jnp.einsum("blkc,kco->blo", windows, w,
                     preferred_element_type=jnp.int32)
    if bias is not None:
        acc = acc + bias[None, None, :]
    return acc.astype(jnp.int32)


def maxpool1d_ref(x, pool: int):
    """Max pooling along L: int32 [B, L, C] -> [B, L//pool, C]."""
    b, l, c = x.shape
    lo = l // pool
    return jnp.max(x[:, : lo * pool, :].reshape(b, lo, pool, c), axis=2)


def avgpool1d_ref(x, pool: int):
    """Average pooling with round-half-up integer division (chip MPE
    semantics: (sum + pool/2) / pool on the int32 accumulator)."""
    b, l, c = x.shape
    lo = l // pool
    s = jnp.sum(x[:, : lo * pool, :].reshape(b, lo, pool, c), axis=2,
                dtype=jnp.int32)
    return (s + pool // 2) // pool


def global_avgpool_ref(x):
    """Global average over L, round-half-up: [B, L, C] -> [B, C]."""
    l = x.shape[1]
    s = jnp.sum(x, axis=1, dtype=jnp.int32)
    return (s + l // 2) // l
