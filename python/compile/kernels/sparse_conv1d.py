"""L1 Pallas kernel: sparse mixed-bit-width 1-D convolution (CMUL model).

Structure mirrors the paper's SPE/CMUL datapath (DESIGN.md
§Hardware-Adaptation):

* The full input row for one recording is resident in VMEM for the whole
  layer — the analogue of the paper's single **shared SPad** that all
  PEs/MPEs of an SPE read simultaneously (vs per-PE SPads in Eyeriss v2).
* Each grid step computes a TILE_L × Cout block of outputs — the W×H×M
  output block the chip computes in parallel (TILE_L ⇔ W×H positions,
  Cout ⇔ the M output channels mapped onto the 12 PE + 4 MPE lanes).
* The multiply is decomposed into **bit-planes** exactly like the CMUL:
  an nbits two's-complement weight w = -2^{n-1}·b_{n-1} + Σ 2^i·b_i is
  applied as nbits 1-bit masked accumulations, each shifted by its bit
  index; the top plane enters negatively. Lowering the configured
  precision removes planes — the structural source of the CMUL's
  cycle/energy scaling (the *timing* benefit itself is owned by the
  rust cycle model, not this kernel).
* Weight sparsity (zeroed weights from co-design pruning) appears as
  zeros in every plane; the select-signal/compressed storage form is a
  compile-time transform in rust/src/compiler/ and does not change the
  arithmetic here.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the AOT'd module
runs on the rust PJRT client. All arithmetic is int32 (accumulator
contract, see quantize.py) so correctness vs ref.py is exact equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmul_planes(w, nbits: int):
    """Decompose int32 weights (values in the signed nbits range) into
    CMUL bit-planes.

    Returns list of (plane, shift, sign) with plane ∈ {0,1} int32; the
    weight value equals Σ sign·(plane << shift).

    nbits == 1 is ternary sign-magnitude (chip's 1-bit mode multiplies
    by ±1): a positive and a negative plane, both at shift 0.
    """
    if nbits == 1:
        pos = (w > 0).astype(jnp.int32)
        neg = (w < 0).astype(jnp.int32)
        return [(pos, 0, 1), (neg, 0, -1)]
    mask = (1 << nbits) - 1
    u = jnp.bitwise_and(w, mask)  # two's-complement bit pattern
    planes = []
    for b in range(nbits):
        bit = jnp.bitwise_and(jnp.right_shift(u, b), 1)
        sign = -1 if b == nbits - 1 else 1
        planes.append((bit, b, sign))
    return planes


def _kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, stride: int,
            nbits: int, tile_l: int):
    """One grid step: output tile [TILE_L, Cout] for one recording.

    x_ref: [1, L, Cin]      — full row (shared-SPad analogue)
    w_ref: [K, Cin, Cout]   — full weight tensor (on-chip weight buffer)
    b_ref: [Cout]           — bias
    o_ref: [1, TILE_L, Cout]
    """
    lt = pl.program_id(1)
    base = lt * tile_l * stride
    span = (tile_l - 1) * stride + k
    xs = pl.load(x_ref, (0, pl.ds(base, span), slice(None)))  # [span, Cin]
    # windows[l, kk, c] = xs[l*stride + kk, c]  (static strided slices)
    cols = [xs[kk: kk + (tile_l - 1) * stride + 1: stride, :]
            for kk in range(k)]
    windows = jnp.stack(cols, axis=1)  # [TILE_L, K, Cin]
    w = w_ref[...]

    # CMUL: shift-accumulate over bit-planes.
    acc = jnp.zeros((tile_l, w.shape[2]), dtype=jnp.int32)
    for plane, shift, sign in _cmul_planes(w, nbits):
        pp = jnp.einsum("lkc,kco->lo", windows, plane,
                        preferred_element_type=jnp.int32)
        acc = acc + sign * jnp.left_shift(pp, shift)
    acc = acc + b_ref[...][None, :]
    o_ref[0, :, :] = acc


@functools.partial(jax.jit, static_argnames=("stride", "nbits", "tile_l"))
def sparse_conv1d(x, w, bias, *, stride: int = 1, nbits: int = 8,
                  tile_l: int = 16):
    """Sparse mixed-bit-width integer 1-D convolution (valid padding).

    x:    int32 [B, L, Cin]   quantized activations (int8 range)
    w:    int32 [K, Cin, Cout] quantized weights (signed nbits range,
          zeros where pruned)
    bias: int32 [Cout]
    returns int32 accumulator [B, Lout, Cout]

    Lout is truncated to a multiple of tile_l by the caller's layer
    geometry (the model pads L so this holds; asserted here).
    """
    b, l, cin = x.shape
    k, cin2, cout = w.shape
    assert cin == cin2, (cin, cin2)
    lout = (l - k) // stride + 1
    # chip computes whole W*H output blocks; geometry must tile exactly
    tile = min(tile_l, lout)
    while lout % tile != 0:
        tile -= 1
    grid = (b, lout // tile)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, stride=stride, nbits=nbits,
                          tile_l=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, cin), lambda bi, li: (bi, 0, 0)),
            pl.BlockSpec((k, cin, cout), lambda bi, li: (0, 0, 0)),
            pl.BlockSpec((cout,), lambda bi, li: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile, cout), lambda bi, li: (bi, li, 0)),
        out_shape=jax.ShapeDtypeStruct((b, lout, cout), jnp.int32),
        interpret=True,
    )(x, w, bias)


def _pool_kernel(x_ref, o_ref, *, pool: int, mode: str):
    """MPE pooling: [1, L, C] -> [1, L//pool, C]."""
    xs = x_ref[0, :, :]
    lo = xs.shape[0] // pool
    blk = xs[: lo * pool, :].reshape(lo, pool, xs.shape[1])
    if mode == "max":
        o_ref[0, :, :] = jnp.max(blk, axis=1)
    else:  # avg, round-half-up integer division
        s = jnp.sum(blk, axis=1, dtype=jnp.int32)
        o_ref[0, :, :] = (s + pool // 2) // pool


@functools.partial(jax.jit, static_argnames=("pool", "mode"))
def pool1d(x, *, pool: int, mode: str = "max"):
    """MPE pooling kernel. x: int32 [B, L, C] -> [B, L//pool, C]."""
    b, l, c = x.shape
    lo = l // pool
    return pl.pallas_call(
        functools.partial(_pool_kernel, pool=pool, mode=mode),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, l, c), lambda bi: (bi, 0, 0))],
        out_specs=pl.BlockSpec((1, lo, c), lambda bi: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, lo, c), jnp.int32),
        interpret=True,
    )(x)
