"""Synthetic IEGM generator tests: determinism, front-end filter
behaviour, class structure."""

import numpy as np
import pytest

from compile import data


def test_splitmix64_golden():
    """Golden vector shared with rust/src/data/rng.rs."""
    # seed 0 first output is the canonical splitmix64 reference value
    rng0 = data.SplitMix64(0)
    assert rng0.next_u64() == 0xE220A8397B1DCDAF
    rng = data.SplitMix64(1234)
    got = [rng.next_u64() for _ in range(4)]
    assert got == [
        0xBB0CF61B2F181CDB,
        0x97C7A1364DF06524,
        0x33BEFAE49BC025DA,
        0x4E6241F252D0A033,
    ]


def test_splitmix64_uniform_range():
    rng = data.SplitMix64(7)
    us = [rng.uniform() for _ in range(1000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert 0.4 < np.mean(us) < 0.6


def test_corpus_deterministic():
    x1, y1 = data.make_corpus(99, 4)
    x2, y2 = data.make_corpus(99, 4)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    x3, _ = data.make_corpus(100, 4)
    assert not np.array_equal(x1, x3)


def test_corpus_shapes_and_labels():
    x, y = data.make_corpus(5, 3)
    assert x.shape == (12, data.REC_LEN)
    assert sorted(np.unique(y).tolist()) == [0, 1, 2, 3]
    yb = data.make_binary_labels(y)
    assert yb.sum() == 6  # VT + VF half


def test_bandpass_attenuates_out_of_band():
    """15-55 Hz band-pass: strong attenuation at 2 Hz (wander) and at
    100 Hz, near-unity in the passband (30 Hz)."""
    t = np.arange(data.REC_LEN * 4) / data.FS_HZ

    def gain(f):
        x = np.sin(2 * np.pi * f * t)
        y = data.bandpass(x)
        # steady-state portion only
        return np.abs(y[len(y) // 2:]).max()

    assert gain(30.0) > 0.85
    assert gain(2.0) < 0.08
    assert gain(100.0) < 0.25
    assert gain(0.3) < 0.01  # respiration wander gone


def test_preprocess_normalizes():
    rng = data.SplitMix64(5)
    raw = data.synth_recording(rng, data.CLS_NSR)
    y = data.preprocess(raw)
    assert y.shape == (data.REC_LEN,)
    assert np.abs(y).max() <= 1.0
    rms = np.sqrt(np.mean(y * y))
    assert 0.05 < rms <= 0.3


def test_quantize_input_semantics():
    x = np.array([0.0, 1.0, -1.0, 0.5, data.INPUT_SCALE * 0.5,
                  -data.INPUT_SCALE * 0.5])
    q = data.quantize_input(x)
    assert q.dtype == np.int8
    assert q.tolist() == [0, 127, -127, 64, 1, -1]  # half away from zero


@pytest.mark.parametrize("cls", [data.CLS_NSR, data.CLS_SVT,
                                 data.CLS_VT, data.CLS_VF])
def test_each_class_generates(cls):
    rng = data.SplitMix64(cls + 1)
    raw = data.synth_recording(rng, cls)
    assert raw.shape == (data.REC_LEN,)
    assert np.isfinite(raw).all()
    assert np.abs(raw).max() > 0.1  # non-degenerate


def test_classes_are_statistically_distinct():
    """Morphology sanity: NSR's sharp QRS-like deflections produce a
    much higher zero-crossing rate after band-passing than VF's smooth
    4-7 Hz fibrillatory oscillation — a crude separability check (the
    trained CNN does the real work)."""
    def mean_rate(cls, n=12):
        rates = []
        rng = data.SplitMix64(1000 + cls)
        for _ in range(n):
            y = data.preprocess(data.synth_recording(rng, cls))
            # zero-crossing rate of the band-passed signal
            rates.append(np.mean(np.abs(np.diff(np.sign(y)))))
        return np.mean(rates)

    nsr, vf = mean_rate(data.CLS_NSR), mean_rate(data.CLS_VF)
    assert nsr > 1.5 * vf, (nsr, vf)


def test_is_va():
    assert not data.is_va(data.CLS_NSR)
    assert not data.is_va(data.CLS_SVT)
    assert data.is_va(data.CLS_VT)
    assert data.is_va(data.CLS_VF)
