"""L1 Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Integer arithmetic throughout, so every check is exact equality
(np.array_equal), not allclose. Hypothesis sweeps shapes, strides,
bit-widths, and sparsity levels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (avgpool1d_ref, conv1d_int_ref,
                                 global_avgpool_ref, maxpool1d_ref)
from compile.kernels.sparse_conv1d import _cmul_planes, pool1d, sparse_conv1d
from compile.quantize import bits_range


def _rand_case(rng, b, l, cin, cout, k, nbits, sparsity):
    qmax = bits_range(nbits)
    x = rng.integers(-127, 128, size=(b, l, cin)).astype(np.int32)
    w = rng.integers(-qmax, qmax + 1, size=(k, cin, cout)).astype(np.int32)
    if sparsity > 0:
        mask = rng.random(w.shape) >= sparsity
        w = w * mask
    bias = rng.integers(-(1 << 12), 1 << 12, size=(cout,)).astype(np.int32)
    return x, w, bias


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 3),
    lout=st.integers(1, 24),
    cin=st.integers(1, 8),
    cout=st.integers(1, 20),
    k=st.integers(1, 7),
    stride=st.integers(1, 3),
    nbits=st.sampled_from([8, 4, 2, 1]),
    sparsity=st.sampled_from([0.0, 0.5, 0.9]),
    seed=st.integers(0, 2**31),
)
def test_conv_matches_ref(b, lout, cin, cout, k, stride, nbits, sparsity,
                          seed):
    l = (lout - 1) * stride + k
    rng = np.random.default_rng(seed)
    x, w, bias = _rand_case(rng, b, l, cin, cout, k, nbits, sparsity)
    got = np.asarray(sparse_conv1d(jnp.asarray(x), jnp.asarray(w),
                                   jnp.asarray(bias), stride=stride,
                                   nbits=nbits))
    ref = np.asarray(conv1d_int_ref(jnp.asarray(x), jnp.asarray(w),
                                    jnp.asarray(bias), stride=stride))
    assert got.shape == ref.shape == (b, lout, cout)
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("nbits", [8, 4, 2])
def test_cmul_plane_decomposition_reconstructs(nbits):
    """Σ sign·(plane << shift) must reproduce the signed weight exactly
    (two's complement, top plane negative) — Fig. 3's CMUL identity."""
    qmax = bits_range(nbits)
    w = jnp.arange(-qmax, qmax + 1, dtype=jnp.int32).reshape(1, 1, -1)
    total = jnp.zeros_like(w)
    for plane, shift, sign in _cmul_planes(w, nbits):
        total = total + sign * jnp.left_shift(plane, shift)
    assert np.array_equal(np.asarray(total), np.asarray(w))


def test_cmul_ternary_planes():
    w = jnp.asarray([[-1, 0, 1]], dtype=jnp.int32).reshape(1, 1, 3)
    total = jnp.zeros_like(w)
    for plane, shift, sign in _cmul_planes(w, 1):
        assert shift == 0
        total = total + sign * plane
    assert np.array_equal(np.asarray(total), np.asarray(w))


@pytest.mark.parametrize("nbits", [8, 4, 2, 1])
def test_plane_count_tracks_precision(nbits):
    """Lower precision -> fewer planes (the CMUL cycle/energy knob);
    ternary mode is the two-plane sign/magnitude special case."""
    w = jnp.zeros((1, 1, 1), dtype=jnp.int32)
    n = len(_cmul_planes(w, nbits))
    assert n == (2 if nbits == 1 else nbits)


def test_all_zero_weights_give_bias():
    x = jnp.ones((1, 10, 2), jnp.int32) * 7
    w = jnp.zeros((3, 2, 4), jnp.int32)
    bias = jnp.asarray([1, -2, 3, -4], jnp.int32)
    out = np.asarray(sparse_conv1d(x, w, bias, stride=1, nbits=8))
    assert np.array_equal(out, np.broadcast_to([1, -2, 3, -4], (1, 8, 4)))


def test_extreme_values_no_overflow():
    """Worst-case magnitudes stay in int32 (contract: |acc| < 2^23)."""
    x = jnp.full((1, 64, 8), 127, jnp.int32)
    w = jnp.full((7, 8, 4), -127, jnp.int32)
    bias = jnp.zeros((4,), jnp.int32)
    got = np.asarray(sparse_conv1d(x, w, bias, stride=1, nbits=8))
    ref = np.asarray(conv1d_int_ref(x, w, bias, stride=1))
    assert np.array_equal(got, ref)
    assert got.min() == -127 * 127 * 7 * 8


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    lo=st.integers(1, 16),
    c=st.integers(1, 8),
    pool=st.sampled_from([2, 4]),
    mode=st.sampled_from(["max", "avg"]),
    seed=st.integers(0, 2**31),
)
def test_pool_matches_ref(b, lo, c, pool, mode, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, size=(b, lo * pool, c)),
                    jnp.int32)
    got = np.asarray(pool1d(x, pool=pool, mode=mode))
    ref = maxpool1d_ref(x, pool) if mode == "max" else avgpool1d_ref(x, pool)
    assert np.array_equal(got, np.asarray(ref))


def test_global_avgpool_rounding():
    """Round-half-up integer division semantics of the MPE."""
    x = jnp.asarray([[[1], [2]]], jnp.int32)  # mean 1.5 -> 2
    assert int(global_avgpool_ref(x)[0, 0]) == 2
    x = jnp.asarray([[[-1], [-2]]], jnp.int32)  # mean -1.5 -> -1
    assert int(global_avgpool_ref(x)[0, 0]) == -1
    got = np.asarray(pool1d(jnp.asarray([[[1], [2]]], jnp.int32),
                            pool=2, mode="avg"))
    assert got[0, 0, 0] == 2
