"""AOT lowering tests: the HLO-text bridge the rust runtime consumes.

Keeps a full batch-1 lowering (the real artifact path) plus a
compile-and-execute round trip through the python XLA client — the same
HLO text the rust PJRT client will load."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, model


@pytest.fixture(scope="module")
def small_layers():
    specs = model.arch(8)
    params = model.init_params(jax.random.PRNGKey(11), specs)
    x, _ = data.make_corpus(3, 2)
    amax = model.calibrate_amax(params, jnp.asarray(x[..., None]), specs)
    return model.quantize_model(
        [{"w": np.asarray(p["w"]), "b": np.asarray(p["b"])} for p in params],
        specs, amax, data.INPUT_SCALE)


def test_lower_produces_hlo_text(small_layers):
    text = aot.lower_batch(small_layers, batch=1)
    assert "ENTRY" in text and "HloModule" in text
    # input signature: one s32[1,512,1] parameter
    assert "s32[1,512,1]" in text.replace(" ", "")
    # weights must be fully materialized, never elided (the rust parser
    # would silently mis-load the model otherwise)
    assert "{...}" not in text


def test_hlo_text_roundtrips_through_parser(small_layers):
    """The emitted text must re-parse as a valid HLO module with the
    expected entry signature (the rust side re-parses the same text
    with XLA 0.5.1's parser; the full execute round-trip is covered by
    rust/tests/integration_runtime.rs)."""
    from jax._src.lib import xla_client as xc
    text = aot.lower_batch(small_layers, batch=1, use_pallas=False)
    mod = xc._xla.hlo_module_from_text(text)
    text2 = mod.to_string()
    assert "s32[1,512,1]" in text2.replace(" ", "")
    assert "s32[1,2]" in text2.replace(" ", "")


def test_pallas_and_ref_lowerings_agree(small_layers):
    """Both lowering flavours of the same integer model must produce
    identical numerics when executed by jax."""
    x, _ = data.make_corpus(17, 2)
    xq = np.stack([data.quantize_input(r) for r in x])[:, :, None]
    a = np.asarray(model.forward_int(
        small_layers, jnp.asarray(xq, jnp.int32), use_pallas=True))
    b = np.asarray(model.forward_int(
        small_layers, jnp.asarray(xq, jnp.int32), use_pallas=False))
    assert np.array_equal(a, b)
