"""Co-design pruning tests: balance invariants the chip relies on."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import prune


def _w(rng, k=5, cin=8, cout=16):
    return rng.normal(size=(k, cin, cout))


def test_balanced_mask_exact_lane_counts():
    """Every output channel (PE lane) keeps exactly the same number of
    non-zeros — the property that makes zero-skipping pay off on a
    synchronous array."""
    rng = np.random.default_rng(0)
    w = _w(rng)
    m = prune.balanced_mask(w, 0.5)
    per_lane = m.reshape(-1, w.shape[-1]).sum(axis=0)
    assert (per_lane == per_lane[0]).all()
    assert per_lane[0] == round(0.5 * 5 * 8)


@settings(max_examples=25, deadline=None)
@given(sparsity=st.sampled_from([0.25, 0.5, 0.75]),
       k=st.integers(1, 7), cin=st.integers(1, 16),
       cout=st.integers(1, 32), seed=st.integers(0, 2**31))
def test_balanced_mask_sparsity_and_magnitude(sparsity, k, cin, cout, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(k, cin, cout))
    m = prune.balanced_mask(w, sparsity)
    keep = max(1, int(round((1 - sparsity) * k * cin)))
    assert m.reshape(-1, cout).sum(axis=0).tolist() == [keep] * cout
    # kept entries dominate dropped entries per lane
    flat_w = np.abs(w).reshape(-1, cout)
    flat_m = m.reshape(-1, cout)
    for co in range(cout):
        kept_min = flat_w[flat_m[:, co], co].min()
        dropped = flat_w[~flat_m[:, co], co]
        if dropped.size:
            assert kept_min >= dropped.max() - 1e-12


def test_global_mask_hits_sparsity():
    rng = np.random.default_rng(1)
    w = _w(rng, 5, 16, 32)
    m = prune.global_mask(w, 0.5)
    assert abs(m.mean() - 0.5) < 0.01


def test_global_mask_is_unbalanced_balanced_is_not():
    rng = np.random.default_rng(2)
    # skew one lane's magnitudes so global pruning starves other lanes
    w = _w(rng, 5, 16, 8)
    w[:, :, 0] *= 10.0
    gm = prune.global_mask(w, 0.5)
    bm = prune.balanced_mask(w, 0.5)
    assert prune.lane_imbalance(w * gm) > 1.2
    assert abs(prune.lane_imbalance(w * bm) - 1.0) < 1e-9


def test_make_masks_network_sparsity_with_dense_endpoints():
    rng = np.random.default_rng(3)
    params = [{"w": _w(rng, 7, 1, 16)}, {"w": _w(rng, 5, 16, 32)},
              {"w": _w(rng, 3, 32, 32)}, {"w": _w(rng, 1, 32, 2)}]
    masks = prune.make_masks(params, 0.5, mode="balanced")
    assert masks[0] is None and masks[-1] is None
    pruned = prune.apply_masks(params, masks)
    sp = prune.network_sparsity(pruned)
    assert abs(sp - 0.5) < 0.02  # network-wide target despite dense ends


def test_apply_masks_zeroes_only_masked():
    rng = np.random.default_rng(4)
    params = [{"w": _w(rng), "b": np.zeros(16)}]
    masks = [prune.balanced_mask(params[0]["w"], 0.5)]
    out = prune.apply_masks(params, masks)
    assert ((out[0]["w"] == 0) | masks[0]).all()
    assert np.array_equal(out[0]["w"][masks[0]], params[0]["w"][masks[0]])
