"""Unit + property tests for the shared fixed-point quantization
contract (quantize.py). rust/src/nn/requant.rs mirrors these exact
semantics; the rust test suite carries the same golden vectors."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


def test_bits_range():
    assert Q.bits_range(8) == 127
    assert Q.bits_range(4) == 7
    assert Q.bits_range(2) == 1
    assert Q.bits_range(1) == 1


def test_round_half_up():
    x = np.array([-2.5, -1.5, -0.5, 0.5, 1.5, 2.5, 0.49, -0.49])
    got = Q.round_half_up(x)
    assert np.array_equal(got, [-2, -1, 0, 1, 2, 3, 0, 0])


@pytest.mark.parametrize("nbits", [8, 4, 2, 1])
def test_quantize_weights_range_and_scale(nbits):
    rng = np.random.default_rng(0)
    w = rng.normal(size=(5, 3, 7))
    w_q, s_w = Q.quantize_weights(w, nbits)
    qmax = Q.bits_range(nbits)
    assert w_q.max() <= qmax and w_q.min() >= -qmax
    # per-channel max must hit the qmax bucket (scale is exact amax/qmax)
    assert np.array_equal(np.abs(w_q).max(axis=(0, 1)),
                          np.full(7, qmax))
    # dequantized error bounded by half a step per element
    err = np.abs(w_q * s_w - w)
    assert np.all(err <= 0.5 * s_w + 1e-12)


def test_requant_golden_vectors():
    """Golden vectors duplicated in rust/src/nn/requant.rs tests."""
    m0 = np.array([1 << 23], dtype=np.int32)  # M = 0.5 at shift 24
    acc = np.array([[5, -5, 3, -3, 254, -254, 255, -255]], np.int32).T
    got = Q.requant(acc, m0, 24, relu=False).ravel()
    #  0.5*5=2.5 -> 3 (half-up);  -2.5 -> -2;  1.5 -> 2;  -1.5 -> -1
    #  127 stays; -127 stays; 127.5 -> clamp 127; -127.5 -> -127 (clamp)
    assert got.tolist() == [3, -2, 2, -1, 127, -127, 127, -127]


def test_requant_relu():
    m0 = np.array([1 << 24], dtype=np.int32)  # M = 1.0
    acc = np.array([[-10, 0, 10]], np.int32).T
    got = Q.requant(acc, m0, 24, relu=True).ravel()
    assert got.tolist() == [0, 0, 10]


@settings(max_examples=100, deadline=None)
@given(acc=st.integers(-(1 << 23), 1 << 23),
       m=st.floats(1e-4, 2.0),
       relu=st.booleans())
def test_requant_matches_float_reference(acc, m, relu):
    """Fixed-point requant must be within 1 LSB of the real-valued
    scaling (before clamping)."""
    m0, shift = Q.requant_params(1.0, np.array([m]), 1.0)
    got = int(Q.requant(np.array([[acc]], np.int32), m0, shift,
                        relu=relu)[0, 0])
    real = acc * m
    if relu:
        real = max(real, 0.0)
    real = min(max(real, Q.QMIN), Q.QMAX)
    assert abs(got - real) <= 1.0 + 1e-9


@settings(max_examples=50, deadline=None)
@given(s_in=st.floats(1e-4, 1.0), s_out=st.floats(1e-3, 10.0),
       s_w=st.floats(1e-5, 0.1))
def test_requant_params_no_overflow(s_in, s_out, s_w):
    m0, shift = Q.requant_params(s_in, np.array([s_w]), s_out)
    assert m0.dtype == np.int32
    real = s_in * s_w / s_out
    assert abs(int(m0[0]) / (1 << shift) - real) <= 1.0 / (1 << shift)


def test_requant_monotonic():
    """Requantization must be monotone in the accumulator (argmax
    stability of the head)."""
    m0 = np.array([12345678], dtype=np.int32)
    acc = np.arange(-3000, 3000, dtype=np.int32).reshape(-1, 1)
    out = Q.requant(acc, m0, 24, relu=False).ravel()
    assert np.all(np.diff(out) >= 0)
