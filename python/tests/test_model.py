"""L2 model tests: geometry, float/int agreement, pallas-vs-ref
equality on the full 8-layer network, artifact round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import artifact, data, model, prune
from compile import quantize as Q


@pytest.fixture(scope="module")
def tiny_setup():
    """A small trained-ish model (random weights, calibrated scales) —
    enough for numerical agreement tests without real training."""
    specs = model.arch(8)
    params = model.init_params(jax.random.PRNGKey(3), specs)
    x, _ = data.make_corpus(7, 4)
    xj = jnp.asarray(x[..., None], jnp.float32)
    amax = model.calibrate_amax(params, xj, specs)
    layers = model.quantize_model(
        [{"w": np.asarray(p["w"]), "b": np.asarray(p["b"])} for p in params],
        specs, amax, data.INPUT_SCALE)
    xq = np.stack([data.quantize_input(r) for r in x])
    return specs, params, layers, x, xq


def test_arch_geometry():
    specs = model.arch(8)
    assert len(specs) == 8
    l = model.REC_LEN
    for s in specs:
        l = model.out_len(l, s)
    assert l == 4  # 512 / 2^7
    assert specs[-1].cout == model.NUM_CLASSES
    # channel counts are multiples of 16 (M lanes) except in/out
    for s in specs[1:-1]:
        assert s.cout % 16 == 0


def test_mixed_precision_arch():
    bits = [8, 8, 4, 4, 4, 4, 2, 8]
    specs = model.arch(bits)
    assert [s.nbits for s in specs] == bits
    with pytest.raises(AssertionError):
        model.arch([8, 8])


def test_mac_counts():
    specs = model.arch(8)
    macs = model.mac_counts(specs)
    assert len(macs) == 8
    assert macs[0] == 256 * 7 * 1 * 16
    assert macs[-1] == 4 * 1 * 128 * 2
    # headline envelope: ~2 MMAC = ~4 MOPs per inference
    assert 1.0e6 < sum(macs) < 4.0e6


def test_pad_amount_preserves_halving():
    for k, s in [(7, 2), (5, 2), (3, 2), (1, 1)]:
        pl_, pr = model.pad_amount(k, s)
        assert pl_ + pr == k - s
        lout = (64 + pl_ + pr - k) // s + 1
        assert lout == 64 // s


def test_forward_float_shape(tiny_setup):
    specs, params, _, x, _ = tiny_setup
    logits = model.forward_float(params, jnp.asarray(x[..., None]), specs)
    assert logits.shape == (x.shape[0], 2)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_int_pallas_equals_ref(tiny_setup):
    """Full 8-layer integer network: Pallas kernel path must equal the
    jnp reference path BIT-EXACTLY."""
    _, _, layers, _, xq = tiny_setup
    xb = jnp.asarray(xq[:4, :, None], jnp.int32)
    got_pl = np.asarray(model.forward_int(layers, xb, use_pallas=True))
    got_ref = np.asarray(model.forward_int(layers, xb, use_pallas=False))
    assert np.array_equal(got_pl, got_ref)


def test_int_model_tracks_float(tiny_setup):
    """Quantized logits should rank classes like the float model on a
    large margin batch (sanity: quantization preserves decisions more
    often than chance)."""
    specs, params, layers, x, xq = tiny_setup
    fl = np.asarray(model.forward_float(
        params, jnp.asarray(x[..., None]), specs))
    il = np.asarray(model.forward_int(
        layers, jnp.asarray(xq[:, :, None], jnp.int32), use_pallas=False))
    agree = np.mean(fl.argmax(-1) == il.argmax(-1))
    assert agree >= 0.75


def test_quantized_weights_respect_sparsity(tiny_setup):
    specs, params, _, _, _ = tiny_setup
    params_np = [{"w": np.asarray(p["w"]), "b": np.asarray(p["b"])}
                 for p in params]
    masks = prune.make_masks(params_np, 0.5)
    pruned = prune.apply_masks(params_np, masks)
    xr, _ = data.make_corpus(5, 1)
    x = jnp.asarray(xr[..., None], jnp.float32)
    amax = model.calibrate_amax(
        [{"w": jnp.asarray(p["w"]), "b": jnp.asarray(p["b"])}
         for p in pruned], x, specs)
    layers = model.quantize_model(pruned, specs, amax, data.INPUT_SCALE)
    for ly, m in zip(layers, masks):
        if m is not None:
            assert ((np.asarray(ly.w_q) == 0) | m).all()


def test_weights_artifact_roundtrip(tiny_setup, tmp_path):
    _, _, layers, _, _ = tiny_setup
    p = str(tmp_path / "w.bin")
    artifact.write_weights(p, layers)
    back = artifact.read_weights(p)
    assert len(back) == len(layers)
    for a, b in zip(layers, back):
        assert a.spec == b.spec
        assert np.array_equal(a.w_q, b.w_q)
        assert np.array_equal(a.bias_q, b.bias_q)
        assert np.array_equal(a.m0, b.m0)
        assert a.shift == b.shift
        assert a.s_in == pytest.approx(b.s_in)


def test_eval_artifact_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    xq = rng.integers(-127, 128, size=(10, 512)).astype(np.int8)
    y = rng.integers(0, 4, size=10).astype(np.int32)
    p = str(tmp_path / "e.bin")
    artifact.write_eval(p, xq, y)
    xb, yb = artifact.read_eval(p)
    assert np.array_equal(xb, xq) and np.array_equal(yb, y)


def test_requant_jnp_matches_numpy(tiny_setup):
    """The in-graph requant must equal the numpy contract requant."""
    rng = np.random.default_rng(1)
    acc = rng.integers(-(1 << 20), 1 << 20, size=(2, 8, 4)).astype(np.int32)
    m0 = rng.integers(1, 1 << 24, size=4).astype(np.int32)
    got = np.asarray(model._requant_jnp(
        jnp.asarray(acc), jnp.asarray(m0), 24, relu=True))
    ref = Q.requant(acc, m0, 24, relu=True)
    assert np.array_equal(got, ref)
