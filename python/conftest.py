import os
import sys

import jax

# The integer requant contract needs int64 intermediates everywhere.
jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.dirname(__file__))
