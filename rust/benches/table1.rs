//! Bench: regenerates Table 1 end-to-end (the paper's only table).
//! Our column comes from the cycle-accurate simulator + 40 nm model;
//! prior-work columns carry the published constants; baseline
//! algorithm accuracies are measured on the common task.
//!
//! Run: cargo bench --bench table1

use std::time::Instant;

use va_accel::arch::ChipConfig;
use va_accel::baselines::{all_baselines, all_published_rows};
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, Pipeline};
use va_accel::data::{load_eval, Dataset};
use va_accel::metrics::Confusion;
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

fn main() -> anyhow::Result<()> {
    let t_total = Instant::now();
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))?;
    let r = sim::run(&cm, &ds.x[0]);
    let rep = report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40());
    let (rec_conf, _) = Pipeline::evaluate(&Backend::golden(model.clone()),
                                           &ds.x, &ds.va_labels(), VOTE_GROUP)?;

    let tr = Dataset::synthesize(100, 96, 0.6);
    let mut rows = Vec::new();
    for mut b in all_baselines() {
        let t0 = Instant::now();
        b.fit(&tr.x, &tr.va_labels());
        let fit_s = t0.elapsed().as_secs_f64();
        let mut c = Confusion::new();
        for (x, t) in ds.x.iter().zip(ds.va_labels()) {
            c.push(b.predict(x), t);
        }
        rows.push((b.name(), b.published(), c.accuracy(), fit_s));
    }

    println!("== Table 1 (regenerated) ==\n");
    println!("{:<14}{:>8}{:>10}{:>10}{:>10}{:>11}{:>12}{:>12}",
             "work", "tech", "sparsity", "area mm²", "volt V", "freq", "power µW", "dens µW/mm²");
    for (name, p, _, _) in &rows {
        println!("{:<14}{:>8}{:>10}{:>10}{:>10}{:>11}{:>12}{:>12}",
                 name, p.tech_nm,
                 if p.sparsity { "yes" } else { "no" },
                 p.area_mm2.map(|a| format!("{a:.2}")).unwrap_or("N/A".into()),
                 format!("{:.1}", p.voltage_v),
                 format!("{:.2e}", p.freq_hz),
                 format!("{:.2}", p.power_uw),
                 p.density_uw_mm2.map(|d| format!("{d:.2}")).unwrap_or("N/A".into()));
    }
    println!("{:<14}{:>8}{:>10}{:>10}{:>10}{:>11}{:>12}{:>12}",
             "our-work(sim)", 40, "yes",
             format!("{:.2}", rep.area_mm2), "1.1",
             format!("{:.2e}", cfg.freq_hz),
             format!("{:.2}", rep.p_avg_w * 1e6),
             format!("{:.2}", rep.density_uw_mm2));

    println!("\ncommon-task accuracy (same corpus for all):");
    for (name, _, acc, fit_s) in &rows {
        println!("  {name:<10} {:.2}%  (fit {fit_s:.1}s)", acc * 100.0);
    }
    println!("  ours       {:.2}%", rec_conf.accuracy() * 100.0);

    let best = all_published_rows().iter()
        .filter_map(|r| r.density_uw_mm2)
        .fold(f64::INFINITY, f64::min);
    println!("\nshape checks vs paper:");
    println!("  density advantage {:.2}× (paper 14.23×) {}",
             best / rep.density_uw_mm2,
             if (best / rep.density_uw_mm2 - 14.23).abs() < 2.0 { "OK" } else { "DRIFT" });
    println!("  our power {:.2} µW within prior range [5.10, 13.34] {}",
             rep.p_avg_w * 1e6,
             if rep.p_avg_w * 1e6 < 13.34 { "OK" } else { "DRIFT" });
    println!("  CNN beats every baseline on the common task {}",
             if rows.iter().all(|(_, _, a, _)| *a < rec_conf.accuracy()) { "OK" } else { "DRIFT" });
    println!("\nbench wall time: {:.1}s", t_total.elapsed().as_secs_f64());
    Ok(())
}
