//! Bench: §2 co-design pruning — sparsity sweep 0–87.5 % under two
//! pruning policies:
//!
//! * balanced (the paper's compiler: equal non-zeros per PE lane)
//! * global magnitude (classic pruning: same total sparsity,
//!   unbalanced lanes)
//!
//! On the synchronous array the *straggler lane* sets the pace, so the
//! bench demonstrates why the compiler balances: cycles track MAX lane
//! work, energy tracks TOTAL work.
//!
//! Run: cargo bench --bench sparsity

use va_accel::arch::ChipConfig;
use va_accel::compiler::{compile, BalanceReport};
use va_accel::data::{Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

/// Re-prune a loaded model to `sparsity` with either balanced
/// (per-lane top-k) or global (layer-wide threshold) masking.
/// First and last layers stay dense (mirrors the python compiler).
fn reprune(model: &QuantModel, sparsity: f64, balanced: bool) -> QuantModel {
    let mut m = model.clone();
    let n = m.layers.len();
    for (li, ly) in m.layers.iter_mut().enumerate() {
        if li == 0 || li == n - 1 {
            continue;
        }
        let kcin = ly.k * ly.cin;
        if balanced {
            let keep = ((1.0 - sparsity) * kcin as f64).round().max(1.0) as usize;
            for co in 0..ly.cout {
                let mut idx: Vec<usize> = (0..kcin).collect();
                idx.sort_by_key(|&i| std::cmp::Reverse(ly.w[i * ly.cout + co].abs()));
                for &i in &idx[keep.min(kcin)..] {
                    ly.w[i * ly.cout + co] = 0;
                }
            }
        } else {
            let mut mags: Vec<i32> = ly.w.iter().map(|w| w.abs()).collect();
            mags.sort_unstable_by_key(|&m| std::cmp::Reverse(m));
            let keep = ((1.0 - sparsity) * mags.len() as f64).round().max(1.0) as usize;
            let thresh = mags[keep.min(mags.len()) - 1].max(1);
            for w in &mut ly.w {
                if w.abs() < thresh {
                    *w = 0;
                }
            }
        }
    }
    m
}

/// The shipped artifact is already 50 %-pruned; sweep points below
/// that need a dense starting model. Re-densify by filling pruned
/// slots with small pseudorandom weights — the bench measures the
/// hardware cost axis (cycles/energy vs sparsity structure), not
/// accuracy, so the values only need to be non-zero.
fn densify(model: &QuantModel) -> QuantModel {
    let mut m = model.clone();
    let mut rng = va_accel::data::SplitMix64::new(0xDE45E);
    for ly in &mut m.layers {
        for w in &mut ly.w {
            if *w == 0 {
                let v = 1 + (rng.next_u64() % 7) as i32;
                *w = if rng.uniform() < 0.5 { -v } else { v };
            }
        }
    }
    m
}

fn main() -> anyhow::Result<()> {
    let model = densify(&QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?);
    let mut gen = Generator::new(23);
    let x = gen.recording(RhythmClass::Nsr).quantized();
    // the real chip's 128 KiB weight buffer is sized for the 50 %-
    // compressed model; the dense ablation points need more, so the
    // sweep uses an enlarged buffer (storage, not datapath, changes)
    let cfg = ChipConfig { weight_buf_bytes: 512 * 1024, ..ChipConfig::paper_1d() };
    let em = EnergyModel::lp40();
    let am = AreaModel::lp40();
    // ONE arena across every sweep point: the ScratchArena serves
    // different compiled models back to back, so the sweep stops
    // thrashing the allocator (and exercises multi-model arena reuse)
    let mut arena = sim::ScratchArena::new();

    println!("== sparsity sweep (paper: 50 % co-design pruning) ==\n");
    println!("{:<10}{:>12}{:>12}{:>12}{:>12}{:>12}",
             "sparsity", "bal cycles", "glb cycles", "straggler", "bal µJ", "glb µJ");
    for s in [0.0, 0.25, 0.5, 0.625, 0.75, 0.875] {
        let mb = reprune(&model, s, true);
        let mg = reprune(&model, s, false);
        let cb = compile(&mb, &cfg, REC_LEN)?;
        let cg = compile(&mg, &cfg, REC_LEN)?;
        let rb = sim::run_scratch(&cb, &x, &mut arena);
        let rg = sim::run_scratch(&cg, &x, &mut arena);
        let eb = report(&rb.counters, &cfg, &em, &am).e_active_j * 1e6;
        let eg = report(&rg.counters, &cfg, &em, &am).e_active_j * 1e6;
        let penalty = BalanceReport::of(&mg).end_to_end_penalty();
        println!("{:<10}{:>12}{:>12}{:>12.3}{:>12.3}{:>12.3}",
                 format!("{:.1}%", s * 100.0),
                 rb.counters.total_cycles(), rg.counters.total_cycles(),
                 penalty, eb, eg);
    }

    println!("\nzero-skip off (dense datapath) at 50% for reference:");
    let m50 = reprune(&model, 0.5, true);
    let mut dense_cfg = cfg.clone();
    dense_cfg.zero_skip = false;
    let cd = compile(&m50, &dense_cfg, REC_LEN)?;
    let cs = compile(&m50, &cfg, REC_LEN)?;
    let rd = sim::run_scratch(&cd, &x, &mut arena);
    let rs = sim::run_scratch(&cs, &x, &mut arena);
    println!("  dense {} cycles vs zero-skip {} cycles ({:.2}× speedup)",
             rd.counters.total_cycles(), rs.counters.total_cycles(),
             rd.counters.total_cycles() as f64 / rs.counters.total_cycles() as f64);
    Ok(())
}
