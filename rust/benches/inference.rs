//! Bench: §3 headline — per-recording inference time and effective
//! GOPS, on (a) the simulated chip, (b) the PJRT CPU runtime, (c) the
//! golden model. Regenerates the "35 µs / 150 GOPS" claim.
//!
//! Run: cargo bench --bench inference

use std::time::Instant;

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::data::load_eval;
use va_accel::metrics::effective_gops;
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::runtime::Executor;
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

fn main() -> anyhow::Result<()> {
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))?;
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let macs = model.stats(REC_LEN).macs_dense;

    println!("== inference bench (paper §3: 35 µs, 150 GOPS @ 128 PEs) ==\n");

    // (a) simulated chip
    let r = sim::run(&cm, &ds.x[0]);
    let rep = report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40());
    println!("simulated chip (128 PEs @ 400 MHz):");
    println!("  t_inf {:.2} µs   {:.1} GOPS   {} cycles  [paper: 35 µs, 150 GOPS]",
             rep.t_active_s * 1e6, rep.gops, rep.cycles);
    let full = compile(&model, &ChipConfig::paper(), REC_LEN)?;
    let rf = sim::run(&full, &ds.x[0]);
    let repf = report(&rf.counters, &ChipConfig::paper(),
                      &EnergyModel::lp40(), &AreaModel::lp40());
    println!("  full 512-PE engagement: t_inf {:.2} µs   {:.1} GOPS\n",
             repf.t_active_s * 1e6, repf.gops);

    // (b) golden model on this host CPU
    let n = 200.min(ds.len());
    let t0 = Instant::now();
    for x in &ds.x[..n] {
        std::hint::black_box(model.forward(x));
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    println!("rust golden model (host CPU):");
    println!("  t_inf {:.1} µs   {:.2} GOPS equivalent\n",
             per * 1e6, effective_gops(macs, per));

    // (c) PJRT runtime, per batch variant
    let exe = Executor::open(ARTIFACT_DIR)?;
    exe.warmup()?;
    println!("PJRT CPU runtime (AOT artifact):");
    for &b in &exe.artifacts().batches.clone() {
        let xs: Vec<Vec<i8>> = ds.x.iter().take(b).cloned().collect();
        // warm
        exe.infer_batch(&xs)?;
        let iters = if b >= 32 { 3 } else { 10 };
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(exe.infer_batch(&xs)?);
        }
        let per_rec = t0.elapsed().as_secs_f64() / (iters * b) as f64;
        println!("  batch {b:>2}: {:>9.1} µs/recording   {:.3} GOPS equivalent",
                 per_rec * 1e6, effective_gops(macs, per_rec));
    }

    // (d) simulator throughput (how fast the *simulator* itself runs)
    let t0 = Instant::now();
    let k = 20;
    for x in ds.x.iter().take(k) {
        std::hint::black_box(sim::run(&cm, x));
    }
    let per = t0.elapsed().as_secs_f64() / k as f64;
    println!("\nsimulator speed: {:.1} ms/inference ({:.1} M simulated MACs/s)",
             per * 1e3, r.counters.total_macs() as f64 / per / 1e6);
    Ok(())
}
