//! Bench: noise-robustness — the paper's *motivation* axis.
//!
//! The introduction argues rule-based/classical ICD detection is not
//! accurate enough while an on-device CNN is. This bench sweeps sensor
//! noise and compares the quantized CNN against all four Table-1
//! baseline algorithms on freshly generated corpora, reporting
//! per-recording accuracy and voted diagnostic accuracy: the curve
//! that justifies spending silicon on a CNN.
//!
//! Run: cargo bench --bench robustness

use va_accel::baselines::all_baselines;
use va_accel::coordinator::{Backend, Pipeline};
use va_accel::data::Dataset;
use va_accel::metrics::Confusion;
use va_accel::nn::QuantModel;
use va_accel::{ARTIFACT_DIR, VOTE_GROUP};

fn main() -> anyhow::Result<()> {
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let backend = Backend::golden(model);

    println!("== noise robustness sweep ==");
    println!("(model trained at noise_rms 0.6; baselines retrained per point)\n");
    println!("{:<10}{:>10}{:>10}{:>10}{:>10}{:>10}{:>12}",
             "noise", "cnn", "ann", "ks", "svm", "snn", "cnn-voted");
    for noise in [0.2, 0.4, 0.6, 0.8, 1.0] {
        let tr = Dataset::synthesize(500, 64, noise);
        let te = Dataset::synthesize(501, 48, noise);
        let truth = te.va_labels();
        let (rec, ep) = Pipeline::evaluate(&backend, &te.x, &truth, VOTE_GROUP)?;
        let mut cols = Vec::new();
        for mut b in all_baselines() {
            b.fit(&tr.x, &tr.va_labels());
            let mut c = Confusion::new();
            for (x, t) in te.x.iter().zip(&truth) {
                c.push(b.predict(x), *t);
            }
            cols.push(c.accuracy());
        }
        println!("{:<10}{:>9.1}%{:>9.1}%{:>9.1}%{:>9.1}%{:>9.1}%{:>11.1}%",
                 format!("{noise:.1}"),
                 rec.accuracy() * 100.0,
                 cols[0] * 100.0, cols[1] * 100.0,
                 cols[2] * 100.0, cols[3] * 100.0,
                 ep.accuracy() * 100.0);
    }
    println!("\nshape: the CNN dominates every baseline at every noise level,");
    println!("and voting recovers near-perfect diagnosis into the paper's");
    println!("regime — degrading gracefully as noise leaves the training");
    println!("distribution (the CNN is trained once at 0.6, like the chip).");
    Ok(())
}
