//! Bench: noise-robustness — the paper's *motivation* axis.
//!
//! The introduction argues rule-based/classical ICD detection is not
//! accurate enough while an on-device CNN is. This bench sweeps sensor
//! noise and compares the quantized CNN against all four Table-1
//! baseline algorithms on freshly generated corpora, reporting
//! per-recording accuracy and voted diagnostic accuracy: the curve
//! that justifies spending silicon on a CNN.
//!
//! Hermetic: when `artifacts/weights.bin` is absent the fixture model
//! stands in (accuracy shape is then structural, not clinical — the
//! fixture weights are random). Emits `BENCH_robustness.json` for the
//! CI lane asserts either way.
//!
//! Run: cargo bench --bench robustness

use std::fmt::Write as _;

use va_accel::baselines::all_baselines;
use va_accel::coordinator::{Backend, Pipeline};
use va_accel::data::{fixtures, Dataset};
use va_accel::metrics::Confusion;
use va_accel::{ARTIFACT_DIR, VOTE_GROUP};

/// Noise RMS the corpus generator trains at (see `Generator::new`).
const TRAINED_FLOOR: f64 = 0.6;
const NOISE_LEVELS: [f64; 5] = [0.2, 0.4, 0.6, 0.8, 1.0];

fn main() -> anyhow::Result<()> {
    let trained = std::path::Path::new(
        &format!("{ARTIFACT_DIR}/weights.bin")).exists();
    if !trained {
        eprintln!("note: {ARTIFACT_DIR}/weights.bin not found — using the \
                   hermetic fixture model (random weights; run `make \
                   artifacts` for the trained network)");
    }
    let backend = Backend::golden(fixtures::model_or_artifact());

    println!("== noise robustness sweep ==");
    println!("(model trained at noise_rms {TRAINED_FLOOR}; baselines \
              retrained per point)\n");
    println!("{:<10}{:>10}{:>10}{:>10}{:>10}{:>10}{:>12}",
             "noise", "cnn", "ann", "ks", "svm", "snn", "cnn-voted");
    let mut rows = String::new();
    for noise in NOISE_LEVELS {
        let tr = Dataset::synthesize(500, 64, noise);
        let te = Dataset::synthesize(501, 48, noise);
        let truth = te.va_labels();
        let (rec, ep) = Pipeline::evaluate(&backend, &te.x, &truth, VOTE_GROUP)?;
        let mut cols = Vec::new();
        for mut b in all_baselines() {
            b.fit(&tr.x, &tr.va_labels());
            let mut c = Confusion::new();
            for (x, t) in te.x.iter().zip(&truth) {
                c.push(b.predict(x), *t);
            }
            cols.push(c.accuracy());
        }
        println!("{:<10}{:>9.1}%{:>9.1}%{:>9.1}%{:>9.1}%{:>9.1}%{:>11.1}%",
                 format!("{noise:.1}"),
                 rec.accuracy() * 100.0,
                 cols[0] * 100.0, cols[1] * 100.0,
                 cols[2] * 100.0, cols[3] * 100.0,
                 ep.accuracy() * 100.0);
        if !rows.is_empty() {
            rows.push_str(",\n");
        }
        write!(rows,
               "    {{\"noise\": {noise:.1}, \"cnn_acc\": {:.4}, \
                \"cnn_voted_acc\": {:.4}, \"cnn_sens\": {:.4}, \
                \"cnn_spec\": {:.4}, \"ann_acc\": {:.4}, \
                \"ks_acc\": {:.4}, \"svm_acc\": {:.4}, \
                \"snn_acc\": {:.4}}}",
               rec.accuracy(), ep.accuracy(), rec.recall(),
               rec.specificity(), cols[0], cols[1], cols[2], cols[3])?;
    }
    let json = format!(
        "{{\n  \"bench\": \"robustness\",\n  \
         \"trained_weights\": {trained},\n  \
         \"trained_floor\": {TRAINED_FLOOR},\n  \
         \"noise_levels\": {},\n  \"sweep\": [\n{rows}\n  ]\n}}\n",
        NOISE_LEVELS.len());
    std::fs::write("BENCH_robustness.json", &json)?;
    println!("\nwrote BENCH_robustness.json");
    println!("\nshape: the CNN dominates every baseline at every noise level,");
    println!("and voting recovers near-perfect diagnosis into the paper's");
    println!("regime — degrading gracefully as noise leaves the training");
    println!("distribution (the CNN is trained once at 0.6, like the chip).");
    Ok(())
}
