//! Bench: recordings/sec of the simulator hot path.
//!
//! Four single-engine paths over the hermetic fixture corpus —
//!
//! * **fast**    — `sim::run_scratch`: staged position-blocked lane
//!                 kernel, tile-major stripes, reusable arena,
//!                 precompiled static counters;
//! * **counted** — `sim::run_counted_scratch`: the dynamic-counting
//!                 reference over the same arena type (zero-alloc
//!                 serial tile walk);
//! * **golden**  — `nn::QuantModel::forward`: the dense integer model,
//!                 per-call allocations (the audit baseline);
//! * **golden-scratch** — `forward_scratch` over one arena (the
//!                 fleet-competitive golden serving path);
//!
//! — plus the serving comparison: a 4-shard chipsim `Fleet` vs the
//! single-worker `Service`, both on the fast path. Results land in
//! `BENCH_hotpath.json` (machine-readable, one file per run) so the
//! perf trajectory accumulates across PRs.
//!
//! Run: cargo bench --bench hotpath [-- shards] (default 4)
//! Acceptance: fast ≥ 3x counted on the fixture model (hard-fails only
//! with HOTPATH_BENCH_STRICT=1 — wall-clock gates are advisory on
//! loaded machines).

use std::time::Instant;

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, BatcherConfig, Fleet, FleetConfig,
                            Pipeline, Service};
use va_accel::data::fixtures;
use va_accel::sim;
use va_accel::{REC_LEN, VOTE_GROUP};

/// Recordings/sec of `f` over `rounds` passes of the corpus (after one
/// warm-up pass).
fn rps(recs: &[Vec<i8>], rounds: usize, mut f: impl FnMut(&[i8])) -> f64 {
    for x in recs.iter().take(8) {
        f(x);
    }
    let t0 = Instant::now();
    for _ in 0..rounds {
        for x in recs {
            f(x);
        }
    }
    (rounds * recs.len()) as f64 / t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    let shards: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let model = fixtures::default_model();
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let ds = fixtures::eval_corpus(55, 10); // 40 synthetic recordings
    let rounds = 5;
    println!("== hotpath bench: {} recordings x {} rounds ==\n",
             ds.len(), rounds);

    // bit-exactness gate before timing anything: fast logits AND static
    // counters must equal the counted reference (and the golden arena
    // twin must equal the golden model) on every recording
    let mut scratch = sim::ScratchArena::for_model(&cm);
    let mut counted_scratch = sim::ScratchArena::for_model(&cm);
    let mut golden_scratch = sim::ScratchArena::new();
    for (i, x) in ds.x.iter().enumerate() {
        let fast = sim::run_scratch(&cm, x, &mut scratch);
        let counted = sim::run_counted_scratch(&cm, x, &mut counted_scratch);
        assert_eq!(fast.logits, counted.logits, "recording {i}");
        assert_eq!(fast.counters, counted.counters,
                   "recording {i}: static counters != counted");
        assert_eq!(model.forward_scratch(x, &mut golden_scratch),
                   fast.logits, "recording {i}: golden arena twin");
    }
    println!("bit-exact: fast == counted == golden-scratch \
              (logits + counters, {} recordings)",
             ds.len());

    let fast_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(sim::run_scratch(&cm, x, &mut scratch));
    });
    let counted_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(
            sim::run_counted_scratch(&cm, x, &mut counted_scratch));
    });
    let golden_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(model.forward(x));
    });
    let golden_scratch_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(model.forward_scratch(x, &mut golden_scratch));
    });
    let speedup = fast_rps / counted_rps;
    println!("fast    (arena + static counters)  : {fast_rps:>9.1} rec/s");
    println!("counted (dynamic reference, arena) : {counted_rps:>9.1} rec/s");
    println!("golden  (dense int model)          : {golden_rps:>9.1} rec/s");
    println!("golden-scratch (arena twin)        : {golden_scratch_rps:>9.1} rec/s");
    println!("fast vs counted: {speedup:.2}x\n");

    // serving comparison, fast path end to end
    let batcher = BatcherConfig {
        max_batch: VOTE_GROUP,
        max_age: std::time::Duration::ZERO,
    };
    let svc = Service::spawn(Pipeline::new(
        Backend::chipsim(compile(&model, &cfg, REC_LEN)?),
        batcher.clone(), VOTE_GROUP));
    let h = svc.handle();
    let t0 = Instant::now();
    for _ in 0..rounds {
        for x in &ds.x {
            h.submit_recording(x.clone())?;
        }
    }
    h.flush()?;
    let p = svc.shutdown();
    let service_rps = p.stats.recordings as f64 / t0.elapsed().as_secs_f64();

    let fleet = Fleet::spawn(
        FleetConfig {
            batcher,
            stream_diagnoses: false,
            ..FleetConfig::new(shards)
        },
        |_| Ok(Backend::chipsim(compile(&model, &cfg, REC_LEN)?)),
    )?;
    let fh = fleet.handle();
    let t0 = Instant::now();
    for _ in 0..rounds {
        for x in &ds.x {
            fh.submit(x.clone())?;
        }
    }
    fh.flush()?;
    let report = fleet.shutdown();
    let fleet_rps = report.recordings as f64 / t0.elapsed().as_secs_f64();
    println!("service (1 worker)  : {service_rps:>9.1} rec/s");
    println!("fleet ({shards} shards)     : {fleet_rps:>9.1} rec/s");

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"recordings\": {},\n  \
         \"rounds\": {rounds},\n  \"cores\": {cores},\n  \
         \"fast_rps\": {fast_rps:.1},\n  \"counted_rps\": {counted_rps:.1},\n  \
         \"golden_rps\": {golden_rps:.1},\n  \
         \"golden_scratch_rps\": {golden_scratch_rps:.1},\n  \
         \"fast_vs_counted\": {speedup:.3},\n  \
         \"service_rps\": {service_rps:.1},\n  \
         \"fleet_shards\": {shards},\n  \"fleet_rps\": {fleet_rps:.1}\n}}\n",
        ds.len());
    std::fs::write("BENCH_hotpath.json", &json)?;
    println!("\nwrote BENCH_hotpath.json");

    let strict = std::env::var("HOTPATH_BENCH_STRICT")
        .is_ok_and(|v| !v.is_empty() && v != "0");
    if speedup >= 3.0 {
        println!("PASS: fast path ≥3x the counted reference ({speedup:.2}x)");
    } else if strict {
        anyhow::bail!("fast path must be ≥3x the counted reference, \
                       measured {speedup:.2}x");
    } else {
        println!("WARN: measured {speedup:.2}x < 3x — machine loaded? \
                  re-run, or set HOTPATH_BENCH_STRICT=1 to make this fatal");
    }
    Ok(())
}
