//! Bench: recordings/sec of the simulator hot path.
//!
//! Four single-engine paths over the hermetic fixture corpus —
//!
//! * **fast**    — `sim::run_scratch`: staged position-blocked lane
//!                 kernel, tile-major stripes, reusable arena,
//!                 precompiled static counters;
//! * **counted** — `sim::run_counted_scratch`: the dynamic-counting
//!                 reference over the same arena type (zero-alloc
//!                 serial tile walk);
//! * **golden**  — `nn::QuantModel::forward`: the dense integer model,
//!                 per-call allocations (the audit baseline);
//! * **golden-scratch** — `forward_scratch` over one arena (the
//!                 fleet-competitive golden serving path);
//!
//! — plus the **fused-vs-PR3 staging lane**: the interlayer glue both
//! ways — the fused stripe-staging read (`nn::pad_same_from_stripes`,
//! one pass) against the pre-fusion composition (requant-drain the
//! stripes to a row-major map, then `pad_same_into` — the PR3
//! datapath) over one full inference's worth of layer boundaries —
//! the **packed-vs-PR4 kernel lane**: the flat `PackedStreams` weight
//! arena + 8-wide packed tile kernel (`arch::tile_block_packed`)
//! against a reconstruction of the per-lane-heap-`Vec` layout it
//! replaced (bit-exactness-gated, `stream_packed_*` /
//! `tile_kernel_mwps` fields) — the **SIMD-vs-scalar dispatch lane**:
//! the same staged loop through the `arch::tile_block` runtime
//! dispatch under the detected `KernelTier` vs pinned scalar
//! (bit-exactness-gated, `kernel_tier` / `stream_simd_mwps` /
//! `stream_scalar_mwps` / `simd_speedup` fields; the ≥1.5x gate only
//! applies when the detected tier is SIMD) — the **streaming
//! delta-reuse lane**:
//! one quantized sample stream at the paper-overlap hop executed
//! incrementally (`sim::StreamingEngine`, carried columns + fringe
//! recompute) vs full recompute per window (`stream_hop_mwps` /
//! `stream_full_mwps` / `stream_speedup`, in dense-equivalent MACs/s,
//! bit-exactness-gated per window) — and the serving comparison: a
//! 4-shard chipsim `Fleet` vs the single-worker `Service`, both on
//! the fast path. Results land in `BENCH_hotpath.json`
//! (machine-readable, one file per run) so the perf trajectory
//! accumulates across PRs.
//!
//! Run: cargo bench --bench hotpath [-- shards] (default 4)
//! Acceptance: fast ≥ 3x counted on the fixture model (hard-fails only
//! with HOTPATH_BENCH_STRICT=1 — wall-clock gates are advisory on
//! loaded machines).

use std::time::Instant;

use va_accel::arch::{lane_block_staged, stage_window_block, tile_block,
                     tile_block_packed, ChipConfig, KernelTier, LaneWork};
use va_accel::compiler::{compile, CompiledModel};
use va_accel::coordinator::{Backend, BatcherConfig, Fleet, FleetConfig,
                            Pipeline, Service};
use va_accel::data::fixtures;
use va_accel::nn::{pad_same_from_stripes, pad_same_into, requant};
use va_accel::sim;
use va_accel::{REC_LEN, VOTE_GROUP};

/// Recordings/sec of `f` over `rounds` passes of the corpus (after one
/// warm-up pass).
fn rps(recs: &[Vec<i8>], rounds: usize, mut f: impl FnMut(&[i8])) -> f64 {
    for x in recs.iter().take(8) {
        f(x);
    }
    let t0 = Instant::now();
    for _ in 0..rounds {
        for x in recs {
            f(x);
        }
    }
    (rounds * recs.len()) as f64 / t0.elapsed().as_secs_f64()
}

/// The fused-vs-PR3 staging comparison: time one full inference's
/// worth of interlayer glue (every non-input layer boundary) both
/// ways and return `(fused_mwps, prefusion_mwps)` — million staged
/// words (padded-buffer elements) per second. Stripe contents are
/// synthetic; staging cost is geometry-bound, not value-bound.
fn staging_lanes(cm: &CompiledModel, iters: usize) -> (f64, f64) {
    // one synthetic stripe buffer per producer layer
    let outs: Vec<Vec<i32>> = cm.schedule.layers
        [..cm.schedule.layers.len() - 1]
        .iter()
        .map(|s| (0..s.out_len)
            .map(|i| ((i as i32).wrapping_mul(-1640531527)) >> 12)
            .collect())
        .collect();
    let mut padded = Vec::new();
    let mut act = Vec::new();
    let mut want = Vec::new();
    let mut words = 0usize;
    // bit-exactness gate before timing: fused == drain-then-pad on
    // every boundary (and count the staged words once)
    for li in 1..cm.layers.len() {
        let (layer, prev) = (&cm.layers[li], &cm.layers[li - 1]);
        let sched = &cm.schedule.layers[li];
        let (l, cin) = (sched.l_in, layer.cin);
        act.clear();
        act.resize(l * cin, 0);
        for st in &sched.in_stripes {
            let stripe = &outs[li - 1][st.offset..st.offset + l * st.live];
            for (lo, row) in stripe.chunks_exact(st.live).enumerate() {
                for (lane, &v) in row.iter().enumerate() {
                    act[lo * cin + st.base_co + lane] =
                        requant(v, prev.m0[st.base_co + lane], prev.shift,
                                prev.relu);
                }
            }
        }
        pad_same_into(&act, l, cin, layer.k, layer.stride, &mut want);
        pad_same_from_stripes(&sched.in_stripes, &outs[li - 1], l, cin,
                              layer.k, layer.stride, &prev.m0, prev.shift,
                              prev.relu, &mut padded);
        assert_eq!(padded, want, "fused staging != drain+pad, layer {li}");
        words += padded.len();
    }
    let fused = |padded: &mut Vec<i32>| {
        for li in 1..cm.layers.len() {
            let (layer, prev) = (&cm.layers[li], &cm.layers[li - 1]);
            let sched = &cm.schedule.layers[li];
            pad_same_from_stripes(&sched.in_stripes, &outs[li - 1],
                                  sched.l_in, layer.cin, layer.k,
                                  layer.stride, &prev.m0, prev.shift,
                                  prev.relu, padded);
            std::hint::black_box(padded.last());
        }
    };
    let prefusion = |act: &mut Vec<i32>, padded: &mut Vec<i32>| {
        for li in 1..cm.layers.len() {
            let (layer, prev) = (&cm.layers[li], &cm.layers[li - 1]);
            let sched = &cm.schedule.layers[li];
            let (l, cin) = (sched.l_in, layer.cin);
            act.clear();
            act.resize(l * cin, 0);
            for st in &sched.in_stripes {
                let stripe =
                    &outs[li - 1][st.offset..st.offset + l * st.live];
                for (lo, row) in stripe.chunks_exact(st.live).enumerate() {
                    for (lane, &v) in row.iter().enumerate() {
                        act[lo * cin + st.base_co + lane] =
                            requant(v, prev.m0[st.base_co + lane],
                                    prev.shift, prev.relu);
                    }
                }
            }
            pad_same_into(act, l, cin, layer.k, layer.stride, padded);
            std::hint::black_box(padded.last());
        }
    };
    for _ in 0..iters / 10 + 1 {
        fused(&mut padded); // warm-up
        prefusion(&mut act, &mut padded);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        fused(&mut padded);
    }
    let fused_mwps = (iters * words) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    let t0 = Instant::now();
    for _ in 0..iters {
        prefusion(&mut act, &mut padded);
    }
    let pre_mwps = (iters * words) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    (fused_mwps, pre_mwps)
}

/// Positions per staged window block (mirrors the engine's POS_BLOCK).
const B: usize = 8;

/// Owned per-lane stream — the PR4 memory layout (`Vec<Vec<LaneWork>>`
/// with one heap allocation pair per lane), reconstructed from the
/// flat arena purely as a measured baseline: it no longer exists on
/// any inference path.
struct VecLane {
    selects: Vec<u32>,
    weights: Vec<i32>,
    bias: i32,
}

/// The packed-vs-PR4 **kernel** lane: one full model's worth of the
/// staged position-blocked conv loop (all full position blocks of all
/// layers, synthetic activations — kernel cost is geometry-bound, not
/// value-bound), run two ways over identical work:
///
/// * **packed** — the flat `PackedStreams` arena through the 8-wide
///   packed tile kernel (`arch::tile_block_packed`), the fast path's
///   production form;
/// * **vecs** — the same loop reading one heap `Vec` pair per lane
///   through `lane_block_staged` (the PR4 pointer-chasing layout).
///
/// Returns `(packed_mwps, vecs_mwps, tile_kernel_mwps)` in million
/// staged MACs per second (stream pairs decoded × B positions each);
/// `tile_kernel_mwps` isolates `tile_block_packed` on the
/// heaviest-stream layer with staging hoisted out of the timed loop.
/// Bit-exactness-gated: both forms must produce identical stripes
/// before anything is timed.
fn kernel_lanes(cm: &CompiledModel, iters: usize) -> (f64, f64, f64) {
    // PR4 layout reconstruction + synthetic padded inputs per layer
    let vec_layout: Vec<Vec<Vec<VecLane>>> = cm.layers.iter()
        .map(|layer| {
            let ps = &layer.packed;
            (0..ps.ch_tiles()).map(|t| {
                (0..ps.m()).map(|lane| {
                    let v = ps.lane(t, lane);
                    VecLane { selects: v.selects.to_vec(),
                              weights: v.weights.to_vec(),
                              bias: ps.tile_biases(t)[lane] }
                }).collect()
            }).collect()
        })
        .collect();
    let paddeds: Vec<Vec<i32>> = cm.layers.iter()
        .zip(&cm.schedule.layers)
        .map(|(layer, s)| (0..s.l_padded * layer.cin)
            .map(|i| ((i as i32).wrapping_mul(747796405)) >> 24)
            .collect())
        .collect();
    let mut outs: Vec<Vec<i32>> = cm.schedule.layers.iter()
        .map(|s| vec![0i32; s.out_len])
        .collect();
    let mut win = Vec::new();
    // staged MACs per pass: every full block decodes each layer's nnz
    // pairs once and MACs each into B accumulators
    let words: usize = cm.layers.iter().zip(&cm.schedule.layers)
        .map(|(layer, s)| (s.lout / B) * B * layer.packed.nnz() as usize)
        .sum();

    let packed_pass = |outs: &mut [Vec<i32>], win: &mut Vec<i32>| {
        for (li, layer) in cm.layers.iter().enumerate() {
            let sched = &cm.schedule.layers[li];
            let ps = &layer.packed;
            let step = layer.stride * layer.cin;
            let wlen = sched.window_len;
            win.clear();
            win.resize(wlen * B, 0);
            let padded = &paddeds[li];
            let out = &mut outs[li];
            let mut lo = 0usize;
            while lo + B <= sched.lout {
                stage_window_block::<B>(padded, lo * step, step, wlen, win);
                for (t, st) in sched.stripes.iter().enumerate() {
                    let stripe =
                        &mut out[st.offset..st.offset + sched.lout * st.live];
                    tile_block_packed::<B>(ps.selects(), ps.weights(),
                                           ps.tile_ranges(t),
                                           ps.tile_biases(t), win, stripe,
                                           lo, st.live);
                }
                lo += B;
            }
            std::hint::black_box(out.last());
        }
    };
    let vecs_pass = |outs: &mut [Vec<i32>], win: &mut Vec<i32>| {
        for (li, layer) in cm.layers.iter().enumerate() {
            let sched = &cm.schedule.layers[li];
            let step = layer.stride * layer.cin;
            let wlen = sched.window_len;
            win.clear();
            win.resize(wlen * B, 0);
            let padded = &paddeds[li];
            let out = &mut outs[li];
            let mut lo = 0usize;
            while lo + B <= sched.lout {
                stage_window_block::<B>(padded, lo * step, step, wlen, win);
                for (t, st) in sched.stripes.iter().enumerate() {
                    let stripe =
                        &mut out[st.offset..st.offset + sched.lout * st.live];
                    for (lane, ol) in
                        vec_layout[li][t][..st.live].iter().enumerate() {
                        let w = LaneWork { selects: &ol.selects,
                                           weights: &ol.weights };
                        let acc: [i32; B] = lane_block_staged(&w, win, ol.bias);
                        for (p, v) in acc.into_iter().enumerate() {
                            stripe[(lo + p) * st.live + lane] = v;
                        }
                    }
                }
                lo += B;
            }
            std::hint::black_box(out.last());
        }
    };

    // bit-exactness gate: identical stripes from both memory layouts
    packed_pass(&mut outs, &mut win);
    let packed_ref = outs.clone();
    for o in &mut outs {
        o.iter_mut().for_each(|v| *v = 0);
    }
    vecs_pass(&mut outs, &mut win);
    assert_eq!(outs, packed_ref, "packed kernel != per-lane-Vec kernel");

    for _ in 0..iters / 10 + 1 {
        packed_pass(&mut outs, &mut win); // warm-up
        vecs_pass(&mut outs, &mut win);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        packed_pass(&mut outs, &mut win);
    }
    let packed_mwps =
        (iters * words) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    let t0 = Instant::now();
    for _ in 0..iters {
        vecs_pass(&mut outs, &mut win);
    }
    let vecs_mwps = (iters * words) as f64 / t0.elapsed().as_secs_f64() / 1e6;

    // tile-kernel isolation: heaviest stream among layers with at
    // least one full position block (the kernel writes B positions),
    // staging hoisted out of the timed loop
    let li = (0..cm.layers.len())
        .filter(|&li| cm.schedule.layers[li].lout >= B)
        .max_by_key(|&li| cm.layers[li].packed.nnz())
        .expect("model has a layer with >= B output positions");
    let (layer, sched) = (&cm.layers[li], &cm.schedule.layers[li]);
    let ps = &layer.packed;
    win.clear();
    win.resize(sched.window_len * B, 0);
    stage_window_block::<B>(&paddeds[li], 0, layer.stride * layer.cin,
                            sched.window_len, &mut win);
    let out = &mut outs[li];
    let tile_words = ps.nnz() as usize * B;
    let tile_iters = iters * 8;
    let t0 = Instant::now();
    for _ in 0..tile_iters {
        for (t, st) in sched.stripes.iter().enumerate() {
            let stripe = &mut out[st.offset..st.offset + sched.lout * st.live];
            tile_block_packed::<B>(ps.selects(), ps.weights(),
                                   ps.tile_ranges(t), ps.tile_biases(t),
                                   &win, stripe, 0, st.live);
        }
        std::hint::black_box(out.last());
    }
    let tile_kernel_mwps =
        (tile_iters * tile_words) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    (packed_mwps, vecs_mwps, tile_kernel_mwps)
}

/// The SIMD-vs-scalar dispatch lane: one full model's worth of the
/// staged position-blocked conv loop (the `kernel_lanes` geometry) run
/// through [`tile_block`] twice over identical work — once under the
/// host-detected [`KernelTier`] (AVX2 where available, honoring
/// `VACCEL_FORCE_SCALAR`), once pinned to `KernelTier::Scalar`.
/// Returns `(simd_mwps, scalar_mwps, speedup)` in million staged MACs
/// per second. Bit-exactness-gated: both tiers must produce identical
/// stripes before anything is timed. On a host whose detected tier IS
/// scalar the two lanes time the same kernel and the speedup hovers
/// at ~1.0x — the `kernel_tier` JSON field disambiguates.
fn simd_lanes(cm: &CompiledModel, iters: usize) -> (f64, f64, f64) {
    let tier = KernelTier::current();
    let paddeds: Vec<Vec<i32>> = cm.layers.iter()
        .zip(&cm.schedule.layers)
        .map(|(layer, s)| (0..s.l_padded * layer.cin)
            .map(|i| ((i as i32).wrapping_mul(747796405)) >> 24)
            .collect())
        .collect();
    let mut outs: Vec<Vec<i32>> = cm.schedule.layers.iter()
        .map(|s| vec![0i32; s.out_len])
        .collect();
    let mut win = Vec::new();
    let words: usize = cm.layers.iter().zip(&cm.schedule.layers)
        .map(|(layer, s)| (s.lout / B) * B * layer.packed.nnz() as usize)
        .sum();

    let pass = |t: KernelTier, outs: &mut [Vec<i32>], win: &mut Vec<i32>| {
        for (li, layer) in cm.layers.iter().enumerate() {
            let sched = &cm.schedule.layers[li];
            let ps = &layer.packed;
            let step = layer.stride * layer.cin;
            let wlen = sched.window_len;
            win.clear();
            win.resize(wlen * B, 0);
            let padded = &paddeds[li];
            let out = &mut outs[li];
            let mut lo = 0usize;
            while lo + B <= sched.lout {
                stage_window_block::<B>(padded, lo * step, step, wlen, win);
                for (t_ix, st) in sched.stripes.iter().enumerate() {
                    let stripe =
                        &mut out[st.offset..st.offset + sched.lout * st.live];
                    tile_block::<B>(t, ps.stream(), ps.tile_ranges(t_ix),
                                    ps.tile_biases(t_ix), win, stripe, lo,
                                    st.live);
                }
                lo += B;
            }
            std::hint::black_box(out.last());
        }
    };

    // bit-exactness gate: identical stripes from both tiers
    pass(tier, &mut outs, &mut win);
    let simd_ref = outs.clone();
    for o in &mut outs {
        o.iter_mut().for_each(|v| *v = 0);
    }
    pass(KernelTier::Scalar, &mut outs, &mut win);
    assert_eq!(outs, simd_ref,
               "dispatched {tier} kernel != scalar kernel");

    for _ in 0..iters / 10 + 1 {
        pass(tier, &mut outs, &mut win); // warm-up
        pass(KernelTier::Scalar, &mut outs, &mut win);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        pass(tier, &mut outs, &mut win);
    }
    let simd_mwps = (iters * words) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    let t0 = Instant::now();
    for _ in 0..iters {
        pass(KernelTier::Scalar, &mut outs, &mut win);
    }
    let scalar_mwps =
        (iters * words) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    (simd_mwps, scalar_mwps, simd_mwps / scalar_mwps)
}

/// The streaming delta-reuse lane: the same quantized sample stream
/// executed (a) incrementally through `sim::StreamingEngine` —
/// `hop`-sized pushes, carried columns + fringe recompute — and
/// (b) by full recompute of every window through `sim::run_scratch`.
/// Returns `(hop_mwps, full_mwps, speedup)` in million
/// **dense-equivalent** MACs per second: each emitted window counts as
/// one full inference's dense MAC load, so the two lanes are measured
/// in the same unit and the ratio is the per-window wall-clock win.
/// Bit-exactness-gated: every incremental window must equal full
/// recompute on its slice before anything is timed. The priming
/// window (a full pass by construction) is excluded from both timers.
fn streaming_lane(cm: &std::sync::Arc<CompiledModel>, hop: usize,
                  windows: usize) -> (f64, f64, f64) {
    use std::sync::Arc;
    use va_accel::sim::StreamingEngine;
    let n_samples = REC_LEN + hop * (windows - 1);
    let mut rng = va_accel::data::SplitMix64::new(0xD1CE);
    let stream: Vec<i8> = (0..n_samples)
        .map(|_| rng.range(-127.0, 128.0) as i8)
        .collect();

    // bit-exactness gate (doubles as warm-up for both paths)
    let mut eng = StreamingEngine::new(Arc::clone(cm), hop).unwrap();
    let outs = eng.push(&stream);
    assert_eq!(outs.len(), windows);
    let mut arena = sim::ScratchArena::for_model(cm);
    for (i, o) in outs.iter().enumerate() {
        let w = &stream[i * hop..i * hop + REC_LEN];
        assert_eq!(o.logits, sim::run_scratch(cm, w, &mut arena).logits,
                   "stream window {i}: incremental != full recompute");
    }
    let st = eng.stats();
    assert!(st.carried_cols > 0, "hop {hop} lane must reuse columns");

    let dense_per_window = cm.static_cost.counters.total_macs_dense() as f64;

    // hop lane: prime outside the timer, then one push per hop
    let mut eng = StreamingEngine::new(Arc::clone(cm), hop).unwrap();
    assert_eq!(eng.push(&stream[..REC_LEN]).len(), 1);
    let t0 = Instant::now();
    let mut emitted = 0usize;
    for chunk in stream[REC_LEN..].chunks(hop) {
        emitted += eng.push(chunk).len();
    }
    let hop_secs = t0.elapsed().as_secs_f64();
    assert_eq!(emitted, windows - 1);

    // full lane: the same windows, each recomputed from scratch
    let t0 = Instant::now();
    for i in 1..windows {
        let w = &stream[i * hop..i * hop + REC_LEN];
        std::hint::black_box(sim::run_scratch(cm, w, &mut arena));
    }
    let full_secs = t0.elapsed().as_secs_f64();

    let hop_mwps = (windows - 1) as f64 * dense_per_window / hop_secs / 1e6;
    let full_mwps = (windows - 1) as f64 * dense_per_window / full_secs / 1e6;
    (hop_mwps, full_mwps, hop_mwps / full_mwps)
}

fn main() -> anyhow::Result<()> {
    let shards: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let model = fixtures::default_model();
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let ds = fixtures::eval_corpus(55, 10); // 40 synthetic recordings
    let rounds = 5;
    println!("== hotpath bench: {} recordings x {} rounds ==\n",
             ds.len(), rounds);

    // bit-exactness gate before timing anything: fast logits AND static
    // counters must equal the counted reference (and the golden arena
    // twin must equal the golden model) on every recording
    let mut scratch = sim::ScratchArena::for_model(&cm);
    let mut counted_scratch = sim::ScratchArena::for_model(&cm);
    let mut golden_scratch = sim::ScratchArena::new();
    for (i, x) in ds.x.iter().enumerate() {
        let fast = sim::run_scratch(&cm, x, &mut scratch);
        let counted = sim::run_counted_scratch(&cm, x, &mut counted_scratch);
        assert_eq!(fast.logits, counted.logits, "recording {i}");
        assert_eq!(fast.counters, counted.counters,
                   "recording {i}: static counters != counted");
        assert_eq!(model.forward_scratch(x, &mut golden_scratch),
                   fast.logits, "recording {i}: golden arena twin");
    }
    println!("bit-exact: fast == counted == golden-scratch \
              (logits + counters, {} recordings)",
             ds.len());

    let fast_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(sim::run_scratch(&cm, x, &mut scratch));
    });
    let counted_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(
            sim::run_counted_scratch(&cm, x, &mut counted_scratch));
    });
    let golden_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(model.forward(x));
    });
    let golden_scratch_rps = rps(&ds.x, rounds, |x| {
        std::hint::black_box(model.forward_scratch(x, &mut golden_scratch));
    });
    let speedup = fast_rps / counted_rps;
    println!("fast    (arena + static counters)  : {fast_rps:>9.1} rec/s");
    println!("counted (dynamic reference, arena) : {counted_rps:>9.1} rec/s");
    println!("golden  (dense int model)          : {golden_rps:>9.1} rec/s");
    println!("golden-scratch (arena twin)        : {golden_scratch_rps:>9.1} rec/s");
    println!("fast vs counted: {speedup:.2}x\n");

    // fused-vs-PR3 interlayer staging lane: the pass this PR deleted,
    // measured against its fused replacement on the same geometry
    let (stage_fused_mwps, stage_prefusion_mwps) = staging_lanes(&cm, 2000);
    let stage_speedup = stage_fused_mwps / stage_prefusion_mwps;
    println!("staging fused (requant in the read): {stage_fused_mwps:>9.1} Mwords/s");
    println!("staging PR3 (drain pass + pad)     : {stage_prefusion_mwps:>9.1} Mwords/s");
    println!("fused vs pre-fusion staging: {stage_speedup:.2}x\n");

    // packed-vs-PR4 kernel lane: the flat weight-stream arena + 8-wide
    // packed tile kernel against the per-lane-Vec layout it replaced
    let (stream_packed_mwps, stream_vecs_mwps, tile_kernel_mwps) =
        kernel_lanes(&cm, 400);
    let stream_packed_speedup = stream_packed_mwps / stream_vecs_mwps;
    println!("kernel packed (flat stream arena)  : {stream_packed_mwps:>9.1} Mmacs/s");
    println!("kernel PR4 (per-lane heap Vecs)    : {stream_vecs_mwps:>9.1} Mmacs/s");
    println!("tile kernel (heaviest layer)       : {tile_kernel_mwps:>9.1} Mmacs/s");
    println!("packed vs per-lane-Vec kernel: {stream_packed_speedup:.2}x\n");

    // SIMD-vs-scalar dispatch lane: the tile kernel through
    // arch::tile_block under the detected tier vs pinned scalar, same
    // work, bit-exactness-gated inside
    let kernel_tier = KernelTier::current();
    let (stream_simd_mwps, stream_scalar_mwps, simd_speedup) =
        simd_lanes(&cm, 400);
    println!("kernel dispatched ({kernel_tier})      : {stream_simd_mwps:>9.1} Mmacs/s");
    println!("kernel pinned scalar               : {stream_scalar_mwps:>9.1} Mmacs/s");
    println!("{kernel_tier} vs scalar kernel: {simd_speedup:.2}x\n");

    // streaming delta-reuse lane at the paper-overlap hop: incremental
    // window advance vs full recompute per window, dense-equivalent
    // MACs/s (bit-exactness-gated per window inside)
    let stream_hop = 32usize;
    let cm_arc = std::sync::Arc::new(cm.clone());
    let (stream_hop_mwps, stream_full_mwps, stream_speedup) =
        streaming_lane(&cm_arc, stream_hop, 200);
    println!("stream incremental (hop {stream_hop})       : {stream_hop_mwps:>9.1} Mmacs/s");
    println!("stream full recompute per window   : {stream_full_mwps:>9.1} Mmacs/s");
    println!("incremental vs full recompute: {stream_speedup:.2}x\n");

    // serving comparison, fast path end to end
    let batcher = BatcherConfig {
        max_batch: VOTE_GROUP,
        max_age: std::time::Duration::ZERO,
    };
    let svc = Service::spawn(Pipeline::new(
        Backend::chipsim(compile(&model, &cfg, REC_LEN)?),
        batcher.clone(), VOTE_GROUP));
    let h = svc.handle();
    let t0 = Instant::now();
    for _ in 0..rounds {
        for x in &ds.x {
            h.submit_recording(x.clone())?;
        }
    }
    h.flush()?;
    let p = svc.shutdown();
    let service_rps = p.stats.recordings as f64 / t0.elapsed().as_secs_f64();

    let fleet = Fleet::spawn(
        FleetConfig {
            batcher,
            stream_diagnoses: false,
            ..FleetConfig::new(shards)
        },
        {
            let model = model.clone();
            let cfg = cfg.clone();
            move |_| Ok(Backend::chipsim(compile(&model, &cfg, REC_LEN)?))
        },
    )?;
    let fh = fleet.handle();
    let t0 = Instant::now();
    for _ in 0..rounds {
        for x in &ds.x {
            fh.submit(x.clone())?;
        }
    }
    fh.flush()?;
    let report = fleet.shutdown();
    let fleet_rps = report.recordings as f64 / t0.elapsed().as_secs_f64();
    println!("service (1 worker)  : {service_rps:>9.1} rec/s");
    println!("fleet ({shards} shards)     : {fleet_rps:>9.1} rec/s");

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"recordings\": {},\n  \
         \"rounds\": {rounds},\n  \"cores\": {cores},\n  \
         \"fast_rps\": {fast_rps:.1},\n  \"counted_rps\": {counted_rps:.1},\n  \
         \"golden_rps\": {golden_rps:.1},\n  \
         \"golden_scratch_rps\": {golden_scratch_rps:.1},\n  \
         \"fast_vs_counted\": {speedup:.3},\n  \
         \"stage_fused_mwps\": {stage_fused_mwps:.1},\n  \
         \"stage_prefusion_mwps\": {stage_prefusion_mwps:.1},\n  \
         \"stage_fused_speedup\": {stage_speedup:.3},\n  \
         \"stream_packed_mwps\": {stream_packed_mwps:.1},\n  \
         \"stream_vecs_mwps\": {stream_vecs_mwps:.1},\n  \
         \"stream_packed_speedup\": {stream_packed_speedup:.3},\n  \
         \"tile_kernel_mwps\": {tile_kernel_mwps:.1},\n  \
         \"kernel_tier\": \"{kernel_tier}\",\n  \
         \"stream_simd_mwps\": {stream_simd_mwps:.1},\n  \
         \"stream_scalar_mwps\": {stream_scalar_mwps:.1},\n  \
         \"simd_speedup\": {simd_speedup:.3},\n  \
         \"stream_hop\": {stream_hop},\n  \
         \"stream_hop_mwps\": {stream_hop_mwps:.1},\n  \
         \"stream_full_mwps\": {stream_full_mwps:.1},\n  \
         \"stream_speedup\": {stream_speedup:.3},\n  \
         \"service_rps\": {service_rps:.1},\n  \
         \"fleet_shards\": {shards},\n  \"fleet_rps\": {fleet_rps:.1}\n}}\n",
        ds.len());
    std::fs::write("BENCH_hotpath.json", &json)?;
    println!("\nwrote BENCH_hotpath.json");

    let strict = std::env::var("HOTPATH_BENCH_STRICT")
        .is_ok_and(|v| !v.is_empty() && v != "0");
    if speedup >= 3.0 {
        println!("PASS: fast path ≥3x the counted reference ({speedup:.2}x)");
    } else if strict {
        anyhow::bail!("fast path must be ≥3x the counted reference, \
                       measured {speedup:.2}x");
    } else {
        println!("WARN: measured {speedup:.2}x < 3x — machine loaded? \
                  re-run, or set HOTPATH_BENCH_STRICT=1 to make this fatal");
    }
    if !kernel_tier.is_simd() {
        println!("INFO: kernel tier is scalar (no AVX2 or \
                  VACCEL_FORCE_SCALAR set) — simd_speedup gate skipped");
    } else if simd_speedup >= 1.5 {
        println!("PASS: {kernel_tier} kernel ≥1.5x the scalar twin \
                  ({simd_speedup:.2}x)");
    } else if strict {
        anyhow::bail!("{kernel_tier} kernel must be ≥1.5x the scalar twin, \
                       measured {simd_speedup:.2}x");
    } else {
        println!("WARN: {kernel_tier} measured {simd_speedup:.2}x < 1.5x — \
                  machine loaded? re-run, or set HOTPATH_BENCH_STRICT=1 \
                  to make this fatal");
    }
    if stream_speedup >= 3.0 {
        println!("PASS: incremental streaming ≥3x full recompute at hop \
                  {stream_hop} ({stream_speedup:.2}x)");
    } else if strict {
        anyhow::bail!("incremental streaming must be ≥3x full recompute at \
                       hop {stream_hop}, measured {stream_speedup:.2}x");
    } else {
        println!("WARN: streaming measured {stream_speedup:.2}x < 3x — \
                  machine loaded? re-run, or set HOTPATH_BENCH_STRICT=1 \
                  to make this fatal");
    }
    Ok(())
}
