//! Bench: adversarial scenario & drift suite through the FULL
//! streaming path — the stress harness behind the 99.95% claim.
//!
//! Every perturbation family from `data::scenarios` is streamed
//! through a `StreamSession` → `StreamingEngine`, and **every emitted
//! window is audited bit-exact against the offline per-window fast
//! path** (`run_scenario` errors on any logit mismatch — the audit is
//! always fatal, never advisory). Per-scenario sensitivity /
//! specificity / accuracy land in `BENCH_scenarios.json`.
//!
//! Two recalibration acceptance lanes ride along:
//!
//! * **Controlled margin drift** (`ctl_*` lanes): real clean-run
//!   margins from the model are replayed through the
//!   `Recalibrator` with synthetic plateau offsets large enough that
//!   the fixed threshold provably scores sensitivity 0 on the drifted
//!   plateaus, while the loop provably recovers the clean decisions
//!   (the ring holds exactly one full pattern cycle at the scored
//!   positions, so its median tracks the offset exactly). Gated under
//!   `SCENARIOS_BENCH_STRICT=1`.
//! * **Clean-NSR specificity** (`clean_nsr_*` lanes): a recal config
//!   whose dead zone exceeds the stream's total margin spread can
//!   never apply compensation, so recalibrated specificity on clean
//!   NSR equals fixed specificity *exactly*. Structural — always
//!   fatal.
//!
//! Hermetic: fixture model when `artifacts/weights.bin` is absent
//! (scores are then structural, not clinical).
//!
//! Run: cargo bench --bench scenarios
//! Strict gates: SCENARIOS_BENCH_STRICT=1 cargo bench --bench scenarios

use std::collections::HashSet;
use std::fmt::Write as _;
use std::sync::Arc;

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::coordinator::{run_scenario, RecalConfig, Recalibrator};
use va_accel::data::{fixtures, Scenario};
use va_accel::metrics::Confusion;
use va_accel::{ARTIFACT_DIR, REC_LEN};

const HOP: usize = 128;
const SEED: u64 = 0x5CE9;

fn median(v: &mut [i64]) -> f64 {
    v.sort_unstable();
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2] as f64
    } else {
        (v[n / 2 - 1] as f64 + v[n / 2] as f64) / 2.0
    }
}

fn main() -> anyhow::Result<()> {
    let strict = std::env::var("SCENARIOS_BENCH_STRICT")
        .is_ok_and(|v| !v.is_empty() && v != "0");
    let trained = std::path::Path::new(
        &format!("{ARTIFACT_DIR}/weights.bin")).exists();
    if !trained {
        eprintln!("note: {ARTIFACT_DIR}/weights.bin not found — using the \
                   hermetic fixture model (random weights; run `make \
                   artifacts` for the trained network)");
    }
    let model = fixtures::model_or_artifact();
    let cm = Arc::new(compile(&model, &ChipConfig::paper_1d(), REC_LEN)?);

    // the canonical suite plus extra points on the noise axis
    let mut suite = Scenario::standard_suite(SEED);
    suite.extend(Scenario::noise_sweep(SEED ^ 7, 12, &[0.6, 2.0]));

    println!("== adversarial scenario suite (hop {HOP}) ==\n");
    println!("{:<22} {:>7} {:>6} {:>6} {:>6} {:>6} {:>7} {:>6} {:>6}",
             "scenario", "windows", "eval", "sens", "spec", "acc", "agree",
             "rsens", "rspec");
    let mut rows = String::new();
    let (mut total_windows, mut evaluated_windows, mut oracle_checked) =
        (0usize, 0usize, 0usize);
    let mut clean_out = None;
    for sc in &suite {
        // every scenario also gets a recalibrated replay (reported,
        // not gated — the provable gates are the dedicated lanes
        // below); run_scenario asserts the replay's logits are
        // bit-identical to the fixed pass
        let out = run_scenario(&cm, sc, HOP, Some(RecalConfig::default()))?;
        total_windows += out.windows;
        evaluated_windows += out.evaluated;
        oracle_checked += out.audited;
        let rc = out.recal.as_ref().expect("recal replay requested");
        let agree_s = out.clean_agreement
            .map(|a| format!("{a:>7.3}"))
            .unwrap_or_else(|| format!("{:>7}", "-"));
        println!("{:<22} {:>7} {:>6} {:>6.3} {:>6.3} {:>6.3} {agree_s} \
                  {:>6.3} {:>6.3}",
                 out.name, out.windows, out.evaluated, out.fixed.recall(),
                 out.fixed.specificity(), out.fixed.accuracy(),
                 rc.recall(), rc.specificity());
        if !rows.is_empty() {
            rows.push_str(",\n");
        }
        let agree_j = out.clean_agreement
            .map(|a| format!("{a:.4}"))
            .unwrap_or_else(|| "null".into());
        write!(rows,
               "    {{\"name\": \"{}\", \"family\": \"{}\", \
                \"windows\": {}, \"evaluated\": {}, \"sens\": {:.4}, \
                \"spec\": {:.4}, \"acc\": {:.4}, \
                \"clean_agreement\": {agree_j}, \"recal_sens\": {:.4}, \
                \"recal_spec\": {:.4}}}",
               out.name, out.family, out.windows, out.evaluated,
               out.fixed.recall(), out.fixed.specificity(),
               out.fixed.accuracy(), rc.recall(), rc.specificity())?;
        if out.family == "clean" {
            clean_out = Some(out);
        }
    }
    let families: HashSet<_> = suite.iter().map(|s| s.family).collect();
    anyhow::ensure!(families.len() >= 6,
                    "suite must span >=6 scenario families, has {}",
                    families.len());
    println!("\nbit-exact: {oracle_checked} streamed windows matched the \
              offline fast path under every scenario");

    // ---- controlled margin-drift lane (the recalibration sensitivity
    //      acceptance gate) -------------------------------------------
    // Real labeled margins from the clean run, sign-adjusted so the VA
    // median sits above the non-VA median; fall back to a surrogate
    // pattern if the fixture margins are degenerate (no class
    // separation — possible with random weights, impossible to tune
    // around, and irrelevant to what this lane proves about the loop).
    let clean_out = clean_out.expect("suite contains the clean scenario");
    let mut lab: Vec<(i64, bool)> = clean_out.margins.iter()
        .zip(&clean_out.truth)
        .filter_map(|(&m, t)| t.map(|t| (m, t)))
        .collect();
    let n_va = lab.iter().filter(|(_, t)| *t).count();
    let n_nv = lab.len() - n_va;
    let mut surrogate = false;
    if n_va < 2 || n_nv < 2 {
        surrogate = true;
    } else {
        let mut vas: Vec<i64> = lab.iter().filter(|(_, t)| *t)
            .map(|(m, _)| *m).collect();
        let mut nvs: Vec<i64> = lab.iter().filter(|(_, t)| !*t)
            .map(|(m, _)| *m).collect();
        let (mva, mnv) = (median(&mut vas), median(&mut nvs));
        if mva < mnv {
            // model polarity happens to be flipped on this corpus:
            // work in negated-margin space (pure relabeling)
            for (m, _) in lab.iter_mut() {
                *m = -*m;
            }
        }
        if (mva - mnv).abs() < 2.0 {
            surrogate = true;
        }
    }
    if surrogate {
        println!("WARN: clean-run margins carry no class separation \
                  (fixture weights) — controlled-drift lane falls back \
                  to surrogate margins");
        lab = (0..40)
            .map(|i| {
                let t = i % 2 == 0;
                ((if t { 500 } else { -500 }) + (i as i64 % 7), t)
            })
            .collect();
    }
    let mut vas: Vec<i64> = lab.iter().filter(|(_, t)| *t)
        .map(|(m, _)| *m).collect();
    let mut nvs: Vec<i64> = lab.iter().filter(|(_, t)| !*t)
        .map(|(m, _)| *m).collect();
    let (mva, mnv) = (median(&mut vas), median(&mut nvs));
    let ctl_separation = mva - mnv;
    let theta = (mva + mnv) / 2.0;
    let l = lab.len();
    let lo = lab.iter().map(|(m, _)| *m).min().unwrap();
    let hi = lab.iter().map(|(m, _)| *m).max().unwrap();
    // plateau offset: 4x the full margin spread pushes every drifted
    // margin strictly below theta, so the fixed threshold cannot score
    let d = 4 * (hi - lo).max(1);
    let mut recal = Recalibrator::new(RecalConfig {
        theta0: theta, horizon: l, warmup: l, dead_zone: 0.0,
        max_shift: 1e15,
    });
    let mut clean_fixed = Confusion::new();
    let mut fixed_drift = Confusion::new();
    let mut recal_drift = Confusion::new();
    for b in 0..4i64 {
        // each plateau is the labeled pattern twice: the first cycle
        // settles the ring, the second is scored (the ring then holds
        // exactly one full cycle, so its median is the clean median
        // minus the plateau offset, exactly)
        for rep in 0..2 {
            for &(m, t) in &lab {
                let shifted = m - b * d;
                let rdec = recal.decide(shifted);
                let fdec = (shifted as f64) > theta;
                if rep == 1 {
                    if b == 0 {
                        clean_fixed.push(fdec, t);
                    } else {
                        fixed_drift.push(fdec, t);
                        recal_drift.push(rdec, t);
                    }
                }
            }
        }
    }
    let ctl_fixed_sens = fixed_drift.recall();
    let ctl_recal_sens = recal_drift.recall();
    let ctl_delta = ctl_recal_sens - ctl_fixed_sens;
    println!("\ncontrolled drift: separation {ctl_separation:.1}, clean \
              sens {:.3} | drifted plateaus: fixed sens {ctl_fixed_sens:.3} \
              vs recalibrated {ctl_recal_sens:.3} (spec {:.3})",
             clean_fixed.recall(), recal_drift.specificity());
    let ctl_ok = ctl_fixed_sens == 0.0 && ctl_recal_sens > 0.0;
    if ctl_ok {
        println!("PASS: recalibration recovers drifted sensitivity the \
                  fixed threshold loses entirely");
    } else if strict {
        anyhow::bail!("controlled-drift gate: expected fixed sens 0 < \
                       recal sens, got {ctl_fixed_sens:.3} vs \
                       {ctl_recal_sens:.3}");
    } else {
        println!("WARN: controlled-drift gate not met ({ctl_fixed_sens:.3} \
                  vs {ctl_recal_sens:.3}) — set SCENARIOS_BENCH_STRICT=1 \
                  to make this fatal");
    }

    // ---- clean-NSR specificity lane (structural, always fatal) ------
    // With the dead zone wider than the stream's total margin spread,
    // every drift estimate lands inside it, compensation stays 0, and
    // the recalibrated verdicts are bit-identical to argmax.
    let nsr = Scenario::clean_nsr(SEED ^ 9, 16);
    let fixed_pass = run_scenario(&cm, &nsr, HOP, None)?;
    let spread = (fixed_pass.margins.iter().max().unwrap()
        - fixed_pass.margins.iter().min().unwrap()) as f64;
    let guard_cfg = RecalConfig { theta0: 0.0, dead_zone: spread + 1.0,
                                  ..RecalConfig::default() };
    let recal_pass = run_scenario(&cm, &nsr, HOP, Some(guard_cfg))?;
    let spec_fixed = recal_pass.fixed.specificity();
    let spec_recal = recal_pass.recal.as_ref().unwrap().specificity();
    let spec_delta = spec_recal - spec_fixed;
    println!("clean NSR specificity: fixed {spec_fixed:.4}, recalibrated \
              {spec_recal:.4} (margin spread {spread:.0}, dead zone \
              {:.0})", spread + 1.0);
    anyhow::ensure!(spec_delta.abs() < 1e-9,
                    "recalibration degraded clean-NSR specificity: \
                     {spec_fixed:.6} -> {spec_recal:.6} — the dead-zone \
                     guarantee is structural, this is a bug");
    anyhow::ensure!(fixed_pass.fixed == recal_pass.fixed,
                    "clean-NSR fixed pass must be deterministic");
    println!("PASS: clean-NSR specificity unchanged under recalibration \
              (delta {spec_delta:.1e})");

    let json = format!(
        "{{\n  \"bench\": \"scenarios\",\n  \"hop\": {HOP},\n  \
         \"seed\": {SEED},\n  \"trained_weights\": {trained},\n  \
         \"families\": {},\n  \"scenarios\": {},\n  \
         \"total_windows\": {total_windows},\n  \
         \"evaluated_windows\": {evaluated_windows},\n  \
         \"oracle_checked\": {oracle_checked},\n  \
         \"oracle_mismatches\": 0,\n  \
         \"ctl_separation\": {ctl_separation:.1},\n  \
         \"ctl_surrogate\": {surrogate},\n  \
         \"ctl_fixed_sens\": {ctl_fixed_sens:.4},\n  \
         \"ctl_recal_sens\": {ctl_recal_sens:.4},\n  \
         \"ctl_sens_delta\": {ctl_delta:.4},\n  \
         \"clean_nsr_spec_fixed\": {spec_fixed:.4},\n  \
         \"clean_nsr_spec_recal\": {spec_recal:.4},\n  \
         \"clean_nsr_spec_delta\": {spec_delta:.4},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n",
        families.len(), suite.len());
    std::fs::write("BENCH_scenarios.json", &json)?;
    println!("\nwrote BENCH_scenarios.json");
    Ok(())
}
