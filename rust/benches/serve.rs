//! Bench: the network serving front end under load.
//!
//! Spawns a real `coordinator::NetServer` on a loopback port and
//! drives `SERVE_BENCH_CONNS` (default 1024) concurrent device
//! connections through the full wire path with
//! `coordinator::loadgen`: every device speaks the length-prefixed
//! binary protocol over its own `TcpStream`, rendezvouses at a
//! barrier *after* connecting (so the sessions are provably
//! concurrent, not sequential), streams `SERVE_BENCH_WINDOWS`
//! (default 4) windows of pre-quantized samples in lockstep, and then
//! verifies every received diagnosis against a fresh offline
//! `StreamSession` run of the identical sample stream.
//!
//! Always fatal (bit-exactness is not a wall-clock property):
//!
//! * any streamed diagnosis differing from the offline oracle;
//! * any expected window not delivered.
//!
//! Fatal only with `SERVE_BENCH_STRICT=1` (scale gates depend on the
//! host's fd limits and scheduler):
//!
//! * any device failing to connect (after retry/backoff);
//! * peak concurrent sessions below the connection target.
//!
//! Results land in `BENCH_serve.json`: conns, sustained samples/s,
//! p50/p99/mean end-to-end diagnosis latency, BUSY/eviction counts.
//!
//! Run: cargo bench --bench serve
//! Env: SERVE_BENCH_CONNS (1024), SERVE_BENCH_WINDOWS (4),
//!      SERVE_BENCH_HOP (128), SERVE_BENCH_STRICT (0)

use std::sync::Arc;

use va_accel::arch::{ChipConfig, KernelTier};
use va_accel::compiler::compile;
use va_accel::coordinator::{loadgen, NetServer, ServeConfig};
use va_accel::data::fixtures;
use va_accel::REC_LEN;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let conns = env_usize("SERVE_BENCH_CONNS", 1024);
    let windows = env_usize("SERVE_BENCH_WINDOWS", 4);
    let hop = env_usize("SERVE_BENCH_HOP", 128);
    let strict = std::env::var("SERVE_BENCH_STRICT")
        .is_ok_and(|v| !v.is_empty() && v != "0");

    let model = fixtures::default_model();
    let cm = Arc::new(compile(&model, &ChipConfig::paper_1d(), REC_LEN)?);
    let kernel_tier = KernelTier::current();
    println!("== serve bench: {conns} concurrent device connections x \
              {windows} windows, hop {hop}, kernel tier {kernel_tier} ==\n");

    let token = "bench-token";
    let mut cfg = ServeConfig::loopback(token, hop);
    cfg.max_conns = conns + 64; // headroom over the device fleet
    let (shards, workers) = (cfg.accept_shards, cfg.workers);
    let srv = NetServer::spawn(cfg, Arc::clone(&cm))?;
    let addr = srv.local_addr();
    println!("server on {addr}: {shards} accept shards, \
              {workers} session workers");

    let rep = loadgen(addr, token, Arc::clone(&cm), conns, windows)?;
    let stats = srv.shutdown();

    println!("connected: {}/{} devices ({} connect failures)",
             conns as u64 - rep.connect_failures, conns,
             rep.connect_failures);
    println!("peak concurrent sessions: {}", stats.peak_sessions);
    println!("windows: {} delivered / {} expected",
             rep.total_windows,
             (conns as u64 - rep.connect_failures) * windows as u64);
    println!("throughput: {:.0} samples/s sustained ({} samples in \
              {:.2}s)", rep.samples_per_s, rep.total_samples,
             rep.elapsed_s);
    println!("latency: p50 {:.0}µs  p99 {:.0}µs  mean {:.0}µs",
             rep.p50_us, rep.p99_us, rep.mean_us);
    println!("backpressure: {} BUSY frames ({} client resends), \
              {} slow-reader evictions",
             stats.busy_frames, rep.busy_retries, stats.evicted_slow);

    // bit-exactness and delivery: always fatal
    anyhow::ensure!(rep.mismatches == 0,
                    "{} streamed diagnoses diverged from the offline \
                     StreamSession oracle", rep.mismatches);
    let want = (conns as u64 - rep.connect_failures) * windows as u64;
    anyhow::ensure!(rep.total_windows == want,
                    "delivered {}/{want} windows", rep.total_windows);
    println!("\nbit-exact: every streamed diagnosis matches the offline \
              oracle ({} windows)", rep.total_windows);

    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"conns\": {conns},\n  \
         \"connect_failures\": {},\n  \"windows_per_conn\": {windows},\n  \
         \"hop\": {hop},\n  \"total_windows\": {},\n  \
         \"total_samples\": {},\n  \"samples_per_s\": {:.1},\n  \
         \"p50_us\": {:.1},\n  \"p99_us\": {:.1},\n  \"mean_us\": {:.1},\n  \
         \"busy_frames\": {},\n  \"busy_retries\": {},\n  \
         \"evicted_slow\": {},\n  \"peak_sessions\": {},\n  \
         \"mismatches\": {},\n  \"kernel_tier\": \"{kernel_tier}\"\n}}\n",
        rep.connect_failures, rep.total_windows, rep.total_samples,
        rep.samples_per_s, rep.p50_us, rep.p99_us, rep.mean_us,
        stats.busy_frames, rep.busy_retries, stats.evicted_slow,
        stats.peak_sessions, rep.mismatches);
    std::fs::write("BENCH_serve.json", &json)?;
    println!("wrote BENCH_serve.json");

    // scale gates: advisory unless strict (fd limits / scheduler)
    if rep.connect_failures == 0 && stats.peak_sessions >= conns {
        println!("PASS: {conns} concurrent sessions sustained \
                  (peak {})", stats.peak_sessions);
    } else if strict {
        anyhow::bail!("scale gate: {} connect failures, peak {} < {conns} \
                       concurrent sessions",
                      rep.connect_failures, stats.peak_sessions);
    } else {
        println!("WARN: {} connect failures, peak {} sessions (target \
                  {conns}) — raise `ulimit -n`, or set \
                  SERVE_BENCH_STRICT=1 to make this fatal",
                 rep.connect_failures, stats.peak_sessions);
    }
    Ok(())
}
