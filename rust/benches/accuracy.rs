//! Bench: §3 accuracy claims — per-recording inference accuracy and
//! voted diagnostic accuracy/precision/recall on the evaluation corpus
//! (the corpus python audited at build time; bit-exact across
//! backends, so the backend choice only changes wall time).
//!
//! Run: cargo bench --bench accuracy

use va_accel::coordinator::{Backend, Pipeline};
use va_accel::data::load_eval;
use va_accel::nn::QuantModel;
use va_accel::{ARTIFACT_DIR, VOTE_GROUP};

fn main() -> anyhow::Result<()> {
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))?;
    let truth = ds.va_labels();
    let backend = Backend::golden(model);

    println!("== accuracy bench (paper §3) ==");
    println!("corpus: {} recordings (4-class synthetic IEGM, VA = VT|VF)\n", ds.len());
    let (rec, ep) = Pipeline::evaluate(&backend, &ds.x, &truth, VOTE_GROUP)?;
    println!("                         paper       ours");
    println!("inference accuracy    :  92.35 %   {:>6.2} %", rec.accuracy() * 100.0);
    println!("diagnostic accuracy   :  99.95 %   {:>6.2} %", ep.accuracy() * 100.0);
    println!("diagnostic precision  :  99.88 %   {:>6.2} %", ep.precision() * 100.0);
    println!("diagnostic recall     :  99.84 %   {:>6.2} %", ep.recall() * 100.0);
    println!("\nper-recording detail  : {rec}");
    println!("episode detail        : {ep}");

    // vote-group sweep: why the paper chose 6
    println!("\nvote-group sweep (diagnostic accuracy):");
    for g in [1usize, 2, 4, 6, 8, 12] {
        let (_, e) = Pipeline::evaluate(&backend, &ds.x, &truth, g)?;
        println!("  group {g:>2}: acc {:.4}  prec {:.4}  rec {:.4}  ({} episodes)",
                 e.accuracy(), e.precision(), e.recall(), e.total());
    }
    Ok(())
}
