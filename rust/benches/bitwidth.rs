//! Bench: Fig. 3 — the mixed-bit CMUL. Sweeps weight precision
//! 8/4/2/1-bit (uniform and mixed per-layer profiles) and reports
//! cycles, inference time, energy, and effective GOPS: the
//! "adaptively select operands for different precision requirements,
//! enhancing both energy efficiency and performance" claim.
//!
//! Precision re-quantization here is structural (clamping to the
//! narrower range) — accuracy at reduced precision is a training-time
//! question (python QAT supports per-layer nbits); this bench isolates
//! the hardware cost axis.
//!
//! Run: cargo bench --bench bitwidth

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::data::{Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

fn requantize(model: &QuantModel, bits: &[u32]) -> QuantModel {
    let mut m = model.clone();
    for (ly, &nb) in m.layers.iter_mut().zip(bits) {
        ly.nbits = nb;
        let qmax = if nb == 1 { 1 } else { (1 << (nb - 1)) - 1 };
        for w in &mut ly.w {
            *w = (*w).clamp(-qmax, qmax);
        }
    }
    m
}

fn main() -> anyhow::Result<()> {
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let mut gen = Generator::new(17);
    let x = gen.recording(RhythmClass::Vf).quantized();
    let cfg = ChipConfig::paper_1d();
    let em = EnergyModel::lp40();
    let am = AreaModel::lp40();

    println!("== CMUL precision sweep (Fig. 3: 8/4/2/1-bit reconfigurable) ==\n");
    println!("{:<26}{:>9}{:>11}{:>11}{:>9}{:>12}",
             "profile", "cycles", "t_inf µs", "µJ/inf", "GOPS", "seg-ops");
    let uniform: Vec<(String, Vec<u32>)> = [8u32, 4, 2, 1].iter()
        .map(|&b| (format!("uniform {b}-bit"), vec![b; 8]))
        .collect();
    let mixed = vec![
        ("mixed 8-4-4-4-4-4-4-8".to_string(), vec![8, 4, 4, 4, 4, 4, 4, 8]),
        ("mixed 8-8-4-4-4-2-2-8".to_string(), vec![8, 8, 4, 4, 4, 2, 2, 8]),
    ];
    let mut base_cycles = 0u64;
    for (label, bits) in uniform.into_iter().chain(mixed) {
        let m = requantize(&model, &bits);
        let cm = compile(&m, &cfg, REC_LEN)?;
        let r = sim::run(&cm, &x);
        let rep = report(&r.counters, &cfg, &em, &am);
        if base_cycles == 0 {
            base_cycles = rep.cycles;
        }
        println!("{label:<26}{:>9}{:>11.2}{:>11.3}{:>9.1}{:>12}",
                 rep.cycles, rep.t_active_s * 1e6, rep.e_active_j * 1e6,
                 rep.gops, r.counters.total_segment_ops());
    }
    println!("\nshape check: cycles and energy must fall monotonically with");
    println!("precision (8→1-bit gives up to {}× CMUL throughput).",
             va_accel::arch::macs_per_cycle(1));
    Ok(())
}
