//! Bench: the sharded multi-chip serving engine vs the single-worker
//! `Service` on the synthetic IEGM corpus, plus bit-exactness of the
//! parallel tile engine. Fully hermetic (fixture model — geometry,
//! sparsity and precision profile of the paper network).
//!
//! Run: cargo bench --bench fleet [-- shards] (default 4)

use std::time::Instant;

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, BatcherConfig, Fleet, FleetConfig,
                            Pipeline, Service};
use va_accel::data::fixtures;
use va_accel::sim;
use va_accel::{REC_LEN, VOTE_GROUP};

fn main() -> anyhow::Result<()> {
    let shards: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let model = fixtures::default_model();
    let cfg = ChipConfig::paper_1d();
    let ds = fixtures::eval_corpus(33, 30); // 120 synthetic recordings
    let batcher = BatcherConfig {
        max_batch: VOTE_GROUP,
        max_age: std::time::Duration::ZERO,
    };

    println!("== fleet bench: {} recordings, chipsim backend ==\n", ds.len());

    // (a) parallel tile engine must be bit-exact (logits AND counters)
    let cm = compile(&model, &cfg, REC_LEN)?;
    for x in ds.x.iter().take(16) {
        let a = sim::run_serial(&cm, x);
        let b = sim::run_parallel(&cm, x);
        assert_eq!(a.logits, b.logits, "parallel engine changed logits");
        assert_eq!(a.counters, b.counters, "parallel engine changed counters");
    }
    println!("parallel tile engine: bit-exact vs serial (16 recordings, \
              logits + counters)");

    // (b) single-worker Service baseline
    let backend = Backend::chipsim(compile(&model, &cfg, REC_LEN)?);
    let svc = Service::spawn(Pipeline::new(backend, batcher.clone(), VOTE_GROUP));
    let h = svc.handle();
    let t0 = Instant::now();
    for x in &ds.x {
        h.submit_recording(x.clone())?;
    }
    h.flush()?;
    let p = svc.shutdown();
    let t_service = t0.elapsed().as_secs_f64();
    assert_eq!(p.stats.recordings, ds.len() as u64);
    let rps_service = ds.len() as f64 / t_service;
    println!("service (1 worker) : {:>8.3} s  {:>8.1} rec/s  latency {}",
             t_service, rps_service, p.latency.clone().summary());

    // (c) sharded fleet, one compiled model + engine per shard
    let fleet = Fleet::spawn(
        FleetConfig {
            batcher: batcher.clone(),
            stream_diagnoses: false, // report-style run, nobody recv()s
            ..FleetConfig::new(shards)
        },
        {
            let model = model.clone();
            let cfg = cfg.clone();
            move |_| Ok(Backend::chipsim(compile(&model, &cfg, REC_LEN)?))
        },
    )?;
    let fh = fleet.handle();
    let t0 = Instant::now();
    for x in &ds.x {
        fh.submit(x.clone())?;
    }
    fh.flush()?;
    let report = fleet.shutdown();
    let t_fleet = t0.elapsed().as_secs_f64();
    assert_eq!(report.recordings, ds.len() as u64);
    let rps_fleet = ds.len() as f64 / t_fleet;
    println!("fleet ({shards} shards)    : {:>8.3} s  {:>8.1} rec/s",
             t_fleet, rps_fleet);
    println!("\n{report}\n");

    let speedup = rps_fleet / rps_service;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("fleet speedup: {speedup:.2}x over single-worker service \
              ({cores} cores available)");
    if cores < shards {
        println!("note: fewer cores than shards — scaling check skipped");
    } else if speedup >= 2.0 {
        println!("PASS: ≥2x fleet scaling demonstrated");
    } else if std::env::var("FLEET_BENCH_STRICT").is_ok() {
        // hard gate only on request: wall-clock thresholds are
        // nondeterministic on loaded/throttled machines
        anyhow::bail!("a {shards}-shard fleet on {cores} cores must be \
                       ≥2x the single worker, measured {speedup:.2}x");
    } else {
        println!("WARN: measured {speedup:.2}x < 2x — machine loaded? \
                  re-run, or set FLEET_BENCH_STRICT=1 to make this fatal");
    }
    Ok(())
}
