//! Bench: Fig. 1/2 architecture ablations.
//!
//! (a) SPad organization — the paper's single shared SPad per SPE vs
//!     Eyeriss-v2-style per-PE SPads+FIFOs: energy, area, both dies
//!     running the same workload.
//! (b) Array geometry — N×W×H×M scaling and PE engagement.
//!
//! Run: cargo bench --bench spe_ablation

use va_accel::arch::{ChipConfig, SpadSharing};
use va_accel::compiler::compile;
use va_accel::data::{Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::power::{area_mm2, report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

fn main() -> anyhow::Result<()> {
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))?;
    let mut gen = Generator::new(31);
    let x = gen.recording(RhythmClass::Vt).quantized();
    let em = EnergyModel::lp40();
    let am = AreaModel::lp40();

    println!("== SPE ablation (Fig. 2: single shared SPad, no FIFOs) ==\n");
    println!("{:<36}{:>12}{:>12}{:>12}{:>14}", "organization", "µJ/inf",
             "die mm²", "avg µW", "spad+fifo ev");
    for (sharing, label) in [
        (SpadSharing::Shared, "shared SPad per SPE (paper)"),
        (SpadSharing::PerPe, "per-PE SPads + FIFOs (Eyeriss-v2)"),
    ] {
        let cfg = ChipConfig { spad_sharing: sharing, ..ChipConfig::paper_1d() };
        let cm = compile(&model, &cfg, REC_LEN)?;
        let r = sim::run(&cm, &x);
        let rep = report(&r.counters, &cfg, &em, &am);
        let t = r.counters.total();
        println!("{label:<36}{:>12.3}{:>12.2}{:>12.2}{:>14}",
                 rep.e_active_j * 1e6, rep.area_mm2, rep.p_avg_w * 1e6,
                 t.spad.reads + t.spad.writes + t.spad.fifo_ops);
    }
    let shared = ChipConfig::paper_1d();
    let perpe = ChipConfig { spad_sharing: SpadSharing::PerPe, ..ChipConfig::paper_1d() };
    println!("\narea saved by sharing: {:.2} mm² on the 512-PE die",
             area_mm2(&perpe, &am) - area_mm2(&shared, &am));

    println!("\n== geometry scaling (W×H×M output block parallelism) ==\n");
    println!("{:<28}{:>6}{:>11}{:>10}{:>10}", "config", "PEs", "t_inf µs", "GOPS", "util %");
    for (n, w, h, label) in [(1usize, 1usize, 2usize, "1×1×2×16"),
                             (1, 1, 4, "1×1×4×16"),
                             (2, 1, 4, "2×1×4×16 (paper 1D)"),
                             (2, 2, 4, "2×2×4×16"),
                             (2, 4, 4, "2×4×4×16 (paper full)")] {
        let cfg = ChipConfig { n, w, h, cores_engaged: w, ..ChipConfig::paper() };
        let cm = compile(&model, &cfg, REC_LEN)?;
        let r = sim::run(&cm, &x);
        let rep = report(&r.counters, &cfg, &em, &am);
        // utilization: nnz MACs retired / (PEs × compute cycles)
        let util = 100.0 * r.counters.total_macs() as f64
            / (cfg.engaged_pes() as f64 * rep.cycles as f64);
        println!("{label:<28}{:>6}{:>11.2}{:>10.1}{:>10.1}",
                 cfg.total_pes(), rep.t_active_s * 1e6, rep.gops, util);
    }
    Ok(())
}
