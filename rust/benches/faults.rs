//! Bench: seeded fault-injection campaigns across the integrity stack
//! — the numbers behind DESIGN.md §8's detection contract.
//!
//! Five lanes, every correctness gate always fatal (they are
//! structural properties of the checks, not wall-clock numbers):
//!
//! * **Weight SEU** — `FAULTS_BENCH_SEEDS` campaigns ×
//!   `FAULTS_BENCH_FLIPS` single-bit upsets in the packed weight
//!   arena. Every campaign must be CRC-detected (`integrity::verify`),
//!   scrubbed back from the `i32` mirror, and re-pass the golden
//!   vector. A campaign that flips bits and still verifies clean
//!   counts as an undetected corruption.
//! * **Carry-slab canary** — dense slab corruption injected mid-stream
//!   at canary cadences 1 / 2 / 4, each audited window-by-window
//!   against an unfaulted oracle twin. Cadence 1 is the
//!   zero-undetected-corruption configuration: no corrupted window may
//!   ever be emitted. Larger cadences trade bounded leakage
//!   (≤ cadence−1 windows) for overhead; the lane reports the
//!   empirical detection latency and leak count, and requires
//!   bit-exact re-convergence after every resync.
//! * **Canary overhead** — clean-stream throughput at cadence 0 / 8 /
//!   1 (the price of the contract; ~2× at cadence 1).
//! * **Stuck SPE lane** — a stuck-at accumulator must diverge on the
//!   counted reference path and repair bit-exact once cleared.
//! * **Worker panic** — an injected fleet-shard panic under live
//!   traffic: all diagnoses delivered, exactly one supervised respawn.
//!
//! A transport lane rides along: [`FaultyStream`] perturbation counts
//! must be seed-deterministic (twin campaigns perturb identically).
//!
//! The headline gate, asserted unconditionally and echoed in the JSON:
//! `undetected_corruptions == 0` (weight campaigns that evaded the CRC
//! plus corrupted windows leaked at canary cadence 1).
//!
//! Hermetic: fixture model when `artifacts/weights.bin` is absent
//! (faults and checks are structural — trained weights not required).
//!
//! Run: cargo bench --bench faults
//! Strict: FAULTS_BENCH_STRICT=1 adds the wall-clock overhead gate
//! Env: FAULTS_BENCH_SEEDS (12), FAULTS_BENCH_FLIPS (16)

use std::sync::Arc;
use std::time::{Duration, Instant};

use va_accel::arch::{ChipConfig, KernelTier};
use va_accel::compiler::compile;
use va_accel::coordinator::{wire, Backend, Fleet, FleetConfig, StreamSession};
use va_accel::data::{fixtures, SplitMix64};
use va_accel::reliability::{integrity, FaultKind, FaultPlan, FaultyStream,
                            GoldenVector, PlannedFault};
use va_accel::sim::{self, ScratchArena};
use va_accel::REC_LEN;

const SEED: u64 = 0xFA_0175;
const HOP: usize = 128;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One canary lane: corrupt the slab densely before window `inject`,
/// then audit every emitted window against an unfaulted twin.
struct CanaryLane {
    cadence: u64,
    planted: usize,
    tripped: bool,
    /// Windows from injection to the first trip (0 = caught on the
    /// injection window itself); meaningless unless `tripped`.
    latency: u64,
    /// Divergent windows emitted before the corruption was caught (or
    /// before it naturally shifted out of the carry region).
    leaked: usize,
    /// Divergent windows emitted after the first trip's resync — must
    /// be 0: recovery is a FULL re-prime, bit-exact by construction.
    post_trip_mismatches: usize,
}

fn canary_lane(cm: &Arc<va_accel::compiler::CompiledModel>, cadence: u64,
               stream: &[i8], windows: usize, inject: usize)
               -> anyhow::Result<CanaryLane> {
    let mut sess = StreamSession::new(Arc::clone(cm), HOP)?;
    sess.set_canary(cadence);
    let mut oracle = StreamSession::new(Arc::clone(cm), HOP)?;
    let prime = sess.push_quantized(&stream[..REC_LEN]);
    let oprime = oracle.push_quantized(&stream[..REC_LEN]);
    anyhow::ensure!(prime.len() == 1 && prime[0].logits == oprime[0].logits,
                    "priming pass diverged before any fault");
    let mut lane = CanaryLane { cadence, planted: 0, tripped: false,
                                latency: 0, leaked: 0,
                                post_trip_mismatches: 0 };
    let mut trips_seen = 0u64;
    for w in 1..=windows {
        if w == inject {
            for i in (0..sess.carry_words()).step_by(3) {
                lane.planted += sess.corrupt_carry(i, 0x40_0000) as usize;
            }
        }
        let lo = REC_LEN + (w - 1) * HOP;
        let got = sess.push_quantized(&stream[lo..lo + HOP]);
        let want = oracle.push_quantized(&stream[lo..lo + HOP]);
        anyhow::ensure!(got.len() == 1 && want.len() == 1,
                        "hop-sized push must emit exactly one window");
        let trips = sess.stats().canary_trips;
        if trips > trips_seen && !lane.tripped {
            lane.tripped = true;
            lane.latency = (w - inject) as u64;
        }
        trips_seen = trips;
        if got[0].logits != want[0].logits {
            if lane.tripped {
                lane.post_trip_mismatches += 1;
            } else {
                lane.leaked += 1;
            }
        }
    }
    anyhow::ensure!(lane.planted > 0, "no carry words corrupted");
    anyhow::ensure!(lane.post_trip_mismatches == 0,
                    "cadence {cadence}: {} windows diverged AFTER the \
                     canary resync — recovery must be bit-exact",
                    lane.post_trip_mismatches);
    Ok(lane)
}

fn main() -> anyhow::Result<()> {
    let strict = std::env::var("FAULTS_BENCH_STRICT")
        .is_ok_and(|v| !v.is_empty() && v != "0");
    let campaigns = env_usize("FAULTS_BENCH_SEEDS", 12);
    let flips = env_usize("FAULTS_BENCH_FLIPS", 16);
    let trained = std::path::Path::new(
        &format!("{}/weights.bin", va_accel::ARTIFACT_DIR)).exists();
    let model = fixtures::model_or_artifact();
    let chip = ChipConfig::paper_1d();
    let kernel_tier = KernelTier::current();
    println!("== fault-injection bench: {campaigns} campaigns × {flips} \
              weight flips, kernel tier {kernel_tier} ==\n");

    // ---- integrity check costs on a pristine arena ------------------
    let pristine = compile(&model, &chip, REC_LEN)?;
    let golden = GoldenVector::stamp(&pristine);
    anyhow::ensure!(golden.check(&pristine) &&
                    integrity::verify(&pristine).is_empty(),
                    "pristine arena fails its own integrity checks");
    let reps = 32u32;
    let t = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(integrity::verify(&pristine));
    }
    let verify_us = t.elapsed().as_secs_f64() * 1e6 / reps as f64;
    let t = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(golden.check(&pristine));
    }
    let golden_us = t.elapsed().as_secs_f64() * 1e6 / reps as f64;
    println!("integrity: CRC verify {verify_us:.1}µs/pass, golden vector \
              {golden_us:.1}µs/check");

    // ---- weight-SEU campaigns ---------------------------------------
    let mut injected = 0u64;
    let mut detected_layers = 0u64;
    let mut undetected = 0u64;
    let mut scrub_us_total = 0.0f64;
    for s in 0..campaigns as u64 {
        let mut cm = compile(&model, &chip, REC_LEN)?;
        let plan = FaultPlan::weight_seu(SEED ^ s, &cm, flips, 1);
        let mut flipped = 0u64;
        for f in &plan.faults {
            if let FaultKind::WeightBit { layer, word, bit } = f.kind {
                flipped += cm.layers[layer].packed
                    .flip_word_bit(word, bit) as u64;
            }
        }
        injected += flipped;
        let bad = integrity::verify(&cm);
        if flipped > 0 && bad.is_empty() {
            undetected += 1;
        }
        detected_layers += bad.len() as u64;
        let t = Instant::now();
        let rep = integrity::scrub(&mut cm);
        scrub_us_total += t.elapsed().as_secs_f64() * 1e6;
        anyhow::ensure!(rep.restored,
                        "scrub failed to restore {} corrupted layers",
                        rep.corrupted.len());
        anyhow::ensure!(integrity::verify(&cm).is_empty()
                        && golden.check(&cm),
                        "arena not bit-identical after scrub");
    }
    let scrub_us = scrub_us_total / campaigns as f64;
    println!("weights  : {injected} flips over {campaigns} campaigns, \
              {detected_layers} corrupt layers detected, scrub \
              {scrub_us:.1}µs/pass, undetected campaigns: {undetected}");

    // ---- carry-slab canary lanes ------------------------------------
    let windows = 16usize;
    let inject = 5usize;
    let total = REC_LEN + HOP * windows;
    let mut rng = SplitMix64::new(SEED ^ 0xCA2217);
    let stream: Vec<i8> = (0..total)
        .map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect();
    let cm = Arc::new(compile(&model, &chip, REC_LEN)?);
    let mut lanes = Vec::new();
    for cadence in [1u64, 2, 4] {
        let lane = canary_lane(&cm, cadence, &stream, windows, inject)?;
        println!("canary c{cadence}: planted {}, tripped {}, latency {} \
                  windows, leaked {} windows", lane.planted, lane.tripped,
                 lane.latency, lane.leaked);
        lanes.push(lane);
    }
    // cadence 1 is the zero-undetected-corruption contract
    anyhow::ensure!(lanes[0].tripped && lanes[0].latency == 0,
                    "cadence 1 must catch corruption on the very next \
                     window");
    undetected += lanes[0].leaked as u64;
    // cadence 2: the corrupted columns persist ≥2 hops in the carry
    // region, so the next check must trip; leakage is bounded
    anyhow::ensure!(lanes[1].tripped && lanes[1].leaked <= 1,
                    "cadence 2 must trip within its leak bound (leaked \
                     {})", lanes[1].leaked);
    // cadence 4: corruption may shift out of the carry region before
    // the next check (the documented escape window) — the lane only
    // bounds the leak and requires natural re-convergence, both
    // enforced inside canary_lane / the leak bound here
    anyhow::ensure!(lanes[2].leaked <= 3,
                    "cadence 4 leaked {} windows > bound 3",
                    lanes[2].leaked);

    // ---- canary overhead on a clean stream --------------------------
    let mut wps = Vec::new();
    for cadence in [0u64, 8, 1] {
        let mut sess = StreamSession::new(Arc::clone(&cm), HOP)?;
        sess.set_canary(cadence);
        sess.push_quantized(&stream[..REC_LEN]);
        let t = Instant::now();
        for w in 1..=windows {
            let lo = REC_LEN + (w - 1) * HOP;
            std::hint::black_box(sess.push_quantized(&stream[lo..lo + HOP]));
        }
        wps.push(windows as f64 / t.elapsed().as_secs_f64());
    }
    let (off_wps, c8_wps, c1_wps) = (wps[0], wps[1], wps[2]);
    println!("overhead : {off_wps:.0} w/s canary-off, {c8_wps:.0} w/s \
              cadence 8, {c1_wps:.0} w/s cadence 1 ({:.2}x cost)",
             off_wps / c1_wps);

    // ---- stuck SPE lane ---------------------------------------------
    let x = &stream[..REC_LEN];
    let healthy = sim::run(&cm, x);
    let mut arena = ScratchArena::for_model(&cm);
    anyhow::ensure!(arena.force_stuck_lane(0, 0x000F_FFFF),
                    "SPE lane 0 must exist");
    let stuck = sim::run_counted_scratch(&cm, x, &mut arena);
    let stuck_detected = stuck.logits != healthy.logits;
    arena.clear_stuck_lanes();
    let repaired = sim::run_counted_scratch(&cm, x, &mut arena);
    let stuck_repaired = repaired.logits == healthy.logits;
    println!("spe      : stuck-lane divergence detected {stuck_detected}, \
              repair bit-exact {stuck_repaired}");
    anyhow::ensure!(stuck_detected && stuck_repaired,
                    "stuck-lane detect/repair contract violated");

    // ---- wire perturbation determinism ------------------------------
    let wire_frames = 256u64;
    let run_wire = || -> anyhow::Result<(u64, u64, u64)> {
        let mut fs = FaultyStream::new(Vec::new(), SEED ^ 0x3127E, 0.25);
        for _ in 0..wire_frames {
            if wire::write_frame(&mut fs, &wire::Frame::Goodbye).is_err() {
                break; // injected truncation poisons the pipe
            }
        }
        Ok((fs.dropped, fs.duplicated, fs.truncated))
    };
    let (dropped, duplicated, truncated) = run_wire()?;
    anyhow::ensure!((dropped, duplicated, truncated) == run_wire()?,
                    "wire fault campaign is not seed-deterministic");
    anyhow::ensure!(dropped + duplicated + truncated > 0,
                    "rate 0.25 perturbed nothing over {wire_frames} frames");
    println!("wire     : {dropped} dropped, {duplicated} duplicated, \
              {truncated} truncated (seed-deterministic)");

    // ---- supervised worker panic under live fleet traffic -----------
    let jobs = 32usize;
    let mut fcfg = FleetConfig::new(1);
    fcfg.batcher.max_batch = 1;
    fcfg.batcher.max_age = Duration::ZERO;
    fcfg.vote_group = 1;
    fcfg.fault_plan = FaultPlan {
        seed: SEED,
        faults: vec![PlannedFault {
            at_window: 0,
            kind: FaultKind::WorkerPanic { shard: 0, after: 5 },
        }],
    };
    let t = Instant::now();
    let fleet = Fleet::spawn(fcfg, {
        let model = model.clone();
        let chip = chip.clone();
        move |_| Ok(Backend::chipsim(compile(&model, &chip, REC_LEN)?))
    })?;
    let h = fleet.handle();
    let mut rng = SplitMix64::new(SEED ^ 0xF1EE7);
    for _ in 0..jobs {
        let rec: Vec<i8> = (0..REC_LEN)
            .map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect();
        h.submit(rec)?;
    }
    h.flush()?;
    for got in 0..jobs {
        anyhow::ensure!(fleet.recv().is_some(),
                        "fleet died after {got}/{jobs} diagnoses");
    }
    let frep = fleet.shutdown();
    let fleet_ms = t.elapsed().as_secs_f64() * 1e3;
    println!("fleet    : panic injected after 5 jobs — {jobs}/{jobs} \
              delivered, {} respawn(s), {fleet_ms:.0}ms", frep.respawns);
    anyhow::ensure!(frep.respawns == 1,
                    "expected exactly 1 supervised respawn, saw {}",
                    frep.respawns);

    // ---- the headline gate ------------------------------------------
    anyhow::ensure!(undetected == 0,
                    "undetected_corruptions: {undetected} — the scrub + \
                     cadence-1 canary contract is broken");
    println!("\nPASS: undetected_corruptions: 0 across {campaigns} weight \
              campaigns and the cadence-1 canary lane");

    let lane_rows: Vec<String> = lanes.iter().map(|l| format!(
        "    {{\"cadence\": {}, \"planted\": {}, \"tripped\": {}, \
         \"trip_latency_windows\": {}, \"leaked_windows\": {}, \
         \"post_trip_mismatches\": {}}}",
        l.cadence, l.planted, l.tripped, l.latency, l.leaked,
        l.post_trip_mismatches)).collect();
    let json = format!(
        "{{\n  \"bench\": \"faults\",\n  \"seed\": {SEED},\n  \
         \"trained_weights\": {trained},\n  \
         \"campaigns\": {campaigns},\n  \
         \"flips_per_campaign\": {flips},\n  \
         \"injected_flips\": {injected},\n  \
         \"detected_layers\": {detected_layers},\n  \
         \"undetected_corruptions\": {undetected},\n  \
         \"verify_us\": {verify_us:.1},\n  \
         \"scrub_us\": {scrub_us:.1},\n  \
         \"golden_check_us\": {golden_us:.1},\n  \
         \"canary\": [\n{}\n  ],\n  \
         \"canary_off_wps\": {off_wps:.0},\n  \
         \"canary_c8_wps\": {c8_wps:.0},\n  \
         \"canary_c1_wps\": {c1_wps:.0},\n  \
         \"stuck_lane_detected\": {stuck_detected},\n  \
         \"stuck_lane_repaired\": {stuck_repaired},\n  \
         \"wire_dropped\": {dropped},\n  \
         \"wire_duplicated\": {duplicated},\n  \
         \"wire_truncated\": {truncated},\n  \
         \"fleet_jobs\": {jobs},\n  \
         \"fleet_respawns\": {},\n  \
         \"fleet_elapsed_ms\": {fleet_ms:.0},\n  \
         \"kernel_tier\": \"{kernel_tier}\"\n}}\n",
        lane_rows.join(",\n"), frep.respawns);
    std::fs::write("BENCH_faults.json", &json)?;
    println!("wrote BENCH_faults.json");

    // wall-clock gate: cadence 1 buys its guarantee at a bounded price
    let overhead = off_wps / c1_wps;
    if overhead <= 4.0 {
        println!("PASS: cadence-1 canary costs {overhead:.2}x (≤4x bound)");
    } else if strict {
        anyhow::bail!("cadence-1 canary costs {overhead:.2}x > 4x — \
                       machine loaded? re-run, or drop \
                       FAULTS_BENCH_STRICT to make this advisory");
    } else {
        println!("WARN: cadence-1 canary costs {overhead:.2}x > 4x — set \
                  FAULTS_BENCH_STRICT=1 to make this fatal");
    }
    Ok(())
}
