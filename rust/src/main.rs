//! `vaccel` — CLI for the VA-detection accelerator stack.
//!
//! Subcommands (hand-rolled arg parsing; the offline build environment
//! has no clap — see Cargo.toml):
//!
//! ```text
//! vaccel detect   [--backend pjrt|golden|chipsim] [--n N] [--seed S]
//! vaccel simulate [--dense] [--full-array]
//! vaccel report                      # Table-1 operating point
//! vaccel eval     [--backend ...]    # accuracy on artifacts/eval.bin
//! vaccel baselines                   # the four Table-1 comparators
//! vaccel serve    [--episodes N]     # threaded streaming demo
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use va_accel::arch::ChipConfig;
use va_accel::baselines::all_baselines;
use va_accel::compiler::compile;
use va_accel::coordinator::{Backend, Pipeline, Service};
use va_accel::data::{load_eval, Dataset, Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::runtime::Executor;
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
        }
        i += 1;
    }
    flags
}

fn load_model() -> Result<QuantModel> {
    QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin"))
}

fn make_backend(kind: &str) -> Result<Backend> {
    Ok(match kind {
        "pjrt" => Backend::Pjrt(Executor::open(ARTIFACT_DIR)?),
        "golden" => Backend::Golden(load_model()?),
        "chipsim" => {
            let m = load_model()?;
            Backend::ChipSim(Box::new(compile(&m, &ChipConfig::paper_1d(), REC_LEN)?))
        }
        k => bail!("unknown backend '{k}' (pjrt|golden|chipsim)"),
    })
}

fn cmd_detect(flags: &HashMap<String, String>) -> Result<()> {
    let backend = make_backend(flags.get("backend").map(String::as_str).unwrap_or("golden"))?;
    let n: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let mut gen = Generator::new(seed);
    println!("backend: {}", backend.name());
    for i in 0..n {
        let class = RhythmClass::ALL[i % 4];
        let rec = gen.recording(class);
        let det = backend.infer(&[rec.quantized()])?[0];
        println!("rec {i:>3}  truth {:>3}  logits [{:>6}, {:>6}]  -> {}",
                 class.name(), det.logits[0], det.logits[1],
                 if det.is_va { "VA  !" } else { "non-VA" });
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let model = load_model()?;
    let mut cfg = if flags.contains_key("full-array") {
        ChipConfig::paper()
    } else {
        ChipConfig::paper_1d()
    };
    if flags.contains_key("dense") {
        cfg.zero_skip = false;
    }
    let cm = compile(&model, &cfg, REC_LEN)?;
    let mut gen = Generator::new(2);
    let rec = gen.recording(RhythmClass::Vt);
    let r = sim::run(&cm, &rec.quantized());
    println!("{}", sim::render_trace(&r.counters, cfg.freq_hz));
    println!("prediction: {} (logits {:?})",
             if r.predicted == 1 { "VA" } else { "non-VA" }, r.logits);
    println!();
    println!("{}", report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40()));
    Ok(())
}

fn cmd_report() -> Result<()> {
    let model = load_model()?;
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let stats = model.stats(REC_LEN);
    println!("model: {} params, {:.1}% sparse, {:.2} MMACs dense/inference",
             stats.params, stats.sparsity * 100.0,
             stats.macs_dense as f64 / 1e6);
    println!("compressed weights: {} KiB (of {} KiB buffer)\n",
             cm.compressed_bytes() / 1024, cfg.weight_buf_bytes / 1024);
    println!("{}", cm.balance);
    println!();
    let mut gen = Generator::new(3);
    let rec = gen.recording(RhythmClass::Vf);
    let r = sim::run(&cm, &rec.quantized());
    println!("{}", report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40()));
    Ok(())
}

fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let backend = make_backend(flags.get("backend").map(String::as_str).unwrap_or("golden"))?;
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))
        .context("eval corpus (run `make artifacts`)")?;
    let truth = ds.va_labels();
    let (rec, ep) = Pipeline::evaluate(&backend, &ds.x, &truth, VOTE_GROUP)?;
    println!("backend: {}  corpus: {} recordings", backend.name(), ds.len());
    println!("per-recording: {rec}");
    println!("diagnostic   : {ep}");
    println!("paper        : acc 0.9235 / diag 0.9995 prec 0.9988 rec 0.9984");
    Ok(())
}

fn cmd_baselines() -> Result<()> {
    let tr = Dataset::synthesize(100, 96, 0.6);
    let te = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))
        .unwrap_or_else(|_| Dataset::synthesize(101, 64, 0.6));
    println!("training 4 baselines on {} recordings...", tr.len());
    for mut b in all_baselines() {
        b.fit(&tr.x, &tr.va_labels());
        let mut conf = va_accel::metrics::Confusion::new();
        for (x, t) in te.x.iter().zip(te.va_labels()) {
            conf.push(b.predict(x), t);
        }
        let row = b.published();
        println!("{:<10} acc {:.4}  ops/inf {:>8}  (published: {} {}nm {}µW)",
                 b.name(), conf.accuracy(), b.ops_per_inference(),
                 row.label, row.tech_nm, row.power_uw);
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let backend = make_backend(flags.get("backend").map(String::as_str).unwrap_or("golden"))?;
    let episodes: usize = flags.get("episodes").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let pipeline = Pipeline::paper(backend);
    let svc = Service::spawn(pipeline);
    let h = svc.handle();
    let mut gen = Generator::new(7);
    let plan = [RhythmClass::Nsr, RhythmClass::Vt, RhythmClass::Svt, RhythmClass::Vf];
    for e in 0..episodes {
        let class = plan[e % plan.len()];
        let (samples, _) = gen.stream(&[(class, VOTE_GROUP)]);
        h.submit_samples(samples)?;
        h.flush()?;
        let d = svc.recv().context("service died")?;
        println!("episode {e}: truth {:<3} -> {}  (votes {:?})",
                 class.name(),
                 if d.episode.is_va { "VA  ! defibrillate" } else { "non-VA" },
                 d.episode.votes);
    }
    let p = svc.shutdown();
    println!("\n{} recordings, {} episodes, latency: {}",
             p.stats.recordings, p.stats.episodes,
             p.latency.clone().summary());
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "detect" => cmd_detect(&flags),
        "simulate" => cmd_simulate(&flags),
        "report" => cmd_report(),
        "eval" => cmd_eval(&flags),
        "baselines" => cmd_baselines(),
        "serve" => cmd_serve(&flags),
        _ => {
            println!("vaccel — mixed-bit-width sparse CNN accelerator stack");
            println!("usage: vaccel <detect|simulate|report|eval|baselines|serve> [--flags]");
            println!("  detect    classify synthetic recordings (--backend pjrt|golden|chipsim)");
            println!("  simulate  cycle-accurate chip simulation (--dense, --full-array)");
            println!("  report    chip operating point + workload balance");
            println!("  eval      accuracy on the build-time eval corpus (--backend ...)");
            println!("  baselines train + score the four Table-1 baseline algorithms");
            println!("  serve     threaded streaming ICD demo (--episodes N)");
            Ok(())
        }
    }
}
