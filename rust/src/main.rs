//! `vaccel` — CLI for the VA-detection accelerator stack.
//!
//! Subcommands (hand-rolled arg parsing; the offline build environment
//! has no clap — see Cargo.toml):
//!
//! ```text
//! vaccel detect   [--backend pjrt|golden|chipsim] [--n N] [--seed S]
//! vaccel simulate [--dense] [--full-array]
//! vaccel report                      # Table-1 operating point
//! vaccel eval     [--backend ...]    # accuracy on artifacts/eval.bin
//! vaccel baselines                   # the four Table-1 comparators
//! vaccel serve    [--episodes N]     # threaded streaming demo
//! vaccel serve    --listen ADDR [--hop H] [--token T] [--interval-ms MS] [--duration-s S]
//! vaccel serve    --loadgen M [--windows K] [--hop H] [--scenario F] [--seed S]  # loopback wire-path bench
//! vaccel stream   [--hop H] [--n N] [--seed S] [--audit] [--recalibrate]  # incremental delta-reuse streaming
//! vaccel fleet    [--shards N] [--n N] [--backend ...] [--watch] [--interval-ms MS]
//! vaccel scenarios [--hop H] [--seed S] [--recalibrate]  # adversarial scenario suite
//! vaccel faults   [--smoke] [--seed S]  # fault-injection self-test (SEU, canary, stuck lanes, panics)
//! ```
//!
//! `scenarios` runs the adversarial stress suite (`data::scenarios`):
//! every perturbation family through the full streaming path, each
//! window audited bit-exact against the offline fast path, with
//! sensitivity/specificity per scenario; `--recalibrate` (here and on
//! `stream`) arms the online threshold-recalibration loop
//! (`coordinator::Recalibrator` — moves only the decision threshold,
//! never the logits).
//!
//! `serve --listen` starts the TCP front end (`coordinator::NetServer`):
//! length-prefixed binary frames, one `StreamSession` per connected
//! device, BUSY backpressure, push-model DIAGNOSIS/STATS.
//! `serve --loadgen M` spawns the same server on a loopback port and
//! drives M concurrent device connections through the full wire path,
//! verifying every diagnosis against the offline oracle.
//!
//! Backends: `golden` (integer model), `chipsim` (simulator fast
//! path, one chip per shard), `chipsim-par` (big-chip batch-parallel
//! simulator — throughput over latency), `pjrt` (AOT artifacts).
//!
//! When `artifacts/weights.bin` is absent (no `make artifacts`), the
//! hermetic fixture model (`data::fixtures`) stands in so every
//! subcommand runs out of the box; accuracy numbers are then
//! meaningless (random weights) but timing/power/serving behavior is
//! representative.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use va_accel::arch::ChipConfig;
use va_accel::baselines::all_baselines;
use va_accel::compiler::compile;
use va_accel::coordinator::{loadgen, loadgen_scenario, run_scenario, Backend,
                            Fleet, FleetConfig, NetServer, Pipeline,
                            RecalConfig, ServeConfig, Service, StreamSession};
use va_accel::data::{fixtures, load_eval, Dataset, Generator, RhythmClass,
                     Scenario};
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::runtime::Executor;
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN, VOTE_GROUP};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
        }
        i += 1;
    }
    flags
}

fn load_model() -> Result<QuantModel> {
    let path = format!("{ARTIFACT_DIR}/weights.bin");
    if !std::path::Path::new(&path).exists() {
        // absence is expected on a fresh checkout; any OTHER load error
        // (truncation, bad magic) must surface, not be masked by the
        // fixture fallback
        eprintln!("note: {path} not found — using the hermetic fixture \
                   model (random weights; run `make artifacts` for the \
                   trained network)");
        return Ok(fixtures::default_model());
    }
    QuantModel::load(&path)
}

fn load_eval_or_synthetic() -> Result<Dataset> {
    let path = format!("{ARTIFACT_DIR}/eval.bin");
    if !std::path::Path::new(&path).exists() {
        eprintln!("note: {path} not found — using a synthetic eval corpus");
        return Ok(fixtures::default_eval(64));
    }
    load_eval(&path)
}

fn make_backend(kind: &str) -> Result<Backend> {
    Ok(match kind {
        // pjrt/golden attach the compiled model's static cost so every
        // backend reports the same chip counters on the serving path
        "pjrt" => {
            let backend = Backend::pjrt(Executor::open(ARTIFACT_DIR)?);
            // only stamp counters derived from the SAME network the AOT
            // artifact executes: without the trained weights.bin the
            // fixture fallback would describe a different model, so
            // pjrt then runs without counters rather than lying
            let wpath = format!("{ARTIFACT_DIR}/weights.bin");
            if std::path::Path::new(&wpath).exists() {
                let m = QuantModel::load(&wpath)?;
                let cm = compile(&m, &ChipConfig::paper_1d(), REC_LEN)?;
                backend.with_static_cost(cm.static_cost)
            } else {
                eprintln!("note: {wpath} not found — pjrt backend will \
                           report no chip counters");
                backend
            }
        }
        "golden" => {
            let m = load_model()?;
            let cm = compile(&m, &ChipConfig::paper_1d(), REC_LEN)?;
            Backend::golden(m).with_static_cost(cm.static_cost)
        }
        "chipsim" => {
            let m = load_model()?;
            Backend::chipsim(compile(&m, &ChipConfig::paper_1d(), REC_LEN)?)
        }
        // the "big chip": batches fan out across rayon workers —
        // throughput over latency (best as a single shard that owns
        // the whole machine)
        "chipsim-par" | "chipsim_parallel" => {
            let m = load_model()?;
            Backend::chipsim_parallel(
                compile(&m, &ChipConfig::paper_1d(), REC_LEN)?)
        }
        k => bail!("unknown backend '{k}' (pjrt|golden|chipsim|chipsim-par)"),
    })
}

fn cmd_detect(flags: &HashMap<String, String>) -> Result<()> {
    let backend = make_backend(flags.get("backend").map(String::as_str).unwrap_or("golden"))?;
    let n: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let mut gen = Generator::new(seed);
    println!("backend: {}", backend.name());
    for i in 0..n {
        let class = RhythmClass::ALL[i % 4];
        let rec = gen.recording(class);
        let det = backend.infer(&[rec.quantized()])?[0];
        println!("rec {i:>3}  truth {:>3}  logits [{:>6}, {:>6}]  -> {}",
                 class.name(), det.logits[0], det.logits[1],
                 if det.is_va { "VA  !" } else { "non-VA" });
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let model = load_model()?;
    let mut cfg = if flags.contains_key("full-array") {
        ChipConfig::paper()
    } else {
        ChipConfig::paper_1d()
    };
    if flags.contains_key("dense") {
        cfg.zero_skip = false;
    }
    let cm = compile(&model, &cfg, REC_LEN)?;
    let mut gen = Generator::new(2);
    let rec = gen.recording(RhythmClass::Vt);
    let r = sim::run(&cm, &rec.quantized());
    println!("{}", sim::render_trace(&r.counters, cfg.freq_hz));
    println!("prediction: {} (logits {:?})",
             if r.predicted == 1 { "VA" } else { "non-VA" }, r.logits);
    println!();
    println!("{}", report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40()));
    Ok(())
}

fn cmd_report() -> Result<()> {
    let model = load_model()?;
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&model, &cfg, REC_LEN)?;
    let stats = model.stats(REC_LEN);
    println!("model: {} params, {:.1}% sparse, {:.2} MMACs dense/inference",
             stats.params, stats.sparsity * 100.0,
             stats.macs_dense as f64 / 1e6);
    println!("compressed weights: {} KiB (of {} KiB buffer); \
              packed host arena: {} KiB physical\n",
             cm.compressed_bytes() / 1024, cfg.weight_buf_bytes / 1024,
             cm.weight_arena_bytes() / 1024);
    println!("{}", cm.balance);
    println!();
    let mut gen = Generator::new(3);
    let rec = gen.recording(RhythmClass::Vf);
    let r = sim::run(&cm, &rec.quantized());
    println!("{}", report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40()));
    Ok(())
}

fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let backend = make_backend(flags.get("backend").map(String::as_str).unwrap_or("golden"))?;
    let ds = load_eval_or_synthetic()?;
    let truth = ds.va_labels();
    let (rec, ep) = Pipeline::evaluate(&backend, &ds.x, &truth, VOTE_GROUP)?;
    println!("backend: {}  corpus: {} recordings", backend.name(), ds.len());
    println!("per-recording: {rec}");
    println!("diagnostic   : {ep}");
    println!("paper        : acc 0.9235 / diag 0.9995 prec 0.9988 rec 0.9984");
    Ok(())
}

fn cmd_baselines() -> Result<()> {
    let tr = Dataset::synthesize(100, 96, 0.6);
    let te = load_eval(format!("{ARTIFACT_DIR}/eval.bin"))
        .unwrap_or_else(|_| Dataset::synthesize(101, 64, 0.6));
    println!("training 4 baselines on {} recordings...", tr.len());
    for mut b in all_baselines() {
        b.fit(&tr.x, &tr.va_labels());
        let mut conf = va_accel::metrics::Confusion::new();
        for (x, t) in te.x.iter().zip(te.va_labels()) {
            conf.push(b.predict(x), t);
        }
        let row = b.published();
        println!("{:<10} acc {:.4}  ops/inf {:>8}  (published: {} {}nm {}µW)",
                 b.name(), conf.accuracy(), b.ops_per_inference(),
                 row.label, row.tech_nm, row.power_uw);
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("listen") || flags.contains_key("loadgen") {
        return cmd_serve_net(flags);
    }
    let backend = make_backend(flags.get("backend").map(String::as_str).unwrap_or("golden"))?;
    let episodes: usize = flags.get("episodes").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let pipeline = Pipeline::paper(backend);
    let svc = Service::spawn(pipeline);
    let h = svc.handle();
    let mut gen = Generator::new(7);
    let plan = [RhythmClass::Nsr, RhythmClass::Vt, RhythmClass::Svt, RhythmClass::Vf];
    for e in 0..episodes {
        let class = plan[e % plan.len()];
        let (samples, _) = gen.stream(&[(class, VOTE_GROUP)]);
        h.submit_samples(samples)?;
        h.flush()?;
        let d = svc.recv().context("service died")?;
        println!("episode {e}: truth {:<3} -> {}  (votes {:?})",
                 class.name(),
                 if d.episode.is_va { "VA  ! defibrillate" } else { "non-VA" },
                 d.episode.votes);
    }
    let p = svc.shutdown();
    println!("\n{} recordings, {} episodes, latency: {}",
             p.stats.recordings, p.stats.episodes,
             p.latency.clone().summary());
    Ok(())
}

/// The TCP serving front end: `--listen ADDR` runs it against the
/// world; `--loadgen M` runs it on a loopback port and drives M
/// concurrent device connections through the full wire path, checking
/// every streamed diagnosis against the offline `StreamSession`
/// oracle (the CI smoke path).
fn cmd_serve_net(flags: &HashMap<String, String>) -> Result<()> {
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let hop: usize = flags.get("hop").map(|s| s.parse()).transpose()?.unwrap_or(128);
    let token = flags.get("token").cloned().unwrap_or_else(|| "vaccel".into());
    let interval_ms: u64 = flags.get("interval-ms").map(|s| s.parse()).transpose()?.unwrap_or(200);
    let model = load_model()?;
    let cm = Arc::new(compile(&model, &ChipConfig::paper_1d(), REC_LEN)?);
    let mut cfg = ServeConfig::loopback(&token, hop);
    cfg.stats_interval = Duration::from_millis(interval_ms.max(1));

    if let Some(m) = flags.get("loadgen") {
        let conns: usize = m.parse().context("--loadgen wants a connection count")?;
        let windows: usize = flags.get("windows").map(|s| s.parse()).transpose()?.unwrap_or(4);
        let family = flags.get("scenario").map(|name| {
            va_accel::data::scenarios::Family::from_name(name)
                .with_context(|| format!(
                    "unknown scenario family {name:?}; one of: {}",
                    va_accel::data::scenarios::Family::ALL.iter()
                        .map(|f| f.name()).collect::<Vec<_>>().join("|")))
        }).transpose()?;
        let srv = NetServer::spawn(cfg, Arc::clone(&cm))?;
        let addr = srv.local_addr();
        println!("serve: loopback on {addr}, hop {hop}, \
                  {conns} device connections × {windows} windows{}",
                 family.map(|f| format!(", scenario {}", f.name()))
                     .unwrap_or_default());
        let rep = match family {
            Some(f) => {
                let seed: u64 = flags.get("seed").map(|s| s.parse())
                    .transpose()?.unwrap_or(0x5CE0);
                loadgen_scenario(addr, &token, Arc::clone(&cm),
                                 conns, windows, f, seed)?
            }
            None => loadgen(addr, &token, Arc::clone(&cm), conns, windows)?,
        };
        let stats = srv.shutdown();
        println!("loadgen: {} conns ({} connect failures), {} windows, \
                  {} samples streamed in {:.2}s ({:.0} samples/s)",
                 rep.conns, rep.connect_failures, rep.total_windows,
                 rep.total_samples, rep.elapsed_s, rep.samples_per_s);
        println!("latency: p50 {:.0}µs  p99 {:.0}µs  mean {:.0}µs",
                 rep.p50_us, rep.p99_us, rep.mean_us);
        println!("server: peak sessions {}, busy frames {}, evicted {}, \
                  protocol errors {}",
                 stats.peak_sessions, stats.busy_frames, stats.evicted_slow,
                 stats.protocol_errors);
        anyhow::ensure!(rep.connect_failures == 0,
                        "{} device connections failed", rep.connect_failures);
        let want = (conns * windows) as u64;
        anyhow::ensure!(rep.total_windows == want,
                        "delivered {}/{want} windows", rep.total_windows);
        anyhow::ensure!(rep.mismatches == 0,
                        "{} streamed diagnoses diverged from the offline \
                         oracle", rep.mismatches);
        println!("bit-exact: every streamed diagnosis matches the offline \
                  StreamSession oracle");
        return Ok(());
    }

    cfg.addr = flags.get("listen").unwrap().clone();
    let duration_s: u64 = flags.get("duration-s").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let srv = NetServer::spawn(cfg, cm)?;
    println!("serve: listening on {} (hop {hop}, stats every {interval_ms}ms\
              {})", srv.local_addr(),
             if duration_s > 0 { format!(", draining after {duration_s}s") }
             else { String::new() });
    let t0 = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(interval_ms.max(1)));
        let s = srv.stats();
        println!("sessions {:>4} (peak {:>4})  windows {:>8}  samples {:>10}  \
                  busy {:>5}  evicted {:>4}  rejected {:>4}",
                 s.sessions, s.peak_sessions, s.windows, s.samples,
                 s.busy_frames, s.evicted_slow,
                 s.rejected_capacity + s.rejected_rate + s.rejected_auth);
        if duration_s > 0 && t0.elapsed() >= Duration::from_secs(duration_s) {
            break;
        }
    }
    let s = srv.shutdown();
    println!("drained: {} connections served, {} windows diagnosed",
             s.accepted, s.windows);
    Ok(())
}

fn cmd_stream(flags: &HashMap<String, String>) -> Result<()> {
    let hop: usize = flags.get("hop").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let episodes: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(11);
    let audit = flags.contains_key("audit");
    let recalibrate = flags.contains_key("recalibrate");
    let model = load_model()?;
    let cm = std::sync::Arc::new(compile(&model, &ChipConfig::paper_1d(), REC_LEN)?);
    let mut sess = if recalibrate {
        StreamSession::with_recalibration(std::sync::Arc::clone(&cm), hop,
                                          RecalConfig::default())?
    } else {
        StreamSession::new(std::sync::Arc::clone(&cm), hop)?
    };
    println!("stream: hop {hop} samples ({} windows/recording), \
              incremental delta reuse, kernel tier {}{}",
             REC_LEN / hop.max(1), va_accel::arch::KernelTier::current(),
             if recalibrate { ", online recalibration armed" } else { "" });

    let mut gen = Generator::new(seed);
    let plan = [RhythmClass::Nsr, RhythmClass::Vt, RhythmClass::Svt,
                RhythmClass::Vf];
    for e in 0..episodes {
        let class = plan[e % plan.len()];
        let (samples, _) = gen.stream(&[(class, 1)]);
        let dets = sess.push(&samples);
        let va = dets.iter().filter(|d| d.is_va).count();
        println!("episode {e}: truth {:<3}  {} windows, {} flagged VA",
                 class.name(), dets.len(), va);
    }
    let st = sess.stats();
    let total = st.carried_cols + st.recomputed_cols;
    println!("\n{} windows: {} columns carried, {} recomputed ({:.1}% reused)",
             st.windows, st.carried_cols, st.recomputed_cols,
             100.0 * st.carried_cols as f64 / total.max(1) as f64);
    if let Some(rs) = sess.recal_stats() {
        println!("recalibration: threshold {:.1} (shift estimate {:.1}), \
                  {} of {} windows decided with compensation",
                 rs.threshold, rs.estimate, rs.compensated_windows,
                 rs.windows);
    }

    if audit {
        // bit-exactness audit: regenerate the SAME quantized stream
        // (identical seed + front-end chain), replay it through a
        // fresh delta-reuse session AND the per-window fast path, and
        // compare every window
        let mut quantizer = StreamSession::new(std::sync::Arc::clone(&cm), hop)?;
        let mut audit_sess = StreamSession::new(std::sync::Arc::clone(&cm), hop)?;
        let mut ref_arena = va_accel::sim::ScratchArena::for_model(&cm);
        let mut gen = Generator::new(seed);
        let mut qstream: Vec<i8> = Vec::new();
        for e in 0..episodes {
            let class = plan[e % plan.len()];
            let (samples, _) = gen.stream(&[(class, 1)]);
            qstream.extend(quantizer.quantize(&samples));
        }
        let dets = audit_sess.push_quantized(&qstream);
        let mut mismatches = 0usize;
        for (i, d) in dets.iter().enumerate() {
            let w = &qstream[i * hop..i * hop + REC_LEN];
            let full = va_accel::sim::run_scratch(&cm, w, &mut ref_arena);
            if d.logits.as_slice() != full.logits.as_slice() {
                mismatches += 1;
            }
        }
        if mismatches > 0 {
            bail!("audit FAILED: {mismatches}/{} windows diverged from \
                   full recompute", dets.len());
        }
        println!("audit: {} windows bit-exact vs full recompute", dets.len());
    }
    Ok(())
}

/// Adversarial scenario suite: every perturbation family through the
/// full streaming path, each emitted window audited bit-exact against
/// the offline per-window fast path (fatal on mismatch), scored
/// against per-segment ground truth. `--recalibrate` replays each
/// scenario with the online threshold-recalibration loop armed and
/// reports both scores side by side.
fn cmd_scenarios(flags: &HashMap<String, String>) -> Result<()> {
    let hop: usize = flags.get("hop").map(|s| s.parse()).transpose()?.unwrap_or(128);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0x5CE9);
    let recalibrate = flags.contains_key("recalibrate");
    let model = load_model()?;
    let cm = std::sync::Arc::new(compile(&model, &ChipConfig::paper_1d(), REC_LEN)?);
    let suite = Scenario::standard_suite(seed);
    println!("scenarios: {} families, hop {hop}, seed {seed:#x}{}",
             suite.len(), if recalibrate
             { ", online recalibration replay armed" } else { "" });
    println!("{:<22} {:>7} {:>6} {:>6} {:>6} {:>6} {:>7}{}",
             "scenario", "windows", "eval", "sens", "spec", "acc", "agree",
             if recalibrate { "   rsens   rspec" } else { "" });
    let mut audited = 0usize;
    for sc in &suite {
        let cfg = if recalibrate { Some(RecalConfig::default()) } else { None };
        let out = run_scenario(&cm, sc, hop, cfg)?;
        audited += out.audited;
        let agree = out.clean_agreement
            .map(|a| format!("{a:>7.3}"))
            .unwrap_or_else(|| format!("{:>7}", "-"));
        let rcols = match &out.recal {
            Some(rc) => format!("  {:>6.3}  {:>6.3}",
                                rc.recall(), rc.specificity()),
            None => String::new(),
        };
        println!("{:<22} {:>7} {:>6} {:>6.3} {:>6.3} {:>6.3} {agree}{rcols}",
                 out.name, out.windows, out.evaluated, out.fixed.recall(),
                 out.fixed.specificity(), out.fixed.accuracy());
    }
    println!("\nbit-exact: {audited} streamed windows matched the offline \
              fast path under every scenario");
    if !std::path::Path::new(&format!("{ARTIFACT_DIR}/weights.bin")).exists() {
        println!("(fixture weights — scores are structural, not clinical; \
                  run `make artifacts` for the trained network)");
    }
    Ok(())
}

fn cmd_fleet(flags: &HashMap<String, String>) -> Result<()> {
    let kind = flags.get("backend").map(String::as_str).unwrap_or("chipsim");
    let shards: usize = flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let episodes: usize = flags.get("n").map(|s| s.parse()).transpose()?.unwrap_or(40);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let watch = flags.contains_key("watch");
    let interval_ms: u64 = flags.get("interval-ms").map(|s| s.parse()).transpose()?.unwrap_or(200);
    println!("fleet: {} shards, backend {kind}, {} episodes of {} recordings, \
              kernel tier {}",
             shards, episodes, VOTE_GROUP,
             va_accel::arch::KernelTier::current());
    // every shard gets its OWN backend (own compiled model + engine);
    // report-only: nobody drains the diagnosis stream here. Stealing is
    // off because episodes are pinned: a vote group split across two
    // shards' voters would be clinically meaningless.
    let mut cfg = FleetConfig::report_only(shards);
    cfg.steal = false;
    let fleet = {
        let kind = kind.to_string();
        Fleet::spawn(cfg, move |_| make_backend(&kind))?
    };
    let h = fleet.handle();
    // one "patient episode" = VOTE_GROUP consecutive recordings of one
    // rhythm class, pinned to one shard so its voter sees the whole group
    let mut gen = Generator::new(seed);
    for e in 0..episodes {
        let class = RhythmClass::ALL[e % RhythmClass::ALL.len()];
        let shard = e % shards;
        for _ in 0..VOTE_GROUP {
            let rec = gen.recording(class);
            h.submit_to_labeled(shard, rec.quantized(), class.is_va())?;
        }
    }
    h.flush()?;
    if watch {
        // live telemetry while the queues drain — push-model: the
        // fleet publishes snapshots on its own cadence
        // (--interval-ms) instead of this loop hammering the stats
        // mutex in a hot poll
        let rx = h.subscribe_stats(
            std::time::Duration::from_millis(interval_ms.max(1)));
        for stats in rx {
            println!("{stats}");
            if stats.queued() == 0 {
                break;
            }
        }
    }
    let report = fleet.shutdown();
    println!("{report}");
    Ok(())
}

/// Fault-injection self-test: every fault class through its detection
/// and recovery path, enforcing the hard gate — zero undetected
/// corruptions with scrub + canary armed. `--smoke` trims the
/// campaign for CI; `--seed S` re-seeds the whole sweep.
fn cmd_faults(flags: &HashMap<String, String>) -> Result<()> {
    use std::sync::Arc;
    use std::time::Duration;
    use va_accel::data::SplitMix64;
    use va_accel::reliability::{integrity, FaultKind, FaultPlan,
                                GoldenVector, PlannedFault};
    use va_accel::sim::ScratchArena;

    let smoke = flags.contains_key("smoke");
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?
        .unwrap_or(0xFA0175);
    let seeds: u64 = if smoke { 2 } else { 8 };
    let flips: usize = if smoke { 4 } else { 16 };
    let model = load_model()?;
    let chip = ChipConfig::paper_1d();
    println!("faults: seed {seed:#x}, {seeds} campaign seeds × {flips} \
              weight flips{}", if smoke { " (smoke)" } else { "" });

    // golden self-test on the pristine arena
    let pristine = compile(&model, &chip, REC_LEN)?;
    let golden = GoldenVector::stamp(&pristine);
    anyhow::ensure!(golden.check(&pristine),
                    "golden self-test failed on a pristine arena");
    anyhow::ensure!(integrity::verify(&pristine).is_empty(),
                    "pristine arena fails its own CRCs");
    println!("golden : pristine arena passes CRC + golden vector");

    // weight-SEU campaign: every flip CRC-detected, scrubbed back,
    // golden-verified — the undetected count is the hard gate
    let mut injected = 0u64;
    let mut detected_layers = 0u64;
    let mut undetected = 0u64;
    for s in 0..seeds {
        let mut cm = compile(&model, &chip, REC_LEN)?;
        let plan = FaultPlan::weight_seu(seed ^ s, &cm, flips, 1);
        let mut flipped = 0u64;
        for f in &plan.faults {
            if let FaultKind::WeightBit { layer, word, bit } = f.kind {
                if cm.layers[layer].packed.flip_word_bit(word, bit) {
                    flipped += 1;
                }
            }
        }
        injected += flipped;
        let bad = integrity::verify(&cm);
        if flipped > 0 && bad.is_empty() {
            undetected += 1;
        }
        detected_layers += bad.len() as u64;
        let rep = integrity::scrub(&mut cm);
        anyhow::ensure!(rep.restored,
                        "scrub failed to restore {} corrupted layers",
                        rep.corrupted.len());
        anyhow::ensure!(integrity::verify(&cm).is_empty(),
                        "arena still fails CRC after scrub");
        anyhow::ensure!(golden.check(&cm),
                        "golden self-test failed after scrub");
    }
    println!("weights: {injected} bit flips injected, {detected_layers} \
              corrupt layers CRC-detected, scrub restored all, \
              undetected_corruptions: {undetected}");
    anyhow::ensure!(undetected == 0,
                    "{undetected} weight campaigns went undetected");

    // carry-slab corruption masked live by the streaming canary
    let cm = Arc::new(compile(&model, &chip, REC_LEN)?);
    let hop = 128usize;
    let windows = if smoke { 6 } else { 16 };
    let total = REC_LEN + hop * (windows - 1);
    let mut rng = SplitMix64::new(seed ^ 0xCA2217);
    let stream: Vec<i8> = (0..total)
        .map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect();
    let mut sess = StreamSession::new(Arc::clone(&cm), hop)?;
    sess.set_canary(1);
    let mut oracle = StreamSession::new(Arc::clone(&cm), hop)?;
    let mut got = sess.push_quantized(&stream[..REC_LEN]);
    let mut want = oracle.push_quantized(&stream[..REC_LEN]);
    let mut planted = 0usize;
    for i in (0..sess.carry_words()).step_by(7) {
        planted += sess.corrupt_carry(i, 0x40_0000) as usize;
    }
    for w in 1..windows {
        let lo = REC_LEN + (w - 1) * hop;
        got.extend(sess.push_quantized(&stream[lo..lo + hop]));
        want.extend(oracle.push_quantized(&stream[lo..lo + hop]));
    }
    let mism = got.iter().zip(&want)
        .filter(|(g, w)| g.logits != w.logits).count();
    let st = sess.stats();
    println!("carry  : {planted} slab words corrupted, canary trips {}, \
              resyncs {}, emitted-window mismatches vs oracle: {mism}",
             st.canary_trips, st.resyncs);
    anyhow::ensure!(planted > 0 && st.canary_trips >= 1,
                    "carry corruption never tripped the canary");
    anyhow::ensure!(mism == 0,
                    "{mism} corrupted windows leaked past the canary");

    // stuck SPE drain lane: counted path diverges, repair restores
    let x = &stream[..REC_LEN];
    let healthy = sim::run(&cm, x);
    let mut arena = ScratchArena::for_model(&cm);
    anyhow::ensure!(arena.force_stuck_lane(0, 0x000F_FFFF),
                    "SPE lane 0 must exist");
    let stuck = sim::run_counted_scratch(&cm, x, &mut arena);
    let stuck_detected = stuck.logits != healthy.logits;
    arena.clear_stuck_lanes();
    let repaired = sim::run_counted_scratch(&cm, x, &mut arena);
    println!("spe    : stuck lane detected by counted-vs-fast divergence: \
              {stuck_detected}, repair bit-exact: {}",
             repaired.logits == healthy.logits);
    anyhow::ensure!(stuck_detected,
                    "stuck lane did not perturb the counted path");
    anyhow::ensure!(repaired.logits == healthy.logits,
                    "clearing the stuck lane did not restore bit-exactness");

    // injected worker panic under live fleet traffic
    let n = if smoke { 8 } else { 24 };
    let mut fcfg = FleetConfig::new(1);
    fcfg.batcher.max_batch = 1;
    fcfg.batcher.max_age = Duration::ZERO;
    fcfg.vote_group = 1;
    fcfg.fault_plan = FaultPlan {
        seed,
        faults: vec![PlannedFault {
            at_window: 0,
            kind: FaultKind::WorkerPanic { shard: 0, after: 3 },
        }],
    };
    let fleet = Fleet::spawn(fcfg, {
        let model = model.clone();
        let chip = chip.clone();
        move |_| Ok(Backend::chipsim(compile(&model, &chip, REC_LEN)?))
    })?;
    let h = fleet.handle();
    let mut rng = SplitMix64::new(seed ^ 0xF1EE7);
    for _ in 0..n {
        let rec: Vec<i8> = (0..REC_LEN)
            .map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect();
        h.submit(rec)?;
    }
    h.flush()?;
    let mut received = 0usize;
    while received < n {
        anyhow::ensure!(fleet.recv().is_some(),
                        "fleet died before delivering all diagnoses");
        received += 1;
    }
    let rep = fleet.shutdown();
    println!("fleet  : injected worker panic survived — {received}/{n} \
              diagnoses delivered, {} respawn(s)", rep.respawns);
    anyhow::ensure!(rep.respawns == 1,
                    "expected exactly 1 supervised respawn, saw {}",
                    rep.respawns);

    println!("faults: ALL LANES PASS (undetected_corruptions: 0)");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "detect" => cmd_detect(&flags),
        "simulate" => cmd_simulate(&flags),
        "report" => cmd_report(),
        "eval" => cmd_eval(&flags),
        "baselines" => cmd_baselines(),
        "serve" => cmd_serve(&flags),
        "stream" => cmd_stream(&flags),
        "scenarios" => cmd_scenarios(&flags),
        "fleet" => cmd_fleet(&flags),
        "faults" => cmd_faults(&flags),
        _ => {
            println!("vaccel — mixed-bit-width sparse CNN accelerator stack");
            println!("usage: vaccel <detect|simulate|report|eval|baselines|serve|stream|scenarios|fleet|faults> [--flags]");
            println!("  detect    classify synthetic recordings (--backend pjrt|golden|chipsim|chipsim-par)");
            println!("  simulate  cycle-accurate chip simulation (--dense, --full-array)");
            println!("  report    chip operating point + workload balance");
            println!("  eval      accuracy on the build-time eval corpus (--backend ...)");
            println!("  baselines train + score the four Table-1 baseline algorithms");
            println!("  serve     threaded streaming ICD demo (--episodes N)");
            println!("            --listen ADDR  TCP wire-protocol front end (--hop H, --token T, --interval-ms MS, --duration-s S)");
            println!("            --loadgen M    loopback wire-path bench, M concurrent devices (--windows K, --hop H)");
            println!("            --scenario F   loadgen streams adversarial analog waveforms of family F");
            println!("                           (clean|sensor-noise|baseline-wander|lead-dislodgement|powerline|amplitude-drift|morphology-drift)");
            println!("  stream    incremental streaming inference, delta reuse per hop (--hop H, --n N, --seed S, --audit, --recalibrate)");
            println!("  scenarios adversarial scenario suite, bit-exact audited (--hop H, --seed S, --recalibrate)");
            println!("  fleet     sharded multi-chip serving engine (--shards N, --n N, --watch, --interval-ms MS)");
            println!("  faults    fault-injection self-test: SEU/scrub, canary resync, stuck lanes, worker panics (--smoke, --seed S)");
            Ok(())
        }
    }
}
