//! The sharded multi-chip serving engine.
//!
//! [`super::serve::Service`] runs ONE pipeline on one thread — the
//! single-accelerator story. A [`Fleet`] scales that out: `N` worker
//! shards, each owning its **own** backend instance (its own compiled
//! model / quantized model, precompiled static counters, and reusable
//! `ScratchArena` — the software analogue of N fabricated chips behind
//! one ingest point, with zero per-recording allocation on each
//! shard's ChipSim OR Golden hot path), fed from a **work-stealing
//! submit queue**:
//!
//! ```text
//!     FleetHandle::submit / submit_labeled / submit_to / submit_shared
//!                               │ (round-robin / pinned)    │
//!             ┌────────┬────────┼────────┬────────┐         ▼
//!             ▼        ▼        ▼        ▼        │   global injector
//!          local q  local q  local q  local q ◄───┘  (first free shard
//!             │        │        │        │              takes it)
//!          shard 0  shard 1  shard 2  shard 3
//!             │        │        │        │
//!             └──── idle shards steal half of the longest backlog ───┘
//! ```
//!
//! Each shard pops recordings in chunks (cross-recording batching: one
//! lock acquisition moves up to `max_batch` jobs), pushes them through
//! its private [`Pipeline`] (front batcher → backend → voter), records
//! per-recording latency in its own [`LatencyRecorder`], and scores
//! labeled submissions against ground truth. [`Fleet::shutdown`] joins
//! the shards and folds everything into a [`FleetReport`]: per-shard
//! latency percentiles plus aggregated confusion matrices, merged
//! simulator counters and fleet throughput.
//!
//! A **running** fleet is observable too: each worker publishes its
//! progress and its backend arena's high-water marks into a shared
//! telemetry table after every processed chunk, and
//! [`FleetHandle::stats`] snapshots that table together with the live
//! queue depths into a [`FleetStats`] — the streaming counterpart of
//! the shutdown report (`vaccel fleet --watch` polls and prints it).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use super::batcher::BatcherConfig;
use super::detector::Backend;
use super::pipeline::{Diagnosis, Pipeline, PipelineStats};
use crate::metrics::{Confusion, LatencyRecorder};
use crate::nn::majority_vote;
use crate::reliability::{run_caught, Backoff, FaultKind, FaultPlan};
use crate::sim::{ArenaStats, Counters};

/// Consecutive backend-rebuild failures after which a supervised shard
/// gives up and reports itself dead instead of retrying forever.
const MAX_REBUILD_FAILURES: u32 = 4;

/// Take a queue/telemetry lock, recovering from poisoning instead of
/// propagating the panic (DESIGN.md §8). Sound here: pushes and pops
/// on the queue state are individually atomic with respect to panics
/// (no multi-step invariant is ever left half-written), so a lock
/// poisoned by a dying worker still guards valid state — and the
/// supervisor's whole job is to keep serving after exactly that panic.
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Fleet sizing + the per-shard pipeline policy.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Worker shards (one backend instance each).
    pub shards: usize,
    /// Batching policy of each shard's pipeline; `max_batch` is also
    /// the queue chunk size a shard grabs per lock acquisition.
    pub batcher: BatcherConfig,
    /// Recordings per diagnosis vote (paper: 6).
    pub vote_group: usize,
    /// Stream every diagnosis out through [`Fleet::recv`]. Disable for
    /// report-style runs (submit → shutdown, nobody receiving): the
    /// channel is unbounded, so undrained diagnoses would otherwise
    /// accumulate for the fleet's lifetime.
    pub stream_diagnoses: bool,
    /// Allow idle shards to steal from sibling local queues. Disable
    /// when shard placement is semantic (one patient's vote-group
    /// episodes pinned per shard): stealing would split an episode
    /// across two voters. The global injector still load-balances.
    pub steal: bool,
    /// Deterministic fault-injection plan
    /// ([`crate::reliability::FaultPlan`], default: no faults). The
    /// fleet honours [`FaultKind::WorkerPanic`] entries: incarnation
    /// `i` of shard `s` panics after processing the `after` count of
    /// the shard's `i`-th planned panic — exercising the supervised
    /// respawn path under real traffic. Other fault kinds target other
    /// layers and are ignored here.
    pub fault_plan: FaultPlan,
}

impl FleetConfig {
    pub fn new(shards: usize) -> Self {
        Self {
            shards,
            batcher: BatcherConfig::default(),
            vote_group: crate::VOTE_GROUP,
            stream_diagnoses: true,
            steal: true,
            fault_plan: FaultPlan::none(),
        }
    }

    /// Report-style fleet: diagnoses are folded into the shutdown
    /// report only, never streamed.
    pub fn report_only(shards: usize) -> Self {
        Self { stream_diagnoses: false, ..Self::new(shards) }
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self::new(4)
    }
}

/// Where a submission lands.
#[derive(Debug, Clone, Copy)]
enum Route {
    /// Round-robin across local queues.
    RoundRobin,
    /// Pinned to one shard's local queue (bounds-checked).
    Shard(usize),
    /// Shared injector: first free shard takes it.
    Global,
}

/// One queued recording (optionally labeled for online scoring).
struct Job {
    rec: Vec<i8>,
    truth: Option<bool>,
}

struct QueueState {
    locals: Vec<VecDeque<Job>>,
    global: VecDeque<Job>,
    /// False once shutdown begins; submits are rejected, workers drain.
    open: bool,
    /// Bumped by [`FleetHandle::flush`]; each worker flushes its
    /// pipeline when it observes an epoch newer than its own.
    flush_epoch: u64,
}

struct Queues {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// One shard's live telemetry slot, published by the worker after
/// every processed chunk and read by [`FleetHandle::stats`]. The
/// mutex is effectively uncontended (one writer, occasional pollers).
#[derive(Debug, Default, Clone, Copy)]
struct ShardLive {
    processed: u64,
    arena: ArenaStats,
}

/// Live per-shard telemetry snapshot from [`FleetHandle::stats`].
#[derive(Debug, Clone, Copy)]
pub struct ShardStats {
    pub shard: usize,
    /// Jobs waiting in this shard's local queue right now.
    pub queue_depth: usize,
    /// Recordings the shard has executed so far.
    pub processed: u64,
    /// The shard backend's arena high-water marks as of its last
    /// completed chunk (all-zero for arena-less backends and before
    /// the shard's first chunk).
    pub arena: ArenaStats,
}

/// Live fleet telemetry: what [`FleetHandle::stats`] returns while
/// the fleet is running — the streaming counterpart of the
/// shutdown-time [`FleetReport`]. Lets operators watch queue growth
/// and arena high-water marks **before** shutdown (`vaccel fleet
/// --watch` polls and prints it).
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub shards: Vec<ShardStats>,
    /// Jobs waiting in the shared global injector.
    pub global_depth: usize,
}

impl FleetStats {
    /// Jobs queued anywhere (local queues + global injector). Zero
    /// means every submitted recording has been *picked up*, not
    /// necessarily finished — shutdown still drains pipelines.
    pub fn queued(&self) -> usize {
        self.global_depth
            + self.shards.iter().map(|s| s.queue_depth).sum::<usize>()
    }

    /// Recordings executed across the fleet so far.
    pub fn processed(&self) -> u64 {
        self.shards.iter().map(|s| s.processed).sum()
    }

    /// Element-wise max of the shards' live arena high-water marks.
    pub fn arena_high_water(&self) -> ArenaStats {
        self.shards.iter()
            .fold(ArenaStats::default(), |acc, s| acc.max(&s.arena))
    }
}

impl std::fmt::Display for FleetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fleet live: {} queued ({} shared), {} processed",
               self.queued(), self.global_depth, self.processed())?;
        for s in &self.shards {
            write!(f, "\n  shard {}: queue {:>4}  processed {:>6}  arena {}",
                   s.shard, s.queue_depth, s.processed, s.arena)?;
        }
        Ok(())
    }
}

/// Pop up to `chunk` jobs for `shard`: own local queue first, then the
/// global injector; only an otherwise-idle shard steals (when `steal`
/// is on) — half of the longest sibling backlog, from the back.
/// Returns the jobs plus how many were stolen.
fn grab_jobs(st: &mut QueueState, shard: usize, chunk: usize,
             steal: bool) -> (Vec<Job>, u64) {
    let mut jobs = Vec::new();
    while jobs.len() < chunk {
        match st.locals[shard].pop_front() {
            Some(j) => jobs.push(j),
            None => break,
        }
    }
    while jobs.len() < chunk {
        match st.global.pop_front() {
            Some(j) => jobs.push(j),
            None => break,
        }
    }
    let mut stolen = 0u64;
    if jobs.is_empty() && steal {
        let victim = (0..st.locals.len())
            .filter(|&i| i != shard && !st.locals[i].is_empty())
            .max_by_key(|&i| st.locals[i].len());
        if let Some(v) = victim {
            let take = st.locals[v].len().div_ceil(2).min(chunk.max(1));
            for _ in 0..take {
                if let Some(j) = st.locals[v].pop_back() {
                    jobs.push(j);
                    stolen += 1;
                }
            }
            // popped from the back: restore FIFO order within the run
            jobs.reverse();
        }
    }
    (jobs, stolen)
}

/// Per-shard results recovered at shutdown.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    pub stats: PipelineStats,
    /// Per-recording inference latency of this shard.
    pub latency: LatencyRecorder,
    /// Accumulated simulator counters (ChipSim backend only).
    pub sim_counters: Counters,
    /// Per-recording confusion vs submitted labels.
    pub rec_confusion: Confusion,
    /// Per-episode (voted) confusion vs submitted labels.
    pub ep_confusion: Confusion,
    /// Recordings this shard executed (== stats.recordings unless the
    /// backend errored).
    pub processed: u64,
    /// How many of those were stolen from sibling queues.
    pub stolen: u64,
    /// Backend/pipeline errors this shard swallowed. Each error also
    /// voids the shard's pending truth queue (the failed batch's
    /// detections never arrive), so scoring stays aligned.
    pub errors: u64,
    /// High-water marks of the shard backend's scratch arena at
    /// shutdown (all-zero for a PJRT backend, which has none).
    /// Capacities only grow, so a steady workload should show a flat
    /// value across shards and runs — growth here means something is
    /// enlarging the arena per recording.
    pub arena: ArenaStats,
    /// Worker incarnations the supervisor respawned after a panic
    /// (0 = the shard never died). Counters above describe the LAST
    /// incarnation: a panic loses that incarnation's in-flight work
    /// and accounting, by the same discard-everything-in-flight rule
    /// the worker applies to a pipeline error.
    pub respawns: u64,
}

impl ShardReport {
    /// The report of a shard whose supervisor gave up (the backend
    /// could not be rebuilt after repeated failures) or whose thread
    /// was lost entirely: empty accounting, one error, the respawn
    /// history preserved.
    fn dead(shard: usize, respawns: u64) -> Self {
        Self {
            shard,
            stats: PipelineStats::default(),
            latency: LatencyRecorder::new(),
            sim_counters: Counters::default(),
            rec_confusion: Confusion::new(),
            ep_confusion: Confusion::new(),
            processed: 0,
            stolen: 0,
            errors: 1,
            arena: ArenaStats::default(),
            respawns,
        }
    }
}

/// Aggregated fleet results.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub shards: Vec<ShardReport>,
    pub recordings: u64,
    pub episodes: u64,
    pub va_episodes: u64,
    /// Backend errors swallowed across shards (see [`ShardReport::errors`]).
    pub errors: u64,
    /// Worker panics survived (shards respawned) across the fleet.
    pub respawns: u64,
    pub rec_confusion: Confusion,
    pub ep_confusion: Confusion,
    /// All shards' latency samples merged (per-recording percentiles).
    pub latency: LatencyRecorder,
    pub sim_counters: Counters,
    /// Element-wise maximum of the shards' arena high-water marks —
    /// the fleet's peak per-backend working-set telemetry.
    pub arena_high_water: ArenaStats,
    /// Wall-clock seconds from spawn to shutdown completion.
    pub wall_s: f64,
}

impl FleetReport {
    fn aggregate(shards: Vec<ShardReport>, wall_s: f64) -> Self {
        let mut r = FleetReport {
            shards: Vec::new(),
            recordings: 0,
            episodes: 0,
            va_episodes: 0,
            errors: 0,
            respawns: 0,
            rec_confusion: Confusion::new(),
            ep_confusion: Confusion::new(),
            latency: LatencyRecorder::new(),
            sim_counters: Counters::default(),
            arena_high_water: ArenaStats::default(),
            wall_s,
        };
        for s in &shards {
            r.recordings += s.stats.recordings;
            r.episodes += s.stats.episodes;
            r.va_episodes += s.stats.va_episodes;
            r.errors += s.errors;
            r.respawns += s.respawns;
            r.rec_confusion.merge(&s.rec_confusion);
            r.ep_confusion.merge(&s.ep_confusion);
            r.latency.merge(&s.latency);
            r.sim_counters.merge(&s.sim_counters);
            r.arena_high_water = r.arena_high_water.max(&s.arena);
        }
        r.shards = shards;
        r
    }

    /// Recordings per wall-clock second across the fleet.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.recordings as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

impl std::fmt::Display for FleetReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "fleet: {} shards, {} recordings, {} episodes ({} VA) \
                     in {:.3} s  ->  {:.1} rec/s",
                 self.shards.len(), self.recordings, self.episodes,
                 self.va_episodes, self.wall_s, self.throughput_rps())?;
        for s in &self.shards {
            writeln!(f, "  shard {}: {:>6} rec ({:>4} stolen, {} errors, \
                         {} respawns)  latency {}",
                     s.shard, s.processed, s.stolen, s.errors, s.respawns,
                     s.latency.clone().summary())?;
        }
        if self.rec_confusion.total() > 0 {
            writeln!(f, "  per-recording: {}", self.rec_confusion)?;
            writeln!(f, "  diagnostic   : {}", self.ep_confusion)?;
        }
        if self.arena_high_water.total_words() > 0 {
            writeln!(f, "  arena high-water (max shard): {}",
                     self.arena_high_water)?;
        }
        write!(f, "  fleet latency: {}", self.latency.clone().summary())
    }
}

struct Worker {
    shard: usize,
    pipeline: Pipeline,
    queues: Arc<Queues>,
    /// This worker's slot in the fleet's live-telemetry table.
    telemetry: Arc<Vec<Mutex<ShardLive>>>,
    events: Sender<(usize, Diagnosis)>,
    stream_diagnoses: bool,
    steal: bool,
    chunk: usize,
    seen_flush: u64,
    /// Ground truth of submitted-and-not-yet-diagnosed recordings, in
    /// FIFO order (the voter emits detections in submission order).
    truths: VecDeque<Option<bool>>,
    rec_conf: Confusion,
    ep_conf: Confusion,
    processed: u64,
    stolen: u64,
    errors: u64,
    /// Injected fault: panic after processing this many recordings
    /// (this incarnation). `None` = healthy worker.
    panic_after: Option<u64>,
    /// How many earlier incarnations of this shard panicked.
    respawns: u64,
}

impl Worker {
    fn forward(&mut self, diagnoses: Vec<Diagnosis>) {
        for d in diagnoses {
            let group = d.detections.len();
            let mut truths = Vec::with_capacity(group);
            for det in &d.detections {
                if let Some(Some(t)) = self.truths.pop_front() {
                    self.rec_conf.push(det.is_va, t);
                    truths.push(t);
                }
            }
            if truths.len() == group && group > 0 {
                self.ep_conf.push(d.episode.is_va, majority_vote(&truths).is_va);
            }
            if self.stream_diagnoses {
                // receiver gone is fine: diagnoses are also folded into
                // the shard stats recovered at shutdown
                let _ = self.events.send((self.shard, d));
            }
        }
    }

    /// A pipeline error loses the failed batch's detections — which
    /// batched recordings it covered is unknowable from here. Resetting
    /// ONLY the truth queue would leave the voter's pending detections
    /// (and the batcher's queued recordings) to pair with the wrong
    /// labels later, so everything in flight is discarded on both
    /// sides: pipeline (batcher + voter partial group + detection
    /// buffer) and the shard's truth queue. Scoring stays aligned;
    /// the dropped work is visible as `errors`.
    fn pump(&mut self, result: anyhow::Result<Vec<Diagnosis>>) {
        match result {
            Ok(ds) => self.forward(ds),
            Err(_) => {
                self.errors += 1;
                self.pipeline.reset_in_flight();
                self.truths.clear();
            }
        }
    }

    fn run(mut self) -> ShardReport {
        loop {
            let mut do_flush = false;
            let jobs = {
                let mut st = lock_ok(&self.queues.state);
                loop {
                    let (jobs, stolen) =
                        grab_jobs(&mut st, self.shard, self.chunk, self.steal);
                    if !jobs.is_empty() {
                        self.stolen += stolen;
                        break jobs;
                    }
                    if st.flush_epoch > self.seen_flush {
                        self.seen_flush = st.flush_epoch;
                        do_flush = true;
                        break Vec::new();
                    }
                    if !st.open {
                        break Vec::new(); // closed and fully drained
                    }
                    st = match self.queues.cv.wait(st) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            };
            if jobs.is_empty() && !do_flush {
                break;
            }
            let had_jobs = !jobs.is_empty();
            for job in jobs {
                self.truths.push_back(job.truth);
                self.processed += 1;
                let r = self.pipeline.push_recording(job.rec);
                self.pump(r);
                if self.panic_after == Some(self.processed) {
                    // injected fault (FaultKind::WorkerPanic): die the
                    // way a real bug would — mid-chunk, with work in
                    // flight — so the supervisor's respawn path is
                    // exercised under genuine load
                    panic!("injected fault: shard {} panics after {} \
                            recordings", self.shard, self.processed);
                }
            }
            if do_flush {
                let r = self.pipeline.flush();
                self.pump(r);
            }
            if had_jobs {
                // publish live telemetry once per chunk (not per
                // recording): progress + the backend arena's current
                // high-water marks, for FleetHandle::stats pollers
                let mut live = lock_ok(&self.telemetry[self.shard]);
                live.processed = self.processed;
                live.arena = self.pipeline.arena_stats();
            }
        }
        // drain in-flight batches (partial vote groups stay pending by
        // design: an ICD must not diagnose on an incomplete episode)
        let r = self.pipeline.flush();
        self.pump(r);
        ShardReport {
            shard: self.shard,
            stats: self.pipeline.stats.clone(),
            latency: self.pipeline.latency.clone(),
            sim_counters: self.pipeline.sim_counters.clone(),
            rec_confusion: self.rec_conf,
            ep_confusion: self.ep_conf,
            processed: self.processed,
            stolen: self.stolen,
            errors: self.errors,
            arena: self.pipeline.arena_stats(),
            respawns: self.respawns,
        }
    }
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct FleetHandle {
    queues: Arc<Queues>,
    next_shard: Arc<AtomicU64>,
    telemetry: Arc<Vec<Mutex<ShardLive>>>,
}

impl FleetHandle {
    fn push(&self, job: Job, route: Route) -> Result<()> {
        let mut st = lock_ok(&self.queues.state);
        if !st.open {
            bail!("fleet is shut down");
        }
        match route {
            Route::Global => st.global.push_back(job),
            Route::Shard(s) => {
                ensure!(s < st.locals.len(), "shard {s} out of range");
                st.locals[s].push_back(job);
            }
            Route::RoundRobin => {
                let n = st.locals.len() as u64;
                let s = (self.next_shard.fetch_add(1, Ordering::Relaxed) % n)
                    as usize;
                st.locals[s].push_back(job);
            }
        }
        drop(st);
        self.queues.cv.notify_all();
        Ok(())
    }

    /// Submit one quantized recording (round-robin shard placement).
    pub fn submit(&self, rec: Vec<i8>) -> Result<()> {
        self.push(Job { rec, truth: None }, Route::RoundRobin)
    }

    /// Submit with ground truth; the owning shard scores the eventual
    /// detection/diagnosis into the fleet confusion matrices.
    pub fn submit_labeled(&self, rec: Vec<i8>, truth: bool) -> Result<()> {
        self.push(Job { rec, truth: Some(truth) }, Route::RoundRobin)
    }

    /// Pin a recording to a specific shard (session affinity — e.g.
    /// one ICD patient per shard). Idle siblings may still steal it
    /// unless the fleet was configured with `steal: false`.
    pub fn submit_to(&self, shard: usize, rec: Vec<i8>) -> Result<()> {
        self.push(Job { rec, truth: None }, Route::Shard(shard))
    }

    /// [`Self::submit_to`] with ground truth for online scoring.
    pub fn submit_to_labeled(&self, shard: usize, rec: Vec<i8>,
                             truth: bool) -> Result<()> {
        self.push(Job { rec, truth: Some(truth) }, Route::Shard(shard))
    }

    /// Submit into the shared global injector: no placement decision,
    /// the first shard that runs out of local work takes it. Good for
    /// latency-critical one-offs that must not sit behind any one
    /// shard's backlog.
    pub fn submit_shared(&self, rec: Vec<i8>) -> Result<()> {
        self.push(Job { rec, truth: None }, Route::Global)
    }

    /// Live telemetry snapshot: per-shard queue depth, recordings
    /// processed so far, and the shard backend's arena high-water
    /// marks — available while the fleet RUNS, unlike the
    /// [`FleetReport`] recovered at shutdown. Queue depths and shard
    /// progress come from different locks, so the snapshot is
    /// per-field consistent, not a global atomic cut — fine for
    /// watching growth, not for exact accounting (shutdown is).
    pub fn stats(&self) -> FleetStats {
        let (global_depth, depths) = {
            let st = lock_ok(&self.queues.state);
            (st.global.len(),
             st.locals.iter().map(|q| q.len()).collect::<Vec<_>>())
        };
        let shards = depths.into_iter().enumerate()
            .map(|(shard, queue_depth)| {
                let live = *lock_ok(&self.telemetry[shard]);
                ShardStats {
                    shard,
                    queue_depth,
                    processed: live.processed,
                    arena: live.arena,
                }
            })
            .collect();
        FleetStats { shards, global_depth }
    }

    /// Push-model telemetry: convert the [`Self::stats`] pull-poll
    /// into an event channel. A publisher thread samples the live
    /// telemetry every `interval` and sends [`FleetStats`] snapshots
    /// until the subscriber drops the receiver or the fleet shuts
    /// down; the snapshot taken *after* shutdown is observed is still
    /// delivered, so subscribers always see the drained end state
    /// before the channel closes. `vaccel fleet --watch` and the
    /// network front-end's STATS push cadence
    /// ([`super::serve_net`]) both ride this.
    pub fn subscribe_stats(&self, interval: Duration)
                           -> Receiver<FleetStats> {
        let (tx, rx) = channel();
        let h = self.clone();
        std::thread::Builder::new()
            .name("va-fleet-stats".into())
            .spawn(move || loop {
                let closed = !lock_ok(&h.queues.state).open;
                if tx.send(h.stats()).is_err() || closed {
                    return;
                }
                std::thread::sleep(interval);
            })
            .expect("spawn fleet stats publisher");
        rx
    }

    /// Force pending work through every shard's batcher (completed
    /// vote groups surface; partial groups keep pending).
    pub fn flush(&self) -> Result<()> {
        let mut st = lock_ok(&self.queues.state);
        if !st.open {
            bail!("fleet is shut down");
        }
        st.flush_epoch += 1;
        drop(st);
        self.queues.cv.notify_all();
        Ok(())
    }
}

/// A running fleet of pipeline shards.
pub struct Fleet {
    queues: Arc<Queues>,
    next_shard: Arc<AtomicU64>,
    telemetry: Arc<Vec<Mutex<ShardLive>>>,
    events: Receiver<(usize, Diagnosis)>,
    workers: Vec<JoinHandle<ShardReport>>,
    t0: Instant,
}

impl Fleet {
    /// Spawn `cfg.shards` supervised workers; `make_backend(shard)`
    /// builds each shard's private backend (for ChipSim: compile the
    /// model once per shard so every worker owns its own engine
    /// instance). The factory is shared with every shard's supervisor
    /// — hence `Fn + Send + Sync + 'static` — because a worker panic
    /// is caught on the shard thread and the worker is **rebuilt from
    /// a fresh backend** after a jittered exponential backoff
    /// ([`crate::reliability::Backoff`]) rather than taking the fleet
    /// down. In-flight work of the dead incarnation is lost (same rule
    /// as a pipeline error); everything still queued is untouched and
    /// drains through the respawned worker. Respawns are visible as
    /// [`ShardReport::respawns`]. The first build of every shard still
    /// fails fast with an `Err` — a fleet that can never build a
    /// backend should not spawn at all.
    pub fn spawn(cfg: FleetConfig,
                 make_backend: impl Fn(usize) -> Result<Backend>
                     + Send + Sync + 'static)
                 -> Result<Self> {
        ensure!(cfg.shards >= 1, "fleet needs at least one shard");
        let make: Arc<dyn Fn(usize) -> Result<Backend> + Send + Sync> =
            Arc::new(make_backend);
        let queues = Arc::new(Queues {
            state: Mutex::new(QueueState {
                locals: (0..cfg.shards).map(|_| VecDeque::new()).collect(),
                global: VecDeque::new(),
                open: true,
                flush_epoch: 0,
            }),
            cv: Condvar::new(),
        });
        let telemetry: Arc<Vec<Mutex<ShardLive>>> = Arc::new(
            (0..cfg.shards).map(|_| Mutex::new(ShardLive::default())).collect());
        // per-shard injected-panic schedule, in plan order: incarnation
        // i of shard s dies after its i-th entry's `after` recordings
        let mut panic_plan: Vec<VecDeque<u64>> =
            vec![VecDeque::new(); cfg.shards];
        for pf in &cfg.fault_plan.faults {
            if let FaultKind::WorkerPanic { shard, after } = pf.kind {
                if shard < cfg.shards {
                    panic_plan[shard].push_back(after);
                }
            }
        }
        let (tx, rx) = channel();
        let mut workers = Vec::with_capacity(cfg.shards);
        for (shard, mut planned_panics) in panic_plan.into_iter().enumerate() {
            let backend = make(shard)?;
            let make = Arc::clone(&make);
            let queues = Arc::clone(&queues);
            let telemetry = Arc::clone(&telemetry);
            let events = tx.clone();
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("va-fleet-{shard}"))
                    .spawn(move || {
                        let mut backoff =
                            Backoff::serving(cfg.fault_plan.seed
                                             ^ 0xF1EE7 ^ shard as u64);
                        let mut respawns = 0u64;
                        let mut rebuild_failures = 0u32;
                        let mut backend = Some(backend);
                        loop {
                            let b = match backend.take() {
                                Some(b) => b,
                                None => match make(shard) {
                                    Ok(b) => {
                                        rebuild_failures = 0;
                                        b
                                    }
                                    Err(_) => {
                                        rebuild_failures += 1;
                                        if rebuild_failures
                                            >= MAX_REBUILD_FAILURES {
                                            return ShardReport::dead(
                                                shard, respawns);
                                        }
                                        std::thread::sleep(
                                            backoff.next_delay());
                                        continue;
                                    }
                                },
                            };
                            let worker = Worker {
                                shard,
                                pipeline: Pipeline::new(
                                    b, cfg.batcher.clone(), cfg.vote_group),
                                queues: Arc::clone(&queues),
                                telemetry: Arc::clone(&telemetry),
                                events: events.clone(),
                                stream_diagnoses: cfg.stream_diagnoses,
                                steal: cfg.steal,
                                chunk: cfg.batcher.max_batch.max(1),
                                seen_flush: 0,
                                truths: VecDeque::new(),
                                rec_conf: Confusion::new(),
                                ep_conf: Confusion::new(),
                                processed: 0,
                                stolen: 0,
                                errors: 0,
                                panic_after: planned_panics.pop_front(),
                                respawns,
                            };
                            match run_caught(|| worker.run()) {
                                Ok(report) => return report,
                                Err(_msg) => {
                                    // the panic is survived, the shard
                                    // respawns after backing off; its
                                    // queued work is still in the shared
                                    // queue state, untouched
                                    respawns += 1;
                                    std::thread::sleep(backoff.next_delay());
                                }
                            }
                        }
                    })
                    .expect("spawn fleet shard"),
            );
        }
        drop(tx); // recv() ends when the last worker exits
        Ok(Self {
            queues,
            next_shard: Arc::new(AtomicU64::new(0)),
            telemetry,
            events: rx,
            workers,
            t0: Instant::now(),
        })
    }

    pub fn handle(&self) -> FleetHandle {
        FleetHandle {
            queues: Arc::clone(&self.queues),
            next_shard: Arc::clone(&self.next_shard),
            telemetry: Arc::clone(&self.telemetry),
        }
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Next diagnosis from any shard (blocking; `None` once every
    /// worker has exited).
    pub fn recv(&self) -> Option<(usize, Diagnosis)> {
        self.events.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<(usize, Diagnosis)> {
        self.events.try_recv().ok()
    }

    /// Stop accepting work, drain every queue, join the shards and
    /// aggregate the report.
    pub fn shutdown(self) -> FleetReport {
        {
            let mut st = lock_ok(&self.queues.state);
            st.open = false;
        }
        self.queues.cv.notify_all();
        // worker panics are caught and respawned INSIDE the shard
        // thread, so join() failing means the supervisor loop itself
        // died — account the shard as dead rather than poisoning
        // shutdown for the healthy shards
        let mut shards: Vec<ShardReport> = self
            .workers
            .into_iter()
            .enumerate()
            .map(|(i, w)| w.join().unwrap_or_else(|_| ShardReport::dead(i, 0)))
            .collect();
        shards.sort_by_key(|s| s.shard);
        FleetReport::aggregate(shards, self.t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatcherConfig;
    use crate::nn::{QLayer, QuantModel};
    use std::time::Duration;

    fn job(v: i8) -> Job {
        Job { rec: vec![v], truth: None }
    }

    fn state(shards: usize) -> QueueState {
        QueueState {
            locals: (0..shards).map(|_| VecDeque::new()).collect(),
            global: VecDeque::new(),
            open: true,
            flush_epoch: 0,
        }
    }

    fn sign_backend() -> Backend {
        Backend::golden(QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![-1, 1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]})
    }

    fn fast_cfg(shards: usize, vote_group: usize) -> FleetConfig {
        FleetConfig {
            batcher: BatcherConfig { max_batch: 2, max_age: Duration::ZERO },
            vote_group,
            ..FleetConfig::new(shards)
        }
    }

    #[test]
    fn subscribe_stats_pushes_until_shutdown() {
        // steal off + pinned submits: each shard owns one whole vote
        // group, so exactly two diagnoses surface deterministically
        let mut cfg = fast_cfg(2, 2);
        cfg.steal = false;
        let fleet = Fleet::spawn(cfg, |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        let rx = h.subscribe_stats(Duration::from_millis(1));
        for i in 0..4 {
            h.submit_to(i % 2, vec![1i8]).unwrap();
        }
        h.flush().unwrap();
        // at least one pushed snapshot arrives without us ever polling
        let first = rx.recv().expect("pushed snapshot");
        assert_eq!(first.shards.len(), 2);
        // both shards' vote groups complete: 4 recordings / group of 2
        fleet.recv().expect("diagnosis 1");
        fleet.recv().expect("diagnosis 2");
        fleet.shutdown();
        // the publisher observes the closed fleet, delivers one final
        // snapshot, then hangs up (into_iter ending IS the hangup).
        // Every job was grabbed before its diagnosis surfaced, so any
        // post-diagnosis snapshot shows empty queues.
        let last = rx.into_iter().last().expect("final snapshot");
        assert_eq!(last.queued(), 0);
    }

    #[test]
    fn grab_prefers_own_queue_then_global() {
        let mut st = state(2);
        st.locals[0].push_back(job(1));
        st.locals[0].push_back(job(2));
        st.global.push_back(job(3));
        let (jobs, stolen) = grab_jobs(&mut st, 0, 8, true);
        assert_eq!(stolen, 0);
        assert_eq!(jobs.iter().map(|j| j.rec[0]).collect::<Vec<_>>(),
                   vec![1, 2, 3]);
    }

    #[test]
    fn grab_caps_at_chunk() {
        let mut st = state(1);
        for v in 0..5 {
            st.locals[0].push_back(job(v));
        }
        let (jobs, _) = grab_jobs(&mut st, 0, 3, true);
        assert_eq!(jobs.len(), 3);
        assert_eq!(st.locals[0].len(), 2);
    }

    #[test]
    fn idle_shard_steals_half_of_longest_backlog_in_order() {
        let mut st = state(3);
        for v in 0..6 {
            st.locals[1].push_back(job(v));
        }
        st.locals[2].push_back(job(100));
        let (jobs, stolen) = grab_jobs(&mut st, 0, 8, true);
        assert_eq!(stolen, 3);
        // stolen from the BACK of shard 1, FIFO order restored
        assert_eq!(jobs.iter().map(|j| j.rec[0]).collect::<Vec<_>>(),
                   vec![3, 4, 5]);
        assert_eq!(st.locals[1].len(), 3);
        assert_eq!(st.locals[2].len(), 1);
    }

    #[test]
    fn steal_disabled_leaves_siblings_alone() {
        let mut st = state(2);
        for v in 0..6 {
            st.locals[1].push_back(job(v));
        }
        let (jobs, stolen) = grab_jobs(&mut st, 0, 8, false);
        assert!(jobs.is_empty());
        assert_eq!(stolen, 0);
        assert_eq!(st.locals[1].len(), 6);
        // the global injector still feeds a no-steal shard
        st.global.push_back(job(9));
        let (jobs, _) = grab_jobs(&mut st, 0, 8, false);
        assert_eq!(jobs.len(), 1);
    }

    #[test]
    fn busy_shard_does_not_steal() {
        let mut st = state(2);
        st.locals[0].push_back(job(1));
        st.locals[1].push_back(job(2));
        let (jobs, stolen) = grab_jobs(&mut st, 0, 8, true);
        assert_eq!(stolen, 0);
        assert_eq!(jobs.len(), 1);
        assert_eq!(st.locals[1].len(), 1);
    }

    #[test]
    fn fleet_round_trip_with_labels() {
        let fleet = Fleet::spawn(fast_cfg(3, 2), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for i in 0..24 {
            let va = i % 2 == 0;
            let rec = vec![if va { 1i8 } else { -1i8 }; crate::REC_LEN];
            h.submit_labeled(rec, va).unwrap();
        }
        h.flush().unwrap();
        let report = fleet.shutdown();
        assert_eq!(report.recordings, 24);
        assert_eq!(report.rec_confusion.total(), 24);
        assert_eq!(report.rec_confusion.accuracy(), 1.0);
        assert!(report.latency.count() > 0);
        assert_eq!(report.shards.len(), 3);
        let processed: u64 = report.shards.iter().map(|s| s.processed).sum();
        assert_eq!(processed, 24);
        assert!(report.throughput_rps() > 0.0);
        // golden shards that ran recordings grew their arenas, so the
        // high-water marks are live (a shard CAN end up with zero
        // recordings if siblings steal its whole queue, so only the
        // fleet aggregate is unconditionally nonzero)
        for s in &report.shards {
            if s.processed > 0 {
                assert!(s.arena.total_words() > 0, "shard {} arena", s.shard);
            }
            // the fleet aggregate is the element-wise max over shards
            assert_eq!(s.arena.max(&report.arena_high_water),
                       report.arena_high_water, "shard {}", s.shard);
        }
        assert!(report.arena_high_water.total_words() > 0);
        // Display must render without panicking (and includes the
        // arena telemetry line)
        let text = format!("{report}");
        assert!(text.contains("arena high-water"), "{text}");
    }

    #[test]
    fn shutdown_drains_in_flight_recordings() {
        let fleet = Fleet::spawn(fast_cfg(2, 3), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for _ in 0..30 {
            h.submit(vec![1i8; crate::REC_LEN]).unwrap();
        }
        // no flush: shutdown itself must drain every queued recording
        let report = fleet.shutdown();
        assert_eq!(report.recordings, 30);
        assert_eq!(report.episodes,
                   report.shards.iter()
                       .map(|s| s.stats.recordings / 3)
                       .sum::<u64>());
    }

    #[test]
    fn pinned_submissions_get_stolen_by_idle_shards() {
        let fleet = Fleet::spawn(fast_cfg(4, 1), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for _ in 0..200 {
            h.submit_to(0, vec![1i8; crate::REC_LEN]).unwrap();
        }
        let report = fleet.shutdown();
        let processed: u64 = report.shards.iter().map(|s| s.processed).sum();
        assert_eq!(processed, 200);
        assert_eq!(report.recordings, 200);
    }

    #[test]
    fn shared_injector_work_is_served() {
        let fleet = Fleet::spawn(fast_cfg(2, 1), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for _ in 0..10 {
            h.submit_shared(vec![1i8; crate::REC_LEN]).unwrap();
        }
        let report = fleet.shutdown();
        assert_eq!(report.recordings, 10);
        assert_eq!(report.episodes, 10);
    }

    #[test]
    fn report_only_fleet_does_not_stream_diagnoses() {
        let mut cfg = fast_cfg(1, 1);
        cfg.stream_diagnoses = false;
        let fleet = Fleet::spawn(cfg, |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for _ in 0..4 {
            h.submit(vec![1i8; crate::REC_LEN]).unwrap();
        }
        let report = fleet.shutdown();
        assert_eq!(report.episodes, 4);
        assert!(fleet_events_empty(&report), "diagnoses still accounted");
    }

    // report_only fleets fold diagnoses into stats only; the channel
    // receiver was dropped with the Fleet, so "empty" is simply "the
    // stats captured everything"
    fn fleet_events_empty(report: &FleetReport) -> bool {
        report.recordings == 4 && report.va_episodes == 4
    }

    #[test]
    fn live_stats_poll_reports_progress_and_queue_depths() {
        let fleet = Fleet::spawn(fast_cfg(2, 1), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        // before any work: an all-zero snapshot with one row per shard
        let s0 = h.stats();
        assert_eq!(s0.shards.len(), 2);
        assert_eq!(s0.queued(), 0);
        assert_eq!(s0.processed(), 0);
        assert_eq!(s0.arena_high_water(), ArenaStats::default());
        for _ in 0..20 {
            h.submit(vec![1i8; crate::REC_LEN]).unwrap();
        }
        h.flush().unwrap();
        // poll until the live view shows everything picked up and done
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut last = h.stats();
        while (last.processed() < 20 || last.queued() > 0)
            && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
            last = h.stats();
        }
        assert_eq!(last.processed(), 20, "live stats never caught up: {last}");
        assert_eq!(last.queued(), 0);
        // golden shards that ran work published live arena marks
        assert!(last.arena_high_water().total_words() > 0);
        let text = format!("{last}");
        assert!(text.contains("fleet live"), "{text}");
        // the live view agrees with the authoritative shutdown report,
        // and a post-shutdown snapshot still serves the final state
        let report = fleet.shutdown();
        assert_eq!(report.recordings, 20);
        assert_eq!(h.stats().processed(), 20);
    }

    #[test]
    fn injected_worker_panic_is_survived_and_respawned() {
        use crate::reliability::{FaultKind, PlannedFault};
        // chunk = 1 means a panic can never discard grabbed-but-
        // unprocessed siblings, and vote_group = 1 means no partial
        // vote state dies with the incarnation: every submitted
        // recording must surface as a diagnosis despite the panic
        let mut cfg = fast_cfg(1, 1);
        cfg.batcher.max_batch = 1;
        cfg.fault_plan = FaultPlan {
            seed: 7,
            faults: vec![PlannedFault {
                at_window: 0,
                kind: FaultKind::WorkerPanic { shard: 0, after: 3 },
            }],
        };
        let fleet = Fleet::spawn(cfg, |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for _ in 0..10 {
            h.submit(vec![1i8; crate::REC_LEN]).unwrap();
        }
        h.flush().unwrap();
        for i in 0..10 {
            let (shard, d) = fleet.recv()
                .unwrap_or_else(|| panic!("fleet died at diagnosis {i}"));
            assert_eq!(shard, 0);
            assert!(d.episode.is_va);
        }
        let report = fleet.shutdown();
        assert_eq!(report.respawns, 1, "exactly one injected panic");
        // the report counts the LAST incarnation: 10 - 3 recordings
        assert_eq!(report.recordings, 7);
        assert!(format!("{report}").contains("respawns"));
        // the handle still works against the drained, closed fleet
        assert!(h.submit(vec![1i8; crate::REC_LEN]).is_err());
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let fleet = Fleet::spawn(fast_cfg(1, 1), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        let _ = fleet.shutdown();
        assert!(h.submit(vec![0i8; crate::REC_LEN]).is_err());
        assert!(h.flush().is_err());
    }

    #[test]
    fn diagnoses_stream_out_while_running() {
        let fleet = Fleet::spawn(fast_cfg(2, 2), |_| Ok(sign_backend())).unwrap();
        let h = fleet.handle();
        for _ in 0..8 {
            h.submit(vec![1i8; crate::REC_LEN]).unwrap();
        }
        h.flush().unwrap();
        let mut got = 0;
        while got < 4 {
            let (shard, d) = fleet.recv().expect("fleet died early");
            assert!(shard < 2);
            assert!(d.episode.is_va);
            got += 1;
        }
        let report = fleet.shutdown();
        assert_eq!(report.episodes, 4);
    }
}
