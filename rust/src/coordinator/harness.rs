//! Scenario harness: drive one adversarial [`Scenario`] through the
//! full streaming path and score it.
//!
//! This is the shared engine behind `vaccel scenarios` and
//! `benches/scenarios.rs`. For every scenario it:
//!
//! 1. streams the raw samples through a [`StreamSession`] (continuous
//!    filter → running-RMS AGC → ADC → delta-reuse engine) in ragged
//!    chunks, exactly like a live sensing channel;
//! 2. **audits every emitted window against the offline per-window
//!    fast path** ([`crate::sim::run_scratch`] on the session's own
//!    quantized stream) — any logit mismatch is a hard error, so
//!    streaming-vs-offline bit-exactness is pinned *under every
//!    scenario*, not just on clean data;
//! 3. scores fixed-threshold (argmax) decisions against the
//!    scenario's per-segment ground truth (windows straddling a
//!    rhythm transition are excluded, never guessed);
//! 4. optionally replays the identical stream through a session with
//!    the online recalibration loop armed, asserting the *logits* are
//!    bit-identical to the fixed pass (the loop may only move the
//!    threshold) and scoring its decisions separately;
//! 5. when the scenario has a clean twin (same base rhythm, no
//!    perturbation), measures decision agreement between the
//!    perturbed run and the clean run — "how much diagnosis did the
//!    perturbation flip".

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::compiler::CompiledModel;
use crate::data::scenarios::Scenario;
use crate::metrics::Confusion;
use crate::sim::{run_scratch, ScratchArena};
use crate::REC_LEN;

use super::detector::Detection;
use super::recal::{RecalConfig, RecalStats};
use super::stream::StreamSession;

/// Ragged push size: prime and unaligned with `REC_LEN`/hops so chunk
/// boundaries sweep across window boundaries.
const CHUNK: usize = 997;

/// Everything measured for one scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// `Scenario::name`.
    pub name: String,
    /// `Family::name()` of the scenario.
    pub family: &'static str,
    /// Windows the streaming engine emitted.
    pub windows: usize,
    /// Windows with unambiguous ground truth (scored).
    pub evaluated: usize,
    /// Fixed-threshold (argmax) confusion over the scored windows.
    pub fixed: Confusion,
    /// Recalibrated confusion over the same windows (when requested).
    pub recal: Option<Confusion>,
    /// Final state of the recalibration loop (when requested).
    pub recal_stats: Option<RecalStats>,
    /// Fraction of windows whose fixed decision matches the clean
    /// twin's (None when the family has no twin).
    pub clean_agreement: Option<f64>,
    /// Logit margin (`logits[VA] - logits[non-VA]`, widened) per
    /// emitted window — raw material for threshold studies.
    pub margins: Vec<i64>,
    /// Ground truth per emitted window (`None` = transition window).
    pub truth: Vec<Option<bool>>,
    /// Windows audited bit-exact vs the offline fast path (always
    /// equals `windows` on success; the audit is fatal on mismatch).
    pub audited: usize,
}

/// Stream `samples` through a fresh session in ragged chunks.
fn stream_all(sess: &mut StreamSession, samples: &[f64]) -> Vec<Detection> {
    let mut dets = Vec::new();
    for chunk in samples.chunks(CHUNK) {
        dets.extend(sess.push(chunk));
    }
    dets
}

/// Run one scenario end-to-end; see the module docs for the stages.
/// `recal` arms the online threshold-recalibration replay. Errors
/// (never panics) on geometry problems or any bit-exactness breach.
pub fn run_scenario(cm: &Arc<CompiledModel>, sc: &Scenario, hop: usize,
                    recal: Option<RecalConfig>) -> Result<ScenarioOutcome> {
    let st = sc.synthesize();
    ensure!(st.samples.len() >= REC_LEN,
            "scenario {} too short: {} samples", sc.name, st.samples.len());

    // 1. live streaming pass, fixed threshold
    let mut sess = StreamSession::new(Arc::clone(cm), hop)?;
    let dets = stream_all(&mut sess, &st.samples);
    let expected = (st.samples.len() - REC_LEN) / hop + 1;
    ensure!(dets.len() == expected,
            "scenario {}: {} windows emitted, expected {expected}",
            sc.name, dets.len());

    // 2. offline audit: the session's own quantized stream through the
    //    per-window fast path must reproduce every logit bit-exactly
    let qstream = StreamSession::new(Arc::clone(cm), hop)?
        .quantize(&st.samples);
    let mut arena = ScratchArena::for_model(cm);
    let mut audited = 0usize;
    for (i, d) in dets.iter().enumerate() {
        let w = &qstream[i * hop..i * hop + REC_LEN];
        let full = run_scratch(cm, w, &mut arena);
        ensure!(d.logits.as_slice() == full.logits.as_slice(),
                "scenario {}: streaming/offline logit mismatch at window \
                 {i}: {:?} vs {:?}",
                sc.name, d.logits, full.logits);
        ensure!(d.is_va == (full.predicted == 1),
                "scenario {}: verdict mismatch at window {i}", sc.name);
        audited += 1;
    }

    // 3. score against per-segment truth
    let mut fixed = Confusion::default();
    let mut margins = Vec::with_capacity(dets.len());
    let mut truth = Vec::with_capacity(dets.len());
    for (i, d) in dets.iter().enumerate() {
        margins.push(d.logits[1] as i64 - d.logits[0] as i64);
        let t = st.window_truth(i * hop, REC_LEN);
        if let Some(t) = t {
            fixed.push(d.is_va, t);
        }
        truth.push(t);
    }
    let evaluated = truth.iter().filter(|t| t.is_some()).count();

    // 4. recalibrated replay: identical stream, identical logits
    //    (asserted), only the verdicts may differ
    let (recal_conf, recal_stats) = match recal {
        None => (None, None),
        Some(cfg) => {
            let mut rsess =
                StreamSession::with_recalibration(Arc::clone(cm), hop, cfg)?;
            let rdets = stream_all(&mut rsess, &st.samples);
            ensure!(rdets.len() == dets.len(),
                    "scenario {}: recal pass emitted {} windows vs {}",
                    sc.name, rdets.len(), dets.len());
            let mut conf = Confusion::default();
            for (i, (r, d)) in rdets.iter().zip(&dets).enumerate() {
                ensure!(r.logits == d.logits,
                        "scenario {}: recalibration changed logits at \
                         window {i} — it may only move the threshold",
                        sc.name);
                if let Some(t) = truth[i] {
                    conf.push(r.is_va, t);
                }
            }
            (Some(conf), rsess.recal_stats())
        }
    };

    // 5. clean-twin agreement
    let clean_agreement = match sc.clean_twin() {
        None => None,
        Some(twin) => {
            let tst = twin.synthesize();
            let mut tsess = StreamSession::new(Arc::clone(cm), hop)?;
            let tdets = stream_all(&mut tsess, &tst.samples);
            ensure!(tdets.len() == dets.len(),
                    "scenario {}: clean twin emitted {} windows vs {}",
                    sc.name, tdets.len(), dets.len());
            let agree = dets.iter().zip(&tdets)
                .filter(|(a, b)| a.is_va == b.is_va)
                .count();
            Some(agree as f64 / dets.len().max(1) as f64)
        }
    };

    Ok(ScenarioOutcome { name: sc.name.clone(),
                         family: sc.family.name(),
                         windows: dets.len(),
                         evaluated,
                         fixed,
                         recal: recal_conf,
                         recal_stats,
                         clean_agreement,
                         margins,
                         truth,
                         audited })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::data::fixtures;

    fn model() -> Arc<CompiledModel> {
        let m = fixtures::quant_model(0xA5);
        Arc::new(compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap())
    }

    #[test]
    fn clean_scenario_runs_and_audits() {
        let cm = model();
        let sc = Scenario::clean(3, 6);
        let out = run_scenario(&cm, &sc, 128, None).unwrap();
        assert_eq!(out.windows, (6 * REC_LEN - REC_LEN) / 128 + 1);
        assert_eq!(out.audited, out.windows);
        assert_eq!(out.margins.len(), out.windows);
        assert!(out.evaluated > 0);
        assert_eq!(out.evaluated as u64, out.fixed.total());
        assert!(out.recal.is_none());
        assert!(out.clean_agreement.is_none(), "clean has no twin");
    }

    #[test]
    fn perturbed_scenario_reports_twin_agreement() {
        let cm = model();
        let sc = Scenario::powerline(7, 5, 1.5);
        let out = run_scenario(&cm, &sc, 256, None).unwrap();
        let a = out.clean_agreement.expect("powerline has a clean twin");
        assert!((0.0..=1.0).contains(&a), "{a}");
    }

    #[test]
    fn recal_replay_scores_without_touching_logits() {
        let cm = model();
        let sc = Scenario::amplitude_drift(9, 6, 0.2);
        let cfg = RecalConfig { horizon: 8, warmup: 8,
                                ..RecalConfig::default() };
        let out = run_scenario(&cm, &sc, 128, Some(cfg)).unwrap();
        let rc = out.recal.expect("recal pass requested");
        assert_eq!(rc.total(), out.fixed.total(),
                   "same windows scored on both passes");
        let st = out.recal_stats.expect("loop ran");
        assert_eq!(st.windows as usize, out.windows);
    }
}
