//! Episode voter: aggregates consecutive detections into diagnoses
//! (paper: 6 recordings per vote).

use crate::nn::majority_vote;

/// One diagnosed episode.
#[derive(Debug, Clone)]
pub struct Episode {
    /// Index of the episode (0-based, in completed-episode order).
    pub index: u64,
    /// Final diagnosis.
    pub is_va: bool,
    /// Per-recording votes that went into it.
    pub votes: Vec<bool>,
}

/// Accumulates per-recording detections into fixed-size vote groups.
#[derive(Debug)]
pub struct Voter {
    group: usize,
    pending: Vec<bool>,
    completed: u64,
}

impl Voter {
    pub fn new(group: usize) -> Self {
        assert!(group >= 1);
        Self { group, pending: Vec::with_capacity(group), completed: 0 }
    }

    /// Paper protocol: groups of 6.
    pub fn paper() -> Self {
        Self::new(crate::VOTE_GROUP)
    }

    /// Push one detection; returns a completed episode every `group`
    /// detections.
    pub fn push(&mut self, is_va: bool) -> Option<Episode> {
        self.pending.push(is_va);
        if self.pending.len() == self.group {
            let votes = std::mem::take(&mut self.pending);
            let v = majority_vote(&votes);
            let ep = Episode { index: self.completed, is_va: v.is_va, votes };
            self.completed += 1;
            Some(ep)
        } else {
            None
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Drop the partial group (error-recovery path: its detections can
    /// no longer be trusted to line up with submissions). Returns how
    /// many votes were discarded. Completed-episode indexing is
    /// unaffected.
    pub fn reset(&mut self) -> usize {
        let n = self.pending.len();
        self.pending.clear();
        n
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_every_group() {
        let mut v = Voter::new(3);
        assert!(v.push(true).is_none());
        assert!(v.push(true).is_none());
        let ep = v.push(false).unwrap();
        assert!(ep.is_va);
        assert_eq!(ep.index, 0);
        assert_eq!(ep.votes, vec![true, true, false]);
        assert_eq!(v.pending(), 0);
    }

    #[test]
    fn reset_drops_partial_group_keeps_index() {
        let mut v = Voter::new(3);
        assert!(v.push(true).is_none());
        assert!(v.push(true).is_none());
        assert!(v.push(true).unwrap().is_va);
        assert!(v.push(false).is_none());
        assert_eq!(v.reset(), 1);
        assert_eq!(v.pending(), 0);
        // next full group still gets the next index
        v.push(true);
        v.push(true);
        assert_eq!(v.push(true).unwrap().index, 1);
    }

    #[test]
    fn paper_group_of_six() {
        let mut v = Voter::paper();
        for _ in 0..5 {
            assert!(v.push(true).is_none());
        }
        assert!(v.push(true).unwrap().is_va);
        assert_eq!(v.completed(), 1);
    }

    /// Property (seed-swept): episode count = floor(n/group) and each
    /// episode's diagnosis equals the majority of its own votes.
    #[test]
    fn property_grouping_exact() {
        for seed in 0..30u64 {
            let mut rng = crate::data::SplitMix64::new(seed);
            let group = 1 + (rng.next_u64() % 7) as usize;
            let mut v = Voter::new(group);
            let n = 100;
            let mut episodes = Vec::new();
            for _ in 0..n {
                if let Some(ep) = v.push(rng.uniform() < 0.5) {
                    episodes.push(ep);
                }
            }
            assert_eq!(episodes.len(), n / group, "seed {seed}");
            for ep in &episodes {
                assert_eq!(ep.votes.len(), group);
                let pos = ep.votes.iter().filter(|&&b| b).count();
                assert_eq!(ep.is_va, 2 * pos > group, "seed {seed}");
            }
        }
    }
}
