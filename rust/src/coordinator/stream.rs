//! Streaming front end: continuous samples → quantized recordings.

use crate::signal::{bandpass_15_55, quantize_input, BiquadCascade, Framer};


/// Stateful front end for one sensing channel.
///
/// Note the ordering subtlety: the *filter* runs continuously across
/// recording boundaries (it models the analog chain), while
/// normalization + quantization are per-recording (they model the
/// chip's per-window AGC + ADC, and match the build-time pipeline).
#[derive(Debug, Clone)]
pub struct FrontEnd {
    filter: BiquadCascade,
    framer: Framer,
}

impl Default for FrontEnd {
    fn default() -> Self {
        Self::new()
    }
}

impl FrontEnd {
    pub fn new() -> Self {
        Self { filter: bandpass_15_55(), framer: Framer::recordings() }
    }

    /// Push raw samples; returns every completed quantized recording.
    pub fn push(&mut self, samples: &[f64]) -> Vec<Vec<i8>> {
        let filtered: Vec<f64> = samples.iter()
            .map(|&s| self.filter.process(s))
            .collect();
        self.framer.push(&filtered)
            .into_iter()
            .map(|frame| {
                // per-recording RMS normalization to 0.25 FS + clamp
                let rms = (frame.iter().map(|v| v * v).sum::<f64>()
                    / frame.len() as f64).sqrt();
                let g = if rms > 1e-9 { 0.25 / rms } else { 1.0 };
                let norm: Vec<f64> = frame.iter()
                    .map(|&v| (v * g).clamp(-1.0, 1.0))
                    .collect();
                quantize_input(&norm)
            })
            .collect()
    }

    /// Samples buffered toward the next recording.
    pub fn pending(&self) -> usize {
        self.framer.pending()
    }

    pub fn reset(&mut self) {
        self.filter.reset();
        self.framer.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::REC_LEN;

    #[test]
    fn emits_one_recording_per_rec_len() {
        let mut fe = FrontEnd::new();
        assert!(fe.push(&vec![0.1; REC_LEN - 1]).is_empty());
        let recs = fe.push(&[0.1, 0.1]);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].len(), REC_LEN);
        assert_eq!(fe.pending(), 1);
    }

    #[test]
    fn filter_state_crosses_boundaries() {
        // a DC step straddling two recordings: the second recording's
        // first samples must see filter memory, not a fresh filter
        let mut fe = FrontEnd::new();
        let r1 = fe.push(&vec![1.0; REC_LEN]);
        let mut fresh = FrontEnd::new();
        let r2a = fresh.push(&vec![1.0; REC_LEN]);
        assert_eq!(r1, r2a); // same prefix, same state
        let cont = fe.push(&vec![1.0; REC_LEN]);
        let fresh2 = FrontEnd::new().push(&vec![1.0; REC_LEN]);
        assert_ne!(cont, fresh2, "continued stream must differ from reset one");
    }

    #[test]
    fn quantization_range() {
        let mut fe = FrontEnd::new();
        let mut src = crate::data::SplitMix64::new(9);
        let samples: Vec<f64> = (0..REC_LEN).map(|_| src.gauss()).collect();
        for rec in fe.push(&samples) {
            assert!(rec.iter().all(|&v| (-127..=127).contains(&(v as i32))));
        }
    }

    #[test]
    fn matches_offline_preprocess_for_first_recording() {
        // for the FIRST recording (zero filter state) the streaming
        // front end must equal the offline preprocess used at build
        // time
        let mut gen = crate::data::Generator::new(4);
        let rec = gen.recording(crate::data::RhythmClass::Vt);
        let offline = crate::signal::front_end(&rec.raw);
        let streamed = FrontEnd::new().push(&rec.raw);
        assert_eq!(streamed.len(), 1);
        assert_eq!(streamed[0], offline);
    }
}
