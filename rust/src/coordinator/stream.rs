//! Streaming front end: continuous samples → quantized recordings —
//! and the incremental streaming session that feeds hops to
//! [`crate::sim::StreamingEngine`].

use std::sync::Arc;

use anyhow::Result;

use crate::compiler::CompiledModel;
use crate::signal::{bandpass_15_55, quantize_input, quantize_sample,
                    BiquadCascade, Framer};
use crate::sim::{StreamingEngine, StreamingStats};

use super::detector::Detection;
use super::recal::{RecalConfig, RecalStats, Recalibrator};

/// Stateful front end for one sensing channel.
///
/// Note the ordering subtlety: the *filter* runs continuously across
/// recording boundaries (it models the analog chain), while
/// normalization + quantization are per-recording (they model the
/// chip's per-window AGC + ADC, and match the build-time pipeline).
/// Per-window AGC also means overlapping windows are NOT slices of one
/// quantized stream — each window is rescaled by its own RMS — which
/// is why the delta-reuse path lives in [`StreamSession`] (per-sample
/// AGC) rather than behind this front end.
#[derive(Debug, Clone)]
pub struct FrontEnd {
    filter: BiquadCascade,
    framer: Framer,
}

impl Default for FrontEnd {
    fn default() -> Self {
        Self::new()
    }
}

impl FrontEnd {
    pub fn new() -> Self {
        Self { filter: bandpass_15_55(), framer: Framer::recordings() }
    }

    /// Overlapping-window front end: full `REC_LEN` recordings emitted
    /// every `hop` samples. Errors (not panics) on a caller-supplied
    /// hop outside `1..=REC_LEN`; `with_hop(REC_LEN)` is [`new`].
    ///
    /// [`new`]: FrontEnd::new
    pub fn with_hop(hop: usize) -> Result<Self> {
        Ok(Self { filter: bandpass_15_55(),
                  framer: Framer::try_new(crate::REC_LEN, hop)? })
    }

    /// Window advance in samples.
    pub fn hop(&self) -> usize {
        self.framer.hop()
    }

    /// Push raw samples; returns every completed quantized recording.
    pub fn push(&mut self, samples: &[f64]) -> Vec<Vec<i8>> {
        let filtered: Vec<f64> = samples.iter()
            .map(|&s| self.filter.process(s))
            .collect();
        let mut out = Vec::new();
        self.framer.push_with(&filtered, |frame| {
            // per-recording RMS normalization to 0.25 FS + clamp
            let rms = (frame.iter().map(|v| v * v).sum::<f64>()
                / frame.len() as f64).sqrt();
            let g = if rms > 1e-9 { 0.25 / rms } else { 1.0 };
            let norm: Vec<f64> = frame.iter()
                .map(|&v| (v * g).clamp(-1.0, 1.0))
                .collect();
            out.push(quantize_input(&norm));
        });
        out
    }

    /// Samples buffered toward the next recording.
    pub fn pending(&self) -> usize {
        self.framer.pending()
    }

    pub fn reset(&mut self) {
        self.filter.reset();
        self.framer.reset();
    }
}

/// Incremental streaming session: continuous raw samples in, one
/// [`Detection`] out per `hop`-sample window advance, with per-layer
/// delta reuse underneath ([`crate::sim::StreamingEngine`]).
///
/// The front-end chain differs from [`FrontEnd`] by design: the filter
/// still runs continuously, but AGC is a *running* RMS (over every
/// filtered sample seen so far) instead of per-window RMS, so each
/// sample is quantized exactly once and overlapping windows really are
/// slices of one quantized stream — the precondition for reusing
/// conv columns across windows. Every emitted detection is bit-exact
/// vs running the per-window fast path on the same quantized slices
/// (enforced by tests here and in `tests/streaming.rs`).
///
/// Optionally an e-G2C-style online threshold-recalibration loop
/// ([`Recalibrator`]) can ride on the session
/// ([`with_recalibration`]): it recentres the VA decision threshold
/// on the running logit-margin median, but NEVER touches the logits,
/// so every logit-level bit-exactness contract holds with it on. Off
/// by default — a plain session decides by argmax.
///
/// [`with_recalibration`]: StreamSession::with_recalibration
#[derive(Debug)]
pub struct StreamSession {
    filter: BiquadCascade,
    /// Running AGC state: count and sum of squares of all filtered
    /// samples so far.
    n: u64,
    sumsq: f64,
    engine: StreamingEngine,
    /// Optional online threshold recalibration (None ⇒ argmax).
    recal: Option<Recalibrator>,
}

impl StreamSession {
    /// Build a session over a compiled model at one hop. Errors on a
    /// hop outside `1..=frame_len` or a head that is not the binary
    /// VA/non-VA readout [`Detection`] reports.
    pub fn new(cm: Arc<CompiledModel>, hop: usize) -> Result<Self> {
        let cout = cm.layers.last().map(|ly| ly.cout).unwrap_or(0);
        anyhow::ensure!(cout == 2,
                        "StreamSession needs a 2-logit head, model has {cout}");
        let engine = StreamingEngine::new(cm, hop)?;
        Ok(Self { filter: bandpass_15_55(), n: 0, sumsq: 0.0, engine,
                  recal: None })
    }

    /// [`new`], with the online threshold-recalibration loop armed.
    ///
    /// [`new`]: StreamSession::new
    pub fn with_recalibration(cm: Arc<CompiledModel>, hop: usize,
                              cfg: RecalConfig) -> Result<Self> {
        let mut s = Self::new(cm, hop)?;
        s.recal = Some(Recalibrator::new(cfg));
        Ok(s)
    }

    /// Arm (`Some`) or disarm (`None`) recalibration mid-session. The
    /// loop starts from a fresh warmup; logits are unaffected either
    /// way.
    pub fn set_recalibration(&mut self, cfg: Option<RecalConfig>) {
        self.recal = cfg.map(Recalibrator::new);
    }

    /// Recalibration telemetry, `None` when the loop is off.
    pub fn recal_stats(&self) -> Option<RecalStats> {
        self.recal.as_ref().map(|r| r.stats())
    }

    /// Run the front-end chain only — continuous filter, running-RMS
    /// AGC, per-sample ADC quantization — WITHOUT advancing the
    /// engine. Public so audits (`vaccel stream --audit`, tests) can
    /// reproduce the exact quantized stream a session consumed and
    /// replay it through the per-window reference path.
    pub fn quantize(&mut self, samples: &[f64]) -> Vec<i8> {
        let mut q = Vec::with_capacity(samples.len());
        for &s in samples {
            let f = self.filter.process(s);
            self.n += 1;
            self.sumsq += f * f;
            let rms = (self.sumsq / self.n as f64).sqrt();
            let g = if rms > 1e-9 { 0.25 / rms } else { 1.0 };
            q.push(quantize_sample((f * g).clamp(-1.0, 1.0)));
        }
        q
    }

    /// Filter + AGC + quantize each raw sample once, then advance the
    /// engine; returns one detection per completed window.
    pub fn push(&mut self, samples: &[f64]) -> Vec<Detection> {
        let q = self.quantize(samples);
        self.push_quantized(&q)
    }

    /// Advance the engine on already-quantized samples (testing /
    /// replaying a recorded ADC stream).
    pub fn push_quantized(&mut self, q: &[i8]) -> Vec<Detection> {
        let outs = self.engine.push(q);
        let mut dets = Vec::with_capacity(outs.len());
        for o in outs {
            let is_va = match self.recal.as_mut() {
                Some(r) => r.decide(o.logits[1] as i64 - o.logits[0] as i64),
                None => o.predicted == 1,
            };
            dets.push(Detection { logits: [o.logits[0], o.logits[1]],
                                  is_va });
        }
        dets
    }

    /// Window advance in samples.
    pub fn hop(&self) -> usize {
        self.engine.hop()
    }

    /// Samples buffered toward the next window.
    pub fn pending(&self) -> usize {
        self.engine.pending()
    }

    /// Arm the underlying engine's streaming canary
    /// ([`StreamingEngine::set_canary`]): every `every`-th incremental
    /// window is cross-checked against a from-scratch recompute, and a
    /// mismatch emits the trusted result and forces a resync. `0`
    /// disarms. Detections stay bit-exact vs the offline oracle either
    /// way — the canary only changes *which* path computed them when
    /// carried state was corrupted.
    pub fn set_canary(&mut self, every: u64) {
        self.engine.set_canary(every);
    }

    /// The armed canary cadence (0 = off).
    pub fn canary_every(&self) -> u64 {
        self.engine.canary_every()
    }

    /// Invalidate the engine's carried state; the next window is a
    /// FULL recompute over the same buffered stream. Recovery hook for
    /// external integrity checks (scrub, supervisor).
    pub fn resync(&mut self) {
        self.engine.resync();
    }

    /// Fault-injection hook pass-through
    /// ([`StreamingEngine::corrupt_carry`]).
    pub fn corrupt_carry(&mut self, index: usize, xor: i32) -> bool {
        self.engine.corrupt_carry(index, xor)
    }

    /// Total words in the engine's carry slab (fault-site space).
    pub fn carry_words(&self) -> usize {
        self.engine.carry_words()
    }

    /// Carried/recomputed column accounting of the underlying engine.
    pub fn stats(&self) -> StreamingStats {
        self.engine.stats()
    }

    /// Drop buffered samples, carried columns, filter, AGC and
    /// recalibration state.
    pub fn reset(&mut self) {
        self.filter.reset();
        self.n = 0;
        self.sumsq = 0.0;
        self.engine.reset();
        if let Some(r) = self.recal.as_mut() {
            r.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::REC_LEN;

    #[test]
    fn emits_one_recording_per_rec_len() {
        let mut fe = FrontEnd::new();
        assert!(fe.push(&vec![0.1; REC_LEN - 1]).is_empty());
        let recs = fe.push(&[0.1, 0.1]);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].len(), REC_LEN);
        assert_eq!(fe.pending(), 1);
    }

    #[test]
    fn filter_state_crosses_boundaries() {
        // a DC step straddling two recordings: the second recording's
        // first samples must see filter memory, not a fresh filter
        let mut fe = FrontEnd::new();
        let r1 = fe.push(&vec![1.0; REC_LEN]);
        let mut fresh = FrontEnd::new();
        let r2a = fresh.push(&vec![1.0; REC_LEN]);
        assert_eq!(r1, r2a); // same prefix, same state
        let cont = fe.push(&vec![1.0; REC_LEN]);
        let fresh2 = FrontEnd::new().push(&vec![1.0; REC_LEN]);
        assert_ne!(cont, fresh2, "continued stream must differ from reset one");
    }

    #[test]
    fn quantization_range() {
        let mut fe = FrontEnd::new();
        let mut src = crate::data::SplitMix64::new(9);
        let samples: Vec<f64> = (0..REC_LEN).map(|_| src.gauss()).collect();
        for rec in fe.push(&samples) {
            assert!(rec.iter().all(|&v| (-127..=127).contains(&(v as i32))));
        }
    }

    #[test]
    fn matches_offline_preprocess_for_first_recording() {
        // for the FIRST recording (zero filter state) the streaming
        // front end must equal the offline preprocess used at build
        // time
        let mut gen = crate::data::Generator::new(4);
        let rec = gen.recording(crate::data::RhythmClass::Vt);
        let offline = crate::signal::front_end(&rec.raw);
        let streamed = FrontEnd::new().push(&rec.raw);
        assert_eq!(streamed.len(), 1);
        assert_eq!(streamed[0], offline);
    }

    #[test]
    fn with_hop_rejects_bad_hops() {
        assert!(FrontEnd::with_hop(0).is_err());
        assert!(FrontEnd::with_hop(REC_LEN + 1).is_err());
        assert_eq!(FrontEnd::with_hop(64).unwrap().hop(), 64);
    }

    /// Offline oracle for the overlapping-hop front end: filter the
    /// whole stream with one fresh filter, slice windows at every hop
    /// offset, then per-window RMS-normalize + clamp + quantize — the
    /// definition the streaming path must reproduce exactly.
    fn offline_overlapping(raw: &[f64], hop: usize) -> Vec<Vec<i8>> {
        let mut bp = bandpass_15_55();
        let filtered: Vec<f64> = raw.iter().map(|&x| bp.process(x)).collect();
        let mut out = Vec::new();
        let mut at = 0;
        while at + REC_LEN <= filtered.len() {
            let w = &filtered[at..at + REC_LEN];
            let rms = (w.iter().map(|v| v * v).sum::<f64>()
                / w.len() as f64).sqrt();
            let g = if rms > 1e-9 { 0.25 / rms } else { 1.0 };
            let norm: Vec<f64> =
                w.iter().map(|&v| (v * g).clamp(-1.0, 1.0)).collect();
            out.push(quantize_input(&norm));
            at += hop;
        }
        out
    }

    #[test]
    fn overlapping_hops_match_offline_oracle_seed_swept() {
        use crate::data::{Generator, RhythmClass};
        for seed in [1u64, 22, 333] {
            let (raw, _) = Generator::new(seed).stream(&[
                (RhythmClass::Nsr, 1), (RhythmClass::Vf, 1),
                (RhythmClass::Vt, 1),
            ]);
            for hop in [1usize, 32, 128, 200, REC_LEN] {
                let want = offline_overlapping(&raw, hop);
                assert!(!want.is_empty(), "oracle empty at hop {hop}");
                let mut fe = FrontEnd::with_hop(hop).unwrap();
                // ragged pushes straddling window boundaries
                let mut got = Vec::new();
                for chunk in raw.chunks(97) {
                    got.extend(fe.push(chunk));
                }
                assert_eq!(got, want, "seed {seed} hop {hop}");
            }
        }
    }

    #[test]
    fn session_matches_per_window_fast_path() {
        use crate::arch::ChipConfig;
        use crate::compiler::compile;
        use crate::data::{fixtures, Generator, RhythmClass};
        use crate::sim::{run_scratch, ScratchArena};

        let m = fixtures::quant_model(0xBEE);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
        let (raw, _) = Generator::new(5)
            .stream(&[(RhythmClass::Vt, 2), (RhythmClass::Nsr, 1)]);
        let hop = 64;
        let mut sess = StreamSession::new(Arc::clone(&cm), hop).unwrap();

        // reference: run the session's own quantized stream through
        // the per-window fast path — the delta-reuse engine must be a
        // pure optimization on top of identical numerics
        let qstream = StreamSession::new(Arc::clone(&cm), hop)
            .unwrap()
            .quantize(&raw);

        let mut dets = Vec::new();
        for chunk in raw.chunks(211) {
            dets.extend(sess.push(chunk));
        }
        let expected_windows = (raw.len() - REC_LEN) / hop + 1;
        assert_eq!(dets.len(), expected_windows);
        let mut arena = ScratchArena::for_model(&cm);
        for (i, d) in dets.iter().enumerate() {
            let w = &qstream[i * hop..i * hop + REC_LEN];
            let full = run_scratch(&cm, w, &mut arena);
            assert_eq!(d.logits.as_slice(), full.logits.as_slice(),
                       "window {i}");
            assert_eq!(d.is_va, full.predicted == 1, "window {i}");
        }
        assert!(sess.stats().carried_cols > 0,
                "hop 64 session must actually reuse columns");
    }

    #[test]
    fn session_canary_masks_carry_corruption() {
        use crate::arch::ChipConfig;
        use crate::compiler::compile;
        use crate::data::{fixtures, Generator, RhythmClass};
        use crate::sim::{run_scratch, ScratchArena};

        let m = fixtures::quant_model(0xFA11);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
        let (raw, _) = Generator::new(13)
            .stream(&[(RhythmClass::Vf, 2), (RhythmClass::Nsr, 1)]);
        let hop = 64;
        let mut sess = StreamSession::new(Arc::clone(&cm), hop).unwrap();
        sess.set_canary(1);
        assert_eq!(sess.canary_every(), 1);
        let qstream = StreamSession::new(Arc::clone(&cm), hop)
            .unwrap()
            .quantize(&raw);

        // two windows in, corrupt the carry slab, then stream the rest
        let split = (REC_LEN + hop) * 2; // well past two window marks
        let mut dets = sess.push(&raw[..split]);
        assert!(dets.len() >= 2);
        for i in (0..sess.carry_words()).step_by(5) {
            assert!(sess.corrupt_carry(i, 0x20_0000));
        }
        dets.extend(sess.push(&raw[split..]));

        // despite the injected corruption, EVERY detection matches the
        // per-window oracle — the canary swapped in trusted results
        let mut arena = ScratchArena::for_model(&cm);
        for (i, d) in dets.iter().enumerate() {
            let w = &qstream[i * hop..i * hop + REC_LEN];
            let full = run_scratch(&cm, w, &mut arena);
            assert_eq!(d.logits.as_slice(), full.logits.as_slice(),
                       "window {i}");
        }
        let st = sess.stats();
        assert!(st.canary_trips >= 1, "corruption must trip the canary");
        assert_eq!(st.resyncs, st.canary_trips);
    }

    #[test]
    fn session_rejects_bad_geometry() {
        use crate::arch::ChipConfig;
        use crate::compiler::compile;
        use crate::data::fixtures;
        let m = fixtures::quant_model(2);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
        assert!(StreamSession::new(Arc::clone(&cm), 0).is_err());
        assert!(StreamSession::new(Arc::clone(&cm), REC_LEN + 1).is_err());
        assert!(StreamSession::new(cm, 32).is_ok());
    }
}
