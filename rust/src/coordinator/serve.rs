//! Threaded service wrapper: a worker thread owns the pipeline;
//! producers submit recordings over a channel and receive diagnoses on
//! a broadcast-ish output channel. (std threads + mpsc — no tokio in
//! the offline build environment; the event-loop shape is the same.)

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use super::pipeline::{Diagnosis, Pipeline};

enum Msg {
    Recording(Vec<i8>),
    Samples(Vec<f64>),
    Flush,
    Shutdown,
}

/// Handle for submitting work to a running [`Service`].
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Msg>,
}

impl ServiceHandle {
    /// Submit one quantized recording.
    pub fn submit_recording(&self, rec: Vec<i8>) -> Result<()> {
        self.tx.send(Msg::Recording(rec)).map_err(|_| anyhow::anyhow!("service down"))
    }

    /// Submit raw analog samples.
    pub fn submit_samples(&self, samples: Vec<f64>) -> Result<()> {
        self.tx.send(Msg::Samples(samples)).map_err(|_| anyhow::anyhow!("service down"))
    }

    /// Force pending work through the batcher/voter.
    pub fn flush(&self) -> Result<()> {
        self.tx.send(Msg::Flush).map_err(|_| anyhow::anyhow!("service down"))
    }
}

/// A pipeline running on its own thread.
pub struct Service {
    handle: ServiceHandle,
    diagnoses: Receiver<Diagnosis>,
    worker: Option<JoinHandle<Pipeline>>,
}

impl Service {
    /// Spawn the worker thread around a pipeline.
    pub fn spawn(mut pipeline: Pipeline) -> Self {
        let (tx, rx) = channel::<Msg>();
        let (dtx, drx) = channel::<Diagnosis>();
        let worker = std::thread::Builder::new()
            .name("va-detector".into())
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    let out = match msg {
                        Msg::Recording(r) => pipeline.push_recording(r),
                        Msg::Samples(s) => pipeline.push_samples(&s),
                        Msg::Flush => pipeline.flush(),
                        Msg::Shutdown => break,
                    };
                    if let Ok(ds) = out {
                        for d in ds {
                            if dtx.send(d).is_err() {
                                return pipeline; // receiver gone
                            }
                        }
                    }
                }
                pipeline
            })
            .expect("spawn detector thread");
        Self { handle: ServiceHandle { tx }, diagnoses: drx, worker: Some(worker) }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Receive the next diagnosis (blocking).
    pub fn recv(&self) -> Option<Diagnosis> {
        self.diagnoses.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Diagnosis> {
        self.diagnoses.try_recv().ok()
    }

    /// Stop the worker and recover the pipeline (with its stats).
    pub fn shutdown(mut self) -> Pipeline {
        let _ = self.handle.tx.send(Msg::Shutdown);
        self.worker.take().unwrap().join().expect("detector thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, BatcherConfig};
    use crate::nn::{QLayer, QuantModel};

    fn sign_backend() -> Backend {
        Backend::golden(QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![-1, 1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]})
    }

    #[test]
    fn service_round_trip() {
        let p = Pipeline::new(sign_backend(), BatcherConfig {
            max_batch: 1, max_age: std::time::Duration::ZERO,
        }, 2);
        let svc = Service::spawn(p);
        let h = svc.handle();
        h.submit_recording(vec![1i8; crate::REC_LEN]).unwrap();
        h.submit_recording(vec![1i8; crate::REC_LEN]).unwrap();
        h.flush().unwrap();
        let d = svc.recv().expect("diagnosis");
        assert!(d.episode.is_va);
        let pipeline = svc.shutdown();
        assert_eq!(pipeline.stats.recordings, 2);
        assert_eq!(pipeline.stats.episodes, 1);
    }

    #[test]
    fn shutdown_without_work() {
        let p = Pipeline::new(sign_backend(), BatcherConfig::default(), 6);
        let svc = Service::spawn(p);
        let pipeline = svc.shutdown();
        assert_eq!(pipeline.stats.recordings, 0);
    }
}
