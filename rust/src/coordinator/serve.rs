//! Threaded service wrapper: a worker thread owns the pipeline;
//! producers submit recordings over a channel and receive diagnoses on
//! a broadcast-ish output channel. (std threads + mpsc — no tokio in
//! the offline build environment; the event-loop shape is the same.)

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;

use anyhow::Result;

use super::pipeline::{Diagnosis, Pipeline};

enum Msg {
    Recording(Vec<i8>),
    Samples(Vec<f64>),
    Flush,
    Shutdown,
}

/// Handle for submitting work to a running [`Service`].
///
/// Every submission error carries the worker's exit reason, so a
/// serving caller can distinguish a graceful drain ("drained …") from
/// a crash ("worker panicked …") instead of seeing a bare
/// "service down" either way.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Msg>,
    /// Set exactly once when the worker exits: why it is gone.
    exit: Arc<OnceLock<String>>,
}

impl ServiceHandle {
    fn down_error(&self) -> anyhow::Error {
        match self.exit.get() {
            Some(why) => anyhow::anyhow!("service down: {why}"),
            // the channel is closed but no reason was recorded — only
            // reachable in the instant between channel teardown and
            // the exit guard running
            None => anyhow::anyhow!("service down: worker exiting"),
        }
    }

    /// Why the worker exited, if it has (None while it is running).
    pub fn exit_reason(&self) -> Option<&str> {
        self.exit.get().map(String::as_str)
    }

    /// Submit one quantized recording.
    pub fn submit_recording(&self, rec: Vec<i8>) -> Result<()> {
        self.tx.send(Msg::Recording(rec)).map_err(|_| self.down_error())
    }

    /// Submit raw analog samples.
    pub fn submit_samples(&self, samples: Vec<f64>) -> Result<()> {
        self.tx.send(Msg::Samples(samples)).map_err(|_| self.down_error())
    }

    /// Force pending work through the batcher/voter.
    pub fn flush(&self) -> Result<()> {
        self.tx.send(Msg::Flush).map_err(|_| self.down_error())
    }
}

/// A pipeline running on its own thread.
pub struct Service {
    handle: ServiceHandle,
    diagnoses: Receiver<Diagnosis>,
    worker: Option<JoinHandle<Pipeline>>,
}

impl Service {
    /// Spawn the worker thread around a pipeline.
    pub fn spawn(mut pipeline: Pipeline) -> Self {
        let (tx, rx) = channel::<Msg>();
        let (dtx, drx) = channel::<Diagnosis>();
        let exit: Arc<OnceLock<String>> = Arc::new(OnceLock::new());
        let exit_w = Arc::clone(&exit);
        let worker = std::thread::Builder::new()
            .name("va-detector".into())
            .spawn(move || {
                // Records a crash reason if the worker unwinds (e.g. a
                // backend panic mid-batch). A local, so it drops —
                // and publishes — BEFORE the captured channels
                // disconnect: handles observe the reason no later than
                // the send failure.
                struct CrashGuard(Arc<OnceLock<String>>);
                impl Drop for CrashGuard {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            let _ = self.0.set(
                                "worker panicked mid-pipeline (crash, \
                                 not a drain)".into());
                        }
                    }
                }
                let guard = CrashGuard(Arc::clone(&exit_w));
                while let Ok(msg) = rx.recv() {
                    let out = match msg {
                        Msg::Recording(r) => pipeline.push_recording(r),
                        Msg::Samples(s) => pipeline.push_samples(&s),
                        Msg::Flush => pipeline.flush(),
                        Msg::Shutdown => {
                            let _ = exit_w.set(
                                "drained (explicit shutdown)".into());
                            break;
                        }
                    };
                    if let Ok(ds) = out {
                        for d in ds {
                            if dtx.send(d).is_err() {
                                // receiver gone
                                let _ = exit_w.set(
                                    "drained (diagnosis receiver \
                                     dropped)".into());
                                return pipeline;
                            }
                        }
                    }
                }
                let _ = exit_w.set("drained (all handles dropped)".into());
                drop(guard);
                pipeline
            })
            .expect("spawn detector thread");
        Self { handle: ServiceHandle { tx, exit }, diagnoses: drx,
               worker: Some(worker) }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Receive the next diagnosis (blocking).
    pub fn recv(&self) -> Option<Diagnosis> {
        self.diagnoses.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Diagnosis> {
        self.diagnoses.try_recv().ok()
    }

    /// Stop the worker and recover the pipeline (with its stats).
    pub fn shutdown(mut self) -> Pipeline {
        let _ = self.handle.tx.send(Msg::Shutdown);
        self.worker.take().unwrap().join().expect("detector thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, BatcherConfig};
    use crate::nn::{QLayer, QuantModel};

    fn sign_backend() -> Backend {
        Backend::golden(QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![-1, 1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]})
    }

    #[test]
    fn service_round_trip() {
        let p = Pipeline::new(sign_backend(), BatcherConfig {
            max_batch: 1, max_age: std::time::Duration::ZERO,
        }, 2);
        let svc = Service::spawn(p);
        let h = svc.handle();
        h.submit_recording(vec![1i8; crate::REC_LEN]).unwrap();
        h.submit_recording(vec![1i8; crate::REC_LEN]).unwrap();
        h.flush().unwrap();
        let d = svc.recv().expect("diagnosis");
        assert!(d.episode.is_va);
        let pipeline = svc.shutdown();
        assert_eq!(pipeline.stats.recordings, 2);
        assert_eq!(pipeline.stats.episodes, 1);
    }

    #[test]
    fn shutdown_without_work() {
        let p = Pipeline::new(sign_backend(), BatcherConfig::default(), 6);
        let svc = Service::spawn(p);
        let pipeline = svc.shutdown();
        assert_eq!(pipeline.stats.recordings, 0);
    }

    #[test]
    fn error_reason_distinguishes_drain() {
        let p = Pipeline::new(sign_backend(), BatcherConfig::default(), 6);
        let svc = Service::spawn(p);
        let h = svc.handle();
        assert!(h.exit_reason().is_none());
        svc.shutdown();
        let err = h.submit_recording(vec![0i8; crate::REC_LEN]).unwrap_err();
        assert!(err.to_string().contains("drained"), "{err}");
        assert!(h.exit_reason().unwrap().contains("explicit shutdown"));
    }

    #[test]
    fn error_reason_distinguishes_crash() {
        // A 1-logit head makes Detection construction index out of
        // bounds inside the worker thread: a genuine crash, not a
        // drain. The handle's next error must say so.
        let p = Pipeline::new(
            Backend::golden(QuantModel { layers: vec![
                QLayer { k: 1, stride: 1, cin: 1, cout: 1, relu: false,
                         nbits: 8, shift: 0, s_in: 1.0, s_out: 1.0,
                         w: vec![1], bias: vec![0], m0: vec![0] },
            ]}),
            BatcherConfig { max_batch: 1,
                            max_age: std::time::Duration::ZERO },
            1);
        let svc = Service::spawn(p);
        let h = svc.handle();
        h.submit_recording(vec![1i8; 8]).unwrap();
        // the worker dies unwinding; the diagnosis channel closing is
        // the observable signal that teardown (incl. the crash guard)
        // has run
        assert!(svc.recv().is_none());
        let err = loop {
            // submissions may still land in the channel during the
            // worker's unwind; spin until the send actually fails
            match h.flush() {
                Err(e) => break e,
                Ok(()) => std::thread::sleep(
                    std::time::Duration::from_millis(1)),
            }
        };
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(h.exit_reason().unwrap().contains("crash"));
        // NOTE: svc is dropped without shutdown() — joining a panicked
        // worker would re-raise the panic; dropping is the crash path.
    }
}
