//! Detection backends: the same pipeline can execute on the PJRT
//! runtime (production), the golden integer model (audit), or the
//! cycle-accurate chip simulator (power/latency studies). All three
//! are bit-exact by construction; integration tests enforce it.

use std::sync::Mutex;

use anyhow::Result;

use crate::compiler::CompiledModel;
use crate::nn::QuantModel;
use crate::runtime::{Executor, InferenceOutput};
use crate::sim::{self, SimScratch};

/// One recording's detection.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub logits: [i32; 2],
    pub is_va: bool,
}

impl Detection {
    fn from_logits(l: [i32; 2]) -> Self {
        // class 1 = VA; shared argmax, ties to the lower (non-VA) index
        Self { logits: l, is_va: crate::nn::argmax(&l) == 1 }
    }
}

/// Chip-simulator backend state: the compiled model (with its
/// precompiled static counters) plus this backend instance's reusable
/// [`SimScratch`] arena. Scratch ownership follows backend ownership —
/// one per fleet shard, one per `Service` — so the simulator hot path
/// allocates nothing per recording. The mutex is uncontended (each
/// shard/service thread owns its backend exclusively); it only makes
/// the backend `Sync` for shared-reference call sites like
/// `Pipeline::evaluate`.
pub struct ChipSimBackend {
    cm: Box<CompiledModel>,
    scratch: Mutex<SimScratch>,
}

impl ChipSimBackend {
    pub fn new(cm: CompiledModel) -> Self {
        let scratch = Mutex::new(SimScratch::for_model(&cm));
        Self { cm: Box::new(cm), scratch }
    }

    /// The compiled model this backend executes.
    pub fn model(&self) -> &CompiledModel {
        &self.cm
    }

    /// Validate a batch's recording lengths against the compiled input
    /// length. Serving paths surface this as a backend `Err` (handled
    /// by the pipeline's error-recovery arm) BEFORE touching the
    /// simulator, so a malformed submission can neither panic a
    /// shard/service thread nor poison the scratch mutex.
    fn check_lengths(&self, xs: &[Vec<i8>]) -> Result<()> {
        let want = self.cm.static_cost.input_len;
        for x in xs {
            anyhow::ensure!(x.len() == want,
                            "recording length {} != compiled input length {want}",
                            x.len());
        }
        Ok(())
    }
}

/// Pluggable inference backend.
pub enum Backend {
    /// AOT'd XLA module on the PJRT CPU client.
    Pjrt(Executor),
    /// Pure-rust golden integer model.
    Golden(QuantModel),
    /// Cycle-accurate SPE-array simulator on the fast path (static
    /// counters stamped per recording; the pipeline accumulates them
    /// for power reporting).
    ChipSim(ChipSimBackend),
}

impl Backend {
    /// Chip-simulator backend over a compiled model (allocates the
    /// per-backend scratch arena).
    pub fn chipsim(cm: CompiledModel) -> Backend {
        Backend::ChipSim(ChipSimBackend::new(cm))
    }

    /// Classify a batch of quantized recordings.
    pub fn infer(&self, xs: &[Vec<i8>]) -> Result<Vec<Detection>> {
        match self {
            Backend::Pjrt(exe) => Ok(exe.infer_batch(xs)?
                .into_iter()
                .map(|InferenceOutput { logits, .. }| Detection::from_logits(logits))
                .collect()),
            Backend::Golden(m) => Ok(xs.iter()
                .map(|x| {
                    let l = m.forward(x);
                    Detection::from_logits([l[0], l[1]])
                })
                .collect()),
            Backend::ChipSim(b) => {
                b.check_lengths(xs)?;
                let mut s = b.scratch.lock().unwrap();
                Ok(xs.iter()
                    .map(|x| {
                        let r = sim::run_scratch(&b.cm, x, &mut s);
                        Detection::from_logits([r.logits[0], r.logits[1]])
                    })
                    .collect())
            }
        }
    }

    /// Classify a batch AND return simulator counters when the backend
    /// produces them (ChipSim). One fast simulation per recording —
    /// the pipeline hot path uses this instead of `infer` +
    /// `simulate_counters`, and the counters come straight from the
    /// compile-time static cost.
    pub fn infer_with_counters(&self, xs: &[Vec<i8>])
                               -> Result<(Vec<Detection>, Option<sim::Counters>)> {
        match self {
            Backend::ChipSim(b) => {
                b.check_lengths(xs)?;
                let mut s = b.scratch.lock().unwrap();
                let (results, total) = sim::run_batch_scratch(&b.cm, xs, &mut s);
                let dets = results.iter()
                    .map(|r| Detection::from_logits([r.logits[0], r.logits[1]]))
                    .collect();
                Ok((dets, Some(total)))
            }
            _ => Ok((self.infer(xs)?, None)),
        }
    }

    /// Simulator counters for a batch (ChipSim only) — O(layers), no
    /// simulation needed: the static cost scaled by the batch size.
    /// Panics on malformed recording lengths (diagnostic API — counters
    /// for inferences that could never run must not be fabricated).
    pub fn simulate_counters(&self, xs: &[Vec<i8>]) -> Option<sim::Counters> {
        match self {
            Backend::ChipSim(b) => {
                b.check_lengths(xs).unwrap();
                Some(b.cm.static_cost.counters.scaled(xs.len() as u64))
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Golden(_) => "golden",
            Backend::ChipSim(_) => "chipsim",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::nn::QLayer;

    fn tiny() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![1, -1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn golden_and_chipsim_agree() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let golden = Backend::Golden(m);
        let chipsim = Backend::chipsim(cm);
        let xs = vec![vec![5i8; 8], vec![-5i8; 8]];
        let a = golden.infer(&xs).unwrap();
        let b = chipsim.infer(&xs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.logits, y.logits);
            assert_eq!(x.is_va, y.is_va);
        }
        // negative input * [1,-1] -> VA logit larger
        assert!(b[1].is_va);
        assert!(chipsim.simulate_counters(&xs).is_some());
        assert!(golden.simulate_counters(&xs).is_none());
    }

    #[test]
    fn chipsim_rejects_wrong_length_gracefully() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::chipsim(cm);
        let bad = vec![vec![1i8; 7]];
        let err = chipsim.infer(&bad).unwrap_err();
        assert!(err.to_string().contains("recording length"), "{err}");
        assert!(chipsim.infer_with_counters(&bad).is_err());
        // an Err (not a panic) leaves the backend fully serviceable
        let ok = chipsim.infer(&[vec![2i8; 8]]).unwrap();
        assert_eq!(ok.len(), 1);
    }

    #[test]
    fn infer_with_counters_matches_separate_calls() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::chipsim(cm);
        let xs = vec![vec![3i8; 8], vec![-7i8; 8], vec![0i8; 8]];
        let (dets, counters) = chipsim.infer_with_counters(&xs).unwrap();
        let separate = chipsim.infer(&xs).unwrap();
        for (a, b) in dets.iter().zip(&separate) {
            assert_eq!(a.logits, b.logits);
        }
        let counters = counters.expect("chipsim must yield counters");
        assert_eq!(counters, chipsim.simulate_counters(&xs).unwrap());

        let golden = Backend::Golden(m);
        let (gdets, gc) = golden.infer_with_counters(&xs).unwrap();
        assert!(gc.is_none());
        assert_eq!(gdets.len(), 3);
    }
}
