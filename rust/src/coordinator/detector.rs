//! Detection backends: the same pipeline can execute on the PJRT
//! runtime (production), the golden integer model (audit), or the
//! cycle-accurate chip simulator (power/latency studies). All three
//! are bit-exact by construction; integration tests enforce it.

use anyhow::Result;

use crate::compiler::CompiledModel;
use crate::nn::QuantModel;
use crate::runtime::{Executor, InferenceOutput};
use crate::sim;

/// One recording's detection.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub logits: [i32; 2],
    pub is_va: bool,
}

impl Detection {
    fn from_logits(l: [i32; 2]) -> Self {
        Self { logits: l, is_va: l[1] > l[0] }
    }
}

/// Pluggable inference backend.
pub enum Backend {
    /// AOT'd XLA module on the PJRT CPU client.
    Pjrt(Executor),
    /// Pure-rust golden integer model.
    Golden(QuantModel),
    /// Cycle-accurate SPE-array simulator (also yields counters; the
    /// pipeline accumulates them for power reporting).
    ChipSim(Box<CompiledModel>),
}

impl Backend {
    /// Classify a batch of quantized recordings.
    pub fn infer(&self, xs: &[Vec<i8>]) -> Result<Vec<Detection>> {
        match self {
            Backend::Pjrt(exe) => Ok(exe.infer_batch(xs)?
                .into_iter()
                .map(|InferenceOutput { logits, .. }| Detection::from_logits(logits))
                .collect()),
            Backend::Golden(m) => Ok(xs.iter()
                .map(|x| {
                    let l = m.forward(x);
                    Detection::from_logits([l[0], l[1]])
                })
                .collect()),
            Backend::ChipSim(cm) => Ok(xs.iter()
                .map(|x| {
                    let r = sim::run(cm, x);
                    Detection::from_logits([r.logits[0], r.logits[1]])
                })
                .collect()),
        }
    }

    /// Classify a batch AND return simulator counters when the backend
    /// produces them (ChipSim). One simulation per recording — the
    /// pipeline hot path uses this instead of `infer` +
    /// `simulate_counters`, which would run the simulator twice.
    pub fn infer_with_counters(&self, xs: &[Vec<i8>])
                               -> Result<(Vec<Detection>, Option<sim::Counters>)> {
        match self {
            Backend::ChipSim(cm) => {
                let (results, total) = sim::run_batch(cm, xs);
                let dets = results.iter()
                    .map(|r| Detection::from_logits([r.logits[0], r.logits[1]]))
                    .collect();
                Ok((dets, Some(total)))
            }
            _ => Ok((self.infer(xs)?, None)),
        }
    }

    /// Simulator counters for a batch (ChipSim only).
    pub fn simulate_counters(&self, xs: &[Vec<i8>]) -> Option<sim::Counters> {
        match self {
            Backend::ChipSim(cm) => Some(sim::run_batch(cm, xs).1),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Golden(_) => "golden",
            Backend::ChipSim(_) => "chipsim",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::nn::QLayer;

    fn tiny() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![1, -1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn golden_and_chipsim_agree() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let golden = Backend::Golden(m);
        let chipsim = Backend::ChipSim(Box::new(cm));
        let xs = vec![vec![5i8; 8], vec![-5i8; 8]];
        let a = golden.infer(&xs).unwrap();
        let b = chipsim.infer(&xs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.logits, y.logits);
            assert_eq!(x.is_va, y.is_va);
        }
        // negative input * [1,-1] -> VA logit larger
        assert!(b[1].is_va);
        assert!(chipsim.simulate_counters(&xs).is_some());
        assert!(golden.simulate_counters(&xs).is_none());
    }

    #[test]
    fn infer_with_counters_matches_separate_calls() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::ChipSim(Box::new(cm));
        let xs = vec![vec![3i8; 8], vec![-7i8; 8], vec![0i8; 8]];
        let (dets, counters) = chipsim.infer_with_counters(&xs).unwrap();
        let separate = chipsim.infer(&xs).unwrap();
        for (a, b) in dets.iter().zip(&separate) {
            assert_eq!(a.logits, b.logits);
        }
        let counters = counters.expect("chipsim must yield counters");
        assert_eq!(counters, chipsim.simulate_counters(&xs).unwrap());

        let golden = Backend::Golden(m);
        let (gdets, gc) = golden.infer_with_counters(&xs).unwrap();
        assert!(gc.is_none());
        assert_eq!(gdets.len(), 3);
    }
}
