//! Detection backends: the same pipeline can execute on the PJRT
//! runtime (production), the golden integer model (audit), or the
//! cycle-accurate chip simulator (power/latency studies) — the latter
//! in two flavors: `ChipSim` (one chip, serial, zero-allocation) and
//! `ChipSimParallel` (a "big chip" that fans each batch across rayon
//! workers — throughput over latency). All are bit-exact by
//! construction; integration tests enforce it.
//!
//! Arena ownership: the `ChipSim` and `Golden` backends each own one
//! [`ScratchArena`], so both serving hot paths allocate nothing per
//! recording — scratch ownership follows backend ownership (one per
//! fleet shard, one per `Service`). `ChipSimParallel` owns none: its
//! scratch lives in rayon workers for the duration of one batch.
//!
//! Counter stamping: the static cost is **backend-independent by
//! construction** (it is a property of the compiled model, not of
//! whatever executes it), so any backend with an attached
//! [`StaticCost`] stamps counters from
//! [`Backend::infer_with_counters`] — `ChipSim` carries its compiled
//! model inherently; `Golden` and `Pjrt` opt in via
//! [`Backend::with_static_cost`].

use std::sync::{Mutex, MutexGuard};

use anyhow::Result;

use crate::arch::KernelTier;
use crate::compiler::{CompiledModel, StaticCost};
use crate::nn::QuantModel;
use crate::runtime::{Executor, InferenceOutput};
use crate::sim::{self, ScratchArena};

/// One recording's detection.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub logits: [i32; 2],
    pub is_va: bool,
}

impl Detection {
    fn from_logits(l: [i32; 2]) -> Self {
        // class 1 = VA; shared argmax, ties to the lower (non-VA) index
        Self { logits: l, is_va: crate::nn::argmax(&l) == 1 }
    }
}

/// Validate a batch's recording lengths against a compiled input
/// length. Serving paths surface this as a backend `Err` (handled by
/// the pipeline's error-recovery arm) BEFORE touching the execution
/// engine, so a malformed submission can neither panic a shard/service
/// thread nor poison a scratch mutex — and counters are never stamped
/// for inferences that could not have run on the chip.
/// Take a backend scratch lock, recovering from poisoning instead of
/// propagating the panic (part of the serving fault-tolerance
/// contract, DESIGN.md §8). Sound because every execution path
/// reinitializes the arena buffers it uses before reading them
/// (`clear` + `extend`/`resize`), so whatever half-written state a
/// panicking inference left behind is never observed — and a supervised
/// shard respawn must not find its backend permanently wedged by the
/// very panic it just recovered from.
fn lock_scratch(m: &Mutex<ScratchArena>) -> MutexGuard<'_, ScratchArena> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn check_lengths(xs: &[Vec<i8>], want: usize) -> Result<()> {
    for x in xs {
        anyhow::ensure!(x.len() == want,
                        "recording length {} != compiled input length {want}",
                        x.len());
    }
    Ok(())
}

/// Chip-simulator backend state: the compiled model (with its
/// precompiled static counters) plus this backend instance's reusable
/// [`ScratchArena`]. The mutex is uncontended (each shard/service
/// thread owns its backend exclusively); it only makes the backend
/// `Sync` for shared-reference call sites like `Pipeline::evaluate`.
pub struct ChipSimBackend {
    cm: Box<CompiledModel>,
    scratch: Mutex<ScratchArena>,
    /// Kernel tier snapshotted at construction ([`KernelTier::current`]
    /// — AVX2 when the host supports it, scalar otherwise or under
    /// `VACCEL_FORCE_SCALAR=1`); every inference dispatches through it.
    tier: KernelTier,
}

impl ChipSimBackend {
    pub fn new(cm: CompiledModel) -> Self {
        let scratch = Mutex::new(ScratchArena::for_model(&cm));
        Self { cm: Box::new(cm), scratch, tier: KernelTier::current() }
    }

    /// The compiled model this backend executes.
    pub fn model(&self) -> &CompiledModel {
        &self.cm
    }
}

/// Golden integer-model backend state: the model, this instance's
/// [`ScratchArena`] (the `forward_scratch` hot path), and an optional
/// attached static cost for counter stamping.
pub struct GoldenBackend {
    model: QuantModel,
    scratch: Mutex<ScratchArena>,
    cost: Option<Box<StaticCost>>,
}

impl GoldenBackend {
    pub fn new(model: QuantModel) -> Self {
        Self { model, scratch: Mutex::new(ScratchArena::new()), cost: None }
    }

    /// The quantized model this backend executes.
    pub fn model(&self) -> &QuantModel {
        &self.model
    }
}

/// Big-chip throughput backend state: the compiled model only. Each
/// batch fans out across rayon workers
/// ([`crate::sim::run_batch_parallel`]), every worker building its own
/// transient [`ScratchArena`] for the batch (`map_init`) instead of
/// this backend owning one long-lived arena — the scratch strategy
/// trades the single-chip backend's zero-allocation steady state for
/// batch-level parallelism. Use for throughput-over-latency
/// deployments where one shard should saturate all cores; keep
/// [`ChipSimBackend`] when per-recording latency (or one-core-per-
/// shard fleet isolation) matters.
pub struct ChipSimParallelBackend {
    cm: Box<CompiledModel>,
    /// Kernel tier snapshotted at construction; every rayon worker of
    /// every batch dispatches through it.
    tier: KernelTier,
}

impl ChipSimParallelBackend {
    pub fn new(cm: CompiledModel) -> Self {
        Self { cm: Box::new(cm), tier: KernelTier::current() }
    }

    /// The compiled model this backend executes.
    pub fn model(&self) -> &CompiledModel {
        &self.cm
    }
}

/// PJRT backend state: the executor plus an optional attached static
/// cost for counter stamping.
pub struct PjrtBackend {
    exec: Executor,
    cost: Option<Box<StaticCost>>,
}

impl PjrtBackend {
    pub fn new(exec: Executor) -> Self {
        Self { exec, cost: None }
    }
}

/// Pluggable inference backend.
pub enum Backend {
    /// AOT'd XLA module on the PJRT CPU client.
    Pjrt(PjrtBackend),
    /// Pure-rust golden integer model over its own arena
    /// (`QuantModel::forward_scratch`).
    Golden(GoldenBackend),
    /// Cycle-accurate SPE-array simulator on the fast path (static
    /// counters stamped per recording; the pipeline accumulates them
    /// for power reporting).
    ChipSim(ChipSimBackend),
    /// "Big chip": the same simulator fast path, but every batch fans
    /// out across rayon workers with per-worker scratch
    /// ([`crate::sim::run_batch_parallel`]) — throughput over latency.
    ChipSimParallel(ChipSimParallelBackend),
}

impl Backend {
    /// Chip-simulator backend over a compiled model (allocates the
    /// per-backend scratch arena).
    pub fn chipsim(cm: CompiledModel) -> Backend {
        Backend::ChipSim(ChipSimBackend::new(cm))
    }

    /// Batch-parallel "big chip" simulator backend: batches run
    /// through [`crate::sim::run_batch_parallel`] (rayon across
    /// recordings, per-worker scratch). Selectable on the CLI as
    /// `--backend chipsim-par`.
    pub fn chipsim_parallel(cm: CompiledModel) -> Backend {
        Backend::ChipSimParallel(ChipSimParallelBackend::new(cm))
    }

    /// Golden integer-model backend (allocates the per-backend arena).
    pub fn golden(model: QuantModel) -> Backend {
        Backend::Golden(GoldenBackend::new(model))
    }

    /// PJRT runtime backend.
    pub fn pjrt(exec: Executor) -> Backend {
        Backend::Pjrt(PjrtBackend::new(exec))
    }

    /// Attach a compiled model's static cost so this backend stamps
    /// per-inference counters from [`Self::infer_with_counters`] and
    /// [`Self::simulate_counters`]. The static cost is derived from the
    /// compiled model alone — it is valid for ANY backend executing the
    /// same network on the same input length. No-op for `ChipSim`,
    /// which carries its compiled model (and cost) inherently.
    pub fn with_static_cost(mut self, sc: StaticCost) -> Backend {
        match &mut self {
            Backend::Pjrt(b) => b.cost = Some(Box::new(sc)),
            Backend::Golden(b) => b.cost = Some(Box::new(sc)),
            Backend::ChipSim(_) | Backend::ChipSimParallel(_) => {}
        }
        self
    }

    /// The static cost this backend stamps, if any.
    pub fn static_cost(&self) -> Option<&StaticCost> {
        match self {
            Backend::Pjrt(b) => b.cost.as_deref(),
            Backend::Golden(b) => b.cost.as_deref(),
            Backend::ChipSim(b) => Some(&b.cm.static_cost),
            Backend::ChipSimParallel(b) => Some(&b.cm.static_cost),
        }
    }

    /// High-water marks of this backend's [`ScratchArena`] (`None`
    /// for PJRT, which has no arena). Capacities only grow, so the
    /// snapshot is the arena's lifetime high-water mark; the fleet
    /// reports it per shard so accidental per-recording arena growth
    /// is visible ([`crate::coordinator::ShardReport`]).
    pub fn arena_stats(&self) -> Option<sim::ArenaStats> {
        match self {
            // ChipSimParallel has no long-lived arena either: its
            // scratch lives inside rayon workers for one batch only
            Backend::Pjrt(_) | Backend::ChipSimParallel(_) => None,
            Backend::Golden(b) => Some(lock_scratch(&b.scratch).stats()),
            Backend::ChipSim(b) => Some(lock_scratch(&b.scratch).stats()),
        }
    }

    /// Classify a batch of quantized recordings.
    pub fn infer(&self, xs: &[Vec<i8>]) -> Result<Vec<Detection>> {
        match self {
            Backend::Pjrt(b) => Ok(b.exec.infer_batch(xs)?
                .into_iter()
                .map(|InferenceOutput { logits, .. }| Detection::from_logits(logits))
                .collect()),
            Backend::Golden(b) => {
                // validate BEFORE taking the lock: a malformed batch
                // must surface as an Err, not a panic that poisons the
                // scratch mutex (an attached cost pins the exact input
                // length; otherwise the golden model only needs whole
                // [L, Cin] samples)
                if let Some(sc) = b.cost.as_deref() {
                    check_lengths(xs, sc.input_len)?;
                } else {
                    // no attached cost: accept any geometry the golden
                    // model can actually run — whole [L, Cin] samples,
                    // and at least one output position per layer (the
                    // 'same'-padded length chain must never underflow)
                    let cin0 =
                        b.model.layers.first().map_or(1, |ly| ly.cin).max(1);
                    for x in xs {
                        anyhow::ensure!(x.len() % cin0 == 0,
                                        "recording length {} is not a whole \
                                         number of {cin0}-channel samples",
                                        x.len());
                        let mut l = x.len() / cin0;
                        for (li, ly) in b.model.layers.iter().enumerate() {
                            anyhow::ensure!(l >= ly.stride,
                                            "recording too short: layer {li} \
                                             has no output positions \
                                             ({l} samples, stride {})",
                                            ly.stride);
                            l = (l - ly.stride) / ly.stride + 1;
                        }
                    }
                }
                let mut s = lock_scratch(&b.scratch);
                Ok(xs.iter()
                    .map(|x| {
                        let l = b.model.forward_scratch(x, &mut s);
                        Detection::from_logits([l[0], l[1]])
                    })
                    .collect())
            }
            Backend::ChipSim(b) => {
                check_lengths(xs, b.cm.static_cost.input_len)?;
                let mut s = lock_scratch(&b.scratch);
                Ok(xs.iter()
                    .map(|x| {
                        let r = sim::run_scratch_tier(&b.cm, x, &mut s,
                                                      b.tier);
                        Detection::from_logits([r.logits[0], r.logits[1]])
                    })
                    .collect())
            }
            Backend::ChipSimParallel(b) => {
                check_lengths(xs, b.cm.static_cost.input_len)?;
                let (results, _) =
                    sim::run_batch_parallel_tier(&b.cm, xs, b.tier);
                Ok(results.iter()
                    .map(|r| Detection::from_logits([r.logits[0], r.logits[1]]))
                    .collect())
            }
        }
    }

    /// Classify a batch AND return simulator counters when the backend
    /// can stamp them: `ChipSim` always; any other backend once a
    /// static cost is attached ([`Self::with_static_cost`]). One
    /// backend pass per batch — the pipeline hot path uses this
    /// instead of `infer` + `simulate_counters`, and the counters come
    /// straight from the compile-time static cost (bit-identical to
    /// dynamic counting on the simulated chip).
    pub fn infer_with_counters(&self, xs: &[Vec<i8>])
                               -> Result<(Vec<Detection>, Option<sim::Counters>)> {
        match self {
            Backend::ChipSim(b) => {
                check_lengths(xs, b.cm.static_cost.input_len)?;
                let mut s = lock_scratch(&b.scratch);
                let (results, total) =
                    sim::run_batch_scratch_tier(&b.cm, xs, &mut s, b.tier);
                let dets = results.iter()
                    .map(|r| Detection::from_logits([r.logits[0], r.logits[1]]))
                    .collect();
                Ok((dets, Some(total)))
            }
            Backend::ChipSimParallel(b) => {
                check_lengths(xs, b.cm.static_cost.input_len)?;
                let (results, total) =
                    sim::run_batch_parallel_tier(&b.cm, xs, b.tier);
                let dets = results.iter()
                    .map(|r| Detection::from_logits([r.logits[0], r.logits[1]]))
                    .collect();
                Ok((dets, Some(total)))
            }
            _ => {
                // an attached cost pins the input contract: mismatched
                // recordings must fail, not get fabricated counters
                if let Some(sc) = self.static_cost() {
                    check_lengths(xs, sc.input_len)?;
                }
                let dets = self.infer(xs)?;
                let counters = self.static_cost()
                    .map(|sc| sc.counters.scaled(xs.len() as u64));
                Ok((dets, counters))
            }
        }
    }

    /// Simulator counters for a batch — O(layers), no simulation
    /// needed: the static cost scaled by the batch size. `Some` for
    /// `ChipSim` and for any backend with an attached static cost.
    /// Panics on malformed recording lengths (diagnostic API — counters
    /// for inferences that could never run must not be fabricated).
    pub fn simulate_counters(&self, xs: &[Vec<i8>]) -> Option<sim::Counters> {
        self.static_cost().map(|sc| {
            check_lengths(xs, sc.input_len).unwrap();
            sc.counters.scaled(xs.len() as u64)
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Golden(_) => "golden",
            Backend::ChipSim(_) => "chipsim",
            Backend::ChipSimParallel(_) => "chipsim-par",
        }
    }

    /// The kernel tier this backend dispatches the simulator hot
    /// kernel through — `Some` for the chip-simulator backends (the
    /// tier snapshotted at construction), `None` for `Golden`/`Pjrt`,
    /// which never touch the tile kernel. Fleet/stream headers print
    /// this for observability.
    pub fn kernel_tier(&self) -> Option<KernelTier> {
        match self {
            Backend::ChipSim(b) => Some(b.tier),
            Backend::ChipSimParallel(b) => Some(b.tier),
            Backend::Pjrt(_) | Backend::Golden(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::nn::QLayer;

    fn tiny() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![1, -1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn golden_and_chipsim_agree() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let golden = Backend::golden(m);
        let chipsim = Backend::chipsim(cm);
        let xs = vec![vec![5i8; 8], vec![-5i8; 8]];
        let a = golden.infer(&xs).unwrap();
        let b = chipsim.infer(&xs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.logits, y.logits);
            assert_eq!(x.is_va, y.is_va);
        }
        // negative input * [1,-1] -> VA logit larger
        assert!(b[1].is_va);
        assert!(chipsim.simulate_counters(&xs).is_some());
        assert!(golden.simulate_counters(&xs).is_none());
    }

    #[test]
    fn chipsim_rejects_wrong_length_gracefully() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::chipsim(cm);
        let bad = vec![vec![1i8; 7]];
        let err = chipsim.infer(&bad).unwrap_err();
        assert!(err.to_string().contains("recording length"), "{err}");
        assert!(chipsim.infer_with_counters(&bad).is_err());
        // an Err (not a panic) leaves the backend fully serviceable
        let ok = chipsim.infer(&[vec![2i8; 8]]).unwrap();
        assert_eq!(ok.len(), 1);
    }

    #[test]
    fn infer_with_counters_matches_separate_calls() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::chipsim(cm);
        let xs = vec![vec![3i8; 8], vec![-7i8; 8], vec![0i8; 8]];
        let (dets, counters) = chipsim.infer_with_counters(&xs).unwrap();
        let separate = chipsim.infer(&xs).unwrap();
        for (a, b) in dets.iter().zip(&separate) {
            assert_eq!(a.logits, b.logits);
        }
        let counters = counters.expect("chipsim must yield counters");
        assert_eq!(counters, chipsim.simulate_counters(&xs).unwrap());

        let golden = Backend::golden(m);
        let (gdets, gc) = golden.infer_with_counters(&xs).unwrap();
        assert!(gc.is_none());
        assert_eq!(gdets.len(), 3);
    }

    #[test]
    fn attached_static_cost_stamps_any_backend() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let sc = cm.static_cost.clone();
        let chipsim = Backend::chipsim(cm);
        let golden = Backend::golden(m).with_static_cost(sc);
        let xs = vec![vec![3i8; 8], vec![-7i8; 8]];
        // a golden backend with attached cost stamps the SAME counters
        // as the chip simulator — static cost is backend-independent
        let (gdets, gc) = golden.infer_with_counters(&xs).unwrap();
        let (cdets, cc) = chipsim.infer_with_counters(&xs).unwrap();
        for (a, b) in gdets.iter().zip(&cdets) {
            assert_eq!(a.logits, b.logits);
        }
        assert_eq!(gc.expect("golden+cost must stamp"),
                   cc.expect("chipsim must stamp"));
        assert_eq!(golden.simulate_counters(&xs),
                   chipsim.simulate_counters(&xs));
        // the attached cost pins the input contract...
        assert!(golden.infer_with_counters(&[vec![0i8; 7]]).is_err());
        assert!(golden.infer(&[vec![0i8; 7]]).is_err());
        // ...and the Err leaves the backend serviceable (no poisoned lock)
        assert_eq!(golden.infer(&[vec![1i8; 8]]).unwrap().len(), 1);
    }

    #[test]
    fn parallel_backend_matches_chipsim_detections_and_counters() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let serial = Backend::chipsim(cm.clone());
        let par = Backend::chipsim_parallel(cm);
        assert_eq!(par.name(), "chipsim-par");
        // big-chip backend: no long-lived arena, but it still carries
        // its compiled model's static cost inherently
        assert!(par.arena_stats().is_none());
        assert!(par.static_cost().is_some());
        let xs: Vec<Vec<i8>> = (0..9)
            .map(|i| vec![(i as i8) * 7 - 30; 8])
            .collect();
        let (a, ca) = serial.infer_with_counters(&xs).unwrap();
        let (b, cb) = par.infer_with_counters(&xs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.logits, y.logits);
            assert_eq!(x.is_va, y.is_va);
        }
        assert_eq!(ca.unwrap(), cb.unwrap());
        // malformed batches surface as an Err, not a panic
        assert!(par.infer(&[vec![1i8; 7]]).is_err());
        assert_eq!(par.infer(&[vec![1i8; 8]]).unwrap().len(), 1);
    }

    #[test]
    fn kernel_tier_is_reported_only_by_simulator_backends() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::chipsim(cm.clone());
        let par = Backend::chipsim_parallel(cm);
        let golden = Backend::golden(m);
        let tier = chipsim.kernel_tier().expect("chipsim has a tier");
        assert_eq!(tier, crate::arch::KernelTier::current());
        assert_eq!(par.kernel_tier(), Some(tier));
        assert!(golden.kernel_tier().is_none());
    }

    #[test]
    fn poisoned_scratch_lock_recovers_and_serves() {
        let m = tiny();
        let cm = compile(&m, &ChipConfig::paper_1d(), 8).unwrap();
        let chipsim = Backend::chipsim(cm);
        // poison the scratch mutex the way a panicking worker would
        if let Backend::ChipSim(b) = &chipsim {
            let _ = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let _g = b.scratch.lock().unwrap();
                    panic!("dies holding the scratch lock");
                }));
            assert!(b.scratch.is_poisoned());
        } else {
            unreachable!()
        }
        // serving continues with correct results: the arena is
        // reinitialized per inference, so recovery is sound
        let dets = chipsim.infer(&[vec![5i8; 8], vec![-5i8; 8]]).unwrap();
        assert!(!dets[0].is_va);
        assert!(dets[1].is_va);
        assert!(chipsim.arena_stats().is_some());
    }

    #[test]
    fn golden_rejects_ragged_sample_count_without_panicking() {
        // cin0 = 2: a recording must be a whole number of 2-channel
        // samples even with no static cost attached — an odd length is
        // an Err BEFORE the scratch lock, never a poisoning panic
        let golden = Backend::golden(QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 2, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![1, -1, 1, -1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]});
        let err = golden.infer(&[vec![1i8; 7]]).unwrap_err();
        assert!(err.to_string().contains("whole"), "{err}");
        assert_eq!(golden.infer(&[vec![1i8; 8]]).unwrap().len(), 1);
    }

    #[test]
    fn golden_rejects_recordings_too_short_for_the_receptive_field() {
        // k=7, stride=2: a 1-sample recording pads to 6 < k — the
        // length chain has no output position, so this must be an Err
        // before the scratch lock (never an underflow panic inside it)
        let golden = Backend::golden(QuantModel { layers: vec![
            QLayer { k: 7, stride: 2, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![1; 14],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]});
        for bad in [vec![], vec![1i8]] {
            let err = golden.infer(&[bad]).unwrap_err();
            assert!(err.to_string().contains("too short"), "{err}");
        }
        // the Err path leaves the backend serviceable
        assert_eq!(golden.infer(&[vec![1i8; 8]]).unwrap().len(), 1);
    }
}
