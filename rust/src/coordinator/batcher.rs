//! Dynamic batcher: groups recordings for the backend.
//!
//! The ICD produces one recording every 2.048 s, but the same pipeline
//! also serves offline sweeps (thousands of recordings at once) and
//! multi-channel configurations. The batcher accumulates up to
//! `max_batch` recordings and flushes on either (a) a full batch or
//! (b) an age deadline, so a lone streaming recording is never held
//! hostage waiting for peers.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many recordings are queued.
    pub max_batch: usize,
    /// Flush any recording older than this.
    pub max_age: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 6, max_age: Duration::from_millis(50) }
    }
}

/// A flushed batch: recordings + their enqueue order ids.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<u64>,
    pub recordings: Vec<Vec<i8>>,
}

/// FIFO dynamic batcher (order-preserving: ids are monotone across
/// batches — property-tested below).
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<(u64, Vec<i8>, Instant)>,
    next_id: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, queue: VecDeque::new(), next_id: 0 }
    }

    /// Enqueue one recording; returns its id.
    pub fn push(&mut self, recording: Vec<i8>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, recording, Instant::now()));
        id
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    fn take(&mut self, n: usize) -> Batch {
        let mut ids = Vec::with_capacity(n);
        let mut recs = Vec::with_capacity(n);
        for _ in 0..n {
            let (id, r, _) = self.queue.pop_front().unwrap();
            ids.push(id);
            recs.push(r);
        }
        Batch { ids, recordings: recs }
    }

    /// Non-blocking poll: returns a batch if the policy says flush.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        if self.queue.len() >= self.cfg.max_batch {
            return Some(self.take(self.cfg.max_batch));
        }
        if let Some((_, _, t0)) = self.queue.front() {
            if now.duration_since(*t0) >= self.cfg.max_age {
                let n = self.queue.len();
                return Some(self.take(n));
            }
        }
        None
    }

    /// Flush whatever is queued (shutdown path).
    pub fn drain(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            let n = self.queue.len();
            Some(self.take(n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_age: Duration::from_millis(ms) }
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(cfg(3, 10_000));
        b.push(vec![1]);
        b.push(vec![2]);
        assert!(b.poll(Instant::now()).is_none());
        b.push(vec![3]);
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.ids, vec![0, 1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(cfg(100, 0));
        b.push(vec![7]);
        let batch = b.poll(Instant::now() + Duration::from_millis(1)).unwrap();
        assert_eq!(batch.recordings, vec![vec![7]]);
    }

    #[test]
    fn holds_young_partial_batch() {
        let mut b = Batcher::new(cfg(100, 10_000));
        b.push(vec![7]);
        assert!(b.poll(Instant::now()).is_none());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn drain_empties() {
        let mut b = Batcher::new(cfg(10, 10_000));
        b.push(vec![1]);
        b.push(vec![2]);
        let batch = b.drain().unwrap();
        assert_eq!(batch.ids.len(), 2);
        assert!(b.drain().is_none());
    }

    /// Property (seed-swept): ids are strictly increasing across any
    /// interleaving of pushes and polls — the batcher never reorders
    /// or drops.
    #[test]
    fn property_order_preserving_lossless() {
        for seed in 0..50u64 {
            let mut rng = crate::data::SplitMix64::new(seed);
            let max_batch = 1 + (rng.next_u64() % 8) as usize;
            let mut b = Batcher::new(cfg(max_batch, 10_000));
            let mut pushed = 0u64;
            let mut seen = Vec::new();
            for _ in 0..200 {
                if rng.uniform() < 0.6 {
                    b.push(vec![0i8]);
                    pushed += 1;
                } else if let Some(batch) = b.poll(Instant::now()) {
                    assert_eq!(batch.ids.len(), max_batch);
                    seen.extend(batch.ids);
                }
            }
            while let Some(batch) = b.drain() {
                seen.extend(batch.ids);
            }
            assert_eq!(seen.len() as u64, pushed, "seed {seed}");
            assert!(seen.windows(2).all(|w| w[1] == w[0] + 1), "seed {seed}");
        }
    }
}
