//! Online threshold recalibration — the e-G2C-style adaptation loop.
//!
//! An implanted detector's logit margins drift with the signal (lead
//! maturation, AGC settling, amplitude loss), while the network's
//! weights are frozen. The loop here tracks a *running median* of the
//! streamed logit margins (`logits[VA] - logits[non-VA]`) per
//! [`super::StreamSession`] and recentres the decision threshold on
//! the observed shift — bounded, dead-zoned, and strictly causal:
//!
//! * **Logits are never touched.** Recalibration only moves the
//!   threshold the `is_va` verdict is compared against, so every
//!   bit-exactness contract on logits (streaming vs offline, SIMD vs
//!   scalar, fast vs counted) holds identically with the loop on.
//! * **Off by default.** A plain `StreamSession` decides by argmax
//!   (margin > 0, ties to non-VA); the loop must be opted into
//!   (`StreamSession::with_recalibration`, `--recalibrate` on the
//!   CLI).
//! * **No retroactive flips.** [`Recalibrator::decide`] renders the
//!   verdict for window *i* with the threshold derived from windows
//!   `< i`, and only then folds window *i*'s margin into the
//!   statistics. A drifted window can move the threshold for its
//!   successors, never for itself or its past.
//! * **Bounded.** The compensation is clamped to
//!   `±`[`RecalConfig::max_shift`] around [`RecalConfig::theta0`], and
//!   a shift estimate inside [`RecalConfig::dead_zone`] applies no
//!   compensation at all — a stationary stream whose margin jitter
//!   stays inside the dead zone gets *bit-identical* verdicts to the
//!   fixed threshold (see `benches/scenarios.rs`' clean-NSR lane).

/// Tunables for [`Recalibrator`]. Margins are in logit units
/// (`logits[1] - logits[0]`, widened to `i64`).
#[derive(Debug, Clone)]
pub struct RecalConfig {
    /// Base decision threshold: `is_va = margin > theta0 + comp`.
    /// `0.0` reproduces argmax semantics (ties decide non-VA).
    pub theta0: f64,
    /// Ring length (windows) of the running-median drift estimator.
    pub horizon: usize,
    /// Windows observed before the reference median freezes; until
    /// then the threshold stays at `theta0`.
    pub warmup: usize,
    /// Estimated shifts with `|shift| <= dead_zone` apply no
    /// compensation (stationarity guard).
    pub dead_zone: f64,
    /// Hard bound on `|threshold - theta0|`.
    pub max_shift: f64,
}

impl Default for RecalConfig {
    fn default() -> Self {
        Self { theta0: 0.0, horizon: 32, warmup: 32, dead_zone: 24.0,
               max_shift: 1e6 }
    }
}

/// Point-in-time view of the loop (for telemetry / CLI footers).
#[derive(Debug, Clone, Copy)]
pub struct RecalStats {
    /// Margins observed since construction/reset.
    pub windows: u64,
    /// Reference median frozen at warmup (`None` while warming up).
    pub reference: Option<f64>,
    /// Latest running-median shift estimate vs the reference.
    pub estimate: f64,
    /// Compensation currently applied (post dead-zone, post clamp).
    pub compensation: f64,
    /// Effective decision threshold (`theta0 + compensation`).
    pub threshold: f64,
    /// Windows whose verdict used a nonzero compensation.
    pub compensated_windows: u64,
}

/// The online threshold-recalibration loop. See the module docs for
/// the contract; see `benches/scenarios.rs` for the end-to-end
/// drift-recovery measurement.
#[derive(Debug, Clone)]
pub struct Recalibrator {
    cfg: RecalConfig,
    /// Most recent `horizon` margins (insertion ring, order-free use).
    ring: Vec<i64>,
    at: usize,
    seen: u64,
    reference: Option<f64>,
    estimate: f64,
    compensation: f64,
    threshold: f64,
    compensated_windows: u64,
    scratch: Vec<i64>,
}

impl Recalibrator {
    pub fn new(cfg: RecalConfig) -> Self {
        let cfg = RecalConfig { horizon: cfg.horizon.max(1),
                                warmup: cfg.warmup.max(1),
                                dead_zone: cfg.dead_zone.max(0.0),
                                max_shift: cfg.max_shift.max(0.0),
                                ..cfg };
        let threshold = cfg.theta0;
        Self { ring: Vec::with_capacity(cfg.horizon),
               at: 0,
               seen: 0,
               reference: None,
               estimate: 0.0,
               compensation: 0.0,
               threshold,
               compensated_windows: 0,
               scratch: Vec::with_capacity(cfg.horizon),
               cfg }
    }

    /// Verdict for one window, then fold its margin into the running
    /// statistics. The decision uses the threshold derived from
    /// *earlier* windows only — the causality half of the contract.
    pub fn decide(&mut self, margin: i64) -> bool {
        let is_va = (margin as f64) > self.threshold;
        if self.compensation != 0.0 {
            self.compensated_windows += 1;
        }
        self.observe(margin);
        is_va
    }

    /// Median of the ring contents (multiset median: rotation of the
    /// ring never matters).
    fn ring_median(&mut self) -> f64 {
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.ring);
        self.scratch.sort_unstable();
        let n = self.scratch.len();
        if n % 2 == 1 {
            self.scratch[n / 2] as f64
        } else {
            (self.scratch[n / 2 - 1] as f64 + self.scratch[n / 2] as f64) / 2.0
        }
    }

    fn observe(&mut self, margin: i64) {
        if self.ring.len() < self.cfg.horizon {
            self.ring.push(margin);
        } else {
            self.ring[self.at] = margin;
            self.at = (self.at + 1) % self.cfg.horizon;
        }
        self.seen += 1;
        if self.reference.is_none() {
            if self.seen >= self.cfg.warmup as u64 {
                self.reference = Some(self.ring_median());
            }
            return; // threshold stays theta0 through warmup
        }
        let reference = self.reference.unwrap();
        self.estimate = self.ring_median() - reference;
        let dz = self.cfg.dead_zone;
        self.compensation = if self.estimate.abs() <= dz {
            0.0
        } else {
            (self.estimate - dz * self.estimate.signum())
                .clamp(-self.cfg.max_shift, self.cfg.max_shift)
        };
        self.threshold = self.cfg.theta0 + self.compensation;
    }

    pub fn stats(&self) -> RecalStats {
        RecalStats { windows: self.seen,
                     reference: self.reference,
                     estimate: self.estimate,
                     compensation: self.compensation,
                     threshold: self.threshold,
                     compensated_windows: self.compensated_windows }
    }

    /// Back to the just-constructed state (threshold at `theta0`,
    /// statistics empty) — `StreamSession::reset` calls this.
    pub fn reset(&mut self) {
        self.ring.clear();
        self.at = 0;
        self.seen = 0;
        self.reference = None;
        self.estimate = 0.0;
        self.compensation = 0.0;
        self.threshold = self.cfg.theta0;
        self.compensated_windows = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(horizon: usize, warmup: usize, dead_zone: f64, max_shift: f64)
           -> RecalConfig {
        RecalConfig { theta0: 0.0, horizon, warmup, dead_zone, max_shift }
    }

    /// Alternating ±A margins with an additive offset; even horizon ⇒
    /// ring median is exactly the offset.
    fn pattern(len: usize, amp: i64, offset: i64) -> Vec<i64> {
        (0..len)
            .map(|i| if i % 2 == 0 { amp + offset } else { -amp + offset })
            .collect()
    }

    #[test]
    fn stationary_stream_matches_fixed_threshold() {
        let mut r = Recalibrator::new(cfg(4, 4, 1.0, 1e9));
        for &m in &pattern(64, 10, 0) {
            let got = r.decide(m);
            assert_eq!(got, m > 0, "margin {m}");
            assert_eq!(r.stats().threshold, 0.0);
        }
        assert_eq!(r.stats().compensated_windows, 0);
        assert_eq!(r.stats().reference, Some(0.0));
    }

    #[test]
    fn plateau_drift_is_compensated() {
        // 32 windows at drift 0, then 64 at drift -100: the fixed
        // threshold misses every shifted VA window (+10-100 = -90 < 0)
        // while the loop recentres and separates them again.
        let mut r = Recalibrator::new(cfg(8, 8, 2.0, 1e6));
        for &m in &pattern(32, 10, 0) {
            assert_eq!(r.decide(m), m > 0);
        }
        let drifted = pattern(64, 10, -100);
        let mut fixed_hits = 0;
        let mut recal_hits = 0;
        let mut recal_false = 0;
        for (i, &m) in drifted.iter().enumerate() {
            let got = r.decide(m);
            if i < 32 {
                continue; // settling: ring still straddles the step
            }
            let is_va_truth = i % 2 == 0; // the +10-100 = -90 windows
            if m > 0 {
                fixed_hits += 1;
            }
            if got && is_va_truth {
                recal_hits += 1;
            }
            if got && !is_va_truth {
                recal_false += 1;
            }
        }
        // settled ring = {-90 x4, -110 x4}: median -100, shift -100,
        // dead-zone 2 => threshold -98: -90 > -98 (hit), -110 <= -98
        assert_eq!(fixed_hits, 0, "fixed threshold must lose the drifted VA");
        assert_eq!(recal_hits, 16, "recalibrated loop must recover them");
        assert_eq!(recal_false, 0, "and not flag the drifted non-VA");
        let st = r.stats();
        assert!((st.threshold - -98.0).abs() < 1e-9, "{}", st.threshold);
        assert!(st.compensated_windows > 0);
    }

    #[test]
    fn compensation_is_bounded() {
        // same drift, max_shift 50: the threshold pins at -50 and the
        // drifted VA windows stay missed — the bound binds.
        let mut r = Recalibrator::new(cfg(8, 8, 2.0, 50.0));
        for &m in &pattern(32, 10, 0) {
            r.decide(m);
        }
        for (i, &m) in pattern(64, 10, -100).iter().enumerate() {
            let got = r.decide(m);
            let st = r.stats();
            assert!(st.threshold.abs() <= 50.0 + 1e-9,
                    "threshold {} escaped the bound", st.threshold);
            if i >= 32 {
                assert!(!got, "window {i}: -90/-110 both sit below -50");
            }
        }
    }

    #[test]
    fn verdict_precedes_observation() {
        // the first post-warmup outlier is judged by the pre-outlier
        // threshold: no retroactive flip of the window that moved the
        // statistics.
        let mut r = Recalibrator::new(cfg(4, 4, 1.0, 1e9));
        for &m in &pattern(16, 10, 0) {
            r.decide(m);
        }
        assert_eq!(r.stats().threshold, 0.0);
        assert!(r.decide(1_000_000), "judged against theta0 = 0");
        assert!(!r.decide(-1_000_000), "still near 0 (median is robust)");
        // during warmup the threshold is pinned to theta0 regardless
        // of what streams in
        let mut w = Recalibrator::new(cfg(8, 8, 0.0, 1e9));
        assert!(!w.decide(i64::MIN + 1));
        assert!(w.decide(i64::MAX));
        assert_eq!(w.stats().threshold, 0.0);
        assert_eq!(w.stats().compensated_windows, 0);
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut r = Recalibrator::new(cfg(8, 8, 2.0, 1e6));
        for &m in &pattern(32, 10, 0) {
            r.decide(m);
        }
        for &m in &pattern(48, 10, -100) {
            r.decide(m);
        }
        assert!(r.stats().threshold != 0.0, "drift must have moved it");
        r.reset();
        let st = r.stats();
        assert_eq!(st.windows, 0);
        assert_eq!(st.threshold, 0.0);
        assert_eq!(st.reference, None);
        assert_eq!(st.compensated_windows, 0);
        // behaves like a fresh loop
        for &m in &pattern(16, 10, 0) {
            assert_eq!(r.decide(m), m > 0);
        }
    }

    #[test]
    fn degenerate_config_is_clamped() {
        let mut r = Recalibrator::new(RecalConfig { theta0: 5.0,
                                                    horizon: 0,
                                                    warmup: 0,
                                                    dead_zone: -3.0,
                                                    max_shift: -1.0 });
        // horizon/warmup clamp to 1, dead_zone/max_shift to 0: with a
        // zero shift budget the loop degenerates to the fixed theta0
        for m in [-10i64, 10, 3, 7, -2] {
            assert_eq!(r.decide(m), (m as f64) > 5.0);
        }
    }
}
