//! L3 coordinator: the ICD runtime around the accelerator.
//!
//! A continuous IEGM sample stream enters; diagnoses exit. Stages:
//!
//! ```text
//!  samples ──► front end (15–55 Hz band-pass, framing, int8 quant)
//!          ──► batcher (vote groups / dynamic batches)
//!          ──► detector backend (PJRT | golden int model | chip sim)
//!          ──► voter (majority of 6) ──► episode diagnosis
//! ```
//!
//! The backend is pluggable so the same pipeline serves production
//! inference (PJRT), bit-exactness audits (golden), and power/latency
//! studies (chip simulator). Concurrency uses std threads + channels
//! (this build environment has no tokio; see Cargo.toml note).
//!
//! For continuous monitoring with overlapping windows, [`StreamSession`]
//! feeds `hop`-sample advances to [`crate::sim::StreamingEngine`]
//! (per-layer delta reuse) instead of re-running the full network per
//! window; its front end quantizes each sample exactly once
//! (continuous filter + running-RMS AGC), unlike [`FrontEnd`]'s
//! per-window AGC.
//!
//! Scale-out lives in [`Fleet`]: a sharded multi-chip serving engine
//! (N pipelines, each with its own backend instance, behind a
//! work-stealing submit queue). [`Service`] remains the
//! single-accelerator baseline the `fleet` bench compares against.
//!
//! The network edge lives in [`NetServer`] (`serve_net`): a TCP wire
//! protocol (length-prefixed binary frames, see [`wire`]) with sharded
//! accept loops, one [`StreamSession`] per connected device on hashed
//! worker shards, bounded per-session inbound budgets with explicit
//! BUSY backpressure, slow-reader eviction, and push-model DIAGNOSIS /
//! STATS frames — `vaccel serve` on the CLI, [`loadgen`] as the
//! loopback driver behind `benches/serve.rs`.
//!
//! **Which backend / entry point?** [`Backend::chipsim`] serves on
//! the simulator fast path ([`crate::sim::run_scratch`]) with chip
//! counters stamped for free; [`Backend::chipsim_parallel`] is the
//! "big chip" variant (each batch fans across rayon workers via
//! [`crate::sim::run_batch_parallel`] — throughput over latency);
//! [`Backend::golden`] serves on the golden arena twin
//! ([`crate::nn::QuantModel::forward_scratch`], no chip modeling —
//! attach counters via [`Backend::with_static_cost`]); the
//! dynamic-counting reference ([`crate::sim::run_counted_scratch`])
//! is a validation tool, not a serving backend. Each ChipSim/Golden
//! backend owns one [`crate::sim::ScratchArena`]; its high-water
//! marks surface per shard in [`FleetReport`]
//! ([`crate::sim::ArenaStats`]) and, live, through
//! [`FleetHandle::stats`] ([`FleetStats`]).

mod batcher;
mod detector;
mod fleet;
mod harness;
mod pipeline;
mod recal;
mod serve;
mod serve_net;
mod stream;
mod voter;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use detector::{Backend, ChipSimBackend, ChipSimParallelBackend,
                   Detection, GoldenBackend, PjrtBackend};
pub use fleet::{Fleet, FleetConfig, FleetHandle, FleetReport, FleetStats,
                ShardReport, ShardStats};
pub use harness::{run_scenario, ScenarioOutcome};
pub use pipeline::{Diagnosis, Pipeline, PipelineStats};
pub use recal::{RecalConfig, RecalStats, Recalibrator};
pub use serve::{Service, ServiceHandle};
pub use serve_net::{loadgen, loadgen_scenario, wire, DeviceClient,
                    LoadgenReport, NetServer, NetStats, ResilientDevice,
                    ServeConfig, WindowDiag};
pub use stream::{FrontEnd, StreamSession};
pub use voter::{Episode, Voter};
