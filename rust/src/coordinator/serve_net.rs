//! Network serving front end: a TCP wire protocol around
//! [`StreamSession`] so devices *stream* samples instead of submitting
//! whole windows in-process.
//!
//! Pure `std::net` + threads/mpsc (the offline build environment has
//! no tokio; see the `serve.rs` precedent). The shape:
//!
//! ```text
//!  N accept loops ── one shared listener, per-IP connect rate limit,
//!        │           bounded connection pool
//!        ▼
//!  per connection: reader thread ──► session worker shard (by device
//!        │          id hash; owns the StreamSession, bounded inbound
//!        │          budget with explicit BUSY backpressure)
//!        ▼                                   │
//!  writer thread ◄── bounded outbound queue ◄┘ (slow readers are
//!                    evicted, never buffered unboundedly)
//! ```
//!
//! Wire protocol (see [`wire`]): little-endian length-prefixed frames,
//! `[u32 len][u8 tag][payload]` where `len` counts tag + payload.
//! A client speaks HELLO (auth token + device id), then SAMPLES frames
//! (f32 analog or pre-quantized i8); the server pushes DIAGNOSIS per
//! completed window, periodic STATS to subscribers, BUSY when a
//! samples frame is shed, ERROR, and GOODBYE on drain.
//!
//! Backpressure is byte-bounded end to end: each session may have at
//! most `max_inflight_samples` samples queued toward its worker
//! (excess frames are shed whole, with a BUSY frame naming the count),
//! and each connection's outbound queue holds at most
//! `outbound_frames` frames (a full queue on a *diagnosis* push means
//! the reader is too slow — the session is evicted; stats frames are
//! simply dropped).

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{IpAddr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender,
                      TrySendError};
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::compiler::CompiledModel;
use crate::data::scenarios::{Family, Scenario};
use crate::metrics::LatencyRecorder;
use crate::reliability::{run_caught, Backoff, FaultKind, FaultPlan};

use super::stream::StreamSession;

/// Serving must keep answering around a poisoned mutex: every lock in
/// this module protects state that is either reinitialized per use or
/// atomic with respect to a panic (map insert/remove), so recovering
/// the guard is sound.
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Connection/writer threads are plentiful (2 per connection + the
/// device side in loadgen); default 8 MiB stacks would exhaust
/// address space long before 1000 sessions. The handlers are shallow.
const SMALL_STACK: usize = 256 * 1024;

pub mod wire {
    //! Frame grammar: `[u32 LE len][u8 tag][payload]`, `len` = 1 +
    //! payload bytes. All integers little-endian.

    use std::fmt;
    use std::io::{self, Read, Write};

    /// Default per-frame ceiling. A frame larger than this is a
    /// protocol error, not a memory commitment.
    pub const MAX_FRAME_BYTES: usize = 1 << 20;

    // client → server
    pub const TAG_HELLO: u8 = 1;
    pub const TAG_SAMPLES_F32: u8 = 2;
    pub const TAG_SAMPLES_I8: u8 = 3;
    pub const TAG_SUBSCRIBE_STATS: u8 = 4;
    pub const TAG_GOODBYE: u8 = 5;
    // server → client
    pub const TAG_WELCOME: u8 = 0x81;
    pub const TAG_DIAGNOSIS: u8 = 0x82;
    pub const TAG_STATS: u8 = 0x83;
    pub const TAG_BUSY: u8 = 0x84;
    pub const TAG_ERROR: u8 = 0x85;

    // ERROR frame codes
    pub const ERR_AUTH: u16 = 1;
    pub const ERR_PROTOCOL: u16 = 2;
    pub const ERR_CAPACITY: u16 = 3;
    pub const ERR_RATE_LIMITED: u16 = 4;
    pub const ERR_SHUTTING_DOWN: u16 = 5;
    /// Supervisor-initiated eviction: the session worker restarted
    /// after a panic and this session's state is gone. NOT the
    /// client's fault — reconnect and replay (see `ResilientDevice`
    /// in the parent module).
    pub const ERR_EVICTED: u16 = 6;
    /// Client-misbehavior eviction: the reader let its outbound
    /// diagnosis queue overflow. Reconnecting without draining faster
    /// will evict again.
    pub const ERR_SLOW_READER: u16 = 7;

    /// Stable label for an ERROR code (logs, bench JSON).
    pub fn err_name(code: u16) -> &'static str {
        match code {
            ERR_AUTH => "auth",
            ERR_PROTOCOL => "protocol",
            ERR_CAPACITY => "capacity",
            ERR_RATE_LIMITED => "rate-limited",
            ERR_SHUTTING_DOWN => "shutting-down",
            ERR_EVICTED => "evicted-by-supervisor",
            ERR_SLOW_READER => "slow-reader",
            _ => "unknown",
        }
    }

    /// One wire frame, either direction.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Frame {
        /// Client opener: auth token + stable device identity (the
        /// worker-shard key).
        Hello { token: String, device_id: u64 },
        /// Raw analog samples (server runs the full front-end chain).
        SamplesF32(Vec<f32>),
        /// Pre-quantized ADC samples (device-side front end).
        SamplesI8(Vec<i8>),
        /// Ask for periodic [`Frame::Stats`] pushes.
        SubscribeStats,
        /// Either side: orderly close. The server answers a client
        /// GOODBYE with its own after the session drains.
        Goodbye,
        /// Server accept: session id + streaming geometry.
        Welcome { session: u64, hop: u32, frame_len: u32 },
        /// One completed window's verdict.
        Diagnosis { window: u64, logits: [i32; 2], is_va: bool },
        /// Periodic server-wide snapshot (subscribers only).
        Stats { sessions: u64, windows: u64, samples: u64, busy: u64,
                evicted: u64 },
        /// A samples frame was shed whole (`dropped` samples); the
        /// client should back off and resend.
        Busy { dropped: u32 },
        /// Terminal rejection; the server closes after sending.
        Error { code: u16, msg: String },
    }

    /// Decode/IO failure reading a frame.
    #[derive(Debug)]
    pub enum WireError {
        Io(io::Error),
        /// Declared length exceeds the negotiated frame ceiling —
        /// rejected *before* allocating.
        Oversized(u32),
        Malformed(String),
    }

    impl fmt::Display for WireError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            match self {
                WireError::Io(e) => write!(f, "wire io: {e}"),
                WireError::Oversized(n) =>
                    write!(f, "oversized frame: {n} bytes"),
                WireError::Malformed(m) =>
                    write!(f, "malformed frame: {m}"),
            }
        }
    }

    impl std::error::Error for WireError {}

    impl From<io::Error> for WireError {
        fn from(e: io::Error) -> Self {
            WireError::Io(e)
        }
    }

    impl WireError {
        /// True for errors that mean "the peer went away" rather than
        /// "the peer spoke garbage".
        pub fn is_io(&self) -> bool {
            matches!(self, WireError::Io(_))
        }
    }

    fn put_u16(b: &mut Vec<u8>, v: u16) { b.extend_from_slice(&v.to_le_bytes()); }
    fn put_u32(b: &mut Vec<u8>, v: u32) { b.extend_from_slice(&v.to_le_bytes()); }
    fn put_u64(b: &mut Vec<u8>, v: u64) { b.extend_from_slice(&v.to_le_bytes()); }
    fn put_i32(b: &mut Vec<u8>, v: i32) { b.extend_from_slice(&v.to_le_bytes()); }

    fn get_u16(b: &[u8]) -> u16 { u16::from_le_bytes([b[0], b[1]]) }
    fn get_u32(b: &[u8]) -> u32 { u32::from_le_bytes([b[0], b[1], b[2], b[3]]) }
    fn get_u64(b: &[u8]) -> u64 {
        u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
    fn get_i32(b: &[u8]) -> i32 { get_u32(b) as i32 }

    /// Serialize a frame to `[len][tag][payload]` bytes.
    pub fn encode(f: &Frame) -> Vec<u8> {
        let mut b = vec![0u8; 4]; // length stamped last
        match f {
            Frame::Hello { token, device_id } => {
                b.push(TAG_HELLO);
                put_u64(&mut b, *device_id);
                b.extend_from_slice(token.as_bytes());
            }
            Frame::SamplesF32(v) => {
                b.push(TAG_SAMPLES_F32);
                for x in v {
                    put_u32(&mut b, x.to_bits());
                }
            }
            Frame::SamplesI8(q) => {
                b.push(TAG_SAMPLES_I8);
                b.extend(q.iter().map(|&x| x as u8));
            }
            Frame::SubscribeStats => b.push(TAG_SUBSCRIBE_STATS),
            Frame::Goodbye => b.push(TAG_GOODBYE),
            Frame::Welcome { session, hop, frame_len } => {
                b.push(TAG_WELCOME);
                put_u64(&mut b, *session);
                put_u32(&mut b, *hop);
                put_u32(&mut b, *frame_len);
            }
            Frame::Diagnosis { window, logits, is_va } => {
                b.push(TAG_DIAGNOSIS);
                put_u64(&mut b, *window);
                put_i32(&mut b, logits[0]);
                put_i32(&mut b, logits[1]);
                b.push(*is_va as u8);
            }
            Frame::Stats { sessions, windows, samples, busy, evicted } => {
                b.push(TAG_STATS);
                for v in [sessions, windows, samples, busy, evicted] {
                    put_u64(&mut b, *v);
                }
            }
            Frame::Busy { dropped } => {
                b.push(TAG_BUSY);
                put_u32(&mut b, *dropped);
            }
            Frame::Error { code, msg } => {
                b.push(TAG_ERROR);
                put_u16(&mut b, *code);
                b.extend_from_slice(msg.as_bytes());
            }
        }
        let len = (b.len() - 4) as u32;
        b[..4].copy_from_slice(&len.to_le_bytes());
        b
    }

    /// Parse one frame body (tag byte already split off).
    pub fn decode(tag: u8, p: &[u8]) -> Result<Frame, WireError> {
        let need = |n: usize| -> Result<(), WireError> {
            if p.len() < n {
                Err(WireError::Malformed(format!(
                    "tag {tag:#x}: payload {} < {n} bytes", p.len())))
            } else {
                Ok(())
            }
        };
        match tag {
            TAG_HELLO => {
                need(8)?;
                let token = std::str::from_utf8(&p[8..])
                    .map_err(|_| WireError::Malformed(
                        "HELLO token is not UTF-8".into()))?;
                Ok(Frame::Hello { token: token.to_string(),
                                  device_id: get_u64(p) })
            }
            TAG_SAMPLES_F32 => {
                if p.len() % 4 != 0 {
                    return Err(WireError::Malformed(
                        "SAMPLES_F32 payload not a multiple of 4".into()));
                }
                Ok(Frame::SamplesF32(
                    p.chunks_exact(4)
                        .map(|c| f32::from_bits(get_u32(c)))
                        .collect()))
            }
            TAG_SAMPLES_I8 =>
                Ok(Frame::SamplesI8(p.iter().map(|&b| b as i8).collect())),
            TAG_SUBSCRIBE_STATS => Ok(Frame::SubscribeStats),
            TAG_GOODBYE => Ok(Frame::Goodbye),
            TAG_WELCOME => {
                need(16)?;
                Ok(Frame::Welcome { session: get_u64(p),
                                    hop: get_u32(&p[8..]),
                                    frame_len: get_u32(&p[12..]) })
            }
            TAG_DIAGNOSIS => {
                need(17)?;
                Ok(Frame::Diagnosis {
                    window: get_u64(p),
                    logits: [get_i32(&p[8..]), get_i32(&p[12..])],
                    is_va: p[16] != 0,
                })
            }
            TAG_STATS => {
                need(40)?;
                Ok(Frame::Stats { sessions: get_u64(p),
                                  windows: get_u64(&p[8..]),
                                  samples: get_u64(&p[16..]),
                                  busy: get_u64(&p[24..]),
                                  evicted: get_u64(&p[32..]) })
            }
            TAG_BUSY => {
                need(4)?;
                Ok(Frame::Busy { dropped: get_u32(p) })
            }
            TAG_ERROR => {
                need(2)?;
                Ok(Frame::Error {
                    code: get_u16(p),
                    msg: String::from_utf8_lossy(&p[2..]).into_owned(),
                })
            }
            _ => Err(WireError::Malformed(format!("unknown tag {tag:#x}"))),
        }
    }

    /// Read exactly one frame. The length prefix is validated against
    /// `max` *before* any payload allocation, so a hostile prefix
    /// cannot commit memory.
    pub fn read_frame(r: &mut impl Read, max: usize)
                      -> Result<Frame, WireError> {
        let mut hdr = [0u8; 4];
        r.read_exact(&mut hdr)?;
        let len = u32::from_le_bytes(hdr);
        if len == 0 {
            return Err(WireError::Malformed("zero-length frame".into()));
        }
        if len as usize > max {
            return Err(WireError::Oversized(len));
        }
        let mut body = vec![0u8; len as usize];
        r.read_exact(&mut body)?;
        decode(body[0], &body[1..])
    }

    /// Write one frame (no flush — callers own buffering policy).
    pub fn write_frame(w: &mut impl Write, f: &Frame) -> io::Result<()> {
        w.write_all(&encode(f))
    }
}

/// Tunables for [`NetServer`]. All bounds are hard: the server never
/// buffers unboundedly on behalf of a client.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; use port 0 for an OS-assigned loopback port.
    pub addr: String,
    /// Accept-loop shards sharing one listener.
    pub accept_shards: usize,
    /// Session-worker shards (each owns the `StreamSession`s whose
    /// device id hashes to it).
    pub workers: usize,
    /// Shared auth token expected in HELLO.
    pub token: String,
    /// Window advance in samples for every session.
    pub hop: usize,
    /// Connection pool size; further connects get `ERR_CAPACITY`.
    pub max_conns: usize,
    /// Per-session inbound budget in *samples*; a frame that would
    /// exceed it is shed whole with a BUSY frame.
    pub max_inflight_samples: usize,
    /// Per-connection outbound queue depth in frames; a full queue on
    /// a diagnosis push evicts the (slow) reader.
    pub outbound_frames: usize,
    /// Per-IP connects allowed per `per_ip_window`; 0 = unlimited.
    pub per_ip_burst: usize,
    pub per_ip_window: Duration,
    /// Frame-size ceiling (length-prefix validation bound).
    pub max_frame_bytes: usize,
    /// STATS push cadence for subscribed sessions.
    pub stats_interval: Duration,
    /// Deterministic fault schedule ([`FaultKind::WorkerPanic`] kills
    /// the matching session-worker shard). Defaults to no faults.
    pub fault_plan: FaultPlan,
}

impl ServeConfig {
    /// Loopback defaults used by tests, the bench, and `--loadgen`.
    pub fn loopback(token: &str, hop: usize) -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            accept_shards: 2,
            workers: std::thread::available_parallelism()
                .map(|n| n.get()).unwrap_or(4),
            token: token.into(),
            hop,
            max_conns: 2048,
            max_inflight_samples: 4 * crate::REC_LEN,
            outbound_frames: 64,
            per_ip_burst: 0,
            per_ip_window: Duration::from_secs(1),
            max_frame_bytes: wire::MAX_FRAME_BYTES,
            stats_interval: Duration::from_millis(200),
            fault_plan: FaultPlan::none(),
        }
    }
}

#[derive(Default)]
struct Counters {
    accepted: AtomicU64,
    closed: AtomicU64,
    rejected_capacity: AtomicU64,
    rejected_rate: AtomicU64,
    rejected_auth: AtomicU64,
    protocol_errors: AtomicU64,
    busy_frames: AtomicU64,
    evicted_slow: AtomicU64,
    evicted_super: AtomicU64,
    worker_respawns: AtomicU64,
    windows: AtomicU64,
    samples: AtomicU64,
}

/// Point-in-time server counters (all monotonic except `conns` /
/// `sessions`).
#[derive(Debug, Clone, Copy, Default)]
pub struct NetStats {
    pub conns: usize,
    pub sessions: usize,
    /// High-water mark of concurrently open sessions.
    pub peak_sessions: usize,
    pub accepted: u64,
    pub closed: u64,
    pub rejected_capacity: u64,
    pub rejected_rate: u64,
    pub rejected_auth: u64,
    pub protocol_errors: u64,
    pub busy_frames: u64,
    /// Sessions evicted for client misbehavior (outbound overflow,
    /// wire code [`wire::ERR_SLOW_READER`]).
    pub evicted_slow: u64,
    /// Sessions evicted because their worker shard restarted after a
    /// panic (wire code [`wire::ERR_EVICTED`]).
    pub evicted_super: u64,
    /// Session-worker incarnations respawned by the supervisor.
    pub worker_respawns: u64,
    pub windows: u64,
    pub samples: u64,
}

struct Shared {
    cfg: ServeConfig,
    cm: Arc<CompiledModel>,
    /// False once shutdown begins: acceptors exit, readers stop
    /// ingesting, workers drain.
    open: AtomicBool,
    conns: AtomicUsize,
    sessions: AtomicUsize,
    peak_sessions: AtomicUsize,
    next_session: AtomicU64,
    ctr: Counters,
    /// Per-IP connect timestamps within the rate window.
    rate: Mutex<HashMap<IpAddr, Vec<Instant>>>,
    /// Live session sockets — the drain path half-closes these, the
    /// eviction path full-closes them.
    socks: Mutex<HashMap<u64, TcpStream>>,
    /// Sessions subscribed to STATS pushes.
    subs: Mutex<HashMap<u64, SyncSender<wire::Frame>>>,
}

enum SubmitMsg {
    Open { session: u64, out: SyncSender<wire::Frame>,
           inflight: Arc<AtomicUsize> },
    Analog { session: u64, samples: Vec<f64> },
    Quantized { session: u64, q: Vec<i8> },
    Close { session: u64 },
}

/// Reserve `n` samples of a session's inbound budget; false (no
/// change) if that would exceed `cap`. A single frame larger than
/// `cap` therefore *always* sheds — deterministic BUSY for tests.
fn reserve(inflight: &AtomicUsize, n: usize, cap: usize) -> bool {
    let mut cur = inflight.load(Ordering::SeqCst);
    loop {
        if cur + n > cap {
            return false;
        }
        match inflight.compare_exchange(cur, cur + n, Ordering::SeqCst,
                                        Ordering::SeqCst) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

struct DeviceSession {
    sess: StreamSession,
    out: SyncSender<wire::Frame>,
    inflight: Arc<AtomicUsize>,
    window: u64,
}

/// Supervised session-worker shard: each incarnation pumps the submit
/// channel inside a panic boundary. A panic (injected via
/// [`FaultKind::WorkerPanic`] or a real bug) loses that incarnation's
/// sessions — the supervisor evicts each one with an explicit
/// [`wire::ERR_EVICTED`] ERROR frame, then respawns the pump after a
/// jittered exponential backoff. The session map lives OUTSIDE the
/// panic boundary (behind a poison-recovered mutex) precisely so the
/// supervisor can still enumerate the casualties.
fn worker_loop(shared: Arc<Shared>, rx: Receiver<SubmitMsg>, widx: usize) {
    let sessions: Mutex<HashMap<u64, DeviceSession>> =
        Mutex::new(HashMap::new());
    let mut planned: VecDeque<u64> = shared.cfg.fault_plan.faults.iter()
        .filter_map(|f| match f.kind {
            FaultKind::WorkerPanic { shard, after } if shard == widx =>
                Some(after),
            _ => None,
        })
        .collect();
    let mut backoff = Backoff::serving(
        shared.cfg.fault_plan.seed ^ 0x5E12_7E ^ widx as u64);
    loop {
        let panic_after = planned.pop_front();
        match run_caught(|| worker_pump(&shared, &rx, &sessions,
                                        panic_after)) {
            Ok(()) => return, // channel closed: orderly shutdown drain
            Err(_) => {
                let dead: Vec<(u64, DeviceSession)> =
                    lock_ok(&sessions).drain().collect();
                for (id, ds) in dead {
                    shared.sessions.fetch_sub(1, Ordering::SeqCst);
                    shared.ctr.evicted_super.fetch_add(1, Ordering::SeqCst);
                    lock_ok(&shared.subs).remove(&id);
                    let queued = ds.out.try_send(wire::Frame::Error {
                        code: wire::ERR_EVICTED,
                        msg: "session lost: worker restarted".into(),
                    }).is_ok();
                    if let Some(sock) = lock_ok(&shared.socks).remove(&id) {
                        if queued {
                            // reader exits on EOF, the writer drains
                            // the queued ERROR before the socket dies
                            let _ = sock.shutdown(Shutdown::Read);
                        } else {
                            evict_with_error(&sock, wire::ERR_EVICTED,
                                "session lost: worker restarted");
                        }
                    }
                }
                shared.ctr.worker_respawns.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(backoff.next_delay());
            }
        }
    }
}

/// One worker incarnation: drain submit messages until the channel
/// closes. Unwinds (back into [`worker_loop`]) on a real or injected
/// panic; `panic_after` fires AFTER the n-th samples message is fully
/// processed, so its diagnoses are already queued outbound.
fn worker_pump(shared: &Shared, rx: &Receiver<SubmitMsg>,
               sessions: &Mutex<HashMap<u64, DeviceSession>>,
               panic_after: Option<u64>) {
    let mut processed = 0u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            SubmitMsg::Open { session, out, inflight } => {
                // geometry was validated at server spawn; a failure
                // here (OOM-ish) just leaves the session unopened and
                // the connection idle until the client gives up
                if let Ok(sess) = StreamSession::new(
                    Arc::clone(&shared.cm), shared.cfg.hop) {
                    lock_ok(sessions).insert(session, DeviceSession {
                        sess, out, inflight, window: 0,
                    });
                    let n = shared.sessions.fetch_add(1, Ordering::SeqCst) + 1;
                    shared.peak_sessions.fetch_max(n, Ordering::SeqCst);
                }
            }
            SubmitMsg::Analog { session, samples } => {
                let mut map = lock_ok(sessions);
                advance(shared, &mut map, session, samples.len(),
                        |s| s.push(&samples));
                drop(map);
                processed += 1;
                if panic_after == Some(processed) {
                    panic!("injected fault: serve worker panics after \
                            {processed} sample frames");
                }
            }
            SubmitMsg::Quantized { session, q } => {
                let mut map = lock_ok(sessions);
                advance(shared, &mut map, session, q.len(),
                        |s| s.push_quantized(&q));
                drop(map);
                processed += 1;
                if panic_after == Some(processed) {
                    panic!("injected fault: serve worker panics after \
                            {processed} sample frames");
                }
            }
            SubmitMsg::Close { session } => {
                if let Some(ds) = lock_ok(sessions).remove(&session) {
                    shared.sessions.fetch_sub(1, Ordering::SeqCst);
                    // best-effort: the writer flushes this before the
                    // connection handler lets the socket close
                    let _ = ds.out.try_send(wire::Frame::Goodbye);
                }
            }
        }
    }
}

/// Feed one samples chunk through a session, push diagnoses, release
/// the inbound budget, evict on a full outbound queue.
fn advance<F>(shared: &Shared, sessions: &mut HashMap<u64, DeviceSession>,
              session: u64, n: usize, run: F)
where
    F: FnOnce(&mut StreamSession) -> Vec<super::detector::Detection>,
{
    // None = healthy, Some(true) = slow reader, Some(false) = gone
    let mut kill: Option<bool> = None;
    if let Some(ds) = sessions.get_mut(&session) {
        let dets = run(&mut ds.sess);
        ds.inflight.fetch_sub(n, Ordering::SeqCst);
        shared.ctr.samples.fetch_add(n as u64, Ordering::SeqCst);
        shared.ctr.windows.fetch_add(dets.len() as u64, Ordering::SeqCst);
        for d in dets {
            let frame = wire::Frame::Diagnosis {
                window: ds.window, logits: d.logits, is_va: d.is_va,
            };
            ds.window += 1;
            match ds.out.try_send(frame) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    kill = Some(true);
                    break;
                }
                Err(TrySendError::Disconnected(_)) => {
                    kill = Some(false);
                    break;
                }
            }
        }
    }
    if let Some(slow) = kill {
        sessions.remove(&session);
        shared.sessions.fetch_sub(1, Ordering::SeqCst);
        if slow {
            // the reader can't keep up with its own diagnoses: drop
            // the connection rather than buffer without bound. The
            // outbound queue is full, so the ERROR goes straight onto
            // the socket — distinct code from supervisor eviction.
            shared.ctr.evicted_slow.fetch_add(1, Ordering::SeqCst);
            if let Some(sock) = lock_ok(&shared.socks).remove(&session) {
                evict_with_error(&sock, wire::ERR_SLOW_READER,
                    "evicted: outbound queue overflow (slow reader)");
            }
        }
    }
}

/// Best-effort terminal ERROR written straight onto the socket —
/// bypassing the per-connection outbound queue, which is full or
/// abandoned — then a full close. The direct write may interleave
/// with a writer-thread frame already in flight; the client must
/// treat a garbled tail before EOF as a close, which the wire decoder
/// already guarantees (it surfaces `WireError`, never panics).
fn evict_with_error(sock: &TcpStream, code: u16, msg: &str) {
    if let Ok(mut s) = sock.try_clone() {
        let _ = s.set_write_timeout(Some(Duration::from_millis(250)));
        let _ = wire::write_frame(&mut s, &wire::Frame::Error {
            code, msg: msg.into(),
        });
    }
    let _ = sock.shutdown(Shutdown::Both);
}

fn writer_loop(sock: TcpStream, rx: Receiver<wire::Frame>) {
    let mut w = BufWriter::new(sock);
    while let Ok(f) = rx.recv() {
        if wire::write_frame(&mut w, &f).is_err() {
            return;
        }
        // batch whatever is already queued before paying one flush
        while let Ok(f) = rx.try_recv() {
            if wire::write_frame(&mut w, &f).is_err() {
                return;
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
    // all senders gone: orderly half-close so the peer sees EOF
    if let Ok(sock) = w.into_inner() {
        let _ = sock.shutdown(Shutdown::Write);
    }
}

/// Synchronous pre-handshake rejection (capacity / rate limit): one
/// ERROR frame with a short write timeout, then close.
fn reject(stream: TcpStream, code: u16, msg: &str) {
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let mut s = stream;
    let _ = wire::write_frame(&mut s, &wire::Frame::Error {
        code, msg: msg.into(),
    });
    let _ = s.shutdown(Shutdown::Both);
}

fn rate_ok(shared: &Shared, ip: IpAddr) -> bool {
    let now = Instant::now();
    let mut map = lock_ok(&shared.rate);
    let hits = map.entry(ip).or_default();
    hits.retain(|t| now.duration_since(*t) < shared.cfg.per_ip_window);
    if hits.len() >= shared.cfg.per_ip_burst {
        return false;
    }
    hits.push(now);
    true
}

fn accept_loop(shared: Arc<Shared>, listener: Arc<TcpListener>,
               workers: Vec<Sender<SubmitMsg>>) {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(_) => {
                if !shared.open.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if !shared.open.load(Ordering::SeqCst) {
            // the shutdown path dials once per acceptor to unblock
            // accept(); drop the wakeup connection and exit
            return;
        }
        if shared.cfg.per_ip_burst > 0 && !rate_ok(&shared, peer.ip()) {
            shared.ctr.rejected_rate.fetch_add(1, Ordering::SeqCst);
            reject(stream, wire::ERR_RATE_LIMITED, "connect rate limit");
            continue;
        }
        if shared.conns.fetch_add(1, Ordering::SeqCst)
            >= shared.cfg.max_conns {
            shared.conns.fetch_sub(1, Ordering::SeqCst);
            shared.ctr.rejected_capacity.fetch_add(1, Ordering::SeqCst);
            reject(stream, wire::ERR_CAPACITY, "connection pool full");
            continue;
        }
        shared.ctr.accepted.fetch_add(1, Ordering::SeqCst);
        let sh = Arc::clone(&shared);
        let wk = workers.clone();
        if std::thread::Builder::new()
            .name("va-serve-conn".into())
            .stack_size(SMALL_STACK)
            .spawn(move || handle_conn(sh, stream, wk))
            .is_err()
        {
            shared.conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

fn protocol_reject(shared: &Shared, otx: &SyncSender<wire::Frame>,
                   e: &wire::WireError) {
    shared.ctr.protocol_errors.fetch_add(1, Ordering::SeqCst);
    let _ = otx.send(wire::Frame::Error {
        code: wire::ERR_PROTOCOL, msg: e.to_string(),
    });
}

/// Reader side of one connection: handshake, then frames → worker
/// shard. Returns the opened (session, worker index), if any, for
/// teardown.
fn drive_conn(shared: &Arc<Shared>, stream: &TcpStream,
              otx: SyncSender<wire::Frame>, workers: &[Sender<SubmitMsg>])
              -> Option<(u64, usize)> {
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return None,
    };

    // HELLO must arrive promptly; afterwards a session may idle
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let device_id = match wire::read_frame(&mut reader,
                                           shared.cfg.max_frame_bytes) {
        Ok(wire::Frame::Hello { token, device_id }) => {
            if token != shared.cfg.token {
                shared.ctr.rejected_auth.fetch_add(1, Ordering::SeqCst);
                let _ = otx.send(wire::Frame::Error {
                    code: wire::ERR_AUTH, msg: "bad token".into(),
                });
                return None;
            }
            device_id
        }
        Ok(_) => {
            shared.ctr.protocol_errors.fetch_add(1, Ordering::SeqCst);
            let _ = otx.send(wire::Frame::Error {
                code: wire::ERR_PROTOCOL, msg: "expected HELLO".into(),
            });
            return None;
        }
        Err(e) => {
            if !e.is_io() {
                protocol_reject(shared, &otx, &e);
            }
            return None;
        }
    };
    let _ = stream.set_read_timeout(None);

    let session = shared.next_session.fetch_add(1, Ordering::SeqCst);
    let widx = ((device_id ^ (device_id >> 32))
        % workers.len() as u64) as usize;
    let inflight = Arc::new(AtomicUsize::new(0));
    if let Ok(sock) = stream.try_clone() {
        lock_ok(&shared.socks).insert(session, sock);
    }
    if workers[widx].send(SubmitMsg::Open {
        session, out: otx.clone(), inflight: Arc::clone(&inflight),
    }).is_err() {
        let _ = otx.send(wire::Frame::Error {
            code: wire::ERR_SHUTTING_DOWN, msg: "server draining".into(),
        });
        lock_ok(&shared.socks).remove(&session);
        return None;
    }
    let _ = otx.send(wire::Frame::Welcome {
        session,
        hop: shared.cfg.hop as u32,
        frame_len: shared.cm.schedule.l_in as u32,
    });

    let opened = Some((session, widx));
    let cap = shared.cfg.max_inflight_samples;
    loop {
        let frame = match wire::read_frame(&mut reader,
                                           shared.cfg.max_frame_bytes) {
            Ok(f) => f,
            Err(e) => {
                // Io covers clean close, half-close, reset, and the
                // drain path's shutdown(Read) — all mean "stop
                // reading"; anything else is the peer's fault
                if !e.is_io() {
                    protocol_reject(shared, &otx, &e);
                }
                return opened;
            }
        };
        match frame {
            wire::Frame::SamplesF32(v) => {
                let n = v.len();
                if !reserve(&inflight, n, cap) {
                    shared.ctr.busy_frames.fetch_add(1, Ordering::SeqCst);
                    if otx.send(wire::Frame::Busy {
                        dropped: n as u32 }).is_err() {
                        return opened;
                    }
                    continue;
                }
                let samples: Vec<f64> =
                    v.iter().map(|&x| x as f64).collect();
                if workers[widx].send(SubmitMsg::Analog {
                    session, samples }).is_err() {
                    return opened;
                }
            }
            wire::Frame::SamplesI8(q) => {
                let n = q.len();
                if !reserve(&inflight, n, cap) {
                    shared.ctr.busy_frames.fetch_add(1, Ordering::SeqCst);
                    if otx.send(wire::Frame::Busy {
                        dropped: n as u32 }).is_err() {
                        return opened;
                    }
                    continue;
                }
                if workers[widx].send(SubmitMsg::Quantized {
                    session, q }).is_err() {
                    return opened;
                }
            }
            wire::Frame::SubscribeStats => {
                lock_ok(&shared.subs).insert(session, otx.clone());
            }
            wire::Frame::Goodbye => return opened,
            _ => {
                shared.ctr.protocol_errors.fetch_add(1, Ordering::SeqCst);
                let _ = otx.send(wire::Frame::Error {
                    code: wire::ERR_PROTOCOL,
                    msg: "unexpected client frame".into(),
                });
                return opened;
            }
        }
    }
}

fn handle_conn(shared: Arc<Shared>, stream: TcpStream,
               workers: Vec<Sender<SubmitMsg>>) {
    let _ = stream.set_nodelay(true);
    let (otx, orx) = sync_channel(shared.cfg.outbound_frames);
    let writer = match stream.try_clone() {
        Ok(ws) => std::thread::Builder::new()
            .name("va-serve-writer".into())
            .stack_size(SMALL_STACK)
            .spawn(move || writer_loop(ws, orx))
            .ok(),
        Err(_) => None,
    };

    let opened = drive_conn(&shared, &stream, otx, &workers);

    if let Some((session, widx)) = opened {
        lock_ok(&shared.subs).remove(&session);
        lock_ok(&shared.socks).remove(&session);
        // Close rides the same FIFO channel as queued Samples, so
        // every in-flight diagnosis is pushed before Goodbye and the
        // worker's outbound clone drops last
        let _ = workers[widx].send(SubmitMsg::Close { session });
    }
    // the writer exits once every SyncSender clone is gone (reader's,
    // the stats subscription's, the worker's) — joining here keeps the
    // final Goodbye/ERROR flush inside the connection's lifetime
    if let Some(w) = writer {
        let _ = w.join();
    }
    let _ = stream.shutdown(Shutdown::Both);
    shared.ctr.closed.fetch_add(1, Ordering::SeqCst);
    shared.conns.fetch_sub(1, Ordering::SeqCst);
}

fn stats_loop(shared: Arc<Shared>) {
    let slice = Duration::from_millis(25);
    let mut since_push = Duration::ZERO;
    loop {
        if !shared.open.load(Ordering::SeqCst)
            && shared.conns.load(Ordering::SeqCst) == 0 {
            return;
        }
        std::thread::sleep(slice);
        since_push += slice;
        if since_push < shared.cfg.stats_interval {
            continue;
        }
        since_push = Duration::ZERO;
        let frame = wire::Frame::Stats {
            sessions: shared.sessions.load(Ordering::SeqCst) as u64,
            windows: shared.ctr.windows.load(Ordering::SeqCst),
            samples: shared.ctr.samples.load(Ordering::SeqCst),
            busy: shared.ctr.busy_frames.load(Ordering::SeqCst),
            evicted: shared.ctr.evicted_slow.load(Ordering::SeqCst),
        };
        lock_ok(&shared.subs).retain(|_, tx| {
            match tx.try_send(frame.clone()) {
                Ok(()) => true,
                // stats are droppable — a momentarily full queue is
                // not an eviction offense (diagnosis pushes are)
                Err(TrySendError::Full(_)) => true,
                Err(TrySendError::Disconnected(_)) => false,
            }
        });
    }
}

/// A running TCP serving front end. Dropping without
/// [`NetServer::shutdown`] leaks the listener threads for the process
/// lifetime — always shut down.
pub struct NetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptors: Vec<JoinHandle<()>>,
    workers_tx: Vec<Sender<SubmitMsg>>,
    workers: Vec<JoinHandle<()>>,
    stats_thread: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind and start accepting. Fails fast (before accepting
    /// anything) on an unbindable address, a zero shard count, or a
    /// hop/model geometry `StreamSession` would reject per-connection.
    pub fn spawn(cfg: ServeConfig, cm: Arc<CompiledModel>) -> Result<Self> {
        anyhow::ensure!(cfg.accept_shards >= 1, "need ≥1 accept shard");
        anyhow::ensure!(cfg.workers >= 1, "need ≥1 session worker");
        anyhow::ensure!(cfg.max_conns >= 1, "need ≥1 connection slot");
        anyhow::ensure!(cfg.max_inflight_samples >= 1,
                        "need a ≥1-sample inbound budget");
        anyhow::ensure!(cfg.outbound_frames >= 1,
                        "need a ≥1-frame outbound queue");
        // probe session: surface bad hop / head geometry at spawn,
        // not as a per-connection mystery
        StreamSession::new(Arc::clone(&cm), cfg.hop)
            .context("serve config incompatible with model")?;

        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let listener = Arc::new(listener);
        let shared = Arc::new(Shared {
            cfg, cm,
            open: AtomicBool::new(true),
            conns: AtomicUsize::new(0),
            sessions: AtomicUsize::new(0),
            peak_sessions: AtomicUsize::new(0),
            next_session: AtomicU64::new(1),
            ctr: Counters::default(),
            rate: Mutex::new(HashMap::new()),
            socks: Mutex::new(HashMap::new()),
            subs: Mutex::new(HashMap::new()),
        });

        let mut workers_tx = Vec::with_capacity(shared.cfg.workers);
        let mut workers = Vec::with_capacity(shared.cfg.workers);
        for i in 0..shared.cfg.workers {
            let (tx, rx) = channel();
            let sh = Arc::clone(&shared);
            workers.push(std::thread::Builder::new()
                .name(format!("va-serve-worker-{i}"))
                .spawn(move || worker_loop(sh, rx, i))?);
            workers_tx.push(tx);
        }
        let mut acceptors = Vec::with_capacity(shared.cfg.accept_shards);
        for i in 0..shared.cfg.accept_shards {
            let sh = Arc::clone(&shared);
            let ls = Arc::clone(&listener);
            let wk = workers_tx.clone();
            acceptors.push(std::thread::Builder::new()
                .name(format!("va-serve-accept-{i}"))
                .spawn(move || accept_loop(sh, ls, wk))?);
        }
        let stats_thread = {
            let sh = Arc::clone(&shared);
            Some(std::thread::Builder::new()
                .name("va-serve-stats".into())
                .spawn(move || stats_loop(sh))?)
        };
        Ok(Self { shared, addr, acceptors, workers_tx, workers,
                  stats_thread })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> NetStats {
        let s = &self.shared;
        NetStats {
            conns: s.conns.load(Ordering::SeqCst),
            sessions: s.sessions.load(Ordering::SeqCst),
            peak_sessions: s.peak_sessions.load(Ordering::SeqCst),
            accepted: s.ctr.accepted.load(Ordering::SeqCst),
            closed: s.ctr.closed.load(Ordering::SeqCst),
            rejected_capacity: s.ctr.rejected_capacity.load(Ordering::SeqCst),
            rejected_rate: s.ctr.rejected_rate.load(Ordering::SeqCst),
            rejected_auth: s.ctr.rejected_auth.load(Ordering::SeqCst),
            protocol_errors: s.ctr.protocol_errors.load(Ordering::SeqCst),
            busy_frames: s.ctr.busy_frames.load(Ordering::SeqCst),
            evicted_slow: s.ctr.evicted_slow.load(Ordering::SeqCst),
            evicted_super: s.ctr.evicted_super.load(Ordering::SeqCst),
            worker_respawns: s.ctr.worker_respawns.load(Ordering::SeqCst),
            windows: s.ctr.windows.load(Ordering::SeqCst),
            samples: s.ctr.samples.load(Ordering::SeqCst),
        }
    }

    /// Graceful drain: stop accepting, half-close every session's read
    /// side (queued samples still produce diagnoses), wait for
    /// connections to finish (bounded), then join workers.
    pub fn shutdown(mut self) -> NetStats {
        self.shared.open.store(false, Ordering::SeqCst);
        // one wakeup dial per acceptor blocked in accept()
        for _ in 0..self.acceptors.len() {
            let _ = TcpStream::connect(self.addr);
        }
        for a in self.acceptors.drain(..) {
            let _ = a.join();
        }
        // repeat the half-close: connections mid-handshake register
        // their socket after our first pass
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            for sock in lock_ok(&self.shared.socks).values() {
                let _ = sock.shutdown(Shutdown::Read);
            }
            if self.shared.conns.load(Ordering::SeqCst) == 0
                || Instant::now() > deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // master senders drop → workers drain remaining Close msgs
        // and exit
        self.workers_tx.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(s) = self.stats_thread.take() {
            let _ = s.join();
        }
        self.stats()
    }
}

/// Minimal synchronous client for one device connection — used by the
/// loadgen, the CLI loopback mode, and the wire tests.
pub struct DeviceClient {
    sock: TcpStream,
    reader: BufReader<TcpStream>,
    max_frame: usize,
    pub session: u64,
    pub hop: u32,
    pub frame_len: u32,
}

impl DeviceClient {
    pub fn connect(addr: SocketAddr, token: &str, device_id: u64)
                   -> Result<Self> {
        Self::handshake(TcpStream::connect(addr)?, token, device_id)
    }

    /// Connect with retry and jittered exponential backoff — under a
    /// synchronized 1000-client ramp the listener backlog overflows
    /// transiently and the OS refuses or resets; retrying is part of
    /// the protocol. The jitter is deterministic per device id, so
    /// retrying devices desynchronize instead of stampeding in phase.
    pub fn connect_retry(addr: SocketAddr, token: &str, device_id: u64,
                         tries: usize) -> Result<Self> {
        let mut backoff = Backoff::new(Duration::from_millis(5),
                                       Duration::from_millis(250),
                                       device_id ^ 0xD1A7);
        let mut last = None;
        for _ in 0..tries.max(1) {
            match TcpStream::connect(addr)
                .map_err(anyhow::Error::from)
                .and_then(|s| Self::handshake(s, token, device_id)) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(backoff.next_delay());
                }
            }
        }
        Err(last.unwrap())
    }

    fn handshake(sock: TcpStream, token: &str, device_id: u64)
                 -> Result<Self> {
        sock.set_nodelay(true)?;
        let mut sock = sock;
        wire::write_frame(&mut sock, &wire::Frame::Hello {
            token: token.into(), device_id,
        })?;
        let mut reader = BufReader::new(sock.try_clone()?);
        let _ = sock.set_read_timeout(Some(Duration::from_secs(30)));
        match wire::read_frame(&mut reader, wire::MAX_FRAME_BYTES)? {
            wire::Frame::Welcome { session, hop, frame_len } => {
                let _ = sock.set_read_timeout(None);
                Ok(Self { sock, reader, max_frame: wire::MAX_FRAME_BYTES,
                          session, hop, frame_len })
            }
            wire::Frame::Error { code, msg } =>
                anyhow::bail!("server rejected (code {code}): {msg}"),
            f => anyhow::bail!("unexpected handshake frame: {f:?}"),
        }
    }

    pub fn send_f32(&mut self, v: &[f32]) -> Result<()> {
        wire::write_frame(&mut self.sock,
                          &wire::Frame::SamplesF32(v.to_vec()))?;
        Ok(())
    }

    pub fn send_i8(&mut self, q: &[i8]) -> Result<()> {
        wire::write_frame(&mut self.sock,
                          &wire::Frame::SamplesI8(q.to_vec()))?;
        Ok(())
    }

    pub fn subscribe_stats(&mut self) -> Result<()> {
        wire::write_frame(&mut self.sock, &wire::Frame::SubscribeStats)?;
        Ok(())
    }

    /// Escape hatch for protocol-abuse tests: raw bytes, no framing.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.sock.write_all(bytes)?;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<wire::Frame, wire::WireError> {
        wire::read_frame(&mut self.reader, self.max_frame)
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.sock.set_read_timeout(d)?;
        Ok(())
    }

    /// Orderly close: GOODBYE, then read until the server's GOODBYE
    /// (or EOF) so the drain is observed, not assumed.
    pub fn finish(mut self) -> Result<()> {
        wire::write_frame(&mut self.sock, &wire::Frame::Goodbye)?;
        let _ = self.sock.set_read_timeout(Some(Duration::from_secs(5)));
        loop {
            match self.recv() {
                Ok(wire::Frame::Goodbye) | Err(_) => return Ok(()),
                Ok(_) => {}
            }
        }
    }
}

/// How many complete windows a stream of `n` samples yields.
fn windows_done(n: usize, frame_len: usize, hop: usize) -> u64 {
    if n < frame_len { 0 } else { (1 + (n - frame_len) / hop) as u64 }
}

/// One end-to-end window verdict from [`ResilientDevice::push`].
/// `window` is the index in the device's *whole* sample history —
/// already deduplicated across reconnect replays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowDiag {
    pub window: u64,
    pub logits: [i32; 2],
    pub is_va: bool,
}

/// Self-healing device connection: a [`DeviceClient`] that survives
/// server-side faults. On any failure — read timeout, connection
/// reset, supervisor eviction ([`wire::ERR_EVICTED`]) — it reconnects
/// with jittered exponential backoff and **replays its full sample
/// history** on the fresh session. Because a replayed session
/// recomputes the same windows (streaming is deterministic) and every
/// DIAGNOSIS frame carries its window index, replayed duplicates are
/// recognized and swallowed: the caller sees every window's verdict
/// exactly once, in order, no matter how many times the session died.
///
/// Push window-aligned chunks (first `frame_len` samples, then `hop`
/// per call) as the loadgen does; the lock-step send/await keeps at
/// most one un-acknowledged chunk in flight so a BUSY shed is always
/// attributable to the chunk just sent.
pub struct ResilientDevice {
    addr: SocketAddr,
    token: String,
    device_id: u64,
    client: Option<DeviceClient>,
    hop: usize,
    frame_len: usize,
    /// Every sample ever pushed — the replay source.
    history: Vec<i8>,
    /// Samples sent on the CURRENT connection.
    sent: usize,
    /// Start of the last chunk sent (BUSY rollback point).
    last_chunk_start: usize,
    /// Diagnoses received on the CURRENT connection.
    recv_on_conn: u64,
    /// Diagnoses handed to the caller — the dedupe horizon: a
    /// replayed DIAGNOSIS with `window < delivered` is a duplicate.
    delivered: u64,
    backoff: Backoff,
    read_timeout: Duration,
    /// Reconnect attempts per `push` before giving up.
    max_reconnects: usize,
    pub reconnects: u64,
    pub replayed_windows: u64,
    pub busy_retries: u64,
}

impl ResilientDevice {
    pub fn connect(addr: SocketAddr, token: &str, device_id: u64)
                   -> Result<Self> {
        let mut me = Self {
            addr,
            token: token.to_string(),
            device_id,
            client: None,
            hop: 0,
            frame_len: 0,
            history: Vec::new(),
            sent: 0,
            last_chunk_start: 0,
            recv_on_conn: 0,
            delivered: 0,
            backoff: Backoff::serving(device_id ^ 0xDEC1CE),
            read_timeout: Duration::from_secs(30),
            max_reconnects: 8,
            reconnects: 0,
            replayed_windows: 0,
            busy_retries: 0,
        };
        me.reconnect()?;
        Ok(me)
    }

    pub fn hop(&self) -> usize { self.hop }
    pub fn frame_len(&self) -> usize { self.frame_len }
    /// Total windows delivered to the caller so far.
    pub fn delivered(&self) -> u64 { self.delivered }

    fn reconnect(&mut self) -> Result<()> {
        let c = DeviceClient::connect_retry(self.addr, &self.token,
                                            self.device_id, 40)?;
        c.set_read_timeout(Some(self.read_timeout))?;
        self.hop = c.hop as usize;
        self.frame_len = c.frame_len as usize;
        self.client = Some(c);
        self.sent = 0;
        self.last_chunk_start = 0;
        self.recv_on_conn = 0;
        Ok(())
    }

    /// Stream `chunk` and return the *new* diagnoses it completes.
    /// Transparent across faults: on failure the connection is
    /// rebuilt (backoff), the history replayed, duplicates swallowed.
    pub fn push(&mut self, chunk: &[i8]) -> Result<Vec<WindowDiag>> {
        self.history.extend_from_slice(chunk);
        let want = windows_done(self.history.len(), self.frame_len,
                                self.hop);
        let mut out = Vec::new();
        let mut attempts = 0usize;
        while self.delivered < want || self.sent < self.history.len() {
            if self.client.is_none() {
                anyhow::ensure!(attempts < self.max_reconnects,
                    "device {}: gave up after {attempts} reconnects",
                    self.device_id);
                attempts += 1;
                self.reconnects += 1;
                std::thread::sleep(self.backoff.next_delay());
                if self.reconnect().is_err() {
                    continue;
                }
            }
            if self.drive(&mut out).is_err() {
                self.client = None; // next loop: backoff + replay
            }
        }
        self.backoff.reset(); // healthy round trip
        Ok(out)
    }

    /// Lock-step pump on the current connection: send the next
    /// window-aligned chunk, await the diagnoses it makes due.
    /// `Err(())` means the connection is dead (caller replays).
    fn drive(&mut self, out: &mut Vec<WindowDiag>) -> Result<(), ()> {
        loop {
            let due = windows_done(self.sent, self.frame_len, self.hop);
            if self.recv_on_conn < due {
                self.pump_one(out)?;
                continue;
            }
            if self.sent >= self.history.len() {
                return Ok(());
            }
            let end = if self.sent == 0 {
                self.history.len().min(self.frame_len)
            } else {
                self.history.len().min(self.sent + self.hop)
            };
            let chunk = self.history[self.sent..end].to_vec();
            let c = self.client.as_mut().ok_or(())?;
            if c.send_i8(&chunk).is_err() {
                return Err(());
            }
            self.last_chunk_start = self.sent;
            self.sent = end;
        }
    }

    fn pump_one(&mut self, out: &mut Vec<WindowDiag>) -> Result<(), ()> {
        let c = self.client.as_mut().ok_or(())?;
        match c.recv() {
            Ok(wire::Frame::Diagnosis { window, logits, is_va }) => {
                self.recv_on_conn += 1;
                if window < self.delivered {
                    // replayed duplicate from a pre-fault window
                    self.replayed_windows += 1;
                } else {
                    out.push(WindowDiag {
                        window: self.delivered, logits, is_va,
                    });
                    self.delivered += 1;
                }
                Ok(())
            }
            Ok(wire::Frame::Busy { .. }) => {
                // the chunk just sent was shed whole — roll back and
                // let drive() resend it
                self.busy_retries += 1;
                self.sent = self.last_chunk_start;
                std::thread::sleep(Duration::from_millis(1));
                Ok(())
            }
            Ok(wire::Frame::Stats { .. }) => Ok(()),
            // ERROR (eviction), GOODBYE, EOF, timeout: reconnect
            Ok(_) | Err(_) => Err(()),
        }
    }

    /// Orderly close of the underlying connection, if any.
    pub fn finish(mut self) -> Result<()> {
        match self.client.take() {
            Some(c) => c.finish(),
            None => Ok(()),
        }
    }
}

/// One device's outcome inside [`loadgen`].
struct DeviceOutcome {
    lat: LatencyRecorder,
    windows: u64,
    samples: u64,
    mismatches: u64,
    busy_retries: u64,
    stats_frames: u64,
    elapsed: Duration,
    failed_connect: bool,
}

/// Aggregate loadgen result — the source of `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub conns: usize,
    /// `None` for the synthetic pre-quantized stream; the
    /// [`Family::name`] lane for `--scenario` runs.
    pub scenario: Option<&'static str>,
    pub connect_failures: u64,
    pub windows_per_conn: usize,
    pub total_windows: u64,
    pub total_samples: u64,
    /// Streamed diagnoses that differ from the offline
    /// `StreamSession` oracle — must be 0.
    pub mismatches: u64,
    pub busy_retries: u64,
    pub stats_frames: u64,
    pub elapsed_s: f64,
    pub samples_per_s: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

/// Drive `conns` concurrent device connections through the full wire
/// path against a live server: every device rendezvouses at a barrier
/// *after* connecting (so all sessions are provably concurrent),
/// streams `windows` windows of pre-quantized samples in lockstep
/// (send chunk → await its diagnosis, BUSY → resend), then verifies
/// every received diagnosis against a fresh offline [`StreamSession`]
/// run of the identical sample stream.
pub fn loadgen(addr: SocketAddr, token: &str, cm: Arc<CompiledModel>,
               conns: usize, windows: usize) -> Result<LoadgenReport> {
    loadgen_inner(addr, token, cm, conns, windows, None)
}

/// [`loadgen`] variant that streams adversarial [`crate::data::scenarios`]
/// waveforms — analog f32 wire frames, exercising the full server-side
/// front-end chain — instead of synthetic pre-quantized samples. Each
/// device synthesizes the standard-suite representative of `family` at
/// a device-unique seed derived from `seed`; verification still runs
/// the *identical* (f32-rounded) stream through an offline
/// [`StreamSession`] oracle, so `mismatches` must stay 0 under
/// adversarial inputs too.
pub fn loadgen_scenario(addr: SocketAddr, token: &str,
                        cm: Arc<CompiledModel>, conns: usize,
                        windows: usize, family: Family, seed: u64)
                        -> Result<LoadgenReport> {
    loadgen_inner(addr, token, cm, conns, windows, Some((family, seed)))
}

fn loadgen_inner(addr: SocketAddr, token: &str, cm: Arc<CompiledModel>,
                 conns: usize, windows: usize,
                 scenario: Option<(Family, u64)>) -> Result<LoadgenReport> {
    anyhow::ensure!(conns >= 1 && windows >= 1,
                    "loadgen needs ≥1 connection and ≥1 window");
    let barrier = Arc::new(Barrier::new(conns + 1));
    let mut handles = Vec::with_capacity(conns);
    for d in 0..conns {
        let barrier = Arc::clone(&barrier);
        let cm = Arc::clone(&cm);
        let token = token.to_string();
        handles.push(std::thread::Builder::new()
            .name(format!("va-loadgen-{d}"))
            .stack_size(SMALL_STACK)
            .spawn(move || device_run(addr, &token, cm, d, windows,
                                      &barrier, scenario))
            .context("spawn loadgen device thread")?);
    }
    barrier.wait(); // every device connected (or gave up) — go
    let mut lat = LatencyRecorder::new();
    let mut rep = LoadgenReport {
        conns,
        scenario: scenario.map(|(f, _)| f.name()),
        connect_failures: 0,
        windows_per_conn: windows,
        total_windows: 0,
        total_samples: 0,
        mismatches: 0,
        busy_retries: 0,
        stats_frames: 0,
        elapsed_s: 0.0,
        samples_per_s: 0.0,
        p50_us: 0.0,
        p99_us: 0.0,
        mean_us: 0.0,
    };
    for h in handles {
        let o = h.join().expect("loadgen device thread panicked");
        if o.failed_connect {
            rep.connect_failures += 1;
            continue;
        }
        lat.merge(&o.lat);
        rep.total_windows += o.windows;
        rep.total_samples += o.samples;
        rep.mismatches += o.mismatches;
        rep.busy_retries += o.busy_retries;
        rep.stats_frames += o.stats_frames;
        rep.elapsed_s = rep.elapsed_s.max(o.elapsed.as_secs_f64());
    }
    if rep.elapsed_s > 0.0 {
        rep.samples_per_s = rep.total_samples as f64 / rep.elapsed_s;
    }
    rep.p50_us = lat.percentile_us(50.0);
    rep.p99_us = lat.percentile_us(99.0);
    rep.mean_us = lat.mean_us();
    Ok(rep)
}

/// Deterministic per-device pre-quantized sample stream (range
/// −127..=127, matching the ADC).
fn device_stream(device: usize, n: usize) -> Vec<i8> {
    let mut rng = crate::data::SplitMix64::new(
        0x5EED_0000_0000_0000 ^ (device as u64).wrapping_mul(0x9E3779B9));
    (0..n).map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect()
}

/// The sample source a loadgen device streams: synthetic pre-quantized
/// i8 (wire tag SAMPLES_I8) or an adversarial analog scenario (wire
/// tag SAMPLES_F32, server-side front end).
enum DeviceStream {
    Quantized(Vec<i8>),
    Analog(Vec<f32>),
}

fn device_run(addr: SocketAddr, token: &str, cm: Arc<CompiledModel>,
              device: usize, windows: usize, barrier: &Barrier,
              scenario: Option<(Family, u64)>) -> DeviceOutcome {
    let mut out = DeviceOutcome {
        lat: LatencyRecorder::new(),
        windows: 0,
        samples: 0,
        mismatches: 0,
        busy_retries: 0,
        stats_frames: 0,
        elapsed: Duration::ZERO,
        failed_connect: false,
    };
    // stagger the thundering herd a little; retries absorb the rest
    std::thread::sleep(Duration::from_millis((device as u64 / 64) * 5));
    let client = DeviceClient::connect_retry(addr, token,
                                             device as u64, 40);
    // the barrier must pass regardless of outcome, or everyone hangs
    let mut client = match client {
        Ok(c) => c,
        Err(_) => {
            barrier.wait();
            out.failed_connect = true;
            return out;
        }
    };
    if device == 0 {
        let _ = client.subscribe_stats();
    }
    barrier.wait();

    let frame_len = client.frame_len as usize;
    let hop = client.hop as usize;
    let total = frame_len + hop * (windows - 1);
    let stream = match scenario {
        None => DeviceStream::Quantized(device_stream(device, total)),
        Some((family, seed)) => {
            // device-unique seed: every connection streams a different
            // instance of the same adversarial family
            let segments = (total + crate::REC_LEN - 1) / crate::REC_LEN;
            let scn = Scenario::representative(
                family, seed ^ (device as u64).wrapping_mul(0x9E37_79B9),
                segments);
            DeviceStream::Analog(scn.synthesize().samples[..total]
                .iter().map(|&x| x as f32).collect())
        }
    };
    let _ = client.set_read_timeout(Some(Duration::from_secs(60)));

    let t_run = Instant::now();
    let mut sent = 0usize;
    let mut got: Vec<[i32; 2]> = Vec::with_capacity(windows);
    'windows: for w in 0..windows {
        let (lo, hi) = if w == 0 { (0, frame_len) } else { (sent, sent + hop) };
        let send_chunk = |c: &mut DeviceClient| match &stream {
            DeviceStream::Quantized(q) => c.send_i8(&q[lo..hi]),
            DeviceStream::Analog(a) => c.send_f32(&a[lo..hi]),
        };
        let t0 = Instant::now();
        let mut tries = 0u32;
        if send_chunk(&mut client).is_err() {
            break 'windows;
        }
        loop {
            match client.recv() {
                Ok(wire::Frame::Diagnosis { logits, .. }) => {
                    out.lat.push(t0.elapsed());
                    got.push(logits);
                    break;
                }
                Ok(wire::Frame::Busy { .. }) => {
                    // whole frame shed — resend (bounded)
                    out.busy_retries += 1;
                    tries += 1;
                    if tries > 1000 {
                        break 'windows;
                    }
                    std::thread::sleep(Duration::from_micros(
                        200 * (device % 7 + 1) as u64));
                    if send_chunk(&mut client).is_err() {
                        break 'windows;
                    }
                }
                Ok(wire::Frame::Stats { .. }) => out.stats_frames += 1,
                Ok(_) | Err(_) => break 'windows,
            }
        }
        sent = hi;
    }
    out.elapsed = t_run.elapsed();
    out.samples = sent as u64;
    out.windows = got.len() as u64;
    let _ = client.finish();

    // offline oracle — AFTER the timed phase so verification cost
    // never pollutes the latency/throughput numbers. The analog lane
    // replays the f32-rounded wire values, exactly what the server saw.
    let mut oracle = StreamSession::new(cm, hop)
        .expect("oracle session (geometry validated at server spawn)");
    let want: Vec<[i32; 2]> = match &stream {
        DeviceStream::Quantized(q) => oracle.push_quantized(&q[..sent]),
        DeviceStream::Analog(a) => {
            let f: Vec<f64> = a[..sent].iter().map(|&x| x as f64).collect();
            oracle.push(&f)
        }
    }.into_iter().map(|d| d.logits).collect();
    if got.len() != want.len() {
        out.mismatches += got.len().abs_diff(want.len()) as u64;
    }
    out.mismatches += got.iter().zip(&want)
        .filter(|(g, w)| g != w).count() as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: wire::Frame) {
        let bytes = wire::encode(&f);
        let got = wire::read_frame(&mut &bytes[..], wire::MAX_FRAME_BYTES)
            .expect("decode");
        assert_eq!(got, f);
    }

    #[test]
    fn wire_round_trips_every_frame() {
        round_trip(wire::Frame::Hello {
            token: "sekrit".into(), device_id: 0xDEAD_BEEF_0BAD_F00D });
        round_trip(wire::Frame::SamplesF32(vec![0.0, -1.5, 3.25e6]));
        round_trip(wire::Frame::SamplesI8(vec![-127, -1, 0, 1, 127]));
        round_trip(wire::Frame::SubscribeStats);
        round_trip(wire::Frame::Goodbye);
        round_trip(wire::Frame::Welcome {
            session: 7, hop: 128, frame_len: 512 });
        round_trip(wire::Frame::Diagnosis {
            window: 42, logits: [i32::MIN, i32::MAX], is_va: true });
        round_trip(wire::Frame::Stats {
            sessions: 1, windows: 2, samples: 3, busy: 4, evicted: 5 });
        round_trip(wire::Frame::Busy { dropped: 512 });
        round_trip(wire::Frame::Error {
            code: wire::ERR_PROTOCOL, msg: "nope".into() });
    }

    #[test]
    fn wire_rejects_bad_prefixes() {
        // zero-length frame
        let z = 0u32.to_le_bytes();
        assert!(matches!(
            wire::read_frame(&mut &z[..], 1024),
            Err(wire::WireError::Malformed(_))));
        // oversized declared length — rejected before allocation
        let mut big = Vec::new();
        big.extend_from_slice(&u32::MAX.to_le_bytes());
        big.push(wire::TAG_GOODBYE);
        assert!(matches!(
            wire::read_frame(&mut &big[..], 1024),
            Err(wire::WireError::Oversized(_))));
        // truncated: header promises more than the stream holds
        let mut trunc = Vec::new();
        trunc.extend_from_slice(&100u32.to_le_bytes());
        trunc.push(wire::TAG_GOODBYE);
        assert!(matches!(
            wire::read_frame(&mut &trunc[..], 1024),
            Err(wire::WireError::Io(_))));
        // unknown tag
        let enc = wire::encode(&wire::Frame::Goodbye);
        let mut bad = enc.clone();
        bad[4] = 0x7E;
        assert!(matches!(
            wire::read_frame(&mut &bad[..], 1024),
            Err(wire::WireError::Malformed(_))));
    }

    #[test]
    fn wire_rejects_short_payloads() {
        // a DIAGNOSIS frame with a truncated payload must be
        // Malformed, not a panic
        let mut b = vec![0u8; 4];
        b.push(wire::TAG_DIAGNOSIS);
        b.extend_from_slice(&[0u8; 5]);
        let len = (b.len() - 4) as u32;
        b[..4].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(
            wire::read_frame(&mut &b[..], 1024),
            Err(wire::WireError::Malformed(_))));
        // f32 payload not divisible by 4
        let mut b = vec![0u8; 4];
        b.push(wire::TAG_SAMPLES_F32);
        b.extend_from_slice(&[1, 2, 3]);
        let len = (b.len() - 4) as u32;
        b[..4].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(
            wire::read_frame(&mut &b[..], 1024),
            Err(wire::WireError::Malformed(_))));
    }

    #[test]
    fn reserve_budget_semantics() {
        let inflight = AtomicUsize::new(0);
        assert!(reserve(&inflight, 400, 1024));
        assert!(reserve(&inflight, 624, 1024)); // exactly full
        assert!(!reserve(&inflight, 1, 1024)); // full → shed
        assert_eq!(inflight.load(Ordering::SeqCst), 1024); // no change
        inflight.fetch_sub(1024, Ordering::SeqCst);
        // a single frame above the whole budget always sheds —
        // deterministic BUSY
        assert!(!reserve(&inflight, 2048, 1024));
        assert_eq!(inflight.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn device_stream_is_deterministic_and_in_adc_range() {
        let a = device_stream(3, 1000);
        let b = device_stream(3, 1000);
        assert_eq!(a, b);
        assert_ne!(a, device_stream(4, 1000));
        assert!(a.iter().all(|&v| (-127..=127).contains(&(v as i32))));
    }

    #[test]
    fn windows_done_counts_complete_windows() {
        assert_eq!(windows_done(0, 512, 128), 0);
        assert_eq!(windows_done(511, 512, 128), 0);
        assert_eq!(windows_done(512, 512, 128), 1);
        assert_eq!(windows_done(512 + 127, 512, 128), 1);
        assert_eq!(windows_done(512 + 128, 512, 128), 2);
        assert_eq!(windows_done(512 + 5 * 128, 512, 128), 6);
    }

    #[test]
    fn error_codes_have_distinct_stable_names() {
        let codes = [wire::ERR_AUTH, wire::ERR_PROTOCOL,
                     wire::ERR_CAPACITY, wire::ERR_RATE_LIMITED,
                     wire::ERR_SHUTTING_DOWN, wire::ERR_EVICTED,
                     wire::ERR_SLOW_READER];
        let names: std::collections::HashSet<_> =
            codes.iter().map(|&c| wire::err_name(c)).collect();
        assert_eq!(names.len(), codes.len(),
                   "every error code needs a distinct label");
        assert_eq!(wire::err_name(wire::ERR_EVICTED),
                   "evicted-by-supervisor");
        assert_eq!(wire::err_name(wire::ERR_SLOW_READER), "slow-reader");
        assert_eq!(wire::err_name(999), "unknown");
    }

    /// Unit-level slow-reader eviction: a full outbound queue on a
    /// diagnosis push must remove the session, bump `evicted_slow`,
    /// and write an [`wire::ERR_SLOW_READER`] ERROR straight onto the
    /// socket (the queue is full, so it can't ride the writer).
    #[test]
    fn slow_reader_eviction_writes_the_misbehavior_code() {
        use crate::arch::ChipConfig;
        use crate::compiler::compile;
        use crate::data::fixtures;

        let m = fixtures::quant_model(0x51_0E);
        let cm = Arc::new(compile(&m, &ChipConfig::paper_1d(),
                                  crate::REC_LEN).unwrap());
        let shared = Shared {
            cfg: ServeConfig::loopback("t", 128),
            cm: Arc::clone(&cm),
            open: AtomicBool::new(true),
            conns: AtomicUsize::new(0),
            sessions: AtomicUsize::new(1),
            peak_sessions: AtomicUsize::new(1),
            next_session: AtomicU64::new(2),
            ctr: Counters::default(),
            rate: Mutex::new(HashMap::new()),
            socks: Mutex::new(HashMap::new()),
            subs: Mutex::new(HashMap::new()),
        };
        // a real loopback socket pair so the eviction write lands
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        lock_ok(&shared.socks).insert(1, server_side);

        let (out, _orx) = sync_channel(1);
        out.try_send(wire::Frame::Goodbye).unwrap(); // queue now full
        let mut sessions = HashMap::new();
        sessions.insert(1, DeviceSession {
            sess: StreamSession::new(Arc::clone(&cm), 128).unwrap(),
            out,
            inflight: Arc::new(AtomicUsize::new(4)),
            window: 0,
        });
        // the diagnosis push hits the full queue → eviction
        advance(&shared, &mut sessions, 1, 4, |_| {
            vec![super::super::detector::Detection {
                logits: [1, 2], is_va: true,
            }]
        });
        assert!(sessions.is_empty(), "slow session must be removed");
        assert_eq!(shared.ctr.evicted_slow.load(Ordering::SeqCst), 1);
        assert_eq!(shared.ctr.evicted_super.load(Ordering::SeqCst), 0);
        assert!(lock_ok(&shared.socks).is_empty());

        let mut reader = BufReader::new(client);
        match wire::read_frame(&mut reader, wire::MAX_FRAME_BYTES) {
            Ok(wire::Frame::Error { code, .. }) =>
                assert_eq!(code, wire::ERR_SLOW_READER),
            other => panic!("expected slow-reader ERROR, got {other:?}"),
        }
    }
}
