//! The synchronous detection pipeline: front end → batcher → backend
//! → voter, with latency + accuracy accounting.

use std::time::Instant;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::detector::{Backend, Detection};
use super::stream::FrontEnd;
use super::voter::{Episode, Voter};
use crate::metrics::{Confusion, LatencyRecorder};
use crate::sim::Counters;

/// One completed diagnosis, with the per-recording detail.
#[derive(Debug, Clone)]
pub struct Diagnosis {
    pub episode: Episode,
    /// Logits of each recording in the episode.
    pub detections: Vec<Detection>,
}

/// Pipeline counters exposed to the CLI / examples.
#[derive(Debug, Default, Clone)]
pub struct PipelineStats {
    pub recordings: u64,
    pub episodes: u64,
    pub va_episodes: u64,
}

/// Synchronous streaming pipeline (single channel).
pub struct Pipeline {
    front: FrontEnd,
    batcher: Batcher,
    backend: Backend,
    voter: Voter,
    detections_buf: Vec<Detection>,
    /// Diagnoses completed during a pump whose LATER batch errored:
    /// they could not be returned with the error, so they are held
    /// here and delivered by the next successful pump — a backend
    /// error never loses an already-completed diagnosis.
    ready_buf: Vec<Diagnosis>,
    pub stats: PipelineStats,
    /// Per-recording inference latency (backend call / batch size).
    pub latency: LatencyRecorder,
    /// Accumulated simulator counters (ChipSim backend only).
    pub sim_counters: Counters,
}

impl Pipeline {
    pub fn new(backend: Backend, batcher_cfg: BatcherConfig, vote_group: usize) -> Self {
        Self {
            front: FrontEnd::new(),
            batcher: Batcher::new(batcher_cfg),
            backend,
            voter: Voter::new(vote_group),
            detections_buf: Vec::new(),
            ready_buf: Vec::new(),
            stats: PipelineStats::default(),
            latency: LatencyRecorder::new(),
            sim_counters: Counters::default(),
        }
    }

    /// Paper configuration over the given backend.
    pub fn paper(backend: Backend) -> Self {
        Self::new(backend, BatcherConfig::default(), crate::VOTE_GROUP)
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// High-water marks of the backend's scratch arena, if it has one
    /// ([`Backend::arena_stats`]) — zero for a PJRT backend. Fleet
    /// shards snapshot this at shutdown into their [`super::ShardReport`].
    pub fn arena_stats(&self) -> crate::sim::ArenaStats {
        self.backend.arena_stats().unwrap_or_default()
    }

    /// Push raw analog samples; returns completed diagnoses.
    pub fn push_samples(&mut self, samples: &[f64]) -> Result<Vec<Diagnosis>> {
        for rec in self.front.push(samples) {
            self.batcher.push(rec);
        }
        self.pump(false)
    }

    /// Push an already-quantized recording (offline eval path).
    pub fn push_recording(&mut self, rec: Vec<i8>) -> Result<Vec<Diagnosis>> {
        self.batcher.push(rec);
        self.pump(false)
    }

    /// Flush everything pending (end of session).
    pub fn flush(&mut self) -> Result<Vec<Diagnosis>> {
        self.pump(true)
    }

    /// Error-recovery: discard everything in flight — batched-but-not-
    /// inferred recordings, buffered detections, and the voter's
    /// partial group — returning how many recordings/votes were
    /// dropped. After a backend error the caller cannot know which
    /// queued recordings the failed batch covered, so this is the only
    /// way to restore a consistent submission↔detection alignment.
    /// Diagnoses already COMPLETED before the error (`ready_buf`) are
    /// kept: they are valid and surface on the next successful pump.
    pub fn reset_in_flight(&mut self) -> usize {
        let batched = self.batcher.drain().map_or(0, |b| b.recordings.len());
        let voted = self.voter.reset();
        self.detections_buf.clear();
        batched + voted
    }

    fn pump(&mut self, drain: bool) -> Result<Vec<Diagnosis>> {
        // deliver diagnoses stranded by a previous pump's backend error
        let mut out = std::mem::take(&mut self.ready_buf);
        loop {
            let batch = if drain {
                self.batcher.drain()
            } else {
                self.batcher.poll(Instant::now())
            };
            let Some(batch) = batch else { break };
            let n = batch.recordings.len() as f64;
            let t0 = Instant::now();
            // single backend pass yields detections AND (for ChipSim)
            // the counters — no second simulation of the batch. The
            // ChipSim backend runs the zero-allocation fast path over
            // its own scratch arena and stamps the compile-time static
            // counters (bit-identical to dynamic counting).
            let (dets, counters) =
                match self.backend.infer_with_counters(&batch.recordings) {
                    Ok(r) => r,
                    Err(e) => {
                        // don't lose episodes this pump already completed
                        self.ready_buf = out;
                        return Err(e);
                    }
                };
            let dt = t0.elapsed();
            self.latency.push_us(dt.as_secs_f64() * 1e6 / n.max(1.0));
            if let Some(c) = counters {
                self.sim_counters.merge(&c);
            }
            for det in dets {
                self.stats.recordings += 1;
                self.detections_buf.push(det);
                if let Some(episode) = self.voter.push(det.is_va) {
                    self.stats.episodes += 1;
                    if episode.is_va {
                        self.stats.va_episodes += 1;
                    }
                    let k = episode.votes.len();
                    let detections =
                        self.detections_buf.drain(..k).collect();
                    out.push(Diagnosis { episode, detections });
                }
            }
        }
        Ok(out)
    }

    /// Offline evaluation: run a labelled corpus through the backend
    /// (bypassing the analog front end — inputs are already quantized)
    /// and score per-recording + per-episode confusion matrices.
    pub fn evaluate(backend: &Backend, xs: &[Vec<i8>], va_truth: &[bool],
                    vote_group: usize) -> Result<(Confusion, Confusion)> {
        let mut rec_conf = Confusion::new();
        let dets = backend.infer(xs)?;
        for (d, &t) in dets.iter().zip(va_truth) {
            rec_conf.push(d.is_va, t);
        }
        // group recordings of the SAME ground truth into episodes
        // (recordings of one episode share a rhythm)
        let mut ep_conf = Confusion::new();
        for truth in [false, true] {
            let idx: Vec<usize> = (0..xs.len())
                .filter(|&i| va_truth[i] == truth)
                .collect();
            for g in idx.chunks(vote_group) {
                if g.len() < vote_group {
                    break;
                }
                let votes: Vec<bool> = g.iter().map(|&i| dets[i].is_va).collect();
                ep_conf.push(crate::nn::majority_vote(&votes).is_va, truth);
            }
        }
        Ok((rec_conf, ep_conf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{QLayer, QuantModel};

    /// Backend whose sign tracks the input mean: x>0 → VA.
    fn sign_backend() -> Backend {
        Backend::golden(QuantModel { layers: vec![
            QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0, w: vec![-1, 1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]})
    }

    #[test]
    fn end_to_end_diagnosis_flow() {
        let mut p = Pipeline::new(sign_backend(), BatcherConfig {
            max_batch: 2, max_age: std::time::Duration::ZERO,
        }, 3);
        // recordings of constant sign: +1 -> VA. With max_age ZERO each
        // push flushes immediately, so the diagnosis may surface on the
        // third push rather than at flush time.
        let mut d = Vec::new();
        for _ in 0..3 {
            d.extend(p.push_recording(vec![1i8; crate::REC_LEN]).unwrap());
        }
        d.extend(p.flush().unwrap());
        assert_eq!(d.len(), 1);
        assert!(d[0].episode.is_va);
        assert_eq!(d[0].detections.len(), 3);
        assert_eq!(p.stats.recordings, 3);
        assert_eq!(p.stats.va_episodes, 1);
    }

    #[test]
    fn mixed_votes_majority() {
        let mut p = Pipeline::new(sign_backend(), BatcherConfig::default(), 3);
        p.push_recording(vec![1i8; crate::REC_LEN]).unwrap();
        p.push_recording(vec![-1i8; crate::REC_LEN]).unwrap();
        p.push_recording(vec![-1i8; crate::REC_LEN]).unwrap();
        let d = p.flush().unwrap();
        assert_eq!(d.len(), 1);
        assert!(!d[0].episode.is_va, "2/3 non-VA must win");
    }

    #[test]
    fn evaluate_scores_both_levels() {
        let backend = sign_backend();
        let xs: Vec<Vec<i8>> = (0..12)
            .map(|i| vec![if i < 6 { 1i8 } else { -1i8 }; crate::REC_LEN])
            .collect();
        let truth: Vec<bool> = (0..12).map(|i| i < 6).collect();
        let (rec, ep) = Pipeline::evaluate(&backend, &xs, &truth, 6).unwrap();
        assert_eq!(rec.accuracy(), 1.0);
        assert_eq!(ep.accuracy(), 1.0);
        assert_eq!(ep.total(), 2);
    }

    #[test]
    fn samples_path_produces_recordings() {
        let mut p = Pipeline::new(sign_backend(), BatcherConfig {
            max_batch: 1, max_age: std::time::Duration::ZERO,
        }, 1);
        let mut gen = crate::data::Generator::new(3);
        let rec = gen.recording(crate::data::RhythmClass::Nsr);
        let d = p.push_samples(&rec.raw).unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(p.stats.recordings, 1);
        assert!(p.latency.count() > 0);
    }
}
