//! The simulation engine: functional execution + event counting.

use crate::arch::{Cmul, Mpe, Spe};
use crate::compiler::{CompiledLayer, CompiledModel};
use crate::nn::{pad_same, requant};
use crate::sim::counters::{Counters, LayerCounters};

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Head logits (global-avg-pooled int32 accumulators) — bit-exact
    /// vs [`crate::nn::QuantModel::forward`].
    pub logits: Vec<i32>,
    /// Predicted class (argmax, ties to lower index).
    pub predicted: usize,
    pub counters: Counters,
}

/// Cycle cost of one array step (position tile) for a channel tile:
/// the slowest lane at this precision, or the dense window walk when
/// zero-skip is disabled; +1 exposed regfile fill cycle.
fn tile_cycles(layer: &CompiledLayer, ch_tile: usize, window_len: usize,
               zero_skip: bool) -> u64 {
    let compute = if zero_skip {
        layer.packed.tiles[ch_tile]
            .iter()
            .map(|l| Cmul::cycles_for(l.len() as u64, layer.nbits))
            .max()
            .unwrap_or(0)
    } else {
        Cmul::cycles_for(window_len as u64, layer.nbits)
    };
    compute.max(1) + 1
}

/// Simulate one recording through the compiled model.
pub fn run(cm: &CompiledModel, x: &[i8]) -> SimResult {
    let cfg = &cm.cfg;
    let mut counters = Counters::default();
    counters.input_load_cycles = x.len() as u64;

    let mut a: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    // x is [L, Cin] row-major; the production model has Cin = 1
    let cin0 = cm.layers[0].cin;
    debug_assert_eq!(a.len() % cin0, 0);
    let mut l = a.len() / cin0;
    let mut head: Vec<i32> = Vec::new();
    let mut head_len = 0usize;

    for (li, layer) in cm.layers.iter().enumerate() {
        let sched = &cm.schedule.layers[li];
        let mut lc = LayerCounters::default();
        let padded = pad_same(&a, l, layer.cin, layer.k, layer.stride);
        let lp = padded.len() / layer.cin;
        let lout = sched.lout;
        debug_assert_eq!(lout, (lp - layer.k) / layer.stride + 1);

        let mut out = vec![0i32; lout * layer.cout];
        // one SPE instance carries the traffic/energy counters; all
        // engaged SPEs behave identically so functional execution just
        // walks every position through it.
        let mut spe = Spe::new(cfg.m);
        for (t, (lanes, biases)) in layer.packed.tiles.iter()
            .zip(&layer.packed.biases).enumerate() {
            // stage the input tile into the SPads
            lc.spad.fill(cfg.spad_sharing, sched.fill_words, cfg.m as u64);
            let live = layer.cout - t * cfg.m;
            let live = live.min(cfg.m);
            let tile_nnz: u64 = lanes.iter().map(|l| l.len() as u64).sum();
            let mut accs = vec![0i32; cfg.m];
            for lo in 0..lout {
                let base = lo * layer.stride * layer.cin;
                let window = &padded[base..base + layer.k * layer.cin];
                let (_, seg, macs) = spe.execute_position_into(
                    cfg, window, lanes, biases, layer.nbits, &mut accs);
                out[lo * layer.cout + t * cfg.m
                    ..lo * layer.cout + t * cfg.m + live]
                    .copy_from_slice(&accs[..live]);
                lc.macs += macs;
                lc.segment_ops += seg;
            }
            // timing: per position tile, all SPEs in lockstep
            let tc = tile_cycles(layer, t, sched.window_len, cfg.zero_skip);
            lc.cycles += sched.pos_tiles as u64
                * (tc + sched.ctrl_cycles_per_tile);
            // weights broadcast once per position tile
            lc.weight_fetches += tile_nnz * sched.pos_tiles as u64;
        }
        lc.cycles += sched.layer_overhead_cycles;
        lc.macs_dense = (lout * layer.k * layer.cin * layer.cout) as u64;
        lc.output_writes = (lout * layer.cout) as u64;
        lc.spad.merge(&spe.spad);
        if !cfg.zero_skip {
            // dense datapath executes every weight (energy follows)
            lc.macs = lc.macs_dense;
            lc.segment_ops = lc.macs_dense * layer.nbits as u64;
            lc.weight_fetches =
                lc.macs_dense / lout.max(1) as u64 * sched.pos_tiles as u64;
        }
        counters.per_layer.push(lc);

        if layer.is_head {
            head = out;
            head_len = lout;
        } else {
            // PE drain path: requant + ReLU into the next layer's input
            let mut next = Vec::with_capacity(lout * layer.cout);
            for lo in 0..lout {
                for co in 0..layer.cout {
                    next.push(requant(out[lo * layer.cout + co],
                                      layer.m0[co], layer.shift, layer.relu));
                }
            }
            a = next;
            l = lout;
        }
    }

    // MPE global average pooling + readout
    let cout = cm.layers.last().map(|l| l.cout).unwrap_or(0);
    let mut mpe = Mpe::new();
    let mut logits = Vec::with_capacity(cout);
    for co in 0..cout {
        let col: Vec<i32> = (0..head_len)
            .map(|lo| head[lo * cout + co])
            .collect();
        logits.push(mpe.avg_pool(&col));
    }
    let mpes = (cfg.mpes_per_spe * cfg.engaged_spes()).max(1) as u64;
    counters.readout_cycles = ((head_len * cout) as u64).div_ceil(mpes) + 4;
    if let Some(lc) = counters.per_layer.last_mut() {
        lc.pool_ops = mpe.pool_ops;
    }

    let mut predicted = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[predicted] {
            predicted = i;
        }
    }
    SimResult { logits, predicted, counters }
}

/// Simulate a batch; counters accumulate across recordings.
pub fn run_batch(cm: &CompiledModel, xs: &[Vec<i8>]) -> (Vec<SimResult>, Counters) {
    let mut total = Counters::default();
    let results: Vec<SimResult> = xs.iter().map(|x| run(cm, x)).collect();
    for r in &results {
        total.merge(&r.counters);
    }
    (results, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::nn::{QLayer, QuantModel};

    fn tiny_model() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 3, stride: 2, cin: 1, cout: 4, relu: true, nbits: 8,
                     shift: 24, s_in: 1.0, s_out: 1.0,
                     w: vec![1, 0, -2, 0, 3, 0, 0, -4, 5, 0, 0, 6],
                     bias: vec![1, -2, 3, -4], m0: vec![1 << 23; 4] },
            QLayer { k: 1, stride: 1, cin: 4, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0,
                     w: vec![1, -1, 2, 0, 0, 3, -2, 1],
                     bias: vec![5, -5], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn bit_exact_vs_golden_model() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let mut rng = crate::data::SplitMix64::new(77);
        for _ in 0..50 {
            let x: Vec<i8> = (0..16)
                .map(|_| (rng.range(-127.0, 128.0)) as i8)
                .collect();
            let golden = m.forward(&x);
            let sim = run(&cm, &x);
            assert_eq!(sim.logits, golden);
        }
    }

    #[test]
    fn dense_mode_same_numerics_more_cycles() {
        let m = tiny_model();
        let sparse_cfg = ChipConfig::paper_1d();
        let mut dense_cfg = ChipConfig::paper_1d();
        dense_cfg.zero_skip = false;
        let cm_s = compile(&m, &sparse_cfg, 16).unwrap();
        let cm_d = compile(&m, &dense_cfg, 16).unwrap();
        let x: Vec<i8> = (0..16).map(|i| (i as i8) - 8).collect();
        let rs = run(&cm_s, &x);
        let rd = run(&cm_d, &x);
        assert_eq!(rs.logits, rd.logits);
        assert!(rd.counters.total_cycles() >= rs.counters.total_cycles());
        assert!(rd.counters.total_macs() > rs.counters.total_macs());
    }

    #[test]
    fn counters_populated() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let x = vec![1i8; 16];
        let r = run(&cm, &x);
        let c = &r.counters;
        assert_eq!(c.per_layer.len(), 2);
        assert_eq!(c.input_load_cycles, 16);
        assert!(c.total_cycles() > 16);
        assert!(c.total_macs() > 0);
        assert!(c.total_macs_dense() > c.total_macs());
        assert!(c.total_segment_ops() >= 8 * c.total_macs());
        let t = c.total();
        assert!(t.weight_fetches > 0 && t.output_writes > 0);
        assert!(t.spad.reads > 0 && t.spad.writes > 0);
        assert!(t.pool_ops > 0);
    }

    #[test]
    fn batch_accumulates() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let xs = vec![vec![1i8; 16], vec![-1i8; 16]];
        let (rs, total) = run_batch(&cm, &xs);
        assert_eq!(rs.len(), 2);
        assert_eq!(total.total_cycles(),
                   rs[0].counters.total_cycles() + rs[1].counters.total_cycles());
    }
}
