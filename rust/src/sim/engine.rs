//! The simulation engine: functional execution + event counting.
//!
//! The channel-tile loop of each layer can run serially or in parallel
//! (rayon over output-channel tiles). Both paths are bit-exact: every
//! tile produces its own [`LayerCounters`] partial and the partials are
//! merged with the associative [`LayerCounters::merge`] in tile order,
//! so logits AND counters are identical regardless of execution order
//! (enforced by tests below and `tests/integration_bitexact.rs`).

use rayon::prelude::*;

use crate::arch::{Cmul, Mpe, Spe};
use crate::compiler::{CompiledLayer, CompiledModel};
use crate::nn::{pad_same, requant};
use crate::sim::counters::{Counters, LayerCounters};

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Head logits (global-avg-pooled int32 accumulators) — bit-exact
    /// vs [`crate::nn::QuantModel::forward`].
    pub logits: Vec<i32>,
    /// Predicted class (argmax, ties to lower index).
    pub predicted: usize,
    pub counters: Counters,
}

/// Channel-tile execution strategy for [`run_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TileExec {
    Serial,
    Parallel,
    /// Parallel only for layers with enough dense work to amortize the
    /// rayon dispatch. The paper's 1-D CNN tops out at ~492k dense
    /// MACs per layer, below the threshold, so the serving path (and
    /// the fleet's shard threads) never touch the shared rayon pool;
    /// bigger 2-D workloads opt in automatically.
    Auto,
}

/// Per-layer dense-MAC threshold above which [`TileExec::Auto`] uses
/// the parallel tile loop (1 Mi MACs — deliberately above every layer
/// of the paper model).
const PAR_MIN_DENSE_MACS: u64 = 1 << 20;

/// Cycle cost of one array step (position tile) for a channel tile:
/// the slowest lane at this precision, or the dense window walk when
/// zero-skip is disabled; +1 exposed regfile fill cycle.
fn tile_cycles(layer: &CompiledLayer, ch_tile: usize, window_len: usize,
               zero_skip: bool) -> u64 {
    let compute = if zero_skip {
        layer.packed.tiles[ch_tile]
            .iter()
            .map(|l| Cmul::cycles_for(l.len() as u64, layer.nbits))
            .max()
            .unwrap_or(0)
    } else {
        Cmul::cycles_for(window_len as u64, layer.nbits)
    };
    compute.max(1) + 1
}

/// Execute one output-channel tile over every output position. Returns
/// the tile's `[lout, live]` accumulator columns plus its counter
/// partial; partials merge associatively, so tiles can run in any
/// order (or concurrently) without changing the result.
fn sim_tile(cm: &CompiledModel, li: usize, t: usize, padded: &[i32],
            lout: usize) -> (Vec<i32>, LayerCounters) {
    let cfg = &cm.cfg;
    let layer = &cm.layers[li];
    let sched = &cm.schedule.layers[li];
    let lanes = &layer.packed.tiles[t];
    let biases = &layer.packed.biases[t];
    let mut lc = LayerCounters::default();
    // one SPE instance per tile carries the traffic/energy counters;
    // all engaged SPEs behave identically so functional execution just
    // walks every position through it.
    let mut spe = Spe::new(cfg.m);
    // stage the input tile into the SPads
    lc.spad.fill(cfg.spad_sharing, sched.fill_words, cfg.m as u64);
    let live = (layer.cout - t * cfg.m).min(cfg.m);
    let tile_nnz: u64 = lanes.iter().map(|l| l.len() as u64).sum();
    let mut accs = vec![0i32; cfg.m];
    let mut cols = vec![0i32; lout * live];
    for lo in 0..lout {
        let base = lo * layer.stride * layer.cin;
        let window = &padded[base..base + layer.k * layer.cin];
        let (_, seg, macs) = spe.execute_position_into(
            cfg, window, lanes, biases, layer.nbits, &mut accs);
        cols[lo * live..(lo + 1) * live].copy_from_slice(&accs[..live]);
        lc.macs += macs;
        lc.segment_ops += seg;
    }
    // timing: per position tile, all SPEs in lockstep
    let tc = tile_cycles(layer, t, sched.window_len, cfg.zero_skip);
    lc.cycles += sched.pos_tiles as u64 * (tc + sched.ctrl_cycles_per_tile);
    // weights broadcast once per position tile
    lc.weight_fetches += tile_nnz * sched.pos_tiles as u64;
    lc.spad.merge(&spe.spad);
    (cols, lc)
}

/// Simulate one recording through the compiled model.
fn run_with(cm: &CompiledModel, x: &[i8], exec: TileExec) -> SimResult {
    let cfg = &cm.cfg;
    let mut counters = Counters::default();
    counters.input_load_cycles = x.len() as u64;

    let mut a: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    // x is [L, Cin] row-major; the production model has Cin = 1
    let cin0 = cm.layers[0].cin;
    debug_assert_eq!(a.len() % cin0, 0);
    let mut l = a.len() / cin0;
    let mut head: Vec<i32> = Vec::new();
    let mut head_len = 0usize;

    for (li, layer) in cm.layers.iter().enumerate() {
        let sched = &cm.schedule.layers[li];
        let padded = pad_same(&a, l, layer.cin, layer.k, layer.stride);
        let lp = padded.len() / layer.cin;
        let lout = sched.lout;
        debug_assert_eq!(lout, (lp - layer.k) / layer.stride + 1);
        let n_tiles = layer.packed.tiles.len();
        let dense = (lout * layer.k * layer.cin * layer.cout) as u64;

        let parallel = match exec {
            TileExec::Serial => false,
            TileExec::Parallel => n_tiles > 1,
            TileExec::Auto => n_tiles > 1 && dense >= PAR_MIN_DENSE_MACS,
        };
        let tile = |t: usize| sim_tile(cm, li, t, &padded, lout);
        let partials: Vec<(Vec<i32>, LayerCounters)> = if parallel {
            (0..n_tiles).into_par_iter().map(tile).collect()
        } else {
            (0..n_tiles).map(tile).collect()
        };

        // deterministic in-tile-order merge: counter addition is
        // associative and the scatter targets are disjoint columns
        let mut out = vec![0i32; lout * layer.cout];
        let mut lc = LayerCounters::default();
        for (t, (cols, part)) in partials.iter().enumerate() {
            lc.merge(part);
            let live = (layer.cout - t * cfg.m).min(cfg.m);
            for lo in 0..lout {
                out[lo * layer.cout + t * cfg.m
                    ..lo * layer.cout + t * cfg.m + live]
                    .copy_from_slice(&cols[lo * live..(lo + 1) * live]);
            }
        }
        lc.cycles += sched.layer_overhead_cycles;
        lc.macs_dense = dense;
        lc.output_writes = (lout * layer.cout) as u64;
        if !cfg.zero_skip {
            // dense datapath executes every weight (energy follows)
            lc.macs = lc.macs_dense;
            lc.segment_ops = lc.macs_dense * layer.nbits as u64;
            lc.weight_fetches =
                lc.macs_dense / lout.max(1) as u64 * sched.pos_tiles as u64;
        }
        counters.per_layer.push(lc);

        if layer.is_head {
            head = out;
            head_len = lout;
        } else {
            // PE drain path: requant + ReLU into the next layer's input
            let mut next = Vec::with_capacity(lout * layer.cout);
            for lo in 0..lout {
                for co in 0..layer.cout {
                    next.push(requant(out[lo * layer.cout + co],
                                      layer.m0[co], layer.shift, layer.relu));
                }
            }
            a = next;
            l = lout;
        }
    }

    // MPE global average pooling + readout
    let cout = cm.layers.last().map(|l| l.cout).unwrap_or(0);
    let mut mpe = Mpe::new();
    let mut logits = Vec::with_capacity(cout);
    for co in 0..cout {
        let col: Vec<i32> = (0..head_len)
            .map(|lo| head[lo * cout + co])
            .collect();
        logits.push(mpe.avg_pool(&col));
    }
    let mpes = (cfg.mpes_per_spe * cfg.engaged_spes()).max(1) as u64;
    counters.readout_cycles = ((head_len * cout) as u64).div_ceil(mpes) + 4;
    if let Some(lc) = counters.per_layer.last_mut() {
        lc.pool_ops = mpe.pool_ops;
    }

    let mut predicted = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[predicted] {
            predicted = i;
        }
    }
    SimResult { logits, predicted, counters }
}

/// Simulate one recording. Large layers (≥ `PAR_MIN_DENSE_MACS` dense
/// MACs and more than one channel tile) use the rayon tile loop;
/// smaller ones stay serial. Always bit-exact — logits and counters —
/// with [`run_serial`] and [`run_parallel`].
pub fn run(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_with(cm, x, TileExec::Auto)
}

/// Force the serial channel-tile loop (reference path).
pub fn run_serial(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_with(cm, x, TileExec::Serial)
}

/// Force the rayon channel-tile loop regardless of layer size.
pub fn run_parallel(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_with(cm, x, TileExec::Parallel)
}

/// Simulate a batch; counters accumulate across recordings.
pub fn run_batch(cm: &CompiledModel, xs: &[Vec<i8>]) -> (Vec<SimResult>, Counters) {
    let results: Vec<SimResult> = xs.iter().map(|x| run(cm, x)).collect();
    let mut total = Counters::default();
    for r in &results {
        total.merge(&r.counters);
    }
    (results, total)
}

/// Batch simulation with rayon across recordings (each recording runs
/// the serial tile loop — one level of parallelism is enough). Results
/// and the merged counters are identical to [`run_batch`]: the merge
/// is associative and applied in submission order.
pub fn run_batch_parallel(cm: &CompiledModel, xs: &[Vec<i8>])
                          -> (Vec<SimResult>, Counters) {
    let results: Vec<SimResult> =
        xs.par_iter().map(|x| run_serial(cm, x)).collect();
    let mut total = Counters::default();
    for r in &results {
        total.merge(&r.counters);
    }
    (results, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::nn::{QLayer, QuantModel};

    fn tiny_model() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 3, stride: 2, cin: 1, cout: 4, relu: true, nbits: 8,
                     shift: 24, s_in: 1.0, s_out: 1.0,
                     w: vec![1, 0, -2, 0, 3, 0, 0, -4, 5, 0, 0, 6],
                     bias: vec![1, -2, 3, -4], m0: vec![1 << 23; 4] },
            QLayer { k: 1, stride: 1, cin: 4, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0,
                     w: vec![1, -1, 2, 0, 0, 3, -2, 1],
                     bias: vec![5, -5], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn bit_exact_vs_golden_model() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let mut rng = crate::data::SplitMix64::new(77);
        for _ in 0..50 {
            let x: Vec<i8> = (0..16)
                .map(|_| (rng.range(-127.0, 128.0)) as i8)
                .collect();
            let golden = m.forward(&x);
            let sim = run(&cm, &x);
            assert_eq!(sim.logits, golden);
        }
    }

    #[test]
    fn dense_mode_same_numerics_more_cycles() {
        let m = tiny_model();
        let sparse_cfg = ChipConfig::paper_1d();
        let mut dense_cfg = ChipConfig::paper_1d();
        dense_cfg.zero_skip = false;
        let cm_s = compile(&m, &sparse_cfg, 16).unwrap();
        let cm_d = compile(&m, &dense_cfg, 16).unwrap();
        let x: Vec<i8> = (0..16).map(|i| (i as i8) - 8).collect();
        let rs = run(&cm_s, &x);
        let rd = run(&cm_d, &x);
        assert_eq!(rs.logits, rd.logits);
        assert!(rd.counters.total_cycles() >= rs.counters.total_cycles());
        assert!(rd.counters.total_macs() > rs.counters.total_macs());
    }

    #[test]
    fn counters_populated() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let x = vec![1i8; 16];
        let r = run(&cm, &x);
        let c = &r.counters;
        assert_eq!(c.per_layer.len(), 2);
        assert_eq!(c.input_load_cycles, 16);
        assert!(c.total_cycles() > 16);
        assert!(c.total_macs() > 0);
        assert!(c.total_macs_dense() > c.total_macs());
        assert!(c.total_segment_ops() >= 8 * c.total_macs());
        let t = c.total();
        assert!(t.weight_fetches > 0 && t.output_writes > 0);
        assert!(t.spad.reads > 0 && t.spad.writes > 0);
        assert!(t.pool_ops > 0);
    }

    #[test]
    fn batch_accumulates() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let xs = vec![vec![1i8; 16], vec![-1i8; 16]];
        let (rs, total) = run_batch(&cm, &xs);
        assert_eq!(rs.len(), 2);
        assert_eq!(total.total_cycles(),
                   rs[0].counters.total_cycles() + rs[1].counters.total_cycles());
    }

    #[test]
    fn parallel_tiles_bit_exact_with_serial_including_counters() {
        let m = crate::data::fixtures::quant_model(0xBEEF);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let ds = crate::data::Dataset::synthesize(17, 2, 0.5);
        for x in &ds.x {
            let a = run_serial(&cm, x);
            let b = run_parallel(&cm, x);
            let c = run(&cm, x);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.logits, c.logits);
            assert_eq!(a.predicted, b.predicted);
            assert_eq!(a.counters, b.counters,
                       "parallel counters must equal serial counters");
            assert_eq!(a.counters, c.counters);
        }
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        let m = crate::data::fixtures::quant_model(0xF00D);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let ds = crate::data::Dataset::synthesize(23, 2, 0.5);
        let (rs, ts) = run_batch(&cm, &ds.x);
        let (rp, tp) = run_batch_parallel(&cm, &ds.x);
        assert_eq!(rs.len(), rp.len());
        for (a, b) in rs.iter().zip(&rp) {
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.counters, b.counters);
        }
        assert_eq!(ts, tp);
    }
}
