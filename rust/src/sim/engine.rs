//! The simulation engine: a compile-time cost model + a pure compute
//! kernel, with a counted reference path — both executing over the
//! **tile-major activation layout** and one shared [`ScratchArena`].
//!
//! Two execution paths, one integer function:
//!
//! * **Fast path** ([`run`] / [`run_scratch`] / [`run_batch`]) — pure
//!   functional execution through the staged position-blocked packed
//!   tile kernel (the dispatched [`crate::arch::tile_block`]: every
//!   channel tile streams its contiguous slice of the layer's
//!   bit-packed [`crate::compiler::PackedStreams`] weight arena over
//!   one shared `[window_len, 8]` stage, through the
//!   [`KernelTier`]-selected AVX2 or scalar twin) over a reusable
//!   [`ScratchArena`] (zero heap allocation in the compute kernel).
//!   Counters are NOT
//!   measured: the compiler already derived the complete event set
//!   ([`crate::compiler::StaticCost`]) from the packed streams +
//!   schedule — zero-skip operates on weights, never activations, so
//!   every count is input-independent — and the static cost is
//!   cloned-and-stamped onto each [`SimResult`].
//! * **Counted reference path** ([`run_counted`] /
//!   [`run_counted_scratch`] / [`run_serial`] / [`run_parallel`]) —
//!   walks every position through an [`Spe`] instance and measures
//!   every event dynamically. The channel-tile loop runs serially
//!   (reusing the arena's SPE + accumulators, zero allocation) or in
//!   parallel (rayon over output-channel stripes, per-worker SPE) with
//!   per-tile [`LayerCounters`] partials merged in tile order.
//!
//! Layout invariant: each channel tile writes its accumulators
//! directly into its disjoint column stripe of the layer output buffer
//! (`[ch_tile][lout][lane]`, see [`crate::compiler::LayerSchedule`]) —
//! there is no `[lout, live]` → `[lout, cout]` scatter pass on any
//! path. Stripes are also the **interchange format between layers**:
//! each layer's padded window buffer is staged straight from the
//! producer's stripes with the requant fused into the read
//! ([`crate::nn::pad_same_from_stripes`] over the schedule's carried
//! `in_stripes` table), so the separate requant-drain pass — and with
//! it every row-major intermediate feature map — is gone. Only the
//! network input arrives `[L, Cin]` row-major; the head readout pools
//! straight from the head's stripes. Fusing the drain moves work, not
//! events: the counted path still charges the identical
//! `output_writes` (one requantized write per `lout · cout` element)
//! and cycle terms, so static == counted stays pinned.
//!
//! The bit-exactness invariant is threefold (enforced by tests below,
//! `tests/integration_bitexact.rs`, `tests/static_counters.rs` and
//! `tests/layout_arena.rs`):
//!
//! 1. logits: fast == counted == golden `nn::QuantModel::forward`
//!    (and its arena twin `forward_scratch`);
//! 2. counters: static (compile-time) == reference (counted);
//! 3. serial == parallel, for both tile- and batch-level parallelism.

use rayon::prelude::*;

use crate::arch::{stage_window_block, tile_block, tile_cycles, KernelTier,
                  LaneWork, Mpe, Spe};
use crate::compiler::{CompiledLayer, CompiledModel, LayerSchedule};
use crate::nn::{argmax, global_avgpool_stripes, pad_same_from_stripes,
                pad_same_into};
use crate::sim::counters::{Counters, LayerCounters};
use crate::sim::scratch::ScratchArena;

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Head logits (global-avg-pooled int32 accumulators) — bit-exact
    /// vs [`crate::nn::QuantModel::forward`].
    pub logits: Vec<i32>,
    /// Predicted class ([`crate::nn::argmax`], ties to lower index).
    pub predicted: usize,
    pub counters: Counters,
}

/// Output positions computed per weight-stream pass of the hot kernel:
/// each (select, weight) pair decoded once feeds this many independent
/// accumulator chains (see [`crate::arch::lane_block_packed`] /
/// [`crate::arch::tile_block_packed`]); the window stage buffer holds
/// `window_len · POS_BLOCK` words.
pub(crate) const POS_BLOCK: usize = 8;

// ---------------------------------------------------------------------
// Fast path: pure compute + precompiled static counters
// ---------------------------------------------------------------------

/// One `B`-wide step of the staged packed fast kernel: stage the
/// window block for output positions `[lo, lo + B)` and run every
/// channel tile's packed stream over it through the **dispatched**
/// tile kernel ([`crate::arch::tile_block`] — the `tier`'s AVX2 or
/// scalar twin, bit-exact either way), writing straight into the
/// tile-major stripe slab. `win` must be exactly `window_len · B`.
#[inline]
fn block_step<const B: usize>(layer: &CompiledLayer, sched: &LayerSchedule,
                              padded: &[i32], out: &mut [i32],
                              win: &mut [i32], lo: usize, tier: KernelTier) {
    let step = layer.stride * layer.cin;
    let ps = &layer.packed;
    stage_window_block::<B>(padded, lo * step, step, sched.window_len, win);
    for (t, st) in sched.stripes.iter().enumerate() {
        let stripe = &mut out[st.offset..st.offset + sched.lout * st.live];
        tile_block::<B>(tier, ps.stream(), ps.tile_ranges(t),
                        ps.tile_biases(t), win, stripe, lo, st.live);
    }
}

/// Compute output columns `[lo0, hi)` of one layer into its tile-major
/// stripe slab, walking a greedy 8/4/2/1 position-block ladder so even
/// short ranges stay on the staged packed kernel instead of a
/// per-position scalar loop. Positions are independent — each column
/// is a pure function of its receptive field — so any sub-range, under
/// any blocking, is bit-exact with a full `[0, lout)` pass; this is
/// the property [`crate::sim::StreamingEngine`] leans on to recompute
/// only the hop-invalidated fringe of each layer. `out` must hold the
/// layer's full `out_len` slab; `win` is the arena's window stage,
/// (re)sized here.
pub(crate) fn compute_cols(layer: &CompiledLayer, sched: &LayerSchedule,
                           padded: &[i32], out: &mut [i32],
                           win: &mut Vec<i32>, lo0: usize, hi: usize,
                           tier: KernelTier) {
    debug_assert!(lo0 <= hi && hi <= sched.lout);
    let wlen = sched.window_len;
    win.clear();
    win.resize(wlen * POS_BLOCK, 0);
    let mut lo = lo0;
    while lo + 8 <= hi {
        block_step::<8>(layer, sched, padded, out, &mut win[..wlen * 8], lo,
                        tier);
        lo += 8;
    }
    if lo + 4 <= hi {
        block_step::<4>(layer, sched, padded, out, &mut win[..wlen * 4], lo,
                        tier);
        lo += 4;
    }
    if lo + 2 <= hi {
        block_step::<2>(layer, sched, padded, out, &mut win[..wlen * 2], lo,
                        tier);
        lo += 2;
    }
    if lo < hi {
        block_step::<1>(layer, sched, padded, out, &mut win[..wlen], lo,
                        tier);
    }
}

/// Simulate one recording on the fast path using a caller-owned
/// scratch arena (zero allocation in the compute kernel; the returned
/// `SimResult` owns only its logits and the cloned static counters).
/// Uses the process-wide detected [`KernelTier`]; see
/// [`run_scratch_tier`] to pin the tier explicitly.
pub fn run_scratch(cm: &CompiledModel, x: &[i8], s: &mut ScratchArena)
                   -> SimResult {
    run_scratch_tier(cm, x, s, KernelTier::current())
}

/// [`run_scratch`] with an explicit kernel tier. Both tiers are
/// bit-exact (the dispatch-equivalence tests in
/// `tests/simd_dispatch.rs` sweep this); pinning the tier is for
/// benchmarking the SIMD-vs-scalar gap and for backends that snapshot
/// the tier at construction.
pub fn run_scratch_tier(cm: &CompiledModel, x: &[i8], s: &mut ScratchArena,
                        tier: KernelTier) -> SimResult {
    let sc = &cm.static_cost;
    assert_eq!(x.len(), sc.input_len,
               "recording length {} != compiled input length {}",
               x.len(), sc.input_len);
    let ScratchArena { act, padded, out, win, .. } = s;

    act.clear();
    act.extend(x.iter().map(|&v| v as i32));
    let mut l = x.len() / cm.layers[0].cin;

    for (li, layer) in cm.layers.iter().enumerate() {
        let sched = &cm.schedule.layers[li];
        if li == 0 {
            // the network input is the only row-major map in the pass
            pad_same_into(act, l, layer.cin, layer.k, layer.stride, padded);
        } else {
            // fused requant drain (the PE drain path): stage this
            // layer's padded window buffer straight from the
            // producer's stripes — still in `out` from the previous
            // iteration — requantizing each element on the way
            let prev = &cm.layers[li - 1];
            pad_same_from_stripes(&sched.in_stripes, out, l, layer.cin,
                                  layer.k, layer.stride, &prev.m0,
                                  prev.shift, prev.relu, padded);
        }
        out.clear();
        out.resize(sched.out_len, 0);

        // Position-block outer, channel-tile inner: the staged window
        // block is shared by every lane of every tile at these
        // positions, so the strided gather is paid once per block;
        // each tile then streams its contiguous slice of the flat
        // weight arena through the packed tile kernel (8-wide blocks,
        // 4/2/1 ladder for the tail).
        compute_cols(layer, sched, padded, out, win, 0, sched.lout, tier);

        l = sched.lout;
        // no drain pass: `out` keeps this layer's stripes for the next
        // iteration's fused staging read (or the head readout below)
    }

    // MPE global average pooling + readout: ONE position-major
    // streaming pass over the head's stripes
    // (`nn::global_avgpool_stripes`, the shared `avg_round` rounding —
    // bit-exact with the per-lane strided walk the counted reference
    // still performs through its Mpe)
    let cout = cm.layers.last().map(|ly| ly.cout).unwrap_or(0);
    let head_len = l;
    let logits = match cm.schedule.layers.last() {
        Some(sched) =>
            global_avgpool_stripes(&sched.stripes, out, head_len, cout),
        None => Vec::new(),
    };
    let predicted = argmax(&logits);
    SimResult { logits, predicted, counters: sc.counters.clone() }
}

/// Simulate one recording (fast path, fresh arena). Callers on a hot
/// loop should hold a [`ScratchArena`] and use [`run_scratch`] /
/// [`run_batch_scratch`] instead. Bit-exact — logits AND counters —
/// with [`run_counted`], [`run_serial`] and [`run_parallel`].
pub fn run(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_scratch(cm, x, &mut ScratchArena::for_model(cm))
}

/// Simulate a batch on the fast path through one reusable arena;
/// total counters are the static cost scaled by the batch size
/// (bit-identical to merging each recording's counters in order).
pub fn run_batch_scratch(cm: &CompiledModel, xs: &[Vec<i8>],
                         s: &mut ScratchArena) -> (Vec<SimResult>, Counters) {
    run_batch_scratch_tier(cm, xs, s, KernelTier::current())
}

/// [`run_batch_scratch`] with an explicit kernel tier.
pub fn run_batch_scratch_tier(cm: &CompiledModel, xs: &[Vec<i8>],
                              s: &mut ScratchArena, tier: KernelTier)
                              -> (Vec<SimResult>, Counters) {
    let results: Vec<SimResult> =
        xs.iter().map(|x| run_scratch_tier(cm, x, s, tier)).collect();
    (results, cm.static_cost.counters.scaled(xs.len() as u64))
}

/// Simulate a batch (fast path); counters accumulate across recordings.
pub fn run_batch(cm: &CompiledModel, xs: &[Vec<i8>]) -> (Vec<SimResult>, Counters) {
    run_batch_scratch(cm, xs, &mut ScratchArena::for_model(cm))
}

/// Batch simulation with rayon across recordings, each worker owning
/// its own arena. Results and merged counters are identical to
/// [`run_batch`].
pub fn run_batch_parallel(cm: &CompiledModel, xs: &[Vec<i8>])
                          -> (Vec<SimResult>, Counters) {
    run_batch_parallel_tier(cm, xs, KernelTier::current())
}

/// [`run_batch_parallel`] with an explicit kernel tier (every rayon
/// worker uses the same pinned tier).
pub fn run_batch_parallel_tier(cm: &CompiledModel, xs: &[Vec<i8>],
                               tier: KernelTier)
                               -> (Vec<SimResult>, Counters) {
    let results: Vec<SimResult> = xs
        .par_iter()
        .map_init(|| ScratchArena::for_model(cm),
                  |s, x| run_scratch_tier(cm, x, s, tier))
        .collect();
    (results, cm.static_cost.counters.scaled(xs.len() as u64))
}

// ---------------------------------------------------------------------
// Counted reference path: dynamic event measurement
// ---------------------------------------------------------------------

/// Channel-tile execution strategy for [`run_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TileExec {
    Serial,
    Parallel,
    /// Parallel only for layers with enough dense work to amortize the
    /// rayon dispatch. The paper's 1-D CNN tops out at ~492k dense
    /// MACs per layer, below the threshold, so the counted path (and
    /// the fleet's shard threads) never touch the shared rayon pool;
    /// bigger 2-D workloads opt in automatically.
    Auto,
}

/// Per-layer dense-MAC threshold above which [`TileExec::Auto`] uses
/// the parallel tile loop (1 Mi MACs — deliberately above every layer
/// of the paper model).
const PAR_MIN_DENSE_MACS: u64 = 1 << 20;

/// Execute one output-channel tile over every output position, writing
/// its accumulator columns directly into the tile's column `stripe`
/// (`[lout, live]` of the tile-major layer output — its final
/// location, no merge pass follows). Returns the tile's counter
/// partial; partials merge associatively, so tiles can run in any
/// order (or concurrently over disjoint stripes) without changing the
/// result. `spe` must be counter-reset ([`Spe::reset`]), `accs` must
/// hold `m` lane accumulators, and `lanes` is a reusable buffer this
/// function refills with the tile's `m` borrowed stream views from
/// the layer's [`crate::compiler::PackedStreams`] arena; all three
/// come from a [`ScratchArena`] / caller local (serial loop) or a
/// rayon worker's init state (parallel loop), so this function
/// allocates nothing in steady state.
#[allow(clippy::too_many_arguments)]
fn sim_tile<'m>(cm: &'m CompiledModel, li: usize, t: usize, padded: &[i32],
                stripe: &mut [i32], spe: &mut Spe, accs: &mut [i32],
                lanes: &mut Vec<LaneWork<'m>>) -> LayerCounters {
    let cfg = &cm.cfg;
    let layer = &cm.layers[li];
    let sched = &cm.schedule.layers[li];
    layer.packed.tile_lanes_into(t, lanes);
    let biases = layer.packed.tile_biases(t);
    let live = sched.stripes[t].live;
    let lout = sched.lout;
    debug_assert_eq!(stripe.len(), lout * live);
    let mut lc = LayerCounters::default();
    // stage the input tile into the SPads
    lc.spad.fill(cfg.spad_sharing, sched.fill_words, cfg.m as u64);
    let tile_nnz: u64 = lanes.iter().map(|l| l.len() as u64).sum();
    for (lo, row) in stripe.chunks_exact_mut(live).enumerate() {
        let base = lo * layer.stride * layer.cin;
        let window = &padded[base..base + layer.k * layer.cin];
        // full tiles drain the SPE accumulators straight into the
        // stripe row; a partial tile stages through `accs` because its
        // padding lanes have no stripe slot to drain into
        let (seg, macs) = if live == spe.num_lanes() {
            spe.execute_position_into(
                cfg, window, lanes, biases, layer.nbits, row)
        } else {
            let r = spe.execute_position_into(
                cfg, window, lanes, biases, layer.nbits, accs);
            row.copy_from_slice(&accs[..live]);
            r
        };
        lc.macs += macs;
        lc.segment_ops += seg;
    }
    // timing: per position tile, all SPEs in lockstep — the one shared
    // formula (`arch::tile_cycles`), also used by the static cost model
    let tc = tile_cycles(lanes, sched.window_len, layer.nbits, cfg.zero_skip);
    lc.cycles += sched.pos_tiles as u64 * (tc + sched.ctrl_cycles_per_tile);
    // weights broadcast once per position tile
    lc.weight_fetches += tile_nnz * sched.pos_tiles as u64;
    lc.spad.merge(&spe.spad);
    lc
}

/// Simulate one recording through the compiled model, measuring every
/// counter dynamically, over the caller's arena.
fn run_with(cm: &CompiledModel, x: &[i8], exec: TileExec,
            arena: &mut ScratchArena) -> SimResult {
    let cfg = &cm.cfg;
    let mut counters = Counters::default();
    counters.input_load_cycles = x.len() as u64;

    let ScratchArena { act, padded, out, accs, spe, .. } = arena;
    act.clear();
    act.extend(x.iter().map(|&v| v as i32));
    // x is [L, Cin] row-major; the production model has Cin = 1
    let cin0 = cm.layers[0].cin;
    debug_assert_eq!(act.len() % cin0, 0);
    let mut l = act.len() / cin0;
    // reusable lane-view buffer for the serial tile walk (the parallel
    // branch gives each rayon worker its own in map_init)
    let mut lane_views: Vec<LaneWork> = Vec::with_capacity(cfg.m);

    for (li, layer) in cm.layers.iter().enumerate() {
        let sched = &cm.schedule.layers[li];
        if li == 0 {
            pad_same_into(act, l, layer.cin, layer.k, layer.stride, padded);
        } else {
            // fused requant drain, same glue as the fast path: the
            // producer's stripes (in `out`) requantize straight into
            // this layer's padded window buffer. The drain's events
            // are unchanged — `output_writes` below charges the same
            // lout·cout requantized writes the standalone pass did —
            // so static == counted stays pinned.
            let prev = &cm.layers[li - 1];
            pad_same_from_stripes(&sched.in_stripes, out, l, layer.cin,
                                  layer.k, layer.stride, &prev.m0,
                                  prev.shift, prev.relu, padded);
        }
        let lp = padded.len() / layer.cin;
        let lout = sched.lout;
        debug_assert_eq!(lout, (lp - layer.k) / layer.stride + 1);
        let n_tiles = layer.packed.ch_tiles();
        let dense = (lout * layer.k * layer.cin * layer.cout) as u64;

        let parallel = match exec {
            TileExec::Serial => false,
            TileExec::Parallel => n_tiles > 1,
            TileExec::Auto => n_tiles > 1 && dense >= PAR_MIN_DENSE_MACS,
        };
        out.clear();
        out.resize(sched.out_len, 0);
        let mut lc = LayerCounters::default();
        if parallel {
            // disjoint column stripes via chunks_mut — every tile
            // writes straight into its slice of `out`, no merge pass;
            // each rayon worker owns its SPE + accumulators
            let padded_ref: &[i32] = padded;
            let partials: Vec<LayerCounters> = out
                .par_chunks_mut(sched.stripe_stride.max(1))
                .enumerate()
                .map_init(
                    || (Spe::new(cfg.m), vec![0i32; cfg.m],
                        Vec::with_capacity(cfg.m)),
                    |(spe, accs, lanes), (t, stripe)| {
                        spe.reset();
                        sim_tile(cm, li, t, padded_ref, stripe, spe, accs,
                                 lanes)
                    })
                .collect();
            // deterministic in-tile-order merge (collect preserves the
            // stripe order; counter addition is associative anyway)
            for part in &partials {
                lc.merge(part);
            }
        } else {
            // zero-allocation serial walk over the arena's SPE
            let spe = ScratchArena::spe_for(spe, cfg.m);
            accs.clear();
            accs.resize(cfg.m, 0);
            for (t, stripe) in sched.stripe_chunks_mut(out).enumerate() {
                spe.reset();
                lc.merge(&sim_tile(cm, li, t, padded, stripe, spe, accs,
                                   &mut lane_views));
            }
        }
        lc.cycles += sched.layer_overhead_cycles;
        lc.macs_dense = dense;
        lc.output_writes = (lout * layer.cout) as u64;
        if !cfg.zero_skip {
            // dense datapath executes every weight (energy follows)
            lc.macs = lc.macs_dense;
            lc.segment_ops = lc.macs_dense * layer.nbits as u64;
            lc.weight_fetches =
                lc.macs_dense / lout.max(1) as u64 * sched.pos_tiles as u64;
        }
        counters.per_layer.push(lc);

        l = lout;
        // no drain pass — see the fast path above
    }

    // MPE global average pooling + readout, off the head's stripes
    let cout = cm.layers.last().map(|ly| ly.cout).unwrap_or(0);
    let head_len = l;
    let mut mpe = Mpe::new();
    let mut logits = vec![0i32; cout];
    if let Some(sched) = cm.schedule.layers.last() {
        let mut col = Vec::with_capacity(head_len);
        for st in &sched.stripes {
            for lane in 0..st.live {
                col.clear();
                col.extend((0..head_len)
                    .map(|lo| out[st.offset + lo * st.live + lane]));
                logits[st.base_co + lane] = mpe.avg_pool(&col);
            }
        }
    }
    let mpes = (cfg.mpes_per_spe * cfg.engaged_spes()).max(1) as u64;
    counters.readout_cycles = ((head_len * cout) as u64).div_ceil(mpes) + 4;
    if let Some(lc) = counters.per_layer.last_mut() {
        lc.pool_ops = mpe.pool_ops;
    }

    let predicted = argmax(&logits);
    SimResult { logits, predicted, counters }
}

/// Counted reference path. Large layers (≥ `PAR_MIN_DENSE_MACS` dense
/// MACs and more than one channel tile) use the rayon tile loop;
/// smaller ones stay serial. Always bit-exact — logits and counters —
/// with [`run`] (fast), [`run_serial`] and [`run_parallel`].
pub fn run_counted(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_with(cm, x, TileExec::Auto, &mut ScratchArena::for_model(cm))
}

/// [`run_counted`] over a caller-owned arena: the zero-allocation form
/// for sweeps (`benches/sparsity`, `benches/table1`) that iterate the
/// reference path heavily. On serial layers the tile loop reuses the
/// arena's SPE and lane accumulators; nothing is allocated per tile.
pub fn run_counted_scratch(cm: &CompiledModel, x: &[i8],
                           s: &mut ScratchArena) -> SimResult {
    run_with(cm, x, TileExec::Auto, s)
}

/// Force the serial channel-tile loop (counted reference path).
pub fn run_serial(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_with(cm, x, TileExec::Serial, &mut ScratchArena::for_model(cm))
}

/// Force the rayon channel-tile loop regardless of layer size
/// (counted reference path).
pub fn run_parallel(cm: &CompiledModel, x: &[i8]) -> SimResult {
    run_with(cm, x, TileExec::Parallel, &mut ScratchArena::for_model(cm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::nn::{QLayer, QuantModel};

    fn tiny_model() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 3, stride: 2, cin: 1, cout: 4, relu: true, nbits: 8,
                     shift: 24, s_in: 1.0, s_out: 1.0,
                     w: vec![1, 0, -2, 0, 3, 0, 0, -4, 5, 0, 0, 6],
                     bias: vec![1, -2, 3, -4], m0: vec![1 << 23; 4] },
            QLayer { k: 1, stride: 1, cin: 4, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0,
                     w: vec![1, -1, 2, 0, 0, 3, -2, 1],
                     bias: vec![5, -5], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn bit_exact_vs_golden_model() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let mut rng = crate::data::SplitMix64::new(77);
        for _ in 0..50 {
            let x: Vec<i8> = (0..16)
                .map(|_| (rng.range(-127.0, 128.0)) as i8)
                .collect();
            let golden = m.forward(&x);
            let sim = run(&cm, &x);
            assert_eq!(sim.logits, golden);
            assert_eq!(run_counted(&cm, &x).logits, golden);
        }
    }

    #[test]
    fn fast_path_with_reused_scratch_matches_counted_path() {
        let m = crate::data::fixtures::quant_model(0x5CAB);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let ds = crate::data::Dataset::synthesize(41, 2, 0.5);
        // ONE arena across the whole corpus — on BOTH paths: stale
        // state from a previous recording must never leak into the next
        let mut s = ScratchArena::for_model(&cm);
        let mut cs = ScratchArena::for_model(&cm);
        for (i, x) in ds.x.iter().enumerate() {
            let fast = run_scratch(&cm, x, &mut s);
            let counted = run_counted_scratch(&cm, x, &mut cs);
            assert_eq!(fast.logits, counted.logits, "recording {i}");
            assert_eq!(fast.predicted, counted.predicted, "recording {i}");
            assert_eq!(fast.counters, counted.counters,
                       "recording {i}: static counters must equal counted");
        }
    }

    #[test]
    #[should_panic(expected = "recording length")]
    fn fast_path_rejects_wrong_input_length() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let _ = run(&cm, &[0i8; 15]);
    }

    #[test]
    fn dense_mode_same_numerics_more_cycles() {
        let m = tiny_model();
        let sparse_cfg = ChipConfig::paper_1d();
        let mut dense_cfg = ChipConfig::paper_1d();
        dense_cfg.zero_skip = false;
        let cm_s = compile(&m, &sparse_cfg, 16).unwrap();
        let cm_d = compile(&m, &dense_cfg, 16).unwrap();
        let x: Vec<i8> = (0..16).map(|i| (i as i8) - 8).collect();
        let rs = run(&cm_s, &x);
        let rd = run(&cm_d, &x);
        assert_eq!(rs.logits, rd.logits);
        assert!(rd.counters.total_cycles() >= rs.counters.total_cycles());
        assert!(rd.counters.total_macs() > rs.counters.total_macs());
        // dense-mode static counters must equal the counted path too
        assert_eq!(rd.counters, run_counted(&cm_d, &x).counters);
    }

    #[test]
    fn counters_populated() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let x = vec![1i8; 16];
        let r = run(&cm, &x);
        let c = &r.counters;
        assert_eq!(c.per_layer.len(), 2);
        assert_eq!(c.input_load_cycles, 16);
        assert!(c.total_cycles() > 16);
        assert!(c.total_macs() > 0);
        assert!(c.total_macs_dense() > c.total_macs());
        assert!(c.total_segment_ops() >= 8 * c.total_macs());
        let t = c.total();
        assert!(t.weight_fetches > 0 && t.output_writes > 0);
        assert!(t.spad.reads > 0 && t.spad.writes > 0);
        assert!(t.pool_ops > 0);
    }

    #[test]
    fn batch_accumulates() {
        let m = tiny_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), 16).unwrap();
        let xs = vec![vec![1i8; 16], vec![-1i8; 16]];
        let (rs, total) = run_batch(&cm, &xs);
        assert_eq!(rs.len(), 2);
        assert_eq!(total.total_cycles(),
                   rs[0].counters.total_cycles() + rs[1].counters.total_cycles());
        // and the empty batch stays the empty default
        let (re, te) = run_batch(&cm, &[]);
        assert!(re.is_empty());
        assert_eq!(te, Counters::default());
    }

    #[test]
    fn parallel_tiles_bit_exact_with_serial_including_counters() {
        let m = crate::data::fixtures::quant_model(0xBEEF);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let ds = crate::data::Dataset::synthesize(17, 2, 0.5);
        for x in &ds.x {
            let a = run_serial(&cm, x);
            let b = run_parallel(&cm, x);
            let c = run(&cm, x);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.logits, c.logits);
            assert_eq!(a.predicted, b.predicted);
            assert_eq!(a.counters, b.counters,
                       "parallel counters must equal serial counters");
            assert_eq!(a.counters, c.counters,
                       "static counters must equal counted counters");
        }
    }

    #[test]
    fn explicit_tiers_are_bit_exact_with_the_detected_tier() {
        let m = crate::data::fixtures::quant_model(0xD15B);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let ds = crate::data::Dataset::synthesize(19, 2, 0.5);
        let mut s = ScratchArena::for_model(&cm);
        for (i, x) in ds.x.iter().enumerate() {
            let auto = run_scratch(&cm, x, &mut s);
            let scalar =
                run_scratch_tier(&cm, x, &mut s, KernelTier::Scalar);
            // Avx2 safely falls back to the scalar twin on hosts
            // without the feature, so this arm is always testable
            let avx2 = run_scratch_tier(&cm, x, &mut s, KernelTier::Avx2);
            assert_eq!(auto.logits, scalar.logits, "recording {i}");
            assert_eq!(auto.logits, avx2.logits, "recording {i}");
        }
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        let m = crate::data::fixtures::quant_model(0xF00D);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let ds = crate::data::Dataset::synthesize(23, 2, 0.5);
        let (rs, ts) = run_batch(&cm, &ds.x);
        let (rp, tp) = run_batch_parallel(&cm, &ds.x);
        assert_eq!(rs.len(), rp.len());
        for (a, b) in rs.iter().zip(&rp) {
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.counters, b.counters);
        }
        assert_eq!(ts, tp);
        // batch totals (static × n) == counted per-recording merge
        let mut counted_total = Counters::default();
        for x in &ds.x {
            counted_total.merge(&run_counted(&cm, x).counters);
        }
        assert_eq!(ts, counted_total);
    }
}
