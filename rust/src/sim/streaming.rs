//! Incremental streaming inference: NNUE-style delta reuse across
//! overlapping windows.
//!
//! A continuous IEGM stream chopped into `hop`-advanced windows shares
//! `frame_len - hop` samples between consecutive windows. Because every
//! conv layer is shift-invariant, most of each layer's output columns
//! for the new window are *exactly* the previous window's columns
//! shifted left — only the columns whose receptive field touches the
//! changed samples (the "fringe") need recomputing. The compiler
//! derives that geometry once per `(schedule, hop)` as a
//! [`StreamPlan`]; this engine holds every layer's full stripe-shaped
//! output in the arena's `carry` slab across hops, shifts the carried
//! columns with one `copy_within` per stripe, and recomputes only the
//! fringe through the same staged packed kernel
//! ([`super::engine::compute_cols`]) the per-window fast path uses.
//!
//! **Bit-exactness contract**: for the same quantized sample stream,
//! every window's logits are bit-identical to running
//! [`crate::sim::run_scratch`] on that window from scratch (enforced
//! by `tests/streaming.rs` across seeds, hops 1..=frame_len, and both
//! paper + ragged fixtures). Carried columns are reused *before*
//! requantization — the carry slab holds raw i32 accumulators, and the
//! fused requant happens on the staging read exactly as on the
//! per-window path — so no rounding path differs between carried and
//! recomputed columns.
//!
//! `hop == frame_len` degenerates gracefully: the plan collapses to
//! all-[`LayerFringe::FULL`] and every window is a full recompute,
//! i.e. today's per-window path with a persistent arena.
//!
//! The engine consumes an already-quantized `i8` sample stream.
//! Per-window AGC (the offline [`crate::signal::preprocess`] /
//! [`crate::coordinator::FrontEnd`] normalization) rescales every
//! window differently and therefore breaks shift invariance; the
//! serving-side adapter that quantizes each sample exactly once
//! (continuous filter + running-RMS gain) is
//! [`crate::coordinator::StreamSession`]. See DESIGN.md §"Incremental
//! streaming: the carry-slab contract".

use std::sync::Arc;

use anyhow::Result;

use crate::arch::KernelTier;
use crate::compiler::{CompiledModel, LayerFringe, StreamPlan};
use crate::nn::{argmax, global_avgpool_stripes, pad_same_from_stripes,
                pad_same_into};
use crate::sim::engine::{compute_cols, run_scratch_tier};
use crate::sim::scratch::ScratchArena;

/// One emitted window result (the streaming analogue of
/// [`crate::sim::SimResult`] minus counters — the static per-window
/// event set does not describe a fringe recompute; see
/// [`StreamingStats`] for the work actually done).
#[derive(Debug, Clone)]
pub struct StreamOutput {
    /// Head logits — bit-exact vs [`crate::sim::run_scratch`] on this
    /// window.
    pub logits: Vec<i32>,
    /// Predicted class ([`crate::nn::argmax`], ties to lower index).
    pub predicted: usize,
}

/// Cumulative work accounting for one [`StreamingEngine`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamingStats {
    /// Windows emitted (including the priming full pass).
    pub windows: u64,
    /// Output columns carried over (shifted, not recomputed), summed
    /// over layers and windows.
    pub carried_cols: u64,
    /// Output columns recomputed through the kernel, summed over
    /// layers and windows.
    pub recomputed_cols: u64,
    /// Canary cross-checks executed (cadence-gated full recomputes
    /// compared against the incremental result; see
    /// [`StreamingEngine::set_canary`]).
    pub canary_checks: u64,
    /// Canary checks that caught a divergence (silent carry-slab
    /// corruption) and forced a resync.
    pub canary_trips: u64,
    /// FULL-recompute resyncs forced (canary trips plus any external
    /// [`StreamingEngine::resync`] calls).
    pub resyncs: u64,
}

/// Incremental streaming executor over one compiled model at one hop.
///
/// Feed raw quantized samples with [`push`](Self::push); a
/// [`StreamOutput`] is emitted for every full window boundary crossed.
/// The first window is always a full pass (nothing to carry from);
/// every subsequent window recomputes only the [`StreamPlan`] fringe.
#[derive(Debug)]
pub struct StreamingEngine {
    cm: Arc<CompiledModel>,
    plan: StreamPlan,
    /// Carry-slab start offset of each layer's stripe block, plus one
    /// trailing total (cumsum of per-layer `out_len`).
    layer_offsets: Vec<usize>,
    /// Pending raw samples; consumed by index, compacted once per push
    /// (same discipline as [`crate::signal::Framer`]).
    buf: Vec<i8>,
    /// Consumed prefix of `buf` (start of the next window).
    pos: usize,
    /// Whether the carry slab holds a previous window's outputs.
    primed: bool,
    arena: ScratchArena,
    stats: StreamingStats,
    /// Kernel tier snapshotted at construction; both the priming full
    /// pass and every fringe recompute dispatch through it.
    tier: KernelTier,
    /// Canary cadence: cross-check every Nth incremental window
    /// against a full recompute (0 = off, the production default — the
    /// clean hot path pays nothing).
    canary_every: u64,
    /// Incremental windows since the last canary check.
    since_canary: u64,
}

impl StreamingEngine {
    /// Build an engine for `hop`-sample advances, dispatching through
    /// the process-wide detected [`KernelTier`]. Errors on a hop
    /// outside `1..=frame_len` (the serving path must not panic on a
    /// caller-supplied hop).
    pub fn new(cm: Arc<CompiledModel>, hop: usize) -> Result<Self> {
        Self::with_tier(cm, hop, KernelTier::current())
    }

    /// [`Self::new`] with an explicitly pinned kernel tier (both tiers
    /// are bit-exact; pinning is for benchmarks and dispatch tests).
    pub fn with_tier(cm: Arc<CompiledModel>, hop: usize, tier: KernelTier)
                     -> Result<Self> {
        let frame_len = cm.static_cost.input_len;
        anyhow::ensure!(hop >= 1 && hop <= frame_len,
                        "stream hop {hop} outside 1..={frame_len}");
        let plan = StreamPlan::of(&cm.schedule, hop);
        let mut layer_offsets = Vec::with_capacity(cm.layers.len() + 1);
        let mut total = 0usize;
        for sched in &cm.schedule.layers {
            layer_offsets.push(total);
            total += sched.out_len;
        }
        layer_offsets.push(total);
        let mut arena = ScratchArena::for_model(&cm);
        arena.carry.resize(total, 0);
        Ok(Self { cm, plan, layer_offsets, buf: Vec::new(), pos: 0,
                  primed: false, arena, stats: StreamingStats::default(),
                  tier, canary_every: 0, since_canary: 0 })
    }

    /// The kernel tier this engine dispatches through.
    pub fn kernel_tier(&self) -> KernelTier {
        self.tier
    }

    /// Window length in samples (the compiled input length).
    pub fn frame_len(&self) -> usize {
        self.cm.static_cost.input_len
    }

    /// Samples the window advances by between emitted outputs.
    pub fn hop(&self) -> usize {
        self.plan.hop
    }

    /// The fringe geometry this engine executes per hop.
    pub fn plan(&self) -> &StreamPlan {
        &self.plan
    }

    /// Buffered samples not yet part of an emitted window.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Cumulative carried/recomputed column accounting.
    pub fn stats(&self) -> StreamingStats {
        self.stats
    }

    /// Arena high-water marks (includes the streaming carry slab).
    pub fn arena_stats(&self) -> crate::sim::ArenaStats {
        self.arena.stats()
    }

    /// Drop all buffered samples and carried state: the next window is
    /// a priming full pass again (use after a gap in the stream, where
    /// carried columns would describe the wrong samples).
    pub fn reset(&mut self) {
        self.buf.clear();
        self.pos = 0;
        self.primed = false;
    }

    /// Invalidate the carried state but keep buffered samples: the
    /// next window is a priming FULL recompute over the same stream.
    /// This is the recovery action after any external integrity check
    /// (scrub, supervisor) reports state it cannot trust — the full
    /// pass rewrites the entire carry slab, so corruption cannot
    /// survive it.
    pub fn resync(&mut self) {
        self.primed = false;
        self.stats.resyncs += 1;
    }

    /// Arm the streaming canary: every `every`-th incremental window
    /// is re-run from scratch through [`crate::sim::run_scratch`] and
    /// compared bit-for-bit with the carry-slab result. On divergence
    /// the engine emits the trusted full-recompute logits, counts a
    /// [`StreamingStats::canary_trips`], and forces a resync (the next
    /// window re-primes FULL). `every == 0` disarms (the default).
    ///
    /// Cadence contract (DESIGN.md §8): `every == 1` checks every
    /// window, so no corrupted diagnosis can ever be emitted — the
    /// zero-undetected-corruption configuration, at ~2× hot-path cost.
    /// Larger cadences bound the overhead instead (`1/every` extra
    /// full passes) and bound detection latency by `every` windows,
    /// but a corrupted column that shifts out of the carry region
    /// between checks can escape detection — choose per deployment.
    pub fn set_canary(&mut self, every: u64) {
        self.canary_every = every;
        self.since_canary = 0;
    }

    /// The armed canary cadence (0 = off).
    pub fn canary_every(&self) -> u64 {
        self.canary_every
    }

    /// Total words in the streaming carry slab (the fault-injection
    /// site space of [`crate::reliability::FaultPlan::carry_seu`]).
    pub fn carry_words(&self) -> usize {
        self.layer_offsets.last().copied().unwrap_or(0)
    }

    /// Fault-injection hook: XOR one word of the carry slab (SEU in
    /// the activation state). Returns `false` (and does nothing) when
    /// the site is out of range. A no-op for correctness when the
    /// engine is unprimed — the priming pass rewrites the whole slab —
    /// which is why [`crate::reliability::FaultPlan::carry_seu`] never
    /// schedules window 0.
    pub fn corrupt_carry(&mut self, index: usize, xor: i32) -> bool {
        if index >= self.arena.carry.len() {
            return false;
        }
        self.arena.carry[index] ^= xor;
        true
    }

    /// Feed quantized samples; returns one output per completed
    /// window. Consumption is index-based with a single compaction at
    /// the end, so a push emitting many windows does one memmove, not
    /// one per window.
    pub fn push(&mut self, samples: &[i8]) -> Vec<StreamOutput> {
        self.buf.extend_from_slice(samples);
        let frame_len = self.frame_len();
        let hop = self.plan.hop;
        let mut outs = Vec::new();
        while self.buf.len() - self.pos >= frame_len {
            outs.push(self.pass());
            self.pos += hop;
        }
        if self.pos > 0 {
            let len = self.buf.len();
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(len - self.pos);
            self.pos = 0;
        }
        outs
    }

    /// Execute the window starting at `self.pos`: a priming full pass
    /// if the carry slab is cold, otherwise the planned fringe
    /// recompute. Either way the carry slab ends up holding this
    /// window's complete per-layer stripes, and the head readout pools
    /// from the last layer's block.
    fn pass(&mut self) -> StreamOutput {
        let cm = Arc::clone(&self.cm);
        let frame_len = cm.static_cost.input_len;
        let window = &self.buf[self.pos..self.pos + frame_len];
        let offsets = &self.layer_offsets;
        // `LayerFringe::FULL` per layer reproduces the per-window path
        // (empty shift, whole range recomputed), so priming needs no
        // separate code path — only a different fringe table.
        let primed = self.primed;
        let ScratchArena { act, padded, win, carry, .. } = &mut self.arena;

        act.clear();
        act.extend(window.iter().map(|&v| v as i32));
        let mut l = frame_len / cm.layers[0].cin;

        for (li, layer) in cm.layers.iter().enumerate() {
            let sched = &cm.schedule.layers[li];
            let fr = if primed { self.plan.layers[li] }
                     else { LayerFringe::FULL };
            if li == 0 {
                pad_same_into(act, l, layer.cin, layer.k, layer.stride,
                              padded);
            } else {
                // fused requant drain off the *carried* previous-layer
                // stripes — already updated for this window by the
                // previous loop iteration
                let prev = &cm.layers[li - 1];
                let prev_out = &carry[offsets[li - 1]..offsets[li]];
                pad_same_from_stripes(&sched.in_stripes, prev_out, l,
                                      layer.cin, layer.k, layer.stride,
                                      &prev.m0, prev.shift, prev.relu,
                                      padded);
            }
            let lout = sched.lout;
            let cur = &mut carry[offsets[li]..offsets[li + 1]];
            if fr.carried() > 0 {
                // columns [head, reuse_end) of the new window equal
                // columns [head+shift, reuse_end+shift) of the old one:
                // one overlapping-safe memmove per stripe, in place
                for st in &sched.stripes {
                    let stripe =
                        &mut cur[st.offset..st.offset + lout * st.live];
                    stripe.copy_within(
                        (fr.head + fr.shift) * st.live
                            ..(fr.reuse_end + fr.shift) * st.live,
                        fr.head * st.live);
                }
            }
            // recompute the fringe: head columns whose receptive field
            // touches the left 'same' padding, and the tail from the
            // first column that sees any new sample
            compute_cols(layer, sched, padded, cur, win, 0, fr.head,
                         self.tier);
            compute_cols(layer, sched, padded, cur, win, fr.reuse_end,
                         lout, self.tier);
            self.stats.carried_cols += fr.carried() as u64;
            self.stats.recomputed_cols += fr.recomputed(lout) as u64;
            l = lout;
        }

        let cout = cm.layers.last().map(|ly| ly.cout).unwrap_or(0);
        let logits = match cm.schedule.layers.last() {
            Some(sched) => {
                let n = cm.layers.len();
                let head = &carry[offsets[n - 1]..offsets[n]];
                global_avgpool_stripes(&sched.stripes, head, l, cout)
            }
            None => Vec::new(),
        };
        self.primed = true;
        self.stats.windows += 1;

        // Streaming canary: cadence-gated cross-check of the
        // incremental result against a from-scratch recompute of the
        // identical window. `run_scratch_tier` uses only the arena's
        // per-pass scratch (`act`/`padded`/`out`/`win`) — it never
        // reads or writes the carry slab — so running it here cannot
        // perturb the carried state it is auditing. Only incremental
        // windows are checked: the priming pass IS a full recompute.
        if primed && self.canary_every > 0 {
            self.since_canary += 1;
            if self.since_canary >= self.canary_every {
                self.since_canary = 0;
                self.stats.canary_checks += 1;
                let window =
                    &self.buf[self.pos..self.pos + cm.static_cost.input_len];
                let oracle =
                    run_scratch_tier(&cm, window, &mut self.arena, self.tier);
                if oracle.logits != logits {
                    // Silent state corruption caught: emit the trusted
                    // full-recompute result and invalidate the slab so
                    // the next window re-primes FULL.
                    self.stats.canary_trips += 1;
                    self.stats.resyncs += 1;
                    self.primed = false;
                    return StreamOutput { predicted: oracle.predicted,
                                          logits: oracle.logits };
                }
            }
        }

        let predicted = argmax(&logits);
        StreamOutput { logits, predicted }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::data::fixtures;
    use crate::sim::run_scratch;

    /// Quantized pseudo-stream long enough for several hops.
    fn qstream(seed: u64, n: usize) -> Vec<i8> {
        let mut rng = crate::data::SplitMix64::new(seed);
        (0..n).map(|_| rng.range(-127.0, 128.0) as i8).collect()
    }

    #[test]
    fn matches_full_recompute_paper_model_hop32() {
        let m = fixtures::quant_model(0xA11CE);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let mut eng = StreamingEngine::new(Arc::clone(&cm), 32).unwrap();
        let stream = qstream(7, crate::REC_LEN + 32 * 6);
        let outs = eng.push(&stream);
        assert_eq!(outs.len(), 7);
        let mut s = ScratchArena::for_model(&cm);
        for (i, o) in outs.iter().enumerate() {
            let w = &stream[i * 32..i * 32 + crate::REC_LEN];
            let full = run_scratch(&cm, w, &mut s);
            assert_eq!(o.logits, full.logits, "window {i}");
            assert_eq!(o.predicted, full.predicted, "window {i}");
        }
        let st = eng.stats();
        assert_eq!(st.windows, 7);
        assert!(st.carried_cols > 0, "hop 32 must reuse columns");
    }

    #[test]
    fn chunked_pushes_equal_one_push() {
        let m = fixtures::quant_model(0xF0);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let stream = qstream(11, crate::REC_LEN + 64 * 3);
        let whole: Vec<StreamOutput> =
            StreamingEngine::new(Arc::clone(&cm), 64).unwrap().push(&stream);
        let mut eng = StreamingEngine::new(cm, 64).unwrap();
        let mut chunked = Vec::new();
        // ragged chunk sizes, including empty
        for chunk in [0usize, 3, 100, 1, 511, 200, 700].iter()
            .scan(0usize, |at, &n| {
                let end = (*at + n).min(stream.len());
                let c = &stream[*at..end];
                *at = end;
                Some(c)
            })
        {
            chunked.extend(eng.push(chunk));
        }
        chunked.extend(eng.push(&stream[1515.min(stream.len())..]));
        assert_eq!(whole.len(), chunked.len());
        for (a, b) in whole.iter().zip(&chunked) {
            assert_eq!(a.logits, b.logits);
        }
    }

    #[test]
    fn rejects_bad_hop() {
        let m = fixtures::quant_model(1);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        assert!(StreamingEngine::new(Arc::clone(&cm), 0).is_err());
        assert!(StreamingEngine::new(Arc::clone(&cm), crate::REC_LEN + 1)
                .is_err());
        assert!(StreamingEngine::new(cm, crate::REC_LEN).is_ok());
    }

    #[test]
    fn canary_is_silent_on_a_clean_stream() {
        let m = fixtures::quant_model(0xCAFE);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let stream = qstream(21, crate::REC_LEN + 32 * 8);
        // canary every window vs canary off: identical outputs
        let plain: Vec<StreamOutput> =
            StreamingEngine::new(Arc::clone(&cm), 32).unwrap().push(&stream);
        let mut eng = StreamingEngine::new(Arc::clone(&cm), 32).unwrap();
        eng.set_canary(1);
        let checked = eng.push(&stream);
        assert_eq!(plain.len(), checked.len());
        for (a, b) in plain.iter().zip(&checked) {
            assert_eq!(a.logits, b.logits);
        }
        let st = eng.stats();
        assert_eq!(st.canary_checks, st.windows - 1,
                   "every incremental window must be checked");
        assert_eq!(st.canary_trips, 0);
        assert_eq!(st.resyncs, 0);
    }

    #[test]
    fn canary_catches_carry_corruption_and_resyncs_bit_exact() {
        let m = fixtures::quant_model(0xC0DE);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let mut eng = StreamingEngine::new(Arc::clone(&cm), 32).unwrap();
        eng.set_canary(1);
        let stream = qstream(5, crate::REC_LEN + 32 * 6);
        let mut s = ScratchArena::for_model(&cm);

        // prime + one incremental window
        let mut emitted = eng.push(&stream[..crate::REC_LEN + 32]);
        assert_eq!(emitted.len(), 2);
        // corrupt sites across the whole slab: at least one lands in a
        // reused (non-fringe) column and poisons the next pass
        for i in (0..eng.carry_words()).step_by(7) {
            assert!(eng.corrupt_carry(i, 0x40_0000));
        }
        // windows 2..6: the corrupted carry would poison them all, but
        // the per-window canary emits the oracle result and resyncs
        for w in 2..7 {
            let lo = crate::REC_LEN + 32 * (w - 1);
            emitted.extend(eng.push(&stream[lo..lo + 32]));
        }
        let st = eng.stats();
        assert!(st.canary_trips >= 1, "the corruption must be caught");
        assert_eq!(st.resyncs, st.canary_trips);
        // EVERY emitted window, including the tripped one, matches the
        // offline oracle bit-exactly
        for (i, o) in emitted.iter().enumerate() {
            let w = &stream[i * 32..i * 32 + crate::REC_LEN];
            let full = run_scratch(&cm, w, &mut s);
            assert_eq!(o.logits, full.logits, "window {i}");
        }
    }

    #[test]
    fn corrupt_carry_rejects_out_of_range_sites() {
        let m = fixtures::quant_model(0xC0DE);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let mut eng = StreamingEngine::new(cm, 32).unwrap();
        assert!(eng.carry_words() > 0);
        assert!(!eng.corrupt_carry(eng.carry_words(), 1));
        assert!(eng.corrupt_carry(eng.carry_words() - 1, 1));
    }

    #[test]
    fn resync_recovers_from_unchecked_corruption() {
        let m = fixtures::quant_model(0x5AFE);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let mut eng = StreamingEngine::new(Arc::clone(&cm), 32).unwrap();
        let stream = qstream(17, crate::REC_LEN + 32 * 2);
        let _ = eng.push(&stream[..crate::REC_LEN]);
        assert!(eng.corrupt_carry(0, 0x10_0000));
        // no canary armed — an external check orders the resync; the
        // next window is a FULL recompute and must be oracle-exact
        eng.resync();
        let outs = eng.push(&stream[crate::REC_LEN..crate::REC_LEN + 32]);
        assert_eq!(outs.len(), 1);
        let w = &stream[32..32 + crate::REC_LEN];
        let full = run_scratch(&cm, w, &mut ScratchArena::for_model(&cm));
        assert_eq!(outs[0].logits, full.logits);
        assert_eq!(eng.stats().resyncs, 1);
    }

    #[test]
    fn reset_reprimes_cleanly() {
        let m = fixtures::quant_model(0xDD);
        let cm = Arc::new(
            compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap());
        let mut eng = StreamingEngine::new(Arc::clone(&cm), 128).unwrap();
        let a = qstream(3, crate::REC_LEN + 128);
        let _ = eng.push(&a);
        eng.reset();
        assert_eq!(eng.pending(), 0);
        // after reset the engine must not reuse stale carry state
        let b = qstream(4, crate::REC_LEN);
        let outs = eng.push(&b);
        assert_eq!(outs.len(), 1);
        let full = run_scratch(&cm, &b, &mut ScratchArena::for_model(&cm));
        assert_eq!(outs[0].logits, full.logits);
    }
}
