//! Reusable simulation scratch arena for the zero-allocation fast path.
//!
//! [`SimScratch`] owns the ping-pong activation buffers, the padded
//! window staging buffer and the layer accumulator slab, all sized at
//! construction from the compiled schedule's **maximum layer
//! footprint**. After the first use every buffer operation stays within
//! reserved capacity, so [`crate::sim::run_scratch`] performs zero heap
//! allocation in its compute kernel — the only per-recording
//! allocations left are the returned `SimResult`'s logits and the
//! cloned static counters.
//!
//! Ownership story (DESIGN.md §4): one scratch per execution context —
//! each fleet shard's `Backend` owns one, a single `Service`'s backend
//! owns one, `run_batch_parallel` gives each rayon worker its own.
//! Scratches are never shared between concurrent recordings.

use crate::compiler::CompiledModel;

/// Preallocated working memory for one simulation context.
#[derive(Debug)]
pub struct SimScratch {
    /// Current layer-input activations, `[L, Cin]` row-major
    /// (ping side; refilled in place by the requant drain).
    pub(crate) act: Vec<i32>,
    /// 'same'-padded window buffer for the layer being executed.
    pub(crate) padded: Vec<i32>,
    /// Layer output accumulators, `[Lout, Cout]` row-major (pong side).
    pub(crate) out: Vec<i32>,
}

impl SimScratch {
    /// Size every buffer for the model's largest layer footprint.
    pub fn for_model(cm: &CompiledModel) -> Self {
        let mut max_act = cm.static_cost.input_len;
        let mut max_padded = 0usize;
        let mut max_out = 0usize;
        for (layer, sched) in cm.layers.iter().zip(&cm.schedule.layers) {
            max_padded = max_padded.max(sched.l_padded * layer.cin);
            let o = sched.lout * layer.cout;
            max_out = max_out.max(o);
            if !layer.is_head {
                // this layer's drain is the next layer's input
                max_act = max_act.max(o);
            }
        }
        Self {
            act: Vec::with_capacity(max_act),
            padded: Vec::with_capacity(max_padded),
            out: Vec::with_capacity(max_out),
        }
    }

    /// Total reserved capacity in words (diagnostics / benches).
    pub fn capacity_words(&self) -> usize {
        self.act.capacity() + self.padded.capacity() + self.out.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::data::fixtures;

    #[test]
    fn sized_for_the_largest_layer() {
        let m = fixtures::default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let s = SimScratch::for_model(&cm);
        // layer 1 dominates: padded 517×1 is smaller than layer 2's
        // 131×16; act must hold the 512-sample input and every
        // intermediate feature map
        assert!(s.act.capacity() >= crate::REC_LEN);
        for (layer, sched) in cm.layers.iter().zip(&cm.schedule.layers) {
            assert!(s.padded.capacity() >= sched.l_padded * layer.cin);
            assert!(s.out.capacity() >= sched.lout * layer.cout);
            if !layer.is_head {
                assert!(s.act.capacity() >= sched.lout * layer.cout);
            }
        }
        assert!(s.capacity_words() > 0);
    }
}
