//! The unified scratch arena: one reusable working-memory slab for
//! **every** execution path.
//!
//! [`ScratchArena`] owns the ping-pong activation buffers, the padded
//! window staging buffer, the tile-major layer output slab, the
//! position-block window stage, and the counted path's lane
//! accumulators + reusable [`Spe`] instance. Three paths share it:
//!
//! * fast ([`crate::sim::run_scratch`]) — `act`/`padded`/`out`/`win`;
//! * counted reference ([`crate::sim::run_counted_scratch`]) —
//!   `act`/`padded`/`out` plus `accs` and the arena `Spe`;
//! * golden ([`crate::nn::QuantModel::forward_scratch`]) —
//!   `act`/`padded`/`out` as plain row-major slabs.
//!
//! Every buffer operation is `clear`/`resize` before use, so
//! correctness never depends on capacity or on which model (or path)
//! used the arena last — an arena can serve different-shaped models
//! back to back and simply grows to the largest footprint it has seen.
//! [`ScratchArena::for_model`] pre-reserves a compiled model's maximum
//! layer footprint so the steady state performs zero heap allocation;
//! [`ScratchArena::new`] starts empty and warms up on first use.
//!
//! Ownership story (DESIGN.md §4): one arena per execution context —
//! each backend (`ChipSim` AND `Golden`) owns one, hence one per fleet
//! shard and one per `Service`; `run_batch_parallel` gives each rayon
//! worker its own. Arenas are never shared between concurrent
//! recordings.

use crate::arch::Spe;
use crate::compiler::CompiledModel;

use super::engine::POS_BLOCK;

/// Preallocated working memory for one execution context (any path).
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Current layer-input activations, `[L, Cin]` row-major
    /// (ping side; refilled in place by the requant drain).
    pub(crate) act: Vec<i32>,
    /// 'same'-padded window buffer for the layer being executed.
    pub(crate) padded: Vec<i32>,
    /// Layer output accumulators (pong side): tile-major
    /// `[ch_tile][lout][lane]` stripes on the simulator paths,
    /// row-major `[Lout, Cout]` on the golden path.
    pub(crate) out: Vec<i32>,
    /// Staged `[window_len, POS_BLOCK]` window block
    /// ([`crate::arch::stage_window_block`], fast path only).
    pub(crate) win: Vec<i32>,
    /// Counted-path lane accumulators (`m` words, drained per position).
    pub(crate) accs: Vec<i32>,
    /// Counted-path reusable SPE instance (`m` lanes), reset per tile.
    pub(crate) spe: Option<Spe>,
}

impl ScratchArena {
    /// An empty arena; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for the model's largest layer footprint.
    pub fn for_model(cm: &CompiledModel) -> Self {
        let mut max_act = cm.static_cost.input_len;
        let mut max_padded = 0usize;
        let mut max_out = 0usize;
        let mut max_win = 0usize;
        for (layer, sched) in cm.layers.iter().zip(&cm.schedule.layers) {
            max_padded = max_padded.max(sched.l_padded * layer.cin);
            max_out = max_out.max(sched.out_len);
            max_win = max_win.max(sched.window_len * POS_BLOCK);
            if !layer.is_head {
                // this layer's drain is the next layer's input
                max_act = max_act.max(sched.out_len);
            }
        }
        Self {
            act: Vec::with_capacity(max_act),
            padded: Vec::with_capacity(max_padded),
            out: Vec::with_capacity(max_out),
            win: Vec::with_capacity(max_win),
            accs: Vec::with_capacity(cm.cfg.m),
            spe: Some(Spe::new(cm.cfg.m)),
        }
    }

    /// The counted path's reusable SPE, (re)built only when the lane
    /// count changes (associated fn so callers can hold other arena
    /// fields borrowed); the engine resets its counters per tile.
    pub(crate) fn spe_for(spe: &mut Option<Spe>, m: usize) -> &mut Spe {
        if spe.as_ref().map_or(true, |s| s.num_lanes() != m) {
            *spe = Some(Spe::new(m));
        }
        spe.as_mut().unwrap()
    }

    /// Total reserved capacity in words (diagnostics / benches).
    pub fn capacity_words(&self) -> usize {
        self.act.capacity() + self.padded.capacity() + self.out.capacity()
            + self.win.capacity() + self.accs.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::data::fixtures;

    #[test]
    fn sized_for_the_largest_layer() {
        let m = fixtures::default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let s = ScratchArena::for_model(&cm);
        // layer 1 dominates: padded 517×1 is smaller than layer 2's
        // 131×16; act must hold the 512-sample input and every
        // intermediate feature map
        assert!(s.act.capacity() >= crate::REC_LEN);
        for (layer, sched) in cm.layers.iter().zip(&cm.schedule.layers) {
            assert!(s.padded.capacity() >= sched.l_padded * layer.cin);
            assert!(s.out.capacity() >= sched.out_len);
            assert!(s.win.capacity() >= sched.window_len * POS_BLOCK);
            if !layer.is_head {
                assert!(s.act.capacity() >= sched.out_len);
            }
        }
        assert_eq!(s.spe.as_ref().map(|spe| spe.num_lanes()), Some(cm.cfg.m));
        assert!(s.capacity_words() > 0);
    }

    #[test]
    fn empty_arena_serves_any_model() {
        // ScratchArena::new starts with zero capacity; buffers must
        // grow transparently, and a model switch must rebuild the SPE
        let m = fixtures::default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let mut s = ScratchArena::new();
        let x = vec![1i8; crate::REC_LEN];
        let from_empty = crate::sim::run_scratch(&cm, &x, &mut s);
        let fresh = crate::sim::run(&cm, &x);
        assert_eq!(from_empty.logits, fresh.logits);
        let spe = ScratchArena::spe_for(&mut s.spe, 4);
        assert_eq!(spe.num_lanes(), 4);
        let spe = ScratchArena::spe_for(&mut s.spe, 4);
        assert_eq!(spe.num_lanes(), 4); // reused, not rebuilt
    }
}
