//! The unified scratch arena: one reusable working-memory slab for
//! **every** execution path.
//!
//! [`ScratchArena`] owns the input staging buffer, the padded window
//! buffer, the layer output slab (stripe-shaped on the simulator
//! paths), the position-block window stage, and the counted path's
//! lane accumulators + reusable [`Spe`] instance. Three paths share
//! it:
//!
//! * fast ([`crate::sim::run_scratch`]) — `act`/`padded`/`out`/`win`;
//! * counted reference ([`crate::sim::run_counted_scratch`]) —
//!   `act`/`padded`/`out` plus `accs` and the arena `Spe`;
//! * golden ([`crate::nn::QuantModel::forward_scratch`]) —
//!   `act`/`padded`/`out` as plain row-major slabs.
//!
//! Since the requant drain was fused into layer staging there is no
//! ping/pong pair of feature-map buffers: `act` holds only the
//! network input, and each layer's `padded` window buffer is staged
//! straight from the previous layer's `out` (stripes on the sim
//! paths, conv accumulators on the golden path) with the requant
//! fused into the read. Only the head readout leaves `out`'s stripe
//! space. See DESIGN.md §"Data layout contract" for who owns which
//! buffer at each phase.
//!
//! Every buffer operation is `clear`/`resize` before use, so
//! correctness never depends on capacity or on which model (or path)
//! used the arena last — an arena can serve different-shaped models
//! back to back and simply grows to the largest footprint it has seen.
//! [`ScratchArena::for_model`] pre-reserves a compiled model's maximum
//! layer footprint so the steady state performs zero heap allocation;
//! [`ScratchArena::new`] starts empty and warms up on first use.
//! [`ScratchArena::stats`] reports the per-buffer capacity high-water
//! marks (capacities only grow), which the fleet surfaces per shard
//! ([`crate::coordinator::FleetReport`]) to catch accidental
//! per-recording growth.
//!
//! Ownership story (DESIGN.md §4): one arena per execution context —
//! each backend (`ChipSim` AND `Golden`) owns one, hence one per fleet
//! shard and one per `Service`; `run_batch_parallel` gives each rayon
//! worker its own. Arenas are never shared between concurrent
//! recordings.

use crate::arch::Spe;
use crate::compiler::CompiledModel;

use super::engine::POS_BLOCK;

/// Per-buffer capacity high-water marks of a [`ScratchArena`] in
/// words (capacities only grow, so a snapshot IS the high-water
/// mark). Reported per fleet shard through
/// [`crate::coordinator::ShardReport`] and element-wise-maxed into
/// [`crate::coordinator::FleetReport`] so accidental per-recording
/// arena growth is visible in serving telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Network-input staging buffer.
    pub act_words: usize,
    /// 'same'-padded window buffer.
    pub padded_words: usize,
    /// Layer output slab (stripes / golden accumulators).
    pub out_words: usize,
    /// Fast-path position-block window stage.
    pub win_words: usize,
    /// Counted-path lane accumulators.
    pub accs_words: usize,
    /// Streaming-path per-layer stripe carry slab
    /// ([`crate::sim::StreamingEngine`]'s ring of carried columns).
    pub carry_words: usize,
}

impl ArenaStats {
    /// Total reserved words across every buffer.
    pub fn total_words(&self) -> usize {
        self.act_words + self.padded_words + self.out_words
            + self.win_words + self.accs_words + self.carry_words
    }

    /// Element-wise maximum (the fleet-level high-water aggregate).
    pub fn max(&self, other: &ArenaStats) -> ArenaStats {
        ArenaStats {
            act_words: self.act_words.max(other.act_words),
            padded_words: self.padded_words.max(other.padded_words),
            out_words: self.out_words.max(other.out_words),
            win_words: self.win_words.max(other.win_words),
            accs_words: self.accs_words.max(other.accs_words),
            carry_words: self.carry_words.max(other.carry_words),
        }
    }
}

impl std::fmt::Display for ArenaStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f,
               "{} words (act {}, padded {}, out {}, win {}, accs {}, \
                carry {})",
               self.total_words(), self.act_words, self.padded_words,
               self.out_words, self.win_words, self.accs_words,
               self.carry_words)
    }
}

/// Preallocated working memory for one execution context (any path).
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Network-input staging, `[L, Cin]` row-major — the input is the
    /// only row-major activation map in a pass; intermediate layers
    /// stage straight from `out` (fused requant drain).
    pub(crate) act: Vec<i32>,
    /// 'same'-padded window buffer for the layer being executed.
    pub(crate) padded: Vec<i32>,
    /// Layer output accumulators: tile-major `[ch_tile][lout][lane]`
    /// stripes on the simulator paths, row-major `[Lout, Cout]` conv
    /// accumulators on the golden path. Doubles as the next layer's
    /// staging source, read back by the fused requant+pad before it
    /// is resized for the next layer's output.
    pub(crate) out: Vec<i32>,
    /// Staged `[window_len, POS_BLOCK]` window block
    /// ([`crate::arch::stage_window_block`], fast path only). Shared
    /// by both kernel tiers: the AVX2 kernel loads its 8-wide rows
    /// straight from this stage with unaligned vector loads, so the
    /// layout contract is identical to the scalar twin's.
    pub(crate) win: Vec<i32>,
    /// Counted-path lane accumulators (`m` words, drained per position).
    pub(crate) accs: Vec<i32>,
    /// Counted-path reusable SPE instance (`m` lanes), reset per tile.
    pub(crate) spe: Option<Spe>,
    /// Streaming-path carry slab: every layer's full stripe-shaped
    /// output, concatenated in layer order, persisted across hops so
    /// [`crate::sim::StreamingEngine`] can shift carried columns and
    /// recompute only the fringe. Unused (and never grown) by the
    /// per-window paths.
    pub(crate) carry: Vec<i32>,
}

impl ScratchArena {
    /// An empty arena; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for the model's largest layer footprint.
    pub fn for_model(cm: &CompiledModel) -> Self {
        // `act` stages only the network input: the fused requant drain
        // means no intermediate feature map ever lands there
        let max_act = cm.static_cost.input_len;
        let mut max_padded = 0usize;
        let mut max_out = 0usize;
        let mut max_win = 0usize;
        for (layer, sched) in cm.layers.iter().zip(&cm.schedule.layers) {
            max_padded = max_padded.max(sched.l_padded * layer.cin);
            max_out = max_out.max(sched.out_len);
            max_win = max_win.max(sched.window_len * POS_BLOCK);
        }
        Self {
            act: Vec::with_capacity(max_act),
            padded: Vec::with_capacity(max_padded),
            out: Vec::with_capacity(max_out),
            win: Vec::with_capacity(max_win),
            accs: Vec::with_capacity(cm.cfg.m),
            spe: Some(Spe::new(cm.cfg.m)),
            // the carry slab belongs to the streaming path only; the
            // StreamingEngine sizes it (sum of out_len over layers) on
            // construction, so the per-window paths don't pay for it
            carry: Vec::new(),
        }
    }

    /// The counted path's reusable SPE, (re)built only when the lane
    /// count changes (associated fn so callers can hold other arena
    /// fields borrowed); the engine resets its counters per tile.
    pub(crate) fn spe_for(spe: &mut Option<Spe>, m: usize) -> &mut Spe {
        if spe.as_ref().map_or(true, |s| s.num_lanes() != m) {
            *spe = Some(Spe::new(m));
        }
        spe.as_mut().unwrap()
    }

    /// Fault-injection hook: force a stuck-at accumulator lane on the
    /// counted path's SPE ([`crate::arch::Spe::force_stuck`],
    /// [`crate::reliability::FaultKind::StuckLane`]). Returns `false`
    /// when the arena has no SPE yet or the lane is out of range. The
    /// fault survives per-tile SPE resets (it models broken silicon)
    /// but not a model switch that rebuilds the SPE with a different
    /// lane count.
    pub fn force_stuck_lane(&mut self, lane: usize, value: i32) -> bool {
        self.spe.as_mut().is_some_and(|s| s.force_stuck(lane, value))
    }

    /// Clear every stuck-at lane override (the repair action).
    pub fn clear_stuck_lanes(&mut self) {
        if let Some(s) = self.spe.as_mut() {
            s.clear_stuck();
        }
    }

    /// Per-buffer capacity high-water marks (capacities only grow, so
    /// this snapshot is the lifetime high-water mark of the arena).
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            act_words: self.act.capacity(),
            padded_words: self.padded.capacity(),
            out_words: self.out.capacity(),
            win_words: self.win.capacity(),
            accs_words: self.accs.capacity(),
            carry_words: self.carry.capacity(),
        }
    }

    /// Total reserved capacity in words (diagnostics / benches).
    pub fn capacity_words(&self) -> usize {
        self.stats().total_words()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::data::fixtures;

    #[test]
    fn sized_for_the_largest_layer() {
        let m = fixtures::default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let s = ScratchArena::for_model(&cm);
        // act stages only the 512-sample network input: with the
        // requant drain fused into staging, no intermediate feature
        // map is ever materialized there
        assert!(s.act.capacity() >= crate::REC_LEN);
        for (layer, sched) in cm.layers.iter().zip(&cm.schedule.layers) {
            assert!(s.padded.capacity() >= sched.l_padded * layer.cin);
            assert!(s.out.capacity() >= sched.out_len);
            assert!(s.win.capacity() >= sched.window_len * POS_BLOCK);
        }
        assert_eq!(s.spe.as_ref().map(|spe| spe.num_lanes()), Some(cm.cfg.m));
        assert!(s.capacity_words() > 0);
    }

    #[test]
    fn stats_report_per_buffer_high_water_marks() {
        let m = fixtures::default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let empty = ScratchArena::new().stats();
        assert_eq!(empty, ArenaStats::default());
        assert_eq!(empty.total_words(), 0);
        let s = ScratchArena::for_model(&cm);
        let st = s.stats();
        assert_eq!(st.act_words, s.act.capacity());
        assert_eq!(st.out_words, s.out.capacity());
        assert_eq!(st.total_words(), s.capacity_words());
        // the carry slab is streaming-only: a per-window arena never
        // grows it
        assert_eq!(st.carry_words, 0);
        // element-wise max aggregates fleet-style
        let bigger = ArenaStats { out_words: st.out_words + 1, ..empty };
        let agg = st.max(&bigger);
        assert_eq!(agg.out_words, st.out_words + 1);
        assert_eq!(agg.act_words, st.act_words);
        // Display renders without panicking
        let _ = format!("{st}");
    }

    #[test]
    fn stuck_lane_perturbs_counted_path_and_repair_restores_it() {
        // detection vector for StuckLane faults: the counted reference
        // path drains through the arena's SPE, so a forced lane makes
        // it diverge from the (unfaulted) fast path; clearing restores
        // bit-exactness
        let m = fixtures::quant_model(0x57CC);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let x: Vec<i8> = (0..crate::REC_LEN).map(|i| (i % 160) as i8 - 80)
            .collect();
        let healthy = crate::sim::run(&cm, &x);
        let mut s = ScratchArena::for_model(&cm);
        assert!(!s.force_stuck_lane(cm.cfg.m, 1), "out-of-range lane");
        assert!(s.force_stuck_lane(0, 0x0F_FFFF));
        let faulty = crate::sim::run_counted_scratch(&cm, &x, &mut s);
        assert_ne!(faulty.logits, healthy.logits,
                   "a stuck accumulator lane must move the logits");
        s.clear_stuck_lanes();
        let repaired = crate::sim::run_counted_scratch(&cm, &x, &mut s);
        assert_eq!(repaired.logits, healthy.logits);
    }

    #[test]
    fn empty_arena_serves_any_model() {
        // ScratchArena::new starts with zero capacity; buffers must
        // grow transparently, and a model switch must rebuild the SPE
        let m = fixtures::default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let mut s = ScratchArena::new();
        let x = vec![1i8; crate::REC_LEN];
        let from_empty = crate::sim::run_scratch(&cm, &x, &mut s);
        let fresh = crate::sim::run(&cm, &x);
        assert_eq!(from_empty.logits, fresh.logits);
        let spe = ScratchArena::spe_for(&mut s.spe, 4);
        assert_eq!(spe.num_lanes(), 4);
        let spe = ScratchArena::spe_for(&mut s.spe, 4);
        assert_eq!(spe.num_lanes(), 4); // reused, not rebuilt
    }
}
