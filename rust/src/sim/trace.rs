//! Human-readable per-layer trace tables.

use crate::sim::Counters;

/// Render a per-layer cycle/energy-event table (used by the CLI's
/// `simulate` subcommand and the chip_report example).
pub fn render_trace(c: &Counters, freq_hz: f64) -> String {
    let mut s = String::new();
    s.push_str("layer   cycles     time(µs)   MACs(nnz)  MACs(dense)  util%   spad-rd   w-fetch\n");
    let mut total_util_num = 0.0;
    for (i, l) in c.per_layer.iter().enumerate() {
        let t_us = l.cycles as f64 / freq_hz * 1e6;
        // utilization: executed MACs per cycle vs the engaged array's
        // peak of 1 MAC/lane/cycle is folded into the caller's report;
        // here we show nnz/dense density
        let util = if l.macs_dense > 0 {
            100.0 * l.macs as f64 / l.macs_dense as f64
        } else {
            0.0
        };
        total_util_num += util;
        s.push_str(&format!(
            "{:>5}  {:>8}  {:>9.2}  {:>10}  {:>11}  {:>5.1}  {:>8}  {:>8}\n",
            i + 1, l.cycles, t_us, l.macs, l.macs_dense, util,
            l.spad.reads, l.weight_fetches));
    }
    let total = c.total();
    s.push_str(&format!(
        "total  {:>8}  {:>9.2}  {:>10}  {:>11}  {:>5.1}  {:>8}  {:>8}\n",
        c.total_cycles(),
        c.total_cycles() as f64 / freq_hz * 1e6,
        total.macs, total.macs_dense,
        total_util_num / c.per_layer.len().max(1) as f64,
        total.spad.reads, total.weight_fetches));
    s.push_str(&format!("(+ input load {} cy, readout {} cy)\n",
                        c.input_load_cycles, c.readout_cycles));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::LayerCounters;

    #[test]
    fn renders_rows_per_layer() {
        let mut c = Counters::default();
        c.per_layer.push(LayerCounters { cycles: 100, macs: 50,
                                         macs_dense: 100, ..Default::default() });
        c.per_layer.push(LayerCounters { cycles: 200, macs: 80,
                                         macs_dense: 160, ..Default::default() });
        c.input_load_cycles = 512;
        let t = render_trace(&c, 400e6);
        assert_eq!(t.lines().count(), 5); // header + 2 layers + total + note
        assert!(t.contains("512"));
        assert!(t.contains("total"));
    }
}
