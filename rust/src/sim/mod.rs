//! Cycle-accurate simulator of the SPE array.
//!
//! Executes a [`crate::compiler::CompiledModel`] on real inputs with
//! the *same arithmetic as the silicon datapath* (CMUL bit-plane
//! multiplies, select-signal activation MUXing, synchronous lockstep
//! lanes) while counting every timing- and energy-relevant event. The
//! functional output is bit-exact against [`crate::nn::QuantModel`]
//! (enforced by integration tests); the event counts feed
//! [`crate::power`].

mod counters;
mod engine;
mod trace;

pub use counters::{Counters, LayerCounters};
pub use engine::{run, run_batch, run_batch_parallel, run_parallel, run_serial,
                 SimResult};
pub use trace::render_trace;
