//! Cycle-accurate simulator of the SPE array.
//!
//! Executes a [`crate::compiler::CompiledModel`] on real inputs with
//! the *same arithmetic as the silicon datapath* (CMUL bit-plane
//! multiplies, select-signal activation MUXing, synchronous lockstep
//! lanes), over the tile-major activation layout the schedule
//! describes. Event counting is split: the **fast path** ([`run`],
//! [`run_scratch`], [`run_batch`]) executes pure compute over a
//! reusable [`ScratchArena`] and stamps the compile-time
//! [`crate::compiler::StaticCost`] counters; the **counted reference
//! path** ([`run_counted`], [`run_counted_scratch`], [`run_serial`],
//! [`run_parallel`]) measures every event dynamically. Logits are
//! bit-exact against [`crate::nn::QuantModel`] on every path, and
//! static == counted counters (enforced by integration tests +
//! `tests/static_counters.rs`); the event counts feed [`crate::power`].

mod counters;
mod engine;
mod scratch;
mod trace;

pub use counters::{Counters, LayerCounters};
pub use engine::{run, run_batch, run_batch_parallel, run_batch_scratch,
                 run_counted, run_counted_scratch, run_parallel,
                 run_scratch, run_serial, SimResult};
pub use scratch::ScratchArena;
pub use trace::render_trace;
