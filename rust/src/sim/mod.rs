//! Cycle-accurate simulator of the SPE array.
//!
//! Executes a [`crate::compiler::CompiledModel`] on real inputs with
//! the *same arithmetic as the silicon datapath* (CMUL bit-plane
//! multiplies, select-signal activation MUXing, synchronous lockstep
//! lanes), over the tile-major activation layout the schedule
//! describes — stripes are the interchange format between layers, and
//! the requant drain is fused into each layer's staging read, so no
//! row-major intermediate feature map exists on any path (DESIGN.md
//! §"Data layout contract").
//!
//! **Which entry point?**
//!
//! * [`run`] / [`run_scratch`] / [`run_batch`] / [`run_batch_parallel`]
//!   — the serving default (fast path): pure compute through the
//!   staged kernel (dispatched per [`crate::arch::KernelTier`] — AVX2
//!   or scalar twin, bit-exact either way; the `*_tier` variants pin
//!   it explicitly), compile-time [`crate::compiler::StaticCost`]
//!   counters stamped for free. Use unless you are changing the event
//!   model itself.
//! * [`run_counted`] / [`run_counted_scratch`] / [`run_serial`] /
//!   [`run_parallel`] — the dynamic-counting reference: walks every
//!   position through an SPE instance. Slower by design; use when
//!   validating counter/timing changes — it is the measurement the
//!   static cost must keep matching.
//! * [`StreamingEngine`] — incremental streaming over overlapping
//!   windows: per-layer stripe columns persist in the arena's carry
//!   slab across `hop`-sample advances and only the receptive-field
//!   fringe is recomputed. Bit-exact per window vs [`run_scratch`];
//!   use for continuous-monitoring serving where windows overlap.
//! * [`crate::nn::QuantModel::forward`] / `forward_scratch` — the
//!   golden integer model: no chip modeling at all. Use for numerics
//!   audits or serving without power/latency accounting.
//!
//! Logits are bit-exact against [`crate::nn::QuantModel`] on every
//! path, and static == counted counters (enforced by integration
//! tests + `tests/static_counters.rs` + `tests/layout_arena.rs`); the
//! event counts feed [`crate::power`]. Working memory for all paths
//! lives in one [`ScratchArena`] per execution context;
//! [`ArenaStats`] reports its per-buffer high-water marks for
//! serving telemetry.

mod counters;
mod engine;
mod scratch;
mod streaming;
mod trace;

pub use counters::{Counters, LayerCounters};
pub use engine::{run, run_batch, run_batch_parallel,
                 run_batch_parallel_tier, run_batch_scratch,
                 run_batch_scratch_tier, run_counted, run_counted_scratch,
                 run_parallel, run_scratch, run_scratch_tier, run_serial,
                 SimResult};
pub use scratch::{ArenaStats, ScratchArena};
pub use streaming::{StreamOutput, StreamingEngine, StreamingStats};
pub use trace::render_trace;
