//! Event counters — the interface between timing simulation and the
//! energy model.

use crate::arch::Spad;

/// Counters for one layer.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LayerCounters {
    /// Array cycles spent in this layer (compute + control).
    pub cycles: u64,
    /// MACs executed (non-zero weights only when zero-skip is on).
    pub macs: u64,
    /// Dense-equivalent MACs (what a dense datapath would execute).
    pub macs_dense: u64,
    /// CMUL segment operations (energy ∝ precision).
    pub segment_ops: u64,
    /// Weight-buffer fetch events (one compressed weight+select pair
    /// broadcast to the SPE row).
    pub weight_fetches: u64,
    /// Output activations written back.
    pub output_writes: u64,
    /// SPad / regfile / FIFO traffic.
    pub spad: Spad,
    /// MPE pooling element operations.
    pub pool_ops: u64,
}

impl LayerCounters {
    pub fn merge(&mut self, o: &LayerCounters) {
        self.cycles += o.cycles;
        self.macs += o.macs;
        self.macs_dense += o.macs_dense;
        self.segment_ops += o.segment_ops;
        self.weight_fetches += o.weight_fetches;
        self.output_writes += o.output_writes;
        self.spad.merge(&o.spad);
        self.pool_ops += o.pool_ops;
    }

    /// `n` identical inferences in one update — exactly `n` repeated
    /// [`Self::merge`]s of self (u64 addition distributes).
    pub fn scale(&mut self, n: u64) {
        self.cycles *= n;
        self.macs *= n;
        self.macs_dense *= n;
        self.segment_ops *= n;
        self.weight_fetches *= n;
        self.output_writes *= n;
        self.spad.scale(n);
        self.pool_ops *= n;
    }
}

/// Whole-inference counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    pub per_layer: Vec<LayerCounters>,
    /// Cycles streaming the input recording into the SPad (1/cycle).
    pub input_load_cycles: u64,
    /// Cycles in the final pooling/readout stage.
    pub readout_cycles: u64,
}

impl Counters {
    /// Total array cycles for one inference.
    pub fn total_cycles(&self) -> u64 {
        self.per_layer.iter().map(|l| l.cycles).sum::<u64>()
            + self.input_load_cycles
            + self.readout_cycles
    }

    pub fn total_macs(&self) -> u64 {
        self.per_layer.iter().map(|l| l.macs).sum()
    }

    pub fn total_macs_dense(&self) -> u64 {
        self.per_layer.iter().map(|l| l.macs_dense).sum()
    }

    pub fn total_segment_ops(&self) -> u64 {
        self.per_layer.iter().map(|l| l.segment_ops).sum()
    }

    pub fn total(&self) -> LayerCounters {
        let mut t = LayerCounters::default();
        for l in &self.per_layer {
            t.merge(l);
        }
        t
    }

    pub fn merge(&mut self, o: &Counters) {
        if self.per_layer.len() < o.per_layer.len() {
            self.per_layer.resize(o.per_layer.len(), LayerCounters::default());
        }
        for (a, b) in self.per_layer.iter_mut().zip(&o.per_layer) {
            a.merge(b);
        }
        self.input_load_cycles += o.input_load_cycles;
        self.readout_cycles += o.readout_cycles;
    }

    /// Counters for `n` identical inferences: bit-identical to merging
    /// `n` copies of `self` into a fresh default (so `scaled(0)` is the
    /// empty default). Lets the fast batch path produce totals from the
    /// compile-time [`crate::compiler::StaticCost`] in O(layers) per
    /// batch instead of O(layers) per recording.
    pub fn scaled(&self, n: u64) -> Counters {
        if n == 0 {
            return Counters::default();
        }
        let mut c = self.clone();
        for l in &mut c.per_layer {
            l.scale(n);
        }
        c.input_load_cycles *= n;
        c.readout_cycles *= n;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_sum_layers() {
        let mut c = Counters::default();
        c.per_layer.push(LayerCounters { cycles: 10, macs: 5, ..Default::default() });
        c.per_layer.push(LayerCounters { cycles: 20, macs: 7, ..Default::default() });
        c.input_load_cycles = 512;
        c.readout_cycles = 8;
        assert_eq!(c.total_cycles(), 550);
        assert_eq!(c.total_macs(), 12);
        assert_eq!(c.total().cycles, 30);
    }

    #[test]
    fn scaled_equals_repeated_merge() {
        let mut one = Counters::default();
        one.per_layer.push(LayerCounters {
            cycles: 3, macs: 5, macs_dense: 10, segment_ops: 40,
            weight_fetches: 7, output_writes: 2, pool_ops: 1,
            ..Default::default()
        });
        one.input_load_cycles = 512;
        one.readout_cycles = 6;
        let mut merged = Counters::default();
        for _ in 0..9 {
            merged.merge(&one);
        }
        assert_eq!(one.scaled(9), merged);
        assert_eq!(one.scaled(0), Counters::default());
        assert_eq!(one.scaled(1), one);
    }

    #[test]
    fn merge_aligns_layers() {
        let mut a = Counters::default();
        a.per_layer.push(LayerCounters { cycles: 1, ..Default::default() });
        let mut b = Counters::default();
        b.per_layer.push(LayerCounters { cycles: 2, ..Default::default() });
        b.per_layer.push(LayerCounters { cycles: 3, ..Default::default() });
        a.merge(&b);
        assert_eq!(a.per_layer[0].cycles, 3);
        assert_eq!(a.per_layer[1].cycles, 3);
    }
}
