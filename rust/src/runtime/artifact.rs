//! Artifact discovery: which AOT batch variants exist.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

/// The AOT'd executables available in an artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    /// Batch sizes with a `model_b{B}.hlo.txt` present, ascending.
    pub batches: Vec<usize>,
}

/// Path of one batch variant.
pub fn artifact_path(dir: &Path, batch: usize) -> PathBuf {
    dir.join(format!("model_b{batch}.hlo.txt"))
}

impl ArtifactSet {
    /// Scan a directory for model artifacts.
    pub fn discover(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mut batches = Vec::new();
        for b in 1..=1024 {
            if artifact_path(&dir, b).exists() {
                batches.push(b);
            }
        }
        ensure!(!batches.is_empty(),
                "no model_b*.hlo.txt in {} — run `make artifacts`",
                dir.display());
        Ok(Self { dir, batches })
    }

    /// Smallest batch variant ≥ `n`, or the largest available.
    pub fn best_batch_for(&self, n: usize) -> usize {
        *self.batches.iter().find(|&&b| b >= n)
            .unwrap_or_else(|| self.batches.last().unwrap())
    }

    pub fn path_for(&self, batch: usize) -> PathBuf {
        artifact_path(&self.dir, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_batch_selection() {
        let s = ArtifactSet { dir: PathBuf::from("x"), batches: vec![1, 6, 32] };
        assert_eq!(s.best_batch_for(1), 1);
        assert_eq!(s.best_batch_for(2), 6);
        assert_eq!(s.best_batch_for(6), 6);
        assert_eq!(s.best_batch_for(7), 32);
        assert_eq!(s.best_batch_for(100), 32);
    }

    #[test]
    fn discover_fails_on_empty_dir() {
        let dir = std::env::temp_dir().join("va_accel_empty_art");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ArtifactSet::discover(&dir).is_err());
    }

    #[test]
    fn discovers_real_artifacts_if_present() {
        if let Ok(s) = ArtifactSet::discover(crate::ARTIFACT_DIR) {
            assert!(s.batches.contains(&1));
        }
    }
}
