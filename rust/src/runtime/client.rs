//! PJRT client wrapper: compile HLO text once, execute many times.
//!
//! The real implementation rides on the `xla` bindings, which need a
//! local `xla_extension` install that the offline build environment
//! does not ship. It is therefore gated behind the `pjrt` cargo
//! feature (see Cargo.toml); the default build substitutes a stub
//! whose constructor reports the backend unavailable, so everything
//! downstream (Executor, Backend::Pjrt plumbing, CLI flags) compiles
//! and fails gracefully at runtime instead of at link time.

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{Context, Result};

    /// A PJRT CPU client plus a cache of compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Create the CPU PJRT client (one per process is plenty; the
        /// executor layer shares it behind a mutex).
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { client, cache: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + parse + compile one HLO-text artifact (cached by path).
        pub fn load(&mut self, path: impl AsRef<Path>) -> Result<()> {
            self.load_cached(path).map(|_| ())
        }

        fn load_cached(&mut self, path: impl AsRef<Path>)
                       -> Result<&xla::PjRtLoadedExecutable> {
            let key = path.as_ref().display().to_string();
            if !self.cache.contains_key(&key) {
                let proto = xla::HloModuleProto::from_text_file(path.as_ref())
                    .with_context(|| format!("parsing HLO text {key}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp)
                    .with_context(|| format!("compiling {key}"))?;
                self.cache.insert(key.clone(), exe);
            }
            Ok(self.cache.get(&key).unwrap())
        }

        /// Execute a loaded artifact on a batch of quantized recordings.
        ///
        /// `batch` must equal the artifact's AOT batch size; short batches
        /// are zero-padded by the caller ([`super::super::Executor`]).
        /// Returns the `[batch, 2]` int32 logits row-major.
        pub fn infer(&mut self, path: impl AsRef<Path>, batch: usize,
                     recordings: &[Vec<i8>]) -> Result<Vec<[i32; 2]>> {
            anyhow::ensure!(recordings.len() <= batch,
                            "batch overflow: {} > {batch}", recordings.len());
            let rec_len = crate::REC_LEN;
            let mut flat = vec![0i32; batch * rec_len];
            for (i, r) in recordings.iter().enumerate() {
                anyhow::ensure!(r.len() == rec_len, "bad recording length {}", r.len());
                for (j, &v) in r.iter().enumerate() {
                    flat[i * rec_len + j] = v as i32;
                }
            }
            let input = xla::Literal::vec1(&flat)
                .reshape(&[batch as i64, rec_len as i64, 1])?;
            let exe = self.load_cached(path)?;
            let result = exe.execute::<xla::Literal>(&[input])?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True → 1-tuple
            let out = result.to_tuple1()?;
            let v = out.to_vec::<i32>()?;
            anyhow::ensure!(v.len() == batch * 2, "unexpected output size {}", v.len());
            Ok((0..batch).map(|i| [v[2 * i], v[2 * i + 1]]).collect())
        }
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Runtime(platform={}, cached={})",
                   self.client.platform_name(), self.cache.len())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT backend unavailable: built without the `pjrt` feature \
         (use the golden or chipsim backend, or rebuild with \
         --features pjrt and a local xla dependency)";

    /// Stub PJRT client: same surface as the real one, constructor
    /// always errors.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&mut self, _path: impl AsRef<Path>) -> Result<()> {
            bail!(UNAVAILABLE)
        }

        pub fn infer(&mut self, _path: impl AsRef<Path>, _batch: usize,
                     _recordings: &[Vec<i8>]) -> Result<Vec<[i32; 2]>> {
            bail!(UNAVAILABLE)
        }
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Runtime(unavailable: no pjrt feature)")
        }
    }
}

pub use imp::Runtime;

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::Runtime;

    #[test]
    fn stub_reports_unavailable() {
        let err = Runtime::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
