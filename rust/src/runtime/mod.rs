//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes
//! them on the CPU PJRT client via the `xla` crate.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py`
//! and /opt/xla-example/README.md: serialized protos from jax ≥ 0.5
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids).
//!
//! Python never runs here — after `make artifacts` the rust binary is
//! self-contained.
//!
//! The `xla` bindings are gated behind the `pjrt` cargo feature (the
//! offline build environment has no xla_extension); without it the
//! [`Runtime`] is a stub whose constructor reports the backend
//! unavailable, and the golden / chipsim backends carry all traffic.

mod artifact;
mod client;
mod executor;

pub use artifact::{artifact_path, ArtifactSet};
pub use client::Runtime;
pub use executor::{Executor, InferenceOutput};
