//! High-level inference executor: batch-variant selection, padding,
//! warm-up, thread safety.

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::artifact::ArtifactSet;
use super::client::Runtime;

/// One recording's inference result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InferenceOutput {
    /// Head logits [non-VA, VA].
    pub logits: [i32; 2],
    /// VA detected? (argmax with ties to non-VA — matches the golden
    /// model and the simulator.)
    pub predicted_va: bool,
}

impl InferenceOutput {
    pub fn from_logits(logits: [i32; 2]) -> Self {
        Self { logits, predicted_va: logits[1] > logits[0] }
    }
}

/// Thread-safe executor over the artifact set.
pub struct Executor {
    runtime: Mutex<Runtime>,
    artifacts: ArtifactSet,
}

// SAFETY: the `xla` crate's client/executable handles are `Rc` + raw
// pointers, hence not auto-Send. The Executor owns the *only* handles
// (the Runtime and every cached executable are created inside it and
// never leak), so moving the whole Executor to another thread moves
// every reference count with it; and all `&self` access paths go
// through the internal Mutex, so cross-thread shared access is
// serialized. The PJRT CPU client itself is thread-safe for compiled
// executions.
unsafe impl Send for Executor {}
unsafe impl Sync for Executor {}

impl Executor {
    /// Open the artifact directory and create the PJRT client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Self {
            runtime: Mutex::new(Runtime::cpu()?),
            artifacts: ArtifactSet::discover(dir)?,
        })
    }

    pub fn artifacts(&self) -> &ArtifactSet {
        &self.artifacts
    }

    /// Compile every batch variant up front (PJRT compilation is
    /// seconds; do it before the first heartbeat, not during one).
    pub fn warmup(&self) -> Result<Vec<(usize, f64)>> {
        let mut rt = self.runtime.lock().unwrap();
        let mut times = Vec::new();
        for &b in &self.artifacts.batches {
            let t0 = Instant::now();
            rt.load(self.artifacts.path_for(b))?;
            times.push((b, t0.elapsed().as_secs_f64()));
        }
        Ok(times)
    }

    /// Run one recording (batch-1 artifact).
    pub fn infer_one(&self, x: &[i8]) -> Result<InferenceOutput> {
        let b = self.artifacts.best_batch_for(1);
        let mut rt = self.runtime.lock().unwrap();
        let rows = rt.infer(self.artifacts.path_for(b), b,
                            std::slice::from_ref(&x.to_vec()))?;
        Ok(InferenceOutput::from_logits(rows[0]))
    }

    /// Run a batch, choosing the smallest artifact that fits and
    /// zero-padding the remainder; splits batches larger than the
    /// largest artifact.
    pub fn infer_batch(&self, xs: &[Vec<i8>]) -> Result<Vec<InferenceOutput>> {
        let mut out = Vec::with_capacity(xs.len());
        let max_b = *self.artifacts.batches.last().unwrap();
        let mut rt = self.runtime.lock().unwrap();
        for chunk in xs.chunks(max_b) {
            let b = self.artifacts.best_batch_for(chunk.len());
            let rows = rt.infer(self.artifacts.path_for(b), b, chunk)?;
            out.extend(rows.iter().take(chunk.len())
                .map(|&l| InferenceOutput::from_logits(l)));
        }
        Ok(out)
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executor(batches={:?})", self.artifacts.batches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_argmax_ties_to_non_va() {
        assert!(!InferenceOutput::from_logits([5, 5]).predicted_va);
        assert!(InferenceOutput::from_logits([5, 6]).predicted_va);
        assert!(!InferenceOutput::from_logits([6, 5]).predicted_va);
    }
}
