//! Tile scheduling: how the synchronous array walks a layer, and the
//! **tile-major activation layout** the engines execute over.
//!
//! Geometry per layer (1-D mapping, DESIGN.md §Hardware-Adaptation):
//! the engaged SPEs each compute one output *position* at a time, all
//! `m` output channels of a channel tile in parallel; positions are
//! assigned to SPEs in contiguous blocks for SPad locality. A layer is
//! therefore a `ch_tiles × pos_tiles` grid of synchronous array steps.
//!
//! Layout: a layer's output buffer is `[ch_tile][lout][lane]` — each
//! channel tile owns one contiguous **column stripe** (`lout × live`
//! words, where `live ≤ m` is the stripe's populated lane count). The
//! stripes of a layer are disjoint and ordered, so both engines split
//! the output buffer with `chunks_mut(stripe_stride)` and write every
//! tile's accumulators directly into their final location — no
//! `[lout, live]` → `[lout, cout]` scatter pass exists anywhere.
//!
//! The stripe layout is also the **interchange format between
//! layers**: [`Schedule::of`] copies each producer's stripe table onto
//! the consumer's [`LayerSchedule::in_stripes`], and the engines stage
//! the next layer's padded window buffer straight from those stripes
//! with the requant fused into the read
//! ([`crate::nn::pad_same_from_stripes`]). No separate requant-drain
//! pass — and no row-major intermediate feature map — exists between
//! conv layers; only the network input arrives `[L, Cin]` row-major,
//! and only the head readout leaves stripe space (it pools straight
//! off the head's stripes). See DESIGN.md §"Data layout contract".
//!
//! The schedule is kernel-tier agnostic: the fast path executes each
//! stripe through the [`crate::arch::KernelTier`]-dispatched tile
//! kernel (AVX2 over the sub-byte packed words, or the scalar twin
//! over the decoded mirror — see [`crate::compiler::PackedStreams`]),
//! and nothing here changes between tiers because both consume the
//! same `(ranges, stripes, window_len)` geometry.

use crate::arch::ChipConfig;
use crate::nn::QLayer;

/// Column-stripe geometry of one output-channel tile in the tile-major
/// layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileStripe {
    /// First output channel of the stripe (`tile · m`).
    pub base_co: usize,
    /// Populated lanes: `min(cout - base_co, m)`. Only the last stripe
    /// of a layer can be partial (`live < m`); its padding lanes exist
    /// in the SPE array but not in the activation buffer.
    pub live: usize,
    /// Word offset of the stripe in the layer's output buffer
    /// (`tile · stripe_stride` — full stripes precede the partial one).
    pub offset: usize,
}

/// Static schedule for one layer.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    /// Kernel taps (copied from the layer so schedule-level geometry —
    /// e.g. [`StreamPlan::of`]'s receptive-field fringe — needs no
    /// [`QLayer`] in hand).
    pub k: usize,
    /// Convolution stride (see [`LayerSchedule::k`]).
    pub stride: usize,
    /// Input length after 'same' padding.
    pub l_padded: usize,
    /// Output positions.
    pub lout: usize,
    /// Receptive-field window per position (K·Cin).
    pub window_len: usize,
    /// Output-channel tiles: ceil(Cout / M).
    pub ch_tiles: usize,
    /// Position tiles: ceil(Lout / engaged SPEs).
    pub pos_tiles: usize,
    /// SPad words written to stage the input tile (per channel tile).
    pub fill_words: u64,
    /// Control overhead cycles charged per array step (tile dispatch,
    /// address generation — the "simple control logic" of Fig. 2).
    pub ctrl_cycles_per_tile: u64,
    /// One-off per-layer overhead (descriptor load, pipeline flush).
    pub layer_overhead_cycles: u64,
    /// Output buffer length in words (`lout · cout` — the tile-major
    /// layout is packed: partial stripes store only live lanes).
    pub out_len: usize,
    /// Word stride between consecutive stripe starts (`m · lout`).
    /// `chunks_mut(stripe_stride)` over an `out_len` buffer yields
    /// exactly the layer's stripes, the last one `live · lout` long.
    pub stripe_stride: usize,
    /// Column-stripe table, one entry per channel tile, in tile order.
    pub stripes: Vec<TileStripe>,
    /// Input length in samples (the producer's `lout`, or the network
    /// input length for layer 0).
    pub l_in: usize,
    /// Producer-side layout of this layer's INPUT feature map: the
    /// producing layer's stripe table, copied across the layer
    /// boundary by [`Schedule::of`] so the engines can stage the
    /// padded window buffer straight from the producer's stripes
    /// ([`crate::nn::pad_same_from_stripes`]). Empty for layer 0 (the
    /// network input is `[L, Cin]` row-major, not striped) and for a
    /// [`LayerSchedule`] built standalone via [`LayerSchedule::of`].
    pub in_stripes: Vec<TileStripe>,
}

impl LayerSchedule {
    pub fn of(ly: &QLayer, cfg: &ChipConfig, l_in: usize) -> Self {
        let pad = ly.k - ly.stride;
        let l_padded = l_in + pad;
        let lout = (l_padded - ly.k) / ly.stride + 1;
        let spes = cfg.engaged_spes();
        let ch_tiles = ly.cout.div_ceil(cfg.m);
        let stripe_stride = cfg.m * lout;
        let stripes = (0..ch_tiles)
            .map(|t| {
                let base_co = t * cfg.m;
                TileStripe {
                    base_co,
                    live: (ly.cout - base_co).min(cfg.m),
                    offset: t * stripe_stride,
                }
            })
            .collect();
        Self {
            k: ly.k,
            stride: ly.stride,
            l_padded,
            lout,
            window_len: ly.k * ly.cin,
            ch_tiles,
            pos_tiles: lout.div_ceil(spes),
            fill_words: (l_padded * ly.cin) as u64,
            ctrl_cycles_per_tile: 2,
            layer_overhead_cycles: 32,
            out_len: lout * ly.cout,
            stripe_stride,
            stripes,
            l_in,
            in_stripes: Vec::new(),
        }
    }

    /// Total synchronous array steps in this layer.
    pub fn steps(&self) -> u64 {
        (self.ch_tiles * self.pos_tiles) as u64
    }

    /// Split a tile-major output buffer into its disjoint column
    /// stripes (one `&mut` per channel tile, in tile order). The
    /// serial engines index stripes directly; the rayon tile loop uses
    /// `par_chunks_mut(stripe_stride)`, which produces the identical
    /// partition.
    pub fn stripe_chunks_mut<'a>(&self, out: &'a mut [i32])
                                 -> std::slice::ChunksMut<'a, i32> {
        debug_assert_eq!(out.len(), self.out_len);
        out.chunks_mut(self.stripe_stride.max(1))
    }
}

/// Whole-model schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub layers: Vec<LayerSchedule>,
    /// Input length (samples) the model was scheduled for. The static
    /// cost model and the fast engine's input-length check both key off
    /// this: every schedule-derived count assumes exactly this many
    /// samples stream in.
    pub l_in: usize,
}

impl Schedule {
    pub fn of(layers: &[QLayer], cfg: &ChipConfig, l_in: usize) -> Self {
        let mut l = l_in;
        let mut out: Vec<LayerSchedule> = Vec::with_capacity(layers.len());
        for ly in layers {
            let mut s = LayerSchedule::of(ly, cfg, l);
            // carry the producer's layout across the layer boundary:
            // the consumer stages its padded input straight from these
            // stripes (fused requant, `nn::pad_same_from_stripes`)
            if let Some(prev) = out.last() {
                s.in_stripes = prev.stripes.clone();
            }
            l = s.lout;
            out.push(s);
        }
        Self { layers: out, l_in }
    }

    /// Final feature-map length (head input to global pooling).
    pub fn final_len(&self) -> usize {
        self.layers.last().map(|l| l.lout).unwrap_or(0)
    }
}

// ---------------------------------------------------------------------
// Streaming fringe geometry (incremental inference, NNUE-style reuse)
// ---------------------------------------------------------------------

/// Per-layer fringe geometry for a `hop`-sample window advance: which
/// output columns a [`crate::sim::StreamingEngine`] may carry over
/// (shifted) from the previous window, and which it must recompute.
///
/// Column semantics for a layer with `lout` output positions:
///
/// * `[0, head)` — the **head fringe**: receptive fields touch the
///   left 'same' padding (or a column the producer itself recomputed),
///   so the previous window's value is stale. Recomputed every hop.
/// * `[head, reuse_end)` — the **carry region**: column `lo` of the
///   new window is bit-identical to column `lo + shift` of the
///   previous window. Shifted in place, zero MACs.
/// * `[reuse_end, lout)` — the **tail fringe**: receptive fields reach
///   the freshly-arrived samples (or the right padding). Recomputed.
///
/// A full-recompute layer (hop not divisible by the cumulative stride,
/// or the carry region collapsed to nothing) is encoded as
/// [`LayerFringe::FULL`]: `head == reuse_end == 0`, so the uniform
/// "recompute `[0, head)` and `[reuse_end, lout)`" rule recomputes the
/// whole layer and carries nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerFringe {
    /// Output-column shift of the carry region (`hop / cumulative
    /// stride`); 0 iff the layer is fully recomputed.
    pub shift: usize,
    /// First carried column; `[0, head)` is recomputed.
    pub head: usize,
    /// One past the last carried column; `[reuse_end, lout)` is
    /// recomputed.
    pub reuse_end: usize,
}

impl LayerFringe {
    /// The no-reuse encoding: every column recomputed, none carried.
    pub const FULL: LayerFringe = LayerFringe { shift: 0, head: 0, reuse_end: 0 };

    /// Columns carried over from the previous window.
    pub fn carried(&self) -> usize {
        self.reuse_end - self.head
    }

    /// Columns recomputed per hop (head + tail fringe).
    pub fn recomputed(&self, lout: usize) -> usize {
        lout - self.carried()
    }
}

/// Whole-model fringe geometry for one hop size: how many output
/// positions of each layer a `hop`-sample window advance invalidates,
/// derived from kernel/stride/padding alone (input-independent, like
/// every other schedule quantity).
///
/// Derivation (DESIGN.md §"Incremental streaming: the carry-slab
/// contract"): layer
/// inputs agree with the previous window's on a shifted interval
/// `[a, b)` (at layer 0: `[0, l_in - hop)`, shift `hop` — the samples
/// both windows share). A column `lo` may be carried iff the carried
/// shift is stride-aligned (`d % stride == 0`) and its padded
/// receptive field `[lo·s − pl, lo·s − pl + k)` lies entirely inside
/// `[a, b)` — touching the left padding, a producer-recomputed column,
/// or the fresh tail all invalidate it. The carried interval of this
/// layer's *output* becomes the next layer's agreement interval, with
/// shift `d / stride`; once agreement collapses (misaligned stride or
/// empty carry), every deeper layer is full-recompute.
#[derive(Debug, Clone)]
pub struct StreamPlan {
    /// Window advance in input samples (`1 ..= l_in`;
    /// `hop == l_in` degenerates to full recompute everywhere).
    pub hop: usize,
    /// One entry per layer, in layer order.
    pub layers: Vec<LayerFringe>,
}

impl StreamPlan {
    pub fn of(sched: &Schedule, hop: usize) -> Self {
        assert!(hop >= 1 && hop <= sched.l_in,
                "hop {hop} outside 1..={}", sched.l_in);
        // (a, b, d): this layer's input agrees with the previous
        // window's input shifted by d on [a, b); None once agreement
        // has collapsed
        let mut agree: Option<(usize, usize, usize)> = if hop < sched.l_in {
            Some((0, sched.l_in - hop, hop))
        } else {
            None
        };
        let mut layers = Vec::with_capacity(sched.layers.len());
        for ls in &sched.layers {
            let fr = match agree {
                Some((a, b, d)) if d % ls.stride == 0 => {
                    let s = ls.stride;
                    let d_out = d / s;
                    let pl = (ls.k - s) / 2; // left 'same' pad (low half)
                    // first column whose RF clears the left boundary:
                    // lo·s − pl ≥ a
                    let head = (a + pl).div_ceil(s);
                    // one past the last column whose RF stays inside
                    // the agreement: lo·s − pl + k ≤ b
                    let rf_end = if b + pl >= ls.k {
                        (b + pl - ls.k) / s + 1
                    } else {
                        0
                    };
                    // the carried source column lo + d_out must exist
                    // in the previous window's output
                    let reuse_end =
                        rf_end.min(ls.lout.saturating_sub(d_out));
                    if reuse_end > head && d_out > 0 {
                        agree = Some((head, reuse_end, d_out));
                        LayerFringe { shift: d_out, head, reuse_end }
                    } else {
                        agree = None;
                        LayerFringe::FULL
                    }
                }
                _ => {
                    agree = None;
                    LayerFringe::FULL
                }
            };
            layers.push(fr);
        }
        Self { hop, layers }
    }

    /// Fraction of the model's dense MACs recomputed per hop (the
    /// static streaming-speedup predictor: `1 / fraction` is the ideal
    /// MAC-count win over full recompute, before staging overheads).
    pub fn dense_mac_fraction(&self, sched: &Schedule) -> f64 {
        let mut full = 0f64;
        let mut inc = 0f64;
        for (fr, ls) in self.layers.iter().zip(&sched.layers) {
            // dense MACs per output column = window_len · cout, and
            // out_len = lout · cout
            let per_col =
                (ls.window_len * (ls.out_len / ls.lout.max(1))) as f64;
            full += per_col * ls.lout as f64;
            inc += per_col * fr.recomputed(ls.lout) as f64;
        }
        if full > 0.0 { inc / full } else { 1.0 }
    }

    /// Total columns carried per hop across all layers.
    pub fn carried_cols(&self) -> usize {
        self.layers.iter().map(|f| f.carried()).sum()
    }

    /// Total columns recomputed per hop across all layers.
    pub fn recomputed_cols(&self, sched: &Schedule) -> usize {
        self.layers.iter().zip(&sched.layers)
            .map(|(f, ls)| f.recomputed(ls.lout))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;

    fn qlayer(k: usize, stride: usize, cin: usize, cout: usize) -> QLayer {
        QLayer { k, stride, cin, cout, relu: true, nbits: 8, shift: 24,
                 s_in: 1.0, s_out: 1.0, w: vec![1; k * cin * cout],
                 bias: vec![0; cout], m0: vec![0; cout] }
    }

    #[test]
    fn halving_geometry() {
        let cfg = ChipConfig::paper_1d(); // 8 SPEs
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &cfg, 512);
        assert_eq!(s.lout, 256);
        assert_eq!(s.window_len, 7);
        assert_eq!(s.ch_tiles, 1);
        assert_eq!(s.pos_tiles, 32); // 256 / 8 SPEs
        assert_eq!(s.steps(), 32);
    }

    #[test]
    fn channel_tiles_round_up() {
        let cfg = ChipConfig::paper_1d();
        let s = LayerSchedule::of(&qlayer(3, 2, 64, 96), &cfg, 16);
        assert_eq!(s.ch_tiles, 6);
        assert_eq!(s.lout, 8);
        assert_eq!(s.pos_tiles, 1);
        assert_eq!(s.steps(), 6);
    }

    #[test]
    fn full_model_chains_lengths() {
        let cfg = ChipConfig::paper_1d();
        let layers = vec![
            qlayer(7, 2, 1, 16), qlayer(5, 2, 16, 32), qlayer(5, 2, 32, 48),
            qlayer(5, 2, 48, 64), qlayer(5, 2, 64, 64), qlayer(3, 2, 64, 96),
            qlayer(3, 2, 96, 128), qlayer(1, 1, 128, 2),
        ];
        let s = Schedule::of(&layers, &cfg, 512);
        let louts: Vec<usize> = s.layers.iter().map(|l| l.lout).collect();
        assert_eq!(louts, vec![256, 128, 64, 32, 16, 8, 4, 4]);
        assert_eq!(s.final_len(), 4);
        assert_eq!(s.l_in, 512);
    }

    #[test]
    fn in_stripes_carry_the_producer_layout() {
        let cfg = ChipConfig::paper_1d(); // m = 16
        let layers = vec![
            qlayer(7, 2, 1, 20), // ends in a partial stripe (live 4)
            qlayer(5, 2, 20, 32),
            qlayer(1, 1, 32, 2),
        ];
        let s = Schedule::of(&layers, &cfg, 64);
        // layer 0 consumes the row-major network input: no stripes
        assert!(s.layers[0].in_stripes.is_empty());
        // every later layer carries its producer's stripe table and
        // input length verbatim
        for li in 1..s.layers.len() {
            assert_eq!(s.layers[li].in_stripes, s.layers[li - 1].stripes,
                       "layer {li}");
            assert_eq!(s.layers[li].l_in, s.layers[li - 1].lout, "layer {li}");
        }
        // a standalone LayerSchedule has no producer to inherit from
        let lone = LayerSchedule::of(&qlayer(5, 2, 20, 32), &cfg, 32);
        assert!(lone.in_stripes.is_empty());
        assert_eq!(lone.l_in, 32);
    }

    #[test]
    fn more_spes_fewer_pos_tiles() {
        let full = ChipConfig::paper(); // 32 SPEs
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &full, 512);
        assert_eq!(s.pos_tiles, 8); // 256 / 32
    }

    #[test]
    fn stripes_tile_the_output_buffer_exactly() {
        let cfg = ChipConfig::paper_1d(); // m = 16
        // cout 20 -> one full stripe + one partial stripe of 4 lanes
        let s = LayerSchedule::of(&qlayer(3, 2, 4, 20), &cfg, 16);
        assert_eq!(s.lout, 8);
        assert_eq!(s.out_len, 8 * 20);
        assert_eq!(s.stripe_stride, 16 * 8);
        assert_eq!(s.stripes.len(), 2);
        assert_eq!(s.stripes[0],
                   TileStripe { base_co: 0, live: 16, offset: 0 });
        assert_eq!(s.stripes[1],
                   TileStripe { base_co: 16, live: 4, offset: 128 });
        // chunks_mut(stripe_stride) reproduces the stripe table
        let mut buf = vec![0i32; s.out_len];
        let chunks: Vec<usize> =
            s.stripe_chunks_mut(&mut buf).map(|c| c.len()).collect();
        assert_eq!(chunks, vec![128, 32]);
        for (st, len) in s.stripes.iter().zip(&chunks) {
            assert_eq!(st.live * s.lout, *len);
        }
        // offsets are contiguous: stripe t starts where t-1 ended
        assert_eq!(s.stripes[1].offset,
                   s.stripes[0].offset + s.stripes[0].live * s.lout);
    }

    fn paper_layers() -> Vec<QLayer> {
        vec![
            qlayer(7, 2, 1, 16), qlayer(5, 2, 16, 32), qlayer(5, 2, 32, 48),
            qlayer(5, 2, 48, 64), qlayer(5, 2, 64, 64), qlayer(3, 2, 64, 96),
            qlayer(3, 2, 96, 128), qlayer(1, 1, 128, 2),
        ]
    }

    #[test]
    fn schedule_carries_kernel_geometry() {
        let cfg = ChipConfig::paper_1d();
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &cfg, 512);
        assert_eq!((s.k, s.stride), (7, 2));
    }

    #[test]
    fn stream_plan_paper_hop128_hand_checked() {
        // hand-derived fringe chain for the paper geometry at hop 128:
        // agreement starts [0, 384) shift 128; each layer halves the
        // shift, keeps a 1-column head fringe (left padding), and
        // loses ~(shift + k/s) tail columns, until L6's carry interval
        // collapses and the rest is full recompute
        let cfg = ChipConfig::paper_1d();
        let s = Schedule::of(&paper_layers(), &cfg, 512);
        let p = StreamPlan::of(&s, 128);
        assert_eq!(p.layers.len(), 8);
        assert_eq!(p.layers[0],
                   LayerFringe { shift: 64, head: 1, reuse_end: 190 });
        assert_eq!(p.layers[1],
                   LayerFringe { shift: 32, head: 1, reuse_end: 94 });
        assert_eq!(p.layers[2],
                   LayerFringe { shift: 16, head: 1, reuse_end: 46 });
        assert_eq!(p.layers[3],
                   LayerFringe { shift: 8, head: 1, reuse_end: 22 });
        assert_eq!(p.layers[4],
                   LayerFringe { shift: 4, head: 1, reuse_end: 10 });
        assert_eq!(p.layers[5],
                   LayerFringe { shift: 2, head: 1, reuse_end: 4 });
        assert_eq!(p.layers[6], LayerFringe::FULL);
        assert_eq!(p.layers[7], LayerFringe::FULL);
        let frac = p.dense_mac_fraction(&s);
        assert!(frac > 0.0 && frac < 1.0);
        assert!(p.carried_cols() > 0);
    }

    #[test]
    fn stream_plan_structural_invariants() {
        let cfg = ChipConfig::paper_1d();
        let s = Schedule::of(&paper_layers(), &cfg, 512);
        for hop in [1usize, 2, 7, 16, 32, 64, 100, 128, 256, 500, 512] {
            let p = StreamPlan::of(&s, hop);
            let mut collapsed = false;
            for (fr, ls) in p.layers.iter().zip(&s.layers) {
                assert!(fr.head <= fr.reuse_end, "hop {hop}");
                assert!(fr.reuse_end <= ls.lout, "hop {hop}");
                if fr.carried() > 0 {
                    assert!(fr.shift >= 1, "hop {hop}");
                    // carried source columns exist in the old window
                    assert!(fr.reuse_end + fr.shift <= ls.lout, "hop {hop}");
                    assert!(!collapsed,
                            "hop {hop}: reuse after a full-recompute layer");
                } else {
                    assert_eq!(*fr, LayerFringe::FULL, "hop {hop}");
                    collapsed = true;
                }
                assert_eq!(fr.carried() + fr.recomputed(ls.lout), ls.lout);
            }
        }
    }

    #[test]
    fn stream_plan_degenerate_hops_recompute_everything() {
        let cfg = ChipConfig::paper_1d();
        let s = Schedule::of(&paper_layers(), &cfg, 512);
        // hop == frame_len: no shared samples at all (today's path)
        let full = StreamPlan::of(&s, 512);
        assert!(full.layers.iter().all(|f| *f == LayerFringe::FULL));
        assert_eq!(full.carried_cols(), 0);
        assert!((full.dense_mac_fraction(&s) - 1.0).abs() < 1e-12);
        // hop == 1 against a stride-2 first layer: shift misaligned
        let odd = StreamPlan::of(&s, 1);
        assert!(odd.layers.iter().all(|f| *f == LayerFringe::FULL));
    }

    #[test]
    fn stream_plan_denser_overlap_recomputes_less() {
        let cfg = ChipConfig::paper_1d();
        let s = Schedule::of(&paper_layers(), &cfg, 512);
        let f32_ = StreamPlan::of(&s, 32).dense_mac_fraction(&s);
        let f128 = StreamPlan::of(&s, 128).dense_mac_fraction(&s);
        let f256 = StreamPlan::of(&s, 256).dense_mac_fraction(&s);
        assert!(f32_ < f128 && f128 < f256,
                "expected monotone fractions, got {f32_} {f128} {f256}");
        // the paper-overlap operating point saves >3x in MAC count
        assert!(f32_ < 1.0 / 3.0, "hop-32 fraction {f32_} too high");
    }

    #[test]
    #[should_panic(expected = "hop")]
    fn stream_plan_rejects_zero_hop() {
        let cfg = ChipConfig::paper_1d();
        let s = Schedule::of(&paper_layers(), &cfg, 512);
        let _ = StreamPlan::of(&s, 0);
    }

    #[test]
    fn full_multiple_cout_has_only_full_stripes() {
        let cfg = ChipConfig::paper_1d();
        let s = LayerSchedule::of(&qlayer(5, 2, 16, 32), &cfg, 64);
        assert_eq!(s.stripes.len(), 2);
        assert!(s.stripes.iter().all(|st| st.live == 16));
        assert_eq!(s.out_len, s.ch_tiles * s.stripe_stride);
    }
}
