//! Tile scheduling: how the synchronous array walks a layer.
//!
//! Geometry per layer (1-D mapping, DESIGN.md §Hardware-Adaptation):
//! the engaged SPEs each compute one output *position* at a time, all
//! `m` output channels of a channel tile in parallel; positions are
//! assigned to SPEs in contiguous blocks for SPad locality. A layer is
//! therefore a `ch_tiles × pos_tiles` grid of synchronous array steps.

use crate::arch::ChipConfig;
use crate::nn::QLayer;

/// Static schedule for one layer.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    /// Input length after 'same' padding.
    pub l_padded: usize,
    /// Output positions.
    pub lout: usize,
    /// Receptive-field window per position (K·Cin).
    pub window_len: usize,
    /// Output-channel tiles: ceil(Cout / M).
    pub ch_tiles: usize,
    /// Position tiles: ceil(Lout / engaged SPEs).
    pub pos_tiles: usize,
    /// SPad words written to stage the input tile (per channel tile).
    pub fill_words: u64,
    /// Control overhead cycles charged per array step (tile dispatch,
    /// address generation — the "simple control logic" of Fig. 2).
    pub ctrl_cycles_per_tile: u64,
    /// One-off per-layer overhead (descriptor load, pipeline flush).
    pub layer_overhead_cycles: u64,
}

impl LayerSchedule {
    pub fn of(ly: &QLayer, cfg: &ChipConfig, l_in: usize) -> Self {
        let pad = ly.k - ly.stride;
        let l_padded = l_in + pad;
        let lout = (l_padded - ly.k) / ly.stride + 1;
        let spes = cfg.engaged_spes();
        Self {
            l_padded,
            lout,
            window_len: ly.k * ly.cin,
            ch_tiles: ly.cout.div_ceil(cfg.m),
            pos_tiles: lout.div_ceil(spes),
            fill_words: (l_padded * ly.cin) as u64,
            ctrl_cycles_per_tile: 2,
            layer_overhead_cycles: 32,
        }
    }

    /// Total synchronous array steps in this layer.
    pub fn steps(&self) -> u64 {
        (self.ch_tiles * self.pos_tiles) as u64
    }
}

/// Whole-model schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub layers: Vec<LayerSchedule>,
    /// Input length (samples) the model was scheduled for. The static
    /// cost model and the fast engine's input-length check both key off
    /// this: every schedule-derived count assumes exactly this many
    /// samples stream in.
    pub l_in: usize,
}

impl Schedule {
    pub fn of(layers: &[QLayer], cfg: &ChipConfig, l_in: usize) -> Self {
        let mut l = l_in;
        let mut out = Vec::with_capacity(layers.len());
        for ly in layers {
            let s = LayerSchedule::of(ly, cfg, l);
            l = s.lout;
            out.push(s);
        }
        Self { layers: out, l_in }
    }

    /// Final feature-map length (head input to global pooling).
    pub fn final_len(&self) -> usize {
        self.layers.last().map(|l| l.lout).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;

    fn qlayer(k: usize, stride: usize, cin: usize, cout: usize) -> QLayer {
        QLayer { k, stride, cin, cout, relu: true, nbits: 8, shift: 24,
                 s_in: 1.0, s_out: 1.0, w: vec![1; k * cin * cout],
                 bias: vec![0; cout], m0: vec![0; cout] }
    }

    #[test]
    fn halving_geometry() {
        let cfg = ChipConfig::paper_1d(); // 8 SPEs
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &cfg, 512);
        assert_eq!(s.lout, 256);
        assert_eq!(s.window_len, 7);
        assert_eq!(s.ch_tiles, 1);
        assert_eq!(s.pos_tiles, 32); // 256 / 8 SPEs
        assert_eq!(s.steps(), 32);
    }

    #[test]
    fn channel_tiles_round_up() {
        let cfg = ChipConfig::paper_1d();
        let s = LayerSchedule::of(&qlayer(3, 2, 64, 96), &cfg, 16);
        assert_eq!(s.ch_tiles, 6);
        assert_eq!(s.lout, 8);
        assert_eq!(s.pos_tiles, 1);
        assert_eq!(s.steps(), 6);
    }

    #[test]
    fn full_model_chains_lengths() {
        let cfg = ChipConfig::paper_1d();
        let layers = vec![
            qlayer(7, 2, 1, 16), qlayer(5, 2, 16, 32), qlayer(5, 2, 32, 48),
            qlayer(5, 2, 48, 64), qlayer(5, 2, 64, 64), qlayer(3, 2, 64, 96),
            qlayer(3, 2, 96, 128), qlayer(1, 1, 128, 2),
        ];
        let s = Schedule::of(&layers, &cfg, 512);
        let louts: Vec<usize> = s.layers.iter().map(|l| l.lout).collect();
        assert_eq!(louts, vec![256, 128, 64, 32, 16, 8, 4, 4]);
        assert_eq!(s.final_len(), 4);
        assert_eq!(s.l_in, 512);
    }

    #[test]
    fn more_spes_fewer_pos_tiles() {
        let full = ChipConfig::paper(); // 32 SPEs
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &full, 512);
        assert_eq!(s.pos_tiles, 8); // 256 / 32
    }
}
