//! Tile scheduling: how the synchronous array walks a layer, and the
//! **tile-major activation layout** the engines execute over.
//!
//! Geometry per layer (1-D mapping, DESIGN.md §Hardware-Adaptation):
//! the engaged SPEs each compute one output *position* at a time, all
//! `m` output channels of a channel tile in parallel; positions are
//! assigned to SPEs in contiguous blocks for SPad locality. A layer is
//! therefore a `ch_tiles × pos_tiles` grid of synchronous array steps.
//!
//! Layout: a layer's output buffer is `[ch_tile][lout][lane]` — each
//! channel tile owns one contiguous **column stripe** (`lout × live`
//! words, where `live ≤ m` is the stripe's populated lane count). The
//! stripes of a layer are disjoint and ordered, so both engines split
//! the output buffer with `chunks_mut(stripe_stride)` and write every
//! tile's accumulators directly into their final location — no
//! `[lout, live]` → `[lout, cout]` scatter pass exists anywhere.
//!
//! The stripe layout is also the **interchange format between
//! layers**: [`Schedule::of`] copies each producer's stripe table onto
//! the consumer's [`LayerSchedule::in_stripes`], and the engines stage
//! the next layer's padded window buffer straight from those stripes
//! with the requant fused into the read
//! ([`crate::nn::pad_same_from_stripes`]). No separate requant-drain
//! pass — and no row-major intermediate feature map — exists between
//! conv layers; only the network input arrives `[L, Cin]` row-major,
//! and only the head readout leaves stripe space (it pools straight
//! off the head's stripes). See DESIGN.md §"Data layout contract".

use crate::arch::ChipConfig;
use crate::nn::QLayer;

/// Column-stripe geometry of one output-channel tile in the tile-major
/// layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileStripe {
    /// First output channel of the stripe (`tile · m`).
    pub base_co: usize,
    /// Populated lanes: `min(cout - base_co, m)`. Only the last stripe
    /// of a layer can be partial (`live < m`); its padding lanes exist
    /// in the SPE array but not in the activation buffer.
    pub live: usize,
    /// Word offset of the stripe in the layer's output buffer
    /// (`tile · stripe_stride` — full stripes precede the partial one).
    pub offset: usize,
}

/// Static schedule for one layer.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    /// Input length after 'same' padding.
    pub l_padded: usize,
    /// Output positions.
    pub lout: usize,
    /// Receptive-field window per position (K·Cin).
    pub window_len: usize,
    /// Output-channel tiles: ceil(Cout / M).
    pub ch_tiles: usize,
    /// Position tiles: ceil(Lout / engaged SPEs).
    pub pos_tiles: usize,
    /// SPad words written to stage the input tile (per channel tile).
    pub fill_words: u64,
    /// Control overhead cycles charged per array step (tile dispatch,
    /// address generation — the "simple control logic" of Fig. 2).
    pub ctrl_cycles_per_tile: u64,
    /// One-off per-layer overhead (descriptor load, pipeline flush).
    pub layer_overhead_cycles: u64,
    /// Output buffer length in words (`lout · cout` — the tile-major
    /// layout is packed: partial stripes store only live lanes).
    pub out_len: usize,
    /// Word stride between consecutive stripe starts (`m · lout`).
    /// `chunks_mut(stripe_stride)` over an `out_len` buffer yields
    /// exactly the layer's stripes, the last one `live · lout` long.
    pub stripe_stride: usize,
    /// Column-stripe table, one entry per channel tile, in tile order.
    pub stripes: Vec<TileStripe>,
    /// Input length in samples (the producer's `lout`, or the network
    /// input length for layer 0).
    pub l_in: usize,
    /// Producer-side layout of this layer's INPUT feature map: the
    /// producing layer's stripe table, copied across the layer
    /// boundary by [`Schedule::of`] so the engines can stage the
    /// padded window buffer straight from the producer's stripes
    /// ([`crate::nn::pad_same_from_stripes`]). Empty for layer 0 (the
    /// network input is `[L, Cin]` row-major, not striped) and for a
    /// [`LayerSchedule`] built standalone via [`LayerSchedule::of`].
    pub in_stripes: Vec<TileStripe>,
}

impl LayerSchedule {
    pub fn of(ly: &QLayer, cfg: &ChipConfig, l_in: usize) -> Self {
        let pad = ly.k - ly.stride;
        let l_padded = l_in + pad;
        let lout = (l_padded - ly.k) / ly.stride + 1;
        let spes = cfg.engaged_spes();
        let ch_tiles = ly.cout.div_ceil(cfg.m);
        let stripe_stride = cfg.m * lout;
        let stripes = (0..ch_tiles)
            .map(|t| {
                let base_co = t * cfg.m;
                TileStripe {
                    base_co,
                    live: (ly.cout - base_co).min(cfg.m),
                    offset: t * stripe_stride,
                }
            })
            .collect();
        Self {
            l_padded,
            lout,
            window_len: ly.k * ly.cin,
            ch_tiles,
            pos_tiles: lout.div_ceil(spes),
            fill_words: (l_padded * ly.cin) as u64,
            ctrl_cycles_per_tile: 2,
            layer_overhead_cycles: 32,
            out_len: lout * ly.cout,
            stripe_stride,
            stripes,
            l_in,
            in_stripes: Vec::new(),
        }
    }

    /// Total synchronous array steps in this layer.
    pub fn steps(&self) -> u64 {
        (self.ch_tiles * self.pos_tiles) as u64
    }

    /// Split a tile-major output buffer into its disjoint column
    /// stripes (one `&mut` per channel tile, in tile order). The
    /// serial engines index stripes directly; the rayon tile loop uses
    /// `par_chunks_mut(stripe_stride)`, which produces the identical
    /// partition.
    pub fn stripe_chunks_mut<'a>(&self, out: &'a mut [i32])
                                 -> std::slice::ChunksMut<'a, i32> {
        debug_assert_eq!(out.len(), self.out_len);
        out.chunks_mut(self.stripe_stride.max(1))
    }
}

/// Whole-model schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub layers: Vec<LayerSchedule>,
    /// Input length (samples) the model was scheduled for. The static
    /// cost model and the fast engine's input-length check both key off
    /// this: every schedule-derived count assumes exactly this many
    /// samples stream in.
    pub l_in: usize,
}

impl Schedule {
    pub fn of(layers: &[QLayer], cfg: &ChipConfig, l_in: usize) -> Self {
        let mut l = l_in;
        let mut out: Vec<LayerSchedule> = Vec::with_capacity(layers.len());
        for ly in layers {
            let mut s = LayerSchedule::of(ly, cfg, l);
            // carry the producer's layout across the layer boundary:
            // the consumer stages its padded input straight from these
            // stripes (fused requant, `nn::pad_same_from_stripes`)
            if let Some(prev) = out.last() {
                s.in_stripes = prev.stripes.clone();
            }
            l = s.lout;
            out.push(s);
        }
        Self { layers: out, l_in }
    }

    /// Final feature-map length (head input to global pooling).
    pub fn final_len(&self) -> usize {
        self.layers.last().map(|l| l.lout).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;

    fn qlayer(k: usize, stride: usize, cin: usize, cout: usize) -> QLayer {
        QLayer { k, stride, cin, cout, relu: true, nbits: 8, shift: 24,
                 s_in: 1.0, s_out: 1.0, w: vec![1; k * cin * cout],
                 bias: vec![0; cout], m0: vec![0; cout] }
    }

    #[test]
    fn halving_geometry() {
        let cfg = ChipConfig::paper_1d(); // 8 SPEs
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &cfg, 512);
        assert_eq!(s.lout, 256);
        assert_eq!(s.window_len, 7);
        assert_eq!(s.ch_tiles, 1);
        assert_eq!(s.pos_tiles, 32); // 256 / 8 SPEs
        assert_eq!(s.steps(), 32);
    }

    #[test]
    fn channel_tiles_round_up() {
        let cfg = ChipConfig::paper_1d();
        let s = LayerSchedule::of(&qlayer(3, 2, 64, 96), &cfg, 16);
        assert_eq!(s.ch_tiles, 6);
        assert_eq!(s.lout, 8);
        assert_eq!(s.pos_tiles, 1);
        assert_eq!(s.steps(), 6);
    }

    #[test]
    fn full_model_chains_lengths() {
        let cfg = ChipConfig::paper_1d();
        let layers = vec![
            qlayer(7, 2, 1, 16), qlayer(5, 2, 16, 32), qlayer(5, 2, 32, 48),
            qlayer(5, 2, 48, 64), qlayer(5, 2, 64, 64), qlayer(3, 2, 64, 96),
            qlayer(3, 2, 96, 128), qlayer(1, 1, 128, 2),
        ];
        let s = Schedule::of(&layers, &cfg, 512);
        let louts: Vec<usize> = s.layers.iter().map(|l| l.lout).collect();
        assert_eq!(louts, vec![256, 128, 64, 32, 16, 8, 4, 4]);
        assert_eq!(s.final_len(), 4);
        assert_eq!(s.l_in, 512);
    }

    #[test]
    fn in_stripes_carry_the_producer_layout() {
        let cfg = ChipConfig::paper_1d(); // m = 16
        let layers = vec![
            qlayer(7, 2, 1, 20), // ends in a partial stripe (live 4)
            qlayer(5, 2, 20, 32),
            qlayer(1, 1, 32, 2),
        ];
        let s = Schedule::of(&layers, &cfg, 64);
        // layer 0 consumes the row-major network input: no stripes
        assert!(s.layers[0].in_stripes.is_empty());
        // every later layer carries its producer's stripe table and
        // input length verbatim
        for li in 1..s.layers.len() {
            assert_eq!(s.layers[li].in_stripes, s.layers[li - 1].stripes,
                       "layer {li}");
            assert_eq!(s.layers[li].l_in, s.layers[li - 1].lout, "layer {li}");
        }
        // a standalone LayerSchedule has no producer to inherit from
        let lone = LayerSchedule::of(&qlayer(5, 2, 20, 32), &cfg, 32);
        assert!(lone.in_stripes.is_empty());
        assert_eq!(lone.l_in, 32);
    }

    #[test]
    fn more_spes_fewer_pos_tiles() {
        let full = ChipConfig::paper(); // 32 SPEs
        let s = LayerSchedule::of(&qlayer(7, 2, 1, 16), &full, 512);
        assert_eq!(s.pos_tiles, 8); // 256 / 32
    }

    #[test]
    fn stripes_tile_the_output_buffer_exactly() {
        let cfg = ChipConfig::paper_1d(); // m = 16
        // cout 20 -> one full stripe + one partial stripe of 4 lanes
        let s = LayerSchedule::of(&qlayer(3, 2, 4, 20), &cfg, 16);
        assert_eq!(s.lout, 8);
        assert_eq!(s.out_len, 8 * 20);
        assert_eq!(s.stripe_stride, 16 * 8);
        assert_eq!(s.stripes.len(), 2);
        assert_eq!(s.stripes[0],
                   TileStripe { base_co: 0, live: 16, offset: 0 });
        assert_eq!(s.stripes[1],
                   TileStripe { base_co: 16, live: 4, offset: 128 });
        // chunks_mut(stripe_stride) reproduces the stripe table
        let mut buf = vec![0i32; s.out_len];
        let chunks: Vec<usize> =
            s.stripe_chunks_mut(&mut buf).map(|c| c.len()).collect();
        assert_eq!(chunks, vec![128, 32]);
        for (st, len) in s.stripes.iter().zip(&chunks) {
            assert_eq!(st.live * s.lout, *len);
        }
        // offsets are contiguous: stripe t starts where t-1 ended
        assert_eq!(s.stripes[1].offset,
                   s.stripes[0].offset + s.stripes[0].live * s.lout);
    }

    #[test]
    fn full_multiple_cout_has_only_full_stripes() {
        let cfg = ChipConfig::paper_1d();
        let s = LayerSchedule::of(&qlayer(5, 2, 16, 32), &cfg, 64);
        assert_eq!(s.stripes.len(), 2);
        assert!(s.stripes.iter().all(|st| st.live == 16));
        assert_eq!(s.out_len, s.ch_tiles * s.stripe_stride);
    }
}
