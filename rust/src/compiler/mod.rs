//! The model compiler (paper §2: "a co-design pruning mechanism is
//! implemented in the compiler to balance workloads and execution
//! times across and within PEs").
//!
//! Input: a trained, pruned, quantized [`crate::nn::QuantModel`]
//! (from `artifacts/weights.bin`) + a [`crate::arch::ChipConfig`].
//! Output: a [`CompiledModel`] — per-layer compressed weight streams
//! packed into one flat SoA arena each ([`PackedStreams`]: contiguous
//! select-signal + non-zero-weight vectors with a `[tile][lane] →
//! (offset, len)` range table, Fig. 2 — the software analogue of the
//! chip streaming compressed weights from a contiguous SPad), the
//! tile schedule the synchronous array walks, buffer-fit checks,
//! workload-balance diagnostics, and the precompiled [`StaticCost`]:
//! the complete per-inference event-counter set, derivable at compile
//! time because zero-skip operates on weights, never activations.
//!
//! The [`Schedule`] also owns the **data-layout contract** (DESIGN.md
//! §"Data layout contract"): each [`LayerSchedule`] carries its
//! output stripe table ([`TileStripe`]) *and* its producer's table
//! (`in_stripes`), which is what lets every engine stage layer inputs
//! straight from the previous layer's stripes with the requant fused
//! into the read ([`crate::nn::pad_same_from_stripes`]). Execute a
//! `CompiledModel` via [`crate::sim::run`] (serving fast path),
//! [`crate::sim::run_counted_scratch`] (dynamic counter reference) or
//! audit it against [`crate::nn::QuantModel::forward_scratch`]
//! (golden, no chip model) — see [`crate::sim`] for the full routing
//! guide.

mod balance;
mod packer;
mod program;
mod schedule;
mod statics;

pub use balance::{BalanceReport, LaneBalance};
pub use packer::{crc32_words, pack_layer, PackedStreams};
pub use program::{compile, CompiledLayer, CompiledModel};
pub use schedule::{LayerFringe, LayerSchedule, Schedule, StreamPlan,
                   TileStripe};
pub use statics::{derive_static_cost, StaticCost};
