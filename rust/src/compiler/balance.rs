//! Workload-balance diagnostics.
//!
//! On a synchronous array a layer finishes when its slowest PE lane
//! finishes, so the *imbalance factor* (max lane work / mean lane
//! work) is exactly the latency penalty unbalanced pruning pays. The
//! python build pipeline prunes balanced (equal non-zeros per output
//! channel); this module verifies that property at load time and
//! quantifies what an unbalanced model would cost (the `sparsity`
//! bench sweeps it).

use crate::nn::{QLayer, QuantModel};

/// Per-layer lane balance.
#[derive(Debug, Clone)]
pub struct LaneBalance {
    pub layer: usize,
    /// Non-zero weights per lane (output channel).
    pub lane_nnz: Vec<usize>,
    pub max: usize,
    pub mean: f64,
    /// max / mean ≥ 1; 1.0 is perfectly balanced.
    pub imbalance: f64,
    /// Cycles wasted per output position vs a perfectly balanced
    /// distribution of the same total work (at 8-bit, 1 MAC/cycle).
    pub straggler_cycles: f64,
}

impl LaneBalance {
    pub fn of(layer: usize, ly: &QLayer) -> Self {
        let lane_nnz = ly.lane_nnz();
        let max = lane_nnz.iter().copied().max().unwrap_or(0);
        let mean = if lane_nnz.is_empty() {
            0.0
        } else {
            lane_nnz.iter().sum::<usize>() as f64 / lane_nnz.len() as f64
        };
        let imbalance = if mean > 0.0 { max as f64 / mean } else { 1.0 };
        Self { layer, lane_nnz, max, mean, imbalance,
               straggler_cycles: max as f64 - mean }
    }

    /// True when every lane carries identical work (the co-design
    /// pruning invariant).
    pub fn is_balanced(&self) -> bool {
        self.lane_nnz.windows(2).all(|w| w[0] == w[1])
    }
}

/// Whole-model balance report.
#[derive(Debug, Clone)]
pub struct BalanceReport {
    pub layers: Vec<LaneBalance>,
}

impl BalanceReport {
    pub fn of(model: &QuantModel) -> Self {
        Self {
            layers: model.layers.iter().enumerate()
                .map(|(i, ly)| LaneBalance::of(i, ly))
                .collect(),
        }
    }

    /// Worst imbalance across layers.
    pub fn worst(&self) -> f64 {
        self.layers.iter().map(|l| l.imbalance).fold(1.0, f64::max)
    }

    /// Latency-weighted imbalance: Σ max / Σ mean (the end-to-end
    /// slowdown factor attributable to stragglers).
    pub fn end_to_end_penalty(&self) -> f64 {
        let max: f64 = self.layers.iter().map(|l| l.max as f64).sum();
        let mean: f64 = self.layers.iter().map(|l| l.mean).sum();
        if mean > 0.0 { max / mean } else { 1.0 }
    }
}

impl std::fmt::Display for BalanceReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "layer  lanes  mean-nnz  max-nnz  imbalance")?;
        for l in &self.layers {
            writeln!(f, "{:>5}  {:>5}  {:>8.1}  {:>7}  {:>9.3}",
                     l.layer, l.lane_nnz.len(), l.mean, l.max, l.imbalance)?;
        }
        write!(f, "end-to-end straggler penalty: {:.3}x",
               self.end_to_end_penalty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer_with(w: Vec<i32>, cout: usize) -> QLayer {
        let kcin = w.len() / cout;
        QLayer { k: kcin, stride: 1, cin: 1, cout, relu: true, nbits: 8,
                 shift: 24, s_in: 1.0, s_out: 1.0, w,
                 bias: vec![0; cout], m0: vec![0; cout] }
    }

    #[test]
    fn balanced_detection() {
        // [K*Cin=2, cout=2] interleaved layout: lanes get 1 nnz each
        let b = LaneBalance::of(0, &layer_with(vec![1, 0, 0, 2], 2));
        assert!(b.is_balanced());
        assert!((b.imbalance - 1.0).abs() < 1e-12);
        assert_eq!(b.straggler_cycles, 0.0);
    }

    #[test]
    fn unbalanced_quantified() {
        // lane0: 2 nnz, lane1: 0 nnz -> max 2, mean 1, imbalance 2
        let b = LaneBalance::of(0, &layer_with(vec![1, 0, 3, 0], 2));
        assert!(!b.is_balanced());
        assert!((b.imbalance - 2.0).abs() < 1e-12);
        assert_eq!(b.max, 2);
    }

    #[test]
    fn report_penalty_weights_layers() {
        let m = QuantModel { layers: vec![
            layer_with(vec![1, 0, 0, 2], 2),   // balanced, mean 1
            layer_with(vec![1, 0, 3, 0], 2),   // imbalanced 2x, mean 1
        ]};
        let r = BalanceReport::of(&m);
        assert!((r.worst() - 2.0).abs() < 1e-12);
        // (1 + 2) / (1 + 1) = 1.5
        assert!((r.end_to_end_penalty() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn artifact_model_is_balanced_if_present() {
        let p = std::path::Path::new(crate::ARTIFACT_DIR).join("weights.bin");
        if let Ok(m) = QuantModel::load(&p) {
            let r = BalanceReport::of(&m);
            // python prunes balanced on layers 2..7 (first/last dense)
            for l in &r.layers {
                assert!(l.imbalance < 1.05,
                        "layer {} imbalance {}", l.layer, l.imbalance);
            }
        }
    }
}
