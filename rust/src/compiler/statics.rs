//! Compile-time event counters (the "precompiled static cost").
//!
//! The chip's sparse dataflow is fixed at compile time: zero-skip
//! operates on *weights*, never activations, so every event the
//! simulator counts — MACs, CMUL segment ops, SPad traffic, weight
//! fetches, cycles, pool ops — is a property of the packed lane
//! streams plus the tile schedule, not of the input recording. This
//! module derives the complete per-inference [`Counters`] once at
//! [`super::compile`] time; the fast simulator path
//! ([`crate::sim::run`]) then clones-and-stamps it onto each
//! [`crate::sim::SimResult`] instead of re-counting, and the counted
//! reference path ([`crate::sim::run_counted`]) re-measures it
//! dynamically. `tests/static_counters.rs` pins the two bit-identical
//! across seeds, precisions, strides and dense/sparse modes.
//!
//! Every formula here mirrors one line of the counted engine
//! (`sim::engine::sim_tile` / `run_with`); the timing term goes
//! through the SAME [`tile_cycles`] the reference path calls, so the
//! two cannot drift apart silently. The remaining counter formulas are
//! DELIBERATELY derived independently rather than shared: the counted
//! engine measures events as execution happens, this module computes
//! them closed-form, and `tests/static_counters.rs` pins the two
//! bit-identical — a shared implementation would make that cross-check
//! tautological. If you change an event model on either side, the
//! suite fails until the mirror line is updated.

use crate::arch::{cmul_segments, tile_cycles, ChipConfig, LaneWork, Spad};
use crate::sim::{Counters, LayerCounters};

use super::program::CompiledLayer;
use super::schedule::Schedule;

/// The complete input-independent cost of one inference.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StaticCost {
    /// Required quantized input length (`l_in · cin₀`); the fast engine
    /// asserts every recording matches before stamping the counters.
    pub input_len: usize,
    /// Full per-inference counters, bit-identical to what
    /// [`crate::sim::run_counted`] measures on any valid input.
    pub counters: Counters,
}

/// Derive the static cost of one inference from the compiled layers
/// and schedule.
pub fn derive_static_cost(cfg: &ChipConfig, layers: &[CompiledLayer],
                          schedule: &Schedule) -> StaticCost {
    let cin0 = layers.first().map(|l| l.cin).unwrap_or(0);
    let mut counters = Counters {
        // input streams into the SPad at one sample per cycle
        input_load_cycles: (schedule.l_in * cin0) as u64,
        ..Counters::default()
    };

    let n = layers.len();
    // one reusable lane-view buffer across every tile of every layer:
    // materializing the m borrowed views per tile allocates nothing in
    // steady state. The views borrow the arena's decoded i32 weight
    // MIRROR, not the sub-byte packed words — the bit-packing is a
    // physical-storage concern of the SIMD fast path and moves no
    // events, so the static cost is identical under either kernel tier
    // (see PackedStreams' mirror contract).
    let mut lanes: Vec<LaneWork> = Vec::new();
    for (li, layer) in layers.iter().enumerate() {
        let sched = &schedule.layers[li];
        let lout = sched.lout as u64;
        let mut lc = LayerCounters::default();
        let mut total_nnz = 0u64;
        for t in 0..layer.packed.ch_tiles() {
            layer.packed.tile_lanes_into(t, &mut lanes);
            let tile_nnz: u64 = lanes.iter().map(|l| l.len() as u64).sum();
            total_nnz += tile_nnz;
            // per tile: stage the input tile, then every position
            // broadcasts its window from SPad into the regfile
            let mut spad = Spad::new();
            spad.fill(cfg.spad_sharing, sched.fill_words, cfg.m as u64);
            spad.fetch_activations(cfg.spad_sharing,
                                   sched.window_len as u64 * lout,
                                   cfg.m as u64);
            lc.spad.merge(&spad);
            // timing: all position tiles of this channel tile in
            // lockstep — the one shared formula
            let tc = tile_cycles(&lanes, sched.window_len, layer.nbits,
                                 cfg.zero_skip);
            lc.cycles +=
                sched.pos_tiles as u64 * (tc + sched.ctrl_cycles_per_tile);
            // weights broadcast once per position tile
            lc.weight_fetches += tile_nnz * sched.pos_tiles as u64;
        }
        lc.cycles += sched.layer_overhead_cycles;
        lc.macs = lout * total_nnz;
        lc.segment_ops = lc.macs * cmul_segments(layer.nbits) as u64;
        lc.macs_dense =
            lout * (layer.k * layer.cin * layer.cout) as u64;
        // the requant drain's event count: one requantized write per
        // output element. The drain is FUSED into the next layer's
        // staging read (`nn::pad_same_from_stripes`) — fusion moves
        // the pass, not the events, so this charge is identical on
        // the pre- and post-fusion datapaths and the counted engine
        // mirrors it unconditionally.
        lc.output_writes = lout * layer.cout as u64;
        if !cfg.zero_skip {
            // dense datapath executes every weight (energy follows)
            lc.macs = lc.macs_dense;
            lc.segment_ops = lc.macs_dense * layer.nbits as u64;
            lc.weight_fetches =
                lc.macs_dense / lout.max(1) * sched.pos_tiles as u64;
        }
        if li == n - 1 {
            // MPE global average pooling: one op per head element
            lc.pool_ops = lout * layer.cout as u64;
        }
        counters.per_layer.push(lc);
    }

    // readout: head feature map drains through the engaged MPEs
    let head_elems =
        (schedule.final_len() * layers.last().map(|l| l.cout).unwrap_or(0))
            as u64;
    let mpes = (cfg.mpes_per_spe * cfg.engaged_spes()).max(1) as u64;
    counters.readout_cycles = head_elems.div_ceil(mpes) + 4;

    StaticCost { input_len: schedule.l_in * cin0, counters }
}

#[cfg(test)]
mod tests {
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::data::fixtures;

    /// The real assertions (static == dynamically counted, seed-swept,
    /// dense + stride edge cases) live in `tests/static_counters.rs`;
    /// here we pin the structural shape only.
    #[test]
    fn static_cost_is_fully_populated() {
        let m = fixtures::quant_model(0xA11CE);
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let sc = &cm.static_cost;
        assert_eq!(sc.input_len, crate::REC_LEN);
        assert_eq!(sc.counters.per_layer.len(), m.layers.len());
        assert_eq!(sc.counters.input_load_cycles, crate::REC_LEN as u64);
        assert!(sc.counters.readout_cycles > 4);
        for (li, lc) in sc.counters.per_layer.iter().enumerate() {
            assert!(lc.cycles > 0, "layer {li}");
            assert!(lc.macs > 0 && lc.macs_dense >= lc.macs, "layer {li}");
            assert!(lc.weight_fetches > 0 && lc.output_writes > 0, "layer {li}");
            assert!(lc.spad.reads > 0 && lc.spad.writes > 0, "layer {li}");
        }
        assert!(sc.counters.per_layer.last().unwrap().pool_ops > 0);
        assert_eq!(sc.counters.per_layer[0].pool_ops, 0);
    }

    #[test]
    fn dense_mode_costs_more() {
        let m = fixtures::quant_model(0xA11CE);
        let mut dense_cfg = ChipConfig::paper_1d();
        dense_cfg.zero_skip = false;
        let sparse = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        let dense = compile(&m, &dense_cfg, crate::REC_LEN).unwrap();
        let (s, d) = (&sparse.static_cost.counters, &dense.static_cost.counters);
        assert!(d.total_cycles() > s.total_cycles());
        assert!(d.total_macs() > s.total_macs());
        assert_eq!(d.total_macs(), d.total_macs_dense());
    }
}
