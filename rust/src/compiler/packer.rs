//! Weight packing: dense `[K, Cin, Cout]` tensors → one flat
//! **stream arena** of compressed (select, weight) pairs per layer.
//!
//! The select signal is the index into the output position's
//! receptive-field window (`k * cin + ci`), exactly the MUX address of
//! Fig. 2; zero weights simply do not appear in the stream, which is
//! how the chip skips them "costing neither a cycle nor a multiplier
//! toggle".
//!
//! Memory layout ([`PackedStreams`], DESIGN.md §"Weight-stream memory
//! layout" and §"Sub-byte weight words & kernel dispatch"): the
//! paper's SPE streams compressed weights from a contiguous SPad, so
//! the software model does the same — one layer is parallel SoA
//! vectors (`selects`, plus the weight stream **bit-packed at the
//! layer's `nbits`** with a decoded `i32` mirror) holding every
//! lane's pairs back to back in execution order
//! (`[ch_tile][lane][pair]`), plus a flat `[tile · m + lane] →
//! (offset, len)` range table and a flat bias vector. A
//! [`LaneWork`] is just one range of that arena materialized as
//! borrowed slices; nothing on the inference path owns a per-lane
//! heap allocation.

use crate::arch::{unpack_weight, LaneWork, WeightStream};
use crate::nn::QLayer;

/// One layer's compressed streams in a single flat SoA arena, grouped
/// into output-channel tiles of `m` lanes (the M dimension of the
/// array). Replaces the per-lane `Vec<Vec<LaneWork>>` of earlier
/// revisions: every engine iterates two contiguous vectors instead of
/// chasing per-lane heap pointers.
///
/// Invariants (pinned by `tests/packed_streams.rs`):
/// * ranges are **tight and ordered**: lane `[t][l]`'s range starts
///   where `[t][l-1]`'s ends (lane 0 of tile 0 at offset 0) and the
///   last range ends at `selects.len() == weights.len()`;
/// * the last tile's trailing lanes (`cout % m != 0`, the array's
///   padding lanes — "redundant computing units will be padded by
///   zero during inference") have empty ranges and zero bias;
/// * packing order per lane is window order (`k`-major, then `ci`),
///   identical to the order the reference per-co packing emits, so
///   packing moves memory, never arithmetic or events.
/// Sub-byte packing: the weight stream is stored **bit-packed by the
/// layer's `nbits`** — `wbits = nbits.max(2)` two's-complement fields,
/// LSB-first, `32 / wbits` fields per `u32` word (2-bit → 16/word,
/// 4-bit → 8/word, 8-bit → 4/word), so the flat range table addresses
/// packed crumbs/nibbles directly: pair `i` of the arena is word
/// `i / per_word`, field `i % per_word`. A decoded `i32` **mirror** is
/// kept alongside ([`Self::weights`]) so every counter path
/// (`tile_lanes_into` → [`crate::arch::Spe`] / `tile_cycles` /
/// `compiler::statics`) sees the same `i32` views as before — packing
/// moves memory, never events — while the SIMD tier
/// ([`crate::arch::tile_block`]) decodes the physical words
/// in-register. `nbits = 1` still packs at 2 bits: ±1 needs a sign
/// bit.
#[derive(Debug, Clone)]
pub struct PackedStreams {
    /// All lanes' select signals, concatenated `[ch_tile][lane]`-major.
    selects: Vec<u32>,
    /// Decoded `i32` mirror of [`Self::weight_words`] (same indexing
    /// as `selects`) — what every scalar/counter path reads.
    weights: Vec<i32>,
    /// Physical bit-packed weight stream: `wbits`-bit two's-complement
    /// fields, LSB-first, `32 / wbits` per word.
    weight_words: Vec<u32>,
    /// Bits per packed weight field (`nbits.max(2)`).
    wbits: u32,
    /// `[tile · m + lane] → (offset, len)` into `selects`/`weights`
    /// (and, as packed-field indices, into `weight_words`).
    ranges: Vec<(u32, u32)>,
    /// Bias per `[tile · m + lane]` (0 on padding lanes).
    biases: Vec<i32>,
    /// Lanes per SPE (the array's M).
    m: usize,
    /// Output-channel tiles: `ceil(cout / m)`.
    ch_tiles: usize,
    /// **Logical** bits of weight-buffer storage the chip would spend:
    /// `nnz · (nbits + select_bits)`. See [`Self::arena_bytes`] for
    /// the physical host-arena footprint.
    pub storage_bits: u64,
}

impl PackedStreams {
    /// Output-channel tiles in this layer.
    pub fn ch_tiles(&self) -> usize {
        self.ch_tiles
    }

    /// Lanes per tile (the array's M).
    pub fn m(&self) -> usize {
        self.m
    }

    /// The whole layer's select-signal stream (flat arena).
    pub fn selects(&self) -> &[u32] {
        &self.selects
    }

    /// The whole layer's non-zero weight stream — the decoded `i32`
    /// mirror of the packed words (flat arena).
    pub fn weights(&self) -> &[i32] {
        &self.weights
    }

    /// The physical bit-packed weight words (`32 / wbits` fields per
    /// word, LSB-first) — what the SIMD tier decodes in-register.
    pub fn weight_words(&self) -> &[u32] {
        &self.weight_words
    }

    /// Bits per packed weight field (`nbits.max(2)`).
    pub fn wbits(&self) -> u32 {
        self.wbits
    }

    /// The kernel-facing view bundle (selects + decoded mirror +
    /// packed words) the dispatched tile kernel
    /// ([`crate::arch::tile_block`]) consumes.
    pub fn stream(&self) -> WeightStream<'_> {
        WeightStream { selects: &self.selects, weights: &self.weights,
                       words: &self.weight_words, wbits: self.wbits }
    }

    /// Decode one lane's weights from the **physical packed words**
    /// into `buf` (cleared first). The unpack path of the sub-byte
    /// contract: for every lane this must reproduce
    /// [`Self::lane`]`.weights` exactly (pinned by the round-trip
    /// property test in `tests/simd_dispatch.rs`).
    pub fn unpack_lane(&self, t: usize, lane: usize, buf: &mut Vec<i32>) {
        let (off, len) = self.ranges[t * self.m + lane];
        let (off, len) = (off as usize, len as usize);
        buf.clear();
        buf.extend((off..off + len)
            .map(|i| unpack_weight(&self.weight_words, self.wbits, i)));
    }

    /// **Physical** bytes of this layer's host stream arena: the
    /// packed weight words plus the `u32` select stream. This is the
    /// footprint the packing actually pays (the decoded mirror is a
    /// software convenience, accounted separately by
    /// [`Self::mirror_bytes`]); contrast with the logical
    /// [`Self::storage_bits`] the chip's weight buffer would spend.
    pub fn arena_bytes(&self) -> u64 {
        4 * (self.weight_words.len() + self.selects.len()) as u64
    }

    /// Bytes of the decoded `i32` mirror kept for the scalar/counter
    /// paths.
    pub fn mirror_bytes(&self) -> u64 {
        4 * self.weights.len() as u64
    }

    /// Non-zero (select, weight) pairs across the layer.
    pub fn nnz(&self) -> u64 {
        self.weights.len() as u64
    }

    /// One tile's `m`-entry `(offset, len)` range table — what the
    /// packed tile kernel ([`crate::arch::tile_block_packed`]) walks.
    pub fn tile_ranges(&self, t: usize) -> &[(u32, u32)] {
        &self.ranges[t * self.m..(t + 1) * self.m]
    }

    /// One tile's `m` accumulator preloads (0 on padding lanes).
    pub fn tile_biases(&self, t: usize) -> &[i32] {
        &self.biases[t * self.m..(t + 1) * self.m]
    }

    /// Borrowed view of one lane's stream.
    pub fn lane(&self, t: usize, lane: usize) -> LaneWork<'_> {
        let (off, len) = self.ranges[t * self.m + lane];
        let (off, len) = (off as usize, len as usize);
        LaneWork {
            selects: &self.selects[off..off + len],
            weights: &self.weights[off..off + len],
        }
    }

    /// Fill `buf` with all `m` lane views of one tile (padding lanes
    /// become empty views) — the counted [`crate::arch::Spe`] path and
    /// the static cost model reuse one buffer across tiles so the view
    /// materialization allocates nothing in steady state.
    pub fn tile_lanes_into<'a>(&'a self, t: usize,
                               buf: &mut Vec<LaneWork<'a>>) {
        buf.clear();
        buf.extend((0..self.m).map(|lane| self.lane(t, lane)));
    }

    // -- integrity / fault-injection surface (reliability subsystem) --

    /// Number of physical packed weight words (the SEU target space of
    /// [`crate::reliability::FaultPlan::weight_seu`]).
    pub fn word_count(&self) -> usize {
        self.weight_words.len()
    }

    /// CRC32 over the physical packed weight words — the per-layer
    /// integrity stamp `compile()` records on
    /// [`crate::compiler::CompiledModel::weight_crcs`] and the scrub
    /// pass recomputes to detect upsets.
    pub fn words_crc(&self) -> u32 {
        crc32_words(&self.weight_words)
    }

    /// Flip one bit of one packed weight word (single-event-upset
    /// injection). Returns `false` (and does nothing) when the site is
    /// out of range. The decoded mirror is deliberately left alone:
    /// that asymmetry is the fault model — the SIMD tier now computes
    /// from corrupted physical storage while the mirror still holds
    /// truth, which is exactly what lets [`Self::repack_from_mirror`]
    /// restore the words.
    pub fn flip_word_bit(&mut self, word: usize, bit: u32) -> bool {
        if word >= self.weight_words.len() || bit >= 32 {
            return false;
        }
        self.weight_words[word] ^= 1 << bit;
        true
    }

    /// Rebuild the physical packed words from the decoded `i32`
    /// mirror, field by field — the restore half of the scrub pass.
    /// Uses the identical packing recipe as [`pack_layer`], so on an
    /// uncorrupted layer this is a byte-identical no-op.
    pub fn repack_from_mirror(&mut self) {
        let per_word = (32 / self.wbits) as usize;
        self.weight_words.clear();
        self.weight_words.resize(self.weights.len().div_ceil(per_word), 0);
        for (i, &w) in self.weights.iter().enumerate() {
            self.weight_words[i / per_word] |=
                ((w as u32) & ((1u32 << self.wbits) - 1))
                    << ((i % per_word) as u32 * self.wbits);
        }
    }
}

/// CRC-32 (ISO-HDLC polynomial, the zlib/`cksum -o3` one) over a word
/// slice, each word contributing its 4 LE bytes. Table-driven; the
/// table is built at compile time so the scrub pass costs ~1 cycle per
/// byte with no lazy-init branch on the hot path.
pub fn crc32_words(words: &[u32]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut t = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    };
    let mut c = 0xFFFF_FFFFu32;
    for w in words {
        for b in w.to_le_bytes() {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    !c
}

/// Select-signal width for a window of `window_len` entries.
fn select_bits(window_len: usize) -> u32 {
    (usize::BITS - (window_len.max(2) - 1).leading_zeros()).max(1)
}

/// Pack one quantized layer for an array with `m` lanes per SPE.
///
/// Channel `co` lands in tile `co / m`, lane `co % m`; since the flat
/// index `t · m + lane == co`, packing walks the channels in order and
/// the arena comes out `[ch_tile][lane]`-major by construction.
pub fn pack_layer(ly: &QLayer, m: usize) -> PackedStreams {
    let window_len = ly.k * ly.cin;
    let ch_tiles = ly.cout.div_ceil(m);
    let mut selects = Vec::new();
    let mut weights = Vec::new();
    let mut ranges = Vec::with_capacity(ch_tiles * m);
    let mut biases = vec![0i32; ch_tiles * m];
    for co in 0..ly.cout {
        biases[co] = ly.bias[co];
        let start = selects.len();
        for k in 0..ly.k {
            for ci in 0..ly.cin {
                let w = ly.w[(k * ly.cin + ci) * ly.cout + co];
                if w != 0 {
                    selects.push((k * ly.cin + ci) as u32);
                    weights.push(w);
                }
            }
        }
        ranges.push((start as u32, (selects.len() - start) as u32));
    }
    // padding lanes of the last tile: empty streams at the arena's end
    ranges.resize(ch_tiles * m, (selects.len() as u32, 0));
    // bit-pack the stream at the layer's width (±1 at nbits=1 still
    // needs a sign bit, so the floor is 2): pair i → word i/per_word,
    // field i%per_word, LSB-first two's complement
    let wbits = ly.nbits.max(2);
    let per_word = (32 / wbits) as usize;
    let mut weight_words = vec![0u32; weights.len().div_ceil(per_word)];
    for (i, &w) in weights.iter().enumerate() {
        assert!(w >= -(1 << (wbits - 1)) && w < (1 << (wbits - 1)),
                "weight {w} does not fit {wbits}-bit two's complement");
        weight_words[i / per_word] |=
            ((w as u32) & ((1u32 << wbits) - 1)) << ((i % per_word) as u32 * wbits);
    }
    let storage_bits = weights.len() as u64
        * (ly.nbits as u64 + select_bits(window_len) as u64);
    PackedStreams { selects, weights, weight_words, wbits, ranges, biases,
                    m, ch_tiles, storage_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::QLayer;

    fn layer_nbits(w: Vec<i32>, k: usize, cin: usize, cout: usize,
                   nbits: u32) -> QLayer {
        QLayer { k, stride: 1, cin, cout, relu: true, nbits, shift: 24,
                 s_in: 1.0, s_out: 1.0, w,
                 bias: (0..cout as i32).collect(),
                 m0: vec![1 << 24; cout] }
    }

    fn layer(w: Vec<i32>, k: usize, cin: usize, cout: usize) -> QLayer {
        layer_nbits(w, k, cin, cout, 8)
    }

    #[test]
    fn strips_zeros_and_orders_by_window() {
        // k=2, cin=1, cout=1: weights [5, 0] -> one pair (select 0, 5)
        let p = pack_layer(&layer(vec![5, 0], 2, 1, 1), 4);
        assert_eq!(p.ch_tiles(), 1);
        assert_eq!(p.lane(0, 0).selects, &[0u32]);
        assert_eq!(p.lane(0, 0).weights, &[5i32]);
        assert!(p.lane(0, 1).is_empty()); // padding lane
        assert_eq!(p.tile_biases(0), &[0, 0, 0, 0]);
    }

    #[test]
    fn channel_tiling_splits_cout() {
        // cout=5, m=4 -> 2 tiles, second has 1 live + 3 padding lanes
        let w = vec![1i32; 5]; // k=1, cin=1, cout=5
        let p = pack_layer(&layer(w, 1, 1, 5), 4);
        assert_eq!(p.ch_tiles(), 2);
        assert_eq!((0..4).filter(|&l| !p.lane(0, l).is_empty()).count(), 4);
        assert_eq!((0..4).filter(|&l| !p.lane(1, l).is_empty()).count(), 1);
        assert_eq!(p.tile_biases(1)[0], 4);
    }

    #[test]
    fn arena_ranges_are_tight_and_ordered() {
        // the flat arena must be a tight concatenation: each lane's
        // range starts where the previous ended, padding lanes are
        // empty at the end, and every pair is covered exactly once
        let w = vec![1, 0, 2, 0, 3,
                     0, 4, 0, 5, 0]; // k=2, cin=1, cout=5
        let p = pack_layer(&layer(w, 2, 1, 5), 4);
        assert_eq!(p.nnz(), 5);
        assert_eq!(p.selects().len(), p.weights().len());
        let mut expect_off = 0u32;
        for t in 0..p.ch_tiles() {
            for (off, len) in p.tile_ranges(t) {
                assert_eq!(*off, expect_off, "tile {t}");
                expect_off += len;
            }
        }
        assert_eq!(expect_off as usize, p.weights().len());
        // padding lanes: empty view, zero bias
        for lane in 1..4 {
            assert!(p.lane(1, lane).is_empty());
            assert_eq!(p.tile_biases(1)[lane], 0);
        }
        // tile_lanes_into yields exactly the m per-lane views
        let mut buf = Vec::new();
        p.tile_lanes_into(0, &mut buf);
        assert_eq!(buf.len(), 4);
        for (lane, v) in buf.iter().enumerate() {
            assert_eq!(v.selects, p.lane(0, lane).selects);
            assert_eq!(v.weights, p.lane(0, lane).weights);
        }
    }

    #[test]
    fn select_indexes_reconstruct_conv() {
        // pack a random-ish small layer and check one position's dot
        // product against the golden conv
        let k = 3;
        let cin = 2;
        let cout = 2;
        let w = vec![1, 0, 0, -2, 3, 0, 0, 4, -5, 0, 0, 6];
        let ly = layer(w.clone(), k, cin, cout);
        let p = pack_layer(&ly, 2);
        let a = [7, -3, 2, 9, -1, 4]; // one window [k*cin]
        let golden = crate::nn::conv1d_int(&a, k, cin, &w, k, cout,
                                           &ly.bias, 1);
        for co in 0..cout {
            let lane = p.lane(0, co);
            let mut acc = ly.bias[co];
            for (&s, &wt) in lane.selects.iter().zip(lane.weights) {
                acc += a[s as usize] * wt;
            }
            assert_eq!(acc, golden[co]);
        }
    }

    #[test]
    fn storage_accounting() {
        // window 4 -> 2 select bits; 3 nnz at 8-bit -> 3*(8+2)=30 bits
        let p = pack_layer(&layer(vec![1, 2, 0, 3], 4, 1, 1), 1);
        assert_eq!(p.storage_bits, 30);
        // physical arena: 3 selects (12 B) + 1 packed word of 4
        // 8-bit fields (4 B); the decoded mirror is 3 i32 (12 B)
        assert_eq!(p.arena_bytes(), 16);
        assert_eq!(p.mirror_bytes(), 12);
        assert_eq!(p.wbits(), 8);
        assert_eq!(p.weight_words().len(), 1);
    }

    #[test]
    fn sub_byte_words_pack_lsb_first_twos_complement() {
        // nbits=4: [1, -7, 3] -> fields 0x1, 0x9, 0x3 -> word 0x391
        let p = pack_layer(&layer_nbits(vec![1, -7, 3], 3, 1, 1, 4), 1);
        assert_eq!(p.wbits(), 4);
        assert_eq!(p.weight_words(), &[0x391u32]);
        assert_eq!(p.weights(), &[1, -7, 3]);
        // nbits=2: [1, -1] -> fields 0b01, 0b11 -> word 0b1101
        let p = pack_layer(&layer_nbits(vec![1, -1], 2, 1, 1, 2), 1);
        assert_eq!(p.wbits(), 2);
        assert_eq!(p.weight_words(), &[0b1101u32]);
        // nbits=1 packs at 2 bits: ±1 needs a sign bit
        let p = pack_layer(&layer_nbits(vec![1, -1], 2, 1, 1, 1), 1);
        assert_eq!(p.wbits(), 2);
        assert_eq!(p.weight_words(), &[0b1101u32]);
    }

    #[test]
    fn unpack_lane_round_trips_the_mirror() {
        // multi-lane 4-bit layer crossing a word boundary (9 nnz at
        // 8 fields/word), including an all-zero (empty) channel
        let w = vec![ 1, 0, -2,
                      3, 0,  4,
                     -5, 0,  6,
                      7, 0, -7,
                      2, 0,  0]; // k=5, cin=1, cout=3 (co-major rows)
        let p = pack_layer(&layer_nbits(w, 5, 1, 3, 4), 2);
        assert!(p.weight_words().len() >= 2);
        let mut buf = Vec::new();
        for t in 0..p.ch_tiles() {
            for lane in 0..p.m() {
                p.unpack_lane(t, lane, &mut buf);
                assert_eq!(buf.as_slice(), p.lane(t, lane).weights,
                           "tile {t} lane {lane}");
            }
        }
        // the stream() bundle exposes the same three views
        let ws = p.stream();
        assert_eq!(ws.selects, p.selects());
        assert_eq!(ws.weights, p.weights());
        assert_eq!(ws.words, p.weight_words());
        assert_eq!(ws.wbits, p.wbits());
    }

    #[test]
    #[should_panic(expected = "two's complement")]
    fn rejects_weights_outside_the_declared_width() {
        // a 3 does not fit 2-bit two's complement [-2, 1]
        let _ = pack_layer(&layer_nbits(vec![3], 1, 1, 1, 2), 1);
    }

    #[test]
    fn select_bits_widths() {
        assert_eq!(select_bits(2), 1);
        assert_eq!(select_bits(16), 4);
        assert_eq!(select_bits(17), 5);
        assert_eq!(select_bits(640), 10);
    }

    #[test]
    fn crc32_matches_reference_vector() {
        // CRC-32/ISO-HDLC of bytes 01 02 03 04 05 06 07 08 (two LE
        // words) — cross-checked against python zlib.crc32
        let words = [0x0403_0201u32, 0x0807_0605];
        assert_eq!(crc32_words(&words), 0x3FCA_88C5);
        assert_eq!(crc32_words(&[]), 0);
    }

    #[test]
    fn flip_word_bit_changes_crc_and_repack_restores() {
        let mut p = pack_layer(&layer_nbits(vec![1, -7, 3], 3, 1, 1, 4), 1);
        let clean_words = p.weight_words().to_vec();
        let clean_crc = p.words_crc();
        assert!(p.flip_word_bit(0, 5));
        assert_ne!(p.words_crc(), clean_crc, "a flip must move the CRC");
        assert_ne!(p.weight_words(), clean_words.as_slice());
        // the mirror is untouched, so repacking restores byte-identity
        p.repack_from_mirror();
        assert_eq!(p.weight_words(), clean_words.as_slice());
        assert_eq!(p.words_crc(), clean_crc);
        // out-of-range sites are rejected without touching anything
        assert!(!p.flip_word_bit(p.word_count(), 0));
        assert!(!p.flip_word_bit(0, 32));
        assert_eq!(p.words_crc(), clean_crc);
    }

    #[test]
    fn repack_is_a_noop_on_a_clean_layer() {
        let w = vec![1, 0, -2, 3, 0, 4, -5, 0, 6, 7, 0, -7, 2, 0, 0];
        let mut p = pack_layer(&layer_nbits(w, 5, 1, 3, 4), 2);
        let words = p.weight_words().to_vec();
        p.repack_from_mirror();
        assert_eq!(p.weight_words(), words.as_slice());
    }
}
