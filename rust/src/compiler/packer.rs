//! Weight packing: dense `[K, Cin, Cout]` tensors → per-lane
//! compressed (select, weight) streams.
//!
//! The select signal is the index into the output position's
//! receptive-field window (`k * cin + ci`), exactly the MUX address of
//! Fig. 2; zero weights simply do not appear in the stream, which is
//! how the chip skips them "costing neither a cycle nor a multiplier
//! toggle".

use crate::arch::LaneWork;
use crate::nn::QLayer;

/// One layer's compressed streams, grouped into output-channel tiles
/// of `m` lanes (the M dimension of the array).
#[derive(Debug, Clone)]
pub struct PackedLayer {
    /// `[ch_tile][lane]` — lane streams; the last tile is padded with
    /// empty lanes when `cout % m != 0` ("redundant computing units
    /// will be padded by zero during inference").
    pub tiles: Vec<Vec<LaneWork>>,
    /// Bias per `[ch_tile][lane]` (0 on padding lanes).
    pub biases: Vec<Vec<i32>>,
    /// Bits of weight-buffer storage for weights + select signals.
    pub storage_bits: u64,
}

/// Select-signal width for a window of `window_len` entries.
fn select_bits(window_len: usize) -> u32 {
    (usize::BITS - (window_len.max(2) - 1).leading_zeros()).max(1)
}

/// Pack one quantized layer for an array with `m` lanes per SPE.
pub fn pack_layer(ly: &QLayer, m: usize) -> PackedLayer {
    let window_len = ly.k * ly.cin;
    let ch_tiles = ly.cout.div_ceil(m);
    let mut tiles = vec![vec![LaneWork::default(); m]; ch_tiles];
    let mut biases = vec![vec![0i32; m]; ch_tiles];
    let mut nnz_total = 0u64;
    for co in 0..ly.cout {
        let (t, lane) = (co / m, co % m);
        biases[t][lane] = ly.bias[co];
        let work = &mut tiles[t][lane];
        for k in 0..ly.k {
            for ci in 0..ly.cin {
                let w = ly.w[(k * ly.cin + ci) * ly.cout + co];
                if w != 0 {
                    work.selects.push((k * ly.cin + ci) as u32);
                    work.weights.push(w);
                    nnz_total += 1;
                }
            }
        }
    }
    let storage_bits =
        nnz_total * (ly.nbits as u64 + select_bits(window_len) as u64);
    PackedLayer { tiles, biases, storage_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::QLayer;

    fn layer(w: Vec<i32>, k: usize, cin: usize, cout: usize) -> QLayer {
        QLayer { k, stride: 1, cin, cout, relu: true, nbits: 8, shift: 24,
                 s_in: 1.0, s_out: 1.0, w,
                 bias: (0..cout as i32).collect(),
                 m0: vec![1 << 24; cout] }
    }

    #[test]
    fn strips_zeros_and_orders_by_window() {
        // k=2, cin=1, cout=1: weights [5, 0] -> one pair (select 0, 5)
        let p = pack_layer(&layer(vec![5, 0], 2, 1, 1), 4);
        assert_eq!(p.tiles.len(), 1);
        assert_eq!(p.tiles[0][0].selects, vec![0]);
        assert_eq!(p.tiles[0][0].weights, vec![5]);
        assert!(p.tiles[0][1].is_empty()); // padding lane
        assert_eq!(p.biases[0], vec![0, 0, 0, 0]);
    }

    #[test]
    fn channel_tiling_splits_cout() {
        // cout=5, m=4 -> 2 tiles, second has 1 live + 3 padding lanes
        let w = vec![1i32; 5]; // k=1, cin=1, cout=5
        let p = pack_layer(&layer(w, 1, 1, 5), 4);
        assert_eq!(p.tiles.len(), 2);
        assert_eq!(p.tiles[0].iter().filter(|l| !l.is_empty()).count(), 4);
        assert_eq!(p.tiles[1].iter().filter(|l| !l.is_empty()).count(), 1);
        assert_eq!(p.biases[1][0], 4);
    }

    #[test]
    fn select_indexes_reconstruct_conv() {
        // pack a random-ish small layer and check one position's dot
        // product against the golden conv
        let k = 3;
        let cin = 2;
        let cout = 2;
        let w = vec![1, 0, 0, -2, 3, 0, 0, 4, -5, 0, 0, 6];
        let ly = layer(w.clone(), k, cin, cout);
        let p = pack_layer(&ly, 2);
        let a = [7, -3, 2, 9, -1, 4]; // one window [k*cin]
        let golden = crate::nn::conv1d_int(&a, k, cin, &w, k, cout,
                                           &ly.bias, 1);
        for co in 0..cout {
            let lane = &p.tiles[0][co];
            let mut acc = ly.bias[co];
            for (&s, &wt) in lane.selects.iter().zip(&lane.weights) {
                acc += a[s as usize] * wt;
            }
            assert_eq!(acc, golden[co]);
        }
    }

    #[test]
    fn storage_accounting() {
        // window 4 -> 2 select bits; 3 nnz at 8-bit -> 3*(8+2)=30 bits
        let p = pack_layer(&layer(vec![1, 2, 0, 3], 4, 1, 1), 1);
        assert_eq!(p.storage_bits, 30);
    }

    #[test]
    fn select_bits_widths() {
        assert_eq!(select_bits(2), 1);
        assert_eq!(select_bits(16), 4);
        assert_eq!(select_bits(17), 5);
        assert_eq!(select_bits(640), 10);
    }
}
