//! The compiled program: packed streams + schedule + fit checks.

use anyhow::{ensure, Result};

use super::balance::BalanceReport;
use super::packer::{pack_layer, PackedStreams};
use super::schedule::Schedule;
use super::statics::{derive_static_cost, StaticCost};
use crate::arch::ChipConfig;
use crate::nn::QuantModel;

/// One layer ready for the array.
#[derive(Debug, Clone)]
pub struct CompiledLayer {
    /// The layer's flat weight-stream arena (selects + weights +
    /// range table) — what every engine streams.
    pub packed: PackedStreams,
    /// Requant parameters copied from the model (the PE drain path).
    pub m0: Vec<i32>,
    pub shift: u32,
    pub relu: bool,
    pub nbits: u32,
    pub stride: usize,
    pub k: usize,
    pub cin: usize,
    pub cout: usize,
    /// Is this the head layer (no requant, feeds global pooling)?
    pub is_head: bool,
}

/// A model compiled against a chip configuration.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub cfg: ChipConfig,
    pub layers: Vec<CompiledLayer>,
    pub schedule: Schedule,
    pub balance: BalanceReport,
    /// Total weight-buffer bits used (weights + select signals).
    pub weight_storage_bits: u64,
    /// Complete input-independent per-inference counters, derived once
    /// here and stamped onto every fast-path [`crate::sim::SimResult`].
    pub static_cost: StaticCost,
    /// Per-layer CRC32 integrity stamps over the physical packed
    /// weight words, recorded here at `compile()` — the reference the
    /// reliability scrub pass ([`crate::reliability::integrity`])
    /// checks against to detect weight-arena SEUs and the target
    /// [`PackedStreams::repack_from_mirror`] must re-converge to.
    pub weight_crcs: Vec<u32>,
}

/// Compile a quantized model for a chip configuration.
///
/// Errors if the compressed weights + selects exceed the on-chip
/// weight buffer or an SPE input tile exceeds the SPad.
pub fn compile(model: &QuantModel, cfg: &ChipConfig, l_in: usize)
               -> Result<CompiledModel> {
    cfg.validate()?;
    model.validate()?;
    let schedule = Schedule::of(&model.layers, cfg, l_in);
    let mut layers = Vec::with_capacity(model.layers.len());
    let mut storage = 0u64;
    let n = model.layers.len();
    for (i, ly) in model.layers.iter().enumerate() {
        let packed = pack_layer(ly, cfg.m);
        storage += packed.storage_bits;
        layers.push(CompiledLayer {
            packed,
            m0: ly.m0.clone(),
            shift: ly.shift,
            relu: ly.relu,
            nbits: ly.nbits,
            stride: ly.stride,
            k: ly.k,
            cin: ly.cin,
            cout: ly.cout,
            is_head: i == n - 1,
        });
    }
    ensure!(storage <= 8 * cfg.weight_buf_bytes as u64,
            "compressed model ({} bits) exceeds weight buffer ({} bits)",
            storage, 8 * cfg.weight_buf_bytes);
    for (i, s) in schedule.layers.iter().enumerate() {
        // the SPE stages one position window at a time
        ensure!(s.window_len * 4 <= cfg.spad_bytes,
                "layer {i} window ({} words) exceeds SPad", s.window_len);
    }
    let static_cost = derive_static_cost(cfg, &layers, &schedule);
    let weight_crcs = layers.iter().map(|ly| ly.packed.words_crc()).collect();
    Ok(CompiledModel {
        cfg: cfg.clone(),
        layers,
        schedule,
        balance: BalanceReport::of(model),
        weight_storage_bits: storage,
        static_cost,
        weight_crcs,
    })
}

impl CompiledModel {
    /// Compressed model size in bytes (what the chip stores): the
    /// *logical* bit count — every nonzero weight at its layer's
    /// `nbits` plus its select signal — rounded up to bytes. This is
    /// the paper's storage metric; see [`Self::weight_arena_bytes`]
    /// for what the host-side simulator arena physically holds.
    pub fn compressed_bytes(&self) -> u64 {
        self.weight_storage_bits.div_ceil(8)
    }

    /// Physical bytes of the packed host-side weight arenas summed
    /// over layers: sub-byte weight words (each weight at
    /// `nbits.max(2)` bits, `32 / wbits` per `u32` word) plus the
    /// `u32` select stream. Larger than [`Self::compressed_bytes`]
    /// because selects are stored as whole words and the last word of
    /// each layer's stream may be partially filled — but it shrinks
    /// with `nbits` exactly as the paper's mixed-bit-width scheme
    /// intends, unlike the old all-`i32` arena.
    pub fn weight_arena_bytes(&self) -> u64 {
        self.layers.iter().map(|ly| ly.packed.arena_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::QLayer;

    fn tiny_model() -> QuantModel {
        QuantModel { layers: vec![
            QLayer { k: 3, stride: 2, cin: 1, cout: 4, relu: true, nbits: 8,
                     shift: 24, s_in: 1.0, s_out: 1.0,
                     w: vec![1, 0, -2, 0, 3, 0, 0, -4, 5, 0, 0, 6],
                     bias: vec![1, 2, 3, 4], m0: vec![1 << 24; 4] },
            QLayer { k: 1, stride: 1, cin: 4, cout: 2, relu: false, nbits: 8,
                     shift: 0, s_in: 1.0, s_out: 1.0,
                     w: vec![1, 0, 0, 1, 1, 0, 0, 1],
                     bias: vec![0, 0], m0: vec![0, 0] },
        ]}
    }

    #[test]
    fn compiles_and_accounts_storage() {
        let cfg = ChipConfig::paper_1d();
        let cm = compile(&tiny_model(), &cfg, 16).unwrap();
        assert_eq!(cm.layers.len(), 2);
        assert!(cm.layers[1].is_head);
        // integrity stamps: one CRC per layer, matching the arena
        assert_eq!(cm.weight_crcs.len(), cm.layers.len());
        for (ly, &crc) in cm.layers.iter().zip(&cm.weight_crcs) {
            assert_eq!(ly.packed.words_crc(), crc);
        }
        assert!(cm.weight_storage_bits > 0);
        assert_eq!(cm.compressed_bytes(),
                   cm.weight_storage_bits.div_ceil(8));
        // physical packed arena: per-layer words, never smaller than
        // the logical (bit-granular) storage it realizes
        assert_eq!(cm.weight_arena_bytes(),
                   cm.layers.iter()
                       .map(|ly| ly.packed.arena_bytes())
                       .sum::<u64>());
        assert!(cm.weight_arena_bytes() >= cm.compressed_bytes());
    }

    #[test]
    fn rejects_oversized_model() {
        let mut cfg = ChipConfig::paper_1d();
        cfg.weight_buf_bytes = 1; // 8 bits
        assert!(compile(&tiny_model(), &cfg, 16).is_err());
    }

    #[test]
    fn rejects_oversized_window() {
        let mut cfg = ChipConfig::paper_1d();
        cfg.spad_bytes = 4; // one word
        assert!(compile(&tiny_model(), &cfg, 16).is_err());
    }

    #[test]
    fn artifact_model_fits_paper_chip() {
        let p = std::path::Path::new(crate::ARTIFACT_DIR).join("weights.bin");
        if let Ok(m) = QuantModel::load(&p) {
            let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
            // 50%-sparse ~102K-param model compresses well under 128 KB
            assert!(cm.compressed_bytes() < 128 * 1024);
            assert_eq!(cm.schedule.final_len(), 4);
        }
    }
}
