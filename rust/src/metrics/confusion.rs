//! Binary confusion matrix for the VA detection task.

/// Accumulating binary confusion matrix (positive class = VA).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, predicted_va: bool, truth_va: bool) {
        match (predicted_va, truth_va) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, false) => self.tn += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    pub fn merge(&mut self, other: &Confusion) {
        self.tp += other.tp;
        self.fp += other.fp;
        self.tn += other.tn;
        self.fn_ += other.fn_;
    }

    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    /// Positive predictive value. 0 when no positives were predicted.
    pub fn precision(&self) -> f64 {
        let d = self.tp + self.fp;
        if d == 0 { 0.0 } else { self.tp as f64 / d as f64 }
    }

    /// Sensitivity — the metric an ICD lives or dies by.
    pub fn recall(&self) -> f64 {
        let d = self.tp + self.fn_;
        if d == 0 { 0.0 } else { self.tp as f64 / d as f64 }
    }

    pub fn specificity(&self) -> f64 {
        let d = self.tn + self.fp;
        if d == 0 { 0.0 } else { self.tn as f64 / d as f64 }
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) }
    }
}

impl std::fmt::Display for Confusion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f,
               "acc {:.4} prec {:.4} rec {:.4} spec {:.4} (tp {} fp {} tn {} fn {})",
               self.accuracy(), self.precision(), self.recall(),
               self.specificity(), self.tp, self.fp, self.tn, self.fn_)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_classifier() {
        let mut c = Confusion::new();
        c.push(true, true);
        c.push(false, false);
        assert_eq!(c.accuracy(), 1.0);
        assert_eq!(c.precision(), 1.0);
        assert_eq!(c.recall(), 1.0);
        assert_eq!(c.f1(), 1.0);
    }

    #[test]
    fn known_matrix() {
        let c = Confusion { tp: 8, fp: 2, tn: 6, fn_: 4 };
        assert!((c.accuracy() - 14.0 / 20.0).abs() < 1e-12);
        assert!((c.precision() - 0.8).abs() < 1e-12);
        assert!((c.recall() - 8.0 / 12.0).abs() < 1e-12);
        assert!((c.specificity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero_not_nan() {
        let c = Confusion::new();
        assert_eq!(c.accuracy(), 0.0);
        assert_eq!(c.precision(), 0.0);
        assert_eq!(c.recall(), 0.0);
        assert_eq!(c.f1(), 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = Confusion { tp: 1, fp: 2, tn: 3, fn_: 4 };
        a.merge(&Confusion { tp: 10, fp: 20, tn: 30, fn_: 40 });
        assert_eq!(a, Confusion { tp: 11, fp: 22, tn: 33, fn_: 44 });
    }
}
