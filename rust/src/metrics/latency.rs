//! Latency recording with exact percentiles (sorted sample store —
//! fine at this scale; the serving path produces thousands, not
//! billions, of samples per run).

use std::time::Duration;

/// Collects latency samples and reports percentiles/throughput.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
    sorted: bool,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
        self.sorted = false;
    }

    /// Record a raw microsecond sample. Non-finite values (NaN/±inf —
    /// e.g. a garbage upstream timestamp delta) are dropped: one bad
    /// sample must not poison the whole fleet report's percentiles.
    pub fn push_us(&mut self, us: f64) {
        if !us.is_finite() {
            return;
        }
        self.samples_us.push(us);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Fold another recorder's samples in (fleet aggregation: shard
    /// recorders merge into one fleet-level percentile view).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.sorted = false;
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp, not partial_cmp().unwrap(): sorting must never
            // panic even if a non-finite sample slips in through an
            // older serialized recorder
            self.samples_us.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank). `p` in [0, 100].
    pub fn percentile_us(&mut self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.samples_us.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples_us[rank.min(n) - 1]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn max_us(&mut self) -> f64 {
        self.percentile_us(100.0)
    }

    pub fn summary(&mut self) -> String {
        format!("n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs max={:.1}µs",
                self.count(), self.mean_us(), self.percentile_us(50.0),
                self.percentile_us(95.0), self.percentile_us(99.0),
                self.max_us())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut r = LatencyRecorder::new();
        for v in [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0] {
            r.push_us(v);
        }
        assert_eq!(r.percentile_us(50.0), 50.0);
        assert_eq!(r.percentile_us(95.0), 100.0);
        assert_eq!(r.percentile_us(10.0), 10.0);
        assert_eq!(r.max_us(), 100.0);
        assert!((r.mean_us() - 55.0).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        let mut r = LatencyRecorder::new();
        assert_eq!(r.percentile_us(99.0), 0.0);
        assert_eq!(r.mean_us(), 0.0);
    }

    #[test]
    fn unsorted_pushes_resort() {
        let mut r = LatencyRecorder::new();
        r.push_us(30.0);
        r.push_us(10.0);
        assert_eq!(r.percentile_us(50.0), 10.0);
        r.push_us(5.0);
        assert_eq!(r.percentile_us(50.0), 10.0);
        assert_eq!(r.percentile_us(100.0), 30.0);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = LatencyRecorder::new();
        a.push_us(10.0);
        a.push_us(30.0);
        let mut b = LatencyRecorder::new();
        b.push_us(20.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.percentile_us(50.0), 20.0);
        assert_eq!(a.max_us(), 30.0);
    }

    #[test]
    fn non_finite_samples_rejected_and_never_panic() {
        let mut r = LatencyRecorder::new();
        r.push_us(f64::NAN);
        r.push_us(f64::INFINITY);
        r.push_us(f64::NEG_INFINITY);
        assert_eq!(r.count(), 0);
        r.push_us(20.0);
        r.push_us(10.0);
        // regression: a NaN in the store used to panic ensure_sorted
        // via partial_cmp().unwrap(); percentiles must stay usable
        r.push_us(f64::NAN);
        assert_eq!(r.count(), 2);
        assert_eq!(r.percentile_us(50.0), 10.0);
        assert_eq!(r.max_us(), 20.0);
        let _ = r.summary();
    }

    #[test]
    fn duration_conversion() {
        let mut r = LatencyRecorder::new();
        r.push(Duration::from_micros(1500));
        assert!((r.mean_us() - 1500.0).abs() < 1e-9);
    }
}
