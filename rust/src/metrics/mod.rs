//! Evaluation metrics: detection quality (confusion matrices) and
//! serving quality (latency percentiles, throughput).

mod confusion;
mod latency;

pub use confusion::Confusion;
pub use latency::LatencyRecorder;

/// GOPS accounting: the chip community counts 1 MAC = 2 OPs, and the
/// paper reports *effective* GOPS (dense-equivalent work divided by
/// wall time, so sparsity raises the number).
pub fn effective_gops(dense_macs: u64, seconds: f64) -> f64 {
    (2.0 * dense_macs as f64) / seconds / 1e9
}

#[cfg(test)]
mod tests {
    #[test]
    fn gops_accounting() {
        // 1 M MACs in 1 ms = 2 GOPS
        assert!((super::effective_gops(1_000_000, 1e-3) - 2.0).abs() < 1e-12);
    }
}
