//! # va-accel
//!
//! Full-stack reproduction of *"A 10.60 µW 150 GOPS Mixed-Bit-Width
//! Sparse CNN Accelerator for Life-Threatening Ventricular Arrhythmia
//! Detection"* (Qin et al., ASP-DAC '25).
//!
//! The crate is the **Layer-3 runtime** of a three-layer Rust + JAX +
//! Pallas stack (see `DESIGN.md`): python authors and AOT-compiles the
//! quantized 8-layer 1-D CNN once (`make artifacts`); this crate owns
//! everything that runs afterwards — streaming IEGM ingestion, the
//! detection pipeline, the cycle-accurate chip simulator with its
//! 40 nm power/area model, the model compiler (weight packing +
//! co-design workload balancing), the Table-1 baselines, and the PJRT
//! runtime that executes the AOT artifacts. Python is never on the
//! request path.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`signal`] | DSP substrate: biquad band-pass front end, framing |
//! | [`data`] | synthetic IEGM generator + dataset/artifact I/O |
//! | [`nn`] | integer golden model (bit-exact vs chip sim & PJRT) |
//! | [`arch`] | microarchitecture description: CMUL, PE, SPE, SPad |
//! | [`compiler`] | model loading, select-signal packing, balancing |
//! | [`sim`] | cycle-accurate SPE-array simulator |
//! | [`power`] | 40 nm LP energy/area model → µW, GOPS, µW/mm² |
//! | [`runtime`] | PJRT client: load + execute `artifacts/*.hlo.txt` |
//! | [`coordinator`] | detection pipeline + voting + sharded [`coordinator::Fleet`] |
//! | [`reliability`] | fault injection, integrity scrubbing, supervision |
//! | [`baselines`] | Table-1 comparators: ANN, KS-test, DWT+SVM, SNN |
//! | [`metrics`] | confusion matrices, latency percentiles |
//!
//! The crate is hermetic by default: when the AOT artifacts are absent,
//! [`data::fixtures`] provides a deterministic paper-shaped model and
//! synthetic corpus so every test and bench runs from a fresh checkout
//! (the PJRT paths additionally need the `pjrt` cargo feature).

pub mod arch;
pub mod baselines;
pub mod compiler;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod nn;
pub mod power;
pub mod reliability;
pub mod runtime;
pub mod signal;
pub mod sim;

/// Samples per recording (paper: "each recording samples 512 points").
pub const REC_LEN: usize = 512;
/// Sampling rate (paper: 250 Hz).
pub const FS_HZ: f64 = 250.0;
/// Recordings aggregated per diagnosis vote (paper: 6).
pub const VOTE_GROUP: usize = 6;
/// Default artifact directory produced by `make artifacts`.
pub const ARTIFACT_DIR: &str = "artifacts";
