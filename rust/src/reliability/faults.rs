//! Deterministic fault injection: seed-driven plans naming exact fault
//! sites, plus the frame-level wire perturbation adapter.
//!
//! Everything here is reproducible by construction: a [`FaultPlan`] is
//! a pure function of `(seed, geometry)` via [`crate::data::SplitMix64`],
//! so a campaign's fault sites — which layer, which word, which bit,
//! which window — are bit-identical across runs and hosts. That is
//! what turns "we survived some faults" into a gateable number
//! (`tests/faults.rs` pins the determinism; `benches/faults.rs` gates
//! `undetected_corruptions == 0`).
//!
//! Injection is pull-based: the plan is data, and each subsystem asks
//! for the faults due at its own trigger points
//! ([`FaultPlan::due_at`]). Production paths carry no plan at all —
//! the hooks they check ([`crate::sim::StreamingEngine::corrupt_carry`],
//! `FleetConfig::fault_panic`, `ServeConfig::fault_panic`) default to
//! no-ops.

use std::io::{self, Read, Write};

use crate::compiler::CompiledModel;
use crate::data::SplitMix64;

/// One category of injectable fault. The taxonomy mirrors DESIGN.md
/// §8: storage (SEU bit flips), state (carry-slab words), datapath
/// (stuck-at lanes), control (worker panics), and transport (wire
/// perturbation, modeled separately by [`FaultyStream`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip one bit of one packed `weight_words` word of one layer
    /// (single-event upset in the weight SRAM).
    WeightBit { layer: usize, word: usize, bit: u32 },
    /// XOR one word of the streaming carry slab (SEU in the activation
    /// buffer holding carried stripe columns).
    CarryWord { index: usize, xor: i32 },
    /// Force one SPE lane's accumulator to a constant (stuck-at
    /// datapath defect; observable on the counted reference path).
    StuckLane { lane: usize, value: i32 },
    /// Panic the given worker shard after it has processed the given
    /// number of jobs/windows (control-plane death).
    WorkerPanic { shard: usize, after: u64 },
}

/// A fault plus the window index it fires at (streaming faults) or 0
/// for faults injected before traffic starts (weight SEUs, stuck
/// lanes, panic arming).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedFault {
    pub at_window: u64,
    pub kind: FaultKind,
}

/// A deterministic, seed-addressed fault campaign.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    pub faults: Vec<PlannedFault>,
}

impl FaultPlan {
    /// The empty plan (the production default: injects nothing).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// `flips` single-bit weight-arena upsets, sites drawn uniformly
    /// over every packed word of every layer (weighted by word count,
    /// so big layers absorb proportionally more hits), each scheduled
    /// uniformly in `[0, windows)`.
    pub fn weight_seu(seed: u64, cm: &CompiledModel, flips: usize,
                      windows: u64) -> Self {
        let mut rng = SplitMix64::new(seed ^ 0x5E0_F11B);
        let counts: Vec<usize> =
            cm.layers.iter().map(|ly| ly.packed.word_count()).collect();
        let total: usize = counts.iter().sum();
        let mut faults = Vec::with_capacity(flips);
        if total == 0 {
            return Self { seed, faults };
        }
        for _ in 0..flips {
            let mut w = (rng.next_u64() % total as u64) as usize;
            let mut layer = 0;
            while w >= counts[layer] {
                w -= counts[layer];
                layer += 1;
            }
            let bit = (rng.next_u64() % 32) as u32;
            let at_window = if windows > 0 { rng.next_u64() % windows } else { 0 };
            faults.push(PlannedFault {
                at_window,
                kind: FaultKind::WeightBit { layer, word: w, bit },
            });
        }
        faults.sort_by_key(|f| f.at_window);
        Self { seed, faults }
    }

    /// `flips` carry-slab word corruptions over a slab of
    /// `carry_words` words, each an XOR with a random nonzero mask,
    /// scheduled uniformly in `[1, windows)` (window 0 is the priming
    /// pass — the slab is rewritten wholesale there, so a flip before
    /// it cannot survive to be detected).
    pub fn carry_seu(seed: u64, carry_words: usize, flips: usize,
                     windows: u64) -> Self {
        let mut rng = SplitMix64::new(seed ^ 0xCA22_51AB);
        let mut faults = Vec::with_capacity(flips);
        if carry_words == 0 {
            return Self { seed, faults };
        }
        for _ in 0..flips {
            let index = (rng.next_u64() % carry_words as u64) as usize;
            let mut xor = 0i32;
            while xor == 0 {
                xor = rng.next_u64() as i32;
            }
            let at_window =
                if windows > 1 { 1 + rng.next_u64() % (windows - 1) } else { 1 };
            faults.push(PlannedFault {
                at_window,
                kind: FaultKind::CarryWord { index, xor },
            });
        }
        faults.sort_by_key(|f| f.at_window);
        Self { seed, faults }
    }

    /// Faults scheduled for exactly window `w`, in plan order.
    pub fn due_at(&self, w: u64) -> impl Iterator<Item = &PlannedFault> {
        self.faults.iter().filter(move |f| f.at_window == w)
    }
}

// ---------------------------------------------------------------------
// Wire perturbation
// ---------------------------------------------------------------------

/// A transport-level fault applied to one complete outbound frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFault {
    /// The frame never reaches the peer (packet loss past the TCP
    /// layer — models a dying link the client must detect by timeout).
    Drop,
    /// The frame is sent twice back-to-back (retransmit storm; the
    /// receiver must dedupe by window index).
    Duplicate,
    /// Only the first `keep` bytes are sent, then the stream is
    /// poisoned: every later write fails. A truncated frame is
    /// indistinguishable from a mid-frame connection cut, so the only
    /// honest continuation is a broken pipe — the client reconnects.
    Truncate { keep: usize },
}

/// Frame-aware faulty transport: wraps any `Read + Write` byte stream
/// and perturbs *complete outbound frames* according to a seeded
/// schedule, independent of the caller's write granularity (bytes are
/// buffered until a whole `[len][tag][payload]` frame is present, so
/// a fault never splits or spans frames by accident — only
/// [`WireFault::Truncate`] does, deliberately).
///
/// Reads pass through untouched: the adapter models a lossy device
/// uplink, and the server's inbound leg is exercised by what arrives
/// (or doesn't). Determinism: one `next_u64` per completed frame.
pub struct FaultyStream<S> {
    inner: S,
    rng: SplitMix64,
    /// Probability in [0,1] that a given outbound frame is perturbed.
    rate: f64,
    buf: Vec<u8>,
    poisoned: bool,
    /// Outbound frames perturbed, by kind.
    pub dropped: u64,
    pub duplicated: u64,
    pub truncated: u64,
}

impl<S> FaultyStream<S> {
    pub fn new(inner: S, seed: u64, rate: f64) -> Self {
        Self { inner, rng: SplitMix64::new(seed ^ 0x31BE_FA), rate,
               buf: Vec::new(), poisoned: false,
               dropped: 0, duplicated: 0, truncated: 0 }
    }

    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    /// Draw the fault (if any) for the next completed frame.
    fn draw(&mut self) -> Option<WireFault> {
        if self.rng.uniform() >= self.rate {
            return None;
        }
        Some(match self.rng.next_u64() % 3 {
            0 => WireFault::Drop,
            1 => WireFault::Duplicate,
            _ => WireFault::Truncate {
                keep: 2 + (self.rng.next_u64() % 3) as usize,
            },
        })
    }
}

impl<S: Write> FaultyStream<S> {
    /// Forward every complete frame at the head of the buffer, with
    /// its drawn fault applied.
    fn pump(&mut self) -> io::Result<()> {
        loop {
            if self.buf.len() < 4 {
                return Ok(());
            }
            let len = u32::from_le_bytes([self.buf[0], self.buf[1],
                                          self.buf[2], self.buf[3]]) as usize;
            let total = 4 + len;
            if self.buf.len() < total {
                return Ok(());
            }
            let frame: Vec<u8> = self.buf.drain(..total).collect();
            match self.draw() {
                None => self.inner.write_all(&frame)?,
                Some(WireFault::Drop) => self.dropped += 1,
                Some(WireFault::Duplicate) => {
                    self.duplicated += 1;
                    self.inner.write_all(&frame)?;
                    self.inner.write_all(&frame)?;
                }
                Some(WireFault::Truncate { keep }) => {
                    self.truncated += 1;
                    let keep = keep.min(frame.len().saturating_sub(1));
                    self.inner.write_all(&frame[..keep])?;
                    self.inner.flush()?;
                    self.poisoned = true;
                    return Err(io::Error::new(
                        io::ErrorKind::BrokenPipe,
                        "injected wire truncation"));
                }
            }
        }
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, b: &[u8]) -> io::Result<usize> {
        if self.poisoned {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe,
                                      "stream poisoned by injected fault"));
        }
        self.buf.extend_from_slice(b);
        self.pump()?;
        Ok(b.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

impl<S: Read> Read for FaultyStream<S> {
    fn read(&mut self, b: &mut [u8]) -> io::Result<usize> {
        self.inner.read(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::coordinator::wire;
    use crate::REC_LEN;

    fn cm() -> CompiledModel {
        let m = crate::data::fixtures::quant_model(0xFA01);
        compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap()
    }

    #[test]
    fn plans_are_seed_deterministic_and_seed_sensitive() {
        let cm = cm();
        let a = FaultPlan::weight_seu(9, &cm, 32, 64);
        let b = FaultPlan::weight_seu(9, &cm, 32, 64);
        let c = FaultPlan::weight_seu(10, &cm, 32, 64);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.faults.len(), 32);
        let d = FaultPlan::carry_seu(9, 4096, 16, 64);
        assert_eq!(d, FaultPlan::carry_seu(9, 4096, 16, 64));
        assert_eq!(d.faults.len(), 16);
    }

    #[test]
    fn weight_sites_are_in_range() {
        let cm = cm();
        let p = FaultPlan::weight_seu(123, &cm, 200, 32);
        for f in &p.faults {
            match f.kind {
                FaultKind::WeightBit { layer, word, bit } => {
                    assert!(layer < cm.layers.len());
                    assert!(word < cm.layers[layer].packed.word_count(),
                            "layer {layer} word {word}");
                    assert!(bit < 32);
                    assert!(f.at_window < 32);
                }
                other => panic!("unexpected fault {other:?}"),
            }
        }
    }

    #[test]
    fn carry_faults_never_fire_during_priming() {
        let p = FaultPlan::carry_seu(7, 1024, 64, 16);
        for f in &p.faults {
            assert!(f.at_window >= 1, "{f:?}");
            match f.kind {
                FaultKind::CarryWord { index, xor } => {
                    assert!(index < 1024);
                    assert_ne!(xor, 0);
                }
                other => panic!("unexpected fault {other:?}"),
            }
        }
    }

    #[test]
    fn due_at_filters_by_window() {
        let cm = cm();
        let p = FaultPlan::weight_seu(5, &cm, 64, 8);
        let total: usize = (0..8).map(|w| p.due_at(w).count()).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn faulty_stream_is_transparent_at_rate_zero() {
        let mut fs = FaultyStream::new(Vec::new(), 1, 0.0);
        let f = wire::Frame::Goodbye;
        wire::write_frame(&mut fs, &f).unwrap();
        wire::write_frame(&mut fs, &wire::Frame::Busy { dropped: 3 }).unwrap();
        let mut expect = wire::encode(&f);
        expect.extend(wire::encode(&wire::Frame::Busy { dropped: 3 }));
        assert_eq!(fs.get_ref(), &expect);
        assert_eq!(fs.dropped + fs.duplicated + fs.truncated, 0);
    }

    #[test]
    fn faulty_stream_reassembles_split_writes() {
        // byte-at-a-time writes must still fault whole frames
        let bytes = wire::encode(&wire::Frame::Welcome {
            session: 7, hop: 128, frame_len: 512 });
        let mut fs = FaultyStream::new(Vec::new(), 2, 0.0);
        for b in &bytes {
            fs.write_all(std::slice::from_ref(b)).unwrap();
        }
        assert_eq!(fs.get_ref(), &bytes);
    }

    #[test]
    fn faulty_stream_rate_one_perturbs_every_frame() {
        let mut fs = FaultyStream::new(Vec::new(), 3, 1.0);
        for i in 0..64 {
            if wire::write_frame(&mut fs,
                                 &wire::Frame::Busy { dropped: i }).is_err() {
                break; // injected truncation poisons the pipe
            }
        }
        let perturbed = fs.dropped + fs.duplicated + fs.truncated;
        assert!(perturbed > 0);
        // determinism: an identically-seeded twin perturbs identically
        let mut twin = FaultyStream::new(Vec::new(), 3, 1.0);
        for i in 0..64 {
            if wire::write_frame(&mut twin,
                                 &wire::Frame::Busy { dropped: i }).is_err() {
                break;
            }
        }
        assert_eq!((fs.dropped, fs.duplicated, fs.truncated),
                   (twin.dropped, twin.duplicated, twin.truncated));
    }
}
