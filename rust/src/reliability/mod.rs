//! Fault injection, integrity self-checking, and supervised recovery.
//!
//! The paper's device class (implantable/wearable VA detectors) cannot
//! tolerate a *silent* fault: a flipped bit in the packed weight arena
//! corrupts every subsequent diagnosis, and a dead serving shard takes
//! its devices offline until someone notices. This module makes faults
//! first-class citizens of the stack — injectable, detectable, and
//! recoverable — in three coupled pieces:
//!
//! 1. **Deterministic injection** ([`faults`]): a seed-driven
//!    [`FaultPlan`] names exact fault sites (weight-arena bit flips,
//!    carry-slab word corruption, stuck-at SPE lanes, worker-thread
//!    panics, wire perturbation via [`FaultyStream`]) and the windows
//!    they fire at. Same seed ⇒ bit-identical campaign, so detection
//!    latencies are reproducible numbers, not anecdotes. Every hook in
//!    the production structs defaults to a no-op (`Option::None` /
//!    cadence 0) so the clean hot path is untouched.
//! 2. **Integrity + self-check** ([`integrity`], plus the scrub pass
//!    on [`crate::compiler::CompiledModel`] and the streaming canary
//!    on [`crate::sim::StreamingEngine`]): per-layer CRC32 stamped
//!    over the packed weight words at `compile()`, a scrub pass that
//!    detects flips and restores the words from the decoded `i32`
//!    mirror, a cadence canary that cross-checks the incremental
//!    carry-slab result against a full [`crate::sim::run_scratch`]
//!    recompute, and a golden self-test vector ([`GoldenVector`])
//!    pinned at compile time and runnable at session start.
//! 3. **Supervision** ([`supervisor`]): the exponential
//!    jittered-backoff policy ([`Backoff`]) and panic-catch helper
//!    ([`run_caught`]) that `coordinator::Fleet` and
//!    `coordinator::serve_net` workers respawn through, so one
//!    panicking shard degrades to a detection-latency blip instead of
//!    a permanently dark partition.
//!
//! Division of labour between the checks (DESIGN.md §8): the CRC scrub
//! owns *weight* corruption (the canary cannot see it — both the
//! incremental and the recompute path read the same corrupted arena);
//! the canary owns *carry-slab* corruption (the CRC cannot see it —
//! activations are never checksummed); the golden vector owns
//! everything frozen at compile time (schedule, requant constants,
//! kernel dispatch). `benches/faults.rs` sweeps seeded campaigns over
//! all three and gates `undetected_corruptions == 0`.

pub mod faults;
pub mod integrity;
pub mod supervisor;

pub use faults::{FaultKind, FaultPlan, FaultyStream, PlannedFault, WireFault};
pub use integrity::{crc32_words, GoldenVector, ScrubReport};
pub use supervisor::{run_caught, Backoff};
