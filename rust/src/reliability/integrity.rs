//! Integrity self-checks: CRC scrub over the packed weight arenas and
//! the compile-time golden self-test vector.
//!
//! The scrub pass is the weight half of the detection contract
//! (module docs on [`crate::reliability`]): `compile()` stamps a
//! CRC32 per layer over the physical `weight_words`
//! ([`crate::compiler::CompiledModel::weight_crcs`]); [`verify`]
//! recomputes and reports mismatching layers; [`scrub`] additionally
//! restores the words from the decoded `i32` mirror
//! ([`crate::compiler::PackedStreams::repack_from_mirror`]) and
//! re-verifies. Restoration is possible precisely because the mirror
//! and the packed words are redundant encodings of the same stream —
//! an upset in one cannot also be in the other.

pub use crate::compiler::crc32_words;

use crate::compiler::CompiledModel;
use crate::data::SplitMix64;
use crate::sim::{run_scratch, ScratchArena};

/// Outcome of one [`scrub`] pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScrubReport {
    /// Layers checked (all of them, every pass).
    pub layers: usize,
    /// Layers whose recomputed CRC mismatched the compile-time stamp.
    pub corrupted: Vec<usize>,
    /// Every corrupted layer re-verified clean after restoration from
    /// the mirror. `true` when nothing was corrupted.
    pub restored: bool,
}

impl ScrubReport {
    pub fn clean(&self) -> bool {
        self.corrupted.is_empty()
    }
}

/// Recompute every layer's weight-arena CRC and return the indices
/// that mismatch their compile-time stamps (empty ⇒ arena intact).
pub fn verify(cm: &CompiledModel) -> Vec<usize> {
    cm.layers.iter().zip(&cm.weight_crcs).enumerate()
        .filter(|(_, (ly, &crc))| ly.packed.words_crc() != crc)
        .map(|(i, _)| i)
        .collect()
}

/// One scrub pass: detect corrupted layers ([`verify`]), restore each
/// from its decoded mirror, and re-verify the restoration.
pub fn scrub(cm: &mut CompiledModel) -> ScrubReport {
    let corrupted = verify(cm);
    let mut restored = true;
    for &i in &corrupted {
        cm.layers[i].packed.repack_from_mirror();
        restored &= cm.layers[i].packed.words_crc() == cm.weight_crcs[i];
    }
    ScrubReport { layers: cm.layers.len(), corrupted, restored }
}

/// A golden self-test vector: one deterministic input with its logits
/// pinned at stamp time. [`GoldenVector::check`] re-runs the full
/// fast path and compares — a cheap whole-stack smoke (weights,
/// schedule, requant constants, kernel dispatch) for session start
/// and post-recovery re-admission.
///
/// Stamp immediately after `compile()`: a vector stamped from an
/// already-corrupted model would pin the corruption as truth.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GoldenVector {
    pub input: Vec<i8>,
    pub logits: Vec<i32>,
    pub predicted: usize,
}

impl GoldenVector {
    /// The deterministic self-test input for a given length (fixed
    /// internal seed: the vector is part of the integrity contract,
    /// not a sampling knob).
    pub fn input_for(len: usize) -> Vec<i8> {
        let mut rng = SplitMix64::new(0x601D_E57);
        (0..len).map(|_| rng.range(-127.0, 128.0) as i8).collect()
    }

    /// Run the deterministic input through the fast path and pin its
    /// logits.
    pub fn stamp(cm: &CompiledModel) -> Self {
        let input = Self::input_for(cm.static_cost.input_len);
        let r = run_scratch(cm, &input, &mut ScratchArena::for_model(cm));
        Self { input, logits: r.logits, predicted: r.predicted }
    }

    /// Re-run the vector; `true` iff the logits are bit-identical to
    /// the stamp.
    pub fn check(&self, cm: &CompiledModel) -> bool {
        let r = run_scratch(cm, &self.input,
                            &mut ScratchArena::for_model(cm));
        r.logits == self.logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::compile;
    use crate::REC_LEN;

    fn cm() -> CompiledModel {
        let m = crate::data::fixtures::quant_model(0x1277);
        compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap()
    }

    #[test]
    fn clean_model_verifies_and_scrubs_clean() {
        let mut cm = cm();
        assert!(verify(&cm).is_empty());
        let rep = scrub(&mut cm);
        assert!(rep.clean() && rep.restored);
        assert_eq!(rep.layers, cm.layers.len());
    }

    #[test]
    fn scrub_detects_and_restores_injected_flips() {
        let mut cm = cm();
        let before: Vec<Vec<u32>> = cm.layers.iter()
            .map(|ly| ly.packed.weight_words().to_vec()).collect();
        // flip one bit in two different layers
        assert!(cm.layers[0].packed.flip_word_bit(0, 7));
        let last = cm.layers.len() - 1;
        assert!(cm.layers[last].packed.flip_word_bit(0, 30));
        assert_eq!(verify(&cm), vec![0, last]);
        let rep = scrub(&mut cm);
        assert_eq!(rep.corrupted, vec![0, last]);
        assert!(rep.restored, "mirror restoration must re-verify");
        assert!(verify(&cm).is_empty());
        // byte-identical restoration, not merely CRC-identical
        for (ly, orig) in cm.layers.iter().zip(&before) {
            assert_eq!(ly.packed.weight_words(), orig.as_slice());
        }
    }

    #[test]
    fn golden_vector_is_deterministic_and_passes_on_a_clean_model() {
        let cm = cm();
        let gv = GoldenVector::stamp(&cm);
        assert_eq!(gv.input.len(), REC_LEN);
        assert_eq!(gv.logits.len(), 2);
        assert!(gv.check(&cm));
        assert_eq!(gv, GoldenVector::stamp(&cm), "stamp is deterministic");
        // a vector stamped from a different model must not validate
        // this one (the fixtures differ in weights, hence in logits)
        let other = compile(&crate::data::fixtures::quant_model(0x1278),
                            &ChipConfig::paper_1d(), REC_LEN).unwrap();
        assert!(!GoldenVector::stamp(&other).check(&cm));
    }
}
