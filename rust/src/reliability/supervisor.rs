//! Supervision primitives: panic capture and jittered exponential
//! backoff, shared by the `Fleet` and `serve_net` worker supervisors.
//!
//! The policy is deliberately tiny — the interesting logic (what state
//! to rebuild, which sessions to evict) lives with the owner of that
//! state in `coordinator`. What belongs here is the part that must be
//! identical everywhere so recovery behaviour is predictable and
//! testable: how long to wait before attempt N ([`Backoff`], capped
//! exponential with deterministic seed-driven jitter so respawn storms
//! decorrelate without sacrificing reproducibility), and how to turn a
//! worker panic into a value instead of a dead thread ([`run_caught`]).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use crate::data::SplitMix64;

/// Capped exponential backoff with deterministic ±25 % jitter.
///
/// Delay for attempt `n` (0-based) is `base · 2ⁿ`, capped at `cap`,
/// scaled by a jitter factor in `[0.75, 1.25)` drawn from a seeded
/// [`SplitMix64`] — same seed ⇒ same delay sequence (fault campaigns
/// measure recovery time; nondeterministic sleeps would smear the
/// numbers), different seeds (one per shard) ⇒ decorrelated respawns.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: SplitMix64,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Self {
        Self { base, cap, attempt: 0, rng: SplitMix64::new(seed ^ 0xBAC0FF) }
    }

    /// The serving default: 10 ms base, 2 s cap.
    pub fn serving(seed: u64) -> Self {
        Self::new(Duration::from_millis(10), Duration::from_secs(2), seed)
    }

    /// Consecutive failures so far (resets on [`Backoff::reset`]).
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Delay before the next retry; advances the attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.base.saturating_mul(1u32 << self.attempt.min(16));
        let capped = exp.min(self.cap);
        self.attempt = self.attempt.saturating_add(1);
        let jitter = 0.75 + 0.5 * self.rng.uniform();
        capped.mul_f64(jitter)
    }

    /// Call after a sustained healthy period so the next failure
    /// starts from the base delay again.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// Run `f`, converting a panic into `Err(message)` instead of
/// unwinding through the supervisor. The `AssertUnwindSafe` is sound
/// for our callers by construction: a supervised worker's partial
/// state is dropped and rebuilt from scratch on the respawn path,
/// never observed again.
pub fn run_caught<R>(f: impl FnOnce() -> R) -> Result<R, String> {
    catch_unwind(AssertUnwindSafe(f)).map_err(|e| {
        if let Some(s) = e.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = e.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_caps_and_jitters_within_bounds() {
        let mut b = Backoff::new(Duration::from_millis(10),
                                 Duration::from_millis(500), 42);
        let delays: Vec<Duration> = (0..10).map(|_| b.next_delay()).collect();
        for (i, d) in delays.iter().enumerate() {
            let nominal = Duration::from_millis(10)
                .saturating_mul(1 << (i as u32).min(16))
                .min(Duration::from_millis(500));
            assert!(*d >= nominal.mul_f64(0.75), "attempt {i}: {d:?}");
            assert!(*d < nominal.mul_f64(1.25), "attempt {i}: {d:?}");
        }
        // capped: late attempts never exceed cap · 1.25
        assert!(delays[9] < Duration::from_millis(625));
        assert_eq!(b.attempts(), 10);
        b.reset();
        assert_eq!(b.attempts(), 0);
        assert!(b.next_delay() < Duration::from_millis(13));
    }

    #[test]
    fn backoff_is_seed_deterministic() {
        let mut a = Backoff::serving(7);
        let mut b = Backoff::serving(7);
        let mut c = Backoff::serving(8);
        let da: Vec<_> = (0..6).map(|_| a.next_delay()).collect();
        let db: Vec<_> = (0..6).map(|_| b.next_delay()).collect();
        let dc: Vec<_> = (0..6).map(|_| c.next_delay()).collect();
        assert_eq!(da, db);
        assert_ne!(da, dc);
    }

    #[test]
    fn run_caught_returns_values_and_captures_panics() {
        assert_eq!(run_caught(|| 41 + 1), Ok(42));
        let err = run_caught(|| -> i32 { panic!("shard died: {}", 3) });
        assert_eq!(err, Err("shard died: 3".to_string()));
        let err = run_caught(|| -> i32 { panic!("literal") });
        assert_eq!(err, Err("literal".to_string()));
    }
}
