//! Microarchitecture model of the paper's accelerator (Figs. 1–3).
//!
//! The fabricated chip is a four-dimensional PE array N×W×H×M =
//! 2×4×4×16 (512 PEs): N core elements tile input channels, W computing
//! cores tile output-feature-map width, H SPEs tile height, and M PEs
//! tile output channels. Each SPE holds 12 PEs + 4 MPEs (the MPEs add
//! max/avg pooling) fed from **one shared scratchpad** (vs per-PE SPads
//! in Eyeriss v2) with weights + select signals streamed straight from
//! the on-chip buffers — no FIFOs, fully synchronous control.
//!
//! This module provides the structural/functional/timing primitives;
//! [`crate::sim`] walks a compiled model over them and
//! [`crate::power`] converts the resulting event counts into energy.
//! Looking for an execution entry point rather than the hardware
//! model? Start at [`crate::sim`] (fast vs counted routing) or
//! [`crate::nn::QuantModel`] (golden reference). The one timing
//! formula every engine shares is [`tile_cycles`]; the drain/readout
//! event contract is documented on [`Spe`] — both are deliberately
//! independent of how the software engines buffer activations.

mod cmul;
mod config;
mod pe;
mod simd;
mod spad;
mod spe;

pub use cmul::{cmul_multiply, cmul_segments, macs_per_cycle, Cmul};
pub use config::{ChipConfig, SpadSharing};
pub use pe::{Mpe, Pe};
pub use simd::{tile_block, unpack_weight, KernelTier, WeightCursor,
               WeightStream};
pub use spad::Spad;
pub use spe::{fill_cycles, lane_block, lane_block_packed,
              lane_block_staged, stage_window_block, tile_block_packed,
              tile_cycles, LaneWork, Spe, SpeTileResult};
