//! Scratchpad (SPad) traffic model.
//!
//! The paper's key area/power saving over Eyeriss v2: **one** SPad per
//! SPE, read simultaneously by all 16 lanes, with weights and select
//! signals streamed directly from the on-chip buffers (no FIFOs). The
//! model tracks read/write event counts; [`crate::power`] charges
//! energy per event, and the `spe_ablation` bench contrasts
//! `SpadSharing::Shared` with `SpadSharing::PerPe`.

use super::config::SpadSharing;

/// SPad + activation-register-file traffic counters for one SPE.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Spad {
    /// Word reads from the SPad SRAM.
    pub reads: u64,
    /// Word writes into the SPad SRAM.
    pub writes: u64,
    /// Register-file broadcasts into the 16-entry activation regs.
    pub reg_loads: u64,
    /// FIFO push+pop events (PerPe organization only — the shared
    /// design eliminates them).
    pub fifo_ops: u64,
}

impl Spad {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one activation fetch broadcast to `lanes` consuming PEs.
    ///
    /// Shared: 1 SRAM read + 1 regfile broadcast regardless of lanes.
    /// PerPe: every lane reads its own SPad copy and pays a FIFO hop.
    #[inline]
    pub fn fetch_activation(&mut self, sharing: SpadSharing, lanes: u64) {
        match sharing {
            SpadSharing::Shared => {
                self.reads += 1;
                self.reg_loads += 1;
            }
            SpadSharing::PerPe => {
                self.reads += lanes;
                self.reg_loads += lanes;
                self.fifo_ops += lanes;
            }
        }
    }

    /// Bulk form of [`Self::fetch_activation`]: `count` broadcasts in
    /// one counter update (simulator hot path).
    #[inline]
    pub fn fetch_activations(&mut self, sharing: SpadSharing, count: u64,
                             lanes: u64) {
        match sharing {
            SpadSharing::Shared => {
                self.reads += count;
                self.reg_loads += count;
            }
            SpadSharing::PerPe => {
                self.reads += count * lanes;
                self.reg_loads += count * lanes;
                self.fifo_ops += count * lanes;
            }
        }
    }

    /// Charge filling the SPad with `words` of an input tile (each
    /// word also transits the FIFO in the PerPe organization, once per
    /// lane's private copy).
    #[inline]
    pub fn fill(&mut self, sharing: SpadSharing, words: u64, lanes: u64) {
        match sharing {
            SpadSharing::Shared => self.writes += words,
            SpadSharing::PerPe => {
                self.writes += words * lanes;
                self.fifo_ops += words * lanes;
            }
        }
    }

    pub fn merge(&mut self, o: &Spad) {
        self.reads += o.reads;
        self.writes += o.writes;
        self.reg_loads += o.reg_loads;
        self.fifo_ops += o.fifo_ops;
    }

    /// `n` identical inferences' worth of traffic in one update
    /// (repeated `merge` of self, exactly — u64 addition distributes).
    /// Used by the fast batch path to stamp compile-time static costs.
    pub fn scale(&mut self, n: u64) {
        self.reads *= n;
        self.writes *= n;
        self.reg_loads *= n;
        self.fifo_ops *= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_reads_once_per_fetch() {
        let mut s = Spad::new();
        s.fetch_activation(SpadSharing::Shared, 16);
        assert_eq!(s.reads, 1);
        assert_eq!(s.fifo_ops, 0);
    }

    #[test]
    fn per_pe_multiplies_traffic() {
        let mut s = Spad::new();
        s.fetch_activation(SpadSharing::PerPe, 16);
        assert_eq!(s.reads, 16);
        assert_eq!(s.fifo_ops, 16);
    }

    #[test]
    fn fill_accounting() {
        let mut a = Spad::new();
        a.fill(SpadSharing::Shared, 100, 16);
        assert_eq!(a.writes, 100);
        let mut b = Spad::new();
        b.fill(SpadSharing::PerPe, 100, 16);
        assert_eq!(b.writes, 1600);
        assert_eq!(b.fifo_ops, 1600);
    }

    #[test]
    fn scale_equals_repeated_merge() {
        let mut one = Spad::new();
        one.fetch_activation(SpadSharing::PerPe, 3);
        one.fill(SpadSharing::Shared, 7, 16);
        let mut merged = Spad::new();
        for _ in 0..5 {
            merged.merge(&one);
        }
        let mut scaled = one.clone();
        scaled.scale(5);
        assert_eq!(scaled, merged);
    }

    #[test]
    fn merge_sums() {
        let mut a = Spad::new();
        a.fetch_activation(SpadSharing::Shared, 16);
        let mut b = Spad::new();
        b.fetch_activation(SpadSharing::PerPe, 4);
        a.merge(&b);
        assert_eq!(a.reads, 5);
        assert_eq!(a.reg_loads, 5);
        assert_eq!(a.fifo_ops, 4);
    }
}
