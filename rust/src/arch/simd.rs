//! Explicit SIMD tile kernels with runtime dispatch over the
//! bit-packed sub-byte weight stream.
//!
//! The portable kernel ([`crate::arch::tile_block_packed`]) trusts the
//! autovectorizer over the **decoded `i32` mirror** of the weight
//! stream. This module adds the production twin: an AVX2 kernel that
//! reads the **physical packed words** of the arena
//! ([`crate::compiler::PackedStreams::weight_words`]) — `wbits`-bit
//! two's-complement fields, LSB-first, `32 / wbits` per `u32` word —
//! unpacks each field in-register-adjacent scalar code (two shifts),
//! broadcasts it, and runs the `madd`-style accumulate over the staged
//! `[window_len, B]` block with 256-bit `vpmulld`/`vpaddd`, plus a
//! horizontal-sum helper for the single-position fringe kernel.
//!
//! **Dispatch** is a two-variant [`KernelTier`] selected once per
//! process ([`KernelTier::current`], cached): `Avx2` when the host has
//! AVX2 and `VACCEL_FORCE_SCALAR` is unset, `Scalar` otherwise. The
//! safe entry point ([`tile_block`]) re-verifies the CPU feature at
//! the dispatch site, so a stale or forged tier value can never reach
//! the intrinsics — the `Avx2` arm degrades to the scalar twin instead
//! of executing unsupported instructions.
//!
//! **Bit-exactness contract**: `i32` addition (wrapping) is
//! associative and commutative and `_mm256_mullo_epi32` is exactly
//! `i32::wrapping_mul`, so any lane blocking, vector width, or
//! horizontal-sum order produces the same accumulators as the scalar
//! twin — both tiers are bit-identical by construction, and
//! `tests/simd_dispatch.rs` pins it seed-swept over every fixture and
//! `nbits ∈ {2, 4, 8}`. Counters never consult the tier: zero-skip
//! acts on weights, so the event model is identical under either
//! kernel.

use std::sync::OnceLock;

use crate::arch::tile_block_packed;

/// Which tile-kernel implementation a backend executes. Selected once
/// at `Backend` construction (via [`KernelTier::current`]) and carried
/// as observability through `vaccel fleet` / `vaccel stream` headers
/// and the `kernel_tier` field of `BENCH_hotpath.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelTier {
    // Reserved next tier: `Avx512Vnni` — an AVX-512-VNNI kernel
    // (`vpdpbusd` fuses the widen-multiply-accumulate that today takes
    // a `vpmulld`/`vpaddd` pair). Detection slots in above `Avx2` in
    // `detect()`; until a kernel exists the variant stays a comment so
    // `match self` sites cannot silently under-handle it.
    /// Explicit 256-bit `std::arch` kernel over the packed sub-byte
    /// weight words (x86-64 hosts with AVX2).
    Avx2,
    /// The portable autovectorized kernel over the decoded `i32`
    /// mirror ([`tile_block_packed`]).
    Scalar,
}

impl KernelTier {
    /// Detect the best tier for this host: `Scalar` when
    /// `VACCEL_FORCE_SCALAR` is set (non-empty, not `"0"`), otherwise
    /// `Avx2` iff the CPU reports AVX2 at runtime.
    pub fn detect() -> Self {
        if std::env::var("VACCEL_FORCE_SCALAR")
            .is_ok_and(|v| !v.is_empty() && v != "0")
        {
            return KernelTier::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return KernelTier::Avx2;
            }
        }
        KernelTier::Scalar
    }

    /// The process-wide tier, detected once and cached — dispatch is
    /// a branch on a copied enum, never a repeated env/CPUID probe.
    pub fn current() -> Self {
        static TIER: OnceLock<KernelTier> = OnceLock::new();
        *TIER.get_or_init(Self::detect)
    }

    /// Stable lowercase name for headers and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Avx2 => "avx2",
            KernelTier::Scalar => "scalar",
        }
    }

    /// Whether this tier uses explicit SIMD intrinsics.
    pub fn is_simd(self) -> bool {
        matches!(self, KernelTier::Avx2)
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Everything a tile kernel needs from one layer's stream arena, in
/// both physical and decoded form: the select stream, the decoded
/// `i32` weight mirror (what the scalar tier and every counter path
/// read), and the bit-packed weight words + field width (what the
/// SIMD tier decodes in-register). Borrowed straight from
/// [`crate::compiler::PackedStreams::stream`]; `Copy`, so passing it
/// moves four slices' worth of pointers, no data.
#[derive(Debug, Clone, Copy)]
pub struct WeightStream<'a> {
    /// Select-signal stream (flat arena order).
    pub selects: &'a [u32],
    /// Decoded `i32` weight mirror (same indexing as `selects`).
    pub weights: &'a [i32],
    /// Physical packed weight words (`32 / wbits` fields per word).
    pub words: &'a [u32],
    /// Bits per packed weight field (`nbits.max(2)`).
    pub wbits: u32,
}

/// Decode packed weight field `idx` from the word stream: field `idx`
/// lives in word `idx / per`, bits `[(idx % per) · wbits,
/// (idx % per + 1) · wbits)`, two's complement. The shift-up/
/// arithmetic-shift-down pair sign-extends without a lookup table.
/// This is the *reference* decode — the kernels below keep a running
/// (word, field) cursor instead of dividing per pair.
#[inline]
pub fn unpack_weight(words: &[u32], wbits: u32, idx: usize) -> i32 {
    debug_assert!((2..=32).contains(&wbits) && 32 % wbits == 0);
    let per = (32 / wbits) as usize;
    let field = words[idx / per] >> ((idx % per) as u32 * wbits);
    ((field << (32 - wbits)) as i32) >> (32 - wbits)
}

/// Sequential decoder over the packed word stream, positioned at pair
/// `idx` — the zero-division inner-loop form of [`unpack_weight`]
/// (one word load per `32 / wbits` weights, two shifts per decode).
#[derive(Debug, Clone, Copy)]
pub struct WeightCursor<'a> {
    words: &'a [u32],
    wbits: u32,
    /// Fields per word.
    per: u32,
    /// Current word index.
    wi: usize,
    /// Current field within the word.
    fi: u32,
}

impl<'a> WeightCursor<'a> {
    /// Cursor positioned at packed pair `idx`.
    #[inline]
    pub fn at(words: &'a [u32], wbits: u32, idx: usize) -> Self {
        debug_assert!((2..=32).contains(&wbits) && 32 % wbits == 0);
        let per = 32 / wbits;
        Self { words, wbits, per,
               wi: idx / per as usize, fi: (idx % per as usize) as u32 }
    }

    /// Decode the field under the cursor and advance one pair.
    #[inline]
    pub fn next_weight(&mut self) -> i32 {
        let field = self.words[self.wi] >> (self.fi * self.wbits);
        self.fi += 1;
        if self.fi == self.per {
            self.fi = 0;
            self.wi += 1;
        }
        ((field << (32 - self.wbits)) as i32) >> (32 - self.wbits)
    }
}

/// The dispatched tile kernel: one channel tile's `live` lanes over
/// ONE staged `[window_len, B]` window block, writing each lane's `B`
/// accumulators into its interleaved stripe columns
/// (`stripe[(lo + p) · live + lane]`) — the same contract as
/// [`tile_block_packed`], which IS the `Scalar` arm. The `Avx2` arm
/// routes every ladder rung `B ∈ {8, 4, 2, 1}` through the explicit
/// kernels below; it re-checks the CPU feature at the call site, so
/// passing `Avx2` on a host without it degrades safely to scalar
/// instead of faulting.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn tile_block<const B: usize>(tier: KernelTier, ws: WeightStream<'_>,
                                  ranges: &[(u32, u32)], biases: &[i32],
                                  stage: &[i32], stripe: &mut [i32],
                                  lo: usize, live: usize) {
    match tier {
        KernelTier::Scalar => {
            tile_block_packed::<B>(ws.selects, ws.weights, ranges, biases,
                                   stage, stripe, lo, live);
        }
        KernelTier::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            if is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 support was just verified at runtime;
                // the kernels themselves index `stage`/`stripe`
                // through bounds-checked slices.
                unsafe {
                    avx2::tile_block::<B>(ws, ranges, biases, stage,
                                          stripe, lo, live);
                }
                return;
            }
            tile_block_packed::<B>(ws.selects, ws.weights, ranges, biases,
                                   stage, stripe, lo, live);
        }
    }
}

/// The AVX2 kernel family. Each kernel reads the **packed** weight
/// words through a [`WeightCursor`] (sub-byte unpack: one word load
/// per `32 / wbits` weights), broadcasts the decoded weight, and
/// multiply-accumulates a whole staged row per instruction. Memory
/// safety does not lean on `unsafe` loads: every stage row is taken
/// as a bounds-checked subslice first, so a malformed select panics
/// exactly like the scalar twin instead of reading out of bounds.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::WeightCursor;
    use super::WeightStream;
    use crate::arch::tile_block_packed;

    /// Dispatch on the position-block width. Every rung of the greedy
    /// 8/4/2/1 ladder has an explicit kernel; only a width outside the
    /// ladder (which `compute_cols` never emits) falls through to the
    /// scalar twin.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn tile_block<const B: usize>(
        ws: WeightStream<'_>, ranges: &[(u32, u32)], biases: &[i32],
        stage: &[i32], stripe: &mut [i32], lo: usize, live: usize) {
        match B {
            8 => tile_block8(ws, ranges, biases, stage, stripe, lo, live),
            4 => tile_block4(ws, ranges, biases, stage, stripe, lo, live),
            2 => tile_block2(ws, ranges, biases, stage, stripe, lo, live),
            1 => tile_block1(ws, ranges, biases, stage, stripe, lo, live),
            _ => tile_block_packed::<B>(ws.selects, ws.weights, ranges,
                                        biases, stage, stripe, lo, live),
        }
    }

    /// Sum the 8 `i32` lanes of a 256-bit vector (wrapping adds, so
    /// the reduction order is immaterial for bit-exactness).
    #[target_feature(enable = "avx2")]
    pub unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let s = _mm_add_epi32(lo, hi);
        // lanes [0+2, 1+3, _, _] then [0+2+1+3, _, _, _]
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
        _mm_cvtsi128_si32(s)
    }

    /// `B = 8`: one 256-bit accumulator per lane; each decoded weight
    /// broadcasts and multiply-accumulates its whole staged row.
    #[target_feature(enable = "avx2")]
    unsafe fn tile_block8(ws: WeightStream<'_>, ranges: &[(u32, u32)],
                          biases: &[i32], stage: &[i32],
                          stripe: &mut [i32], lo: usize, live: usize) {
        debug_assert!(ranges.len() >= live && biases.len() >= live);
        debug_assert!(stripe.len() >= (lo + 8) * live);
        for (lane, (&(off, len), &bias)) in
            ranges[..live].iter().zip(&biases[..live]).enumerate() {
            let (off, len) = (off as usize, len as usize);
            let sels = &ws.selects[off..off + len];
            let mut cur = WeightCursor::at(ws.words, ws.wbits, off);
            let mut acc = _mm256_set1_epi32(bias);
            for &sel in sels {
                let w = cur.next_weight();
                let s = sel as usize * 8;
                let row = &stage[s..s + 8];
                let v = _mm256_loadu_si256(row.as_ptr() as *const __m256i);
                acc = _mm256_add_epi32(
                    acc, _mm256_mullo_epi32(v, _mm256_set1_epi32(w)));
            }
            let mut out = [0i32; 8];
            _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, acc);
            for (p, v) in out.into_iter().enumerate() {
                stripe[(lo + p) * live + lane] = v;
            }
        }
    }

    /// `B = 4`: the 128-bit analogue (AVX2 implies SSE4.1 `pmulld`).
    #[target_feature(enable = "avx2")]
    unsafe fn tile_block4(ws: WeightStream<'_>, ranges: &[(u32, u32)],
                          biases: &[i32], stage: &[i32],
                          stripe: &mut [i32], lo: usize, live: usize) {
        debug_assert!(ranges.len() >= live && biases.len() >= live);
        debug_assert!(stripe.len() >= (lo + 4) * live);
        for (lane, (&(off, len), &bias)) in
            ranges[..live].iter().zip(&biases[..live]).enumerate() {
            let (off, len) = (off as usize, len as usize);
            let sels = &ws.selects[off..off + len];
            let mut cur = WeightCursor::at(ws.words, ws.wbits, off);
            let mut acc = _mm_set1_epi32(bias);
            for &sel in sels {
                let w = cur.next_weight();
                let s = sel as usize * 4;
                let row = &stage[s..s + 4];
                let v = _mm_loadu_si128(row.as_ptr() as *const __m128i);
                acc = _mm_add_epi32(
                    acc, _mm_mullo_epi32(v, _mm_set1_epi32(w)));
            }
            let mut out = [0i32; 4];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, acc);
            for (p, v) in out.into_iter().enumerate() {
                stripe[(lo + p) * live + lane] = v;
            }
        }
    }

    /// `B = 2` (the streaming fringe ladder's two-column rung):
    /// gather-free — vectorize across the *stream*, two pairs per
    /// iteration. Each selected stage row is one contiguous 64-bit
    /// load (`movq`); two rows sit side by side in a 128-bit register
    /// against their duplicated weights, so the register holds two
    /// independent accumulator chains per output column that fold
    /// together at the end. Wrapping-add associativity makes the
    /// even/odd chain split bit-exact with the sequential scalar
    /// chain.
    #[target_feature(enable = "avx2")]
    unsafe fn tile_block2(ws: WeightStream<'_>, ranges: &[(u32, u32)],
                          biases: &[i32], stage: &[i32],
                          stripe: &mut [i32], lo: usize, live: usize) {
        debug_assert!(ranges.len() >= live && biases.len() >= live);
        debug_assert!(stripe.len() >= (lo + 2) * live);
        for (lane, (&(off, len), &bias)) in
            ranges[..live].iter().zip(&biases[..live]).enumerate() {
            let (off, len) = (off as usize, len as usize);
            let sels = &ws.selects[off..off + len];
            let mut cur = WeightCursor::at(ws.words, ws.wbits, off);
            // lanes [0, 1]: even-pair chain (seeded with the bias);
            // lanes [2, 3]: odd-pair chain (seeded with zero)
            let mut vacc = _mm_set_epi32(0, 0, bias, bias);
            let mut i = 0usize;
            while i + 2 <= len {
                let s0 = sels[i] as usize * 2;
                let s1 = sels[i + 1] as usize * 2;
                let r0 = &stage[s0..s0 + 2];
                let r1 = &stage[s1..s1 + 2];
                let w0 = cur.next_weight();
                let w1 = cur.next_weight();
                let v = _mm_unpacklo_epi64(
                    _mm_loadl_epi64(r0.as_ptr() as *const __m128i),
                    _mm_loadl_epi64(r1.as_ptr() as *const __m128i));
                let w = _mm_set_epi32(w1, w1, w0, w0);
                vacc = _mm_add_epi32(vacc, _mm_mullo_epi32(v, w));
                i += 2;
            }
            let mut out = [0i32; 4];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, vacc);
            let mut acc0 = out[0].wrapping_add(out[2]);
            let mut acc1 = out[1].wrapping_add(out[3]);
            if i < len {
                let w = cur.next_weight();
                let s = sels[i] as usize * 2;
                acc0 = acc0.wrapping_add(stage[s].wrapping_mul(w));
                acc1 = acc1.wrapping_add(stage[s + 1].wrapping_mul(w));
            }
            stripe[lo * live + lane] = acc0;
            stripe[(lo + 1) * live + lane] = acc1;
        }
    }

    /// `B = 1` (the streaming fringe's single-column tail): vectorize
    /// across the *stream* instead of across positions — 8 pairs per
    /// iteration gathered scalar into a register, one `vpmulld`, one
    /// deferred [`hsum_epi32`]. Wrapping-add associativity makes the
    /// partial-sum split bit-exact with the sequential scalar chain.
    #[target_feature(enable = "avx2")]
    unsafe fn tile_block1(ws: WeightStream<'_>, ranges: &[(u32, u32)],
                          biases: &[i32], stage: &[i32],
                          stripe: &mut [i32], lo: usize, live: usize) {
        debug_assert!(ranges.len() >= live && biases.len() >= live);
        debug_assert!(stripe.len() >= (lo + 1) * live);
        for (lane, (&(off, len), &bias)) in
            ranges[..live].iter().zip(&biases[..live]).enumerate() {
            let (off, len) = (off as usize, len as usize);
            let sels = &ws.selects[off..off + len];
            let mut cur = WeightCursor::at(ws.words, ws.wbits, off);
            let mut vacc = _mm256_setzero_si256();
            let mut acc = bias;
            let mut i = 0usize;
            while i + 8 <= len {
                let mut rows = [0i32; 8];
                let mut wts = [0i32; 8];
                for j in 0..8 {
                    rows[j] = stage[sels[i + j] as usize];
                    wts[j] = cur.next_weight();
                }
                let v = _mm256_loadu_si256(rows.as_ptr() as *const __m256i);
                let w = _mm256_loadu_si256(wts.as_ptr() as *const __m256i);
                vacc = _mm256_add_epi32(vacc, _mm256_mullo_epi32(v, w));
                i += 8;
            }
            acc = acc.wrapping_add(hsum_epi32(vacc));
            while i < len {
                let w = cur.next_weight();
                acc = acc.wrapping_add(
                    stage[sels[i] as usize].wrapping_mul(w));
                i += 1;
            }
            stripe[lo * live + lane] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unpack_weight_sign_extends_every_width() {
        // wbits 4: fields 0x1, 0x9 (-7), 0x3, 0xF (-1), LSB-first
        let w4 = vec![0xF391u32];
        assert_eq!(unpack_weight(&w4, 4, 0), 1);
        assert_eq!(unpack_weight(&w4, 4, 1), -7);
        assert_eq!(unpack_weight(&w4, 4, 2), 3);
        assert_eq!(unpack_weight(&w4, 4, 3), -1);
        // wbits 2: 0b01 (1), 0b11 (-1), 0b10 (-2)
        let w2 = vec![0b10_11_01u32];
        assert_eq!(unpack_weight(&w2, 2, 0), 1);
        assert_eq!(unpack_weight(&w2, 2, 1), -1);
        assert_eq!(unpack_weight(&w2, 2, 2), -2);
        // wbits 8: i8 range incl. extremes, across a word boundary
        let vals = [-128i32, 127, -1, 5, 99, -100];
        let mut words = vec![0u32; 2];
        for (i, &v) in vals.iter().enumerate() {
            words[i / 4] |= ((v as u32) & 0xFF) << ((i % 4) as u32 * 8);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(unpack_weight(&words, 8, i), v, "idx {i}");
        }
    }

    #[test]
    fn cursor_matches_reference_decode_from_any_start() {
        let mut words = vec![0u32; 5];
        let vals: Vec<i32> = (0..40).map(|i| ((i * 7) % 15) - 7).collect();
        for (i, &v) in vals.iter().enumerate() {
            words[i / 8] |= ((v as u32) & 0xF) << ((i % 8) as u32 * 4);
        }
        for start in [0usize, 1, 7, 8, 13, 39] {
            let mut cur = WeightCursor::at(&words, 4, start);
            for idx in start..vals.len() {
                assert_eq!(cur.next_weight(),
                           unpack_weight(&words, 4, idx),
                           "start {start} idx {idx}");
            }
        }
    }

    #[test]
    fn tier_name_and_display_are_stable() {
        assert_eq!(KernelTier::Avx2.name(), "avx2");
        assert_eq!(KernelTier::Scalar.name(), "scalar");
        assert_eq!(format!("{}", KernelTier::Scalar), "scalar");
        assert!(KernelTier::Avx2.is_simd());
        assert!(!KernelTier::Scalar.is_simd());
        // current() is cached: two calls agree
        assert_eq!(KernelTier::current(), KernelTier::current());
    }

    /// Random (selects, weights) streams per lane over a random stage:
    /// the dispatched Avx2 arm (explicit kernels where the host has
    /// AVX2, scalar fallback otherwise) must equal the Scalar arm
    /// bit-for-bit for every ladder width — including empty lanes and
    /// partial `live`.
    #[test]
    fn avx2_dispatch_matches_scalar_every_block_width() {
        fn check<const B: usize>(seed: u64) {
            let mut rng = crate::data::SplitMix64::new(seed);
            let wlen = 24usize;
            let m = 6usize;
            let wbits = [2u32, 4, 8][(seed % 3) as usize];
            let qmax: i32 = (1 << (wbits - 1)) - 1;
            let mut selects = Vec::new();
            let mut weights = Vec::new();
            let mut ranges = Vec::new();
            let mut biases = Vec::new();
            for lane in 0..m {
                let start = selects.len();
                // lane 2 deliberately empty (a fully-pruned channel)
                let n = if lane == 2 { 0 }
                        else { 1 + (rng.next_u64() % 17) as usize };
                for _ in 0..n {
                    selects.push((rng.next_u64() % wlen as u64) as u32);
                    let v = 1 + (rng.next_u64() % qmax as u64) as i32;
                    weights.push(if rng.uniform() < 0.5 { -v } else { v });
                }
                ranges.push((start as u32, (selects.len() - start) as u32));
                biases.push((rng.next_u64() % 1000) as i32 - 500);
            }
            let per = (32 / wbits) as usize;
            let mut words = vec![0u32; weights.len().div_ceil(per)];
            for (i, &w) in weights.iter().enumerate() {
                words[i / per] |=
                    ((w as u32) & ((1u32 << wbits) - 1))
                        << ((i % per) as u32 * wbits);
            }
            let ws = WeightStream { selects: &selects, weights: &weights,
                                    words: &words, wbits };
            let stage: Vec<i32> = (0..wlen * B)
                .map(|_| (rng.next_u64() % 4001) as i32 - 2000)
                .collect();
            for live in [1usize, 3, m] {
                let lo = 2usize;
                let mut a = vec![0i32; (lo + B) * live];
                let mut b = vec![0i32; (lo + B) * live];
                tile_block::<B>(KernelTier::Scalar, ws, &ranges, &biases,
                                &stage, &mut a, lo, live);
                tile_block::<B>(KernelTier::Avx2, ws, &ranges, &biases,
                                &stage, &mut b, lo, live);
                assert_eq!(a, b, "B {B} live {live} wbits {wbits}");
            }
        }
        for seed in 0..9u64 {
            check::<8>(seed);
            check::<4>(seed);
            check::<2>(seed);
            check::<1>(seed);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn hsum_reduces_all_eight_lanes() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        use std::arch::x86_64::*;
        let vals = [1i32, -2, 30, -400, 5000, -60000, 700000, i32::MAX];
        let want = vals.iter().fold(0i32, |a, &v| a.wrapping_add(v));
        // SAFETY: AVX2 verified above.
        let got = unsafe {
            let v = _mm256_loadu_si256(vals.as_ptr() as *const __m256i);
            avx2::hsum_epi32(v)
        };
        assert_eq!(got, want);
    }
}
