//! SPE — the Sparse Processing Element cluster (Fig. 2).
//!
//! One SPE computes all `M` (=16) output channels of one output
//! position per tile: the 16-entry activation register file is filled
//! from the shared SPad in chunks as the compressed weight streams walk
//! the receptive-field window, each lane MUXes the activation named by
//! its *select signal* and MACs it against the non-zero weight. All
//! lanes run **synchronously**: the tile takes as long as the fullest
//! lane (which is why the compiler's balanced pruning matters).
//!
//! Counter contract: the events this module (and [`Spad`]) measures
//! are properties of the weight streams and the schedule, never of
//! where the software engines buffer activations. In particular the
//! PE **drain** (requant of each accumulator on its way out, charged
//! as `output_writes` by both the counted engine and the static cost
//! model) is one event per output element regardless of whether the
//! software pass is standalone or fused into the next layer's staging
//! read — the SPE datapath never materializes a dense row-major
//! feature map either way.

use super::cmul::Cmul;
use super::config::ChipConfig;
use super::pe::Pe;
use super::spad::Spad;

/// Activation register file depth (the "16 registers" of Fig. 2).
pub const ACT_REGS: usize = 16;

/// Compressed weight stream for one PE lane: (select, weight) pairs,
/// zeros already removed by the compiler. This is a borrowed **view**
/// into the layer's flat stream arena
/// ([`crate::compiler::PackedStreams`]): the compiler stores every
/// lane's pairs contiguously in one SoA allocation per layer, and a
/// `LaneWork` is just the `(offset, len)` range of one lane
/// materialized as slices — cheap to copy, nothing to own.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneWork<'a> {
    /// Indices into the position's activation window.
    pub selects: &'a [u32],
    /// Matching non-zero quantized weights.
    pub weights: &'a [i32],
}

impl LaneWork<'_> {
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }
}

/// Exposed activation-regfile fill cycles for one position window: the
/// window streams SPad→regs in [`ACT_REGS`]-sized chunks but only the
/// FIRST chunk is exposed (later fills overlap compute), so any
/// non-empty window costs exactly one fill cycle and an empty window
/// costs none. Single source of truth: every fill charge — in
/// [`tile_cycles`] and hence in both engines and the compile-time cost
/// model ([`crate::compiler::StaticCost`]) — goes through here.
#[inline]
pub fn fill_cycles(window_len: usize) -> u64 {
    (window_len != 0) as u64
}

/// THE cycle cost of one synchronous array step (one position tile of
/// one channel tile): the slowest lane at this precision when zero-skip
/// streams are loaded, or the dense window walk when zero-skip is
/// disabled, plus the exposed regfile fill. Shared by the compile-time
/// static cost model, the counted reference engine, and the SPE
/// execution model itself — previously `sim::engine` had its own copy
/// whose fill term (`+1` always) disagreed with the SPE's
/// (`min(ceil(w/16),1)`) on empty windows.
pub fn tile_cycles(lanes: &[LaneWork], window_len: usize, nbits: u32,
                   zero_skip: bool) -> u64 {
    let compute = if zero_skip {
        lanes.iter()
            .map(|l| Cmul::cycles_for(l.len() as u64, nbits))
            .max()
            .unwrap_or(0)
    } else {
        Cmul::cycles_for(window_len as u64, nbits)
    };
    compute.max(1) + fill_cycles(window_len)
}

/// Zero-allocation hot kernel: one lane's compressed weight stream
/// applied to a block of `B` consecutive output positions whose windows
/// start at `base`, `base + step`, … in the padded activation buffer
/// (`step` = stride · Cin). Each (select, weight) pair is decoded once
/// and MAC'd into all `B` accumulators — `B` independent dependency
/// chains that pipeline/vectorize, which is where the fast path's
/// speedup over the per-position counted walk comes from. No counters:
/// every event this kernel would count is a compile-time constant of
/// the packed streams ([`crate::compiler::StaticCost`]). Integer
/// wrapping addition is associative, so the position-blocked order is
/// bit-exact with the counted per-position walk.
///
/// The gather `padded[s + p * step]` is strided, which keeps LLVM from
/// vectorizing the inner loop; block callers should stage the window
/// once with [`stage_window_block`] and run the packed tile kernel
/// ([`tile_block_packed`], or [`lane_block_staged`] /
/// [`lane_block_packed`] per lane), which turns every select into a
/// contiguous `B`-wide load shared by all lanes of the tile. This form
/// remains for single-position tails and as the staging-free reference.
#[inline]
pub fn lane_block<const B: usize>(work: &LaneWork, padded: &[i32],
                                  base: usize, step: usize, bias: i32)
                                  -> [i32; B] {
    let mut acc = [bias; B];
    for (&sel, &wt) in work.selects.iter().zip(work.weights) {
        let s = base + sel as usize;
        for p in 0..B {
            acc[p] = acc[p].wrapping_add(padded[s + p * step] * wt);
        }
    }
    acc
}

/// Stage the receptive-field windows of `B` consecutive output
/// positions into a packed `[window_len, B]` block:
/// `stage[sel · B + p] = padded[base + sel + p · step]`. One staging
/// pass per position block is shared by every lane of every channel
/// tile at those positions, so the strided gather is paid once and the
/// hot kernel ([`lane_block_staged`]) reads only contiguous rows.
#[inline]
pub fn stage_window_block<const B: usize>(padded: &[i32], base: usize,
                                          step: usize, window_len: usize,
                                          stage: &mut [i32]) {
    debug_assert!(stage.len() >= window_len * B);
    debug_assert!(padded.len() >= base + window_len + (B - 1) * step);
    for (sel, row) in stage[..window_len * B].chunks_exact_mut(B).enumerate() {
        let s = base + sel;
        for (p, v) in row.iter_mut().enumerate() {
            *v = padded[s + p * step];
        }
    }
}

/// [`lane_block`] over a pre-staged `[window_len, B]` window block:
/// each (select, weight) pair loads the `B` activations of its select
/// row as one contiguous slice — the vectorizable form of the fast
/// kernel. Values and accumulation order are identical to
/// [`lane_block`] on the same positions, so the two are bit-exact.
#[inline]
pub fn lane_block_staged<const B: usize>(work: &LaneWork, stage: &[i32],
                                         bias: i32) -> [i32; B] {
    lane_block_packed(work.selects, work.weights, stage, bias)
}

/// The packed-stream form of the staged kernel: one lane's flat
/// `(selects, weights)` stream — two raw slices straight out of the
/// layer's [`crate::compiler::PackedStreams`] arena — applied to a
/// pre-staged `[window_len, B]` window block. Each select row is read
/// as a **fixed-size `&[i32; B]` array**, so the inner mul-add runs
/// over arrays whose length the compiler knows at every step: the
/// B-wide vectorization is guaranteed by construction (no heuristic
/// bounds-check hoisting), which is the stable-toolchain answer to an
/// explicit `std::simd` i32x8 kernel. Values and accumulation order
/// are identical to [`lane_block`] on the same positions — staging
/// and packing re-order memory, never arithmetic.
#[inline]
pub fn lane_block_packed<const B: usize>(selects: &[u32], weights: &[i32],
                                         stage: &[i32], bias: i32)
                                         -> [i32; B] {
    debug_assert_eq!(selects.len(), weights.len());
    let mut acc = [bias; B];
    for (&sel, &wt) in selects.iter().zip(weights) {
        let s = sel as usize * B;
        let row: &[i32; B] = stage[s..s + B].try_into().expect("staged row");
        for p in 0..B {
            acc[p] = acc[p].wrapping_add(row[p] * wt);
        }
    }
    acc
}

/// One channel tile's worth of the packed fast kernel: run all `live`
/// lanes of a stripe over ONE staged `[window_len, B]` window block,
/// writing each lane's `B` accumulators straight into its interleaved
/// stripe columns (`stripe[(lo + p) · live + lane]`, the tile-major
/// layout of [`crate::compiler::TileStripe`]). The stage is loaded
/// once per tile visit and every lane streams its contiguous slice of
/// the flat arena — no per-lane heap indirection anywhere in the loop.
///
/// `selects`/`weights` are the layer's whole stream arena, `ranges`
/// the tile's `m`-entry `(offset, len)` table
/// ([`crate::compiler::PackedStreams::tile_ranges`]) of which the
/// first `live` lanes are executed, and `biases` the tile's
/// accumulator preloads. Bit-exact with calling [`lane_block_staged`]
/// per lane: the lane order and each lane's stream order are the
/// arena order, which is the packing order.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn tile_block_packed<const B: usize>(selects: &[u32], weights: &[i32],
                                         ranges: &[(u32, u32)],
                                         biases: &[i32], stage: &[i32],
                                         stripe: &mut [i32], lo: usize,
                                         live: usize) {
    debug_assert!(ranges.len() >= live && biases.len() >= live);
    debug_assert!(stripe.len() >= (lo + B) * live);
    for (lane, (&(off, len), &bias)) in
        ranges[..live].iter().zip(&biases[..live]).enumerate() {
        let (off, len) = (off as usize, len as usize);
        let acc: [i32; B] = lane_block_packed(&selects[off..off + len],
                                              &weights[off..off + len],
                                              stage, bias);
        for (p, v) in acc.into_iter().enumerate() {
            stripe[(lo + p) * live + lane] = v;
        }
    }
}

/// Result of executing one output position on an SPE.
#[derive(Debug, Clone)]
pub struct SpeTileResult {
    /// One accumulator per lane (`M` outputs).
    pub accs: Vec<i32>,
    /// Synchronous cycle cost of the tile (slowest lane + regfile
    /// fill that cannot be overlapped).
    pub cycles: u64,
    /// Segment operations executed (CMUL energy events).
    pub segment_ops: u64,
    /// MACs executed (non-zero only).
    pub macs: u64,
}

/// One SPE instance: `m` lanes + traffic counters.
#[derive(Debug, Clone)]
pub struct Spe {
    lanes: Vec<Pe>,
    pub spad: Spad,
    /// Stuck-at fault-injection state: `(lane, value)` overrides
    /// applied at the accumulator drain of every executed position.
    /// Empty (the default) is the healthy datapath — the drain loop
    /// over an empty vec costs nothing.
    stuck: Vec<(usize, i32)>,
}

impl Spe {
    pub fn new(m: usize) -> Self {
        Self { lanes: (0..m).map(|_| Pe::new()).collect(), spad: Spad::new(),
               stuck: Vec::new() }
    }

    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Fault-injection hook: force `lane`'s accumulator output to
    /// `value` on every position until [`Spe::clear_stuck`] — the
    /// stuck-at datapath fault of
    /// [`crate::reliability::FaultKind::StuckLane`]. Returns `false`
    /// (and does nothing) for an out-of-range lane. Deliberately
    /// survives [`Spe::reset`]: a hardware stuck-at persists across
    /// tile visits; only explicit repair clears it.
    pub fn force_stuck(&mut self, lane: usize, value: i32) -> bool {
        if lane >= self.lanes.len() {
            return false;
        }
        self.stuck.retain(|&(l, _)| l != lane);
        self.stuck.push((lane, value));
        true
    }

    /// Clear every stuck-at override (the repair action).
    pub fn clear_stuck(&mut self) {
        self.stuck.clear();
    }

    /// Currently forced `(lane, value)` overrides.
    pub fn stuck_lanes(&self) -> &[(usize, i32)] {
        &self.stuck
    }

    /// Zero every traffic/energy counter and lane accumulator, keeping
    /// the lane storage: lets one SPE instance (e.g. the one owned by a
    /// [`crate::sim::ScratchArena`]) serve successive channel tiles
    /// without reallocating, while each tile's counter partial starts
    /// from a clean slate.
    pub fn reset(&mut self) {
        self.spad = Spad::new();
        for lane in &mut self.lanes {
            *lane = Pe::new();
        }
    }

    /// Execute one output position: `window` is the receptive-field
    /// activation slice (K·Cin values) in SPad, `work[lane]` the
    /// compressed streams, `biases[lane]` the accumulator preloads.
    ///
    /// Timing model ([`tile_cycles`], the one shared formula):
    /// * regfile fill: the window streams SPad→regs in chunks of
    ///   [`ACT_REGS`]; one broadcast per window element, one cycle per
    ///   chunk visible (fills overlap compute after the first chunk).
    /// * compute: lanes run in lockstep; a lane retires
    ///   `macs_per_cycle(nbits)` MACs per cycle; the tile ends when the
    ///   fullest lane drains.
    pub fn execute_position(&mut self, cfg: &ChipConfig, window: &[i32],
                            work: &[LaneWork], biases: &[i32], nbits: u32)
                            -> SpeTileResult {
        let mut accs = vec![0i32; self.lanes.len()];
        let (segment_ops, macs) =
            self.execute_position_into(cfg, window, work, biases, nbits, &mut accs);
        SpeTileResult {
            accs,
            cycles: tile_cycles(work, window.len(), nbits, true),
            segment_ops,
            macs,
        }
    }

    /// Allocation-free variant used by the counted reference engine:
    /// lane accumulators are written into `out[..lanes]`; returns
    /// `(segment_ops, macs)`. Timing is a static property of the
    /// streams, so callers charge it once per tile via [`tile_cycles`]
    /// rather than once per position.
    pub fn execute_position_into(&mut self, cfg: &ChipConfig, window: &[i32],
                                 work: &[LaneWork], biases: &[i32], nbits: u32,
                                 out: &mut [i32]) -> (u64, u64) {
        assert_eq!(work.len(), self.lanes.len());
        assert_eq!(biases.len(), self.lanes.len());
        // SPad → regfile broadcasts (shared: one per element; per-PE:
        // one per element per lane) — bulk counter update (§Perf L3.4)
        self.spad.fetch_activations(cfg.spad_sharing, window.len() as u64,
                                    self.lanes.len() as u64);
        let mut segment_ops = 0u64;
        let mut macs = 0u64;
        for (i, (lane, (w, &bias))) in self.lanes.iter_mut()
            .zip(work.iter().zip(biases)).enumerate() {
            // reference loop: counters are batched per lane and the MAC
            // reduction runs on locals; semantics identical to per-MAC
            // `Pe::mac` (covered by execute_position tests). The fast
            // simulator path uses [`lane_block`] instead and takes its
            // counters from the compile-time static cost model.
            let mut acc = bias;
            for (&sel, &wt) in w.selects.iter().zip(w.weights) {
                debug_assert!(wt != 0, "compiler must strip zero weights");
                debug_assert_eq!(super::cmul::cmul_multiply(
                    window[sel as usize], wt, nbits),
                    window[sel as usize] * wt);
                acc = acc.wrapping_add(window[sel as usize] * wt);
            }
            let n = w.len() as u64;
            lane.cmul.segment_ops += super::cmul::cmul_segments(nbits) as u64 * n;
            lane.cmul.multiplies += n;
            lane.macs += n;
            segment_ops += super::cmul::cmul_segments(nbits) as u64 * n;
            macs += n;
            out[i] = acc;
        }
        // stuck-at drain faults override whatever the lane computed
        for &(lane, v) in &self.stuck {
            if lane < out.len() {
                out[lane] = v;
            }
        }
        (segment_ops, macs)
    }

    /// Dense-mode cycle cost for the same tile (zero-skip disabled):
    /// every lane walks the full window.
    pub fn dense_cycles(window_len: usize, nbits: u32) -> u64 {
        tile_cycles(&[], window_len, nbits, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SpadSharing;

    fn cfg() -> ChipConfig {
        ChipConfig::paper_1d()
    }

    /// Owned backing storage for a [`LaneWork`] view. Production
    /// streams live in the compiler's flat
    /// [`crate::compiler::PackedStreams`] arena; tests keep small
    /// per-lane vectors and borrow views from them.
    #[derive(Clone, Default)]
    struct OwnedLane {
        selects: Vec<u32>,
        weights: Vec<i32>,
    }

    impl OwnedLane {
        fn view(&self) -> LaneWork<'_> {
            LaneWork { selects: &self.selects, weights: &self.weights }
        }
    }

    fn mk_work(pairs: &[(u32, i32)]) -> OwnedLane {
        OwnedLane {
            selects: pairs.iter().map(|p| p.0).collect(),
            weights: pairs.iter().map(|p| p.1).collect(),
        }
    }

    fn views<'a>(lanes: &'a [OwnedLane]) -> Vec<LaneWork<'a>> {
        lanes.iter().map(|l| l.view()).collect()
    }

    #[test]
    fn computes_exact_dot_products() {
        let mut spe = Spe::new(2);
        let window = [3, -1, 4, 1];
        let owned = [
            mk_work(&[(0, 2), (2, -1)]),          // 3*2 + 4*(-1) = 2
            mk_work(&[(1, 5), (3, 7), (0, -2)]),  // -5 + 7 - 6 = -4
        ];
        let work = views(&owned);
        let r = spe.execute_position(&cfg(), &window, &work, &[10, 0], 8);
        assert_eq!(r.accs, vec![12, -4]);
        assert_eq!(r.macs, 5);
    }

    #[test]
    fn cycles_follow_slowest_lane() {
        let mut spe = Spe::new(2);
        let window = [1i32; 8];
        let owned = [
            mk_work(&[(0, 1)]),
            mk_work(&[(0, 1), (1, 1), (2, 1), (3, 1), (4, 1)]),
        ];
        let work = views(&owned);
        let r = spe.execute_position(&cfg(), &window, &work, &[0, 0], 8);
        // slowest lane: 5 macs at 1/cycle + 1 fill cycle
        assert_eq!(r.cycles, 6);
    }

    #[test]
    fn lower_precision_is_faster() {
        let window = [1i32; 8];
        let owned =
            vec![mk_work(&[(0, 1), (1, 1), (2, 1), (3, 1), (4, 1), (5, 1), (6, 1), (7, 1)]); 2];
        let work = views(&owned);
        let r8 = Spe::new(2).execute_position(&cfg(), &window, &work, &[0, 0], 8);
        let r2 = Spe::new(2).execute_position(&cfg(), &window, &work, &[0, 0], 2);
        assert_eq!(r8.cycles, 9); // 8 macs + fill
        assert_eq!(r2.cycles, 3); // ceil(8/4) + fill
        assert!(r2.segment_ops < r8.segment_ops);
    }

    #[test]
    fn shared_vs_per_pe_traffic() {
        let window = [1i32; 4];
        let owned = vec![mk_work(&[(0, 1)]); 16];
        let work = views(&owned);
        let mut shared = Spe::new(16);
        shared.execute_position(&cfg(), &window, &work, &[0; 16], 8);
        let mut per_pe_cfg = cfg();
        per_pe_cfg.spad_sharing = SpadSharing::PerPe;
        let mut private = Spe::new(16);
        private.execute_position(&per_pe_cfg, &window, &work, &[0; 16], 8);
        assert_eq!(shared.spad.reads, 4);
        assert_eq!(private.spad.reads, 64);
        assert_eq!(private.spad.fifo_ops, 64);
    }

    /// The timing-drift fix: the SPE's reported cycles and the
    /// engine/static-cost timing all come from ONE formula
    /// ([`tile_cycles`]), including the empty-window corner where the
    /// old duplicated copies disagreed (`+1` fill always vs
    /// `min(ceil(w/16),1)` = 0).
    #[test]
    fn one_timing_formula_including_empty_windows() {
        // empty window, empty lanes: 1-cycle compute floor, no fill
        assert_eq!(fill_cycles(0), 0);
        assert_eq!(tile_cycles(&[], 0, 8, true), 1);
        assert_eq!(tile_cycles(&[], 0, 8, false), 1);
        let r = Spe::new(0).execute_position(&cfg(), &[], &[], &[], 8);
        assert_eq!(r.cycles, 1);
        assert_eq!((r.segment_ops, r.macs), (0, 0));
        // any non-empty window exposes exactly one fill cycle
        for wl in [1usize, 15, 16, 17, 320] {
            assert_eq!(fill_cycles(wl), 1, "wl={wl}");
        }
        // the SPE's reported cycles come from the same formula
        let window = [1i32; 8];
        let owned = [mk_work(&[(0, 1), (0, 2), (0, 3)]), mk_work(&[(0, 1)])];
        let work = views(&owned);
        let r = Spe::new(2).execute_position(&cfg(), &window, &work, &[0, 0], 8);
        assert_eq!(r.cycles, tile_cycles(&work, 8, 8, true));
        assert_eq!(r.cycles, 4); // slowest lane 3 macs + 1 fill
        // dense branch walks the window instead of the slowest lane
        let one = mk_work(&[(0, 1)]);
        assert_eq!(tile_cycles(&[one.view()], 10, 8, false), 11);
        assert_eq!(Spe::dense_cycles(10, 8), 11);
    }

    /// The position-blocked fast kernel computes the identical integer
    /// function as the counted per-position walk, for every block size.
    #[test]
    fn lane_block_matches_counted_positions() {
        let padded: Vec<i32> = (0..64).map(|i| (i * 7 % 23) - 11).collect();
        let owned = mk_work(&[(0, 3), (2, -5), (5, 1), (1, 127)]);
        let work = owned.view();
        let step = 2; // stride 2, cin 1
        let bias = -9;
        for base in [0usize, 2, 4] {
            let b8: [i32; 8] = lane_block(&work, &padded, base, step, bias);
            for p in 0..8 {
                let window = &padded[base + p * step..base + p * step + 6];
                let mut spe = Spe::new(1);
                let mut out = [0i32; 1];
                spe.execute_position_into(&cfg(), window,
                                          std::slice::from_ref(&work),
                                          &[bias], 8, &mut out);
                let b1: [i32; 1] =
                    lane_block(&work, &padded, base + p * step, step, bias);
                assert_eq!(b8[p], out[0], "base={base} p={p}");
                assert_eq!(b1[0], out[0], "base={base} p={p}");
            }
        }
    }

    /// The staged, packed-stream and tile-level kernels are all
    /// bit-exact with the gather kernel: staging and packing only
    /// re-order memory, never values or accumulation order.
    #[test]
    fn staged_and_packed_kernels_match_gather_kernel() {
        let padded: Vec<i32> = (0..96).map(|i| (i * 13 % 37) - 18).collect();
        let owned = [
            mk_work(&[(0, 3), (2, -5), (5, 1), (1, 127)]),
            mk_work(&[(5, -2)]),
            mk_work(&[]), // fully-pruned lane
        ];
        // flat SoA arena of the three lanes, compiler-style
        let mut selects = Vec::new();
        let mut weights = Vec::new();
        let mut ranges = Vec::new();
        for l in &owned {
            ranges.push((selects.len() as u32, l.selects.len() as u32));
            selects.extend_from_slice(&l.selects);
            weights.extend_from_slice(&l.weights);
        }
        let biases = [-7i32, 4, 0];
        let live = owned.len();
        let wlen = 6;
        for step in [1usize, 2, 3] {
            for base in [0usize, 2, 7] {
                let mut stage = vec![0i32; wlen * 8];
                stage_window_block::<8>(&padded, base, step, wlen, &mut stage);
                // staged rows hold exactly the strided gathers
                for sel in 0..wlen {
                    for p in 0..8 {
                        assert_eq!(stage[sel * 8 + p],
                                   padded[base + sel + p * step]);
                    }
                }
                let mut stripe = vec![0i32; 8 * live];
                tile_block_packed::<8>(&selects, &weights, &ranges, &biases,
                                       &stage, &mut stripe, 0, live);
                for (lane, o) in owned.iter().enumerate() {
                    let work = o.view();
                    let a: [i32; 8] = lane_block(&work, &padded, base, step,
                                                 biases[lane]);
                    let b: [i32; 8] = lane_block_staged(&work, &stage,
                                                        biases[lane]);
                    let (off, len) = ranges[lane];
                    let (off, len) = (off as usize, len as usize);
                    let c: [i32; 8] = lane_block_packed(
                        &selects[off..off + len], &weights[off..off + len],
                        &stage, biases[lane]);
                    assert_eq!(a, b, "step={step} base={base} lane={lane}");
                    assert_eq!(a, c, "step={step} base={base} lane={lane}");
                    for p in 0..8 {
                        assert_eq!(stripe[p * live + lane], a[p],
                                   "step={step} base={base} lane={lane} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn stuck_lane_overrides_drain_until_cleared() {
        let mut spe = Spe::new(2);
        let window = [3, -1, 4, 1];
        let owned = [
            mk_work(&[(0, 2), (2, -1)]),          // 2
            mk_work(&[(1, 5), (3, 7), (0, -2)]),  // -4
        ];
        let work = views(&owned);
        assert!(!spe.force_stuck(2, 9), "lane 2 does not exist");
        assert!(spe.force_stuck(1, 0x7FFF));
        assert!(spe.force_stuck(1, -1), "re-forcing replaces, not stacks");
        assert_eq!(spe.stuck_lanes(), &[(1, -1)]);
        let r = spe.execute_position(&cfg(), &window, &work, &[0, 0], 8);
        assert_eq!(r.accs, vec![2, -1], "lane 1 stuck at -1");
        assert_eq!(r.macs, 5, "counters describe the streams, not the fault");
        // the fault survives reset — it models broken silicon
        spe.reset();
        let r = spe.execute_position(&cfg(), &window, &work, &[0, 0], 8);
        assert_eq!(r.accs, vec![2, -1]);
        spe.clear_stuck();
        let r = spe.execute_position(&cfg(), &window, &work, &[0, 0], 8);
        assert_eq!(r.accs, vec![2, -4], "repair restores the true drain");
    }

    #[test]
    fn reset_clears_counters_and_accumulators() {
        let mut spe = Spe::new(2);
        let window = [3, -1, 4, 1];
        let owned = [mk_work(&[(0, 2), (2, -1)]), mk_work(&[(1, 5)])];
        let work = views(&owned);
        let first = spe.execute_position(&cfg(), &window, &work, &[0, 0], 8);
        assert!(spe.spad.reads > 0);
        spe.reset();
        assert_eq!(spe.spad, crate::arch::Spad::new());
        assert_eq!(spe.num_lanes(), 2);
        // a reset SPE behaves exactly like a fresh one
        let again = spe.execute_position(&cfg(), &window, &work, &[0, 0], 8);
        assert_eq!(again.accs, first.accs);
        assert_eq!(again.macs, first.macs);
        let mut expect = crate::arch::Spad::new();
        expect.fetch_activations(cfg().spad_sharing, 4, 2);
        assert_eq!(spe.spad, expect, "post-reset traffic is one tile's worth");
    }

    #[test]
    fn matches_golden_conv_for_one_position() {
        // one output position of a k=3,cin=2,cout=2 conv, dense streams
        let a = [1, 2, 3, 4, 5, 6]; // window [k*cin]
        let w = [1, -1, 2, -2, 3, -3, 4, -4, 5, -5, 6, -6]; // [K,Cin,Cout]
        let golden = crate::nn::conv1d_int(&a, 3, 2, &w, 3, 2, &[0, 0], 1);
        let mut owned = vec![OwnedLane::default(); 2];
        for k in 0..3 {
            for ci in 0..2 {
                for co in 0..2 {
                    let wt = w[(k * 2 + ci) * 2 + co];
                    owned[co].selects.push((k * 2 + ci) as u32);
                    owned[co].weights.push(wt);
                }
            }
        }
        let lanes = views(&owned);
        let r = Spe::new(2).execute_position(&cfg(), &a, &lanes, &[0, 0], 8);
        assert_eq!(r.accs, golden[..2].to_vec());
    }
}
