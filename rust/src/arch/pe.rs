//! PE and MPE functional models (Fig. 2).
//!
//! A PE owns one CMUL and one int32 accumulator; it receives
//! (select-signal, weight) pairs from the compressed weight stream,
//! MUXes the selected input activation out of the SPE's 16-entry
//! activation register file, multiplies through the CMUL, and
//! accumulates. An MPE is a PE that can additionally execute max/avg
//! pooling on its accumulator path.

use super::cmul::Cmul;

/// One processing element lane.
#[derive(Debug, Clone, Default)]
pub struct Pe {
    pub acc: i32,
    pub cmul: Cmul,
    /// MACs actually executed (non-zero weights only when the select
    /// stream comes from the sparse compiler).
    pub macs: u64,
}

impl Pe {
    pub fn new() -> Self {
        Self::default()
    }

    /// Load the bias (accumulator preload, start of an output tile).
    #[inline]
    pub fn preload(&mut self, bias: i32) {
        self.acc = bias;
    }

    /// One MAC: activation selected by the select signal × weight.
    #[inline]
    pub fn mac(&mut self, act: i32, w: i32, nbits: u32) {
        self.acc = self.acc.wrapping_add(self.cmul.multiply(act, w, nbits));
        self.macs += 1;
    }

    /// Drain the accumulator (end of an output tile).
    #[inline]
    pub fn drain(&mut self) -> i32 {
        let v = self.acc;
        self.acc = 0;
        v
    }
}

/// Mixed PE: a PE plus pooling support.
#[derive(Debug, Clone, Default)]
pub struct Mpe {
    pub pe: Pe,
    pub pool_ops: u64,
}

impl Mpe {
    pub fn new() -> Self {
        Self::default()
    }

    /// Max-pool a window (1 element/cycle on the chip).
    pub fn max_pool(&mut self, window: &[i32]) -> i32 {
        self.pool_ops += window.len() as u64;
        *window.iter().max().expect("empty pool window")
    }

    /// Average-pool with round-half-up integer division (the shared
    /// [`crate::nn::avg_round`] formula).
    pub fn avg_pool(&mut self, window: &[i32]) -> i32 {
        self.pool_ops += window.len() as u64;
        let s: i64 = window.iter().map(|&v| v as i64).sum();
        crate::nn::avg_round(s, window.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_accumulates_exactly() {
        let mut pe = Pe::new();
        pe.preload(10);
        pe.mac(3, -2, 8);
        pe.mac(-5, 4, 8);
        assert_eq!(pe.drain(), 10 - 6 - 20);
        assert_eq!(pe.acc, 0);
        assert_eq!(pe.macs, 2);
    }

    #[test]
    fn mixed_precision_in_one_stream() {
        let mut pe = Pe::new();
        pe.preload(0);
        pe.mac(7, 3, 8);
        pe.mac(7, 1, 1);
        pe.mac(7, -1, 2);
        assert_eq!(pe.drain(), 21 + 7 - 7);
        assert_eq!(pe.cmul.segment_ops, 8 + 1 + 2);
    }

    #[test]
    fn mpe_pooling_semantics() {
        let mut mpe = Mpe::new();
        assert_eq!(mpe.max_pool(&[1, 9, -4]), 9);
        assert_eq!(mpe.avg_pool(&[1, 2]), 2); // round half up
        assert_eq!(mpe.avg_pool(&[-1, -2]), -1);
        assert_eq!(mpe.pool_ops, 3 + 2 + 2);
    }

    #[test]
    fn mpe_avg_matches_nn_pool() {
        let mut mpe = Mpe::new();
        let window = [1, 2, 4, 5];
        let expect = crate::nn::global_avgpool(&window, 4, 1)[0];
        assert_eq!(mpe.avg_pool(&window), expect);
    }
}
