//! Chip configuration: geometry, clocks, buffers.



/// SPad organization ablation (Fig. 2 / DESIGN.md): the paper's single
/// shared SPad per SPE vs an Eyeriss-v2-style private SPad per PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpadSharing {
    /// Paper: one SPad read feeds all 16 lanes of the SPE.
    Shared,
    /// Baseline: every PE fetches from its own SPad (16× the reads,
    /// plus per-PE FIFO energy and asynchronous control overhead).
    PerPe,
}

/// Static description of one accelerator configuration.
#[derive(Debug, Clone)]
pub struct ChipConfig {
    /// Core elements (input-channel parallelism), paper: 2.
    pub n: usize,
    /// Computing cores (ofmap width parallelism), paper: 4.
    pub w: usize,
    /// SPEs per core (ofmap height parallelism), paper: 4.
    pub h: usize,
    /// PE lanes per SPE (output-channel parallelism), paper: 16
    /// (12 PEs + 4 MPEs).
    pub m: usize,
    /// Plain PEs per SPE (paper: 12).
    pub pes_per_spe: usize,
    /// Mixed PEs (pooling-capable) per SPE (paper: 4).
    pub mpes_per_spe: usize,
    /// Core clock (paper: 400 MHz).
    pub freq_hz: f64,
    /// Supply voltage (paper: 1.14 V).
    pub voltage: f64,
    /// Which fraction of the array a workload may engage: the 1-D CNN
    /// demo uses only 1 of the 4 computing cores → 128 of 512 PEs.
    pub cores_engaged: usize,
    /// SPad organization (ablation knob).
    pub spad_sharing: SpadSharing,
    /// Shared SPad capacity per SPE in bytes (activation tile storage).
    pub spad_bytes: usize,
    /// On-chip weight buffer in bytes (holds compressed weights +
    /// select signals for the whole network: the 1-D model fits).
    pub weight_buf_bytes: usize,
    /// Whether zero weights are skipped (select-signal datapath). The
    /// chip always skips; `false` models a dense equivalent for
    /// ablations.
    pub zero_skip: bool,
}

impl ChipConfig {
    /// The fabricated configuration (Table 1 column "Our Work").
    pub fn paper() -> Self {
        Self {
            n: 2,
            w: 4,
            h: 4,
            m: 16,
            pes_per_spe: 12,
            mpes_per_spe: 4,
            freq_hz: 400e6,
            voltage: 1.14,
            cores_engaged: 4,
            spad_sharing: SpadSharing::Shared,
            spad_bytes: 2048,
            weight_buf_bytes: 128 * 1024,
            zero_skip: true,
        }
    }

    /// The 1-D CNN demo engagement: 1 of 4 computing cores → 128 PEs
    /// (paper §3: "only 128 PEs are engaged in this 1D CNN inference").
    pub fn paper_1d() -> Self {
        Self { cores_engaged: 1, ..Self::paper() }
    }

    /// Total fabricated PE lanes (512 for the paper config).
    pub fn total_pes(&self) -> usize {
        self.n * self.w * self.h * self.m
    }

    /// PE lanes engaged by the current workload mapping.
    pub fn engaged_pes(&self) -> usize {
        self.n * self.cores_engaged * self.h * self.m
    }

    /// SPEs engaged (each SPE = `m` lanes).
    pub fn engaged_spes(&self) -> usize {
        self.engaged_pes() / self.m
    }

    /// Output positions computed in parallel: one per engaged SPE
    /// (each SPE's 16 lanes cover 16 output channels of one position).
    pub fn parallel_positions(&self) -> usize {
        self.engaged_spes()
    }

    /// Clock period in seconds.
    pub fn cycle_s(&self) -> f64 {
        1.0 / self.freq_hz
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pes_per_spe + self.mpes_per_spe == self.m,
                      "PE+MPE per SPE must equal M");
        anyhow::ensure!(self.cores_engaged >= 1 && self.cores_engaged <= self.w,
                      "cores_engaged out of range");
        anyhow::ensure!(self.freq_hz > 0.0 && self.voltage > 0.0, "bad clocks");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let c = ChipConfig::paper();
        assert_eq!(c.total_pes(), 512);
        assert_eq!(c.engaged_pes(), 512);
        assert_eq!(c.engaged_spes(), 32);
        c.validate().unwrap();
    }

    #[test]
    fn paper_1d_engages_128() {
        let c = ChipConfig::paper_1d();
        assert_eq!(c.total_pes(), 512);
        assert_eq!(c.engaged_pes(), 128);
        assert_eq!(c.engaged_spes(), 8);
        assert_eq!(c.parallel_positions(), 8);
    }

    #[test]
    fn validate_rejects_bad_spe_split() {
        let mut c = ChipConfig::paper();
        c.pes_per_spe = 10;
        assert!(c.validate().is_err());
    }
}
