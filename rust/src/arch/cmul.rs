//! CMUL — the mixed-bit signed reconfigurable multiplier (Fig. 3).
//!
//! The weight is split into 1-bit segments; each segment selects
//! (via MUX) the input activation or zero, the partial products are
//! shifted by their bit index and accumulated, and the top segment
//! enters negatively (two's complement). One CMUL contains 8 segment
//! slices, so per cycle it completes `8 / nbits` multiplies at
//! `nbits` precision — the architectural source of the paper's
//! "adaptively select operands for different precision requirements,
//! enhancing both energy efficiency and performance".
//!
//! `nbits == 1` is the ternary sign-magnitude mode (multiply by ±1).

/// Hardware segment slices per CMUL (8 → native 8-bit weights).
pub const CMUL_SEGMENTS: u32 = 8;

/// Functional model: multiply `act` by an `nbits`-wide signed weight
/// through the segment datapath. Must equal `act * w` exactly — the
/// decomposition is an identity (verified by tests + used as the chip
/// simulator's datapath so any modeling bug breaks bit-exactness
/// against the golden model).
#[inline]
pub fn cmul_multiply(act: i32, w: i32, nbits: u32) -> i32 {
    debug_assert!(matches!(nbits, 1 | 2 | 4 | 8), "unsupported precision");
    if nbits == 1 {
        // ternary sign-magnitude: one positive and one negative plane
        return match w {
            0 => 0,
            x if x > 0 => act,
            _ => -act,
        };
    }
    let mask = (1i32 << nbits) - 1;
    let u = w & mask; // two's-complement bit pattern of the weight
    let mut acc = 0i32;
    for b in 0..nbits {
        let bit = (u >> b) & 1;
        let pp = act * bit; // MUX: activation or zero
        if b == nbits - 1 {
            acc -= pp << b; // top segment is negative
        } else {
            acc += pp << b;
        }
    }
    acc
}

/// Segment operations consumed by one multiply at this precision
/// (each segment slice toggles once; the energy model charges per
/// segment op).
#[inline]
pub fn cmul_segments(nbits: u32) -> u32 {
    match nbits {
        1 => 1, // single ±1 select
        b => b,
    }
}

/// Multiplies completed per CMUL per cycle at this precision.
#[inline]
pub fn macs_per_cycle(nbits: u32) -> u32 {
    CMUL_SEGMENTS / cmul_segments(nbits).max(1)
}

/// Stateful CMUL wrapper used by the PE model: tracks segment-op and
/// cycle accounting while producing exact products.
#[derive(Debug, Clone, Default)]
pub struct Cmul {
    pub segment_ops: u64,
    pub multiplies: u64,
}

impl Cmul {
    pub fn new() -> Self {
        Self::default()
    }

    /// One multiply through the segment datapath.
    ///
    /// Hot path note (EXPERIMENTS.md §Perf L3.3): the bit-plane
    /// decomposition is an arithmetic *identity* (proven exhaustively
    /// by the tests below), so the simulator computes the product
    /// directly and charges the segment counters — a debug assertion
    /// keeps the fast path honest against the datapath model.
    #[inline]
    pub fn multiply(&mut self, act: i32, w: i32, nbits: u32) -> i32 {
        self.segment_ops += cmul_segments(nbits) as u64;
        self.multiplies += 1;
        debug_assert_eq!(cmul_multiply(act, w, nbits), act * w);
        act * w
    }

    /// Cycles to drain `n` multiplies at `nbits` precision.
    pub fn cycles_for(n: u64, nbits: u32) -> u64 {
        n.div_ceil(macs_per_cycle(nbits) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_for_all_8bit_weights() {
        for w in -127i32..=127 {
            for act in [-127, -64, -1, 0, 1, 37, 127] {
                assert_eq!(cmul_multiply(act, w, 8), act * w, "act={act} w={w}");
            }
        }
    }

    #[test]
    fn exact_for_4_2_1_bit_ranges() {
        for (nbits, qmax) in [(4u32, 7i32), (2, 1), (1, 1)] {
            for w in -qmax..=qmax {
                for act in [-127, -3, 0, 5, 127] {
                    assert_eq!(cmul_multiply(act, w, nbits), act * w,
                               "nbits={nbits} act={act} w={w}");
                }
            }
        }
    }

    #[test]
    fn throughput_scales_with_precision() {
        assert_eq!(macs_per_cycle(8), 1);
        assert_eq!(macs_per_cycle(4), 2);
        assert_eq!(macs_per_cycle(2), 4);
        assert_eq!(macs_per_cycle(1), 8);
    }

    #[test]
    fn cycle_accounting_rounds_up() {
        assert_eq!(Cmul::cycles_for(10, 8), 10);
        assert_eq!(Cmul::cycles_for(10, 4), 5);
        assert_eq!(Cmul::cycles_for(9, 4), 5);
        assert_eq!(Cmul::cycles_for(9, 1), 2);
        assert_eq!(Cmul::cycles_for(0, 8), 0);
    }

    #[test]
    fn segment_energy_tracking() {
        let mut c = Cmul::new();
        c.multiply(5, -3, 8);
        c.multiply(5, 1, 2);
        c.multiply(5, -1, 1);
        assert_eq!(c.segment_ops, 8 + 2 + 1);
        assert_eq!(c.multiplies, 3);
    }
}
