//! The quantized network: loader for `artifacts/weights.bin` plus the
//! golden forward pass.

use std::fs::File;
use std::io::Read;
use std::path::Path;

use anyhow::{ensure, anyhow as eyre, Result};

use super::{conv1d_int, conv1d_int_into, global_avgpool, pad_same,
            pad_same_into, pad_same_requant_into, requant_slice};
use crate::sim::ScratchArena;

/// One quantized conv layer (mirror of `python/compile/model.IntLayer`).
#[derive(Debug, Clone)]
pub struct QLayer {
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
    pub relu: bool,
    /// CMUL precision for this layer (8/4/2/1).
    pub nbits: u32,
    /// Requant right-shift (0 on the head layer = no requant).
    pub shift: u32,
    /// Input/output activation scales (float metadata, not on the
    /// integer path; used for reporting).
    pub s_in: f64,
    pub s_out: f64,
    /// Quantized weights `[K, Cin, Cout]` row-major; zeros = pruned.
    pub w: Vec<i32>,
    pub bias: Vec<i32>,
    /// Per-channel fixed-point requant multipliers.
    pub m0: Vec<i32>,
}

impl QLayer {
    /// Non-zero weight count (what the sparse datapath actually pays).
    pub fn nnz(&self) -> usize {
        self.w.iter().filter(|&&v| v != 0).count()
    }

    /// Weight sparsity fraction.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.w.len() as f64
    }

    /// Non-zero weights per output channel (PE-lane workloads).
    pub fn lane_nnz(&self) -> Vec<usize> {
        let mut lanes = vec![0usize; self.cout];
        for (i, &v) in self.w.iter().enumerate() {
            if v != 0 {
                lanes[i % self.cout] += 1;
            }
        }
        lanes
    }
}

/// Aggregate statistics used in reports and benches.
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub params: usize,
    pub nnz: usize,
    pub sparsity: f64,
    pub macs_dense: u64,
    pub macs_nnz: u64,
}

/// The full quantized model.
#[derive(Debug, Clone)]
pub struct QuantModel {
    pub layers: Vec<QLayer>,
}

impl QuantModel {
    /// Parse `artifacts/weights.bin` (format: `python/compile/artifact.py`).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut buf = Vec::new();
        File::open(path.as_ref())
            .map_err(|e| eyre!("open {}: {e} — run `make artifacts` first",
                               path.as_ref().display()))?
            .read_to_end(&mut buf)?;
        ensure!(buf.len() > 12 && &buf[..4] == b"VACM", "bad weights.bin magic");
        let mut off = 4usize;
        let rd_u32 = |buf: &[u8], off: &mut usize| -> Result<u32> {
            ensure!(buf.len() >= *off + 4, "truncated weights.bin");
            let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
            *off += 4;
            Ok(v)
        };
        let rd_f64 = |buf: &[u8], off: &mut usize| -> Result<f64> {
            ensure!(buf.len() >= *off + 8, "truncated weights.bin");
            let v = f64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
            *off += 8;
            Ok(v)
        };
        let version = rd_u32(&buf, &mut off)?;
        ensure!(version == 2, "unsupported weights.bin version {version}");
        let n_layers = rd_u32(&buf, &mut off)? as usize;
        ensure!(n_layers >= 1 && n_layers <= 64, "implausible layer count");
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let k = rd_u32(&buf, &mut off)? as usize;
            let stride = rd_u32(&buf, &mut off)? as usize;
            let cin = rd_u32(&buf, &mut off)? as usize;
            let cout = rd_u32(&buf, &mut off)? as usize;
            let relu = rd_u32(&buf, &mut off)? != 0;
            let nbits = rd_u32(&buf, &mut off)?;
            let shift = rd_u32(&buf, &mut off)?;
            let s_in = rd_f64(&buf, &mut off)?;
            let s_out = rd_f64(&buf, &mut off)?;
            ensure!(matches!(nbits, 1 | 2 | 4 | 8), "bad nbits {nbits}");
            let nw = k * cin * cout;
            ensure!(buf.len() >= off + nw + 8 * cout, "truncated layer data");
            let w: Vec<i32> = buf[off..off + nw].iter().map(|&b| b as i8 as i32).collect();
            off += nw;
            let mut bias = Vec::with_capacity(cout);
            for i in 0..cout {
                bias.push(i32::from_le_bytes(
                    buf[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
            }
            off += 4 * cout;
            let mut m0 = Vec::with_capacity(cout);
            for i in 0..cout {
                m0.push(i32::from_le_bytes(
                    buf[off + 4 * i..off + 4 * i + 4].try_into().unwrap()));
            }
            off += 4 * cout;
            layers.push(QLayer { k, stride, cin, cout, relu, nbits, shift,
                                 s_in, s_out, w, bias, m0 });
        }
        ensure!(off == buf.len(), "trailing bytes in weights.bin");
        let model = Self { layers };
        model.validate()?;
        Ok(model)
    }

    /// Structural sanity: chained channel counts, head geometry.
    pub fn validate(&self) -> Result<()> {
        for win in self.layers.windows(2) {
            ensure!(win[0].cout == win[1].cin,
                    "layer channel mismatch {} -> {}", win[0].cout, win[1].cin);
        }
        let head = self.layers.last().ok_or_else(|| eyre!("empty model"))?;
        ensure!(!head.relu, "head layer must be linear");
        Ok(())
    }

    /// Golden forward pass: int8-range input `[REC_LEN]` → int32 logits
    /// `[cout_head]` (global-avg-pooled head accumulator). Bit-exact
    /// with the AOT'd XLA module and the chip simulator.
    pub fn forward(&self, x: &[i8]) -> Vec<i32> {
        let mut a: Vec<i32> = x.iter().map(|&v| v as i32).collect();
        // x is [L, Cin] row-major; the production model has Cin = 1
        let cin0 = self.layers[0].cin;
        assert_eq!(a.len() % cin0, 0, "input not a whole number of samples");
        let mut l = a.len() / cin0;
        let mut scratch = Vec::new();
        let n = self.layers.len();
        for (i, ly) in self.layers.iter().enumerate() {
            let padded = pad_same(&a, l, ly.cin, ly.k, ly.stride);
            let lp = padded.len() / ly.cin;
            let acc = conv1d_int(&padded, lp, ly.cin, &ly.w, ly.k, ly.cout,
                                 &ly.bias, ly.stride);
            l = (lp - ly.k) / ly.stride + 1;
            if i < n - 1 {
                requant_slice(&acc, &ly.m0, ly.shift, ly.relu, &mut scratch);
                std::mem::swap(&mut a, &mut scratch);
            } else {
                a = acc;
            }
        }
        global_avgpool(&a, l, self.layers[n - 1].cout)
    }

    /// [`Self::forward`] over a caller-owned [`ScratchArena`]: the
    /// fleet-competitive golden twin. Uses the arena's `act`/`padded`/
    /// `out` slabs (row-major throughout — the golden path never sees
    /// the simulator's tile-major stripes) so a hot serving loop
    /// allocates only the returned logits per recording. The requant
    /// drain is fused into each layer's padding stage
    /// ([`pad_same_requant_into`] reads the previous layer's conv
    /// accumulators straight out of `out`), so no requantized
    /// intermediate feature map is materialized between layers; `act`
    /// holds only the network input. Kept as a separate implementation
    /// from [`Self::forward`] on purpose — `tests/layout_arena.rs`
    /// pins the two bit-identical, and a shared body would make that
    /// check tautological.
    pub fn forward_scratch(&self, x: &[i8], s: &mut ScratchArena) -> Vec<i32> {
        let ScratchArena { act, padded, out, .. } = s;
        act.clear();
        act.extend(x.iter().map(|&v| v as i32));
        let cin0 = self.layers[0].cin;
        assert_eq!(act.len() % cin0, 0, "input not a whole number of samples");
        let mut l = act.len() / cin0;
        let n = self.layers.len();
        for (i, ly) in self.layers.iter().enumerate() {
            if i == 0 {
                pad_same_into(act, l, ly.cin, ly.k, ly.stride, padded);
            } else {
                // fused requant drain: the previous layer's int32
                // accumulators (still in `out`) requantize straight
                // into this layer's padded window buffer
                let prev = &self.layers[i - 1];
                pad_same_requant_into(out, l, ly.cin, ly.k, ly.stride,
                                      &prev.m0, prev.shift, prev.relu,
                                      padded);
            }
            let lp = padded.len() / ly.cin;
            conv1d_int_into(padded, lp, ly.cin, &ly.w, ly.k, ly.cout,
                            &ly.bias, ly.stride, out);
            l = (lp - ly.k) / ly.stride + 1;
        }
        global_avgpool(out, l, self.layers[n - 1].cout)
    }

    /// Predicted class ([`super::argmax`]: ties break to the lower
    /// index = non-VA, the conservative choice, matching jnp argmax).
    pub fn predict(&self, x: &[i8]) -> usize {
        super::argmax(&self.forward(x))
    }

    /// Dense and sparse MAC accounting per layer for an input of
    /// `l_in` samples.
    pub fn stats(&self, l_in: usize) -> ModelStats {
        let mut l = l_in;
        let mut macs_dense = 0u64;
        let mut macs_nnz = 0u64;
        for ly in &self.layers {
            let lo = l / ly.stride;
            macs_dense += (lo * ly.k * ly.cin * ly.cout) as u64;
            // each output position pays only the non-zero weights
            macs_nnz += (lo * ly.nnz()) as u64;
            l = lo;
        }
        let params: usize = self.layers.iter().map(|l| l.w.len()).sum();
        let nnz: usize = self.layers.iter().map(|l| l.nnz()).sum();
        ModelStats {
            params,
            nnz,
            sparsity: 1.0 - nnz as f64 / params as f64,
            macs_dense,
            macs_nnz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> QuantModel {
        // 2 layers: k1 s1 1->2 relu, then head k1 s1 2->2
        QuantModel {
            layers: vec![
                QLayer { k: 1, stride: 1, cin: 1, cout: 2, relu: true,
                         nbits: 8, shift: 24, s_in: 1.0, s_out: 1.0,
                         w: vec![2, -3], bias: vec![1, 1],
                         m0: vec![1 << 24, 1 << 24] },
                QLayer { k: 1, stride: 1, cin: 2, cout: 2, relu: false,
                         nbits: 8, shift: 0, s_in: 1.0, s_out: 1.0,
                         w: vec![1, 0, 0, 1], bias: vec![0, 0],
                         m0: vec![0, 0] },
            ],
        }
    }

    #[test]
    fn tiny_forward_by_hand() {
        let m = tiny_model();
        // x = [3, -1]: layer1 ch0 = 2x+1, ch1 = -3x+1, relu
        // x=3  -> (7, 0) ; x=-1 -> (0, 4)
        // head identity; global avg: ch0 (7+0+1)/2=4, ch1 (0+4+1)/2=2
        let got = m.forward(&[3, -1]);
        assert_eq!(got, vec![4, 2]);
        assert_eq!(m.predict(&[3, -1]), 0);
    }

    #[test]
    fn forward_scratch_matches_forward_with_reused_arena() {
        let m = tiny_model();
        let mut s = crate::sim::ScratchArena::new();
        for x in [[3i8, -1], [-7, 7], [0, 0], [127, -127]] {
            assert_eq!(m.forward_scratch(&x, &mut s), m.forward(&x));
        }
    }

    #[test]
    fn stats_counts_sparsity() {
        let m = tiny_model();
        let s = m.stats(4);
        assert_eq!(s.params, 6);
        assert_eq!(s.nnz, 4);
        // layer1 dense: 4*1*1*2=8 ; head: 4*1*2*2=16
        assert_eq!(s.macs_dense, 24);
        // layer1 nnz 2 -> 8 ; head nnz 2 -> 8
        assert_eq!(s.macs_nnz, 16);
    }

    #[test]
    fn lane_nnz_layout() {
        let ly = &tiny_model().layers[1];
        assert_eq!(ly.lane_nnz(), vec![1, 1]);
        assert!((ly.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_mismatch() {
        let mut m = tiny_model();
        m.layers[1].cin = 3;
        assert!(m.validate().is_err());
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let p = std::path::Path::new(crate::ARTIFACT_DIR).join("weights.bin");
        if let Ok(m) = QuantModel::load(&p) {
            assert_eq!(m.layers.len(), 8);
            let s = m.stats(crate::REC_LEN);
            assert!(s.sparsity > 0.45 && s.sparsity < 0.55,
                    "network sparsity {}", s.sparsity);
            assert_eq!(m.layers[0].cin, 1);
            assert_eq!(m.layers.last().unwrap().cout, 2);
        }
    }
}
