//! Integer 1-D convolution (golden reference) and the **fused
//! requant+staging** reads that make layer outputs the interchange
//! format between layers (DESIGN.md §"Data layout contract").
//!
//! Layout convention (shared with the python kernels): activations are
//! `[L, Cin]` row-major (`a[l * cin + c]`), weights `[K, Cin, Cout]`
//! row-major (`w[(k * cin + ci) * cout + co]`), accumulators
//! `[Lout, Cout]` row-major. The simulator paths additionally use the
//! tile-major stripe layout described by
//! [`crate::compiler::TileStripe`]; [`pad_same_from_stripes`] reads it
//! directly, requantizing on the way into the padded window buffer, so
//! no row-major intermediate feature map is ever materialized between
//! conv layers.

use crate::compiler::TileStripe;

use super::requant::requant;

/// 'same'-style zero padding so `Lout = L / stride` (python
/// `model.pad_amount`): total `k - stride`, split left-biased-low.
pub fn pad_same(a: &[i32], l: usize, cin: usize, k: usize, stride: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity((l + k - stride) * cin);
    pad_same_into(a, l, cin, k, stride, &mut out);
    out
}

/// [`pad_same`] into a caller-owned buffer: allocation-free once the
/// buffer's capacity covers the padded footprint (the simulator's
/// scratch arena reserves it up front).
pub fn pad_same_into(a: &[i32], l: usize, cin: usize, k: usize,
                     stride: usize, out: &mut Vec<i32>) {
    let p = k - stride;
    let (pl, pr) = (p / 2, p - p / 2);
    out.clear();
    out.resize(pl * cin, 0);
    out.extend_from_slice(&a[..l * cin]);
    out.resize((pl + l + pr) * cin, 0);
}

/// Fused requant + 'same' padding over a **row-major** `[L, Cin]`
/// accumulator map: bit-exact with `requant_slice` followed by
/// [`pad_same_into`], in one pass and with no intermediate requantized
/// map. `acc` holds the producing layer's int32 conv accumulators
/// (its `Cout` == this read's `cin`); `m0`/`shift`/`relu` are the
/// producing layer's requant parameters. The golden arena twin
/// ([`crate::nn::QuantModel::forward_scratch`]) stages every
/// non-input layer through this.
#[allow(clippy::too_many_arguments)]
pub fn pad_same_requant_into(acc: &[i32], l: usize, cin: usize, k: usize,
                             stride: usize, m0: &[i32], shift: u32,
                             relu: bool, out: &mut Vec<i32>) {
    debug_assert_eq!(m0.len(), cin);
    let p = k - stride;
    let (pl, pr) = (p / 2, p - p / 2);
    out.clear();
    out.resize(pl * cin, 0);
    out.extend(acc[..l * cin].iter().enumerate()
        .map(|(i, &a)| requant(a, m0[i % cin], shift, relu)));
    out.resize((pl + l + pr) * cin, 0);
}

/// Fused requant + 'same' padding over a **tile-major stripe** layer
/// output (the simulator interchange format, see
/// [`crate::compiler::LayerSchedule`]): reads the producing layer's
/// disjoint `[lout, live]` column stripes directly and writes the
/// consuming layer's padded `[L, Cin]` window buffer, requantizing
/// each element on the way — the requant drain and the padding stage
/// are one pass, so no row-major intermediate feature map exists
/// between conv layers on any simulator path.
///
/// `stripes` is the producer's [`TileStripe`] table (carried across
/// the layer boundary on the consumer's
/// `LayerSchedule::in_stripes`), `out_prev` its stripe buffer, `l`
/// its output length (== this read's input length) and `cin` this
/// layer's input channels (== the producer's `Cout`);
/// `m0`/`shift`/`relu` are the producer's requant parameters.
/// Bit-exact with the pre-fusion composition (stripe requant-drain to
/// `[L, Cin]`, then [`pad_same_into`]): stripe disjointness means
/// every interior element is written exactly once, and the padding
/// margins stay zero from the resize.
#[allow(clippy::too_many_arguments)]
pub fn pad_same_from_stripes(stripes: &[TileStripe], out_prev: &[i32],
                             l: usize, cin: usize, k: usize, stride: usize,
                             m0: &[i32], shift: u32, relu: bool,
                             out: &mut Vec<i32>) {
    debug_assert_eq!(m0.len(), cin);
    let p = k - stride;
    let (pl, pr) = (p / 2, p - p / 2);
    out.clear();
    out.resize((pl + l + pr) * cin, 0);
    for st in stripes {
        let stripe = &out_prev[st.offset..st.offset + l * st.live];
        let lane_m0 = &m0[st.base_co..st.base_co + st.live];
        for (lo, row) in stripe.chunks_exact(st.live).enumerate() {
            let base = (pl + lo) * cin + st.base_co;
            let dst = &mut out[base..base + st.live];
            for (d, (&v, &m)) in dst.iter_mut().zip(row.iter().zip(lane_m0)) {
                *d = requant(v, m, shift, relu);
            }
        }
    }
}

/// Valid integer 1-D convolution: returns `[Lout, Cout]` accumulators,
/// `Lout = (L - K)/stride + 1`.
pub fn conv1d_int(a: &[i32], l: usize, cin: usize, w: &[i32], k: usize,
                  cout: usize, bias: &[i32], stride: usize) -> Vec<i32> {
    let mut out = Vec::new();
    conv1d_int_into(a, l, cin, w, k, cout, bias, stride, &mut out);
    out
}

/// [`conv1d_int`] into a caller-owned buffer: allocation-free once the
/// buffer's capacity covers `Lout · Cout` (the golden path's
/// `forward_scratch` reserves it through the shared arena).
#[allow(clippy::too_many_arguments)]
pub fn conv1d_int_into(a: &[i32], l: usize, cin: usize, w: &[i32], k: usize,
                       cout: usize, bias: &[i32], stride: usize,
                       out: &mut Vec<i32>) {
    debug_assert_eq!(a.len(), l * cin);
    debug_assert_eq!(w.len(), k * cin * cout);
    debug_assert_eq!(bias.len(), cout);
    let lout = (l - k) / stride + 1;
    out.clear();
    out.resize(lout * cout, 0);
    for lo in 0..lout {
        let base = lo * stride;
        let row = &mut out[lo * cout..(lo + 1) * cout];
        row.copy_from_slice(bias);
        for kk in 0..k {
            let arow = &a[(base + kk) * cin..(base + kk + 1) * cin];
            let wrow = &w[kk * cin * cout..(kk + 1) * cin * cout];
            for (ci, &av) in arow.iter().enumerate() {
                if av == 0 {
                    continue; // activation-side skip (exact, free in sw)
                }
                let wr = &wrow[ci * cout..(ci + 1) * cout];
                for (co, &wv) in wr.iter().enumerate() {
                    row[co] += av * wv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel() {
        // k=1, cin=1, cout=1, w=1: conv == input + bias
        let a = [3, -5, 7];
        let out = conv1d_int(&a, 3, 1, &[1], 1, 1, &[10], 1);
        assert_eq!(out, vec![13, 5, 17]);
    }

    #[test]
    fn known_small_case() {
        // L=4, Cin=1, K=2, Cout=1, stride=1: sliding dot product
        let a = [1, 2, 3, 4];
        let w = [10, 1]; // w[k=0]=10, w[k=1]=1
        let out = conv1d_int(&a, 4, 1, &w, 2, 1, &[0], 1);
        assert_eq!(out, vec![12, 23, 34]);
    }

    #[test]
    fn stride_two() {
        let a = [1, 2, 3, 4, 5];
        let w = [1, 1];
        let out = conv1d_int(&a, 5, 1, &w, 2, 1, &[0], 2);
        assert_eq!(out, vec![3, 7]);
    }

    #[test]
    fn multichannel_sums_inputs() {
        // cin=2: both channels contribute
        let a = [1, 10, 2, 20]; // l=2, cin=2
        let w = [1, 2]; // k=1, cin=2, cout=1: w[ci=0]=1, w[ci=1]=2
        let out = conv1d_int(&a, 2, 2, &w, 1, 1, &[0], 1);
        assert_eq!(out, vec![21, 42]);
    }

    #[test]
    fn multioutput_layout() {
        // k=1, cin=1, cout=2
        let a = [3, 4];
        let w = [1, -1]; // co=0 -> +, co=1 -> -
        let out = conv1d_int(&a, 2, 1, &w, 1, 2, &[0, 100], 1);
        assert_eq!(out, vec![3, 97, 4, 96]);
    }

    #[test]
    fn pad_same_geometry() {
        // k=7, stride=2 -> pad 5 = (2, 3)
        let a: Vec<i32> = (1..=4).collect();
        let p = pad_same(&a, 4, 1, 7, 2);
        assert_eq!(p, vec![0, 0, 1, 2, 3, 4, 0, 0, 0]);
        // k=1, stride=1 -> no pad
        assert_eq!(pad_same(&a, 4, 1, 1, 1), a);
    }

    #[test]
    fn pad_same_into_reuses_dirty_buffers() {
        // a previously-used (larger, non-zero) buffer must come out
        // identical to a fresh pad_same
        let a: Vec<i32> = (1..=6).collect();
        let mut buf = vec![99i32; 64];
        pad_same_into(&a, 3, 2, 5, 2, &mut buf); // pad 3 = (1, 2), cin 2
        assert_eq!(buf, pad_same(&a, 3, 2, 5, 2));
        pad_same_into(&a, 6, 1, 3, 1, &mut buf); // different geometry
        assert_eq!(buf, pad_same(&a, 6, 1, 3, 1));
    }

    #[test]
    fn conv_into_reuses_dirty_buffers() {
        // a previously-used (larger, non-zero) buffer must come out
        // identical to a fresh conv1d_int
        let a = [1, 2, 3, 4, 5];
        let w = [1, 1];
        let mut buf = vec![77i32; 32];
        conv1d_int_into(&a, 5, 1, &w, 2, 1, &[3], 2, &mut buf);
        assert_eq!(buf, conv1d_int(&a, 5, 1, &w, 2, 1, &[3], 2));
        conv1d_int_into(&a, 5, 1, &w, 2, 1, &[0], 1, &mut buf);
        assert_eq!(buf, conv1d_int(&a, 5, 1, &w, 2, 1, &[0], 1));
    }

    #[test]
    fn pad_same_requant_into_equals_requant_then_pad() {
        // the fused row-major read == requant_slice ∘ pad_same_into,
        // including on a dirty reused buffer
        let acc = [100, -300, 40, 260, -90, 7]; // l=3, cin=2
        let m0 = [1 << 24, 1 << 23]; // M = 1.0, 0.5
        for (k, stride, relu) in [(5usize, 2usize, true), (3, 1, false),
                                  (2, 2, true)] {
            let mut requanted = Vec::new();
            crate::nn::requant_slice(&acc, &m0, 24, relu, &mut requanted);
            let want = pad_same(&requanted, 3, 2, k, stride);
            let mut got = vec![55i32; 77]; // dirty + oversized
            pad_same_requant_into(&acc, 3, 2, k, stride, &m0, 24, relu,
                                  &mut got);
            assert_eq!(got, want, "k={k} stride={stride} relu={relu}");
        }
    }

    #[test]
    fn pad_same_from_stripes_equals_drain_then_pad() {
        // producer: lout=3, cout=5 in two stripes (live 4 + live 1 —
        // the ragged partial-stripe edge); consumer: k=3, stride=1
        let (l, cin) = (3usize, 5usize);
        let stripes = [TileStripe { base_co: 0, live: 4, offset: 0 },
                       TileStripe { base_co: 4, live: 1, offset: 12 }];
        // stripe buffer [ch_tile][lout][lane], packed
        let out_prev: Vec<i32> =
            (0..15).map(|i| (i as i32 - 7) * 37).collect();
        let m0: Vec<i32> = (0..5).map(|c| (1 << 23) + (c << 10)).collect();
        for (k, stride, relu) in [(3usize, 1usize, true), (2, 2, false),
                                  (5, 2, true)] {
            // pre-fusion composition: requant-drain to [L, Cin] ...
            let mut act = vec![0i32; l * cin];
            for st in &stripes {
                let stripe = &out_prev[st.offset..st.offset + l * st.live];
                for (lo, row) in stripe.chunks_exact(st.live).enumerate() {
                    for (lane, &v) in row.iter().enumerate() {
                        act[lo * cin + st.base_co + lane] =
                            requant(v, m0[st.base_co + lane], 24, relu);
                    }
                }
            }
            // ... then pad
            let want = pad_same(&act, l, cin, k, stride);
            let mut got = vec![-3i32; 99]; // dirty + oversized
            pad_same_from_stripes(&stripes, &out_prev, l, cin, k, stride,
                                  &m0, 24, relu, &mut got);
            assert_eq!(got, want, "k={k} stride={stride} relu={relu}");
        }
    }

    #[test]
    fn zero_activation_skip_is_exact() {
        // the av==0 early-out must not change results
        let a = [0, 5, 0, -3];
        let w = [2, 3];
        let full: i64 = conv1d_int(&a, 4, 1, &w, 2, 1, &[7], 1)
            .iter().map(|&v| v as i64).sum();
        assert_eq!(full, (0 * 2 + 5 * 3 + 7) as i64
                       + (5 * 2 + 0 * 3 + 7) as i64
                       + (0 * 2 + -3 * 3 + 7) as i64);
    }
}
