//! Fixed-point requantization — rust half of the shared contract
//! (`python/compile/quantize.py`). Golden vectors are duplicated in
//! both test suites; any change must be made in both places.

/// Activation quantization range (symmetric, -128 excluded so the
/// CMUL's 8-bit negate is safe).
pub const QMIN: i32 = -127;
/// See [`QMIN`].
pub const QMAX: i32 = 127;

/// Requantize one int32 accumulator to the next layer's int8 range:
/// `clamp(round_half_up((acc * m0) >> shift))` with an int64
/// intermediate and optional fused ReLU.
#[inline(always)]
pub fn requant(acc: i32, m0: i32, shift: u32, relu: bool) -> i32 {
    let t = (acc as i64) * (m0 as i64);
    let mut r = (t + (1i64 << (shift - 1))) >> shift;
    if relu && r < 0 {
        r = 0;
    }
    r.clamp(QMIN as i64, QMAX as i64) as i32
}

/// Requantize a channel-major slice in place:
/// `acc[l * cout + co]` with per-channel multipliers `m0[co]`.
pub fn requant_slice(acc: &[i32], m0: &[i32], shift: u32, relu: bool,
                     out: &mut Vec<i32>) {
    let cout = m0.len();
    debug_assert_eq!(acc.len() % cout, 0);
    out.clear();
    out.reserve(acc.len());
    for (i, &a) in acc.iter().enumerate() {
        out.push(requant(a, m0[i % cout], shift, relu));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_vectors_match_python() {
        // python/tests/test_quantize.py::test_requant_golden_vectors
        let m0 = 1 << 23; // M = 0.5 at shift 24
        let cases = [(5, 3), (-5, -2), (3, 2), (-3, -1), (254, 127),
                     (-254, -127), (255, 127), (-255, -127)];
        for (acc, want) in cases {
            assert_eq!(requant(acc, m0, 24, false), want, "acc={acc}");
        }
    }

    #[test]
    fn relu_clamps_negative() {
        let m0 = 1 << 24; // M = 1.0
        assert_eq!(requant(-10, m0, 24, true), 0);
        assert_eq!(requant(0, m0, 24, true), 0);
        assert_eq!(requant(10, m0, 24, true), 10);
    }

    #[test]
    fn saturates_at_qrange() {
        let m0 = 1 << 24;
        assert_eq!(requant(1_000_000, m0, 24, false), QMAX);
        assert_eq!(requant(-1_000_000, m0, 24, false), QMIN);
    }

    #[test]
    fn rounding_is_half_up() {
        // M = 0.5: 1 -> 0.5 -> 1 (half rounds toward +inf)
        let m0 = 1 << 23;
        assert_eq!(requant(1, m0, 24, false), 1);
        assert_eq!(requant(-1, m0, 24, false), 0);
    }

    #[test]
    fn monotonic_in_accumulator() {
        let m0 = 12_345_678;
        let mut prev = i32::MIN;
        for acc in -3000..3000 {
            let r = requant(acc, m0, 24, false);
            assert!(r >= prev);
            prev = r;
        }
    }

    #[test]
    fn slice_layout_per_channel() {
        let acc = [100, 200, 100, 200];
        let m0 = [1 << 24, 1 << 23]; // M = 1.0, 0.5
        let mut out = Vec::new();
        requant_slice(&acc, &m0, 24, false, &mut out);
        assert_eq!(out, vec![100, 100, 100, 100]);
    }
}
