//! Diagnosis voting (paper: "the inference results from 6 recordings
//! are aggregated through voting to obtain a diagnosis").

/// Outcome of one vote group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VoteResult {
    /// Final diagnosis: is this episode a ventricular arrhythmia?
    pub is_va: bool,
    /// Positive (VA) votes in the group.
    pub va_votes: usize,
    /// Group size.
    pub total: usize,
}

/// Argmax with ties breaking to the **lower** index. Index 0 is the
/// non-VA class everywhere in this stack, so the tie break is the
/// conservative clinical choice (and matches jnp argmax). The single
/// shared implementation — `QuantModel::predict`, both simulator
/// engines and the detection path all route through here (it used to
/// be hand-rolled in each).
pub fn argmax(v: &[i32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Strict-majority vote over per-recording binary predictions.
/// Ties (possible only for even group sizes) resolve to **non-VA**:
/// an ICD must not shock on an ambiguous episode.
pub fn majority_vote(predictions: &[bool]) -> VoteResult {
    let va_votes = predictions.iter().filter(|&&p| p).count();
    VoteResult {
        is_va: 2 * va_votes > predictions.len(),
        va_votes,
        total: predictions.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unanimous() {
        assert!(majority_vote(&[true; 6]).is_va);
        assert!(!majority_vote(&[false; 6]).is_va);
    }

    #[test]
    fn majority_thresholds() {
        assert!(majority_vote(&[true, true, true, true, false, false]).is_va);
        assert!(!majority_vote(&[true, true, true, false, false, false]).is_va,
                "3/6 tie must resolve to non-VA");
        assert!(!majority_vote(&[true, true, false, false, false, false]).is_va);
    }

    #[test]
    fn odd_group() {
        assert!(majority_vote(&[true, true, false]).is_va);
        assert!(!majority_vote(&[true, false, false]).is_va);
    }

    #[test]
    fn counts_reported() {
        let v = majority_vote(&[true, false, true]);
        assert_eq!(v.va_votes, 2);
        assert_eq!(v.total, 3);
    }

    #[test]
    fn empty_group_is_non_va() {
        assert!(!majority_vote(&[]).is_va);
    }

    #[test]
    fn argmax_ties_to_lower_index() {
        assert_eq!(argmax(&[5, 3]), 0);
        assert_eq!(argmax(&[3, 5]), 1);
        assert_eq!(argmax(&[7, 7]), 0, "tie must stay non-VA");
        assert_eq!(argmax(&[-2, -2, -1, -1]), 2);
        assert_eq!(argmax(&[42]), 0);
        assert_eq!(argmax(&[]), 0, "degenerate input defaults to class 0");
    }
}
