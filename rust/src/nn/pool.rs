//! MPE pooling operations (max / average) with the chip's integer
//! rounding semantics.

/// Round-half-up integer average `(sum + n/2) div n` (python floor
/// division) — THE rounding formula shared by every averaging path:
/// [`avgpool1d`], [`global_avgpool`], `arch::Mpe::avg_pool` and the
/// simulator's fast readout, so they cannot drift apart.
#[inline]
pub fn avg_round(sum: i64, n: usize) -> i32 {
    ((sum + (n / 2) as i64).div_euclid(n as i64)) as i32
}

/// Max pooling along L: `[L, C] -> [L/pool, C]` (trailing remainder
/// dropped, as on the chip).
pub fn maxpool1d(a: &[i32], l: usize, c: usize, pool: usize) -> Vec<i32> {
    let lo = l / pool;
    let mut out = vec![i32::MIN; lo * c];
    for o in 0..lo {
        for p in 0..pool {
            let row = &a[(o * pool + p) * c..(o * pool + p + 1) * c];
            let orow = &mut out[o * c..(o + 1) * c];
            for (dst, &v) in orow.iter_mut().zip(row) {
                if v > *dst {
                    *dst = v;
                }
            }
        }
    }
    out
}

/// Average pooling with round-half-up integer division:
/// `(sum + pool/2) / pool` (python `avgpool1d_ref`).
pub fn avgpool1d(a: &[i32], l: usize, c: usize, pool: usize) -> Vec<i32> {
    let lo = l / pool;
    let mut out = vec![0i32; lo * c];
    for o in 0..lo {
        for p in 0..pool {
            let row = &a[(o * pool + p) * c..(o * pool + p + 1) * c];
            let orow = &mut out[o * c..(o + 1) * c];
            for (dst, &v) in orow.iter_mut().zip(row) {
                *dst += v;
            }
        }
    }
    for v in &mut out {
        *v = avg_round(*v as i64, pool);
    }
    out
}

/// Global average over L with round-half-up: `[L, C] -> [C]`.
pub fn global_avgpool(a: &[i32], l: usize, c: usize) -> Vec<i32> {
    let mut out = vec![0i64; c];
    for lo in 0..l {
        for ci in 0..c {
            out[ci] += a[lo * c + ci] as i64;
        }
    }
    out.iter().map(|&s| avg_round(s, l)).collect()
}

/// [`global_avgpool`] straight off a **tile-major stripe** head layer
/// output (the simulator interchange format): ONE position-major
/// streaming pass per stripe — each `[len, live]` stripe is read
/// contiguously front to back, rows accumulating into the stripe's
/// channel sums — instead of the per-lane strided walk (`live`-strided
/// gathers per channel) the fast readout previously performed.
/// Rounding is the shared [`avg_round`] formula, and per channel the
/// elements accumulate in the same position order as the strided walk
/// (and as `Mpe::avg_pool` on a drained column), so the three are
/// bit-exact; `tests/packed_streams.rs` pins the positional pass
/// against the strided walk, partial `live < m` stripes included.
pub fn global_avgpool_stripes(stripes: &[crate::compiler::TileStripe],
                              out: &[i32], len: usize, cout: usize)
                              -> Vec<i32> {
    let mut sums = vec![0i64; cout];
    for st in stripes {
        let stripe = &out[st.offset..st.offset + len * st.live];
        let dst = &mut sums[st.base_co..st.base_co + st.live];
        for row in stripe.chunks_exact(st.live) {
            for (d, &v) in dst.iter_mut().zip(row) {
                *d += v as i64;
            }
        }
    }
    sums.into_iter().map(|s| avg_round(s, len)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_basic() {
        let a = [1, -1, 5, 2, 3, 9, 0, 0]; // l=4, c=2
        assert_eq!(maxpool1d(&a, 4, 2, 2), vec![5, 2, 3, 9]);
    }

    #[test]
    fn avgpool_rounds_half_up() {
        // python floor-div semantics: (1+2+1)//2 = 2 ; (-1-2+1)//2 = -1
        let a = [1, 2];
        assert_eq!(avgpool1d(&a, 2, 1, 2), vec![2]);
        let b = [-1, -2];
        assert_eq!(avgpool1d(&b, 2, 1, 2), vec![-1]);
    }

    #[test]
    fn global_avgpool_matches_python_semantics() {
        // python: (s + l//2) // l with floor division
        let a = [1, 2, 4, 5]; // l=4, c=1 -> (12+2)//4 = 3
        assert_eq!(global_avgpool(&a, 4, 1), vec![3]);
        let b = [-1, -2, -4, -5]; // (-12+2)//4 = floor(-2.5) = -3
        assert_eq!(global_avgpool(&b, 4, 1), vec![-3]);
    }

    #[test]
    fn remainder_dropped() {
        let a = [1, 2, 3, 4, 5];
        assert_eq!(maxpool1d(&a, 5, 1, 2), vec![2, 4]);
    }

    #[test]
    fn stripe_pooling_equals_rowmajor_pooling() {
        // cout 5 in two stripes (live 4 + live 1): pooling the stripes
        // positionally must equal draining to [L, C] row-major and
        // running global_avgpool
        use crate::compiler::TileStripe;
        let (len, cout) = (3usize, 5usize);
        let stripes = [TileStripe { base_co: 0, live: 4, offset: 0 },
                       TileStripe { base_co: 4, live: 1, offset: 12 }];
        let buf: Vec<i32> = (0..15).map(|i| (i - 7) * 31).collect();
        let mut rowmajor = vec![0i32; len * cout];
        for st in &stripes {
            let stripe = &buf[st.offset..st.offset + len * st.live];
            for (lo, row) in stripe.chunks_exact(st.live).enumerate() {
                for (lane, &v) in row.iter().enumerate() {
                    rowmajor[lo * cout + st.base_co + lane] = v;
                }
            }
        }
        assert_eq!(global_avgpool_stripes(&stripes, &buf, len, cout),
                   global_avgpool(&rowmajor, len, cout));
    }
}
