//! Integer golden model: a bit-exact software reference for the chip.
//!
//! Implements the quantized 8-layer 1-D CNN with the shared fixed-point
//! contract (`python/compile/quantize.py` ⇄ `requant.rs`). Three other
//! execution paths must agree with this module **bit-exactly** on every
//! input: the AOT'd Pallas/XLA module run by [`crate::runtime`], the
//! cycle-accurate chip simulator [`crate::sim`], and the python
//! reference (audited at build time). Integration tests enforce all
//! three.

mod model;
mod pool;
mod qconv;
mod requant;
mod vote;

pub use model::{ModelStats, QLayer, QuantModel};
pub use pool::{avg_round, avgpool1d, global_avgpool,
               global_avgpool_stripes, maxpool1d};
pub use qconv::{conv1d_int, conv1d_int_into, pad_same,
                pad_same_from_stripes, pad_same_into, pad_same_requant_into};
pub use requant::{requant, requant_slice, QMAX, QMIN};
pub use vote::{argmax, majority_vote, VoteResult};
