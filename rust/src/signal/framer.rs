//! Stream framing: chop a continuous sample stream into fixed-length
//! recordings (the ICD samples continuously; the chip consumes
//! 512-sample windows).

use anyhow::Result;

/// Accumulates samples and emits complete frames of `frame_len`
/// samples, with an optional hop (`hop < frame_len` ⇒ overlapping
/// windows; `hop == frame_len` ⇒ back-to-back recordings, the paper's
/// mode).
///
/// Frames are consumed by index and the buffer is compacted once per
/// push, so a push that completes many frames costs one memmove of the
/// leftover tail — not one `frame_len`-sized memmove per frame.
#[derive(Debug, Clone)]
pub struct Framer {
    frame_len: usize,
    hop: usize,
    buf: Vec<f64>,
    /// Consumed prefix of `buf` (start of the next frame). Always 0
    /// between calls — `push` compacts before returning.
    pos: usize,
}

impl Framer {
    /// Infallible constructor for internally-chosen geometry (fixtures,
    /// paper defaults). Panics on `hop` outside `1..=frame_len`; the
    /// serving path takes caller-supplied hops through [`try_new`]
    /// instead.
    ///
    /// [`try_new`]: Framer::try_new
    pub fn new(frame_len: usize, hop: usize) -> Self {
        Self::try_new(frame_len, hop).unwrap()
    }

    /// Checked constructor for caller-supplied geometry (CLI/serving):
    /// errors — instead of panicking the process — on `hop` outside
    /// `1..=frame_len` or a zero `frame_len`.
    pub fn try_new(frame_len: usize, hop: usize) -> Result<Self> {
        anyhow::ensure!(frame_len >= 1, "frame_len must be >= 1");
        anyhow::ensure!(hop >= 1 && hop <= frame_len,
                        "hop {hop} outside 1..={frame_len}");
        Ok(Self { frame_len, hop, buf: Vec::with_capacity(2 * frame_len),
                  pos: 0 })
    }

    /// Paper configuration: non-overlapping 512-sample recordings.
    pub fn recordings() -> Self {
        Self::new(crate::REC_LEN, crate::REC_LEN)
    }

    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn hop(&self) -> usize {
        self.hop
    }

    /// Push samples; returns every complete frame that became ready.
    pub fn push(&mut self, samples: &[f64]) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        self.push_with(samples, |frame| out.push(frame.to_vec()));
        out
    }

    /// Visitor form of [`push`](Framer::push): each completed frame is
    /// handed to `emit` as a borrowed slice, so callers that only read
    /// the frame (filter + quantize, tests' oracles) skip the per-frame
    /// allocation entirely.
    pub fn push_with(&mut self, samples: &[f64],
                     mut emit: impl FnMut(&[f64])) {
        self.buf.extend_from_slice(samples);
        while self.buf.len() - self.pos >= self.frame_len {
            emit(&self.buf[self.pos..self.pos + self.frame_len]);
            self.pos += self.hop;
        }
        // single compaction: move the unconsumed tail to the front
        if self.pos > 0 {
            let len = self.buf.len();
            self.buf.copy_within(self.pos.., 0);
            self.buf.truncate(len - self.pos);
            self.pos = 0;
        }
    }

    /// Samples currently buffered (yet to complete a frame).
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn reset(&mut self) {
        self.buf.clear();
        self.pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_exact_frames() {
        let mut f = Framer::new(4, 4);
        assert!(f.push(&[1.0, 2.0, 3.0]).is_empty());
        let frames = f.push(&[4.0, 5.0]);
        assert_eq!(frames, vec![vec![1.0, 2.0, 3.0, 4.0]]);
        assert_eq!(f.pending(), 1);
    }

    #[test]
    fn multiple_frames_in_one_push() {
        let mut f = Framer::new(2, 2);
        let frames = f.push(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[1], vec![3.0, 4.0]);
    }

    #[test]
    fn overlapping_hop() {
        let mut f = Framer::new(4, 2);
        let frames = f.push(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(frames[1], vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn reset_drops_pending() {
        let mut f = Framer::new(4, 4);
        f.push(&[1.0, 2.0]);
        f.reset();
        assert_eq!(f.pending(), 0);
    }

    #[test]
    fn try_new_rejects_bad_geometry() {
        assert!(Framer::try_new(4, 0).is_err());
        assert!(Framer::try_new(4, 5).is_err());
        assert!(Framer::try_new(0, 0).is_err());
        let f = Framer::try_new(4, 1).unwrap();
        assert_eq!((f.frame_len(), f.hop()), (4, 1));
    }

    #[test]
    #[should_panic(expected = "hop")]
    fn infallible_constructor_still_guards_fixtures() {
        let _ = Framer::new(4, 5);
    }

    /// The naive oracle: concatenate everything ever pushed, reslice
    /// from scratch. Frames at offsets 0, hop, 2·hop, ...
    fn oracle(stream: &[f64], frame_len: usize, hop: usize) -> Vec<Vec<f64>> {
        let mut frames = Vec::new();
        let mut at = 0;
        while at + frame_len <= stream.len() {
            frames.push(stream[at..at + frame_len].to_vec());
            at += hop;
        }
        frames
    }

    #[test]
    fn matches_reslice_oracle_all_hops_ragged_pushes() {
        // long stream, every hop size, push chunk sizes that straddle
        // frame boundaries in awkward ways — incl. empty pushes and
        // pushes completing many frames at once
        let frame_len = 16;
        let stream: Vec<f64> = (0..997).map(|i| i as f64 * 0.5 - 30.0)
                                       .collect();
        let chunks = [0usize, 1, 3, 16, 7, 255, 2, 64, 500, 997];
        for hop in 1..=frame_len {
            let mut f = Framer::new(frame_len, hop);
            let mut got = Vec::new();
            let mut at = 0usize;
            for &n in chunks.iter().cycle() {
                if at >= stream.len() {
                    break;
                }
                let end = (at + n).min(stream.len());
                got.extend(f.push(&stream[at..end]));
                at = end;
            }
            assert_eq!(got, oracle(&stream, frame_len, hop), "hop {hop}");
            // pending tail is exactly what the oracle didn't consume
            let consumed = oracle(&stream, frame_len, hop).len() * hop;
            assert_eq!(f.pending(), stream.len() - consumed, "hop {hop}");
        }
    }

    #[test]
    fn visitor_form_matches_allocating_form() {
        let stream: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let mut a = Framer::new(8, 3);
        let mut b = Framer::new(8, 3);
        let alloc = a.push(&stream);
        let mut visited = Vec::new();
        b.push_with(&stream, |fr| visited.push(fr.to_vec()));
        assert_eq!(alloc, visited);
        assert_eq!(a.pending(), b.pending());
    }
}
