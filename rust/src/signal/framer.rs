//! Stream framing: chop a continuous sample stream into fixed-length
//! recordings (the ICD samples continuously; the chip consumes
//! 512-sample windows).

/// Accumulates samples and emits complete frames of `frame_len`
/// samples, with an optional hop (`hop < frame_len` ⇒ overlapping
/// windows; `hop == frame_len` ⇒ back-to-back recordings, the paper's
/// mode).
#[derive(Debug, Clone)]
pub struct Framer {
    frame_len: usize,
    hop: usize,
    buf: Vec<f64>,
}

impl Framer {
    pub fn new(frame_len: usize, hop: usize) -> Self {
        assert!(hop >= 1 && hop <= frame_len);
        Self { frame_len, hop, buf: Vec::with_capacity(2 * frame_len) }
    }

    /// Paper configuration: non-overlapping 512-sample recordings.
    pub fn recordings() -> Self {
        Self::new(crate::REC_LEN, crate::REC_LEN)
    }

    /// Push samples; returns every complete frame that became ready.
    pub fn push(&mut self, samples: &[f64]) -> Vec<Vec<f64>> {
        self.buf.extend_from_slice(samples);
        let mut out = Vec::new();
        while self.buf.len() >= self.frame_len {
            out.push(self.buf[..self.frame_len].to_vec());
            self.buf.drain(..self.hop);
        }
        out
    }

    /// Samples currently buffered (yet to complete a frame).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    pub fn reset(&mut self) {
        self.buf.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_exact_frames() {
        let mut f = Framer::new(4, 4);
        assert!(f.push(&[1.0, 2.0, 3.0]).is_empty());
        let frames = f.push(&[4.0, 5.0]);
        assert_eq!(frames, vec![vec![1.0, 2.0, 3.0, 4.0]]);
        assert_eq!(f.pending(), 1);
    }

    #[test]
    fn multiple_frames_in_one_push() {
        let mut f = Framer::new(2, 2);
        let frames = f.push(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[1], vec![3.0, 4.0]);
    }

    #[test]
    fn overlapping_hop() {
        let mut f = Framer::new(4, 2);
        let frames = f.push(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(frames[1], vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn reset_drops_pending() {
        let mut f = Framer::new(4, 4);
        f.push(&[1.0, 2.0]);
        f.reset();
        assert_eq!(f.pending(), 0);
    }
}
