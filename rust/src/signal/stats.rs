//! Running statistics (Welford) used by the coordinator's signal
//! quality monitor and the benchmark harness.

/// Numerically-stable running mean/variance/min/max.
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_direct_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn empty_is_safe() {
        let s = RunningStats::new();
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.count(), 0);
    }
}
