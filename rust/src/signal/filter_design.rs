//! RBJ-cookbook biquad design (Butterworth Q = 1/√2).
//!
//! Coefficients are computed with the same closed-form expressions as
//! `python/compile/data.py::_butter2`, so the rust front end and the
//! python build-time pipeline apply the identical filter.

use super::biquad::{Biquad, BiquadCascade};
use crate::FS_HZ;

/// 2nd-order Butterworth high-pass at `fc_hz`.
pub fn butter2_highpass(fc_hz: f64, fs_hz: f64) -> Biquad {
    design(fc_hz, fs_hz, true)
}

/// 2nd-order Butterworth low-pass at `fc_hz`.
pub fn butter2_lowpass(fc_hz: f64, fs_hz: f64) -> Biquad {
    design(fc_hz, fs_hz, false)
}

fn design(fc_hz: f64, fs_hz: f64, highpass: bool) -> Biquad {
    let w0 = 2.0 * std::f64::consts::PI * fc_hz / fs_hz;
    let (cw, sw) = (w0.cos(), w0.sin());
    let q = std::f64::consts::FRAC_1_SQRT_2;
    let alpha = sw / (2.0 * q);
    let (b0, b1, b2) = if highpass {
        ((1.0 + cw) / 2.0, -(1.0 + cw), (1.0 + cw) / 2.0)
    } else {
        ((1.0 - cw) / 2.0, 1.0 - cw, (1.0 - cw) / 2.0)
    };
    let a0 = 1.0 + alpha;
    Biquad::new(
        [b0 / a0, b1 / a0, b2 / a0],
        [(-2.0 * cw) / a0, (1.0 - alpha) / a0],
    )
}

/// The paper's 15–55 Hz band-pass front end (HP2 → LP2 cascade).
pub fn bandpass_15_55() -> BiquadCascade {
    BiquadCascade::new(vec![
        butter2_highpass(15.0, FS_HZ),
        butter2_lowpass(55.0, FS_HZ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandpass_response_shape() {
        let bp = bandpass_15_55();
        // passband ~unity, stopbands strongly attenuated
        assert!(bp.magnitude(30.0, FS_HZ) > 0.85);
        assert!(bp.magnitude(2.0, FS_HZ) < 0.08);
        assert!(bp.magnitude(100.0, FS_HZ) < 0.25);
        assert!(bp.magnitude(0.3, FS_HZ) < 0.01);
    }

    #[test]
    fn highpass_blocks_dc() {
        let mut hp = butter2_highpass(15.0, FS_HZ);
        let mut last = 1.0;
        for _ in 0..2000 {
            last = hp.process(1.0);
        }
        assert!(last.abs() < 1e-6, "DC must decay to zero, got {last}");
    }

    #[test]
    fn lowpass_passes_dc() {
        let mut lp = butter2_lowpass(55.0, FS_HZ);
        let mut last = 0.0;
        for _ in 0..2000 {
            last = lp.process(1.0);
        }
        assert!((last - 1.0).abs() < 1e-6);
    }

    #[test]
    fn matches_python_coefficients() {
        // golden values computed by python/compile/data.py::_butter2
        let hp = butter2_highpass(15.0, 250.0);
        let y0 = {
            let mut h = hp.clone();
            h.process(1.0)
        };
        // first output == b0 of the section
        assert!((y0 - 0.765_599_987_913_459_1).abs() < 1e-12, "{y0}");
    }
}
