//! DSP substrate: the chip's analog/digital front end in software.
//!
//! The paper preprocesses each IEGM recording with a 15–55 Hz band-pass
//! filter before it reaches the accelerator. This module provides that
//! front end (RBJ biquad cascades with the same coefficients as the
//! python build-time pipeline), plus stream framing and running
//! statistics used by the coordinator.

mod biquad;
mod filter_design;
mod framer;
mod stats;

pub use biquad::{Biquad, BiquadCascade};
pub use filter_design::{bandpass_15_55, butter2_highpass, butter2_lowpass};
pub use framer::Framer;
pub use stats::RunningStats;

use crate::REC_LEN;

/// Full front-end preprocessing of one raw recording: band-pass
/// 15–55 Hz, RMS-normalize to 0.25 full scale, clamp to [-1, 1].
/// Mirrors `python/compile/data.py::preprocess` bit-for-bit in f64.
pub fn preprocess(raw: &[f64]) -> Vec<f64> {
    let mut bp = bandpass_15_55();
    let mut y: Vec<f64> = raw.iter().map(|&x| bp.process(x)).collect();
    let rms = (y.iter().map(|v| v * v).sum::<f64>() / y.len() as f64).sqrt();
    if rms > 1e-9 {
        let g = 0.25 / rms;
        for v in &mut y {
            *v *= g;
        }
    }
    for v in &mut y {
        *v = v.clamp(-1.0, 1.0);
    }
    y
}

/// Chip ADC quantization of one sample: float [-1,1] → int8 with
/// round-half-away-from-zero at scale 1/127. The single-sample form
/// exists for the streaming path ([`crate::coordinator::StreamSession`]
/// quantizes each sample exactly once as it arrives).
pub fn quantize_sample(v: f64) -> i8 {
    let s = v * 127.0;
    let q = if s >= 0.0 { (s + 0.5).floor() } else { (s - 0.5).ceil() };
    q.clamp(-127.0, 127.0) as i8
}

/// Chip ADC input quantization over a whole recording
/// ([`quantize_sample`] per element).
pub fn quantize_input(x: &[f64]) -> Vec<i8> {
    x.iter().map(|&v| quantize_sample(v)).collect()
}

/// Convenience: preprocess + quantize one recording.
pub fn front_end(raw: &[f64]) -> Vec<i8> {
    assert_eq!(raw.len(), REC_LEN, "front_end expects one full recording");
    quantize_input(&preprocess(raw))
}
