//! Direct-form-I biquad sections and cascades.

/// One second-order IIR section, direct form I (matches the python
//  reference implementation sample-for-sample in f64).
#[derive(Debug, Clone)]
pub struct Biquad {
    b: [f64; 3],
    a: [f64; 2], // a1, a2 (a0 normalized to 1)
    x1: f64,
    x2: f64,
    y1: f64,
    y2: f64,
}

impl Biquad {
    /// Coefficients already normalized by a0.
    pub fn new(b: [f64; 3], a: [f64; 2]) -> Self {
        Self { b, a, x1: 0.0, x2: 0.0, y1: 0.0, y2: 0.0 }
    }

    /// Process one sample.
    #[inline]
    pub fn process(&mut self, x: f64) -> f64 {
        let y = self.b[0] * x + self.b[1] * self.x1 + self.b[2] * self.x2
            - self.a[0] * self.y1
            - self.a[1] * self.y2;
        self.x2 = self.x1;
        self.x1 = x;
        self.y2 = self.y1;
        self.y1 = y;
        y
    }

    /// Reset internal state (between independent recordings).
    pub fn reset(&mut self) {
        self.x1 = 0.0;
        self.x2 = 0.0;
        self.y1 = 0.0;
        self.y2 = 0.0;
    }

    /// Steady-state magnitude response at frequency `f_hz` for sample
    /// rate `fs_hz` (analysis helper for tests).
    pub fn magnitude(&self, f_hz: f64, fs_hz: f64) -> f64 {
        let w = 2.0 * std::f64::consts::PI * f_hz / fs_hz;
        let (re1, im1) = (w.cos(), -w.sin());
        let (re2, im2) = ((2.0 * w).cos(), -(2.0 * w).sin());
        let nr = self.b[0] + self.b[1] * re1 + self.b[2] * re2;
        let ni = self.b[1] * im1 + self.b[2] * im2;
        let dr = 1.0 + self.a[0] * re1 + self.a[1] * re2;
        let di = self.a[0] * im1 + self.a[1] * im2;
        ((nr * nr + ni * ni) / (dr * dr + di * di)).sqrt()
    }
}

/// A cascade of biquad sections applied in order.
#[derive(Debug, Clone)]
pub struct BiquadCascade {
    sections: Vec<Biquad>,
}

impl BiquadCascade {
    pub fn new(sections: Vec<Biquad>) -> Self {
        Self { sections }
    }

    #[inline]
    pub fn process(&mut self, x: f64) -> f64 {
        self.sections.iter_mut().fold(x, |acc, s| s.process(acc))
    }

    pub fn process_block(&mut self, x: &[f64]) -> Vec<f64> {
        x.iter().map(|&v| self.process(v)).collect()
    }

    pub fn reset(&mut self) {
        for s in &mut self.sections {
            s.reset();
        }
    }

    pub fn magnitude(&self, f_hz: f64, fs_hz: f64) -> f64 {
        self.sections.iter().map(|s| s.magnitude(f_hz, fs_hz)).product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_filter_passes_through() {
        let mut bq = Biquad::new([1.0, 0.0, 0.0], [0.0, 0.0]);
        for x in [0.5, -1.0, 2.0, 0.0] {
            assert_eq!(bq.process(x), x);
        }
    }

    #[test]
    fn pure_delay() {
        let mut bq = Biquad::new([0.0, 1.0, 0.0], [0.0, 0.0]);
        assert_eq!(bq.process(3.0), 0.0);
        assert_eq!(bq.process(5.0), 3.0);
        assert_eq!(bq.process(0.0), 5.0);
    }

    #[test]
    fn reset_clears_state() {
        let mut bq = Biquad::new([0.5, 0.5, 0.0], [-0.1, 0.0]);
        bq.process(1.0);
        bq.process(2.0);
        bq.reset();
        let y = bq.process(0.0);
        assert_eq!(y, 0.0);
    }

    #[test]
    fn cascade_order_is_sequential() {
        // gain-2 then delay == delay then gain-2 for LTI; check plumbing
        let g2 = Biquad::new([2.0, 0.0, 0.0], [0.0, 0.0]);
        let dl = Biquad::new([0.0, 1.0, 0.0], [0.0, 0.0]);
        let mut c = BiquadCascade::new(vec![g2, dl]);
        assert_eq!(c.process(1.5), 0.0);
        assert_eq!(c.process(0.0), 3.0);
    }

    #[test]
    fn magnitude_of_identity_is_one() {
        let bq = Biquad::new([1.0, 0.0, 0.0], [0.0, 0.0]);
        assert!((bq.magnitude(30.0, 250.0) - 1.0).abs() < 1e-12);
    }
}
