//! Waveform morphology primitives (mirrors `python/compile/data.py`).

use super::rng::SplitMix64;
use crate::FS_HZ;

/// Parameters for a QRS-like deflection train.
#[derive(Debug, Clone, Copy)]
pub struct SpikeParams {
    /// Activation rate in beats per minute.
    pub rate_bpm: f64,
    /// RR-interval jitter (fraction of the period, gaussian).
    pub jitter: f64,
    /// Deflection half-width in seconds.
    pub width_s: f64,
    /// Peak amplitude.
    pub amp: f64,
    /// 0 = monophasic gaussian, 1 = biphasic gaussian-derivative.
    pub biphasic: f64,
}

impl SpikeParams {
    /// Mid-distribution NSR QRS parameters (centre of the
    /// [`super::Generator`] NSR sampling ranges) — one anchor of the
    /// morphology-drift scenario family.
    pub fn nsr_nominal() -> Self {
        Self { rate_bpm: 77.5, jitter: 0.04, width_s: 0.012, amp: 1.0,
               biphasic: 0.8 }
    }

    /// Mid-distribution VT parameters (centre of the
    /// [`super::Generator`] VT sampling ranges) — the other anchor.
    pub fn vt_nominal() -> Self {
        Self { rate_bpm: 205.0, jitter: 0.015, width_s: 0.030, amp: 1.3,
               biphasic: 0.45 }
    }

    /// Field-wise linear interpolation: `t = 0` is `a`, `t = 1` is
    /// `b`. The morphology-drift scenarios walk `t` from 0 to 1 to
    /// model a rhythm that *gradually* becomes ventricular.
    pub fn lerp(a: Self, b: Self, t: f64) -> Self {
        let mix = |x: f64, y: f64| x + (y - x) * t;
        Self { rate_bpm: mix(a.rate_bpm, b.rate_bpm),
               jitter: mix(a.jitter, b.jitter),
               width_s: mix(a.width_s, b.width_s),
               amp: mix(a.amp, b.amp),
               biphasic: mix(a.biphasic, b.biphasic) }
    }
}

/// Train of gaussian(-derivative) deflections at a given rate: the
/// shared building block for NSR/SVT/VT morphologies.
pub fn spike_train(rng: &mut SplitMix64, n: usize, p: SpikeParams) -> Vec<f64> {
    let mut sig = vec![0.0; n];
    let period = 60.0 / p.rate_bpm;
    let mut tc = rng.range(0.0, period);
    let t_end = n as f64 / FS_HZ + 2.0 * p.width_s;
    // exp(0.5): peak normalization of the gaussian derivative
    const EXP_HALF: f64 = 1.648_721_270_700_128_2;
    while tc < t_end {
        let w = (p.width_s * (1.0 + 0.1 * rng.gauss())).max(1e-4);
        let a = p.amp * (1.0 + 0.1 * rng.gauss());
        for (i, s) in sig.iter_mut().enumerate() {
            let d = (i as f64 / FS_HZ - tc) / w;
            let g = (-0.5 * d * d).exp();
            let mono = g;
            let bi = -d * g * EXP_HALF;
            *s += a * ((1.0 - p.biphasic) * mono + p.biphasic * bi);
        }
        tc += period * (1.0 + p.jitter * rng.gauss());
    }
    sig
}

/// VF: drifting narrow-band (4–7 Hz) oscillators + high-frequency
/// fractionation, no discrete activations.
pub fn vf_chaos(rng: &mut SplitMix64, n: usize) -> Vec<f64> {
    let mut sig = vec![0.0; n];
    for _ in 0..3 {
        let f0 = rng.range(4.0, 7.0);
        let fm = rng.range(0.1, 0.5);
        let fd = rng.range(0.3, 1.2);
        let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let am = 0.5 + 0.5 * rng.uniform();
        let mut phase = 0.0;
        for (i, s) in sig.iter_mut().enumerate() {
            let t = i as f64 / FS_HZ;
            let inst = f0 + fd * (2.0 * std::f64::consts::PI * fm * t + ph).sin();
            phase += 2.0 * std::f64::consts::PI * inst / FS_HZ;
            *s += am * (phase + ph).sin();
        }
    }
    for _ in 0..2 {
        let f0 = rng.range(12.0, 25.0);
        let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let am = 0.15 + 0.2 * rng.uniform();
        for (i, s) in sig.iter_mut().enumerate() {
            let t = i as f64 / FS_HZ;
            *s += am * (2.0 * std::f64::consts::PI * f0 * t + ph).sin();
        }
    }
    sig
}

/// Baseline wander (respiration ~0.3 Hz) + white sensor noise, added
/// in-place. Consumes RNG in the same order as python (`phase` first,
/// then one gaussian per sample).
pub fn add_artifacts(rng: &mut SplitMix64, sig: &mut [f64], wander_amp: f64,
                     noise_rms: f64) {
    let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
    for (i, s) in sig.iter_mut().enumerate() {
        let t = i as f64 / FS_HZ;
        *s += wander_amp * (2.0 * std::f64::consts::PI * 0.3 * t + ph).sin();
    }
    for s in sig.iter_mut() {
        *s += noise_rms * rng.gauss();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::REC_LEN;

    #[test]
    fn spike_train_has_expected_beat_count() {
        let mut rng = SplitMix64::new(3);
        let p = SpikeParams { rate_bpm: 120.0, jitter: 0.0, width_s: 0.012,
                              amp: 1.0, biphasic: 0.0 };
        let sig = spike_train(&mut rng, REC_LEN, p);
        // 120 bpm over 2.048 s ≈ 4 peaks; count local maxima above 0.5
        let peaks = sig.windows(3)
            .filter(|w| w[1] > 0.5 && w[1] > w[0] && w[1] > w[2])
            .count();
        assert!((3..=6).contains(&peaks), "peaks={peaks}");
    }

    #[test]
    fn vf_is_nonzero_and_bounded() {
        let mut rng = SplitMix64::new(4);
        let sig = vf_chaos(&mut rng, REC_LEN);
        let maxabs = sig.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        assert!(maxabs > 0.3 && maxabs < 6.0, "{maxabs}");
    }

    #[test]
    fn artifacts_change_signal() {
        let mut rng = SplitMix64::new(5);
        let mut sig = vec![0.0; REC_LEN];
        add_artifacts(&mut rng, &mut sig, 0.3, 0.05);
        let rms = (sig.iter().map(|v| v * v).sum::<f64>() / sig.len() as f64).sqrt();
        assert!(rms > 0.05, "{rms}");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = SpikeParams { rate_bpm: 80.0, jitter: 0.04, width_s: 0.012,
                              amp: 1.0, biphasic: 0.8 };
        let a = spike_train(&mut SplitMix64::new(9), 64, p);
        let b = spike_train(&mut SplitMix64::new(9), 64, p);
        assert_eq!(a, b);
    }

    /// Count local maxima above half the nominal amplitude — the same
    /// estimator `spike_train_has_expected_beat_count` uses, reused
    /// across a rate sweep.
    fn count_peaks(sig: &[f64], thresh: f64) -> usize {
        sig.windows(3)
            .filter(|w| w[1] > thresh && w[1] > w[0] && w[1] > w[2])
            .count()
    }

    #[test]
    fn beat_count_tracks_rate_across_sweep() {
        // REC_LEN = 512 samples at 250 Hz = 2.048 s; with jitter 0 a
        // rate of R bpm lays down between floor(2.048·R/60) and
        // ceil(...)+1 beats depending on the random first-beat phase.
        // Bounds below widen that by one for the ±10% per-beat width/
        // amp jitter that can push a peak under/over the threshold.
        for (rate, lo, hi) in [(60.0, 1usize, 4usize), (120.0, 3, 6),
                               (200.0, 5, 9)] {
            for seed in [11u64, 12, 13, 14] {
                let p = SpikeParams { rate_bpm: rate, jitter: 0.0,
                                      width_s: 0.012, amp: 1.0,
                                      biphasic: 0.0 };
                let sig = spike_train(&mut SplitMix64::new(seed), REC_LEN, p);
                let peaks = count_peaks(&sig, 0.5);
                assert!((lo..=hi).contains(&peaks),
                        "rate {rate} seed {seed}: peaks={peaks}");
            }
        }
    }

    #[test]
    fn monophasic_envelope_is_one_sided() {
        // pure gaussians: no negative lobe beyond numerical dust, and
        // the peak sits near amp (±10% amp jitter, possible overlap)
        for seed in [21u64, 22, 23] {
            let p = SpikeParams { rate_bpm: 100.0, jitter: 0.0,
                                  width_s: 0.012, amp: 1.0, biphasic: 0.0 };
            let sig = spike_train(&mut SplitMix64::new(seed), REC_LEN, p);
            let min = sig.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = sig.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(min >= -1e-9, "seed {seed}: min={min}");
            assert!(max > 0.5 && max < 2.0, "seed {seed}: max={max}");
        }
    }

    #[test]
    fn biphasic_envelope_is_two_sided() {
        // gaussian derivative normalized by EXP_HALF: both lobes
        // reach a substantial fraction of amp, neither explodes
        for seed in [31u64, 32, 33] {
            let p = SpikeParams { rate_bpm: 100.0, jitter: 0.0,
                                  width_s: 0.012, amp: 1.0, biphasic: 1.0 };
            let sig = spike_train(&mut SplitMix64::new(seed), REC_LEN, p);
            let min = sig.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = sig.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(min < -0.3 && min > -2.0, "seed {seed}: min={min}");
            assert!(max > 0.3 && max < 2.0, "seed {seed}: max={max}");
        }
    }

    #[test]
    fn lerp_endpoints_and_midpoint() {
        let a = SpikeParams::nsr_nominal();
        let b = SpikeParams::vt_nominal();
        let at0 = SpikeParams::lerp(a, b, 0.0);
        let at1 = SpikeParams::lerp(a, b, 1.0);
        let mid = SpikeParams::lerp(a, b, 0.5);
        assert_eq!(at0.rate_bpm, a.rate_bpm);
        assert_eq!(at0.width_s, a.width_s);
        assert_eq!(at1.rate_bpm, b.rate_bpm);
        assert_eq!(at1.biphasic, b.biphasic);
        assert!((mid.rate_bpm - (77.5 + 205.0) / 2.0).abs() < 1e-12);
        assert!((mid.amp - 1.15).abs() < 1e-12);
        // interpolated trains stay deterministic per seed
        let x = spike_train(&mut SplitMix64::new(7), REC_LEN, mid);
        let y = spike_train(&mut SplitMix64::new(7), REC_LEN, mid);
        assert_eq!(x, y);
    }
}
