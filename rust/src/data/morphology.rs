//! Waveform morphology primitives (mirrors `python/compile/data.py`).

use super::rng::SplitMix64;
use crate::FS_HZ;

/// Parameters for a QRS-like deflection train.
#[derive(Debug, Clone, Copy)]
pub struct SpikeParams {
    /// Activation rate in beats per minute.
    pub rate_bpm: f64,
    /// RR-interval jitter (fraction of the period, gaussian).
    pub jitter: f64,
    /// Deflection half-width in seconds.
    pub width_s: f64,
    /// Peak amplitude.
    pub amp: f64,
    /// 0 = monophasic gaussian, 1 = biphasic gaussian-derivative.
    pub biphasic: f64,
}

/// Train of gaussian(-derivative) deflections at a given rate: the
/// shared building block for NSR/SVT/VT morphologies.
pub fn spike_train(rng: &mut SplitMix64, n: usize, p: SpikeParams) -> Vec<f64> {
    let mut sig = vec![0.0; n];
    let period = 60.0 / p.rate_bpm;
    let mut tc = rng.range(0.0, period);
    let t_end = n as f64 / FS_HZ + 2.0 * p.width_s;
    // exp(0.5): peak normalization of the gaussian derivative
    const EXP_HALF: f64 = 1.648_721_270_700_128_2;
    while tc < t_end {
        let w = (p.width_s * (1.0 + 0.1 * rng.gauss())).max(1e-4);
        let a = p.amp * (1.0 + 0.1 * rng.gauss());
        for (i, s) in sig.iter_mut().enumerate() {
            let d = (i as f64 / FS_HZ - tc) / w;
            let g = (-0.5 * d * d).exp();
            let mono = g;
            let bi = -d * g * EXP_HALF;
            *s += a * ((1.0 - p.biphasic) * mono + p.biphasic * bi);
        }
        tc += period * (1.0 + p.jitter * rng.gauss());
    }
    sig
}

/// VF: drifting narrow-band (4–7 Hz) oscillators + high-frequency
/// fractionation, no discrete activations.
pub fn vf_chaos(rng: &mut SplitMix64, n: usize) -> Vec<f64> {
    let mut sig = vec![0.0; n];
    for _ in 0..3 {
        let f0 = rng.range(4.0, 7.0);
        let fm = rng.range(0.1, 0.5);
        let fd = rng.range(0.3, 1.2);
        let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let am = 0.5 + 0.5 * rng.uniform();
        let mut phase = 0.0;
        for (i, s) in sig.iter_mut().enumerate() {
            let t = i as f64 / FS_HZ;
            let inst = f0 + fd * (2.0 * std::f64::consts::PI * fm * t + ph).sin();
            phase += 2.0 * std::f64::consts::PI * inst / FS_HZ;
            *s += am * (phase + ph).sin();
        }
    }
    for _ in 0..2 {
        let f0 = rng.range(12.0, 25.0);
        let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let am = 0.15 + 0.2 * rng.uniform();
        for (i, s) in sig.iter_mut().enumerate() {
            let t = i as f64 / FS_HZ;
            *s += am * (2.0 * std::f64::consts::PI * f0 * t + ph).sin();
        }
    }
    sig
}

/// Baseline wander (respiration ~0.3 Hz) + white sensor noise, added
/// in-place. Consumes RNG in the same order as python (`phase` first,
/// then one gaussian per sample).
pub fn add_artifacts(rng: &mut SplitMix64, sig: &mut [f64], wander_amp: f64,
                     noise_rms: f64) {
    let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
    for (i, s) in sig.iter_mut().enumerate() {
        let t = i as f64 / FS_HZ;
        *s += wander_amp * (2.0 * std::f64::consts::PI * 0.3 * t + ph).sin();
    }
    for s in sig.iter_mut() {
        *s += noise_rms * rng.gauss();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::REC_LEN;

    #[test]
    fn spike_train_has_expected_beat_count() {
        let mut rng = SplitMix64::new(3);
        let p = SpikeParams { rate_bpm: 120.0, jitter: 0.0, width_s: 0.012,
                              amp: 1.0, biphasic: 0.0 };
        let sig = spike_train(&mut rng, REC_LEN, p);
        // 120 bpm over 2.048 s ≈ 4 peaks; count local maxima above 0.5
        let peaks = sig.windows(3)
            .filter(|w| w[1] > 0.5 && w[1] > w[0] && w[1] > w[2])
            .count();
        assert!((3..=6).contains(&peaks), "peaks={peaks}");
    }

    #[test]
    fn vf_is_nonzero_and_bounded() {
        let mut rng = SplitMix64::new(4);
        let sig = vf_chaos(&mut rng, REC_LEN);
        let maxabs = sig.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        assert!(maxabs > 0.3 && maxabs < 6.0, "{maxabs}");
    }

    #[test]
    fn artifacts_change_signal() {
        let mut rng = SplitMix64::new(5);
        let mut sig = vec![0.0; REC_LEN];
        add_artifacts(&mut rng, &mut sig, 0.3, 0.05);
        let rms = (sig.iter().map(|v| v * v).sum::<f64>() / sig.len() as f64).sqrt();
        assert!(rms > 0.05, "{rms}");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = SpikeParams { rate_bpm: 80.0, jitter: 0.04, width_s: 0.012,
                              amp: 1.0, biphasic: 0.8 };
        let a = spike_train(&mut SplitMix64::new(9), 64, p);
        let b = spike_train(&mut SplitMix64::new(9), 64, p);
        assert_eq!(a, b);
    }
}
