//! IEGM recording generator: four rhythm classes, same parameter
//! distributions and RNG consumption order as `python/compile/data.py`.

use super::morphology::{add_artifacts, spike_train, vf_chaos, SpikeParams};
use super::rng::SplitMix64;
use crate::signal;
use crate::REC_LEN;

/// Rhythm classes. `NSR`/`SVT` are non-VA; `VT`/`VF` are the
/// life-threatening ventricular arrhythmias the chip must detect.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RhythmClass {
    /// Normal sinus rhythm (55–100 bpm, narrow biphasic deflections).
    Nsr,
    /// Supraventricular tachycardia (150–220 bpm, narrow, regular).
    Svt,
    /// Ventricular tachycardia (160–250 bpm, wide monomorphic).
    Vt,
    /// Ventricular fibrillation (chaotic 4–7 Hz, no discrete QRS).
    Vf,
}

impl RhythmClass {
    pub const ALL: [RhythmClass; 4] =
        [RhythmClass::Nsr, RhythmClass::Svt, RhythmClass::Vt, RhythmClass::Vf];

    /// Class id shared with python (`CLS_*`) and eval.bin labels.
    pub fn id(self) -> i32 {
        match self {
            RhythmClass::Nsr => 0,
            RhythmClass::Svt => 1,
            RhythmClass::Vt => 2,
            RhythmClass::Vf => 3,
        }
    }

    pub fn from_id(id: i32) -> Option<Self> {
        Some(match id {
            0 => RhythmClass::Nsr,
            1 => RhythmClass::Svt,
            2 => RhythmClass::Vt,
            3 => RhythmClass::Vf,
            _ => return None,
        })
    }

    /// Is this a ventricular arrhythmia (the positive detection class)?
    pub fn is_va(self) -> bool {
        matches!(self, RhythmClass::Vt | RhythmClass::Vf)
    }

    pub fn name(self) -> &'static str {
        match self {
            RhythmClass::Nsr => "NSR",
            RhythmClass::Svt => "SVT",
            RhythmClass::Vt => "VT",
            RhythmClass::Vf => "VF",
        }
    }
}

/// One synthesized recording: raw samples + ground truth.
#[derive(Debug, Clone)]
pub struct Recording {
    pub raw: Vec<f64>,
    pub class: RhythmClass,
}

impl Recording {
    /// Band-passed, normalized, int8-quantized chip input.
    pub fn quantized(&self) -> Vec<i8> {
        signal::front_end(&self.raw)
    }
}

/// Deterministic recording generator.
#[derive(Debug, Clone)]
pub struct Generator {
    rng: SplitMix64,
    pub noise_rms: f64,
    pub wander_amp: f64,
}

impl Generator {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), noise_rms: 0.6, wander_amp: 0.3 }
    }

    pub fn with_noise(seed: u64, noise_rms: f64) -> Self {
        Self { rng: SplitMix64::new(seed), noise_rms, wander_amp: 0.3 }
    }

    /// Synthesize one raw (pre-filter) recording of `REC_LEN` samples.
    pub fn recording(&mut self, class: RhythmClass) -> Recording {
        let rng = &mut self.rng;
        let mut sig = match class {
            RhythmClass::Nsr => {
                let rate = rng.range(55.0, 100.0);
                let mut s = spike_train(rng, REC_LEN, SpikeParams {
                    rate_bpm: rate, jitter: 0.04, width_s: 0.012,
                    amp: 1.0, biphasic: 0.8,
                });
                let t = spike_train(rng, REC_LEN, SpikeParams {
                    rate_bpm: rate, jitter: 0.04, width_s: 0.06,
                    amp: 0.25, biphasic: 0.0,
                });
                for (a, b) in s.iter_mut().zip(t) {
                    *a += b;
                }
                s
            }
            RhythmClass::Svt => {
                let rate = rng.range(150.0, 220.0);
                spike_train(rng, REC_LEN, SpikeParams {
                    rate_bpm: rate, jitter: 0.02, width_s: 0.011,
                    amp: 0.9, biphasic: 0.8,
                })
            }
            RhythmClass::Vt => {
                let rate = rng.range(160.0, 250.0);
                spike_train(rng, REC_LEN, SpikeParams {
                    rate_bpm: rate, jitter: 0.015, width_s: 0.030,
                    amp: 1.3, biphasic: 0.45,
                })
            }
            RhythmClass::Vf => vf_chaos(rng, REC_LEN),
        };
        add_artifacts(rng, &mut sig, self.wander_amp, self.noise_rms);
        Recording { raw: sig, class }
    }

    /// Class-round-robin batch (the corpus layout python trains on).
    pub fn corpus(&mut self, n_per_class: usize) -> Vec<Recording> {
        let mut out = Vec::with_capacity(4 * n_per_class);
        for _ in 0..n_per_class {
            for class in RhythmClass::ALL {
                out.push(self.recording(class));
            }
        }
        out
    }

    /// A continuous sample stream for the live demo: `episodes` of
    /// (class, n_recordings), concatenated back-to-back.
    pub fn stream(&mut self, episodes: &[(RhythmClass, usize)]) -> (Vec<f64>, Vec<RhythmClass>) {
        let mut samples = Vec::new();
        let mut truth = Vec::new();
        for &(class, n) in episodes {
            for _ in 0..n {
                let rec = self.recording(class);
                samples.extend_from_slice(&rec.raw);
                truth.push(class);
            }
        }
        (samples, truth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Generator::new(1).recording(RhythmClass::Vt);
        let b = Generator::new(1).recording(RhythmClass::Vt);
        assert_eq!(a.raw, b.raw);
        let c = Generator::new(2).recording(RhythmClass::Vt);
        assert_ne!(a.raw, c.raw);
    }

    #[test]
    fn quantized_in_range() {
        let mut g = Generator::new(3);
        for class in RhythmClass::ALL {
            let q = g.recording(class).quantized();
            assert_eq!(q.len(), REC_LEN);
            assert!(q.iter().all(|&v| (-127..=127).contains(&(v as i32))));
            // non-degenerate: some signal present
            assert!(q.iter().any(|&v| v.abs() > 5));
        }
    }

    #[test]
    fn class_ids_roundtrip() {
        for class in RhythmClass::ALL {
            assert_eq!(RhythmClass::from_id(class.id()), Some(class));
        }
        assert_eq!(RhythmClass::from_id(9), None);
    }

    #[test]
    fn va_flags() {
        assert!(!RhythmClass::Nsr.is_va());
        assert!(!RhythmClass::Svt.is_va());
        assert!(RhythmClass::Vt.is_va());
        assert!(RhythmClass::Vf.is_va());
    }

    #[test]
    fn corpus_layout_round_robin() {
        let recs = Generator::new(5).corpus(2);
        assert_eq!(recs.len(), 8);
        assert_eq!(recs[0].class, RhythmClass::Nsr);
        assert_eq!(recs[3].class, RhythmClass::Vf);
        assert_eq!(recs[4].class, RhythmClass::Nsr);
    }

    #[test]
    fn stream_concatenates_episodes() {
        let (samples, truth) =
            Generator::new(6).stream(&[(RhythmClass::Nsr, 2), (RhythmClass::Vf, 1)]);
        assert_eq!(samples.len(), 3 * REC_LEN);
        assert_eq!(truth, vec![RhythmClass::Nsr, RhythmClass::Nsr, RhythmClass::Vf]);
    }

    #[test]
    fn nsr_vf_zero_crossing_separation() {
        // same morphology sanity check as python test_data.py
        let zcr = |class: RhythmClass| {
            let mut g = Generator::with_noise(1000 + class.id() as u64, 0.05);
            let mut total = 0.0;
            for _ in 0..8 {
                let y = crate::signal::preprocess(&g.recording(class).raw);
                let z: f64 = y.windows(2)
                    .map(|w| if w[0].signum() != w[1].signum() { 1.0 } else { 0.0 })
                    .sum();
                total += z / (REC_LEN - 1) as f64;
            }
            total / 8.0
        };
        assert!(zcr(RhythmClass::Nsr) > 1.2 * zcr(RhythmClass::Vf));
    }
}
