//! Synthetic IEGM data substrate.
//!
//! The paper's corpus (SingularMedical intracardiac electrograms from
//! ICD leads) is proprietary; this module provides the substitute
//! described in `DESIGN.md` §2 — a parametric morphology model with
//! four rhythm classes (NSR/SVT = non-VA, VT/VF = VA), plus readers
//! for the binary artifacts the python build pipeline emits
//! (`eval.bin`, the exact corpus the model was audited against).
//!
//! [`scenarios`] layers the adversarial stress harness on top: seed-
//! deterministic perturbation families (noise sweeps, baseline
//! wander, lead dislodgement, powerline pickup, amplitude drift,
//! NSR→VT morphology drift) expanded into continuous streams with
//! per-segment ground truth for the streaming path.

mod dataset;
pub mod fixtures;
mod iegm;
mod morphology;
mod rng;
pub mod scenarios;

pub use dataset::{load_eval, Dataset};
pub use iegm::{Generator, RhythmClass, Recording};
pub use morphology::{add_artifacts, spike_train, vf_chaos, SpikeParams};
pub use rng::SplitMix64;
pub use scenarios::{Family, Scenario, ScenarioStream};
