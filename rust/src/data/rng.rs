//! splitmix64 — the deterministic PRNG shared with
//! `python/compile/data.py` (bit-identical integer stream; golden
//! vectors in both test suites).

/// splitmix64 PRNG (Steele, Lea & Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// U[0, 1) with 53-bit resolution (same construction as python).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Box-Muller standard normal, consuming exactly two uniforms (no
    /// caching — keeps the stream position aligned with python).
    #[inline]
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_vectors_match_python() {
        // canonical splitmix64 reference for seed 0
        let mut r0 = SplitMix64::new(0);
        assert_eq!(r0.next_u64(), 0xE220_A839_7B1D_CDAF);
        // shared with python/tests/test_data.py::test_splitmix64_golden
        let mut r = SplitMix64::new(1234);
        assert_eq!(r.next_u64(), 0xBB0C_F61B_2F18_1CDB);
        assert_eq!(r.next_u64(), 0x97C7_A136_4DF0_6524);
        assert_eq!(r.next_u64(), 0x33BE_FAE4_9BC0_25DA);
        assert_eq!(r.next_u64(), 0x4E62_41F2_52D0_A033);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 1000.0;
        assert!((0.4..0.6).contains(&mean), "{mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn deterministic() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
