//! Dataset container + binary artifact readers (formats defined in
//! `python/compile/artifact.py`).

use std::fs::File;
use std::io::Read;
use std::path::Path;

use anyhow::{ensure, anyhow as eyre, Result};

use super::iegm::RhythmClass;

/// An evaluation corpus: quantized int8 inputs + 4-class labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `[n]` recordings, each `rec_len` int8 samples.
    pub x: Vec<Vec<i8>>,
    /// 4-class ground truth.
    pub labels: Vec<RhythmClass>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Binary VA ground truth (the detection target).
    pub fn va_labels(&self) -> Vec<bool> {
        self.labels.iter().map(|c| c.is_va()).collect()
    }

    /// Build a dataset from the rust generator (streaming-scale
    /// workloads; see `data::Generator` for the bit-exactness caveat).
    pub fn synthesize(seed: u64, n_per_class: usize, noise_rms: f64) -> Self {
        let mut gen = super::iegm::Generator::with_noise(seed, noise_rms);
        let recs = gen.corpus(n_per_class);
        let labels = recs.iter().map(|r| r.class).collect();
        let x = recs.iter().map(|r| r.quantized()).collect();
        Self { x, labels }
    }
}

fn read_u32(buf: &[u8], off: &mut usize) -> Result<u32> {
    ensure!(buf.len() >= *off + 4, "truncated artifact");
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

/// Load `artifacts/eval.bin` — the exact corpus the python build
/// audited the quantized model against (bit-exact cross-language
/// comparisons run on this).
pub fn load_eval(path: impl AsRef<Path>) -> Result<Dataset> {
    let mut buf = Vec::new();
    File::open(path.as_ref())
        .map_err(|e| eyre!("open {}: {e}", path.as_ref().display()))?
        .read_to_end(&mut buf)?;
    ensure!(&buf[..4] == b"VAEV", "bad eval.bin magic");
    let mut off = 4;
    let version = read_u32(&buf, &mut off)?;
    ensure!(version == 1, "unsupported eval.bin version {version}");
    let n = read_u32(&buf, &mut off)? as usize;
    let rec_len = read_u32(&buf, &mut off)? as usize;
    ensure!(rec_len == crate::REC_LEN, "rec_len {rec_len} != {}", crate::REC_LEN);

    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let id = read_u32(&buf, &mut off)? as i32;
        labels.push(RhythmClass::from_id(id).ok_or_else(|| eyre!("bad label {id}"))?);
    }
    ensure!(buf.len() - off >= n * rec_len, "truncated sample block");
    let mut x = Vec::with_capacity(n);
    for i in 0..n {
        let s = &buf[off + i * rec_len..off + (i + 1) * rec_len];
        x.push(s.iter().map(|&b| b as i8).collect());
    }
    Ok(Dataset { x, labels })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesize_shapes() {
        let ds = Dataset::synthesize(1, 2, 0.3);
        assert_eq!(ds.len(), 8);
        assert_eq!(ds.x[0].len(), crate::REC_LEN);
        assert_eq!(ds.va_labels().iter().filter(|&&v| v).count(), 4);
    }

    #[test]
    fn load_eval_rejects_garbage() {
        let dir = std::env::temp_dir().join("va_accel_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load_eval(&p).is_err());
    }

    #[test]
    fn load_eval_artifact_if_present() {
        // integration-grade check; skipped when artifacts are not built
        let p = std::path::Path::new(crate::ARTIFACT_DIR).join("eval.bin");
        if let Ok(ds) = load_eval(&p) {
            assert!(ds.len() >= 100);
            assert!(ds.x.iter().all(|r| r.len() == crate::REC_LEN));
        }
    }
}
