//! Hermetic test fixtures: deterministic quantized models + eval
//! corpora synthesized in-process from [`super::rng::SplitMix64`].
//!
//! The integration suites used to skip whenever the python build
//! artifacts (`artifacts/weights.bin`, `artifacts/eval.bin`) were
//! absent — which is always, in CI. These fixtures make the
//! golden-vs-chipsim bit-exactness paths (and the fleet/serving
//! benches) fully hermetic: the model has the paper's exact 8-layer
//! geometry, balanced ~50 % weight sparsity and a mixed-bit-width
//! precision profile, and the corpus is the synthetic IEGM generator's
//! output. The weights are random, so anything accuracy-dependent
//! still needs the trained artifact (`#[ignore]`d tests); everything
//! structural — compilation, scheduling, bit-exactness, timing,
//! energy — behaves like the real network.

use super::dataset::Dataset;
use super::rng::SplitMix64;
use crate::nn::{QLayer, QuantModel};

/// Seed for the default fixture model/corpus (tests and benches that
/// want "the" hermetic model share it so compiled models agree).
pub const FIXTURE_SEED: u64 = 0x5EED_CAB1;

/// The paper's 8-layer 1-D CNN geometry: (k, stride, cin, cout, nbits)
/// with 512-sample input, halving to a length-4 head feature map
/// (`compiler::schedule` tests pin the same chain). The precision
/// profile is mixed — mostly 8-bit with two 4-bit mid layers — which
/// keeps the simulated operating point in the paper's envelope.
fn paper_geometry() -> [(usize, usize, usize, usize, u32); 8] {
    [
        (7, 2, 1, 16, 8),
        (5, 2, 16, 32, 8),
        (5, 2, 32, 48, 8),
        (5, 2, 48, 64, 8),
        (5, 2, 64, 64, 4),
        (3, 2, 64, 96, 4),
        (3, 2, 96, 128, 8),
        (1, 1, 128, 2, 8),
    ]
}

/// Deterministically synthesize a paper-shaped quantized model.
///
/// Per output channel exactly `ceil(K·Cin / 2)` weights are non-zero
/// (the compiler's balanced-pruning invariant), drawn uniformly within
/// the layer's `nbits` range; requant multipliers are sized so
/// activations stay varied (not fully saturated) through the stack.
pub fn quant_model(seed: u64) -> QuantModel {
    model_from_geometry(seed, &paper_geometry())
}

/// Deterministically synthesize a model from an arbitrary layer
/// geometry `(k, stride, cin, cout, nbits)` with the same balanced
/// ~50 % sparsity and requant sizing as [`quant_model`].
pub fn model_from_geometry(seed: u64,
                           geometry: &[(usize, usize, usize, usize, u32)])
                           -> QuantModel {
    let mut rng = SplitMix64::new(seed);
    let n = geometry.len();
    let mut layers = Vec::with_capacity(n);
    for (li, &(k, stride, cin, cout, nbits)) in geometry.iter().enumerate() {
        let is_head = li == n - 1;
        let qmax = if nbits == 1 { 1u64 } else { (1u64 << (nbits - 1)) - 1 };
        let kcin = k * cin;
        let nnz = kcin.div_ceil(2); // ~50 % density, balanced per lane
        let mut w = vec![0i32; kcin * cout];
        let mut idx: Vec<usize> = (0..kcin).collect();
        for co in 0..cout {
            // partial Fisher–Yates: the first `nnz` entries are a
            // uniform random subset of the window positions
            for i in 0..nnz {
                let j = i + (rng.next_u64() as usize) % (kcin - i);
                idx.swap(i, j);
            }
            for &pos in &idx[..nnz] {
                let v = 1 + (rng.next_u64() % qmax) as i32;
                let v = if rng.uniform() < 0.5 { -v } else { v };
                w[pos * cout + co] = v;
            }
        }
        let bias: Vec<i32> = (0..cout)
            .map(|_| (rng.next_u64() % 512) as i32 - 256)
            .collect();
        let m0: Vec<i32> = if is_head {
            vec![0; cout]
        } else {
            (0..cout)
                .map(|_| (1 << 12) + (rng.next_u64() % ((1 << 16) - (1 << 12))) as i32)
                .collect()
        };
        layers.push(QLayer {
            k, stride, cin, cout,
            relu: !is_head,
            nbits,
            shift: if is_head { 0 } else { 24 },
            s_in: 1.0,
            s_out: 1.0,
            w, bias, m0,
        });
    }
    let model = QuantModel { layers };
    debug_assert!(model.validate().is_ok());
    model
}

/// The shared default fixture model ([`FIXTURE_SEED`]).
pub fn default_model() -> QuantModel {
    quant_model(FIXTURE_SEED)
}

/// Input length the ragged fixture is scheduled for.
pub const RAGGED_LEN: usize = 64;

/// A deliberately *ragged* fixture: every conv layer's `cout` is NOT a
/// multiple of the array's 16 lanes, so every layer ends in a partial
/// column stripe (`live < m`) with padding lanes — the tile-major
/// layout's hardest corner. Schedule for [`RAGGED_LEN`] samples.
pub fn ragged_model(seed: u64) -> QuantModel {
    model_from_geometry(seed, &[
        (7, 2, 1, 12, 8),  // 1 tile, live 12
        (5, 2, 12, 20, 4), // 2 tiles, last live 4
        (3, 2, 20, 33, 8), // 3 tiles, last live 1
        (1, 1, 33, 2, 8),  // head: 1 tile, live 2
    ])
}

/// The trained artifact when present, the fixture model otherwise —
/// the standard fallback for structural tests (anything where accuracy
/// is not asserted). Silent on purpose; the CLI's `load_model` keeps
/// stricter corrupt-file semantics.
pub fn model_or_artifact() -> QuantModel {
    QuantModel::load(format!("{}/weights.bin", crate::ARTIFACT_DIR))
        .unwrap_or_else(|_| default_model())
}

/// Deterministic evaluation corpus: `4 * n_per_class` quantized
/// synthetic IEGM recordings (class round-robin) with ground truth.
pub fn eval_corpus(seed: u64, n_per_class: usize) -> Dataset {
    Dataset::synthesize(seed, n_per_class, 0.6)
}

/// The shared default eval corpus.
pub fn default_eval(n_per_class: usize) -> Dataset {
    eval_corpus(FIXTURE_SEED, n_per_class)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipConfig;
    use crate::compiler::{compile, BalanceReport};

    #[test]
    fn deterministic_per_seed() {
        let a = quant_model(7);
        let b = quant_model(7);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.w, y.w);
            assert_eq!(x.bias, y.bias);
            assert_eq!(x.m0, y.m0);
        }
        let c = quant_model(8);
        assert_ne!(a.layers[0].w, c.layers[0].w);
    }

    #[test]
    fn paper_shape_and_balance() {
        let m = default_model();
        assert_eq!(m.layers.len(), 8);
        m.validate().unwrap();
        assert_eq!(m.layers[0].cin, 1);
        assert_eq!(m.layers.last().unwrap().cout, 2);
        let s = m.stats(crate::REC_LEN);
        assert!(s.sparsity > 0.40 && s.sparsity < 0.55,
                "fixture sparsity {}", s.sparsity);
        // balanced pruning: every lane of every layer carries the same
        // number of non-zeros (the co-design compiler invariant)
        let r = BalanceReport::of(&m);
        for l in &r.layers {
            assert!(l.is_balanced(), "layer {} unbalanced", l.layer);
        }
    }

    #[test]
    fn ragged_fixture_ends_every_layer_in_a_partial_stripe() {
        let m = ragged_model(3);
        m.validate().unwrap();
        let cm = compile(&m, &ChipConfig::paper_1d(), RAGGED_LEN).unwrap();
        for sched in &cm.schedule.layers {
            let last = sched.stripes.last().unwrap();
            assert!(last.live < cm.cfg.m,
                    "every ragged layer must have a partial last stripe");
        }
        assert_eq!(cm.schedule.layers[2].stripes.len(), 3);
        assert_eq!(cm.schedule.layers[2].stripes[2].live, 1);
    }

    #[test]
    fn compiles_for_the_paper_chip() {
        let m = default_model();
        let cm = compile(&m, &ChipConfig::paper_1d(), crate::REC_LEN).unwrap();
        assert_eq!(cm.schedule.final_len(), 4);
        assert!(cm.compressed_bytes() < 128 * 1024);
    }

    #[test]
    fn corpus_deterministic_and_shaped() {
        let a = eval_corpus(3, 2);
        let b = eval_corpus(3, 2);
        assert_eq!(a.x, b.x);
        assert_eq!(a.len(), 8);
        assert!(a.x.iter().all(|r| r.len() == crate::REC_LEN));
        assert_eq!(a.va_labels().iter().filter(|&&v| v).count(), 4);
    }

    #[test]
    fn fixture_activations_not_degenerate() {
        // the requant sizing must leave the network responsive: two
        // different recordings should not produce identical logits
        let m = default_model();
        let ds = eval_corpus(11, 1);
        let l0 = m.forward(&ds.x[0]);
        let distinct = ds.x.iter().any(|x| m.forward(x) != l0);
        assert!(distinct, "fixture model collapsed to constant logits");
    }
}
