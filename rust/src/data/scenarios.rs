//! Adversarial scenario generator: deterministic, seed-driven stress
//! streams for the 99.95%-accuracy claim.
//!
//! A [`Scenario`] names one parametric perturbation family applied on
//! top of a clean rhythm stream (built from the same
//! [`super::Generator`] corpus model the chip was audited against).
//! [`Scenario::synthesize`] expands it into a [`ScenarioStream`]:
//! continuous raw samples plus per-`REC_LEN`-segment ground truth, to
//! be pushed through the *full* streaming path
//! ([`crate::coordinator::StreamSession`] →
//! [`crate::sim::StreamingEngine`]) by `coordinator::run_scenario` /
//! `benches/scenarios.rs`.
//!
//! Design rules:
//!
//! * **Deterministic.** Everything derives from `Scenario::seed`
//!   through [`SplitMix64`]; the same scenario synthesizes the same
//!   stream forever.
//! * **Perturbation RNG is independent of the base RNG.** The clean
//!   rhythm stream consumes `SplitMix64::new(seed)` exactly as a
//!   clean run would; perturbations draw from a salted second stream.
//!   So [`Scenario::clean_twin`] shares the *identical* underlying
//!   rhythm samples, and "accuracy lost to the perturbation" is a
//!   well-posed A/B measurement.
//! * **Truth is per segment.** Each `REC_LEN` segment carries one
//!   rhythm class; overlapping windows that straddle segments with
//!   conflicting truth are excluded from scoring
//!   ([`ScenarioStream::window_truth`] returns `None`), never guessed.

use super::iegm::{Generator, RhythmClass};
use super::morphology::{add_artifacts, spike_train, SpikeParams};
use super::rng::SplitMix64;
use crate::{FS_HZ, REC_LEN};

const TAU: f64 = 2.0 * std::f64::consts::PI;
/// Salt separating the perturbation RNG stream from the base-rhythm
/// RNG stream (which uses the raw seed, like a clean run).
const PERTURB_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// The perturbation families. `Clean` is the control lane — also what
/// a [`Scenario::clean_twin`] degrades to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// No perturbation: the corpus-distribution control.
    Clean,
    /// Additive white sensor noise at `intensity` RMS on top of the
    /// training noise floor.
    SensorNoise,
    /// Slow two-tone baseline wander (0.23 + 0.47 Hz, below the
    /// 15–55 Hz passband) at `intensity` peak amplitude.
    BaselineWander,
    /// Lead dislodgement: contact-loss dropouts (signal ×0.02) with
    /// make/break transient spikes at each edge; `intensity` scales
    /// how many segments get hit.
    LeadDislodgement,
    /// Mains pickup: amplitude-modulated 50 Hz tone — *inside* the
    /// 15–55 Hz passband, so the filter cannot remove it.
    Powerline,
    /// AGC stress: sensed amplitude ramps linearly from 1.0× down to
    /// `intensity`× across the stream (lead maturation / micro-
    /// dislodgement).
    AmplitudeDrift,
    /// Gradual VT onset: [`SpikeParams`] morphology interpolated from
    /// NSR-nominal to VT-nominal across segments.
    MorphologyDrift,
}

impl Family {
    pub const ALL: [Family; 7] = [
        Family::Clean,
        Family::SensorNoise,
        Family::BaselineWander,
        Family::LeadDislodgement,
        Family::Powerline,
        Family::AmplitudeDrift,
        Family::MorphologyDrift,
    ];

    /// Stable identifier (JSON lanes, CLI tables).
    pub fn name(self) -> &'static str {
        match self {
            Family::Clean => "clean",
            Family::SensorNoise => "sensor-noise",
            Family::BaselineWander => "baseline-wander",
            Family::LeadDislodgement => "lead-dislodgement",
            Family::Powerline => "powerline",
            Family::AmplitudeDrift => "amplitude-drift",
            Family::MorphologyDrift => "morphology-drift",
        }
    }

    fn index(self) -> u64 {
        Family::ALL.iter().position(|&f| f == self).unwrap() as u64
    }

    /// Inverse of [`Family::name`] (CLI `--scenario` parsing).
    pub fn from_name(s: &str) -> Option<Family> {
        Family::ALL.iter().copied().find(|f| f.name() == s)
    }
}

/// One fully-specified adversarial scenario. Cheap to construct and
/// clone; [`synthesize`] does the work.
///
/// [`synthesize`]: Scenario::synthesize
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Unique display/JSON name, e.g. `"sensor-noise-1.2"`.
    pub name: String,
    pub family: Family,
    pub seed: u64,
    /// Stream length in `REC_LEN` segments.
    pub segments: usize,
    /// Family-specific strength (see [`Family`] docs). Unused by
    /// `Clean` and `MorphologyDrift`.
    pub intensity: f64,
    /// Restrict the base rhythm plan to NSR (specificity lanes)
    /// instead of the round-robin four-class corpus plan.
    pub nsr_only: bool,
}

impl Scenario {
    fn base(name: String, family: Family, seed: u64, segments: usize,
            intensity: f64) -> Self {
        Self { name, family, seed, segments: segments.max(1), intensity,
               nsr_only: false }
    }

    /// Unperturbed four-class control.
    pub fn clean(seed: u64, segments: usize) -> Self {
        Self::base("clean".into(), Family::Clean, seed, segments, 0.0)
    }

    /// Unperturbed all-NSR control (the clean-specificity lane the
    /// recalibration acceptance gate scores against).
    pub fn clean_nsr(seed: u64, segments: usize) -> Self {
        Self { nsr_only: true,
               ..Self::base("clean-nsr".into(), Family::Clean, seed,
                            segments, 0.0) }
    }

    /// Additive white noise at `rms` on top of the corpus noise floor.
    pub fn sensor_noise(seed: u64, segments: usize, rms: f64) -> Self {
        Self::base(format!("sensor-noise-{rms:.1}"), Family::SensorNoise,
                   seed, segments, rms)
    }

    /// Sub-passband two-tone wander at peak amplitude `amp`.
    pub fn baseline_wander(seed: u64, segments: usize, amp: f64) -> Self {
        Self::base(format!("baseline-wander-{amp:.1}"),
                   Family::BaselineWander, seed, segments, amp)
    }

    /// Dropout/transient events on roughly `rate` of the segments.
    pub fn lead_dislodgement(seed: u64, segments: usize, rate: f64) -> Self {
        Self::base(format!("lead-dislodgement-{rate:.1}"),
                   Family::LeadDislodgement, seed, segments, rate)
    }

    /// In-band 50 Hz pickup at amplitude `amp`.
    pub fn powerline(seed: u64, segments: usize, amp: f64) -> Self {
        Self::base(format!("powerline-{amp:.1}"), Family::Powerline, seed,
                   segments, amp)
    }

    /// Gain ramp from 1.0× at stream start to `floor`× at stream end.
    pub fn amplitude_drift(seed: u64, segments: usize, floor: f64) -> Self {
        Self::base(format!("amplitude-drift-{floor:.1}"),
                   Family::AmplitudeDrift, seed, segments, floor)
    }

    /// NSR→VT morphology interpolation across `segments`.
    pub fn morphology_drift(seed: u64, segments: usize) -> Self {
        Self::base("morphology-drift".into(), Family::MorphologyDrift, seed,
                   segments, 0.0)
    }

    /// The canonical suite `benches/scenarios.rs` and `vaccel
    /// scenarios` run: one representative per family.
    pub fn standard_suite(seed: u64) -> Vec<Scenario> {
        vec![
            Scenario::clean(seed, 16),
            Scenario::sensor_noise(seed ^ 1, 16, 1.2),
            Scenario::baseline_wander(seed ^ 2, 16, 3.0),
            Scenario::lead_dislodgement(seed ^ 3, 16, 0.4),
            Scenario::powerline(seed ^ 4, 16, 1.5),
            Scenario::amplitude_drift(seed ^ 5, 16, 0.2),
            Scenario::morphology_drift(seed ^ 6, 24),
        ]
    }

    /// The standard-suite representative of `family` at an arbitrary
    /// stream length (same intensities as [`Scenario::standard_suite`]).
    /// Lets callers that are parameterized by [`Family`] alone — the
    /// serving loadgen's `--scenario` flag — pick a canonical instance.
    pub fn representative(family: Family, seed: u64, segments: usize)
                          -> Self {
        match family {
            Family::Clean => Scenario::clean(seed, segments),
            Family::SensorNoise =>
                Scenario::sensor_noise(seed, segments, 1.2),
            Family::BaselineWander =>
                Scenario::baseline_wander(seed, segments, 3.0),
            Family::LeadDislodgement =>
                Scenario::lead_dislodgement(seed, segments, 0.4),
            Family::Powerline => Scenario::powerline(seed, segments, 1.5),
            Family::AmplitudeDrift =>
                Scenario::amplitude_drift(seed, segments, 0.2),
            Family::MorphologyDrift =>
                Scenario::morphology_drift(seed, segments),
        }
    }

    /// A noise-floor sweep (the `benches/robustness.rs` axis, expressed
    /// as scenarios over the streaming path).
    pub fn noise_sweep(seed: u64, segments: usize, levels: &[f64])
                       -> Vec<Scenario> {
        levels.iter()
            .map(|&rms| Scenario::sensor_noise(seed, segments, rms))
            .collect()
    }

    /// The same scenario with the perturbation removed — identical
    /// base rhythm samples (see module docs). `None` for families
    /// where "the same stream, clean" is meaningless (`Clean` itself,
    /// and `MorphologyDrift`, whose drift *is* the rhythm).
    pub fn clean_twin(&self) -> Option<Scenario> {
        match self.family {
            Family::Clean | Family::MorphologyDrift => None,
            _ => Some(Scenario { name: format!("{}-clean-twin", self.name),
                                 family: Family::Clean,
                                 intensity: 0.0,
                                 ..self.clone() }),
        }
    }

    /// Expand into the concrete sample stream + ground truth.
    pub fn synthesize(&self) -> ScenarioStream {
        if self.family == Family::MorphologyDrift {
            return self.synthesize_morphology_drift();
        }
        // base rhythm stream: consumes SplitMix64::new(seed) exactly
        // like a clean run, so perturbed/clean twins share it
        let plan: Vec<(RhythmClass, usize)> = (0..self.segments)
            .map(|i| {
                let class = if self.nsr_only {
                    RhythmClass::Nsr
                } else {
                    RhythmClass::ALL[i % RhythmClass::ALL.len()]
                };
                (class, 1)
            })
            .collect();
        let (mut samples, classes) = Generator::new(self.seed).stream(&plan);
        let truth: Vec<bool> = classes.iter().map(|c| c.is_va()).collect();
        let mut perturbed = vec![false; self.segments];
        let mut rng = SplitMix64::new(
            self.seed ^ PERTURB_SALT ^ (self.family.index() << 32));
        match self.family {
            Family::Clean | Family::MorphologyDrift => {}
            Family::SensorNoise => {
                for s in samples.iter_mut() {
                    *s += self.intensity * rng.gauss();
                }
                perturbed.iter_mut().for_each(|p| *p = true);
            }
            Family::BaselineWander => {
                let ph1 = rng.range(0.0, TAU);
                let ph2 = rng.range(0.0, TAU);
                for (i, s) in samples.iter_mut().enumerate() {
                    let t = i as f64 / FS_HZ;
                    *s += self.intensity * (TAU * 0.23 * t + ph1).sin()
                        + 0.6 * self.intensity * (TAU * 0.47 * t + ph2).sin();
                }
                perturbed.iter_mut().for_each(|p| *p = true);
            }
            Family::Powerline => {
                let ph = rng.range(0.0, TAU);
                for (i, s) in samples.iter_mut().enumerate() {
                    let t = i as f64 / FS_HZ;
                    let am = 1.0 + 0.3 * (TAU * 0.4 * t).sin();
                    *s += self.intensity * am * (TAU * 50.0 * t + ph).sin();
                }
                perturbed.iter_mut().for_each(|p| *p = true);
            }
            Family::AmplitudeDrift => {
                let n = samples.len();
                let denom = (n.saturating_sub(1)).max(1) as f64;
                for (i, s) in samples.iter_mut().enumerate() {
                    let g = 1.0 + (self.intensity - 1.0) * (i as f64 / denom);
                    *s *= g;
                }
                perturbed.iter_mut().for_each(|p| *p = true);
            }
            Family::LeadDislodgement => {
                let events = ((self.segments as f64 * self.intensity).ceil()
                    as usize).max(1);
                let n = samples.len();
                for _ in 0..events {
                    let dur = (rng.range(0.3, 1.2) * FS_HZ) as usize;
                    let start = (rng.uniform()
                        * (n.saturating_sub(dur + 1)) as f64) as usize;
                    let end = (start + dur).min(n);
                    // contact loss: near-total attenuation
                    for s in &mut samples[start..end] {
                        *s *= 0.02;
                    }
                    // make/break transients: exponential-decay spikes
                    // at each edge, alternating polarity per event
                    let tau = 0.08 * FS_HZ; // 80 ms decay
                    let tail = (4.0 * tau) as usize;
                    let amp = rng.range(2.0, 5.0)
                        * if rng.uniform() < 0.5 { 1.0 } else { -1.0 };
                    let mut last_touched = end.saturating_sub(1);
                    for (edge, sign) in [(start, 1.0), (end, -1.0)] {
                        for k in 0..tail {
                            let at = edge + k;
                            if at >= n {
                                break;
                            }
                            samples[at] +=
                                sign * amp * (-(k as f64) / tau).exp();
                            last_touched = last_touched.max(at);
                        }
                    }
                    for seg in start / REC_LEN
                        ..=(last_touched / REC_LEN).min(self.segments - 1)
                    {
                        perturbed[seg] = true;
                    }
                }
            }
        }
        ScenarioStream { samples, classes, truth, perturbed }
    }

    /// Gradual VT onset: segment `j` at interpolation parameter
    /// `λ = j/(segments-1)` from NSR-nominal to VT-nominal, truth
    /// flipping to VA at `λ ≥ 0.5`. Uses the corpus training floor
    /// for wander/noise so only morphology drifts.
    fn synthesize_morphology_drift(&self) -> ScenarioStream {
        let mut rng = SplitMix64::new(self.seed);
        let mut samples = Vec::with_capacity(self.segments * REC_LEN);
        let mut classes = Vec::with_capacity(self.segments);
        let mut truth = Vec::with_capacity(self.segments);
        let mut perturbed = Vec::with_capacity(self.segments);
        let denom = (self.segments.saturating_sub(1)).max(1) as f64;
        for j in 0..self.segments {
            let lambda =
                if self.segments > 1 { j as f64 / denom } else { 1.0 };
            let p = SpikeParams::lerp(SpikeParams::nsr_nominal(),
                                      SpikeParams::vt_nominal(), lambda);
            let mut sig = spike_train(&mut rng, REC_LEN, p);
            // training-floor artifacts (Generator defaults)
            add_artifacts(&mut rng, &mut sig, 0.3, 0.6);
            samples.extend_from_slice(&sig);
            let is_va = lambda >= 0.5;
            classes.push(if is_va { RhythmClass::Vt } else { RhythmClass::Nsr });
            truth.push(is_va);
            perturbed.push(lambda > 0.0 && lambda < 1.0);
        }
        ScenarioStream { samples, classes, truth, perturbed }
    }
}

/// A synthesized scenario: continuous raw samples plus per-segment
/// ground truth (one `REC_LEN` segment per entry of
/// `classes`/`truth`/`perturbed`).
#[derive(Debug, Clone)]
pub struct ScenarioStream {
    /// Raw (pre-filter) samples, `segments × REC_LEN` long.
    pub samples: Vec<f64>,
    /// Rhythm class per segment.
    pub classes: Vec<RhythmClass>,
    /// `classes[i].is_va()`, precomputed.
    pub truth: Vec<bool>,
    /// Segments materially touched by the perturbation (all of them
    /// for global families; only the hit ones for dislodgement).
    pub perturbed: Vec<bool>,
}

impl ScenarioStream {
    /// Number of `REC_LEN` segments.
    pub fn segments(&self) -> usize {
        self.truth.len()
    }

    /// Ground truth for the window covering samples
    /// `[start, start + frame_len)`: `Some(is_va)` when every segment
    /// the window overlaps agrees, `None` for windows that straddle a
    /// rhythm transition (excluded from scoring, never guessed) or
    /// run past the stream.
    pub fn window_truth(&self, start: usize, frame_len: usize)
                        -> Option<bool> {
        if frame_len == 0 || start + frame_len > self.samples.len() {
            return None;
        }
        let first = start / REC_LEN;
        let last = (start + frame_len - 1) / REC_LEN;
        let t = self.truth[first];
        if (first..=last).all(|k| self.truth[k] == t) {
            Some(t)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesis_is_deterministic() {
        for sc in Scenario::standard_suite(0xD21F) {
            let a = sc.synthesize();
            let b = sc.synthesize();
            assert_eq!(a.samples, b.samples, "{}", sc.name);
            assert_eq!(a.truth, b.truth, "{}", sc.name);
        }
    }

    #[test]
    fn suite_covers_all_families_with_unique_names() {
        let suite = Scenario::standard_suite(7);
        let fams: std::collections::HashSet<_> =
            suite.iter().map(|s| s.family).collect();
        assert_eq!(fams.len(), Family::ALL.len());
        let names: std::collections::HashSet<_> =
            suite.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), suite.len());
    }

    #[test]
    fn stream_shape_matches_segments() {
        let st = Scenario::sensor_noise(3, 5, 0.5).synthesize();
        assert_eq!(st.samples.len(), 5 * REC_LEN);
        assert_eq!(st.segments(), 5);
        assert_eq!(st.classes.len(), 5);
        assert_eq!(st.perturbed.len(), 5);
        for (c, &t) in st.classes.iter().zip(&st.truth) {
            assert_eq!(c.is_va(), t);
        }
    }

    #[test]
    fn clean_twin_shares_base_rhythm() {
        let sc = Scenario::powerline(11, 4, 1.5);
        let twin = sc.clean_twin().unwrap();
        let a = sc.synthesize();
        let b = twin.synthesize();
        assert_eq!(a.classes, b.classes);
        assert_eq!(a.truth, b.truth);
        assert_ne!(a.samples, b.samples, "perturbation must do something");
        // and the twin really is the clean control: a third clean
        // scenario at the same seed reproduces it
        let c = Scenario::clean(11, 4).synthesize();
        assert_eq!(b.samples, c.samples);
    }

    #[test]
    fn nsr_only_plan_has_no_va() {
        let st = Scenario::clean_nsr(9, 6).synthesize();
        assert!(st.truth.iter().all(|&t| !t));
        assert!(st.classes.iter().all(|&c| c == RhythmClass::Nsr));
    }

    #[test]
    fn window_truth_excludes_transitions() {
        let st = Scenario::clean(1, 4).synthesize(); // NSR SVT VT VF
        assert_eq!(st.truth, vec![false, false, true, true]);
        // fully inside segment 0
        assert_eq!(st.window_truth(0, REC_LEN), Some(false));
        // straddles the non-VA/non-VA boundary: still scoreable
        assert_eq!(st.window_truth(REC_LEN / 2, REC_LEN), Some(false));
        // straddles SVT→VT: conflicting truth, excluded
        assert_eq!(st.window_truth(REC_LEN + REC_LEN / 2, REC_LEN), None);
        // inside the VA tail
        assert_eq!(st.window_truth(2 * REC_LEN, 2 * REC_LEN), Some(true));
        // off the end / degenerate
        assert_eq!(st.window_truth(3 * REC_LEN + 1, REC_LEN), None);
        assert_eq!(st.window_truth(0, 0), None);
    }

    #[test]
    fn morphology_drift_truth_ramps() {
        let st = Scenario::morphology_drift(5, 24).synthesize();
        assert_eq!(st.segments(), 24);
        assert!(!st.truth[0], "starts NSR");
        assert!(st.truth[23], "ends VT");
        assert_eq!(st.truth.iter().filter(|&&t| t).count(), 12);
        // monotone: once VA, stays VA
        let first_va = st.truth.iter().position(|&t| t).unwrap();
        assert!(st.truth[first_va..].iter().all(|&t| t));
    }

    #[test]
    fn dislodgement_marks_perturbed_segments() {
        let sc = Scenario::lead_dislodgement(13, 8, 0.4);
        let st = sc.synthesize();
        let twin = sc.clean_twin().unwrap().synthesize();
        assert!(st.perturbed.iter().any(|&p| p), "events must land");
        assert_ne!(st.samples, twin.samples);
        // unperturbed segments are untouched
        for (i, &p) in st.perturbed.iter().enumerate() {
            if !p {
                assert_eq!(st.samples[i * REC_LEN..(i + 1) * REC_LEN],
                           twin.samples[i * REC_LEN..(i + 1) * REC_LEN],
                           "segment {i} flagged clean but differs");
            }
        }
    }
}
