//! [2] Fan et al., ISCAS'24: "An Ultra-Low Power Time-Domain based SNN
//! Processor for ECG Classification".
//!
//! Algorithm family: encode the signal as spike trains through a bank
//! of leaky integrate-and-fire (LIF) neurons with heterogeneous
//! thresholds/time-constants, then classify from spike-count features
//! with a trained linear readout (surrogate for the processor's
//! output population).

use super::common::{to_f64, BaselineDetector, PublishedRow};
use crate::data::SplitMix64;

const N_NEURONS: usize = 24;

/// One LIF neuron's parameters.
#[derive(Debug, Clone, Copy)]
struct Lif {
    /// Membrane decay per sample (0..1).
    decay: f64,
    /// Firing threshold.
    threshold: f64,
    /// Rectification mode: +1 positive half-wave, -1 negative, 0 |x|.
    rect: i8,
}

fn neuron_bank() -> Vec<Lif> {
    // heterogeneous bank spanning fast/slow integration and both
    // polarities — fixed (the "hardware"), only the readout trains
    let mut bank = Vec::with_capacity(N_NEURONS);
    let decays = [0.5, 0.7, 0.85, 0.95];
    let thresholds = [0.4, 0.9];
    let rects = [1i8, -1, 0];
    for &d in &decays {
        for &t in &thresholds {
            for &r in &rects {
                bank.push(Lif { decay: d, threshold: t, rect: r });
            }
        }
    }
    bank
}

/// Spike counts of the bank over one recording (the SNN feature map).
pub(crate) fn spike_counts(x: &[i8]) -> Vec<f64> {
    let f = to_f64(x);
    let bank = neuron_bank();
    let mut counts = vec![0.0f64; bank.len()];
    let mut v = vec![0.0f64; bank.len()];
    for &s in &f {
        for (i, nrn) in bank.iter().enumerate() {
            let drive = match nrn.rect {
                1 => s.max(0.0),
                -1 => (-s).max(0.0),
                _ => s.abs(),
            };
            v[i] = v[i] * nrn.decay + drive;
            if v[i] >= nrn.threshold {
                counts[i] += 1.0;
                v[i] = 0.0; // reset
            }
        }
    }
    // normalize to rates
    let n = f.len() as f64;
    counts.iter().map(|c| c / n * 8.0).collect()
}

/// The time-domain SNN baseline.
pub struct TimeDomainSnn {
    w: Vec<f64>,
    b: f64,
    epochs: usize,
    lr: f64,
}

impl Default for TimeDomainSnn {
    fn default() -> Self {
        Self::new()
    }
}

impl TimeDomainSnn {
    pub fn new() -> Self {
        Self { w: vec![0.0; N_NEURONS], b: 0.0, epochs: 80, lr: 0.1 }
    }

    fn score(&self, counts: &[f64]) -> f64 {
        counts.iter().zip(&self.w).map(|(c, w)| c * w).sum::<f64>() + self.b
    }
}

impl BaselineDetector for TimeDomainSnn {
    fn name(&self) -> &'static str {
        "td-snn"
    }

    fn fit(&mut self, xs: &[Vec<i8>], va: &[bool]) {
        let feats: Vec<Vec<f64>> = xs.iter().map(|x| spike_counts(x)).collect();
        let mut rng = SplitMix64::new(0x511);
        // logistic regression on spike rates (the trained readout)
        for ep in 0..self.epochs {
            let lr = self.lr / (1.0 + 0.05 * ep as f64);
            for _ in 0..xs.len() {
                let i = (rng.next_u64() % xs.len() as u64) as usize;
                let y = if va[i] { 1.0 } else { 0.0 };
                let p = 1.0 / (1.0 + (-self.score(&feats[i])).exp());
                let g = p - y;
                for (w, &c) in self.w.iter_mut().zip(&feats[i]) {
                    *w -= lr * g * c;
                }
                self.b -= lr * g;
            }
        }
    }

    fn predict(&self, x: &[i8]) -> bool {
        self.score(&spike_counts(x)) > 0.0
    }

    fn ops_per_inference(&self) -> u64 {
        // LIF update: 2 ops/neuron/sample + readout
        (2 * N_NEURONS * crate::REC_LEN + 2 * N_NEURONS) as u64
    }

    fn published(&self) -> PublishedRow {
        super::common::all_published_rows()[3].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    #[test]
    fn lif_spikes_monotone_with_drive() {
        let weak = spike_counts(&vec![10i8; crate::REC_LEN]);
        let strong = spike_counts(&vec![90i8; crate::REC_LEN]);
        assert!(strong.iter().sum::<f64>() > weak.iter().sum::<f64>());
    }

    #[test]
    fn silent_input_no_spikes() {
        let c = spike_counts(&vec![0i8; crate::REC_LEN]);
        assert!(c.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn learns_the_synthetic_task() {
        let tr = Dataset::synthesize(400, 40, 0.3);
        let te = Dataset::synthesize(401, 15, 0.3);
        let mut d = TimeDomainSnn::new();
        d.fit(&tr.x, &tr.va_labels());
        let acc = te.x.iter().zip(te.va_labels())
            .filter(|(x, t)| d.predict(x) == *t)
            .count() as f64 / te.len() as f64;
        assert!(acc > 0.75, "SNN accuracy {acc}");
    }
}
