//! Shared baseline interface + the published Table-1 constants.

/// A trainable per-recording VA detector.
pub trait BaselineDetector: Send {
    fn name(&self) -> &'static str;
    /// Fit on a labelled corpus of quantized recordings.
    fn fit(&mut self, xs: &[Vec<i8>], va: &[bool]);
    /// Classify one recording (true = VA).
    fn predict(&self, x: &[i8]) -> bool;
    /// Arithmetic operations per inference (the complexity column).
    fn ops_per_inference(&self) -> u64;
    /// The published chip this algorithm family represents.
    fn published(&self) -> PublishedRow;
}

/// Literature constants for one Table-1 column.
#[derive(Debug, Clone)]
pub struct PublishedRow {
    pub label: &'static str,
    pub venue: &'static str,
    pub tech_nm: u32,
    pub sparsity: bool,
    pub feature: &'static str,
    pub area_mm2: Option<f64>,
    pub voltage_v: f64,
    pub freq_hz: f64,
    pub power_uw: f64,
    /// µW/mm² (None where the paper's table says N/A).
    pub density_uw_mm2: Option<f64>,
}

/// The four prior-work rows exactly as printed in Table 1.
pub fn all_published_rows() -> Vec<PublishedRow> {
    vec![
        PublishedRow { label: "TBCAS'19 [4]", venue: "TBCAS 2019",
                       tech_nm: 180, sparsity: false, feature: "ANN",
                       area_mm2: Some(0.92), voltage_v: 1.8, freq_hz: 25e6,
                       power_uw: 13.34, density_uw_mm2: Some(14.50) },
        PublishedRow { label: "ICICM'22 [5]", venue: "ICICM 2022",
                       tech_nm: 180, sparsity: false, feature: "KS-test",
                       area_mm2: Some(1.45), voltage_v: 1.8, freq_hz: 0.26e3,
                       power_uw: 11.76, density_uw_mm2: Some(8.11) },
        PublishedRow { label: "MWSCAS'22 [3]", venue: "MWSCAS 2022",
                       tech_nm: 40, sparsity: false, feature: "ANN/SVM",
                       area_mm2: Some(0.54), voltage_v: 1.1, freq_hz: 100e6,
                       power_uw: 5.10, density_uw_mm2: Some(9.44) },
        PublishedRow { label: "ISCAS'24 [2]", venue: "ISCAS 2024",
                       tech_nm: 40, sparsity: false, feature: "SNN",
                       area_mm2: None, voltage_v: 1.1, freq_hz: 1e6,
                       power_uw: 12.19, density_uw_mm2: None },
    ]
}

/// Helpers shared by the detectors.
pub(crate) fn to_f64(x: &[i8]) -> Vec<f64> {
    x.iter().map(|&v| v as f64 / 127.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_rows_match_paper_table() {
        let rows = all_published_rows();
        assert_eq!(rows.len(), 4);
        // the 14.23x headline: best prior density / ours (0.57)
        let best_prior = rows.iter()
            .filter_map(|r| r.density_uw_mm2)
            .fold(f64::INFINITY, f64::min);
        assert!((best_prior - 8.11).abs() < 1e-9);
        assert!((best_prior / 0.57 - 14.23).abs() < 0.1,
                "density ratio {} vs paper 14.23x", best_prior / 0.57);
    }
}
