//! [4] Zhao, Shang & Lian, TBCAS'19: "A 13.34 µW event-driven
//! patient-specific ANN cardiac arrhythmia classifier".
//!
//! Algorithm family: hand-crafted per-beat/per-window features into a
//! small fully-connected ANN. Here: 36 features (32-bin downsampled
//! rectified envelope + rate/variability statistics) → 16 hidden
//! (ReLU) → 2, trained with plain SGD + momentum and manual backprop
//! (no autodiff dependency — the network is tiny by design, exactly
//! like the silicon it models).

use super::common::{to_f64, BaselineDetector, PublishedRow};
use crate::data::SplitMix64;

const N_BINS: usize = 16;
const N_FEAT: usize = 2 * N_BINS + 6;
const N_HID: usize = 16;

/// Feature vector: per-bin peak-to-mean structure (spikiness — the
/// per-recording AGC removes amplitude differences, so temporal
/// concentration is the signal) + activation statistics.
pub(super) fn features(x: &[i8]) -> Vec<f64> {
    let f = to_f64(x);
    let n = f.len();
    let mut feat = Vec::with_capacity(N_FEAT);
    // 1) per-bin mean |x| and max |x| (spiky trains: max >> mean)
    let bin = n / N_BINS;
    for b in 0..N_BINS {
        let seg = &f[b * bin..(b + 1) * bin];
        let mean = seg.iter().map(|v| v.abs()).sum::<f64>() / bin as f64;
        let max = seg.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        feat.push(mean);
        feat.push(max);
    }
    // 2) threshold-crossing event rate + irregularity (RR surrogate)
    let thr = 0.45;
    let mut events = Vec::new();
    let mut above = false;
    for (i, &v) in f.iter().enumerate() {
        if v.abs() > thr && !above {
            events.push(i);
            above = true;
        } else if v.abs() < thr * 0.5 {
            above = false;
        }
    }
    let rate = events.len() as f64 / n as f64 * crate::FS_HZ * 60.0; // bpm-ish
    let rr: Vec<f64> = events.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
    let rr_mean = if rr.is_empty() { 0.0 } else { rr.iter().sum::<f64>() / rr.len() as f64 };
    let rr_cv = if rr.len() < 2 || rr_mean == 0.0 {
        1.0
    } else {
        let var = rr.iter().map(|v| (v - rr_mean).powi(2)).sum::<f64>() / rr.len() as f64;
        var.sqrt() / rr_mean
    };
    // 3) zero-crossing rate and total power
    let zcr = f.windows(2).filter(|w| w[0].signum() != w[1].signum()).count()
        as f64 / n as f64;
    let power = f.iter().map(|v| v * v).sum::<f64>() / n as f64;
    // kurtosis: spiky (NSR/SVT/VT) ≫ continuous oscillation (VF)
    let kurt = if power > 1e-12 {
        (f.iter().map(|v| v.powi(4)).sum::<f64>() / n as f64)
            / (power * power)
    } else {
        3.0
    };
    let peak = f.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let crest = peak / power.sqrt().max(1e-9);
    feat.push(rate / 300.0);
    feat.push(rr_cv.min(3.0) / 3.0);
    feat.push(zcr);
    feat.push(power * 10.0);
    feat.push(kurt.min(50.0) / 10.0);
    feat.push(crest / 8.0);
    feat
}

/// The event-driven ANN baseline.
pub struct EventAnn {
    w1: Vec<f64>, // [N_FEAT][N_HID]
    b1: Vec<f64>,
    w2: Vec<f64>, // [N_HID][2]
    b2: Vec<f64>,
    /// Feature standardization (fit on the training set).
    mu: Vec<f64>,
    sigma: Vec<f64>,
    epochs: usize,
    lr: f64,
}

impl Default for EventAnn {
    fn default() -> Self {
        Self::new()
    }
}

impl EventAnn {
    pub fn new() -> Self {
        let mut rng = SplitMix64::new(0xA22);
        let mut init = |n: usize, fan_in: f64| -> Vec<f64> {
            (0..n).map(|_| rng.gauss() * (2.0 / fan_in).sqrt()).collect()
        };
        Self {
            w1: init(N_FEAT * N_HID, N_FEAT as f64),
            b1: vec![0.0; N_HID],
            w2: init(N_HID * 2, N_HID as f64),
            b2: vec![0.0; 2],
            mu: vec![0.0; N_FEAT],
            sigma: vec![1.0; N_FEAT],
            epochs: 60,
            lr: 0.05,
        }
    }

    fn standardize(&self, feat: &[f64]) -> Vec<f64> {
        feat.iter().enumerate()
            .map(|(i, &v)| (v - self.mu[i]) / self.sigma[i])
            .collect()
    }

    fn forward(&self, feat: &[f64]) -> ([f64; 2], Vec<f64>) {
        let mut h = vec![0.0; N_HID];
        for j in 0..N_HID {
            let mut s = self.b1[j];
            for (i, &fv) in feat.iter().enumerate() {
                s += fv * self.w1[i * N_HID + j];
            }
            h[j] = s.max(0.0);
        }
        let mut o = [self.b2[0], self.b2[1]];
        for j in 0..N_HID {
            o[0] += h[j] * self.w2[j * 2];
            o[1] += h[j] * self.w2[j * 2 + 1];
        }
        (o, h)
    }
}

impl BaselineDetector for EventAnn {
    fn name(&self) -> &'static str {
        "event-ann"
    }

    fn fit(&mut self, xs: &[Vec<i8>], va: &[bool]) {
        let raw: Vec<Vec<f64>> = xs.iter().map(|x| features(x)).collect();
        // feature standardization (zero mean, unit variance)
        let n = raw.len().max(1) as f64;
        for i in 0..N_FEAT {
            let mu = raw.iter().map(|f| f[i]).sum::<f64>() / n;
            let var = raw.iter().map(|f| (f[i] - mu).powi(2)).sum::<f64>() / n;
            self.mu[i] = mu;
            self.sigma[i] = var.sqrt().max(1e-6);
        }
        let feats: Vec<Vec<f64>> = raw.iter().map(|f| self.standardize(f)).collect();
        let mut rng = SplitMix64::new(0xF17);
        let mut order: Vec<usize> = (0..xs.len()).collect();
        for ep in 0..self.epochs {
            // Fisher-Yates with our deterministic RNG
            for i in (1..order.len()).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                order.swap(i, j);
            }
            let lr = self.lr / (1.0 + ep as f64 * 0.05);
            for &idx in &order {
                let f = &feats[idx];
                let y = usize::from(va[idx]);
                let (o, h) = self.forward(f);
                // softmax CE gradient
                let m = o[0].max(o[1]);
                let e0 = (o[0] - m).exp();
                let e1 = (o[1] - m).exp();
                let z = e0 + e1;
                let p = [e0 / z, e1 / z];
                let go = [p[0] - f64::from(y == 0), p[1] - f64::from(y == 1)];
                // backprop to hidden
                let mut gh = vec![0.0; N_HID];
                for j in 0..N_HID {
                    gh[j] = go[0] * self.w2[j * 2] + go[1] * self.w2[j * 2 + 1];
                    if h[j] <= 0.0 {
                        gh[j] = 0.0;
                    }
                }
                for j in 0..N_HID {
                    self.w2[j * 2] -= lr * go[0] * h[j];
                    self.w2[j * 2 + 1] -= lr * go[1] * h[j];
                }
                self.b2[0] -= lr * go[0];
                self.b2[1] -= lr * go[1];
                for (i, &fv) in f.iter().enumerate() {
                    for j in 0..N_HID {
                        self.w1[i * N_HID + j] -= lr * gh[j] * fv;
                    }
                }
                for j in 0..N_HID {
                    self.b1[j] -= lr * gh[j];
                }
            }
        }
    }

    fn predict(&self, x: &[i8]) -> bool {
        let (o, _) = self.forward(&self.standardize(&features(x)));
        o[1] > o[0]
    }

    fn ops_per_inference(&self) -> u64 {
        // feature extraction ~3 ops/sample + MLP MACs*2
        (3 * crate::REC_LEN + 2 * (N_FEAT * N_HID + N_HID * 2)) as u64
    }

    fn published(&self) -> PublishedRow {
        super::common::all_published_rows()[0].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    #[test]
    fn learns_the_synthetic_task() {
        let tr = Dataset::synthesize(100, 40, 0.3);
        let te = Dataset::synthesize(101, 15, 0.3);
        let mut d = EventAnn::new();
        d.fit(&tr.x, &tr.va_labels());
        let acc = te.x.iter().zip(te.va_labels())
            .filter(|(x, t)| d.predict(x) == *t)
            .count() as f64 / te.len() as f64;
        assert!(acc > 0.8, "event-ANN accuracy {acc}");
    }

    #[test]
    fn features_shape_and_range() {
        let f = features(&vec![0i8; crate::REC_LEN]);
        assert_eq!(f.len(), N_FEAT);
        let mut g = crate::data::Generator::new(5);
        let f2 = features(&g.recording(crate::data::RhythmClass::Vf).quantized());
        assert!(f2.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ops_accounting_positive() {
        assert!(EventAnn::new().ops_per_inference() > 1000);
    }
}
