//! Table-1 baseline comparators.
//!
//! The paper compares against four prior arrhythmia-detection ASICs.
//! Their silicon is obviously not reproducible, but their *algorithms*
//! are — so each module implements the published algorithm family on
//! our common synthetic task, giving the accuracy/complexity half of
//! the comparison, while the published chip figures (tech node, area,
//! voltage, frequency, power) are carried as literature constants for
//! the table itself.
//!
//! | ref | venue | algorithm | module |
//! |---|---|---|---|
//! | [4] Zhao+ | TBCAS'19 | event-driven patient-specific ANN | [`ann`] |
//! | [5] Zhou & Lyu | ICICM'22 | Kolmogorov–Smirnov test | [`kstest`] |
//! | [3] Xing+ | MWSCAS'22 | DWT features + SVM | [`dwt_svm`] |
//! | [2] Fan+ | ISCAS'24 | time-domain SNN (LIF) | [`snn`] |

mod ann;
mod common;
mod dwt_svm;
mod kstest;
mod snn;

pub use ann::EventAnn;
pub use common::{all_published_rows, BaselineDetector, PublishedRow};
pub use dwt_svm::DwtSvm;
pub use kstest::KsTest;
pub use snn::TimeDomainSnn;

/// Construct all four baselines with default hyperparameters.
pub fn all_baselines() -> Vec<Box<dyn BaselineDetector>> {
    vec![
        Box::new(EventAnn::new()),
        Box::new(KsTest::new()),
        Box::new(DwtSvm::new()),
        Box::new(TimeDomainSnn::new()),
    ]
}

/// Debug hook: expose the ANN feature extractor (used by examples and
/// the accuracy bench to inspect feature separability).
pub fn debug_features(x: &[i8]) -> Vec<f64> {
    ann::features(x)
}
