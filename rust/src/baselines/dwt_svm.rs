//! [3] Xing et al., MWSCAS'22: "A 10.8 nJ/detection ECG processor
//! based on DWT and SVM for real-time arrhythmia detection".
//!
//! Algorithm family: discrete wavelet transform subband features into
//! a linear SVM. Implemented from scratch: a 5-level Haar DWT (the
//! hardware-cheapest wavelet), per-subband energy + absolute-sum
//! features, and a linear SVM trained with the Pegasos subgradient
//! method.

use super::common::{to_f64, BaselineDetector, PublishedRow};
use crate::data::SplitMix64;

const LEVELS: usize = 5;
const N_FEAT: usize = 2 * (LEVELS + 1) + 1; // energy+L1 per subband, +bias-ish rate

/// One Haar DWT level: returns (approximation, detail).
fn haar_step(x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x.len() / 2;
    let mut a = Vec::with_capacity(n);
    let mut d = Vec::with_capacity(n);
    const S: f64 = std::f64::consts::FRAC_1_SQRT_2;
    for i in 0..n {
        a.push((x[2 * i] + x[2 * i + 1]) * S);
        d.push((x[2 * i] - x[2 * i + 1]) * S);
    }
    (a, d)
}

/// Full multi-level decomposition: details d1..dL plus final
/// approximation.
pub(crate) fn haar_dwt(x: &[f64], levels: usize) -> Vec<Vec<f64>> {
    let mut out = Vec::with_capacity(levels + 1);
    let mut a = x.to_vec();
    for _ in 0..levels {
        let (na, d) = haar_step(&a);
        out.push(d);
        a = na;
    }
    out.push(a);
    out
}

fn svm_features(x: &[i8]) -> Vec<f64> {
    let f = to_f64(x);
    let bands = haar_dwt(&f, LEVELS);
    let mut feat = Vec::with_capacity(N_FEAT);
    for b in &bands {
        let n = b.len().max(1) as f64;
        feat.push(b.iter().map(|v| v * v).sum::<f64>() / n * 20.0);
        feat.push(b.iter().map(|v| v.abs()).sum::<f64>() / n * 4.0);
    }
    let zcr = f.windows(2).filter(|w| w[0].signum() != w[1].signum()).count()
        as f64 / f.len() as f64;
    feat.push(zcr);
    feat
}

/// The DWT + linear-SVM baseline.
pub struct DwtSvm {
    w: Vec<f64>,
    b: f64,
    lambda: f64,
    epochs: usize,
}

impl Default for DwtSvm {
    fn default() -> Self {
        Self::new()
    }
}

impl DwtSvm {
    pub fn new() -> Self {
        Self { w: vec![0.0; N_FEAT], b: 0.0, lambda: 1e-4, epochs: 80 }
    }

    fn margin(&self, feat: &[f64]) -> f64 {
        feat.iter().zip(&self.w).map(|(x, w)| x * w).sum::<f64>() + self.b
    }
}

impl BaselineDetector for DwtSvm {
    fn name(&self) -> &'static str {
        "dwt-svm"
    }

    fn fit(&mut self, xs: &[Vec<i8>], va: &[bool]) {
        let feats: Vec<Vec<f64>> = xs.iter().map(|x| svm_features(x)).collect();
        let ys: Vec<f64> = va.iter().map(|&v| if v { 1.0 } else { -1.0 }).collect();
        let mut rng = SplitMix64::new(0x5F3);
        let n = xs.len();
        let mut t = 1u64;
        // Pegasos: stochastic subgradient on the hinge loss
        for _ in 0..self.epochs {
            for _ in 0..n {
                let i = (rng.next_u64() % n as u64) as usize;
                let eta = 1.0 / (self.lambda * t as f64);
                let m = ys[i] * self.margin(&feats[i]);
                for w in self.w.iter_mut() {
                    *w *= 1.0 - eta * self.lambda;
                }
                if m < 1.0 {
                    for (w, &f) in self.w.iter_mut().zip(&feats[i]) {
                        *w += eta * ys[i] * f;
                    }
                    self.b += eta * ys[i] * 0.1; // unregularized bias, damped
                }
                t += 1;
            }
        }
    }

    fn predict(&self, x: &[i8]) -> bool {
        self.margin(&svm_features(x)) > 0.0
    }

    fn ops_per_inference(&self) -> u64 {
        // DWT: 2 ops per coefficient over all levels ≈ 4N; features +
        // dot product
        (4 * crate::REC_LEN + 3 * N_FEAT) as u64
    }

    fn published(&self) -> PublishedRow {
        super::common::all_published_rows()[2].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    #[test]
    fn haar_preserves_energy() {
        let x: Vec<f64> = (0..64).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        let bands = haar_dwt(&x, 3);
        let e_in: f64 = x.iter().map(|v| v * v).sum();
        let e_out: f64 = bands.iter().flat_map(|b| b.iter().map(|v| v * v)).sum();
        assert!((e_in - e_out).abs() < 1e-9, "Parseval violated");
    }

    #[test]
    fn haar_of_constant_is_dc_only() {
        let bands = haar_dwt(&vec![2.0; 32], 3);
        for d in &bands[..3] {
            assert!(d.iter().all(|&v| v.abs() < 1e-12));
        }
        assert!(bands[3].iter().all(|&v| v > 0.0));
    }

    #[test]
    fn learns_the_synthetic_task() {
        let tr = Dataset::synthesize(300, 40, 0.3);
        let te = Dataset::synthesize(301, 15, 0.3);
        let mut d = DwtSvm::new();
        d.fit(&tr.x, &tr.va_labels());
        let acc = te.x.iter().zip(te.va_labels())
            .filter(|(x, t)| d.predict(x) == *t)
            .count() as f64 / te.len() as f64;
        assert!(acc > 0.8, "DWT+SVM accuracy {acc}");
    }
}
