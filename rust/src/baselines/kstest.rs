//! [5] Zhou & Lyu, ICICM'22: "A Low-Power Cardiac Signal Processor for
//! Atrial Fibrillation Detection" — a Kolmogorov–Smirnov-test detector.
//!
//! Algorithm family: compare the empirical distribution of a cheap
//! per-window statistic against a calibrated normal-rhythm reference
//! distribution; flag when the KS distance exceeds a threshold. We use
//! the amplitude distribution of the band-passed recording (VF's
//! continuous oscillation vs NSR's spiky sparsity shifts it strongly)
//! and calibrate both the reference CDF and the threshold on the
//! training split (threshold = best Youden J).

use super::common::{to_f64, BaselineDetector, PublishedRow};

const CDF_BINS: usize = 64;

/// Empirical CDF of |x| over [0, 1] with fixed bins.
fn amplitude_cdf(x: &[i8]) -> [f64; CDF_BINS] {
    let f = to_f64(x);
    let mut hist = [0.0f64; CDF_BINS];
    for v in &f {
        let b = ((v.abs() * CDF_BINS as f64) as usize).min(CDF_BINS - 1);
        hist[b] += 1.0;
    }
    let n = f.len() as f64;
    let mut cdf = [0.0f64; CDF_BINS];
    let mut acc = 0.0;
    for (c, h) in cdf.iter_mut().zip(hist) {
        acc += h / n;
        *c = acc;
    }
    cdf
}

/// KS distance between two binned CDFs.
fn ks_distance(a: &[f64; CDF_BINS], b: &[f64; CDF_BINS]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// The KS-test baseline.
pub struct KsTest {
    reference: [f64; CDF_BINS],
    threshold: f64,
}

impl Default for KsTest {
    fn default() -> Self {
        Self::new()
    }
}

impl KsTest {
    pub fn new() -> Self {
        Self { reference: [0.0; CDF_BINS], threshold: 0.2 }
    }

    /// KS statistic of one recording vs the calibrated reference.
    pub fn statistic(&self, x: &[i8]) -> f64 {
        ks_distance(&amplitude_cdf(x), &self.reference)
    }
}

impl BaselineDetector for KsTest {
    fn name(&self) -> &'static str {
        "ks-test"
    }

    fn fit(&mut self, xs: &[Vec<i8>], va: &[bool]) {
        // reference CDF = mean CDF of non-VA training recordings
        let mut count = 0.0;
        let mut refc = [0.0f64; CDF_BINS];
        for (x, &v) in xs.iter().zip(va) {
            if !v {
                let c = amplitude_cdf(x);
                for (r, cv) in refc.iter_mut().zip(c) {
                    *r += cv;
                }
                count += 1.0;
            }
        }
        if count > 0.0 {
            for r in refc.iter_mut() {
                *r /= count;
            }
        }
        self.reference = refc;
        // threshold: maximize Youden's J over the train statistics
        let mut stats: Vec<(f64, bool)> = xs.iter().zip(va)
            .map(|(x, &v)| (self.statistic(x), v))
            .collect();
        stats.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let pos = stats.iter().filter(|s| s.1).count() as f64;
        let neg = stats.len() as f64 - pos;
        let mut best = (0.0, 0.2);
        let mut tp = pos; // everything above threshold = predicted VA
        let mut fp = neg;
        for i in 0..stats.len() {
            // moving threshold just above stats[i]
            if stats[i].1 {
                tp -= 1.0;
            } else {
                fp -= 1.0;
            }
            let j = tp / pos.max(1.0) - fp / neg.max(1.0);
            if j > best.0 {
                let thr = if i + 1 < stats.len() {
                    0.5 * (stats[i].0 + stats[i + 1].0)
                } else {
                    stats[i].0 + 1e-6
                };
                best = (j, thr);
            }
        }
        self.threshold = best.1;
    }

    fn predict(&self, x: &[i8]) -> bool {
        self.statistic(x) > self.threshold
    }

    fn ops_per_inference(&self) -> u64 {
        // histogram (1 op/sample) + CDF + KS scan
        (crate::REC_LEN + 2 * CDF_BINS) as u64
    }

    fn published(&self) -> PublishedRow {
        super::common::all_published_rows()[1].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    #[test]
    fn separates_vf_from_nsr() {
        let tr = Dataset::synthesize(200, 40, 0.3);
        let te = Dataset::synthesize(201, 15, 0.3);
        let mut d = KsTest::new();
        d.fit(&tr.x, &tr.va_labels());
        let acc = te.x.iter().zip(te.va_labels())
            .filter(|(x, t)| d.predict(x) == *t)
            .count() as f64 / te.len() as f64;
        assert!(acc > 0.7, "KS-test accuracy {acc}");
    }

    #[test]
    fn cdf_is_monotone_and_normalized() {
        let mut g = crate::data::Generator::new(7);
        let c = amplitude_cdf(&g.recording(crate::data::RhythmClass::Nsr).quantized());
        assert!(c.windows(2).all(|w| w[1] >= w[0] - 1e-12));
        assert!((c[CDF_BINS - 1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn identical_distributions_have_zero_distance() {
        let a = amplitude_cdf(&vec![5i8; 100]);
        assert_eq!(ks_distance(&a, &a), 0.0);
    }
}
