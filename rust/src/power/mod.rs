//! 40 nm LP power and area model.
//!
//! The paper's headline numbers (10.60 µW average power, 150 GOPS,
//! 0.57 µW/mm², 35 µs/inference) are *measurements* of a fabricated
//! chip; we reproduce them as **cycle counts × per-event energies +
//! leakage**, with constants drawn from published 40 nm LP
//! characterizations (see `energy.rs` doc comments). Two facts make
//! the arithmetic work the way the paper's does:
//!
//! 1. The chip is heavily duty-cycled: one 512-sample recording spans
//!    2.048 s of wall time but only ~tens of µs of compute, so
//!    **average power ≈ leakage + active energy / period**.
//! 2. GOPS is *effective* (dense-equivalent OPs / active time): with
//!    50 % sparsity the array retires 2 dense-equivalent MACs per
//!    non-zero MAC executed.

mod area;
mod energy;
mod report;

pub use area::{area_mm2, AreaModel};
pub use energy::{EnergyModel, EventEnergies};
pub use report::{report, PowerReport};
