//! The chip operating-point report: the numbers Table 1 and §3 quote.

use crate::arch::ChipConfig;
use crate::metrics::effective_gops;
use crate::power::{area_mm2, AreaModel, EnergyModel};
use crate::sim::Counters;

/// Duty-cycle period: one recording = 512 samples at 250 Hz.
pub const RECORDING_PERIOD_S: f64 = 512.0 / 250.0;

/// One configuration's operating point for one inference workload.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Active compute time per inference (s).
    pub t_active_s: f64,
    /// Dynamic energy per inference (J).
    pub e_active_j: f64,
    /// Die area (mm²).
    pub area_mm2: f64,
    /// Leakage power (W).
    pub p_leak_w: f64,
    /// Average power over the recording period (W) — the paper's
    /// "10.60 µW" accounting.
    pub p_avg_w: f64,
    /// Peak (active-window) power (W).
    pub p_active_w: f64,
    /// Effective GOPS during the active window (dense-equivalent).
    pub gops: f64,
    /// Average power density µW/mm² — the paper's headline 0.57.
    pub density_uw_mm2: f64,
    /// Energy per classification (J) including the period's leakage.
    pub e_per_detection_j: f64,
    /// Cycles per inference.
    pub cycles: u64,
}

/// Build the operating-point report for one simulated inference.
pub fn report(c: &Counters, cfg: &ChipConfig, em: &EnergyModel,
              am: &AreaModel) -> PowerReport {
    let cycles = c.total_cycles();
    let t_active = cycles as f64 * cfg.cycle_s();
    let e_active = em.active_energy_j(c, cfg);
    let area = area_mm2(cfg, am);
    let p_leak = em.leakage_w(area);
    let e_detection = e_active + p_leak * RECORDING_PERIOD_S;
    let p_avg = e_detection / RECORDING_PERIOD_S;
    PowerReport {
        t_active_s: t_active,
        e_active_j: e_active,
        area_mm2: area,
        p_leak_w: p_leak,
        p_avg_w: p_avg,
        p_active_w: e_active / t_active + p_leak,
        gops: effective_gops(c.total_macs_dense(), t_active),
        density_uw_mm2: p_avg * 1e6 / area,
        e_per_detection_j: e_detection,
        cycles,
    }
}

impl std::fmt::Display for PowerReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "inference time : {:>9.2} µs  ({} cycles)",
                 self.t_active_s * 1e6, self.cycles)?;
        writeln!(f, "active energy  : {:>9.3} µJ", self.e_active_j * 1e6)?;
        writeln!(f, "performance    : {:>9.1} GOPS (effective)", self.gops)?;
        writeln!(f, "die area       : {:>9.2} mm²", self.area_mm2)?;
        writeln!(f, "leakage        : {:>9.2} µW", self.p_leak_w * 1e6)?;
        writeln!(f, "average power  : {:>9.2} µW  (over {:.3} s recording)",
                 self.p_avg_w * 1e6, RECORDING_PERIOD_S)?;
        writeln!(f, "active power   : {:>9.1} µW", self.p_active_w * 1e6)?;
        write!(f, "power density  : {:>9.3} µW/mm²", self.density_uw_mm2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::LayerCounters;

    fn fake_counters(cycles: u64, macs_dense: u64) -> Counters {
        let mut c = Counters::default();
        c.per_layer.push(LayerCounters {
            cycles,
            macs: macs_dense / 2,
            macs_dense,
            segment_ops: macs_dense * 4,
            ..Default::default()
        });
        c
    }

    #[test]
    fn report_arithmetic() {
        let cfg = ChipConfig::paper_1d();
        let em = EnergyModel::lp40();
        let am = AreaModel::lp40();
        let r = report(&fake_counters(8000, 2_000_000), &cfg, &em, &am);
        // 8000 cycles @ 400 MHz = 20 µs
        assert!((r.t_active_s - 20e-6).abs() < 1e-12);
        // 4 MOPs / 20 µs = 200 GOPS
        assert!((r.gops - 200.0).abs() < 1.0);
        assert!(r.p_avg_w > r.p_leak_w);
        assert!((r.density_uw_mm2 - r.p_avg_w * 1e6 / r.area_mm2).abs() < 1e-9);
    }

    #[test]
    fn average_power_is_leakage_dominated() {
        let cfg = ChipConfig::paper_1d();
        let r = report(&fake_counters(8000, 2_000_000), &cfg,
                       &EnergyModel::lp40(), &AreaModel::lp40());
        assert!(r.p_leak_w / r.p_avg_w > 0.8,
                "duty-cycled chip: leakage should dominate average power");
    }
}
