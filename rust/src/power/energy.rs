//! Per-event energy constants (TSMC 40 nm LP, 1.14 V, 25 °C) and the
//! counters→joules conversion.
//!
//! Constants are order-of-magnitude anchored to published 40 nm
//! numbers (Horowitz ISSCC'14 energy table scaled 45→40 nm and
//! 0.9→1.14 V; Eyeriss-class RF/SPad characterizations) and then
//! calibrated once so the paper workload lands at its measured
//! operating point (DESIGN.md §Perf records the calibration). They are
//! **inputs to a model, not measurements** — the reproducible content
//! is the *relative* structure: how energy splits across datapath vs
//! memory vs control, and how it scales with sparsity, precision, and
//! SPad organization.

use crate::arch::ChipConfig;
use crate::sim::Counters;

/// Energy per architectural event, in joules.
#[derive(Debug, Clone)]
pub struct EventEnergies {
    /// One CMUL 1-bit segment op (MUX + add slice). An 8-bit MAC is 8
    /// of these; the precision knob of Fig. 3.
    pub segment: f64,
    /// SPad SRAM read (one activation word).
    pub spad_read: f64,
    /// SPad SRAM write.
    pub spad_write: f64,
    /// Activation register-file broadcast.
    pub reg: f64,
    /// FIFO push+pop (PerPe organization only).
    pub fifo: f64,
    /// Weight-buffer fetch of one compressed (weight, select) pair,
    /// broadcast across the SPE row.
    pub weight_fetch: f64,
    /// Output activation write-back.
    pub out_write: f64,
    /// One MPE pooling element op.
    pub pool: f64,
    /// Clock tree + control per cycle per engaged SPE (the "simple
    /// control logic" — the shared-SPad design removes asynchronous
    /// handshakes, which is why this is small).
    pub ctrl_per_spe_cycle: f64,
}

impl EventEnergies {
    /// Calibrated 40 nm LP @ 1.14 V values.
    pub fn lp40() -> Self {
        Self {
            segment: 0.080e-12,
            spad_read: 1.10e-12,
            spad_write: 1.30e-12,
            reg: 0.05e-12,
            fifo: 0.90e-12,
            weight_fetch: 0.60e-12,
            out_write: 1.50e-12,
            pool: 0.40e-12,
            ctrl_per_spe_cycle: 1.20e-12,
        }
    }

    /// Dynamic energy scales with V² (constants are referenced to the
    /// paper's 1.14 V supply).
    pub fn at_voltage(&self, v: f64) -> Self {
        let s = (v / 1.14) * (v / 1.14);
        Self {
            segment: self.segment * s,
            spad_read: self.spad_read * s,
            spad_write: self.spad_write * s,
            reg: self.reg * s,
            fifo: self.fifo * s,
            weight_fetch: self.weight_fetch * s,
            out_write: self.out_write * s,
            pool: self.pool * s,
            ctrl_per_spe_cycle: self.ctrl_per_spe_cycle * s,
        }
    }
}

/// Energy model = event energies + leakage density.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub events: EventEnergies,
    /// Static (leakage) power density, W per mm², at 1.14 V. 40 nm LP
    /// is a low-leakage process; the large die leaks ~10 µW total —
    /// the dominant term of the paper's 10.60 µW average.
    pub leak_w_per_mm2: f64,
}

impl EnergyModel {
    pub fn lp40() -> Self {
        Self { events: EventEnergies::lp40(), leak_w_per_mm2: 0.540e-6 }
    }

    /// Leakage scales roughly linearly with V around the nominal point
    /// (subthreshold; DIBL makes it superlinear but the range we sweep
    /// is narrow).
    pub fn at_voltage(&self, v: f64) -> Self {
        Self {
            events: self.events.at_voltage(v),
            leak_w_per_mm2: self.leak_w_per_mm2 * (v / 1.14),
        }
    }

    /// Active (dynamic) energy of one simulated inference.
    pub fn active_energy_j(&self, c: &Counters, cfg: &ChipConfig) -> f64 {
        let t = c.total();
        let e = &self.events;
        let mut j = 0.0;
        j += t.segment_ops as f64 * e.segment;
        j += t.spad.reads as f64 * e.spad_read;
        j += t.spad.writes as f64 * e.spad_write;
        j += t.spad.reg_loads as f64 * e.reg;
        j += t.spad.fifo_ops as f64 * e.fifo;
        j += t.weight_fetches as f64 * e.weight_fetch;
        j += t.output_writes as f64 * e.out_write;
        j += t.pool_ops as f64 * e.pool;
        j += c.total_cycles() as f64
            * cfg.engaged_spes() as f64
            * e.ctrl_per_spe_cycle;
        j
    }

    /// Static power of a die of `area_mm2`.
    pub fn leakage_w(&self, area_mm2: f64) -> f64 {
        area_mm2 * self.leak_w_per_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::LayerCounters;

    fn counters() -> Counters {
        let mut c = Counters::default();
        let mut l = LayerCounters::default();
        l.cycles = 1000;
        l.segment_ops = 8000;
        l.spad.reads = 500;
        l.spad.writes = 200;
        l.weight_fetches = 300;
        l.output_writes = 100;
        c.per_layer.push(l);
        c
    }

    #[test]
    fn energy_positive_and_decomposable() {
        let m = EnergyModel::lp40();
        let cfg = crate::arch::ChipConfig::paper_1d();
        let j = m.active_energy_j(&counters(), &cfg);
        assert!(j > 0.0);
        // segment term alone: 8000 * 0.08 pJ = 0.64 nJ
        assert!(j > 8000.0 * 0.08e-12);
    }

    #[test]
    fn voltage_scaling_quadratic_dynamic_linear_leak() {
        let m = EnergyModel::lp40();
        let half = m.at_voltage(0.57);
        assert!((half.events.segment / m.events.segment - 0.25).abs() < 1e-9);
        assert!((half.leak_w_per_mm2 / m.leak_w_per_mm2 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn leakage_dominates_at_paper_operating_point() {
        // the physical story of the 10.60 µW claim: a duty-cycled chip
        // whose average power is mostly leakage
        let m = EnergyModel::lp40();
        let leak = m.leakage_w(18.63);
        assert!(leak > 9e-6 && leak < 11e-6, "{leak}");
    }
}
