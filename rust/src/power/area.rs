//! Area model (40 nm LP).
//!
//! The paper fabricates 18.63 mm² for 512 PEs ("to accommodate other
//! NN models ... the chip size can be scaled down as needed"). The
//! model decomposes that into per-unit areas so configuration sweeps
//! (`design_space` example) scale believably; constants are calibrated
//! so `ChipConfig::paper()` reproduces the published die size.

use crate::arch::ChipConfig;

/// Per-unit silicon areas in mm².
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// One PE lane (CMUL + accumulator + select MUX).
    pub pe_mm2: f64,
    /// Per-SPE overhead (activation regfile, shared-SPad port, ctrl).
    pub spe_overhead_mm2: f64,
    /// SRAM density for SPads and buffers.
    pub sram_mm2_per_kb: f64,
    /// Fixed overhead: pads, clock, top-level control, the UI/demo
    /// interface logic.
    pub fixed_mm2: f64,
    /// Extra per-PE area for the per-PE-SPad (Eyeriss-v2-style)
    /// organization: private SPad + FIFO + async control.
    pub per_pe_spad_extra_mm2: f64,
}

impl AreaModel {
    pub fn lp40() -> Self {
        Self {
            pe_mm2: 0.021,
            spe_overhead_mm2: 0.045,
            sram_mm2_per_kb: 0.016,
            fixed_mm2: 3.37,
            per_pe_spad_extra_mm2: 0.008,
        }
    }
}

/// Die area of a configuration in mm².
pub fn area_mm2(cfg: &ChipConfig, m: &AreaModel) -> f64 {
    let pes = cfg.total_pes() as f64;
    let spes = (cfg.total_pes() / cfg.m) as f64;
    let spad_kb = spes * cfg.spad_bytes as f64 / 1024.0;
    let wbuf_kb = cfg.weight_buf_bytes as f64 / 1024.0;
    let mut a = m.fixed_mm2
        + pes * m.pe_mm2
        + spes * m.spe_overhead_mm2
        + (spad_kb + wbuf_kb) * m.sram_mm2_per_kb;
    if matches!(cfg.spad_sharing, crate::arch::SpadSharing::PerPe) {
        a += pes * m.per_pe_spad_extra_mm2;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipConfig, SpadSharing};

    #[test]
    fn paper_die_area_reproduced() {
        let a = area_mm2(&ChipConfig::paper(), &AreaModel::lp40());
        assert!((a - 18.63).abs() < 0.5, "area {a} vs paper 18.63 mm²");
    }

    #[test]
    fn smaller_array_smaller_die() {
        let mut small = ChipConfig::paper();
        small.n = 1;
        small.w = 1;
        small.cores_engaged = 1;
        let m = AreaModel::lp40();
        assert!(area_mm2(&small, &m) < area_mm2(&ChipConfig::paper(), &m));
    }

    #[test]
    fn per_pe_spads_cost_area() {
        let m = AreaModel::lp40();
        let shared = ChipConfig::paper();
        let mut private = ChipConfig::paper();
        private.spad_sharing = SpadSharing::PerPe;
        let delta = area_mm2(&private, &m) - area_mm2(&shared, &m);
        assert!(delta > 3.0, "512 private SPads must cost mm², got {delta}");
    }
}
