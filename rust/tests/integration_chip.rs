//! Chip-level integration: the simulator + power model must land in
//! the paper's operating envelope on the real workload, and the
//! architecture knobs must move the numbers in the right direction.
//!
//! Hermetic: when the trained `weights.bin` is absent the fixture
//! model stands in — it has the paper's exact geometry, balanced ~50 %
//! sparsity and a mixed-precision profile, so the operating envelope
//! (timing/energy/area, NOT accuracy) is representative.

use va_accel::arch::{ChipConfig, SpadSharing};
use va_accel::compiler::compile;
use va_accel::data::{fixtures, Generator, RhythmClass};
use va_accel::nn::QuantModel;
use va_accel::power::{report, AreaModel, EnergyModel};
use va_accel::sim;
use va_accel::REC_LEN;

fn setup() -> (QuantModel, Vec<i8>) {
    let m = fixtures::model_or_artifact();
    let mut gen = Generator::new(9);
    let x = gen.recording(RhythmClass::Vt).quantized();
    (m, x)
}

#[test]
fn operating_point_in_paper_envelope() {
    let (m, x) = setup();
    let cfg = ChipConfig::paper_1d();
    let cm = compile(&m, &cfg, REC_LEN).unwrap();
    let r = sim::run(&cm, &x);
    let rep = report(&r.counters, &cfg, &EnergyModel::lp40(), &AreaModel::lp40());
    // paper: 35 µs, 150 GOPS, 10.60 µW, 18.63 mm², 0.57 µW/mm².
    // simulator must land in the same decade with the right ordering.
    let t_us = rep.t_active_s * 1e6;
    assert!(t_us > 5.0 && t_us < 70.0, "inference {t_us} µs vs paper 35 µs");
    assert!(rep.gops > 75.0 && rep.gops < 300.0,
            "{} GOPS vs paper 150", rep.gops);
    let p_uw = rep.p_avg_w * 1e6;
    assert!(p_uw > 5.0 && p_uw < 21.0, "{p_uw} µW vs paper 10.60 µW");
    assert!((rep.area_mm2 - 18.63).abs() < 0.5, "{} mm²", rep.area_mm2);
    assert!(rep.density_uw_mm2 > 0.3 && rep.density_uw_mm2 < 1.2,
            "{} µW/mm² vs paper 0.57", rep.density_uw_mm2);
}

#[test]
fn zero_skip_speeds_up_by_sparsity_factor() {
    let (m, x) = setup();
    let sparse = compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let mut dense_cfg = ChipConfig::paper_1d();
    dense_cfg.zero_skip = false;
    let dense = compile(&m, &dense_cfg, REC_LEN).unwrap();
    let cs = sim::run(&sparse, &x).counters.total_cycles() as f64;
    let cd = sim::run(&dense, &x).counters.total_cycles() as f64;
    let speedup = cd / cs;
    // ~50 % network sparsity with balanced lanes → ~1.5–2.0× fewer
    // cycles (input load + control overheads dilute the ideal 2×)
    assert!(speedup > 1.3 && speedup < 2.1, "zero-skip speedup {speedup}");
}

#[test]
fn shared_spad_saves_energy_vs_per_pe() {
    let (m, x) = setup();
    let em = EnergyModel::lp40();
    let shared_cfg = ChipConfig::paper_1d();
    let mut perpe_cfg = ChipConfig::paper_1d();
    perpe_cfg.spad_sharing = SpadSharing::PerPe;
    let cm_s = compile(&m, &shared_cfg, REC_LEN).unwrap();
    let cm_p = compile(&m, &perpe_cfg, REC_LEN).unwrap();
    let e_s = em.active_energy_j(&sim::run(&cm_s, &x).counters, &shared_cfg);
    let e_p = em.active_energy_j(&sim::run(&cm_p, &x).counters, &perpe_cfg);
    assert!(e_p / e_s > 1.5,
            "per-PE SPads must cost energy: {:.2}x", e_p / e_s);
    // and area (the paper's 'area-power-efficient' claim)
    let am = AreaModel::lp40();
    assert!(va_accel::power::area_mm2(&perpe_cfg, &am)
            > va_accel::power::area_mm2(&shared_cfg, &am));
}

#[test]
fn lower_precision_cuts_cycles_and_energy() {
    let (m, x) = setup();
    // re-quantize the weights as-if 4/2-bit by masking LSBs (structural
    // sweep: this changes numerics but exercises the timing/energy knob)
    let cfg = ChipConfig::paper_1d();
    let em = EnergyModel::lp40();
    let mut cycles = Vec::new();
    let mut energy = Vec::new();
    for nbits in [8u32, 4, 2] {
        let mut mm = m.clone();
        for ly in &mut mm.layers {
            ly.nbits = nbits;
            let qmax = if nbits == 1 { 1 } else { (1 << (nbits - 1)) - 1 };
            for w in &mut ly.w {
                *w = (*w).clamp(-qmax, qmax);
            }
        }
        let cm = compile(&mm, &cfg, REC_LEN).unwrap();
        let r = sim::run(&cm, &x);
        cycles.push(r.counters.total_cycles());
        energy.push(em.active_energy_j(&r.counters, &cfg));
    }
    assert!(cycles[1] < cycles[0] && cycles[2] < cycles[1],
            "cycles must fall with precision: {cycles:?}");
    assert!(energy[1] < energy[0] && energy[2] < energy[1],
            "energy must fall with precision: {energy:?}");
}

#[test]
fn full_array_2d_mode_is_faster_than_1d_engagement() {
    let (m, x) = setup();
    let cm_1d = compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let cm_2d = compile(&m, &ChipConfig::paper(), REC_LEN).unwrap();
    let c1 = sim::run(&cm_1d, &x);
    let c2 = sim::run(&cm_2d, &x);
    assert_eq!(c1.logits, c2.logits, "engagement must not change numerics");
    assert!(c2.counters.total_cycles() < c1.counters.total_cycles(),
            "512-PE engagement must beat 128-PE");
}

/// Property (seed-swept, artifact-independent): for RANDOM small
/// quantized networks and random inputs, the cycle-accurate simulator
/// must agree bit-exactly with the golden integer model, under random
/// chip geometries, precisions, and sparsity levels. This is the
/// compiler+simulator correctness property that the fixed-artifact
/// tests cannot cover.
#[test]
fn property_random_models_sim_equals_golden() {
    use va_accel::data::SplitMix64;
    use va_accel::nn::QLayer;

    for seed in 0..40u64 {
        let mut rng = SplitMix64::new(0xC0FFEE + seed);
        // random 2-4 layer network
        let n_layers = 2 + (rng.next_u64() % 3) as usize;
        let mut layers = Vec::new();
        let mut cin = 1 + (rng.next_u64() % 3) as usize;
        let cin0 = cin;
        let l_in = 32 + 8 * (rng.next_u64() % 4) as usize;
        let mut l = l_in;
        for li in 0..n_layers {
            let k = [1, 3, 5][(rng.next_u64() % 3) as usize];
            // 'same' padding needs k >= stride; halving needs even L
            let stride = if k > 1 && l % 2 == 0 && l >= 2 * k {
                1 + (rng.next_u64() % 2) as usize
            } else {
                1
            };
            let cout = if li == n_layers - 1 { 2 } else { 1 + (rng.next_u64() % 24) as usize };
            let nbits = [8u32, 4, 2, 1][(rng.next_u64() % 4) as usize];
            let qmax = if nbits == 1 { 1 } else { (1 << (nbits - 1)) - 1 };
            let sparsity = rng.uniform();
            let w: Vec<i32> = (0..k * cin * cout)
                .map(|_| {
                    if rng.uniform() < sparsity {
                        0
                    } else {
                        let v = 1 + (rng.next_u64() % qmax as u64) as i32;
                        if rng.uniform() < 0.5 { -v } else { v }
                    }
                })
                .collect();
            let bias: Vec<i32> = (0..cout)
                .map(|_| (rng.next_u64() % 2000) as i32 - 1000)
                .collect();
            let m0: Vec<i32> = (0..cout)
                .map(|_| 1 + (rng.next_u64() % (1 << 24)) as i32)
                .collect();
            let is_head = li == n_layers - 1;
            layers.push(QLayer {
                k, stride, cin, cout,
                relu: !is_head && rng.uniform() < 0.8,
                nbits,
                shift: if is_head { 0 } else { 24 },
                s_in: 1.0, s_out: 1.0, w, bias, m0,
            });
            l /= stride;
            cin = cout;
        }
        let model = QuantModel { layers };
        // random engagement geometry
        let mut cfg = if rng.uniform() < 0.5 {
            ChipConfig::paper_1d()
        } else {
            ChipConfig::paper()
        };
        cfg.zero_skip = rng.uniform() < 0.8;
        let cm = match compile(&model, &cfg, l_in) {
            Ok(cm) => cm,
            Err(e) => panic!("seed {seed}: compile failed: {e}"),
        };
        for _ in 0..3 {
            let x: Vec<i8> = (0..l_in * cin0)
                .map(|_| (rng.next_u64() % 255) as i32 - 127)
                .map(|v| v as i8)
                .collect();
            let golden = model.forward(&x);
            let simr = sim::run(&cm, &x);
            assert_eq!(simr.logits, golden, "seed {seed}");
        }
        let _ = l; // geometry bookkeeping
    }
}
