//! The crate's central correctness claim: FIVE execution paths compute
//! the identical integer function.
//!
//!   python jnp reference ──(audited at build time, eval.bin)──┐
//!   python Pallas kernels ──(AOT HLO artifact)──► PJRT runtime │
//!   rust golden model (nn::QuantModel) ◄──────────── weights.bin
//!   rust chip simulator (sim::run over compiler output)        │
//!                                                              ▼
//!                 all must agree BIT-EXACTLY on real recordings
//!
//! The golden-vs-chipsim half of that claim is **hermetic**: it runs on
//! the deterministic fixture model + synthetic IEGM corpus
//! (`data::fixtures`), so `cargo test` exercises it on every fresh
//! checkout with zero artifacts. Only the PJRT paths still need
//! `make artifacts` (and the `pjrt` cargo feature); those are
//! `#[ignore]`d with a reason instead of silently returning early.

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::data::{fixtures, load_eval, Dataset};
use va_accel::nn::QuantModel;
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

/// The hermetic stand-ins: paper-shaped model + synthetic corpus. When
/// the trained artifacts exist they are used INSTEAD, so CI covers the
/// fixture and a full build covers the real network with the same
/// assertions.
fn model_and_corpus(n: usize) -> (QuantModel, Dataset) {
    if let Ok(m) = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")) {
        if let Ok(ds) = load_eval(format!("{ARTIFACT_DIR}/eval.bin")) {
            let ds = Dataset {
                x: ds.x.into_iter().take(n).collect(),
                labels: ds.labels.into_iter().take(n).collect(),
            };
            return (m, ds);
        }
    }
    (fixtures::default_model(), fixtures::default_eval(n.div_ceil(4)))
}

#[test]
fn golden_equals_chipsim_on_eval_corpus() {
    let (model, ds) = model_and_corpus(32);
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    assert!(!ds.is_empty());
    // one arena PER PATH across the corpus, like the serving hot paths
    let mut scratch = sim::ScratchArena::for_model(&cm);
    let mut counted_scratch = sim::ScratchArena::for_model(&cm);
    let mut golden_scratch = sim::ScratchArena::new();
    for (i, x) in ds.x.iter().enumerate() {
        let golden = model.forward(x);
        assert_eq!(model.forward_scratch(x, &mut golden_scratch), golden,
                   "recording {i}: forward_scratch twin");
        let simr = sim::run_scratch(&cm, x, &mut scratch);
        assert_eq!(simr.logits, golden, "recording {i}");
        assert_eq!(sim::run_counted_scratch(&cm, x, &mut counted_scratch).logits,
                   golden, "recording {i}");
    }
}

#[test]
fn fast_counted_and_parallel_engines_agree_on_eval_corpus() {
    // the threefold invariant on real(istic) recordings: logits AND
    // counters identical between run (fast path, precompiled static
    // counters), run_counted (dynamic reference), and the forced
    // serial/parallel tile loops
    let (model, ds) = model_and_corpus(12);
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    for (i, x) in ds.x.iter().enumerate() {
        let fast = sim::run(&cm, x);
        let counted = sim::run_counted(&cm, x);
        let a = sim::run_serial(&cm, x);
        let b = sim::run_parallel(&cm, x);
        for r in [&counted, &a, &b] {
            assert_eq!(fast.logits, r.logits, "recording {i}");
            assert_eq!(fast.predicted, r.predicted, "recording {i}");
            assert_eq!(fast.counters, r.counters, "recording {i} counters");
        }
    }
    // and across the batch paths (fast totals are static × n; the
    // counted reference accumulates per recording)
    let (rs, ts) = sim::run_batch(&cm, &ds.x);
    let (rp, tp) = sim::run_batch_parallel(&cm, &ds.x);
    assert_eq!(ts, tp);
    for (a, b) in rs.iter().zip(&rp) {
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.counters, b.counters);
    }
    let mut counted_total = sim::Counters::default();
    for x in &ds.x {
        counted_total.merge(&sim::run_counted(&cm, x).counters);
    }
    assert_eq!(ts, counted_total);
}

#[test]
fn zero_skip_does_not_change_numerics_on_paper_shaped_model() {
    let (model, ds) = model_and_corpus(6);
    let mut dense_cfg = ChipConfig::paper_1d();
    dense_cfg.zero_skip = false;
    let cm_sparse = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let cm_dense = compile(&model, &dense_cfg, REC_LEN).unwrap();
    for x in &ds.x {
        assert_eq!(sim::run(&cm_sparse, x).logits, sim::run(&cm_dense, x).logits);
    }
}

#[test]
fn engagement_geometry_does_not_change_numerics() {
    let (model, ds) = model_and_corpus(4);
    let cm_1d = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let cm_2d = compile(&model, &ChipConfig::paper(), REC_LEN).unwrap();
    for x in &ds.x {
        assert_eq!(sim::run(&cm_1d, x).logits, sim::run(&cm_2d, x).logits);
    }
}

// ---------------------------------------------------------------------
// PJRT paths: need `make artifacts` AND a build with `--features pjrt`
// (plus a local xla dependency). Ignored with a reason, never skipped
// silently.
// ---------------------------------------------------------------------

#[test]
#[ignore = "requires AOT artifacts (`make artifacts`) and the `pjrt` cargo feature"]
fn pjrt_equals_golden_on_eval_corpus() {
    use va_accel::runtime::Executor;
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")).unwrap();
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin")).unwrap();
    let exe = Executor::open(ARTIFACT_DIR).unwrap();
    let xs: Vec<Vec<i8>> = ds.x.into_iter().take(32).collect();
    let outs = exe.infer_batch(&xs).unwrap();
    for (i, (x, out)) in xs.iter().zip(&outs).enumerate() {
        let golden = model.forward(x);
        assert_eq!(out.logits.to_vec(), golden, "recording {i}");
    }
}

#[test]
#[ignore = "requires AOT artifacts (`make artifacts`) and the `pjrt` cargo feature"]
fn pjrt_batch_variants_agree() {
    use va_accel::runtime::Executor;
    let exe = Executor::open(ARTIFACT_DIR).unwrap();
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin")).unwrap();
    let xs: Vec<Vec<i8>> = ds.x.into_iter().take(6).collect();
    // batch-1 path
    let one: Vec<[i32; 2]> = xs.iter()
        .map(|x| exe.infer_one(x).unwrap().logits)
        .collect();
    // batch-6 path (padded artifact execution)
    let six: Vec<[i32; 2]> = exe.infer_batch(&xs).unwrap()
        .iter().map(|o| o.logits).collect();
    assert_eq!(one, six);
}

#[test]
#[ignore = "requires Pallas AOT artifacts (`make artifacts`) and the `pjrt` cargo feature"]
fn pallas_and_ref_lowerings_agree_through_pjrt() {
    // the runtime ships the fast jnp-ref lowering; the Pallas/CMUL
    // lowering is the semantics artifact. Both must compute the same
    // integer function on the rust PJRT client.
    let mut rt = va_accel::runtime::Runtime::cpu().unwrap();
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin")).unwrap();
    for x in ds.x.iter().take(8) {
        let a = rt.infer(format!("{ARTIFACT_DIR}/model_b1.hlo.txt"), 1,
                         std::slice::from_ref(x)).unwrap();
        let b = rt.infer(format!("{ARTIFACT_DIR}/model_pallas_b1.hlo.txt"), 1,
                         std::slice::from_ref(x)).unwrap();
        assert_eq!(a, b);
    }
}
