//! The crate's central correctness claim: FIVE execution paths compute
//! the identical integer function.
//!
//!   python jnp reference ──(audited at build time, eval.bin)──┐
//!   python Pallas kernels ──(AOT HLO artifact)──► PJRT runtime │
//!   rust golden model (nn::QuantModel) ◄──────────── weights.bin
//!   rust chip simulator (sim::run over compiler output)        │
//!                                                              ▼
//!                 all must agree BIT-EXACTLY on real recordings
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a note) when the artifacts are absent so `cargo test` stays
//! green on a fresh checkout.

use va_accel::arch::ChipConfig;
use va_accel::compiler::compile;
use va_accel::data::{load_eval, Dataset};
use va_accel::nn::QuantModel;
use va_accel::runtime::Executor;
use va_accel::sim;
use va_accel::{ARTIFACT_DIR, REC_LEN};

fn artifacts_ready() -> bool {
    std::path::Path::new(ARTIFACT_DIR).join("weights.bin").exists()
        && std::path::Path::new(ARTIFACT_DIR).join("model_b1.hlo.txt").exists()
}

fn eval_subset(n: usize) -> Dataset {
    let ds = load_eval(format!("{ARTIFACT_DIR}/eval.bin")).expect("eval.bin");
    Dataset {
        x: ds.x.into_iter().take(n).collect(),
        labels: ds.labels.into_iter().take(n).collect(),
    }
}

#[test]
fn golden_equals_chipsim_on_eval_corpus() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")).unwrap();
    let cm = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let ds = eval_subset(64);
    for (i, x) in ds.x.iter().enumerate() {
        let golden = model.forward(x);
        let simr = sim::run(&cm, x);
        assert_eq!(simr.logits, golden, "recording {i}");
    }
}

#[test]
fn pjrt_equals_golden_on_eval_corpus() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")).unwrap();
    let exe = Executor::open(ARTIFACT_DIR).unwrap();
    let ds = eval_subset(32);
    let outs = exe.infer_batch(&ds.x).unwrap();
    for (i, (x, out)) in ds.x.iter().zip(&outs).enumerate() {
        let golden = model.forward(x);
        assert_eq!(out.logits.to_vec(), golden, "recording {i}");
    }
}

#[test]
fn pjrt_batch_variants_agree() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let exe = Executor::open(ARTIFACT_DIR).unwrap();
    let ds = eval_subset(6);
    // batch-1 path
    let one: Vec<[i32; 2]> = ds.x.iter()
        .map(|x| exe.infer_one(x).unwrap().logits)
        .collect();
    // batch-6 path (padded artifact execution)
    let six: Vec<[i32; 2]> = exe.infer_batch(&ds.x).unwrap()
        .iter().map(|o| o.logits).collect();
    assert_eq!(one, six);
}

#[test]
fn zero_skip_does_not_change_numerics_on_real_model() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let model = QuantModel::load(format!("{ARTIFACT_DIR}/weights.bin")).unwrap();
    let mut dense_cfg = ChipConfig::paper_1d();
    dense_cfg.zero_skip = false;
    let cm_sparse = compile(&model, &ChipConfig::paper_1d(), REC_LEN).unwrap();
    let cm_dense = compile(&model, &dense_cfg, REC_LEN).unwrap();
    let ds = eval_subset(8);
    for x in &ds.x {
        assert_eq!(sim::run(&cm_sparse, x).logits, sim::run(&cm_dense, x).logits);
    }
}

#[test]
fn pallas_and_ref_lowerings_agree_through_pjrt() {
    // the runtime ships the fast jnp-ref lowering; the Pallas/CMUL
    // lowering is the semantics artifact. Both must compute the same
    // integer function on the rust PJRT client.
    if !artifacts_ready()
        || !std::path::Path::new(ARTIFACT_DIR).join("model_pallas_b1.hlo.txt").exists()
    {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut rt = va_accel::runtime::Runtime::cpu().unwrap();
    let ds = eval_subset(8);
    for x in &ds.x {
        let a = rt.infer(format!("{ARTIFACT_DIR}/model_b1.hlo.txt"), 1,
                         std::slice::from_ref(x)).unwrap();
        let b = rt.infer(format!("{ARTIFACT_DIR}/model_pallas_b1.hlo.txt"), 1,
                         std::slice::from_ref(x)).unwrap();
        assert_eq!(a, b);
    }
}
