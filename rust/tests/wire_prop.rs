//! Property tests for the `serve_net::wire` frame codec.
//!
//! `tests/serve_net.rs` covers hand-picked malformed cases; this suite
//! sweeps seeds instead:
//!
//! * encode→decode round-trip over every frame tag, ragged payload
//!   sizes included;
//! * random truncation of valid frames always yields an error, never
//!   a panic and never a bogus frame;
//! * random byte corruption never panics, and anything that still
//!   decodes re-encodes to a stable byte representation (one
//!   decode–encode pass is a fixed point);
//! * hostile length prefixes (zero, huge, longer-than-available) fail
//!   with the right `WireError` class *before* committing memory.

use va_accel::coordinator::wire::{decode, encode, read_frame, Frame,
                                  WireError, MAX_FRAME_BYTES};
use va_accel::data::SplitMix64;

/// All ten frame variants, seed-driven. Index pins the variant so a
/// sweep covers every tag; the payload contents are random. f32
/// samples are generated finite so `Frame: PartialEq` is usable on
/// the round-trip (NaN payloads are exercised in the corruption pass
/// via the byte-level fixed-point check instead).
fn rand_frame(rng: &mut SplitMix64, variant: usize) -> Frame {
    let token_len = (rng.next_u64() % 12) as usize;
    let token: String = (0..token_len)
        .map(|_| char::from(b'a' + (rng.next_u64() % 26) as u8))
        .collect();
    let vec_len = (rng.next_u64() % 37) as usize;
    match variant % 10 {
        0 => Frame::Hello { token, device_id: rng.next_u64() },
        1 => Frame::SamplesF32(
            (0..vec_len).map(|_| rng.range(-4.0, 4.0) as f32).collect()),
        2 => Frame::SamplesI8(
            (0..vec_len).map(|_| rng.next_u64() as i8).collect()),
        3 => Frame::SubscribeStats,
        4 => Frame::Goodbye,
        5 => Frame::Welcome { session: rng.next_u64(),
                              hop: rng.next_u64() as u32,
                              frame_len: rng.next_u64() as u32 },
        6 => Frame::Diagnosis { window: rng.next_u64(),
                                logits: [rng.next_u64() as i32,
                                         rng.next_u64() as i32],
                                is_va: rng.next_u64() % 2 == 0 },
        7 => Frame::Stats { sessions: rng.next_u64(),
                            windows: rng.next_u64(),
                            samples: rng.next_u64(),
                            busy: rng.next_u64(),
                            evicted: rng.next_u64() },
        8 => Frame::Busy { dropped: rng.next_u64() as u32 },
        _ => Frame::Error { code: rng.next_u64() as u16, msg: token },
    }
}

#[test]
fn roundtrip_all_tags_seed_swept() {
    let mut tags_seen = std::collections::HashSet::new();
    for seed in 0..64u64 {
        let mut rng = SplitMix64::new(0xC0DEC ^ seed);
        for variant in 0..10 {
            let f = rand_frame(&mut rng, variant);
            let bytes = encode(&f);
            tags_seen.insert(bytes[4]);
            // via the reader path (length prefix included)
            let got = read_frame(&mut &bytes[..], MAX_FRAME_BYTES)
                .unwrap_or_else(|e| panic!("seed {seed} variant {variant}: \
                                            {e}"));
            assert_eq!(got, f, "seed {seed} variant {variant}");
            // and via the body path (tag already split off)
            let got2 = decode(bytes[4], &bytes[5..]).unwrap();
            assert_eq!(got2, f);
            // encoding is canonical: re-encode is byte-identical
            assert_eq!(encode(&got), bytes);
        }
    }
    assert_eq!(tags_seen.len(), 10, "sweep must cover every frame tag");
}

#[test]
fn truncation_always_errors_never_panics() {
    let mut rng = SplitMix64::new(0x7A0);
    for variant in 0..10 {
        let f = rand_frame(&mut rng, variant);
        let bytes = encode(&f);
        for cut in 0..bytes.len() {
            let r = read_frame(&mut &bytes[..cut], MAX_FRAME_BYTES);
            let e = match r {
                Err(e) => e,
                Ok(f) => panic!("variant {variant} cut {cut}: truncated \
                                 frame decoded as {f:?}"),
            };
            // a clean cut is an IO-class error (unexpected EOF), not
            // a malformed-grammar claim about bytes we never saw —
            // EXCEPT a cut that leaves only a zero-length prefix
            // (cut >= 4 with frames whose first length byte is 0 is
            // impossible: encode never emits len 0)
            assert!(e.is_io() || !matches!(e, WireError::Oversized(_)),
                    "variant {variant} cut {cut}: {e}");
        }
    }
}

#[test]
fn corruption_never_panics_and_decodes_are_stable() {
    let mut rng = SplitMix64::new(0xBAD);
    let mut survived = 0usize;
    for round in 0..200 {
        let f = rand_frame(&mut rng, round % 10);
        let mut bytes = encode(&f);
        let flips = 1 + (rng.next_u64() % 4) as usize;
        for _ in 0..flips {
            let at = (rng.next_u64() as usize) % bytes.len();
            bytes[at] ^= (rng.next_u64() % 255 + 1) as u8;
        }
        // must not panic; may legitimately still parse (e.g. a payload
        // byte of SAMPLES_I8 flipped is just different samples)
        match read_frame(&mut &bytes[..], MAX_FRAME_BYTES) {
            Err(_) => {}
            Ok(f2) => {
                survived += 1;
                // whatever parsed must have a stable canonical form:
                // encode(decode(encode(x))) == encode(x). Compare at
                // the byte level — NaN f32 payloads defeat PartialEq.
                let b2 = encode(&f2);
                let f3 = read_frame(&mut &b2[..], MAX_FRAME_BYTES)
                    .expect("canonical re-encode must decode");
                assert_eq!(encode(&f3), b2, "round {round}: decode–encode \
                                             is not a fixed point");
            }
        }
    }
    // the property above is vacuous if nothing ever survives a flip;
    // single-byte payload flips on SamplesI8/F32 parse by design
    assert!(survived > 0, "corruption sweep never exercised the Ok arm");
}

#[test]
fn decode_never_panics_on_any_tag() {
    let mut rng = SplitMix64::new(0xFEED);
    let payloads: Vec<Vec<u8>> = vec![
        vec![],
        vec![0],
        vec![0xFF; 3],
        vec![0xAB; 8],
        vec![0xCD; 17],
        (0..64u8).collect(),
        vec![0xFF; 40],
        (0..40).map(|_| rng.next_u64() as u8).collect(),
    ];
    for tag in 0u8..=255 {
        for p in &payloads {
            // Ok or Err both fine; panics are the failure mode
            let _ = decode(tag, p);
        }
    }
}

#[test]
fn hostile_length_prefixes() {
    // zero length: malformed, not io
    let z = [0u8, 0, 0, 0];
    match read_frame(&mut &z[..], MAX_FRAME_BYTES) {
        Err(WireError::Malformed(_)) => {}
        other => panic!("zero len: {other:?}"),
    }
    // huge declared length: rejected as oversized BEFORE allocation
    let mut huge = u32::MAX.to_le_bytes().to_vec();
    huge.push(1);
    match read_frame(&mut &huge[..], MAX_FRAME_BYTES) {
        Err(WireError::Oversized(n)) => assert_eq!(n, u32::MAX),
        other => panic!("huge len: {other:?}"),
    }
    // length within the cap but longer than the available bytes: an
    // IO-class error (peer hung up mid-frame)
    let mut short = 100u32.to_le_bytes().to_vec();
    short.extend_from_slice(&[3, 1, 2]);
    match read_frame(&mut &short[..], MAX_FRAME_BYTES) {
        Err(e) if e.is_io() => {}
        other => panic!("short body: {other:?}"),
    }
    // a tiny negotiated cap rejects frames a permissive one accepts
    let ok = encode(&Frame::Goodbye);
    assert!(read_frame(&mut &ok[..], MAX_FRAME_BYTES).is_ok());
    let big = encode(&Frame::SamplesI8(vec![1; 64]));
    match read_frame(&mut &big[..], 8) {
        Err(WireError::Oversized(n)) => assert_eq!(n, 65),
        other => panic!("tiny cap: {other:?}"),
    }
}
