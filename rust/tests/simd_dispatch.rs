//! Dispatch-equivalence suite for the SIMD tile kernels: the
//! [`va_accel::arch::KernelTier`] runtime dispatch must be invisible
//! in every observable output. Both tiers are exercised on every host
//! — `KernelTier::Avx2` safely falls back to the scalar twin when the
//! CPU lacks the feature, so these tests never need feature-gating —
//! and every comparison is anchored to the golden integer model, not
//! just tier-vs-tier.
//!
//! Coverage per the dispatch contract (DESIGN.md §"Sub-byte weight
//! words & kernel dispatch"):
//!
//! * seed-swept bit-exactness of scalar vs SIMD tiers over the paper
//!   and ragged fixtures (the ragged model's last conv stripe runs at
//!   `live = 1`, the partial-stripe extreme);
//! * all sub-byte widths `nbits ∈ {2, 4, 8}` mixed in one model;
//! * empty pruned lanes (a fully-zeroed output channel contributes an
//!   empty weight stream that the kernels must skip, not misindex);
//! * streaming hop sweeps under both pinned tiers
//!   ([`StreamingEngine::with_tier`]);
//! * the pack→unpack property: the sub-byte weight words round-trip
//!   every lane's `(selects, weights)` exactly on every fixture.

use std::sync::Arc;

use va_accel::arch::{tile_block, ChipConfig, KernelTier, WeightStream};
use va_accel::compiler::compile;
use va_accel::data::fixtures;
use va_accel::data::SplitMix64;
use va_accel::nn::{QLayer, QuantModel};
use va_accel::sim::{run_scratch_tier, ScratchArena, StreamingEngine};
use va_accel::REC_LEN;

const TIERS: [KernelTier; 2] = [KernelTier::Scalar, KernelTier::Avx2];

fn recording(seed: u64, n: usize) -> Vec<i8> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.range(-127.0, 128.0) as i8).collect()
}

/// Every tier's logits must equal the golden model on every recording.
fn assert_tiers_match_golden(m: &QuantModel, l_in: usize, seeds: u64) {
    let cm = compile(m, &ChipConfig::paper_1d(), l_in).unwrap();
    let mut s = ScratchArena::for_model(&cm);
    for seed in 0..seeds {
        let x = recording(0x5EED ^ seed, l_in);
        let golden = m.forward(&x);
        for tier in TIERS {
            let r = run_scratch_tier(&cm, &x, &mut s, tier);
            assert_eq!(r.logits, golden, "tier {tier}, seed {seed}");
        }
    }
}

#[test]
fn paper_fixture_is_tier_invariant_across_seeds() {
    for model_seed in [0xA5u64, 0x5A, 0xC0FFEE] {
        let m = fixtures::quant_model(model_seed);
        assert_tiers_match_golden(&m, REC_LEN, 6);
    }
}

#[test]
fn ragged_fixture_is_tier_invariant_down_to_live_1() {
    // the ragged fixture's 33-channel conv layer leaves its last
    // stripe at live = 1 — the narrowest partial stripe possible
    for model_seed in [1u64, 0xBAD, 0xFACE] {
        let m = fixtures::ragged_model(model_seed);
        assert_tiers_match_golden(&m, fixtures::RAGGED_LEN, 6);
    }
}

#[test]
fn mixed_sub_byte_widths_are_tier_invariant() {
    // one model exercising every packed width: 16, 8 and 4
    // weights/word (nbits 2, 4, 8)
    let m = fixtures::model_from_geometry(0x2481, &[
        (7, 2, 1, 10, 2),
        (5, 2, 10, 14, 4),
        (3, 2, 14, 18, 8),
        (3, 1, 18, 9, 2),
        (1, 1, 9, 2, 8),
    ]);
    assert_tiers_match_golden(&m, 64, 8);
}

#[test]
fn empty_pruned_lanes_are_tier_invariant() {
    // channel 1 of layer 0 is fully pruned: its stream is empty and
    // both kernels must emit exactly its bias at every position
    let m = QuantModel { layers: vec![
        QLayer { k: 3, stride: 2, cin: 1, cout: 4, relu: true, nbits: 4,
                 shift: 24, s_in: 1.0, s_out: 1.0,
                 w: vec![1, 0, -7, 0,
                         3, 0,  2, 0,
                         0, 0, -1, 0],
                 bias: vec![10, -3, 7, 0], m0: vec![1 << 23; 4] },
        QLayer { k: 1, stride: 1, cin: 4, cout: 2, relu: false, nbits: 2,
                 shift: 0, s_in: 1.0, s_out: 1.0,
                 w: vec![1, -1, 0, 0, 1, 1, -1, 0],
                 bias: vec![5, -5], m0: vec![0, 0] },
    ]};
    assert_tiers_match_golden(&m, 16, 10);
}

#[test]
fn streaming_hop_sweep_is_tier_invariant() {
    let m = fixtures::quant_model(0x57EA);
    let cm = Arc::new(
        compile(&m, &ChipConfig::paper_1d(), REC_LEN).unwrap());
    let mut s = ScratchArena::for_model(&cm);
    for hop in [1usize, 13, 32, 128, REC_LEN] {
        let stream = recording(hop as u64 + 99, REC_LEN + hop * 3);
        let mut per_tier: Vec<Vec<Vec<i32>>> = Vec::new();
        for tier in TIERS {
            let mut eng =
                StreamingEngine::with_tier(Arc::clone(&cm), hop, tier)
                    .unwrap();
            assert_eq!(eng.kernel_tier(), tier);
            let outs = eng.push(&stream);
            assert_eq!(outs.len(), 4, "hop {hop}");
            // every window bit-exact vs the scalar per-window path
            for (i, o) in outs.iter().enumerate() {
                let w = &stream[i * hop..i * hop + REC_LEN];
                let full = run_scratch_tier(&cm, w, &mut s,
                                            KernelTier::Scalar);
                assert_eq!(o.logits, full.logits,
                           "hop {hop}, window {i}, tier {tier}");
            }
            per_tier.push(outs.into_iter().map(|o| o.logits).collect());
        }
        assert_eq!(per_tier[0], per_tier[1], "hop {hop}");
    }
}

#[test]
fn ragged_streaming_is_tier_invariant() {
    let m = fixtures::ragged_model(0x9e37);
    let cm = Arc::new(
        compile(&m, &ChipConfig::paper_1d(), fixtures::RAGGED_LEN).unwrap());
    let mut s = ScratchArena::for_model(&cm);
    for hop in [1usize, 7, 16] {
        let stream = recording(hop as u64, fixtures::RAGGED_LEN + hop * 2);
        for tier in TIERS {
            let mut eng =
                StreamingEngine::with_tier(Arc::clone(&cm), hop, tier)
                    .unwrap();
            for (i, o) in eng.push(&stream).iter().enumerate() {
                let w =
                    &stream[i * hop..i * hop + fixtures::RAGGED_LEN];
                let full = run_scratch_tier(&cm, w, &mut s,
                                            KernelTier::Scalar);
                assert_eq!(o.logits, full.logits,
                           "hop {hop}, window {i}, tier {tier}");
            }
        }
    }
}

/// Pack `i32` weights into `wbits`-bit two's-complement fields,
/// LSB-first, `32 / wbits` per word — the arena's physical layout.
fn pack_words(weights: &[i32], wbits: u32) -> Vec<u32> {
    let per = (32 / wbits) as usize;
    let mask = if wbits == 32 { u32::MAX } else { (1u32 << wbits) - 1 };
    let mut words = vec![0u32; weights.len().div_ceil(per).max(1)];
    for (i, &w) in weights.iter().enumerate() {
        words[i / per] |= (w as u32 & mask) << ((i % per) as u32 * wbits);
    }
    words
}

#[test]
fn fringe_b2_kernel_matches_scalar_direct() {
    // Direct pin of the gather-free B=2 vector rung (the PR 7
    // follow-on): both tiers over the same synthetic stream arena —
    // odd/even/empty lane lengths, every sub-byte width, non-zero
    // stripe base — must write identical stripes.
    let rows = 8usize; // staged rows, B = 2 columns each
    for wbits in [2u32, 4, 8] {
        let lim = 1i32 << (wbits - 1); // fields span [-lim, lim)
        let mut rng = SplitMix64::new(0xB2 + wbits as u64);
        // lanes: odd tail, even, empty, and a long odd one
        let lens = [5usize, 4, 0, 9];
        let live = lens.len();
        let total: usize = lens.iter().sum();
        let selects: Vec<u32> = (0..total)
            .map(|_| (rng.next_u64() % rows as u64) as u32).collect();
        let weights: Vec<i32> = (0..total)
            .map(|_| (rng.next_u64() % (2 * lim as u64)) as i32 - lim)
            .collect();
        let words = pack_words(&weights, wbits);
        let mut ranges = Vec::new();
        let mut off = 0u32;
        for &l in &lens {
            ranges.push((off, l as u32));
            off += l as u32;
        }
        let biases: Vec<i32> = (0..live)
            .map(|_| (rng.next_u64() % 2001) as i32 - 1000).collect();
        let stage: Vec<i32> = (0..rows * 2)
            .map(|_| (rng.next_u64() % 200_001) as i32 - 100_000)
            .collect();
        let ws = WeightStream { selects: &selects, weights: &weights,
                                words: &words, wbits };
        let lo = 1usize;
        let mut want = vec![0i32; (lo + 2) * live];
        let mut got = want.clone();
        tile_block::<2>(KernelTier::Scalar, ws, &ranges, &biases,
                        &stage, &mut want, lo, live);
        tile_block::<2>(KernelTier::Avx2, ws, &ranges, &biases,
                        &stage, &mut got, lo, live);
        assert_eq!(got, want, "wbits {wbits}");
        // empty lane 2 must be exactly its bias at both positions
        assert_eq!(want[lo * live + 2], biases[2], "wbits {wbits}");
        assert_eq!(want[(lo + 1) * live + 2], biases[2], "wbits {wbits}");
    }
}

#[test]
fn sub_byte_pack_unpack_round_trips_every_lane() {
    // property over every fixture family: decoding the packed words
    // reproduces each lane's (selects, weights) exactly — selects are
    // untouched by packing, weights survive the sub-byte round trip
    let cases: Vec<(QuantModel, usize)> = vec![
        (fixtures::quant_model(0xF1D0), REC_LEN),
        (fixtures::ragged_model(0xF1D1), fixtures::RAGGED_LEN),
        (fixtures::model_from_geometry(0xF1D2, &[
            (5, 2, 1, 7, 2), (3, 2, 7, 11, 4), (1, 1, 11, 2, 8),
        ]), 32),
    ];
    for (ci, (m, l_in)) in cases.iter().enumerate() {
        let cm = compile(m, &ChipConfig::paper_1d(), *l_in).unwrap();
        let mut buf = Vec::new();
        for (li, layer) in cm.layers.iter().enumerate() {
            let ps = &layer.packed;
            assert_eq!(ps.wbits(), layer.nbits.max(2),
                       "case {ci}, layer {li}");
            for t in 0..ps.ch_tiles() {
                for lane in 0..ps.m() {
                    let v = ps.lane(t, lane);
                    ps.unpack_lane(t, lane, &mut buf);
                    assert_eq!(buf.as_slice(), v.weights,
                               "case {ci}, layer {li}, tile {t}, \
                                lane {lane}");
                }
            }
        }
    }
}
