//! Fault-campaign determinism and recovery contracts, end to end.
//!
//! The reliability layer's whole value is that a fault campaign is a
//! *reproducible experiment*: the same seed must name the same fault
//! sites, trip the same detectors at the same windows, and recover to
//! the same bit-exact state — on any host, forever. These tests pin
//! that contract above the unit level (`reliability::faults` /
//! `reliability::integrity` own the per-function tests):
//!
//! * plan determinism across construction, not just equality of the
//!   `FaultPlan` value;
//! * scrub restoring the packed arena *byte*-identical (CRC equality
//!   is necessary, not sufficient);
//! * canary trip windows being a pure function of (seed, cadence),
//!   with post-resync streams re-converging bit-exact against an
//!   unfaulted oracle;
//! * supervised fleet recovery delivering a deterministic diagnosis
//!   multiset under an injected worker panic.
//!
//! Hermetic: fixture model throughout.

use std::sync::Arc;
use std::time::Duration;

use va_accel::arch::ChipConfig;
use va_accel::compiler::{compile, CompiledModel};
use va_accel::coordinator::{Backend, Fleet, FleetConfig, StreamSession};
use va_accel::data::{fixtures, SplitMix64};
use va_accel::reliability::{integrity, FaultKind, FaultPlan, GoldenVector,
                            PlannedFault};
use va_accel::REC_LEN;

const HOP: usize = 128;

fn cm() -> CompiledModel {
    compile(&fixtures::quant_model(0xFA17), &ChipConfig::paper_1d(),
            REC_LEN).unwrap()
}

fn stream(seed: u64, windows: usize) -> Vec<i8> {
    let mut rng = SplitMix64::new(seed);
    (0..REC_LEN + HOP * windows)
        .map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8).collect()
}

/// Run a seeded carry campaign at one cadence; return (trip windows,
/// per-window logits).
fn carry_campaign(cm: &Arc<CompiledModel>, seed: u64, cadence: u64,
                  windows: usize) -> (Vec<usize>, Vec<[i32; 2]>) {
    let xs = stream(seed, windows);
    let plan = FaultPlan::carry_seu(seed, {
        let s = StreamSession::new(Arc::clone(cm), HOP).unwrap();
        s.carry_words()
    }, 24, windows as u64);
    let mut sess = StreamSession::new(Arc::clone(cm), HOP).unwrap();
    sess.set_canary(cadence);
    let mut logits = Vec::new();
    let mut trip_windows = Vec::new();
    let mut trips_seen = 0u64;
    logits.push(sess.push_quantized(&xs[..REC_LEN])[0].logits);
    for w in 1..=windows {
        for f in plan.due_at(w as u64) {
            if let FaultKind::CarryWord { index, xor } = f.kind {
                sess.corrupt_carry(index, xor);
            }
        }
        let lo = REC_LEN + (w - 1) * HOP;
        logits.push(sess.push_quantized(&xs[lo..lo + HOP])[0].logits);
        let trips = sess.stats().canary_trips;
        if trips > trips_seen {
            trip_windows.push(w);
            trips_seen = trips;
        }
    }
    (trip_windows, logits)
}

#[test]
fn weight_campaign_is_deterministic_and_scrub_restores_bytes() {
    let mut a = cm();
    let mut b = cm();
    let pristine: Vec<Vec<u32>> = a.layers.iter()
        .map(|ly| ly.packed.weight_words().to_vec()).collect();
    let golden = GoldenVector::stamp(&a);
    for target in [&mut a, &mut b] {
        let plan = FaultPlan::weight_seu(0x5EED, target, 24, 4);
        for f in &plan.faults {
            if let FaultKind::WeightBit { layer, word, bit } = f.kind {
                assert!(target.layers[layer].packed.flip_word_bit(word, bit));
            }
        }
    }
    // same seed ⇒ the two models are corrupted identically
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.packed.weight_words(), lb.packed.weight_words());
    }
    // and detection names the same layers on both
    assert_eq!(integrity::verify(&a), integrity::verify(&b));
    assert!(!integrity::verify(&a).is_empty());
    // scrub restores the arena BYTE-identical, not merely CRC-clean
    let rep = integrity::scrub(&mut a);
    assert!(rep.restored && !rep.corrupted.is_empty());
    for (ly, orig) in a.layers.iter().zip(&pristine) {
        assert_eq!(ly.packed.weight_words(), orig.as_slice());
    }
    assert!(golden.check(&a), "restored arena must re-pass the golden \
                               vector");
}

#[test]
fn carry_trip_windows_are_a_pure_function_of_seed_and_cadence() {
    let cm = Arc::new(cm());
    let (trips_a, logits_a) = carry_campaign(&cm, 0xCAFE, 1, 12);
    let (trips_b, logits_b) = carry_campaign(&cm, 0xCAFE, 1, 12);
    assert_eq!(trips_a, trips_b, "identical campaigns must trip at \
                                  identical windows");
    assert_eq!(logits_a, logits_b);
    assert!(!trips_a.is_empty(), "24 seeded carry faults never tripped a \
                                  cadence-1 canary");
    // a different seed faults different sites — trips may land on
    // different windows (and at minimum the plans differ)
    assert_ne!(FaultPlan::carry_seu(0xCAFE, 1024, 24, 12),
               FaultPlan::carry_seu(0xCAFF, 1024, 24, 12));
}

#[test]
fn cadence_one_canary_emits_only_oracle_exact_windows() {
    let cm = Arc::new(cm());
    let windows = 12;
    let (_, logits) = carry_campaign(&cm, 0xCAFE, 1, windows);
    // unfaulted oracle over the identical stream
    let xs = stream(0xCAFE, windows);
    let mut oracle = StreamSession::new(Arc::clone(&cm), HOP).unwrap();
    let mut want = vec![oracle.push_quantized(&xs[..REC_LEN])[0].logits];
    for w in 1..=windows {
        let lo = REC_LEN + (w - 1) * HOP;
        want.push(oracle.push_quantized(&xs[lo..lo + HOP])[0].logits);
    }
    assert_eq!(logits, want, "every window a cadence-1 canary emits must \
                              match the unfaulted oracle bit-exact");
}

#[test]
fn external_resync_reconverges_bit_exact() {
    // corrupt the slab, then recover via the supervisor-facing resync()
    // hook (no canary armed): the next window re-primes FULL and every
    // later window matches the oracle.
    let cm = Arc::new(cm());
    let windows = 8;
    let xs = stream(0x5C4B, windows);
    let mut sess = StreamSession::new(Arc::clone(&cm), HOP).unwrap();
    let mut oracle = StreamSession::new(Arc::clone(&cm), HOP).unwrap();
    sess.push_quantized(&xs[..REC_LEN]);
    oracle.push_quantized(&xs[..REC_LEN]);
    for i in (0..sess.carry_words()).step_by(3) {
        sess.corrupt_carry(i, 0x40_0000);
    }
    sess.resync();
    for w in 1..=windows {
        let lo = REC_LEN + (w - 1) * HOP;
        let got = sess.push_quantized(&xs[lo..lo + HOP]);
        let want = oracle.push_quantized(&xs[lo..lo + HOP]);
        assert_eq!(got[0].logits, want[0].logits,
                   "window {w} diverged after an external resync");
    }
    assert_eq!(sess.stats().resyncs, 1);
}

#[test]
fn fleet_panic_recovery_is_deterministic() {
    let run = || {
        let mut cfg = FleetConfig::new(1);
        cfg.batcher.max_batch = 1;
        cfg.batcher.max_age = Duration::ZERO;
        cfg.vote_group = 1;
        cfg.fault_plan = FaultPlan {
            seed: 0xF1EE7,
            faults: vec![PlannedFault {
                at_window: 0,
                kind: FaultKind::WorkerPanic { shard: 0, after: 2 },
            }],
        };
        let fleet = Fleet::spawn(cfg, |_| {
            Ok(Backend::chipsim(compile(&fixtures::quant_model(0xFA17),
                                        &ChipConfig::paper_1d(), REC_LEN)?))
        }).unwrap();
        let h = fleet.handle();
        let mut rng = SplitMix64::new(0xF1EE7);
        let n = 10;
        for _ in 0..n {
            let rec: Vec<i8> = (0..REC_LEN)
                .map(|_| ((rng.next_u64() % 255) as i64 - 127) as i8)
                .collect();
            h.submit(rec).unwrap();
        }
        h.flush().unwrap();
        let mut preds: Vec<[i32; 2]> = (0..n)
            .map(|_| fleet.recv().expect("fleet died mid-campaign").1
                 .detections[0].logits)
            .collect();
        preds.sort_unstable();
        let rep = fleet.shutdown();
        assert_eq!(rep.respawns, 1);
        preds
    };
    assert_eq!(run(), run(), "identical panic campaigns must deliver \
                              identical diagnosis multisets");
}
